"""Paper Fig. 8 analogue: op-category accounting, before/after the
gather -> shuffle rewrite.

The paper's profiler exposed compiler-generated gather/scatter in the
bulk stencil; replacing them with register shuffles fixed a ~10x
slowdown.  We reproduce both versions and report (a) wall time, (b) the
HLO op-category census (gather ops vs shuffle/select ops), confirming the
shuffle version contains no gathers.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.core import evenodd, su3
from .common import Row, time_fn
from .naive_gather import hop_block_gather


def _hlo_census(fn, *args) -> dict:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    cats = {"gather": 0, "scatter": 0, "select": 0, "slice": 0,
            "concatenate": 0, "dot": 0}
    for line in txt.splitlines():
        for k in cats:
            if re.search(rf"\b{k}\(", line) or \
                    re.search(rf"= [a-z0-9\[\],{{}}]+ {k}", line):
                cats[k] += 1
    return cats


def run() -> list:
    rows: list[Row] = []
    T, Z, Y, X = 8, 8, 8, 16
    U = su3.random_gauge(jax.random.PRNGKey(0), (T, Z, Y, X))
    psi = (jax.random.normal(jax.random.PRNGKey(1), (T, Z, Y, X, 4, 3))
           + 1j * jax.random.normal(jax.random.PRNGKey(2),
                                    (T, Z, Y, X, 4, 3))
           ).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    e, _ = evenodd.pack(psi)

    shuffle_fn = jax.jit(
        lambda a, b, c: evenodd.hop_block(a, b, c, evenodd.ODD))
    gather_fn = jax.jit(
        lambda a, b, c: hop_block_gather(a, b, c, evenodd.ODD))

    # correctness of the naive version first
    d = float(jnp.max(jnp.abs(shuffle_fn(Ue, Uo, e)
                              - gather_fn(Ue, Uo, e))))
    assert d < 1e-4, f"gather version diverges: {d}"

    us_s = time_fn(shuffle_fn, Ue, Uo, e)
    us_g = time_fn(gather_fn, Ue, Uo, e)
    vol = T * Z * Y * X
    rows.append(("breakdown_shuffle_hop", us_s,
                 f"gflops={660 * vol / (us_s * 1e-6) / 1e9:.2f}"))
    rows.append(("breakdown_gather_hop", us_g,
                 f"slowdown_vs_shuffle={us_g / us_s:.2f}x"))

    cs = _hlo_census(lambda a, b, c: evenodd.hop_block(a, b, c, 1),
                     Ue, Uo, e)
    cg = _hlo_census(lambda a, b, c: hop_block_gather(a, b, c, 1),
                     Ue, Uo, e)
    rows.append(("breakdown_shuffle_hlo_gathers", 0.0,
                 f"gather_ops={cs['gather']};select_ops={cs['select']}"))
    rows.append(("breakdown_gather_hlo_gathers", 0.0,
                 f"gather_ops={cg['gather']};select_ops={cg['select']}"))
    return rows
