"""Paper Fig. 8 analogue: op-category accounting, before/after the
gather -> shuffle rewrite.

The paper's profiler exposed compiler-generated gather/scatter in the
bulk stencil; replacing them with register shuffles fixed a ~10x
slowdown.  We reproduce both versions and report (a) wall time, (b) the
HLO op-category census (gather ops vs shuffle/select ops), confirming the
shuffle version contains no gathers.

Also times the fused single-kernel ``Dhat`` (odd intermediate resident in
VMEM scratch) against the unfused two-``pallas_call`` path that
round-trips the intermediate through HBM, and isolates the per-call
layout-conversion + device-placement tax the old complex-interface
operators paid versus the native-domain path the solver now iterates on.

Rows are printed as CSV and mirrored to ``BENCH_breakdown.json``.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro import backends
from repro.core import evenodd, su3
from repro.kernels import layout, ops
from .common import Row, smoke, time_fn, write_json
from .naive_gather import hop_block_gather


def _timing_kw():
    return {"warmup": 1, "iters": 3} if smoke() else {}


def _rand_eo(shape, seed):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    psi = (jax.random.normal(jax.random.PRNGKey(seed + 1), (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.PRNGKey(seed + 2),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    e, _ = evenodd.pack(psi)
    return Ue, Uo, e


def _hlo_census(fn, *args) -> dict:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    cats = {"gather": 0, "scatter": 0, "select": 0, "slice": 0,
            "concatenate": 0, "dot": 0}
    for line in txt.splitlines():
        for k in cats:
            if re.search(rf"\b{k}\(", line) or \
                    re.search(rf"= [a-z0-9\[\],{{}}]+ {k}", line):
                cats[k] += 1
    return cats


def run() -> list:
    rows: list[Row] = []
    T, Z, Y, X = (4, 4, 4, 8) if smoke() else (8, 8, 8, 16)
    Ue, Uo, e = _rand_eo((T, Z, Y, X), seed=0)

    shuffle_fn = jax.jit(
        lambda a, b, c: evenodd.hop_block(a, b, c, evenodd.ODD))
    gather_fn = jax.jit(
        lambda a, b, c: hop_block_gather(a, b, c, evenodd.ODD))

    # correctness of the naive version first
    d = float(jnp.max(jnp.abs(shuffle_fn(Ue, Uo, e)
                              - gather_fn(Ue, Uo, e))))
    assert d < 1e-4, f"gather version diverges: {d}"

    us_s = time_fn(shuffle_fn, Ue, Uo, e, **_timing_kw())
    us_g = time_fn(gather_fn, Ue, Uo, e, **_timing_kw())
    vol = T * Z * Y * X
    rows.append(("breakdown_shuffle_hop", us_s,
                 f"gflops={660 * vol / (us_s * 1e-6) / 1e9:.2f}"))
    rows.append(("breakdown_gather_hop", us_g,
                 f"slowdown_vs_shuffle={us_g / us_s:.2f}x"))

    cs = _hlo_census(lambda a, b, c: evenodd.hop_block(a, b, c, 1),
                     Ue, Uo, e)
    cg = _hlo_census(lambda a, b, c: hop_block_gather(a, b, c, 1),
                     Ue, Uo, e)
    rows.append(("breakdown_shuffle_hlo_gathers", 0.0,
                 f"gather_ops={cs['gather']};select_ops={cs['select']}"))
    rows.append(("breakdown_gather_hlo_gathers", 0.0,
                 f"gather_ops={cg['gather']};select_ops={cg['select']}"))
    rows.extend(_dhat_fusion_rows())
    rows.extend(_conversion_rows())
    write_json("breakdown", rows)
    return rows


def _dhat_fusion_rows() -> list:
    """Fused single-kernel Dhat vs the two-kernel HBM round-trip path.

    Off-TPU both run the Pallas interpreter, so absolute numbers are not
    hardware-meaningful there — the row notes which mode produced them.
    The eliminated traffic (one spinor write + its 5-plane pipelined
    re-read) is reported alongside.
    """
    rows: list[Row] = []
    T, Z, Y, X = (4, 4, 4, 8) if smoke() else (8, 8, 8, 8)
    kappa = 0.13
    Ue, Uo, e = _rand_eo((T, Z, Y, X), seed=3)
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)

    unfused_fn = jax.jit(lambda a, b, c: ops.apply_dhat_planar(
        a, b, c, kappa))
    fused_fn = jax.jit(lambda a, b, c: ops.apply_dhat_planar_fused(
        a, b, c, kappa))

    d = float(jnp.max(jnp.abs(fused_fn(Uep, Uop, ep)
                              - unfused_fn(Uep, Uop, ep))))
    assert d < 1e-5, f"fused Dhat diverges from unfused: {d}"

    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    us_u = time_fn(unfused_fn, Uep, Uop, ep, **_timing_kw())
    us_f = time_fn(fused_fn, Uep, Uop, ep, **_timing_kw())
    tmp_bytes = 4 * 24 * T * Z * Y * (X // 2)
    saved = tmp_bytes * 6  # 1 HBM write + 5 neighbor-plane re-reads
    rows.append(("breakdown_dhat_unfused", us_u,
                 f"mode={mode};tmp_hbm_bytes={tmp_bytes}"))
    rows.append(("breakdown_dhat_fused", us_f,
                 f"mode={mode};speedup_vs_unfused={us_u / us_f:.2f}x;"
                 f"hbm_bytes_eliminated={saved}"))
    return rows


def _conversion_rows() -> list:
    """Layout-conversion + placement tax per ``apply_dhat`` call.

    The old complex-interface path pays ``spinor_to_planar`` /
    ``spinor_from_planar`` (and, for the distributed backend, a
    ``device_put``) on *every* operator application; the native-domain
    path the solver now iterates on pays them once per solve.  The
    difference between the two timed rows is exactly that per-call tax.
    """
    rows: list[Row] = []
    shape = (4, 4, 4, 8) if smoke() else (8, 8, 8, 8)
    kappa = 0.13
    Ue, Uo, e = _rand_eo(shape, seed=7)
    on_tpu = jax.default_backend() == "tpu"

    cases = [("pallas_fused", {} if on_tpu else {"interpret": True}),
             ("distributed", {})]
    for name, opts in cases:
        bops = backends.make_wilson_ops(name, Ue, Uo, **opts)
        v = bops.to_domain(e)
        complex_fn = lambda psi: bops.apply_dhat(psi, kappa)  # noqa: E731
        native_fn = lambda w: bops.apply_dhat_native(w, kappa)  # noqa: E731
        us_c = time_fn(complex_fn, e, **_timing_kw())
        us_n = time_fn(native_fn, v, **_timing_kw())
        mode = "tpu" if on_tpu else "interpret"
        rows.append((f"breakdown_dhat_complex_iface_{name}", us_c,
                     f"mode={mode};domain={bops.domain}"))
        rows.append((f"breakdown_dhat_native_iface_{name}", us_n,
                     f"mode={mode};domain={bops.domain};"
                     f"conversion_overhead_us={us_c - us_n:.1f};"
                     f"conversion_overhead_pct="
                     f"{100.0 * (us_c - us_n) / max(us_c, 1e-9):.1f}"))
    return rows
