"""Paper Fig. 8 analogue: op-category accounting, before/after the
gather -> shuffle rewrite.

The paper's profiler exposed compiler-generated gather/scatter in the
bulk stencil; replacing them with register shuffles fixed a ~10x
slowdown.  We reproduce both versions and report (a) wall time, (b) the
HLO op-category census (gather ops vs shuffle/select ops), confirming the
shuffle version contains no gathers.

Also times the fused single-kernel ``Dhat`` (odd intermediate resident in
VMEM scratch) against the unfused two-``pallas_call`` path that
round-trips the intermediate through HBM, and isolates the per-call
layout-conversion + device-placement tax the old complex-interface
operators paid versus the native-domain path the solver now iterates on.

Rows are printed as CSV and mirrored to ``BENCH_breakdown.json``.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro import api, backends
from repro.core import evenodd, su3
from repro.kernels import layout, ops

from .common import Row, smoke, time_fn, write_json
from .naive_gather import hop_block_gather


def _timing_kw():
    return {"warmup": 1, "iters": 3} if smoke() else {}


def _rand_eo(shape, seed):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    psi = (jax.random.normal(jax.random.PRNGKey(seed + 1), (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.PRNGKey(seed + 2),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    e, _ = evenodd.pack(psi)
    return Ue, Uo, e


def _hlo_census(fn, *args) -> dict:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    cats = {"gather": 0, "scatter": 0, "select": 0, "slice": 0,
            "concatenate": 0, "dot": 0}
    for line in txt.splitlines():
        for k in cats:
            if re.search(rf"\b{k}\(", line) or \
                    re.search(rf"= [a-z0-9\[\],{{}}]+ {k}", line):
                cats[k] += 1
    return cats


def run() -> list:
    rows: list[Row] = []
    T, Z, Y, X = (4, 4, 4, 8) if smoke() else (8, 8, 8, 16)
    Ue, Uo, e = _rand_eo((T, Z, Y, X), seed=0)

    shuffle_fn = jax.jit(
        lambda a, b, c: evenodd.hop_block(a, b, c, evenodd.ODD))
    gather_fn = jax.jit(
        lambda a, b, c: hop_block_gather(a, b, c, evenodd.ODD))

    # correctness of the naive version first
    d = float(jnp.max(jnp.abs(shuffle_fn(Ue, Uo, e)
                              - gather_fn(Ue, Uo, e))))
    assert d < 1e-4, f"gather version diverges: {d}"

    us_s = time_fn(shuffle_fn, Ue, Uo, e, **_timing_kw())
    us_g = time_fn(gather_fn, Ue, Uo, e, **_timing_kw())
    vol = T * Z * Y * X
    rows.append(("breakdown_shuffle_hop", us_s,
                 f"gflops={660 * vol / (us_s * 1e-6) / 1e9:.2f}"))
    rows.append(("breakdown_gather_hop", us_g,
                 f"slowdown_vs_shuffle={us_g / us_s:.2f}x"))

    cs = _hlo_census(lambda a, b, c: evenodd.hop_block(a, b, c, 1),
                     Ue, Uo, e)
    cg = _hlo_census(lambda a, b, c: hop_block_gather(a, b, c, 1),
                     Ue, Uo, e)
    rows.append(("breakdown_shuffle_hlo_gathers", 0.0,
                 f"gather_ops={cs['gather']};select_ops={cs['select']}"))
    rows.append(("breakdown_gather_hlo_gathers", 0.0,
                 f"gather_ops={cg['gather']};select_ops={cg['select']}"))
    rows.extend(_dhat_fusion_rows())
    rows.extend(_dhat_streaming_rows())
    rows.extend(_conversion_rows())
    write_json("breakdown", rows)
    return rows


def _dhat_fusion_rows() -> list:
    """Fused single-kernel Dhat vs the two-kernel HBM round-trip path.

    Off-TPU both run the Pallas interpreter, so absolute numbers are not
    hardware-meaningful there — the row notes which mode produced them.
    The eliminated traffic (one spinor write + its 5-plane pipelined
    re-read) is reported alongside.
    """
    rows: list[Row] = []
    T, Z, Y, X = (4, 4, 4, 8) if smoke() else (8, 8, 8, 8)
    kappa = 0.13
    Ue, Uo, e = _rand_eo((T, Z, Y, X), seed=3)
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)

    unfused_fn = jax.jit(lambda a, b, c: ops.apply_dhat_planar(
        a, b, c, kappa))
    fused_fn = jax.jit(lambda a, b, c: ops.apply_dhat_planar_fused(
        a, b, c, kappa))

    d = float(jnp.max(jnp.abs(fused_fn(Uep, Uop, ep)
                              - unfused_fn(Uep, Uop, ep))))
    assert d < 1e-5, f"fused Dhat diverges from unfused: {d}"

    mode = "tpu" if jax.default_backend() == "tpu" else "interpret"
    us_u = time_fn(unfused_fn, Uep, Uop, ep, **_timing_kw())
    us_f = time_fn(fused_fn, Uep, Uop, ep, **_timing_kw())
    tmp_bytes = 4 * 24 * T * Z * Y * (X // 2)
    saved = tmp_bytes * 6  # 1 HBM write + 5 neighbor-plane re-reads
    rows.append(("breakdown_dhat_unfused", us_u,
                 f"mode={mode};tmp_hbm_bytes={tmp_bytes}"))
    rows.append(("breakdown_dhat_fused", us_f,
                 f"mode={mode};speedup_vs_unfused={us_u / us_f:.2f}x;"
                 f"hbm_bytes_eliminated={saved}"))
    return rows


def _dhat_streaming_rows() -> list:
    """Streaming plane-window fused Dhat: window overhead + the cap-lift.

    Two claims, each with a machine-checkable row:

    1. **Window overhead is bounded** — on a lattice every path can run,
       the streaming kernel (2 recomputed boundary t-rows, ring scratch)
       is timed against the resident fused kernel and the two-kernel
       path, with the model's overhead factor printed next to it.
    2. **The cap is lifted** — a lattice whose (batched) resident
       intermediate FAILS ``fused_dhat_fits`` runs through the streaming
       fused path (policy-selected, one ``pallas_call``) and matches the
       jnp reference to <= 1e-5.  Off-TPU this runs the interpreter, so
       the row is about feasibility + correctness, not absolute time.
    """
    from repro.kernels.wilson_stencil import (
        dhat_stream_traffic_model, fused_dhat_fits, fused_dhat_policy,
        stream_ring_bytes)

    rows: list[Row] = []
    kappa = 0.13
    on_tpu = jax.default_backend() == "tpu"
    mode = "tpu" if on_tpu else "interpret"

    # --- window overhead vs the resident kernel (small lattice) -------
    T, Z, Y, X = (4, 4, 4, 8) if smoke() else (8, 8, 8, 8)
    Ue, Uo, e = _rand_eo((T, Z, Y, X), seed=11)
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    resident_fn = jax.jit(lambda a, b, c: ops.apply_dhat_planar_any(
        a, b, c, kappa, fused="resident"))
    stream_fn = jax.jit(lambda a, b, c: ops.apply_dhat_planar_any(
        a, b, c, kappa, fused="stream"))
    d = float(jnp.max(jnp.abs(stream_fn(Uep, Uop, ep)
                              - resident_fn(Uep, Uop, ep))))
    assert d < 1e-5, f"streaming Dhat diverges from resident: {d}"
    us_r = time_fn(resident_fn, Uep, Uop, ep, **_timing_kw())
    us_s = time_fn(stream_fn, Uep, Uop, ep, **_timing_kw())
    m = dhat_stream_traffic_model(T, Z, Y, X // 2)
    rows.append(("breakdown_dhat_stream_window", us_s,
                 f"mode={mode};resident_us={us_r:.1f};"
                 f"recompute_rows={m['recompute_rows']};"
                 f"window_rows={m['window_rows']};"
                 f"vmem_ring_bytes={m['vmem_ring_bytes']};"
                 f"vmem_resident_bytes={m['vmem_resident_bytes']};"
                 f"model_flops_overhead="
                 f"{(T + 2) / (2 * T) + 0.5:.3f}x"))

    # --- the cap-lift: over-budget lattice through the streaming path -
    # smoke keeps the interpreter affordable; the full run uses the
    # ISSUE's canonical 16x16x16x32 @ nrhs=8 cap casualty.
    (T, Z, Y, X), nrhs = (((20, 8, 16, 16), 8) if smoke()
                          else ((16, 16, 16, 32), 8))
    Ue, Uo, _ = _rand_eo((T, Z, Y, X), seed=13)
    bops = api.WilsonMatrix.bind(
        Ue, Uo, kappa, backend=api.BackendSpec(
            "pallas_fused", interpret=None if on_tpu else True)).ops
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    k = jax.random.PRNGKey(17)
    eb = (jax.random.normal(k, (nrhs, T, Z, Y, X // 2, 4, 3))
          + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                   (nrhs, T, Z, Y, X // 2, 4, 3))
          ).astype(jnp.complex64)
    v = bops.to_domain_batched(eb)
    assert not fused_dhat_fits(v.shape, v.dtype), (
        "cap-lift lattice unexpectedly fits the resident scratch")
    policy = fused_dhat_policy(v.shape, v.dtype)
    assert policy == "stream", policy
    fn = jax.jit(lambda w: bops.apply_dhat_native_batched(w, kappa))
    out = bops.from_domain_batched(fn(v))
    want = jnp.stack([ref.apply_dhat(eb[n], kappa) for n in range(nrhs)])
    err = float(jnp.max(jnp.abs(out - want)))
    assert err <= 1e-5, f"streaming cap-lift diverges from jnp: {err}"
    us = time_fn(fn, v, **_timing_kw())
    mm = dhat_stream_traffic_model(T, Z, Y, X // 2, nrhs=nrhs)
    rows.append(("breakdown_dhat_stream_caplift", us,
                 f"mode={mode};lattice={T}x{Z}x{Y}x{X};nrhs={nrhs};"
                 f"fits_resident=false;policy=stream;"
                 f"max_abs_err_vs_jnp={err:.2e};per_rhs_us={us / nrhs:.1f};"
                 f"vmem_ring_bytes={stream_ring_bytes(v.shape, v.dtype)};"
                 f"vmem_resident_bytes_needed="
                 f"{v.dtype.itemsize * v.size};"
                 f"model_intensity_flops_per_byte="
                 f"{mm['intensity_flops_per_byte']:.2f}"))
    return rows


def _conversion_rows() -> list:
    """Layout-conversion + placement tax per ``apply_dhat`` call.

    The old complex-interface path pays ``spinor_to_planar`` /
    ``spinor_from_planar`` (and, for the distributed backend, a
    ``device_put``) on *every* operator application; the native-domain
    path the solver now iterates on pays them once per solve.  The
    difference between the two timed rows is exactly that per-call tax.
    """
    rows: list[Row] = []
    shape = (4, 4, 4, 8) if smoke() else (8, 8, 8, 8)
    kappa = 0.13
    Ue, Uo, e = _rand_eo(shape, seed=7)
    on_tpu = jax.default_backend() == "tpu"

    cases = [("pallas_fused", None if on_tpu else True),
             ("distributed", None)]
    for name, interpret in cases:
        bops = api.WilsonMatrix.bind(
            Ue, Uo, kappa,
            backend=api.BackendSpec(name, interpret=interpret)).ops
        v = bops.to_domain(e)
        complex_fn = lambda psi: bops.apply_dhat(psi, kappa)  # noqa: E731
        native_fn = lambda w: bops.apply_dhat_native(w, kappa)  # noqa: E731
        us_c = time_fn(complex_fn, e, **_timing_kw())
        us_n = time_fn(native_fn, v, **_timing_kw())
        mode = "tpu" if on_tpu else "interpret"
        rows.append((f"breakdown_dhat_complex_iface_{name}", us_c,
                     f"mode={mode};domain={bops.domain}"))
        rows.append((f"breakdown_dhat_native_iface_{name}", us_n,
                     f"mode={mode};domain={bops.domain};"
                     f"conversion_overhead_us={us_c - us_n:.1f};"
                     f"conversion_overhead_pct="
                     f"{100.0 * (us_c - us_n) / max(us_c, 1e-9):.1f}"))
    return rows
