"""Block-CG and low-mode deflation: sublinear repeated solves.

Three claims, each a machine-checkable row in ``BENCH_deflation.json``:

1. **Block-CG shares one Krylov space** (``blockcg_vs_cg_block``) — on
   a shared-spectrum RHS block, BCGrQ converges in no more iterations
   than the slowest column of a column-independent batched CG solve
   (``blockcg_iters <= cg_iters``), at one batched operator apply per
   iteration either way.
2. **Lanczos deflation pays** (``deflation_lanczos``) — on a weak-field
   (smooth) gauge, whose low spectrum is a few isolated degenerate
   clusters (see :func:`repro.core.su3.weak_gauge`), projecting a
   once-per-gauge Lanczos basis out of every solve cuts the per-solve
   iteration count (``deflated_iters < plain_iters``).
3. **Recycling makes streams sublinear** (``deflation_recycle_stream``)
   — a recycle-mode session harvests Chebyshev-filtered converged
   solutions back into the basis, so per-solve iterations DROP across
   the request stream (``last_iters < first_iters``) with no up-front
   eigensolve; the per-solve counts ride ``SolveSession.stats()``.

All solves go through the public API (:class:`repro.api.WilsonMatrix` /
:class:`repro.api.SolveSession`).  The weak-field configuration is the
honest demonstration bed — on a Haar-random gauge the low modes form a
quasi-continuum and NO small deflation basis (this or anyone else's)
buys iterations; that negative result is physics, not implementation.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro import api
from repro.core import evenodd, su3

from .common import Row, smoke, time_fn, write_json

EPS = 0.2          # weak-field fluctuation strength
SEED = 7


def _setup(shape, kappa):
    U = su3.weak_gauge(jax.random.PRNGKey(SEED), shape, eps=EPS)
    Ue, Uo = evenodd.pack_gauge(U)
    return api.WilsonMatrix.bind(Ue, Uo, kappa, backend="jnp")


def _sources(shape, seed, nrhs=None):
    bshape = (() if nrhs is None else (nrhs,)) + (*shape, 4, 3)
    eta = (jax.random.normal(jax.random.PRNGKey(seed), bshape)
           + 1j * jax.random.normal(jax.random.PRNGKey(seed + 5000),
                                    bshape)).astype(jnp.complex64)
    if nrhs is None:
        return evenodd.pack(eta)
    return jax.vmap(evenodd.pack)(eta)


def run() -> List[Row]:
    rows: List[Row] = []
    if smoke():
        shape, kappa, nrhs = (4, 4, 4, 8), 0.1245, 4
        rank, iters, stream = 24, 160, 14
        tkw = {"warmup": 1, "iters": 3}
    else:
        shape, kappa, nrhs = (8, 8, 8, 8), 0.124, 4
        rank, iters, stream = 24, 200, 16
        tkw = {}

    # -- 1. block-CG vs column-independent CG on one RHS block ---------
    ee, eo = _sources(shape, 31, nrhs=nrhs)
    iters_by_method = {}
    for method in ("cg", "blockcg"):
        sess = api.SolveSession(
            _setup(shape, kappa),
            api.SolveSpec(method=method, tol=1e-6, max_iters=2000))
        _, _, res = sess.solve(ee, eo)
        iters_by_method[method] = int(jnp.max(res.iterations))
        if method == "blockcg":
            us = time_fn(lambda: sess.solve(ee, eo), **tkw)
    rows.append((
        "blockcg_vs_cg_block", us,
        f"blockcg_iters={iters_by_method['blockcg']};"
        f"cg_iters={iters_by_method['cg']};nrhs={nrhs};"
        f"iter_ratio={iters_by_method['cg'] / max(iters_by_method['blockcg'], 1):.2f}x"))

    # -- 2. once-per-gauge Lanczos deflation ---------------------------
    ee1, eo1 = _sources(shape, 41)
    plain = api.SolveSession(
        _setup(shape, kappa),
        api.SolveSpec(method="cg", tol=1e-6, max_iters=2000))
    _, _, r0 = plain.solve(ee1, eo1)
    defl = api.SolveSession(
        _setup(shape, kappa),
        api.SolveSpec(method="cg", tol=1e-6, max_iters=2000,
                      deflate_rank=rank, deflate_iters=iters))
    _, _, r1 = defl.solve(ee1, eo1)
    us = time_fn(lambda: defl.solve(ee1, eo1), **tkw)
    drow = next(iter(defl.stats()["keys"].values()))["deflation"]
    rows.append((
        "deflation_lanczos", us,
        f"plain_iters={int(r0.iterations)};"
        f"deflated_iters={int(r1.iterations)};"
        f"rank={rank};lanczos_iters={iters};"
        f"active={drow['active']};"
        f"iter_ratio={int(r0.iterations) / max(int(r1.iterations), 1):.2f}x"))

    # -- 3. recycle stream: iterations drop, no eigensolve -------------
    sess = api.SolveSession(
        _setup(shape, kappa),
        api.SolveSpec(method="cg", tol=1e-6, max_iters=2000,
                      deflate_rank=rank, deflate_mode="recycle"))
    counts = []
    for i in range(stream):
        ee_i, eo_i = _sources(shape, 100 + i)
        _, _, r = sess.solve(ee_i, eo_i)
        counts.append(int(r.iterations))
    st = sess.stats()
    row = next(iter(st["keys"].values()))
    assert row["iterations"] == counts  # the stats surface IS the claim
    d = row["deflation"]
    steady = row["steady_state_s"] or 0.0
    rows.append((
        "deflation_recycle_stream", steady * 1e6,
        f"first_iters={counts[0]};last_iters={counts[-1]};"
        f"stream={'|'.join(str(c) for c in counts)};"
        f"harvested={d['harvested']};active={d['active']};"
        f"traces={st['traces']}"))

    write_json("deflation", rows)
    return rows
