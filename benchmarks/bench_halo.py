"""Paper Fig. 9 analogue: boundary pack/exchange/unpack (EO1/EO2) cost.

Measures the halo-extension path (slice + ppermute + concat) against the
bulk stencil on the same local volume, and reports the halo-to-bulk byte
ratio that governs the overlap window at scale.  On top of that, the
distributed-operator rows carry the two bandwidth levers this repo
implements for the exchange:

* ``halo_dhat_overlap_{fused,interior}`` — one full Dhat with the
  serialized schedule vs the interior/boundary split that runs the
  interior stencil while the exchange is in flight;
* ``halo_gauge_{none,two_row,minimal}`` — one full Dhat per link
  representation, with the *modeled* per-exchange gauge bytes
  (``halo_traffic_model``: links are shipped compressed, so two_row
  cuts gauge halo traffic by 1/3 and minimal by 5/9) next to the
  *measured* deviation from the uncompressed output.

Runs on however many devices the process has (1 device ->
self-permute, still structurally identical).  Rows are mirrored to
``BENCH_halo.json``; CI asserts modeled compressed bytes < uncompressed
and measured parity <= 1e-5 from that file.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import backends, compat
from repro.core import evenodd, su3
from repro.distributed import halo
from repro.kernels import layout

from .common import Row, smoke, time_fn, write_json

_KAPPA = 0.13


def _dist_rows(Tl: int, Zl: int, Y: int, Xh: int) -> list:
    """Overlap-schedule and link-compression rows on one full Dhat."""
    rows: list[Row] = []
    shape = (Tl, Zl, Y, 2 * Xh)
    U = su3.random_gauge(jax.random.PRNGKey(2), shape)
    k = jax.random.PRNGKey(3)
    psi = (jax.random.normal(k, (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    e, _ = jax.vmap(evenodd.pack)(psi[None])
    Ue, Uo = evenodd.pack_gauge(U)

    def bind(**opts):
        ops = backends.make_wilson_ops("distributed", Ue, Uo, **opts)
        fn = jax.jit(ops.apply_dhat, static_argnums=1)
        return fn, np.asarray(fn(e[0], _KAPPA))

    fused_fn, ref = bind(overlap="fused")
    us_fused = time_fn(fused_fn, e[0], _KAPPA)
    rows.append(("halo_dhat_overlap_fused", us_fused, "overlap=fused"))

    interior_fn, got = bind(overlap="interior")
    us_int = time_fn(interior_fn, e[0], _KAPPA)
    rows.append(("halo_dhat_overlap_interior", us_int,
                 f"overlap=interior;fused_over_interior="
                 f"{us_fused / us_int:.3f}x;max_abs_diff_vs_fused="
                 f"{np.max(np.abs(got - ref)):.3e}"))

    for mode in ("none", "two_row", "minimal"):
        gc = layout.GAUGE_COMPRESSIONS[mode]
        m = halo.halo_traffic_model(Tl, Zl, Y, Xh, gauge_comps=gc)
        fn, got = bind(overlap="fused", gauge_compression=mode)
        us = time_fn(fn, e[0], _KAPPA)
        rows.append((f"halo_gauge_{mode}", us,
                     f"gauge_comps={gc}"
                     f";model_gauge_exchange_bytes="
                     f"{m['bytes_gauge_exchange']}"
                     f";model_dhat_exchange_bytes="
                     f"{m['bytes_dhat_exchange']}"
                     f";max_abs_diff_vs_none="
                     f"{np.max(np.abs(got - ref)):.3e}"))
    return rows


def run() -> list:
    rows: list[Row] = []
    Tl, Zl, Y, Xh = (4, 4, 4, 4) if smoke() else (8, 8, 16, 16)
    spin = jax.random.normal(jax.random.PRNGKey(0),
                             (Tl, Zl, 24, Y, Xh))

    n = jax.device_count()
    mesh_shape = (n, 1) if n > 1 else (1, 1)
    mesh = compat.make_mesh(mesh_shape, ("data", "model"))

    def ext_fn(x):
        return halo.extend_tz(x, ("data",), ("model",), 0, 1)

    sharded = compat.shard_map(ext_fn, mesh=mesh,
                               in_specs=P("data", "model"),
                               out_specs=P("data", "model"),
                               check_vma=False)
    fn = jax.jit(sharded)
    us_halo = time_fn(fn, spin)

    halo_bytes = 4 * (2 * Zl + 2 * (Tl + 2)) * 24 * Y * Xh
    bulk_bytes = 4 * Tl * Zl * 24 * Y * Xh
    rows.append(("halo_extend_tz", us_halo,
                 f"halo_bytes={halo_bytes};bulk_ratio="
                 f"{halo_bytes / bulk_bytes:.3f}"))

    # pack (slice) and unpack (merge) measured separately
    pack = jax.jit(lambda x: (x[:1], x[-1:], x[:, :1], x[:, -1:]))
    us_pack = time_fn(pack, spin)
    rows.append(("halo_pack_eo1", us_pack, "slices=4"))

    unpack = jax.jit(lambda x, lo, hi: jnp.concatenate([lo, x, hi], 0))
    us_unpack = time_fn(unpack, spin, spin[:1], spin[-1:])
    rows.append(("halo_unpack_eo2", us_unpack, "concat_t"))

    rows.extend(_dist_rows(Tl, Zl, Y, Xh))
    write_json("halo", rows)
    return rows
