"""Paper Fig. 9 analogue: boundary pack/exchange/unpack (EO1/EO2) cost.

Measures the halo-extension path (slice + ppermute + concat) against the
bulk stencil on the same local volume, and reports the halo-to-bulk byte
ratio that governs the overlap window at scale.  Runs on however many
devices the process has (1 device -> self-permute, still structurally
identical)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed import halo

from .common import Row, time_fn


def run() -> list:
    rows: list[Row] = []
    Tl, Zl, Y, Xh = 8, 8, 16, 16
    spin = jax.random.normal(jax.random.PRNGKey(0),
                             (Tl, Zl, 24, Y, Xh))

    n = jax.device_count()
    mesh_shape = (n, 1) if n > 1 else (1, 1)
    mesh = compat.make_mesh(mesh_shape, ("data", "model"))

    def ext_fn(x):
        return halo.extend_tz(x, ("data",), ("model",), 0, 1)

    sharded = compat.shard_map(ext_fn, mesh=mesh,
                               in_specs=P("data", "model"),
                               out_specs=P("data", "model"),
                               check_vma=False)
    fn = jax.jit(sharded)
    us_halo = time_fn(fn, spin)

    halo_bytes = 4 * (2 * Zl + 2 * (Tl + 2)) * 24 * Y * Xh
    bulk_bytes = 4 * Tl * Zl * 24 * Y * Xh
    rows.append(("halo_extend_tz", us_halo,
                 f"halo_bytes={halo_bytes};bulk_ratio="
                 f"{halo_bytes / bulk_bytes:.3f}"))

    # pack (slice) and unpack (merge) measured separately
    pack = jax.jit(lambda x: (x[:1], x[-1:], x[:, :1], x[:, -1:]))
    us_pack = time_fn(pack, spin)
    rows.append(("halo_pack_eo1", us_pack, "slices=4"))

    unpack = jax.jit(lambda x, lo, hi: jnp.concatenate([lo, x, hi], 0))
    us_unpack = time_fn(unpack, spin, spin[:1], spin[-1:])
    rows.append(("halo_unpack_eo2", us_unpack, "concat_t"))
    return rows
