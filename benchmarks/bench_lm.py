"""Per-(arch x shape) roofline summary from the dry-run records (the
beyond-paper table).  Requires `python -m repro.launch.dryrun` to have
populated experiments/dryrun/."""
from __future__ import annotations

import json
import pathlib

from .common import Row

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / \
    "dryrun"


def run() -> list:
    rows: list[Row] = []
    if not DRYRUN.exists():
        return [("lm_roofline", -1.0, "no dryrun records; run "
                 "python -m repro.launch.dryrun first")]
    try:
        from repro.launch.roofline import analyze
    except Exception:
        return [("lm_roofline", -1.0, "roofline import failed")]
    for f in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(f.read_text())
        a = analyze(rec)
        if not a:
            continue
        rows.append((f"roofline_{a['cell']}",
                     a["step_time_bound_s"] * 1e6,
                     f"dominant={a['dominant']};frac="
                     f"{a['roofline_fraction']:.3f};"
                     f"fit_gib={a['fit_gib']:.1f}"))
    return rows
