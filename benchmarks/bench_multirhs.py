"""Multi-RHS batched Wilson kernels + mixed-precision refinement.

Three claims, each demonstrated with a machine-checkable row in
``BENCH_multirhs.json``:

1. **Gauge-traffic amortization** — the batched kernel runs ONE
   ``pallas_call`` over the same (T, Z) grid regardless of ``nrhs`` and
   its gauge HBM traffic is nrhs-independent (``hop_traffic_model``),
   so arithmetic intensity grows ~nrhs x.  The model numbers are printed
   next to measured per-RHS times (off-TPU the Pallas interpreter makes
   the absolute times meaningless; the row says which mode ran).
2. **Batched == sequential** — for every registered backend, a batched
   solve agrees column-by-column with independent single-RHS solves to
   1e-5.
3. **Mixed precision pays** — an ``inner_dtype=f32`` iterative-refinement
   solve reaches the f64 tolerance a pure-f64 solve reaches, with fewer
   f64 operator applications.
4. **Session reuse pays** (``multirhs_session_reuse``) — N same-shape
   solves through one :class:`repro.api.SolveSession` trace exactly
   once; the steady-state wall time is the serving-loop number, the
   first-solve time the cold-start one.

Operator binds and solves go through the public API
(:class:`repro.api.WilsonMatrix` / :class:`repro.api.SolveSession`) —
the only solve surface since the legacy shim's removal (lint rule R3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import api, backends
from repro.core import evenodd, su3
from repro.kernels.wilson_stencil import (dhat_stream_traffic_model,
                                          fused_dhat_policy,
                                          hop_traffic_model,
                                          stream_ring_bytes)

from .common import Row, smoke, time_fn, write_json

KAPPA = 0.13


def _timing_kw():
    return {"warmup": 1, "iters": 3} if smoke() else {}


def _rand_eo(shape, seed, nrhs=None):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    bshape = (() if nrhs is None else (nrhs,)) + (*shape, 4, 3)
    psi = (jax.random.normal(jax.random.PRNGKey(seed + 1), bshape)
           + 1j * jax.random.normal(jax.random.PRNGKey(seed + 2),
                                    bshape)).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    if nrhs is None:
        e, o = evenodd.pack(psi)
    else:
        e, o = jax.vmap(evenodd.pack)(psi)
    return Ue, Uo, e, o


def _amortization_rows(shape) -> list:
    """Per-RHS time of the batched native Dhat + the traffic model."""
    rows: list[Row] = []
    T, Z, Y, X = shape
    on_tpu = jax.default_backend() == "tpu"
    mode = "tpu" if on_tpu else "interpret"
    Ue, Uo, _, _ = _rand_eo(shape, seed=0)
    bops = api.WilsonMatrix.bind(
        Ue, Uo, KAPPA, backend=api.BackendSpec(
            "pallas_fused", interpret=None if on_tpu else True)).ops

    nrhs_list = (1, 2, 4) if smoke() else (1, 2, 4, 8)
    base_model = hop_traffic_model(T, Z, Y, X // 2, nrhs=1)
    for n in nrhs_list:
        _, _, e, _ = _rand_eo(shape, seed=1, nrhs=n)
        v = bops.to_domain_batched(e)
        fn = jax.jit(lambda w: bops.apply_dhat_native_batched(w, KAPPA))
        us = time_fn(fn, v, **_timing_kw())
        m = hop_traffic_model(T, Z, Y, X // 2, nrhs=n)
        # Dhat = two hopping blocks; the model scales linearly, ratios
        # are what matter.
        rows.append((f"multirhs_dhat_nrhs{n}", us,
                     f"mode={mode};per_rhs_us={us / n:.1f};"
                     f"model_bytes_gauge={m['bytes_gauge']};"
                     f"model_bytes_spinor={m['bytes_spinor']};"
                     f"model_intensity_flops_per_byte="
                     f"{m['intensity_flops_per_byte']:.2f};"
                     f"model_intensity_gain_vs_nrhs1="
                     f"{m['intensity_flops_per_byte'] / base_model['intensity_flops_per_byte']:.2f}"))

    # The load-once guarantee, asserted structurally: the batched hop is
    # ONE pallas_call (not nrhs of them) and the model's gauge term is
    # nrhs-independent.
    _, _, e8, _ = _rand_eo(shape, seed=2, nrhs=nrhs_list[-1])
    v8 = bops.to_domain_batched(e8)
    jaxpr = str(jax.make_jaxpr(
        lambda w: bops.hop_oe_native_batched(w))(v8))
    n_calls = jaxpr.count("pallas_call")
    g1 = hop_traffic_model(T, Z, Y, X // 2, nrhs=1)["bytes_gauge"]
    gN = hop_traffic_model(T, Z, Y, X // 2,
                           nrhs=nrhs_list[-1])["bytes_gauge"]
    assert n_calls == 1, f"batched hop lowered to {n_calls} kernels"
    assert g1 == gN, (g1, gN)
    rows.append(("multirhs_gauge_load_invariance", 0.0,
                 f"pallas_calls_batched_hop={n_calls};"
                 f"gauge_bytes_nrhs1={g1};"
                 f"gauge_bytes_nrhs{nrhs_list[-1]}={gN};"
                 f"gauge_loaded_once_per_grid_step=true"))
    return rows


def _stream_rows(shape) -> list:
    """Streaming plane-window rows: per-RHS time through the forced
    ``pallas_fused_stream`` backend + the policy thresholds that decide
    when batching pushes a lattice off the resident scratch.

    The policy row is the multi-RHS story of the cap: the SAME lattice
    walks resident -> stream as nrhs grows (the resident scratch scales
    with nrhs, the ring scales with nrhs too but is window/T of it), so
    batched solves keep the single-kernel fused path instead of paying
    the two-kernel HBM round-trip.
    """
    rows: list[Row] = []
    T, Z, Y, X = shape
    on_tpu = jax.default_backend() == "tpu"
    mode = "tpu" if on_tpu else "interpret"
    Ue, Uo, _, _ = _rand_eo(shape, seed=3)
    bops = api.WilsonMatrix.bind(
        Ue, Uo, KAPPA, backend=api.BackendSpec(
            "pallas_fused_stream",
            interpret=None if on_tpu else True)).ops

    for n in (1, 4) if smoke() else (1, 2, 4, 8):
        _, _, e, _ = _rand_eo(shape, seed=4, nrhs=n)
        v = bops.to_domain_batched(e)
        fn = jax.jit(lambda w: bops.apply_dhat_native_batched(w, KAPPA))
        us = time_fn(fn, v, **_timing_kw())
        m = dhat_stream_traffic_model(T, Z, Y, X // 2, nrhs=n)
        rows.append((f"multirhs_dhat_stream_nrhs{n}", us,
                     f"mode={mode};per_rhs_us={us / n:.1f};"
                     f"vmem_ring_bytes={m['vmem_ring_bytes']};"
                     f"recompute_rows={m['recompute_rows']};"
                     f"model_intensity_flops_per_byte="
                     f"{m['intensity_flops_per_byte']:.2f}"))

    # Policy walk: nrhs at which the resident scratch overflows but the
    # ring still fits — machine-checkable evidence the auto backend
    # keeps a fused single kernel where PR 3 fell back to two kernels.
    pshape = (16, 16, 24, 16, 16)          # 16x16x16x32, planar
    walk = {n: fused_dhat_policy((n, *pshape) if n > 1 else pshape)
            for n in (1, 4, 8, 64)}
    assert walk[1] == "resident" and walk[8] == "stream", walk
    rows.append(("multirhs_stream_policy_walk", 0.0,
                 "lattice=16x16x16x32;"
                 + ";".join(f"nrhs{n}={p}" for n, p in walk.items())
                 + f";ring_bytes_nrhs8={stream_ring_bytes((8, *pshape))}"))
    return rows


def _agreement_rows(shape) -> list:
    """Batched-vs-sequential solve agreement for every backend."""
    rows: list[Row] = []
    nrhs = 2
    tol = 1e-6
    on_tpu = jax.default_backend() == "tpu"
    Ue, Uo, be, bo = _rand_eo(shape, seed=5, nrhs=nrhs)
    for name in backends.available_backends():
        interpret = (True if not on_tpu and name.startswith("pallas")
                     else None)
        matrix = api.WilsonMatrix.bind(
            Ue, Uo, KAPPA,
            backend=api.BackendSpec(name, interpret=interpret))
        session = api.SolveSession(
            matrix, api.SolveSpec(method="bicgstab", tol=tol))
        xe_b, _, res_b = session.solve(be, bo)
        worst = 0.0
        for n in range(nrhs):
            # second key in the same session (single-RHS shape); the
            # nrhs-1 later columns are cache hits
            xe_1, _, _ = session.solve(be[n], bo[n])
            d = float(jnp.linalg.norm(xe_b[n] - xe_1)
                      / jnp.linalg.norm(xe_1))
            worst = max(worst, d)
        ok = worst <= 1e-5
        assert ok, f"{name}: batched deviates from sequential by {worst}"
        st = session.stats()
        assert st["traces"] == 2, st   # one per rhs-shape key
        rows.append((f"multirhs_batched_vs_sequential_{name}", 0.0,
                     f"nrhs={nrhs};max_col_rel_diff={worst:.2e};"
                     f"agree_1e5={str(ok).lower()};"
                     f"iters={int(jnp.max(res_b.iterations))};"
                     f"session_traces={st['traces']};"
                     f"session_cache_hits={st['cache_hits']}"))
    return rows


def _session_reuse_rows(shape) -> list:
    """The compiled-solve-cache claim as a row: N same-shape ``nrhs=4``
    solves through ONE :class:`repro.api.SolveSession` trace exactly
    once; first-solve (trace + compile) vs steady-state wall time."""
    rows: list[Row] = []
    nrhs = 4
    on_tpu = jax.default_backend() == "tpu"
    mode = "tpu" if on_tpu else "interpret"
    Ue, Uo, _, _ = _rand_eo(shape, seed=21)
    matrix = api.WilsonMatrix.bind(
        Ue, Uo, KAPPA, backend=api.BackendSpec(
            "pallas_fused", interpret=None if on_tpu else True))
    session = api.SolveSession(
        matrix, api.SolveSpec(method="bicgstab", tol=1e-5))
    n_solves = 3 if smoke() else 5
    for i in range(n_solves):
        _, _, e, o = _rand_eo(shape, seed=30 + i, nrhs=nrhs)
        session.solve(e, o)
    st = session.stats()
    assert st["traces"] == 1 and st["cache_hits"] == n_solves - 1, st
    (krow,) = st["keys"].values()
    first, steady = krow["first_solve_s"], krow["steady_state_s"]
    rows.append(("multirhs_session_reuse", steady * 1e6,
                 f"mode={mode};nrhs={nrhs};solves={n_solves};"
                 f"first_solve_us={first * 1e6:.1f};"
                 f"steady_state_us={steady * 1e6:.1f};"
                 f"trace_count={st['traces']};"
                 f"cache_hits={st['cache_hits']};"
                 f"first_vs_steady={first / max(steady, 1e-12):.1f}x"))
    return rows


def _mixed_precision_rows(shape) -> list:
    """f32-inner refinement vs pure f64: same tolerance, fewer f64 ops."""
    rows: list[Row] = []
    tol = 1e-10
    from jax.experimental import enable_x64

    with enable_x64():
        Ue, Uo, e, o = _rand_eo(shape, seed=9)
        U64e, U64o = Ue.astype(jnp.complex128), Uo.astype(jnp.complex128)
        e64, o64 = e.astype(jnp.complex128), o.astype(jnp.complex128)

        _, _, res_pure = api.solve(
            U64e, U64o, e64, o64, KAPPA, backend="jnp",
            spec=api.SolveSpec(method="cgnr", tol=tol))
        # CGNR applies op + op_dag per iteration, plus the normal-eq RHS
        # and the final true-residual check.
        pure_f64_applies = 2 * int(res_pure.iterations) + 2

        xe, _, res_mix = api.solve(
            U64e, U64o, e64, o64, KAPPA, backend="jnp",
            spec=api.SolveSpec(method="cgnr", tol=tol,
                               inner_dtype="f32"))
        # Independent f64 residual check of the refined solution.
        rhs = e64 + KAPPA * evenodd.hop_eo(U64e, U64o, o64)
        r = rhs - evenodd.apply_dhat(U64e, U64o,
                                     xe.astype(jnp.complex128), KAPPA)
        rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(rhs))

    assert bool(res_pure.converged) and bool(res_mix.converged), (
        res_pure, res_mix)
    assert rel <= tol, rel
    assert res_mix.f64_applies < pure_f64_applies, (
        res_mix.f64_applies, pure_f64_applies)
    rows.append(("multirhs_mixed_precision_f32_inner", 0.0,
                 f"tol={tol};rel_f64={rel:.2e};"
                 f"f64_applies_mixed={res_mix.f64_applies};"
                 f"f64_applies_pure={pure_f64_applies};"
                 f"outer_iterations={res_mix.outer_iterations};"
                 f"inner_iterations={res_mix.inner_iterations};"
                 f"converged_to_f64_tol=true"))
    return rows


def run() -> list:
    shape = (4, 4, 4, 8)
    rows = _amortization_rows(shape)
    rows.extend(_stream_rows(shape))
    rows.extend(_agreement_rows(shape))
    rows.extend(_session_reuse_rows(shape))
    rows.extend(_mixed_precision_rows(shape))
    write_json("multirhs", rows)
    return rows
