"""Resilience benchmark: the cost of the always-on divergence guards
and the recovery behavior of each resilience layer.

Rows (mirrored to ``BENCH_resilience.json``):

* ``resilience_guard_overhead`` — steady-state per-call cost of the
  in-loop guard (non-finite cond + stagnation bookkeeping), guarded vs
  ``guard=False`` on the same compiled solve, forced to run the full
  iteration budget (``tol`` unreachable) so both variants execute
  identical trip counts.  Acceptance: ``overhead_pct`` < 2.
* ``resilience_nan_recovery`` — batched solve with one injected NaN
  column: the poisoned column reports ``diverged`` and the healthy
  columns are bit-exact with the clean run.
* ``resilience_escalation`` — a dead inner operator forces the refined
  solve up the precision ladder; it must still converge to the f64
  tolerance and record the climb.
* ``resilience_fallback`` — an injected kernel fault on the bound
  backend; the session recovers onto the declared fallback chain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import evenodd, solver, su3
from repro.resilience import (break_ops, dead_inner_ops,
                              nan_spinor_column)

from .common import Row, smoke, write_json


def _fields(shape, dtype=jnp.complex64, nrhs=None, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape, dtype=dtype)
    k = jax.random.PRNGKey(seed + 1)
    bshape = (() if nrhs is None else (nrhs,)) + (*shape, 4, 3)
    psi = (jax.random.normal(k, bshape)
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    bshape)).astype(dtype)
    Ue, Uo = evenodd.pack_gauge(U)
    if nrhs is None:
        e, o = evenodd.pack(psi)
    else:
        e, o = jax.vmap(evenodd.pack)(psi)
    return Ue, Uo, e, o


def _guard_overhead_rows(shape) -> list:
    """Guarded vs unguarded steady state at identical trip counts.

    ``tol=1e-30`` is unreachable in f32, so both compiled solves run
    exactly ``max_iters`` iterations; ``max_iters`` stays below the
    stagnation window so the guarded variant never restarts — the
    measured delta is pure guard bookkeeping.

    The A/B calls are INTERLEAVED (guarded, unguarded, guarded, ...)
    and compared by median: timing the two variants in separate blocks
    lets clock drift / cache state between the blocks masquerade as
    multi-percent "overhead" on a quantity that is actually sub-1%."""
    import time

    from repro import backends

    max_iters = 24 if smoke() else 48
    assert max_iters < solver.STAGNATION_WINDOW
    Ue, Uo, e, o = _fields(shape)
    bops = backends.make_wilson_ops("jnp", Ue, Uo)
    v_e, v_o = bops.to_domain(e), bops.to_domain(o)

    fns = {}
    for guard in (True, False):
        fn = jax.jit(solver.make_native_solve(
            bops, 0.13, method="cgnr", tol=1e-30, max_iters=max_iters,
            guard=guard))
        jax.block_until_ready(fn(v_e, v_o))         # compile
        fns[guard] = fn

    def once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(v_e, v_o))
        return (time.perf_counter() - t0) * 1e6

    reps = 15 if smoke() else 31
    samples = {True: [], False: []}
    for _ in range(2):                               # warmup pairs
        once(fns[True]), once(fns[False])
    for _ in range(reps):
        samples[False].append(once(fns[False]))
        samples[True].append(once(fns[True]))
    on = float(np.median(samples[True]))
    off = float(np.median(samples[False]))
    overhead = 100.0 * (on - off) / off
    return [("resilience_guard_overhead", on,
             f"unguarded_us={off:.1f};overhead_pct={overhead:.2f};"
             f"iters={max_iters};reps={reps};target_pct=2.0")]


def _nan_recovery_rows(shape) -> list:
    from repro import backends

    nrhs = 3
    Ue, Uo, e, o = _fields(shape, nrhs=nrhs)
    bops = backends.make_wilson_ops("jnp", Ue, Uo)
    run = jax.jit(solver.make_native_solve(
        bops, 0.13, method="cgnr", tol=1e-5, max_iters=400,
        batched=True))
    v_o = bops.to_domain_batched(o)
    _, _, clean = run(bops.to_domain_batched(e), v_o)
    _, _, res = run(bops.to_domain_batched(nan_spinor_column(e, 1)),
                    v_o)
    healthy_exact = all(
        np.array_equal(np.asarray(res.x[c]), np.asarray(clean.x[c]))
        for c in (0, 2))
    return [("resilience_nan_recovery", 0.0,
             f"diverged_cols={int(jnp.sum(res.diverged))};"
             f"healthy_bit_exact={int(healthy_exact)};"
             f"healthy_converged={int(jnp.sum(res.converged))}")]


def _escalation_rows(shape) -> list:
    from jax.experimental import enable_x64

    with enable_x64():
        Ue, Uo, e, o = _fields(shape, dtype=jnp.complex128)
        D = api.WilsonMatrix.bind(Ue, Uo, 0.13, backend="jnp")
        D._ops = dead_inner_ops(D.ops)
        s = api.SolveSession(D, api.SolveSpec(
            method="cgnr", tol=1e-10, max_iters=2000,
            inner_dtype="f32", inner_tol=1e-4, max_outer=25))
        _, _, res = s.solve(e, o)
    return [("resilience_escalation", 0.0,
             f"converged={int(bool(res.converged))};"
             f"rel={float(res.residual):.2e};"
             f"escalated_to_f64={int('f64' in res.escalations)};"
             f"outer_iterations={int(res.outer_iterations)}")]


def _fallback_rows(shape) -> list:
    Ue, Uo, e, o = _fields(shape)
    spec = api.BackendSpec(
        "pallas",
        interpret=(True if jax.default_backend() != "tpu" else None))
    D = api.WilsonMatrix.bind(Ue, Uo, 0.13, backend=spec, fallback=True)
    D._ops = break_ops(D.ops)
    s = api.SolveSession(D, api.SolveSpec(method="cgnr", tol=1e-5,
                                          max_iters=400))
    _, _, res = s.solve(e, o)
    st = s.stats()
    return [("resilience_fallback", 0.0,
             f"converged={int(bool(res.converged))};"
             f"fallbacks={st['fallbacks']};"
             f"final_backend={st['backend']};"
             f"degraded={int(st['degraded'])}")]


def run() -> list:
    shape = (4, 4, 4, 8) if smoke() else (8, 8, 8, 8)
    rows: list[Row] = []
    rows.extend(_guard_overhead_rows(shape))
    rows.extend(_nan_recovery_rows(shape))
    rows.extend(_escalation_rows(shape))
    rows.extend(_fallback_rows(shape))
    write_json("resilience", rows)
    return rows
