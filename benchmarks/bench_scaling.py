"""Paper Fig. 10 analogue: weak scaling of the even-odd Wilson operator.

Fixed local volume per device, growing device count (forced host devices
in subprocesses: 1, 2, 4, 8).  Reports wall time per Dhat application and
the sustained-throughput-per-device ratio to the 1-device case — the
paper's "performance per node is almost constant" claim, reproduced
structurally on CPU.  The TPU-projected version of this figure comes from
the dry-run collective terms (see EXPERIMENTS.md §Roofline).

Each device count also sweeps the stored SU(3) link representation
(``none`` / ``two_row`` / ``minimal``): compressed links are shipped
compressed, so the multi-host rows carry the per-compression *exchange*
bytes from :func:`repro.distributed.halo.halo_traffic_model` alongside
the measured time — ``weak_scaling_n{N}_{comp}`` rows in
``BENCH_scaling.json`` show gauge halo traffic shrinking 33%/55% with
the storage while the stencil reconstructs links in-register.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

from .common import Row, smoke, write_json

COMPRESSIONS = ("none", "two_row", "minimal")

_CHILD = """
import os
import time
import jax, jax.numpy as jnp
from repro import compat
from repro.core import su3, evenodd
from repro.kernels import layout, ops
from repro.distributed import qcd

n = jax.device_count()
smoke = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")
Tl = 4
T, Z, Y, X = (Tl * n, 4, 4, 8) if smoke else (Tl * n, 8, 8, 16)
U = su3.random_gauge(jax.random.PRNGKey(0), (T, Z, Y, X))
psi = (jax.random.normal(jax.random.PRNGKey(1), (T, Z, Y, X, 4, 3))
       + 1j*jax.random.normal(jax.random.PRNGKey(2), (T, Z, Y, X, 4, 3))
       ).astype(jnp.complex64)
Ue, Uo = evenodd.pack_gauge(U)
e, _ = evenodd.pack(psi)
ep = layout.spinor_to_planar(e)
mesh = compat.make_mesh((n, 1), ("data", "model"))
part = qcd.QCDPartition.for_mesh(mesh, backend="jnp", overlap="fused")
ep_d = jax.device_put(ep, part.spinor_sharding())
for comp in ("none", "two_row", "minimal"):
    # compressed links are stored AND shipped compressed: the planar
    # comps axis shrinks before placement, so halo faces shrink with it
    Uep, Uop = ops.make_planar_fields(Ue, Uo, compression=comp)
    dhat = jax.jit(qcd.make_dhat_fn(part, 0.13))
    args = (jax.device_put(Uep, part.gauge_sharding()),
            jax.device_put(Uop, part.gauge_sharding()),
            ep_d)
    for _ in range(2):
        jax.block_until_ready(dhat(*args))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(dhat(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    print("RESULT", n, comp, ts[len(ts)//2])
"""


def run() -> list:
    from repro.distributed.halo import halo_traffic_model
    from repro.kernels.layout import GAUGE_COMPRESSIONS

    rows: list[Row] = []
    repo = pathlib.Path(__file__).resolve().parents[1]
    base = None
    for n in (1, 2) if smoke() else (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n} "
                            + env.get("XLA_FLAGS", ""))
        env["PYTHONPATH"] = str(repo / "src")
        out = subprocess.run([sys.executable, "-c",
                              textwrap.dedent(_CHILD)],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        if out.returncode != 0:
            rows.append((f"weak_scaling_n{n}", -1.0,
                         f"error={out.stderr.strip()[-120:]}"))
            continue
        results = {}
        for line in out.stdout.splitlines():
            if line.startswith("RESULT"):
                _, n_s, comp, t_s = line.split()
                results[comp] = float(t_s)

        # headline row (uncompressed): weak-scaling efficiency
        us = results["none"] * 1e6
        if base is None:
            base = us
        rows.append((f"weak_scaling_n{n}", us,
                     f"efficiency={base / us:.3f}"))

        # per-compression rows: measured time + modeled per-rank
        # exchange bytes for this local block (Tl fixed, Z unsharded)
        Tl = 4
        _, Z, Y, X = (4, 4, 4, 8) if smoke() else (4, 8, 8, 16)
        none_us = results["none"] * 1e6
        for comp in COMPRESSIONS:
            if comp not in results:
                continue
            traffic = halo_traffic_model(
                Tl, Z, Y, X // 2,
                gauge_comps=GAUGE_COMPRESSIONS[comp])
            cus = results[comp] * 1e6
            rows.append((
                f"weak_scaling_n{n}_{comp}", cus,
                f"gauge_comps={GAUGE_COMPRESSIONS[comp]};"
                f"bytes_gauge_exchange={traffic['bytes_gauge_exchange']};"
                f"bytes_spinor_exchange="
                f"{traffic['bytes_spinor_exchange']};"
                f"bytes_dhat_exchange={traffic['bytes_dhat_exchange']};"
                f"time_vs_none={cus / none_us:.2f}x"))
    write_json("scaling", rows)
    return rows
