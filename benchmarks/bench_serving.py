"""Serving daemon under Poisson load: coalescing beats sequential.

The claim behind ``repro.serving``: at equal offered load, coalescing
independent requests into multi-RHS blocks raises throughput, because
the bandwidth-bound kernel streams the gauge field once per *batch*
(``BENCH_multirhs.json`` prices the per-application amortization; this
bench prices the end-to-end service).  Machine-checkable rows in
``BENCH_serving.json``:

* ``serving_sequential_nrhs1`` — the baseline policy: ``max_block=1``,
  every request solved alone, same arrival schedule.
* ``serving_coalesced_linger_{zero,small,large}`` — ``max_block=4``
  with the linger knob swept: 0 coalesces only requests already queued
  together, small adds a short wait for company, large trades latency
  for fill.  Each row carries throughput (solves/s), latency p50/p95
  (ms), mean batch fill (columns per dispatched batch), and the
  speedup over the sequential row.

The arrival process is identical across rows (same seed, same Poisson
schedule, same sources — mean interarrival at a fraction of the solo
solve time, so the offered load exceeds the sequential service rate
and queueing discipline is what differs).  The acceptance assert:
best coalesced throughput > sequential throughput with batch fill > 1.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.core import evenodd, su3
from repro.serving import (AdmissionPolicy, BatchingPolicy,
                           PropagatorDaemon, SessionPool)

from .common import Row, smoke, write_json

EPS = 0.2
SEED = 7
KAPPA = 0.1245


def _sources(shape, n):
    k = jax.random.PRNGKey(101)
    out = []
    for i in range(n):
        ki = jax.random.fold_in(k, i)
        psi = (jax.random.normal(ki, (*shape, 4, 3))
               + 1j * jax.random.normal(jax.random.fold_in(ki, 1),
                                        (*shape, 4, 3))
               ).astype(jnp.complex64)
        out.append(evenodd.pack(psi))
    return out


def _replay(pool, batching, sources, arrivals, spec) -> dict:
    """Replay one arrival schedule against one batching policy; the
    pool (and its compiled executables) is shared across replays, so
    rows measure queueing discipline, not compilation."""
    daemon = PropagatorDaemon(
        pool=pool, batching=batching,
        admission=AdmissionPolicy(max_queue_depth=4096,
                                  default_timeout_s=None))
    daemon.start()
    done = {}
    futs = []
    t0 = time.monotonic()
    try:
        for i, ((ee, eo), at) in enumerate(zip(sources, arrivals)):
            lag = t0 + at - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            ts = time.monotonic()
            f = daemon.submit("cfg", ee, eo, spec)
            f.add_done_callback(
                lambda fr, i=i, ts=ts:
                done.__setitem__(i, time.monotonic() - ts))
            futs.append(f)
        results = [f.result(timeout=600) for f in futs]
    finally:
        daemon.drain()
    total = time.monotonic() - t0
    assert all(r.converged for r in results)
    lats = np.array([done[i] for i in range(len(futs))])
    m = daemon.metrics()
    return {
        "total_s": total,
        "throughput_sps": len(futs) / total,
        "p50_ms": float(np.percentile(lats, 50)) * 1e3,
        "p95_ms": float(np.percentile(lats, 95)) * 1e3,
        "fill": m["mean_batch_columns"],
        "batches": m["batches"],
    }


def run() -> List[Row]:
    rows: List[Row] = []
    if smoke():
        shape, n_requests = (4, 4, 4, 8), 16
    else:
        shape, n_requests = (8, 8, 8, 8), 48

    U = su3.weak_gauge(jax.random.PRNGKey(SEED), shape, eps=EPS)
    Ue, Uo = evenodd.pack_gauge(U)
    matrix = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
    spec = api.SolveSpec(method="cgnr", tol=1e-6)

    # One pool for every row: compile each bucket once up front, so the
    # replays compare queueing policy at steady state.
    pool = SessionPool()
    pool.register("cfg", matrix)
    warm = pool.warmup("cfg", spec, buckets=(1, 2, 4))
    solo_s = min(warm.values())
    # steady-state solo solve time sets the offered load: arrivals at
    # ~6x the sequential service rate, so the queue actually builds
    e = pool.entry("cfg")
    eta_e, eta_o = _sources(shape, 1)[0]
    t0 = time.perf_counter()
    e.session.solve_block(eta_e, eta_o, spec)
    solo_s = time.perf_counter() - t0

    rng = np.random.default_rng(13)
    arrivals = np.cumsum(rng.exponential(solo_s / 6.0, n_requests))
    sources = _sources(shape, n_requests)

    policies = [
        ("serving_sequential_nrhs1",
         BatchingPolicy(max_block=1, linger_s=0.0, buckets=(1,))),
        ("serving_coalesced_linger_zero",
         BatchingPolicy(max_block=4, linger_s=0.0, buckets=(1, 2, 4))),
        ("serving_coalesced_linger_small",
         BatchingPolicy(max_block=4, linger_s=2 * solo_s,
                        buckets=(1, 2, 4))),
        ("serving_coalesced_linger_large",
         BatchingPolicy(max_block=4, linger_s=20 * solo_s,
                        buckets=(1, 2, 4))),
    ]

    stats = {}
    for name, pol in policies:
        stats[name] = _replay(pool, pol, sources, arrivals, spec)

    base = stats["serving_sequential_nrhs1"]
    for name, _ in policies:
        s = stats[name]
        speedup = s["throughput_sps"] / base["throughput_sps"]
        rows.append((
            name, s["total_s"] / n_requests * 1e6,
            f"throughput_sps={s['throughput_sps']:.3f};"
            f"p50_ms={s['p50_ms']:.1f};p95_ms={s['p95_ms']:.1f};"
            f"batch_fill={s['fill']:.2f};batches={s['batches']};"
            f"requests={n_requests};solo_ms={solo_s * 1e3:.1f};"
            f"speedup_vs_sequential={speedup:.2f}x"))

    best = max(s["throughput_sps"] for k, s in stats.items()
               if k != "serving_sequential_nrhs1")
    # the acceptance claim: same offered load, same sources — batching
    # policy alone must buy throughput (and actually coalesce)
    assert best > base["throughput_sps"], \
        (best, base["throughput_sps"])
    assert max(s["fill"] for k, s in stats.items()
               if k != "serving_sequential_nrhs1") > 1.0

    write_json("serving", rows)
    return rows
