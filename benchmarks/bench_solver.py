"""Solver benchmark: iterations + sustained throughput of the even-odd
Schur solve (the paper's workload unit) on reduced paper volumes,
CGNR vs BiCGStab, with the operator routed through the backend registry
(off-TPU the kernel backends run the Pallas interpreter, so only the
``jnp`` entry is timed there).  Solves iterate in each backend's native
vector domain — encode/decode happens once per solve, so these numbers
include zero per-iteration layout-conversion tax (see bench_breakdown
for that tax measured in isolation)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import api, backends
from repro.core import evenodd, su3

from .common import Row


def run() -> list:
    rows: list[Row] = []
    kappa = 0.13
    on_tpu = jax.default_backend() == "tpu"
    backends_to_time = (("jnp", "pallas", "pallas_fused") if on_tpu
                        else ("jnp",))
    for label, shape in (("8x8x8x8", (8, 8, 8, 8)),
                         ("8x8x8x16", (8, 8, 8, 16))):
        U = su3.random_gauge(jax.random.PRNGKey(0), shape)
        eta = (jax.random.normal(jax.random.PRNGKey(1), (*shape, 4, 3))
               + 1j * jax.random.normal(jax.random.PRNGKey(2),
                                        (*shape, 4, 3))
               ).astype(jnp.complex64)
        Ue, Uo = evenodd.pack_gauge(U)
        ee, eo = evenodd.pack(eta)
        vol = 1
        for d in shape:
            vol *= d
        for backend in backends_to_time:
            bops = backends.make_wilson_ops(backend, Ue, Uo)
            matrix = api.WilsonMatrix.from_ops(bops, kappa,
                                               gauge=(Ue, Uo))
            for method in ("cgnr", "bicgstab"):
                session = api.SolveSession(
                    matrix, api.SolveSpec(method=method, tol=1e-6))
                t0 = time.perf_counter()
                xe, xo, res = session.solve(ee, eo)
                jax.block_until_ready(xe)
                dt = time.perf_counter() - t0
                iters = int(res.iterations)
                ndhat = 2 * iters
                flops = 1368.0 * vol * ndhat
                rows.append(
                    (f"solver_{backend}_{method}_{label}", dt * 1e6,
                     f"iters={iters};rel={float(res.residual):.2e};"
                     f"gflops={flops / dt / 1e9:.2f}"))
    return rows
