"""Solver benchmark: iterations + sustained throughput of the even-odd
Schur solve (the paper's workload unit) on reduced paper volumes,
CGNR vs BiCGStab."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import evenodd, solver, su3, wilson
from .common import Row


def run() -> list:
    rows: list[Row] = []
    kappa = 0.13
    for label, shape in (("8x8x8x8", (8, 8, 8, 8)),
                         ("8x8x8x16", (8, 8, 8, 16))):
        U = su3.random_gauge(jax.random.PRNGKey(0), shape)
        eta = (jax.random.normal(jax.random.PRNGKey(1), (*shape, 4, 3))
               + 1j * jax.random.normal(jax.random.PRNGKey(2),
                                        (*shape, 4, 3))
               ).astype(jnp.complex64)
        Ue, Uo = evenodd.pack_gauge(U)
        ee, eo = evenodd.pack(eta)
        vol = 1
        for d in shape:
            vol *= d
        for method in ("cgnr", "bicgstab"):
            t0 = time.perf_counter()
            xe, xo, res = solver.solve_wilson_eo(
                Ue, Uo, ee, eo, kappa, method=method, tol=1e-6)
            jax.block_until_ready(xe)
            dt = time.perf_counter() - t0
            iters = int(res.iterations)
            ndhat = 2 * iters if method == "cgnr" else 2 * iters
            flops = 1368.0 * vol * ndhat
            rows.append((f"solver_{method}_{label}", dt * 1e6,
                         f"iters={iters};rel={float(res.residual):.2e};"
                         f"gflops={flops / dt / 1e9:.2f}"))
    return rows
