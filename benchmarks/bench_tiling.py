"""Paper Table 1 analogue: 2-D site-packing shape sweep.

The paper varies VLENY x VLENX at fixed local volume.  On TPU the packed
tile is the whole (Y, Xh) plane, so the sweep becomes the plane aspect
ratio at fixed volume: how the same 4-D volume maps onto (sublane, lane)
dims.  We measure the jit'd even-odd Dhat wall time per application on
CPU (structure-true; absolute numbers are CPU-bound) and report the
model-flops throughput, for the paper's three local volumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import evenodd, su3
from repro.kernels import layout, ops, ref

from .common import Row, time_fn

# (label, (T, Z, Y, X)) — paper Table 1 volumes, aspect-swept in (Y, X)
CASES = [
    ("16x16x16x16_y16x8", (16, 16, 16, 16)),
    ("16x16x8x32_y8x16", (16, 16, 8, 32)),
    ("16x16x32x8_y32x4", (16, 16, 32, 8)),
    ("64x16x8x8_y8x4", (16, 8, 8, 64)),     # 64x16x8x8 permuted: X=64 packed
    ("64x32x16x8_y16x4", (8, 16, 16, 64)),  # reduced T*Z to bound CPU time
]


def run() -> list:
    rows: list[Row] = []
    kappa = 0.13
    for label, (T, Z, Y, X) in CASES:
        U = su3.random_gauge(jax.random.PRNGKey(0), (T, Z, Y, X))
        psi = (jax.random.normal(jax.random.PRNGKey(1), (T, Z, Y, X, 4, 3))
               + 1j * jax.random.normal(jax.random.PRNGKey(2),
                                        (T, Z, Y, X, 4, 3))
               ).astype(jnp.complex64)
        Ue, Uo = evenodd.pack_gauge(U)
        e, _ = evenodd.pack(psi)
        Uep, Uop = ops.make_planar_fields(Ue, Uo)
        ep = layout.spinor_to_planar(e)

        fn = jax.jit(lambda a, b, c: ref.apply_dhat_planar_ref(a, b, c,
                                                               kappa))
        us = time_fn(fn, Uep, Uop, ep)
        vol = T * Z * Y * X
        gflops = 1368.0 * vol / (us * 1e-6) / 1e9
        rows.append((f"tiling_{label}", us,
                     f"cpu_sustained_gflops={gflops:.2f}"))
    return rows
