"""Shared benchmark helpers. Output rows are ``name,us_per_call,derived``."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]


def smoke() -> bool:
    """CI smoke mode: tiny volumes, few timing reps (set by run.py --smoke
    or the REPRO_BENCH_SMOKE env var)."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (device-synced)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def write_json(bench: str, rows: List[Row]) -> str:
    """Machine-readable mirror of the CSV rows: ``BENCH_<bench>.json``.

    The ``derived`` field's ``k=v;k=v`` pairs are split out so downstream
    tooling (perf dashboards, regression gates) need no string parsing.
    Output directory: $REPRO_BENCH_DIR or the cwd.
    """
    def parse_derived(derived: str) -> dict:
        out = {}
        for part in derived.split(";"):
            if "=" in part:
                k, v = part.split("=", 1)
                try:
                    out[k] = float(v.rstrip("x"))
                except ValueError:
                    out[k] = v
        return out

    path = os.path.join(os.environ.get("REPRO_BENCH_DIR", "."),
                        f"BENCH_{bench}.json")
    payload = {
        "bench": bench,
        "jax_backend": jax.default_backend(),
        "smoke": smoke(),
        "rows": [{"name": name, "us_per_call": us, "derived": derived,
                  **parse_derived(derived)}
                 for name, us, derived in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path
