"""Deliberately gather-based even-odd hopping block — the "before" code of
the paper's Fig. 8 story.

The paper found the compiler emitting gather-load/scatter-store for a
portable inner loop, bottlenecking L1; replacing them with register
shuffles (sel/tbl/ext) recovered 10x.  This module is the JAX analogue of
the *bad* version: every neighbor fetch is an explicit index gather
(``take_along_axis`` with per-site index arrays) instead of the masked
rolls in :mod:`repro.core.evenodd`.  Benchmarked against the shuffle
version in bench_breakdown.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import gamma
from repro.core.lattice import NDIM


def _neighbor_index(shape, mu, direction, out_parity):
    """Per-site gather indices (flat, over the compacted lattice)."""
    T, Z, Y, Xh = shape
    t = jnp.arange(T).reshape(T, 1, 1, 1)
    z = jnp.arange(Z).reshape(1, Z, 1, 1)
    y = jnp.arange(Y).reshape(1, 1, Y, 1)
    xh = jnp.arange(Xh).reshape(1, 1, 1, Xh)
    t, z, y, xh = (jnp.broadcast_to(a, shape) for a in (t, z, y, xh))
    if mu == 3:
        t = (t + direction) % T
    elif mu == 2:
        z = (z + direction) % Z
    elif mu == 1:
        y = (y + direction) % Y
    else:
        par = (t + z + y) % 2
        m = (out_parity + (1 if direction > 0 else 0)) % 2
        xh = jnp.where(par == m, (xh + direction) % Xh, xh)
    return ((t * Z + z) * Y + y) * Xh + xh


def gather_fetch(field, idx):
    """field: (T,Z,Y,Xh,...) -> neighbor values via flat gather."""
    T, Z, Y, Xh = field.shape[:4]
    rest = field.shape[4:]
    flat = field.reshape(T * Z * Y * Xh, *rest)
    return flat[idx.reshape(-1)].reshape(T, Z, Y, Xh, *rest)


def hop_block_gather(U_e, U_o, src, out_parity):
    """Same math as evenodd.hop_block, all neighbor access via gathers."""
    shape = src.shape[:4]
    U_out = U_o if out_parity else U_e
    U_in = U_e if out_parity else U_o
    out = jnp.zeros_like(src)
    for mu in range(NDIM):
        idx_f = _neighbor_index(shape, mu, +1, out_parity)
        idx_b = _neighbor_index(shape, mu, -1, out_parity)
        fwd = gather_fetch(src, idx_f)
        h = gamma.project(fwd, mu, s=-1)
        uh = jnp.einsum("...ab,...hb->...ha", U_out[mu], h)
        out = out + gamma.reconstruct(uh, mu, s=-1)
        bwd = gather_fetch(src, idx_b)
        u_bwd = gather_fetch(U_in[mu], idx_b)
        h = gamma.project(bwd, mu, s=+1)
        uh = jnp.einsum("...ba,...hb->...ha", u_bwd.conj(), h)
        out = out + gamma.reconstruct(uh, mu, s=+1)
    return out
