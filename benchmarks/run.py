"""Benchmark runner — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only tiling,breakdown,...]

Each benchmark mirrors its rows to ``BENCH_<name>.json`` (see
``common.write_json``) with the schema::

    {"bench": str,            # benchmark name
     "jax_backend": str,      # "cpu" | "tpu" | ...
     "smoke": bool,           # tiny-volume CI mode
     "rows": [{"name": str, "us_per_call": float, "derived": str,
               <derived k=v pairs, floats parsed>...}]}

``BENCH_multirhs.json`` rows carry the multi-RHS acceptance evidence:
``multirhs_dhat_nrhs<N>`` (``per_rhs_us`` + ``model_*`` gauge-traffic
amortization numbers), ``multirhs_gauge_load_invariance``
(``pallas_calls_batched_hop=1``, nrhs-independent ``gauge_bytes_*``),
``multirhs_batched_vs_sequential_<backend>`` (``max_col_rel_diff`` vs
independent solves, every registered backend), and
``multirhs_mixed_precision_f32_inner`` (``f64_applies_mixed`` <
``f64_applies_pure`` at the same f64 tolerance).
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = ("tiling", "breakdown", "halo", "solver", "scaling", "lm",
           "multirhs", "resilience", "deflation", "serving")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma list from {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny volumes / few reps (CI): numbers are not "
                         "hardware-meaningful, only exercise the paths")
    args = ap.parse_args()
    if args.smoke:
        import os
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    which = args.only.split(",") if args.only else list(BENCHES)

    from .common import emit

    print("name,us_per_call,derived")
    failed = 0
    for name in which:
        try:
            mod = __import__(f"benchmarks.bench_{name}",
                             fromlist=["run"])
            emit(mod.run())
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"bench_{name},-1.0,error", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
