"""Benchmark runner — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only tiling,breakdown,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = ("tiling", "breakdown", "halo", "solver", "scaling", "lm")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma list from {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny volumes / few reps (CI): numbers are not "
                         "hardware-meaningful, only exercise the paths")
    args = ap.parse_args()
    if args.smoke:
        import os
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    which = args.only.split(",") if args.only else list(BENCHES)

    from .common import emit

    print("name,us_per_call,derived")
    failed = 0
    for name in which:
        try:
            mod = __import__(f"benchmarks.bench_{name}",
                             fromlist=["run"])
            emit(mod.run())
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"bench_{name},-1.0,error", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
