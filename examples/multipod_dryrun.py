"""Multi-pod dry-run example: lower + compile one LM cell and the QCD
production lattice on the 512-chip mesh, print the roofline terms.

  PYTHONPATH=src python examples/multipod_dryrun.py
(needs no accelerator: forces 512 host devices)
"""
import subprocess
import sys
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]


def main():
    env_py = [sys.executable, "-m", "repro.launch.dryrun",
              "--arch", "deepseek-7b", "--shape", "decode_32k",
              "--mesh", "multi"]
    print("running:", " ".join(env_py))
    subprocess.run(env_py, check=True, cwd=REPO,
                   env={"PYTHONPATH": str(REPO / "src"),
                        "PATH": "/usr/bin:/bin:/usr/local/bin"})
    subprocess.run([sys.executable, "-m", "repro.launch.roofline"],
                   check=True, cwd=REPO,
                   env={"PYTHONPATH": str(REPO / "src"),
                        "PATH": "/usr/bin:/bin:/usr/local/bin"})


if __name__ == "__main__":
    main()
