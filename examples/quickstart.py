"""Quickstart: the paper's operator in five minutes.

  PYTHONPATH=src python examples/quickstart.py

1. Build a random SU(3) gauge field and a spinor source.
2. Apply the full-lattice Wilson matrix D_W.
3. Pack to the even-odd layout (the paper's data layout) and apply the
   hopping blocks — exactly equal to the full operator.
4. Run the Pallas TPU kernel (interpret mode on CPU) and check it against
   the pure-jnp oracle.
5. Bind the operator ONCE into the public API's WilsonMatrix, solve
   D_W xi = eta through a SolveSession, and verify — a second solve
   reuses the compiled Krylov loop (see the session stats).
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.core import evenodd, su3, wilson
from repro.kernels import layout, ops, ref


def main():
    T, Z, Y, X = 8, 8, 8, 8
    kappa = 0.13
    key = jax.random.PRNGKey(0)

    print("1) gauge + source ...")
    U = su3.random_gauge(key, (T, Z, Y, X))
    eta = (jax.random.normal(jax.random.PRNGKey(1), (T, Z, Y, X, 4, 3))
           + 1j * jax.random.normal(jax.random.PRNGKey(2),
                                    (T, Z, Y, X, 4, 3))
           ).astype(jnp.complex64)
    print(f"   plaquette = {float(su3.plaquette(U)):.4f} "
          f"(unit gauge would be 1.0)")

    print("2) full-lattice D_W ...")
    d_eta = wilson.apply_wilson(U, eta, kappa)

    print("3) even-odd layout ...")
    Ue, Uo = evenodd.pack_gauge(U)
    ee, eo = evenodd.pack(eta)
    de, do = evenodd.apply_wilson_eo(Ue, Uo, ee, eo, kappa)
    fe, fo = evenodd.pack(d_eta)
    err = max(float(jnp.max(jnp.abs(de - fe))),
              float(jnp.max(jnp.abs(do - fo))))
    print(f"   even-odd vs full operator: max err {err:.2e}")

    print("4) Pallas kernel (interpret mode off-TPU) ...")
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(ee)
    got = ops.apply_dhat_planar(Uep, Uop, ep, kappa, interpret=True)
    want = ref.apply_dhat_planar_ref(Uep, Uop, ep, kappa)
    print(f"   kernel vs oracle: max err "
          f"{float(jnp.max(jnp.abs(got - want))):.2e}")

    print("5) solve D_W xi = eta (public API: WilsonMatrix + "
          "SolveSession, BiCGStab) ...")
    D = api.WilsonMatrix.bind(Ue, Uo, kappa, backend="jnp")
    session = api.SolveSession(D, api.SolveSpec(method="bicgstab",
                                                tol=1e-6))
    xe, xo, res = session.solve(ee, eo)
    xi = evenodd.unpack(xe, xo)
    rel = float(jnp.linalg.norm(eta - wilson.apply_wilson(U, xi, kappa))
                / jnp.linalg.norm(eta))
    print(f"   {int(res.iterations)} iterations, "
          f"true relative residual {rel:.2e}")

    print("   ... and a second same-shape solve reuses the compiled "
          "Krylov loop:")
    eta2 = (jax.random.normal(jax.random.PRNGKey(3), (T, Z, Y, X, 4, 3))
            + 1j * jax.random.normal(jax.random.PRNGKey(4),
                                     (T, Z, Y, X, 4, 3))
            ).astype(jnp.complex64)
    session.solve(*evenodd.pack(eta2))
    st = session.stats()
    print(f"   session stats: solves={st['solves']} "
          f"traces={st['traces']} cache_hits={st['cache_hits']}")
    print("done.")


if __name__ == "__main__":
    main()
