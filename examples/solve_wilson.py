"""End-to-end driver example: batch of Wilson solves with checkpointing
and a simulated failure + restart, plus an operator-backend sweep —
backend choice is just a registry string (see repro.backends), and every
solve iterates in the chosen backend's *native* vector domain (complex
for jnp, planar for the Pallas kernels, sharded planar for distributed)
with encode/decode only at solve entry/exit.

The driver is built on :mod:`repro.api`: each ``solve.main`` run binds
the gauge once into a ``WilsonMatrix`` and pushes every solve through
one ``SolveSession``, so the per-run session report at the end of each
block shows the compiled-solve cache at work (solves=N, traces=1).

  PYTHONPATH=src python examples/solve_wilson.py
"""
import tempfile

from repro.launch import solve


def main():
    with tempfile.TemporaryDirectory() as d:
        print("=== two solves with checkpointing ===")
        solve.main(["--lattice", "wilson-16x16x16x16", "--tol", "1e-5",
                    "--n-solves", "2", "--ckpt-dir", d])
        print("\n=== restart: resume the same workload (idempotent) ===")
        solve.main(["--lattice", "wilson-16x16x16x16", "--tol", "1e-5",
                    "--n-solves", "1", "--ckpt-dir", d])
    print("\n=== same solve through the fused-kernel backend ===")
    solve.main(["--lattice", "wilson-8x8x8x8", "--tol", "1e-5",
                "--n-solves", "1", "--backend", "pallas_fused"])
    print("\n=== sharded-native solve: spinors stay placed on the mesh "
          "across all iterations ===")
    solve.main(["--lattice", "wilson-8x8x8x8", "--tol", "1e-5",
                "--n-solves", "1", "--backend", "distributed",
                "--recompute-every", "25"])
    print("\n=== multi-RHS: 4 sources in ONE batched Krylov solve (gauge "
          "streamed once per application for the whole block) ===")
    solve.main(["--lattice", "wilson-8x8x8x8", "--tol", "1e-5",
                "--n-solves", "1", "--nrhs", "4", "--method", "bicgstab"])
    print("\n=== mixed precision: f32 inner solves, f64 outer "
          "iterative-refinement loop to 1e-10 ===")
    solve.main(["--lattice", "wilson-8x8x8x8", "--tol", "1e-10",
                "--n-solves", "1", "--inner-dtype", "f32"])
    print("\n=== plain CG on the normal equations (--method cg, the "
          "choice list is derived from SolveSpec.METHODS) ===")
    solve.main(["--lattice", "wilson-8x8x8x8", "--tol", "1e-5",
                "--n-solves", "1", "--method", "cg"])


if __name__ == "__main__":
    main()
