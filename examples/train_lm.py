"""Train a ~100M-param member of an assigned architecture family for a
few hundred steps on synthetic data, with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py                 # quick demo
  PYTHONPATH=src python examples/train_lm.py --full          # ~100M, 300 steps
"""
import argparse
import tempfile

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="deepseek-7b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        if args.full:
            # ~100M params: scale 0.22 of deepseek-7b (d=896, 6 layers)
            train.main(["--arch", args.arch, "--scale", "0.22",
                        "--steps", "300", "--batch", "8", "--seq", "512",
                        "--ckpt-dir", d, "--ckpt-every", "100"])
        else:
            train.main(["--arch", args.arch, "--scale", "0.03",
                        "--steps", "30", "--batch", "4", "--seq", "128",
                        "--ckpt-dir", d, "--ckpt-every", "10"])


if __name__ == "__main__":
    main()
