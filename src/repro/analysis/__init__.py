"""``repro.analysis`` — the repo's invariants as a mechanical CI gate.

Five PRs of Wilson-kernel work accumulated a set of layout/packing
invariants that the paper (arXiv 2303.08609) argues performance lives or
dies on — and that until now were enforced by reviewer memory plus a
handful of one-off jaxpr string checks in tests.  This package checks
them mechanically, in two layers:

**Layer 1 — AST convention linter** (:mod:`repro.analysis.lint` plus the
per-rule modules under :mod:`repro.analysis.rules`): pure-syntax rules
walked over every Python file in the repo.

* **R1** — version-drifted JAX APIs (``shard_map``, ``make_mesh`` /
  ``AxisType``, ``axis_size``, Pallas compiler params) may only be
  touched via :mod:`repro.compat`; ``src/repro/kernels/`` alone may
  import ``jax.experimental.pallas`` directly.
* **R2** — operator implementations are reached through the backend
  registry (``register_backend`` / ``get_backend``), never hand-wired
  across module boundaries inside ``src/repro``.
* **R3** — the ``solve_wilson_eo`` shim was deleted at its PR 7
  removal horizon; defining or referencing that name anywhere is an
  error — callers configure via :mod:`repro.api` specs.
* **R4** — no ``device_put`` / ``to_domain`` / layout-codec calls
  syntactically inside a Krylov ``while_loop`` body in
  ``core/solver.py`` (the conversion-free / placement-free hot loop).

A finding can be waived inline with ``# repro-lint: allow[R2] reason``
on the offending line (or the line above); waivers are for designated
exemptions with a stated reason, not for postponing fixes — postponed
findings belong in the ``--baseline`` file instead.

**Layer 2 — jaxpr invariant analyzers**
(:mod:`repro.analysis.jaxpr_checks`): structural checks that trace the
real entry points.

* **J1** — the native-domain Krylov solve is conversion-free: no
  ``convert_element_type`` on spinor-shaped values anywhere in the
  traced solve, except the compensated-reduction bf16→f32 upcasts.
* **J2** — each fused-Dhat policy branch lowers to its exact
  ``pallas_call`` count (resident: 1, stream: 1, unfused: 2) under the
  declared kernel names.
* **J3** — an independent static VMEM estimate agrees with
  ``fused_dhat_policy`` / ``fused_dhat_fits`` / ``stream_ring_bytes``
  at exact byte boundaries (and the ring is T-independent).
* **J4** — a replayed :class:`repro.api.SolveSession` scenario stays
  within its declared trace budget (no retrace regressions).
* **J5** — the distributed ``overlap="interior"`` schedule keeps its
  interior kernels independent of the in-flight halo ``ppermute``s
  (taint propagation over the ``shard_map`` body jaxpr), so the
  comms/compute overlap claim is structural, not a timing artifact.

Run the gate::

    PYTHONPATH=src python -m repro.analysis            # lint + jaxpr
    PYTHONPATH=src python -m repro.analysis --dead-code  # + seed audit

Exit status is non-zero iff any finding is not in the baseline file
(``--baseline analysis_baseline.json``; ship it empty — the gate exists
to keep it that way).
"""
from __future__ import annotations

from .findings import Finding, load_baseline, write_baseline
from .lint import run_lint
from .jaxpr_checks import run_jaxpr_checks

__all__ = ["Finding", "load_baseline", "write_baseline", "run_lint",
           "run_jaxpr_checks"]
