"""``python -m repro.analysis`` — run the static-analysis gate.

Exit status 0 iff every finding is grandfathered by the baseline (the
shipped baseline is empty, so in practice: iff there are no findings).
The dead-seed audit (``--dead-code``) is report-only and never affects
the exit status.
"""
from __future__ import annotations

import argparse
import json
import sys

from .deadcode import dead_code_report, format_dead_code
from .findings import (format_findings, load_baseline, split_baselined,
                       write_baseline)
from .jaxpr_checks import ALL_JAXPR_CHECKS, run_jaxpr_checks
from .lint import run_lint
from .rules import ALL_RULES


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST convention linter (R1-R4) + jaxpr invariant "
                    "analyzers (J1-J6) for the Wilson-kernel repo.")
    p.add_argument("--root", default=".",
                   help="repository root to analyze (default: cwd)")
    p.add_argument("--baseline", metavar="PATH",
                   help="JSON baseline of grandfathered finding keys; "
                        "findings in it are reported but don't fail "
                        "the gate")
    p.add_argument("--write-baseline", metavar="PATH",
                   help="write all current findings to PATH as the new "
                        "baseline and exit 0")
    p.add_argument("--json", metavar="PATH",
                   help="also dump the full findings report as JSON "
                        "(CI artifact)")
    p.add_argument("--lint-only", action="store_true",
                   help="skip the jaxpr analyzers (no JAX import; "
                        "pure-AST pass only)")
    p.add_argument("--jaxpr-only", action="store_true",
                   help="skip the AST linter")
    p.add_argument("--checks", metavar="IDS",
                   help="comma-separated subset, e.g. 'R1,R3,J2'")
    p.add_argument("--dead-code", action="store_true",
                   help="append the (report-only) dead-seed audit")
    p.add_argument("--list-rules", action="store_true",
                   help="print rule ids and descriptions, then exit")
    return p


def _selected(args):
    if args.checks:
        ids = {c.strip().upper() for c in args.checks.split(",")}
    else:
        ids = None
    lint_ids = None
    jaxpr_ids = None
    if ids is not None:
        lint_ids = [r for r in ALL_RULES if r.RULE_ID in ids]
        jaxpr_ids = [c for c in ALL_JAXPR_CHECKS if c in ids]
        known = {r.RULE_ID for r in ALL_RULES} | set(ALL_JAXPR_CHECKS)
        unknown = ids - known
        if unknown:
            raise SystemExit(f"unknown check ids: {sorted(unknown)}; "
                             f"known: {sorted(known)}")
    run_ast = not args.jaxpr_only and (lint_ids is None or lint_ids)
    run_jx = not args.lint_only and (jaxpr_ids is None or jaxpr_ids)
    return run_ast, lint_ids, run_jx, jaxpr_ids


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.RULE_ID}  {rule.DESCRIPTION}")
        from . import jaxpr_checks as jx
        for name in ALL_JAXPR_CHECKS:
            doc = (jx._CHECK_FNS[name].__doc__ or "").strip()
            print(f"{name}  {doc.splitlines()[0]}")
        return 0

    run_ast, lint_ids, run_jx, jaxpr_ids = _selected(args)

    findings = []
    if run_ast:
        findings.extend(run_lint(args.root, rules=lint_ids))
    if run_jx:
        findings.extend(run_jaxpr_checks(args.root, checks=jaxpr_ids))
    findings.sort()

    if args.write_baseline:
        write_baseline(args.write_baseline, findings)
        print(f"wrote {len(findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline_keys = (load_baseline(args.baseline)
                     if args.baseline else [])
    fresh, grandfathered, stale = split_baselined(findings,
                                                  baseline_keys)

    print(format_findings(fresh, title="findings"))
    if grandfathered:
        print(format_findings(grandfathered,
                              title="grandfathered (baseline)"))
    if stale:
        print(f"stale baseline keys ({len(stale)}) — fixed or moved; "
              "prune them:")
        for key in stale:
            print(f"  {key}")

    dead = None
    if args.dead_code:
        dead = dead_code_report(args.root)
        print()
        print(format_dead_code(dead))

    if args.json:
        payload = {
            "fresh": [f.to_json() for f in fresh],
            "grandfathered": [f.to_json() for f in grandfathered],
            "stale_baseline_keys": stale,
        }
        if dead is not None:
            payload["dead_code"] = dead
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")

    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
