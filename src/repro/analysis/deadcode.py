"""Dead-seed audit: which ``repro`` modules the product surface reaches.

The growth seed shipped a generic LLM training scaffold (architecture
configs, model zoo, optimizer stack, data pipeline, checkpointing)
alongside the lattice-QCD line that this repo actually grows.  This
audit walks the static import graph from the *product surface* — the
public :mod:`repro.api` package and the :mod:`repro.launch.solve` CLI —
and reports every ``repro`` module the surface never reaches, so dormant
seed code is an explicit, reviewed list instead of silent weight.

**Report-only by design**: ROADMAP item 5 earmarks parts of the dormant
set (gauge-configuration checkpointing, data pipeline, ``train.py``'s
launch loop) for harvest into QCD workflow tooling, so dormancy is
expected there — those roots carry an ``intentional`` annotation rather
than a deletion suggestion.  The runner never fails the gate on this
report.

Import edges are collected syntactically (``import x`` / ``from x
import y``, absolute and relative), so conditional and function-local
imports count as edges — this is a reachability audit, not a tree
shaker.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Set, Tuple

#: Modules the product actually serves: the public API, the ``python
#: -m``-able CLIs (solver, dry-run cost model, roofline report), and
#: this analysis gate itself.  ``repro.launch.train`` is deliberately
#: NOT a root — it is a harvest target (see :data:`INTENTIONAL`), so it
#: and everything only it reaches must show up in the report.
ROOTS = ("repro.api", "repro.launch.solve", "repro.launch.dryrun",
         "repro.launch.roofline", "repro.launch.serve",
         "repro.analysis.__main__")

#: Dormant-on-purpose prefixes → the ROADMAP item that plans to harvest
#: them.  These still appear in the report, annotated, so the list stays
#: reviewed rather than forgotten.
INTENTIONAL = {
    "repro.checkpoint": "ROADMAP item 5: harvest for gauge-configuration "
                        "save/restore",
    "repro.data": "ROADMAP item 5: harvest for ensemble/source-batch "
                  "pipelines",
    "repro.launch.train": "ROADMAP item 5: harvest the launch loop for "
                          "multi-solve QCD campaigns",
}

PACKAGE = "repro"


def _module_name(rel_path: str) -> str:
    """src/repro/a/b.py -> repro.a.b ; src/repro/a/__init__.py -> repro.a"""
    parts = rel_path.replace(os.sep, "/").split("/")
    assert parts[0] == "src"
    parts = parts[1:]
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = parts[-1][:-3]
    return ".".join(parts)


def collect_modules(root: str) -> Dict[str, str]:
    """name -> repo-relative path for every module under src/repro."""
    modules: Dict[str, str] = {}
    base = os.path.join(root, "src", PACKAGE)
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            rel = os.path.relpath(os.path.join(dirpath, fn), root)
            modules[_module_name(rel)] = rel
    return modules


def _resolve_relative(importer: str, is_pkg: bool, level: int,
                      module: str) -> str:
    # Relative imports resolve against the importer's package.
    parts = importer.split(".")
    if not is_pkg:
        parts = parts[:-1]
    if level > 1:
        parts = parts[:-(level - 1)]
    return ".".join(parts + ([module] if module else []))


def import_edges(root: str, modules: Dict[str, str]
                 ) -> Dict[str, Set[str]]:
    """Static ``repro``-internal import graph over ``modules``."""
    edges: Dict[str, Set[str]] = {name: set() for name in modules}
    for name, rel in modules.items():
        is_pkg = rel.endswith("__init__.py")
        try:
            with open(os.path.join(root, rel), encoding="utf-8") as fh:
                tree = ast.parse(fh.read())
        except (OSError, SyntaxError):
            continue
        targets: List[str] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                targets.extend(a.name for a in node.names)
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = _resolve_relative(name, is_pkg, node.level,
                                             node.module or "")
                else:
                    base = node.module or ""
                targets.append(base)
                targets.extend(f"{base}.{a.name}" for a in node.names
                               if a.name != "*")
        for tgt in targets:
            # Longest known prefix: "repro.core.solver" matches the
            # module; "repro.core" alone pulls in the package __init__.
            while tgt and tgt not in modules:
                tgt = tgt.rpartition(".")[0]
            if tgt and tgt != name:
                edges[name].add(tgt)
    return edges


def reachable(edges: Dict[str, Set[str]],
              roots: Iterable[str]) -> Set[str]:
    seen: Set[str] = set()
    stack = [r for r in roots if r in edges]
    while stack:
        mod = stack.pop()
        if mod in seen:
            continue
        seen.add(mod)
        # Importing repro.a.b implicitly executes repro.a's __init__.
        parent = mod.rpartition(".")[0]
        if parent in edges and parent not in seen:
            stack.append(parent)
        stack.extend(edges[mod] - seen)
    return seen


def _annotation(name: str) -> Tuple[bool, str]:
    for prefix, why in INTENTIONAL.items():
        if name == prefix or name.startswith(prefix + "."):
            return True, why
    return False, ""


def dead_code_report(root: str) -> dict:
    """The audit as plain data (also what ``--json`` serializes)."""
    modules = collect_modules(root)
    edges = import_edges(root, modules)
    live = reachable(edges, ROOTS)
    dormant = []
    for name in sorted(set(modules) - live):
        intentional, why = _annotation(name)
        dormant.append({"module": name, "path": modules[name],
                        "intentional": intentional, "note": why})
    return {
        "roots": list(ROOTS),
        "modules_total": len(modules),
        "modules_live": len(live),
        "dormant": dormant,
    }


def format_dead_code(report: dict) -> str:
    lines = [
        f"dead-seed audit (report-only): "
        f"{report['modules_live']}/{report['modules_total']} modules "
        f"reachable from {', '.join(report['roots'])}",
    ]
    intentional = [d for d in report["dormant"] if d["intentional"]]
    dormant = [d for d in report["dormant"] if not d["intentional"]]
    if dormant:
        lines.append("")
        lines.append("dormant seed modules (candidates for removal or "
                     "future harvest):")
        lines.extend(f"  {d['path']}  [{d['module']}]" for d in dormant)
    if intentional:
        lines.append("")
        lines.append("dormant on purpose (annotated harvest targets):")
        lines.extend(f"  {d['path']}  [{d['module']}] — {d['note']}"
                     for d in intentional)
    if not report["dormant"]:
        lines.append("no dormant modules.")
    return "\n".join(lines)
