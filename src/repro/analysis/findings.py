"""Finding records, grouped reporting, and the grandfather baseline.

A :class:`Finding` is one violation: rule id, repo-relative path, line,
message.  The baseline file is a JSON list of finding keys — findings
whose key appears there are *grandfathered* (reported, but they don't
fail the gate).  The key includes the line number on purpose: when code
moves, a grandfathered finding goes stale and resurfaces, which is the
gentle pressure to fix instead of accumulate.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List, Sequence, Tuple

__all__ = ["Finding", "load_baseline", "write_baseline",
           "split_baselined", "format_findings"]


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source (or traced-entry-point) location."""

    rule: str        # "R1".."R4" (lint), "J1".."J4" (jaxpr), "DEAD"
    path: str        # repo-relative file, or a symbolic entry-point name
    line: int        # 1-based; 0 for non-source findings
    message: str

    def key(self) -> str:
        """Stable identity used by the baseline file."""
        return f"{self.rule}:{self.path}:{self.line}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def load_baseline(path) -> List[str]:
    """Read a baseline file -> list of finding keys.  Accepts both the
    key-list form and the full finding-object form (``--write-baseline``
    emits the latter, for humans)."""
    with open(path) as f:
        data = json.load(f)
    keys = []
    for entry in data:
        if isinstance(entry, str):
            keys.append(entry)
        else:
            keys.append(Finding(**entry).key())
    return keys


def write_baseline(path, findings: Iterable[Finding]) -> None:
    with open(path, "w") as f:
        json.dump([fi.to_json() for fi in sorted(findings)], f, indent=2)
        f.write("\n")


def split_baselined(findings: Sequence[Finding],
                    baseline_keys: Sequence[str]
                    ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """-> (fresh, grandfathered, stale_baseline_keys)."""
    keys = set(baseline_keys)
    fresh = [f for f in findings if f.key() not in keys]
    old = [f for f in findings if f.key() in keys]
    stale = sorted(keys - {f.key() for f in findings})
    return fresh, old, stale


def format_findings(findings: Sequence[Finding], *,
                    title: str = "findings") -> str:
    """Grouped, file:line-sorted report (rule groups in id order)."""
    if not findings:
        return f"{title}: none"
    lines = [f"{title}: {len(findings)}"]
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    for rule in sorted(by_rule):
        group = sorted(by_rule[rule])
        lines.append(f"  {rule} ({len(group)}):")
        for f in group:
            lines.append(f"    {f.render()}")
    return "\n".join(lines)
