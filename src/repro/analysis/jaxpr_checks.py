"""Jaxpr-level invariant analyzers (the "J" checks).

Where :mod:`repro.analysis.lint` reads the *source*, these checks read
the *traces*: they build tiny-lattice operators through the public
registry/API, trace them with ``jax.make_jaxpr``, and assert the
properties the performance story depends on but no numeric tolerance
can see:

* **J1 — conversion-free native iterate.**  The traced native-domain
  solve pipeline of every registered backend contains no
  ``convert_element_type`` on spinor-sized operands — except the
  compensated-reduction upcasts (narrow float → f32/f64), which are the
  point of :data:`repro.core.solver.COMPENSATED_REDUCTIONS`.
* **J2 — exact pallas_call counts.**  One Dhat application traces to
  exactly 1 ``pallas_call`` on the ``resident`` and ``stream`` fused
  branches and exactly 2 on the ``unfused`` branch.  A refactor that
  silently un-fuses (or double-launches) shows up here, not in any
  parity test.
* **J3 — VMEM model cross-check.**  The static scratch-byte estimates
  (:func:`~repro.kernels.wilson_stencil.fused_dhat_fits`,
  :func:`~repro.kernels.wilson_stencil.stream_ring_bytes`,
  :func:`~repro.kernels.wilson_stencil.fused_dhat_policy`,
  :func:`~repro.kernels.wilson_stencil.dhat_stream_traffic_model`)
  agree with an independently-computed byte count, switch exactly at
  the 12 MiB budget boundary, and the stream ring is T-independent.
* **J4 — retrace budget.**  A replayed :class:`repro.api.SolveSession`
  scenario (repeat solves, a shape change, a spec change) performs
  exactly as many traces as distinct cache keys — the bind-once
  contract expressed as a hard number.
* **J6 — divergence guard in every Krylov loop.**  Every
  ``while_loop`` traced out of :func:`repro.core.solver._run_krylov`
  (all methods, batched and unbatched) carries an ``is_finite``
  primitive in its *cond* jaxpr — the structural footprint of the
  non-finite divergence guard.  A refactor that drops the guard turns
  a single SDC-corrupted residual back into max_iters of silent NaN
  iterations; it shows up here, not in any healthy-path test.
* **J5 — comms/compute overlap schedule.**  The distributed operator
  traced with ``overlap="interior"`` keeps its interior kernels
  *independent* of the in-flight halo exchange: inside the
  ``shard_map`` body, exactly 2 ``pallas_call``s (one interior pass per
  hopping block) whose inputs are NOT data-dependent on any
  ``ppermute`` result (checked by taint propagation over the body
  jaxpr), with all four face exchanges per hop actually present.  A
  refactor that re-serializes the exchange before the main kernel
  shows up here, not in any timing noise.

Every check takes injectable overrides (a wrapped ops factory, a
replacement policy function, a sabotaged session factory) so the test
suite can demonstrate each one *failing* on a seeded violation, not
just passing on the healthy tree.

All checks run on a 4x4x4x8 lattice and only *trace* (no kernel
executes except J4's interpret-mode solves), so the whole layer is
CI-cheap.
"""
from __future__ import annotations

import math
from typing import Callable, Iterable, List, Optional, Sequence

from .findings import Finding

# Findings are anchored at the definition site of the invariant's
# subject so ``file:line`` in the report jumps somewhere actionable.
_ANCHORS = {
    "J1": ("src/repro/core/solver.py", "def make_native_solve"),
    "J2": ("src/repro/kernels/ops.py", "def apply_dhat_planar_any"),
    "J3": ("src/repro/kernels/wilson_stencil.py", "def fused_dhat_policy"),
    "J4": ("src/repro/api/session.py", "class SolveSession"),
    "J5": ("src/repro/distributed/qcd.py", "def make_dhat_fn"),
    "J6": ("src/repro/core/solver.py", "def _run_krylov"),
}

ALL_JAXPR_CHECKS = ("J1", "J2", "J3", "J4", "J5", "J6")

_LATTICE = (4, 4, 4, 8)          # (X, Y, Z, T) — matches the test suite
_KAPPA = 0.13


def _anchor(root: str, check: str):
    """(path, line) of the invariant's subject, by source search."""
    import os
    rel, needle = _ANCHORS[check]
    path = os.path.join(root, rel)
    try:
        with open(path, "r", encoding="utf-8") as fh:
            for i, text in enumerate(fh, start=1):
                if needle in text:
                    return rel, i
    except OSError:
        pass
    return rel, 1


def _finding(root, check, message) -> Finding:
    rel, line = _anchor(root, check)
    return Finding(rule=check, path=rel, line=line, message=message)


# --- shared tiny-lattice fixtures ------------------------------------


def _tiny_eo(seed: int = 2):
    import jax
    import jax.numpy as jnp
    from repro.core import evenodd, su3

    U = su3.random_gauge(jax.random.PRNGKey(seed), _LATTICE)
    k1, k2 = jax.random.PRNGKey(3), jax.random.PRNGKey(4)
    psi = (jax.random.normal(k1, (*_LATTICE, 4, 3))
           + 1j * jax.random.normal(k2, (*_LATTICE, 4, 3))
           ).astype(jnp.complex64)
    e, o = evenodd.pack(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e, o


def _bind(name: str, Ue, Uo):
    """Registry bind, interpret-mode for Pallas backends off-TPU."""
    import jax
    from repro import backends

    opts = ({"interpret": True} if name.startswith("pallas")
            and jax.default_backend() != "tpu" else {})
    return backends.make_wilson_ops(name, Ue, Uo, **opts)


def _walk_eqns(jaxpr):
    """Depth-first over every eqn of a jaxpr and all nested sub-jaxprs
    (while bodies, pjit calls, pallas_call kernels, ...).

    Deliberately NOT deduplicated by sub-jaxpr identity: two call sites
    of one cached pjit share the same ClosedJaxpr object, and J2 must
    count each *launch*, not each distinct kernel body.  Jaxprs are
    acyclic, so per-reference traversal terminates.
    """
    from jax import core as jcore

    agenda = [jaxpr]
    while agenda:
        jx = agenda.pop()
        if isinstance(jx, jcore.ClosedJaxpr):
            jx = jx.jaxpr
        for eqn in jx.eqns:
            yield eqn
            for val in eqn.params.values():
                for sub in _as_jaxprs(val):
                    agenda.append(sub)


def _as_jaxprs(val):
    from jax import core as jcore

    if isinstance(val, (jcore.Jaxpr, jcore.ClosedJaxpr)):
        yield val
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _as_jaxprs(item)


# --- J1: conversion-free native iterate ------------------------------

# Operands at or above this many elements are "spinor-sized"; scalars
# and per-iteration reduction results stay exempt.
_J1_MIN_ELEMENTS = 1024

_FLOAT_WIDTH = {"bfloat16": 16, "float16": 16, "float32": 32,
                "float64": 64}


def _is_compensated_upcast(old_dtype, new_dtype) -> bool:
    """Narrow-float → wider-float: the compensated-reduction pattern."""
    ow = _FLOAT_WIDTH.get(str(old_dtype))
    nw = _FLOAT_WIDTH.get(str(new_dtype))
    return ow is not None and nw is not None and nw > ow


def check_conversion_free(root: str, *,
                          backends: Optional[Sequence[str]] = None,
                          ops_transform: Optional[Callable] = None,
                          method: str = "cgnr") -> List[Finding]:
    """J1: the traced native solve has no layout/precision churn.

    ``ops_transform(bops) -> bops`` lets the self-tests seed a
    violation (e.g. wrap ``apply_dhat_native`` in a bf16 round-trip).
    """
    import jax
    from repro.core import solver

    if backends is None:
        from repro import backends as breg
        backends = breg.available_backends()

    Ue, Uo, e, o = _tiny_eo()
    findings: List[Finding] = []
    for name in backends:
        bops = _bind(name, Ue, Uo)
        if ops_transform is not None:
            bops = ops_transform(bops)
        solve = solver.make_native_solve(bops, _KAPPA, method=method,
                                         tol=1e-6, max_iters=8)
        v_e, v_o = bops.to_domain(e), bops.to_domain(o)
        jaxpr = jax.make_jaxpr(solve)(v_e, v_o)
        for eqn in _walk_eqns(jaxpr):
            if eqn.primitive.name != "convert_element_type":
                continue
            operand = eqn.invars[0].aval
            if math.prod(operand.shape) < _J1_MIN_ELEMENTS:
                continue
            new_dtype = eqn.params.get("new_dtype")
            if _is_compensated_upcast(operand.dtype, new_dtype):
                continue
            findings.append(_finding(
                root, "J1",
                f"backend {name!r} ({method}): convert_element_type "
                f"{operand.dtype} -> {new_dtype} on a "
                f"{tuple(operand.shape)} operand inside the native "
                "solve trace — the Krylov iterate must stay in the "
                "backend's native layout/precision (only "
                "compensated-reduction float upcasts are exempt)"))
            break   # one finding per backend is enough signal
    return findings


# --- J2: exact pallas_call counts per fused branch -------------------

EXPECTED_PALLAS_CALLS = {"resident": 1, "stream": 1, "unfused": 2}


GAUGE_COMPRESSION_AXES = ("none", "two_row", "minimal")


def check_pallas_counts(root: str, *,
                        apply_fn: Optional[Callable] = None,
                        expected: Optional[dict] = None,
                        compressions: Optional[Sequence[str]] = None,
                        ) -> List[Finding]:
    """J2: each fused-policy branch launches its exact kernel count.

    The counts must hold for every stored gauge representation (18/12/8
    real link planes): in-register reconstruction may not add kernel
    launches.  ``apply_fn(u_e_p, u_o_p, src_p, kappa, fused=...)``
    overrides the traced entry point so the self-tests can seed a
    double launch (``compressions`` narrows the sweep for those).
    """
    import jax
    from repro.kernels import layout
    from repro.kernels import ops as kops

    if expected is None:
        expected = EXPECTED_PALLAS_CALLS
    if compressions is None:
        compressions = GAUGE_COMPRESSION_AXES
    if apply_fn is None:
        def apply_fn(u_e_p, u_o_p, src_p, kappa, fused):
            return kops.apply_dhat_planar_any(
                u_e_p, u_o_p, src_p, kappa, fused=fused, interpret=True)

    Ue, Uo, e, _ = _tiny_eo()
    u_e_18, u_o_18 = layout.gauge_to_planar(Ue), layout.gauge_to_planar(Uo)
    src_p = layout.spinor_to_planar(e)

    findings: List[Finding] = []
    for compression in compressions:
        if compression == "none":
            u_e_p, u_o_p = u_e_18, u_o_18
        else:
            u_e_p = layout.gauge_compress_planar(u_e_18, compression)
            u_o_p = layout.gauge_compress_planar(u_o_18, compression)
        for branch, want in sorted(expected.items()):
            jaxpr = jax.make_jaxpr(
                lambda s: apply_fn(u_e_p, u_o_p, s, _KAPPA, branch))(src_p)
            got = sum(1 for eqn in _walk_eqns(jaxpr)
                      if eqn.primitive.name == "pallas_call")
            if got != want:
                findings.append(_finding(
                    root, "J2",
                    f"fused={branch!r} (gauge_compression="
                    f"{compression!r}): one Dhat application traced to "
                    f"{got} pallas_call(s), expected exactly {want} — a "
                    "silent un-fusing (or double launch) changes the "
                    "HBM traffic story without failing any parity "
                    "test"))
    return findings


# --- J3: static VMEM estimates cross-checked -------------------------


def check_vmem_model(root: str, *,
                     fits_fn: Optional[Callable] = None,
                     ring_fn: Optional[Callable] = None,
                     policy_fn: Optional[Callable] = None,
                     headroom_fn: Optional[Callable] = None,
                     limit_bytes: Optional[int] = None) -> List[Finding]:
    """J3: the policy's byte math agrees with an independent estimate.

    The override hooks substitute any one estimator so the self-tests
    can seed an inconsistency (e.g. a policy that streams too early).
    """
    import jax.numpy as jnp
    from repro.kernels import wilson_stencil as ws

    fits = fits_fn or ws.fused_dhat_fits
    ring = ring_fn or ws.stream_ring_bytes
    policy = policy_fn or ws.fused_dhat_policy
    limit = (ws._FUSED_SCRATCH_LIMIT_BYTES
             if limit_bytes is None else limit_bytes)
    window = ws.STREAM_WINDOW_ROWS
    findings: List[Finding] = []

    def plane_elems(shape):
        # Elements of one t-plane of the (possibly batched) planar
        # intermediate: everything except the T axis.
        if len(shape) == 6:          # (nrhs, T, Z, 24, Y, Xh)
            nrhs, _, Z, C, Y, Xh = shape
        else:                        # (T, Z, 24, Y, Xh)
            _, Z, C, Y, Xh = shape
            nrhs = 1
        return nrhs * Z * C * Y * Xh

    # Shapes straddling the budget: resident fits / only the ring fits /
    # nothing fits, plus exact-boundary rows for the <= vs < distinction.
    T_at_limit = limit // (4 * 4 * 24 * 4 * 4)      # f32 (T,4,24,4,4)
    cases = [
        (4, 4, 24, 4, 2), (8, 8, 24, 8, 4),
        (T_at_limit, 4, 24, 4, 4),          # resident == limit exactly
        (T_at_limit + 1, 4, 24, 4, 4),      # one row over
        (4096, 8, 24, 8, 4),                # huge T: stream territory
        (2, 4096, 24, 64, 64),              # huge plane: unfused
        (8, 4, 4, 24, 4, 2),                # batched nrhs=8
    ]
    for shape in cases:
        for dtype in (jnp.float32, jnp.bfloat16):
            itemsize = jnp.dtype(dtype).itemsize
            resident = itemsize * math.prod(shape)
            ringsz = itemsize * window * plane_elems(shape)

            if fits(shape, dtype) != (resident <= limit):
                findings.append(_finding(
                    root, "J3",
                    f"fused_dhat_fits({shape}, {jnp.dtype(dtype).name}) "
                    f"disagrees with the independent estimate "
                    f"{resident}B vs limit {limit}B"))
            got_ring = ring(shape, dtype)
            if got_ring != ringsz:
                findings.append(_finding(
                    root, "J3",
                    f"stream_ring_bytes({shape}, "
                    f"{jnp.dtype(dtype).name}) = {got_ring}, "
                    f"independent estimate {ringsz} "
                    f"({window} rows x {plane_elems(shape)} elems)"))
            want_policy = ("resident" if resident <= limit else
                           "stream" if ringsz <= limit else "unfused")
            got_policy = policy(shape, dtype)
            if got_policy != want_policy:
                findings.append(_finding(
                    root, "J3",
                    f"fused_dhat_policy({shape}, "
                    f"{jnp.dtype(dtype).name}) = {got_policy!r}, but "
                    f"the byte math (resident {resident}B, ring "
                    f"{ringsz}B, limit {limit}B) says {want_policy!r}"))

    # The cap-lift itself: the ring must not grow with T.
    if ring((8, 8, 24, 8, 4)) != ring((4096, 8, 24, 8, 4)):
        findings.append(_finding(
            root, "J3",
            "stream_ring_bytes grew with T — the plane-window ring is "
            "supposed to be T-independent (that is the VMEM cap-lift)"))

    # Compressed-gauge headroom: storing 12/8 of 18 real link planes
    # frees VMEM in the double-buffered gauge window (12 plane-sets in
    # flight per grid step); fits/policy must extend the scratch budget
    # by exactly that headroom — and gauge_comps=18 must be a strict
    # no-op, so every boundary above stays where it was.
    headroom = headroom_fn or ws.gauge_headroom_bytes
    for gc in (18, 12, 8):
        for dtype in (jnp.float32, jnp.bfloat16):
            Y, Xh = 4, 4
            itemsize = jnp.dtype(dtype).itemsize
            want_head = (18 - gc) * 12 * 2 * Y * Xh * itemsize
            got_head = headroom(Y, Xh, itemsize, gauge_comps=gc)
            if got_head != want_head:
                findings.append(_finding(
                    root, "J3",
                    f"gauge_headroom_bytes(Y={Y}, Xh={Xh}, "
                    f"itemsize={itemsize}, gauge_comps={gc}) = "
                    f"{got_head}, independent estimate {want_head} "
                    f"((18-{gc}) planes x 12 plane-sets x 2 buffers)"))
            lim_gc = limit + want_head
            row = itemsize * 4 * 24 * Y * Xh       # one (Z=4) t-row
            T_gc = lim_gc // row
            for T in (T_gc, T_gc + 1):
                shape = (T, 4, 24, Y, Xh)
                resident = itemsize * math.prod(shape)
                got_fits = ws.fused_dhat_fits(shape, dtype,
                                              gauge_comps=gc)
                if got_fits != (resident <= lim_gc):
                    findings.append(_finding(
                        root, "J3",
                        f"fused_dhat_fits({shape}, "
                        f"{jnp.dtype(dtype).name}, gauge_comps={gc}) = "
                        f"{got_fits}, but resident {resident}B vs "
                        f"limit+headroom {lim_gc}B says "
                        f"{resident <= lim_gc}"))
                ringsz = ring(shape, dtype)
                want_policy = ("resident" if resident <= lim_gc else
                               "stream" if ringsz <= lim_gc else
                               "unfused")
                got_policy = ws.fused_dhat_policy(shape, dtype,
                                                  gauge_comps=gc)
                if got_policy != want_policy:
                    findings.append(_finding(
                        root, "J3",
                        f"fused_dhat_policy({shape}, "
                        f"{jnp.dtype(dtype).name}, gauge_comps={gc}) = "
                        f"{got_policy!r}, but the byte math (resident "
                        f"{resident}B, ring {ringsz}B, limit+headroom "
                        f"{lim_gc}B) says {want_policy!r}"))

    # The traffic model reports the same scratch numbers it budgets by.
    model = ws.dhat_stream_traffic_model(16, 8, 8, 4, nrhs=2)
    mring = ring((2, 16, 8, 24, 8, 4))
    if model["vmem_ring_bytes"] != mring:
        findings.append(_finding(
            root, "J3",
            f"dhat_stream_traffic_model reports vmem_ring_bytes="
            f"{model['vmem_ring_bytes']} but stream_ring_bytes says "
            f"{mring} for the same (T=16, Z=8, Y=8, Xh=4, nrhs=2)"))
    if model["vmem_resident_bytes"] != 4 * math.prod((2, 16, 8, 24, 8, 4)):
        findings.append(_finding(
            root, "J3",
            "dhat_stream_traffic_model's vmem_resident_bytes disagrees "
            "with itemsize * prod(shape)"))
    return findings


# --- J4: retrace detector --------------------------------------------


def check_retrace_budget(root: str, *,
                         session_factory: Optional[Callable] = None,
                         ) -> List[Finding]:
    """J4: a replayed serving scenario traces once per distinct key.

    Scenario: 3 solves on one (spec, shape) key, 2 on a second shape
    (batched nrhs=2), 1 on a second spec — 6 solves, 3 keys, so the
    declared budget is exactly 3 traces / 3 misses / 3 hits.

    ``session_factory() -> SolveSession`` lets the self-tests seed a
    cache-defeating session (e.g. one that clears its cache per solve).
    """
    import jax.numpy as jnp
    from repro import api

    Ue, Uo, e, o = _tiny_eo()
    if session_factory is None:
        def session_factory():
            D = api.WilsonMatrix.bind(Ue, Uo, _KAPPA, backend="jnp")
            return api.SolveSession(D, api.SolveSpec(
                method="cgnr", tol=1e-5, max_iters=25))

    session = session_factory()
    spec2 = api.SolveSpec(method="bicgstab", tol=1e-5, max_iters=25)
    eb = jnp.stack([e, e])
    ob = jnp.stack([o, o])

    session.solve(e, o)
    session.solve(e, o)
    session.solve(e, o)
    session.solve(eb, ob)       # new shape key (batched pipeline)
    session.solve(eb, ob)
    session.solve(e, o, spec2)  # new spec key

    stats = session.stats()
    budget = {"solves": 6, "traces": 3,
              "cache_misses": 3, "cache_hits": 3}
    findings: List[Finding] = []
    for key, want in budget.items():
        got = stats.get(key)
        if got != want:
            findings.append(_finding(
                root, "J4",
                f"SolveSession scenario: {key} = {got}, declared "
                f"budget {want} (6 solves over 3 distinct "
                "(spec, shape) keys must compile exactly once each — "
                "anything more is a retrace leak, anything less means "
                "the trace counter stopped counting)"))
    return findings


# --- J5: comms/compute overlap schedule ------------------------------

# One Dhat = two hopping blocks; each exchanges 4 spinor faces and
# (without gauge hoisting) 4 gauge faces.
_J5_MIN_PPERMUTES = 8
_J5_EXPECTED_INTERIOR_KERNELS = 2


def check_overlap_interleave(root: str, *,
                             overlap: str = "interior",
                             partition_factory: Optional[Callable] = None,
                             ) -> List[Finding]:
    """J5: ``overlap='interior'`` really decouples kernels from comms.

    Traces the distributed Dhat (pallas local backend, 1-device mesh)
    and inspects the ``shard_map`` body jaxpr: the halo ``ppermute``s
    must be present (>= 8 — four faces per hopping block), exactly 2
    ``pallas_call``s must launch (one interior pass per hopping block),
    and every ``pallas_call`` must have at least 4 *already-issued*
    ``ppermute``s it is NOT data-dependent on — the faces genuinely in
    flight while it runs (established by forward dependency propagation
    over the body's equations).  The per-kernel formulation matters:
    the second hop's interior kernel legitimately depends on the FIRST
    hop's exchange (through the hopping-block chain) — what it must not
    depend on is its own.  The fused schedule fails (each kernel
    consumes every face exchanged before it), which is the
    seeded-violation self-test.

    ``partition_factory() -> QCDPartition`` overrides the traced
    configuration.
    """
    import jax
    from jax import core as jcore
    from repro import compat
    from repro.distributed import qcd
    from repro.kernels import layout

    Ue, Uo, e, _ = _tiny_eo()
    u_e_p, u_o_p = layout.gauge_to_planar(Ue), layout.gauge_to_planar(Uo)
    src_p = layout.spinor_to_planar(e)

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    if partition_factory is None:
        def partition_factory():
            return qcd.QCDPartition.for_mesh(
                mesh, backend="pallas", overlap=overlap, interpret=True)
    part = partition_factory()
    fn = qcd.make_dhat_fn(part, _KAPPA)
    jaxpr = jax.make_jaxpr(fn)(u_e_p, u_o_p, src_p)

    body = None
    for eqn in _walk_eqns(jaxpr):
        if eqn.primitive.name == "shard_map":
            subs = [s for v in eqn.params.values() for s in _as_jaxprs(v)]
            if subs:
                body = subs[0]
                break
    if body is None:
        return [_finding(
            root, "J5",
            "no shard_map equation in the traced distributed Dhat — "
            "the operator is expected to run under shard_map")]
    if isinstance(body, jcore.ClosedJaxpr):
        body = body.jaxpr

    def _counts(eqn):
        """(ppermutes, pallas_calls) inside one equation (recursively)."""
        pp = pc = 0
        stack = [eqn]
        while stack:
            e_ = stack.pop()
            if e_.primitive.name == "ppermute":
                pp += 1
            elif e_.primitive.name == "pallas_call":
                pc += 1
            for val in e_.params.values():
                for sub in _as_jaxprs(val):
                    sj = sub.jaxpr if isinstance(sub, jcore.ClosedJaxpr) \
                        else sub
                    stack.extend(sj.eqns)
        return pp, pc

    # Forward dependency propagation: deps[var] = set of ppermute ids
    # (issue-ordered ints) the value is data-dependent on.
    deps = {}
    n_ppermute = 0
    n_kernels = 0
    serialized = []                 # (kernel index, overlapped faces)
    for eqn in body.eqns:
        in_deps = set()
        for v in eqn.invars:
            if getattr(v, "count", None) is not None:
                in_deps |= deps.get(v, frozenset())
        pp, pc = _counts(eqn)
        for _ in range(pc):
            # Faces already in flight that this kernel does NOT wait
            # on: every earlier-issued ppermute outside its dep set.
            overlapped = n_ppermute - len(in_deps)
            if overlapped < 4:
                serialized.append((n_kernels, overlapped))
            n_kernels += 1
        if pp:
            in_deps = in_deps | set(range(n_ppermute, n_ppermute + pp))
            n_ppermute += pp
        if in_deps:
            frozen = frozenset(in_deps)
            for v in eqn.outvars:
                deps[v] = frozen

    findings: List[Finding] = []
    if n_ppermute < _J5_MIN_PPERMUTES:
        findings.append(_finding(
            root, "J5",
            f"overlap={overlap!r}: only {n_ppermute} ppermute(s) in the "
            f"shard_map body, expected >= {_J5_MIN_PPERMUTES} (4 faces "
            "per hopping block, 2 hopping blocks per Dhat) — the halo "
            "exchange went missing"))
    if serialized:
        detail = ", ".join(f"kernel {i}: {n} face(s) in flight"
                           for i, n in serialized)
        findings.append(_finding(
            root, "J5",
            f"overlap={overlap!r}: {len(serialized)} pallas_call(s) "
            "have fewer than 4 already-issued ppermutes outside their "
            f"dependency set ({detail}) — the main kernel is "
            "serialized behind the halo exchange instead of "
            "overlapping with it"))
    if n_kernels != _J5_EXPECTED_INTERIOR_KERNELS:
        findings.append(_finding(
            root, "J5",
            f"overlap={overlap!r}: {n_kernels} pallas_call(s) in the "
            f"shard_map body, expected exactly "
            f"{_J5_EXPECTED_INTERIOR_KERNELS} (one interior pass per "
            "hopping block)"))
    return findings


# --- J6: divergence guard present in every Krylov while_loop ---------


def check_nonfinite_guard(root: str, *,
                          run_fn: Optional[Callable] = None,
                          methods: Optional[Sequence[str]] = None,
                          ) -> List[Finding]:
    """J6: every Krylov ``while_loop`` carries the non-finite guard.

    Traces :func:`repro.core.solver._run_krylov` for every method,
    batched and unbatched, over a dense SPD operator, and asserts each
    ``while`` equation's *cond* jaxpr contains an ``is_finite``
    primitive — the structural footprint of the divergence guard
    (``jnp.isfinite(rr)`` in the loop condition).  Without it a single
    corrupted residual runs the full ``max_iters`` of NaN arithmetic
    and exits looking merely "not converged".

    ``run_fn(method, batched) -> SolveResult`` overrides the traced
    entry so the self-tests can seed a guard-free solver
    (``guard=False``).
    """
    import jax
    import jax.numpy as jnp
    from repro.core import solver

    if methods is None:
        methods = solver.KRYLOV_METHODS

    n = 24
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (n, n), dtype=jnp.float32)
    A = G @ G.T + n * jnp.eye(n, dtype=jnp.float32)
    b1 = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                           dtype=jnp.float32)
    bb = jax.random.normal(jax.random.fold_in(key, 2), (3, n),
                           dtype=jnp.float32)

    if run_fn is None:
        def run_fn(method, batched):
            # A is symmetric, so op == op^dag; batched operands carry a
            # leading rhs axis (the solvers reduce per column).
            if batched:
                return solver._run_krylov(
                    method, lambda v: v @ A.T, lambda v: v @ A.T, bb,
                    tol=1e-6, max_iters=8, recompute_every=0,
                    batched=True)
            return solver._run_krylov(
                method, lambda v: A @ v, lambda v: A @ v, b1,
                tol=1e-6, max_iters=8, recompute_every=0,
                batched=False)

    findings: List[Finding] = []
    for method in methods:
        for batched in (False, True):
            jaxpr = jax.make_jaxpr(
                lambda m=method, b=batched: run_fn(m, b))()
            whiles = 0
            unguarded = 0
            for eqn in _walk_eqns(jaxpr):
                if eqn.primitive.name != "while":
                    continue
                whiles += 1
                cond = eqn.params.get("cond_jaxpr")
                if not any(e.primitive.name == "is_finite"
                           for e in _walk_eqns(cond)):
                    unguarded += 1
            label = f"method {method!r} ({'batched' if batched else 'single'})"
            if whiles == 0:
                findings.append(_finding(
                    root, "J6",
                    f"{label}: no while_loop in the traced Krylov solve "
                    "— the iteration is expected to lower to "
                    "lax.while_loop (did the trace entry change?)"))
            elif unguarded:
                findings.append(_finding(
                    root, "J6",
                    f"{label}: {unguarded} of {whiles} while_loop(s) "
                    "have no is_finite primitive in their cond jaxpr — "
                    "the non-finite divergence guard is structurally "
                    "absent, so a corrupted residual would run the "
                    "full iteration budget as silent NaN arithmetic"))
    return findings


# --- runner entry -----------------------------------------------------

_CHECK_FNS = {
    "J1": check_conversion_free,
    "J2": check_pallas_counts,
    "J3": check_vmem_model,
    "J4": check_retrace_budget,
    "J5": check_overlap_interleave,
    "J6": check_nonfinite_guard,
}


def run_jaxpr_checks(root: str,
                     checks: Optional[Iterable[str]] = None
                     ) -> List[Finding]:
    """Run the selected (default: all) jaxpr invariant checks."""
    selected = tuple(checks) if checks is not None else ALL_JAXPR_CHECKS
    findings: List[Finding] = []
    for name in selected:
        try:
            fn = _CHECK_FNS[name]
        except KeyError:
            raise ValueError(
                f"unknown jaxpr check {name!r}; "
                f"choose from {ALL_JAXPR_CHECKS}") from None
        findings.extend(fn(root))
    return sorted(findings)
