"""AST convention linter: engine + shared per-file context.

The engine walks every Python file under the scanned roots, parses it
once, builds a :class:`LintContext` (import-alias map, inline waivers,
repo-relative path), and hands it to each rule module under
:mod:`repro.analysis.rules`.  Rules are pure syntax — no imports of the
scanned code are executed.

Inline waivers: a line containing ``# repro-lint: allow[R2] <reason>``
waives that rule on that line and the next (so the annotation can sit
on its own line above a long statement).  Waivers are designated
exemptions with a stated reason; anything else belongs in the baseline.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

from .findings import Finding

__all__ = ["LintContext", "run_lint", "iter_source_files", "ALL_RULES",
           "SCAN_DIRS"]

# Directories scanned relative to the repo root.
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")

# Directory names never scanned (fixture files contain deliberate
# violations for the linter's own tests).
SKIP_DIR_NAMES = {"__pycache__", ".git", "lint_fixtures", ".ruff_cache"}

_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\[([A-Za-z0-9,\s]+)\]")


class LintContext:
    """Everything a rule needs about one parsed file."""

    def __init__(self, path: str, source: str,
                 tree: Optional[ast.AST] = None):
        self.path = path              # repo-relative, posix separators
        self.source = source
        self.tree = tree if tree is not None else ast.parse(source)
        self.lines = source.splitlines()
        self.aliases = _collect_aliases(self.tree)
        self.waivers = _collect_waivers(self.lines)

    # --- alias resolution ---------------------------------------------

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to a dotted path through this
        file's import aliases (``pltpu.CompilerParams`` ->
        ``jax.experimental.pallas.tpu.CompilerParams``).  Returns None
        for chains not rooted in an imported name."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id)
        if base is None:
            return None
        return ".".join([base] + list(reversed(parts)))

    # --- waivers ------------------------------------------------------

    def waived(self, rule: str, line: int) -> bool:
        return rule in self.waivers.get(line, ())

    def finding(self, rule: str, node: ast.AST, message: str
                ) -> Optional[Finding]:
        """Build a finding unless an inline waiver covers it."""
        line = getattr(node, "lineno", 0)
        if self.waived(rule, line):
            return None
        return Finding(rule=rule, path=self.path, line=line,
                       message=message)


def _collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Local name -> dotted module/object path, from top-level AND
    function-local imports (the repo uses local imports to break
    cycles; the conventions apply to those too)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{mod}.{a.name}"
    return aliases


def _collect_waivers(lines: Sequence[str]) -> Dict[int, tuple]:
    """Line number -> tuple of waived rule ids (the annotated line and
    the line below it, so the comment can precede the statement)."""
    waivers: Dict[int, tuple] = {}
    for i, text in enumerate(lines, start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        for line in (i, i + 1):
            waivers[line] = tuple(set(waivers.get(line, ()) + rules))
    return waivers


def iter_source_files(root) -> Iterable[Path]:
    root = Path(root)
    for scan in SCAN_DIRS:
        base = root / scan
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if any(part in SKIP_DIR_NAMES for part in path.parts):
                continue
            yield path


def _load_rules():
    from .rules import ALL_RULES as rules
    return rules


def run_lint(root, files: Optional[Sequence] = None,
             rules=None) -> List[Finding]:
    """Lint the repo (or an explicit file list) with every rule.

    ``files`` entries may be absolute or root-relative paths; findings
    always report root-relative posix paths.
    """
    root = Path(root)
    rules = list(rules) if rules is not None else _load_rules()
    if files is None:
        paths = list(iter_source_files(root))
    else:
        paths = [Path(f) if Path(f).is_absolute() else root / f
                 for f in files]
    findings: List[Finding] = []
    for path in paths:
        try:
            source = path.read_text()
            rel = path.relative_to(root).as_posix() \
                if path.is_relative_to(root) else path.as_posix()
            ctx = LintContext(rel, source)
        except SyntaxError as e:
            findings.append(Finding(
                rule="PARSE", path=str(path), line=e.lineno or 0,
                message=f"syntax error: {e.msg}"))
            continue
        for rule in rules:
            for f in rule.check(ctx):
                if f is not None:
                    findings.append(f)
    return sorted(findings)


# Re-exported for the runner's --list-rules output.
def ALL_RULES():
    return _load_rules()
