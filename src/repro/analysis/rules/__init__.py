"""Per-rule lint modules.

Each rule module exposes ``RULE_ID``, ``DESCRIPTION`` and
``check(ctx) -> Iterable[Finding | None]`` (``None`` entries are
waived findings and are dropped by the engine).  Register new rules by
appending the module here — the runner, the tests, and ``--list-rules``
all derive from this list.
"""
from __future__ import annotations

from . import r1_compat, r2_registry, r3_api, r4_loop_hygiene

ALL_RULES = (r1_compat, r2_registry, r3_api, r4_loop_hygiene)

__all__ = ["ALL_RULES", "r1_compat", "r2_registry", "r3_api",
           "r4_loop_hygiene"]
