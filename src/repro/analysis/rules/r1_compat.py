"""R1 — version-drifted JAX APIs go through ``repro.compat`` only.

The supported JAX range is 0.4.37 → current; ``shard_map`` (and its
``check_rep``/``check_vma`` kwarg), ``jax.make_mesh`` /
``jax.sharding.AxisType``, ``jax.lax.axis_size``, and the Pallas TPU
compiler-params class all moved between those releases.  Touching any
of them directly re-introduces the exact breakage PR 1 fixed 25 seed
tests for.  ``src/repro/compat.py`` is the one place allowed to; the
Pallas kernels under ``src/repro/kernels/`` may additionally import
``jax.experimental.pallas`` (plain ``pallas as pl`` / ``tpu as
pltpu``) — but even they must take compiler params via
``compat.tpu_compiler_params``.
"""
from __future__ import annotations

import ast
from typing import Iterable

RULE_ID = "R1"
DESCRIPTION = ("version-drifted JAX APIs (shard_map, make_mesh/AxisType, "
               "axis_size, Pallas compiler params) only via repro.compat")

# The one module allowed to touch everything below.
COMPAT_PATH = "src/repro/compat.py"
# Package additionally allowed to import jax.experimental.pallas.
KERNELS_PREFIX = "src/repro/kernels/"

# Fully-resolved dotted paths that drifted across the supported range.
DRIFTED_PATHS = frozenset({
    "jax.shard_map",
    "jax.experimental.shard_map",
    "jax.experimental.shard_map.shard_map",
    "jax.make_mesh",
    "jax.sharding.AxisType",
    "jax.lax.axis_size",
    "jax.core.axis_frame",
    "jax.experimental.pallas.tpu.TPUCompilerParams",
    "jax.experimental.pallas.tpu.CompilerParams",
})

# Module prefixes whose *import* is restricted to kernels/ (+ compat).
PALLAS_PREFIX = "jax.experimental.pallas"


def _imported_modules(node):
    if isinstance(node, ast.Import):
        for a in node.names:
            yield a.name
    elif isinstance(node, ast.ImportFrom) and node.level == 0:
        mod = node.module or ""
        yield mod
        for a in node.names:
            if a.name != "*":
                yield f"{mod}.{a.name}"


def check(ctx) -> Iterable:
    if ctx.path == COMPAT_PATH:
        return
    in_kernels = ctx.path.startswith(KERNELS_PREFIX)

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for mod in _imported_modules(node):
                if mod in DRIFTED_PATHS:
                    yield ctx.finding(
                        RULE_ID, node,
                        f"direct import of drifted API {mod!r}: use the "
                        "repro.compat wrapper instead")
                elif (mod == PALLAS_PREFIX
                      or mod.startswith(PALLAS_PREFIX + ".")):
                    if not in_kernels:
                        yield ctx.finding(
                            RULE_ID, node,
                            f"import of {mod!r} outside src/repro/kernels/"
                            ": Pallas entry points live in the kernels "
                            "package; compiler params via "
                            "repro.compat.tpu_compiler_params")
        elif isinstance(node, ast.Attribute):
            resolved = ctx.resolve(node)
            if resolved in DRIFTED_PATHS:
                yield ctx.finding(
                    RULE_ID, node,
                    f"direct use of drifted API {resolved!r}: use the "
                    "repro.compat wrapper instead")
