"""R2 — operator implementations are reached through the registry.

Inside ``src/repro``, Wilson-operator implementations (the pure-XLA
even-odd reference, the planar Pallas kernels, the shard_map'd
distributed operator) register under :func:`repro.backends.
register_backend` and are bound by name; hand-wiring their callables
across module boundaries bypasses the native-domain/bind-once contract
every solver-side optimisation since PR 2 depends on.

Scope: ``src/repro`` modules *outside* the implementation zone — the
``kernels``/``backends``/``distributed``/``core`` packages, which ARE
the implementations and may compose each other freely.  Tests and
benchmarks are out of scope: measuring or asserting against a concrete
kernel in isolation is their job.

Flagged: importing an operator entry-point module
(``repro.kernels.ops`` / ``wilson_stencil`` / ``ref``), importing an
operator function by name, or calling one through a module alias
(``evenodd.apply_dhat(...)``, ``wilson.apply_wilson(...)``).  Layout
codecs (``pack`` / ``unpack`` / ``repro.kernels.layout``) are not
operators and stay free.
"""
from __future__ import annotations

import ast
from typing import Iterable

RULE_ID = "R2"
DESCRIPTION = ("operator implementations only via the backend registry "
               "(register_backend/get_backend), no cross-boundary "
               "hand-wiring inside src/repro")

SCOPE_PREFIX = "src/repro/"
# Packages that ARE the operator implementations — plus the analysis
# layer, whose *job* is to import implementations and inspect their
# traces.
IMPL_ZONE = ("src/repro/kernels/", "src/repro/backends/",
             "src/repro/distributed/", "src/repro/core/",
             "src/repro/analysis/")

# Operator entry-point modules: importing these at all (from outside the
# implementation zone) is hand-wiring.
IMPL_MODULES = frozenset({
    "repro.kernels.ops",
    "repro.kernels.wilson_stencil",
    "repro.kernels.ref",
})

# Modules whose *operator functions* are flagged but whose codec/helper
# functions (pack, unpack, pack_gauge, random_gauge, ...) are fine.
MIXED_MODULES = frozenset({
    "repro.core.evenodd",
    "repro.core.wilson",
    "repro.distributed.qcd",
}) | IMPL_MODULES

OPERATOR_NAMES = frozenset({
    "apply_dhat", "apply_dhat_dagger", "hop_oe", "hop_eo",
    "apply_wilson", "apply_wilson_dagger",
    "hop_oe_kernel", "hop_eo_kernel", "apply_dhat_kernel",
    "apply_dhat_planar", "apply_dhat_planar_fused",
    "apply_dhat_planar_stream", "apply_dhat_planar_any",
    "dhat_planar_fused", "dhat_planar_fused_stream",
    "hop_block", "hop_block_planar", "hop_block_ext_planar_native",
    "make_hop_fn", "make_dhat_fn", "apply_dhat_planar_ref",
})


def _in_scope(path: str) -> bool:
    if not path.startswith(SCOPE_PREFIX):
        return False
    return not any(path.startswith(zone) for zone in IMPL_ZONE)


def check(ctx) -> Iterable:
    if not _in_scope(ctx.path):
        return

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in IMPL_MODULES:
                    yield ctx.finding(
                        RULE_ID, node,
                        f"import of operator module {a.name!r} outside "
                        "the implementation packages: bind operators by "
                        "name via repro.backends (or repro.api)")
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            for a in node.names:
                full = f"{mod}.{a.name}"
                if full in IMPL_MODULES or (
                        mod in MIXED_MODULES
                        and a.name in OPERATOR_NAMES):
                    yield ctx.finding(
                        RULE_ID, node,
                        f"hand-wired operator import {full!r}: bind "
                        "operators by name via repro.backends (or "
                        "repro.api)")
        elif isinstance(node, ast.Attribute):
            if node.attr not in OPERATOR_NAMES:
                continue
            base = ctx.resolve(node.value) if isinstance(
                node.value, (ast.Name, ast.Attribute)) else None
            if base in MIXED_MODULES:
                yield ctx.finding(
                    RULE_ID, node,
                    f"hand-wired operator call "
                    f"{base}.{node.attr}: operators cross module "
                    "boundaries only through the backend registry")
