"""R3 — the ``solve_wilson_eo`` shim is gone; callers use ``repro.api``.

``solve_wilson_eo`` was a deprecation shim over the bind-once public
API; PR 7 (its announced removal horizon) deleted it.  The rule now
enforces the *post-removal* invariant: the name must not exist — not as
a definition, an import, or a reference — anywhere in the repo.  A
reintroduction would resurrect the kwarg-sprawl surface (and its
rebind-the-backend-per-call cost) that ``repro.api.WilsonMatrix`` /
``SolveSession`` replaced.

Docstring mentions don't trip this rule — it is AST-based, so only
actual definitions, name loads, imports, and calls count.
"""
from __future__ import annotations

import ast
from typing import Iterable

RULE_ID = "R3"
DESCRIPTION = ("the removed solve_wilson_eo shim must not exist or be "
               "referenced anywhere; everyone goes through repro.api")

SHIM_NAME = "solve_wilson_eo"


def check(ctx) -> Iterable:
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name == SHIM_NAME:
                yield ctx.finding(
                    RULE_ID, node,
                    f"definition of removed {SHIM_NAME!r}: the shim was "
                    "deleted at its PR 7 horizon — bind once with "
                    "repro.api.WilsonMatrix and solve through "
                    "SolveSession (see README 'Public API')")
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == SHIM_NAME:
                    yield ctx.finding(
                        RULE_ID, node,
                        f"import of removed {SHIM_NAME!r}: bind once "
                        "with repro.api.WilsonMatrix and solve through "
                        "SolveSession (see README 'Public API')")
        elif isinstance(node, ast.Attribute) and node.attr == SHIM_NAME:
            yield ctx.finding(
                RULE_ID, node,
                f"call of removed {SHIM_NAME!r}: bind once with "
                "repro.api.WilsonMatrix and solve through SolveSession "
                "(see README 'Public API')")
        elif isinstance(node, ast.Name) and node.id == SHIM_NAME:
            yield ctx.finding(
                RULE_ID, node,
                f"reference to removed {SHIM_NAME!r}: use repro.api")
