"""R3 — new callers configure via ``repro.api`` specs, not the shim.

``solve_wilson_eo`` is a deprecation shim (removal horizon: PR 7); it
rebinds the backend — re-planarizing and re-placing the gauge — on
every call, which is exactly the per-call cost the bind-once API
exists to eliminate.  Any reference outside the shim's own module (and
the re-export in ``core/__init__.py``, which is itself part of the
deprecated surface) or its designated shim-parity tests means a PR 7
removal would not be a pure deletion.

Docstring mentions don't trip this rule — it is AST-based, so only
actual name loads/imports/calls count.
"""
from __future__ import annotations

import ast
from typing import Iterable

RULE_ID = "R3"
DESCRIPTION = ("the deprecated solve_wilson_eo shim is only referenced "
               "from its own module and the designated shim-parity "
               "tests; everyone else goes through repro.api")

SHIM_NAME = "solve_wilson_eo"

# The shim's home (definition + package re-export of the deprecated
# surface) and the single designated shim-parity test file — the one
# place PR 7 deletes alongside the shim itself.
ALLOWED_PATHS = frozenset({
    "src/repro/core/solver.py",
    "src/repro/core/__init__.py",
    "tests/test_api.py",
})


def check(ctx) -> Iterable:
    if ctx.path in ALLOWED_PATHS:
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == SHIM_NAME:
                    yield ctx.finding(
                        RULE_ID, node,
                        f"import of deprecated {SHIM_NAME!r}: bind once "
                        "with repro.api.WilsonMatrix and solve through "
                        "SolveSession (see README 'Public API')")
        elif isinstance(node, ast.Attribute) and node.attr == SHIM_NAME:
            yield ctx.finding(
                RULE_ID, node,
                f"call of deprecated {SHIM_NAME!r}: bind once with "
                "repro.api.WilsonMatrix and solve through SolveSession "
                "(see README 'Public API')")
        elif isinstance(node, ast.Name) and node.id == SHIM_NAME:
            yield ctx.finding(
                RULE_ID, node,
                f"reference to deprecated {SHIM_NAME!r}: use repro.api")
