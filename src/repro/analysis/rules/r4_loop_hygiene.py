"""R4 — Krylov loop bodies stay placement- and conversion-free.

The PR 2 contract: solvers encode once at solve entry, iterate entirely
in the backend's native vector domain, and decode once at exit.  A
``device_put`` or a ``to_domain``/``from_domain``/planar-codec call
*syntactically inside* a ``lax.while_loop`` body or cond in
``core/solver.py`` would reintroduce a per-iteration placement or
layout-conversion tax (60-75% per-call overhead measured in
``bench_breakdown``) that no test tolerance would notice.

Mechanically: every call to ``*.while_loop(cond, body, ...)`` in
``solver.py`` has its ``cond``/``body`` arguments resolved (local
``def`` or inline ``lambda``) and those subtrees scanned for the
forbidden call names.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

RULE_ID = "R4"
DESCRIPTION = ("no device_put / to_domain / layout-codec calls inside "
               "Krylov while_loop bodies in core/solver.py")

TARGET_PATH = "src/repro/core/solver.py"

FORBIDDEN_CALLS = frozenset({
    "device_put",
    "to_domain", "from_domain",
    "to_domain_batched", "from_domain_batched",
    "spinor_to_planar", "spinor_from_planar",
    "gauge_to_planar", "gauge_from_planar",
})


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _is_while_loop(node: ast.Call) -> bool:
    return _call_name(node) == "while_loop"


def _local_defs(scope_stack: List[ast.AST], name: str):
    """Innermost-first lookup of a ``def name`` in the enclosing
    function scopes (the ``body``/``cond`` closure pattern)."""
    for scope in reversed(scope_stack):
        for child in ast.walk(scope):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and child.name == name:
                return child
    return None


def _scan_loop_fn(ctx, fn_node, role: str):
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name in FORBIDDEN_CALLS:
                yield ctx.finding(
                    RULE_ID, node,
                    f"{name}() inside a while_loop {role} — the Krylov "
                    "iteration must stay in the native domain on "
                    "already-placed arrays (encode/decode/placement "
                    "happen once, at the solve boundary)")


def check(ctx) -> Iterable:
    if ctx.path != TARGET_PATH:
        return

    # Track enclosing function scopes so Name arguments to while_loop
    # resolve to the right local def.
    def visit(node, scope_stack):
        if isinstance(node, ast.Call) and _is_while_loop(node):
            for role, arg in zip(("cond", "body"), node.args[:2]):
                target = None
                if isinstance(arg, ast.Lambda):
                    target = arg
                elif isinstance(arg, ast.Name):
                    target = _local_defs(scope_stack, arg.id)
                if target is not None:
                    yield from _scan_loop_fn(ctx, target, role)
        new_stack = scope_stack
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            new_stack = scope_stack + [node]
        for child in ast.iter_child_nodes(node):
            yield from visit(child, new_stack)

    yield from visit(ctx.tree, [])
