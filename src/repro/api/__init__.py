"""``repro.api`` — the stable public surface of the reproduction.

The paper's A64FX lesson is that layout packing and data placement must
happen *once*, outside the hot loop; this package is that lesson as an
API.  Three pieces:

* **Specs** (:class:`LatticeSpec`, :class:`BackendSpec`,
  :class:`SolveSpec`) — frozen, validated configuration objects shared
  by Python callers and the CLI, replacing the old ~10-kwarg sprawl.
  :class:`BackendSpec` validates against the registry's per-backend
  capability metadata (:func:`repro.backends.backend_info`).
* **:class:`WilsonMatrix`** — binds ``(gauge, kappa, BackendSpec)``
  once (layout conversion, sharding placement, policy selection at
  construction), registered as a JAX pytree (gauge planes are leaves,
  specs are static aux), so ``D(psi)`` / ``D.dagger(psi)`` /
  ``D.normal(psi)`` compose under ``jit``/``vmap`` and solves close
  over it without retracing.
* **:class:`SolveSession`** — a :class:`WilsonMatrix` plus a cache of
  jitted solve executables keyed on ``(SolveSpec, rhs shape/dtype)``:
  the second and every later same-shape solve skips tracing entirely.
  ``session.stats()`` reports traces / cache hits / per-key timings.

One-shot convenience::

    from repro import api
    xe, xo, res = api.solve(U_e, U_o, eta_e, eta_o, kappa=0.13,
                            backend=api.BackendSpec("pallas_fused"),
                            spec=api.SolveSpec(method="bicgstab"))

The legacy ``solve_wilson_eo`` entry point is gone — it reached its
removal horizon (two PRs after this package's introduction) and lint
rule R3 keeps any definition or reference from coming back.
"""
from __future__ import annotations

from .matrix import WilsonMatrix
from .session import SolveSession
from .specs import BackendSpec, LatticeSpec, SolveSpec

__all__ = ["LatticeSpec", "BackendSpec", "SolveSpec", "WilsonMatrix",
           "SolveSession", "solve"]


def solve(U_e, U_o, eta_e, eta_o, kappa, *, backend="auto",
          spec: SolveSpec = None, **bind_opts):
    """One-shot convenience: bind a :class:`WilsonMatrix`, run a single
    :class:`SolveSession` solve, throw both away.  Callers solving more
    than once should keep the matrix/session to reuse the compiled
    solve (that is the point of this package)."""
    matrix = WilsonMatrix.bind(U_e, U_o, kappa, backend=backend,
                               **bind_opts)
    return SolveSession(matrix).solve(eta_e, eta_o, spec)
