"""Bind-once Wilson operator object, registered as a JAX pytree.

A :class:`WilsonMatrix` binds ``(gauge, kappa, BackendSpec)`` exactly
once: layout conversion (complex -> planar re/im planes), sharding
placement, and backend/policy selection all happen at construction, and
every subsequent application reuses the bound state.  The pytree
registration makes the *gauge arrays the leaves* and the specs/kappa
static aux data, so

* ``jax.jit(lambda D, psi: D(psi))`` compiles once per gauge
  *shape+spec*, not per gauge *value* — a second same-shape matrix hits
  the cache;
* ``jax.tree_util.tree_flatten`` / ``tree_map`` work (the operators are
  rebuilt from the mapped leaves on unflatten, via the backend's
  registered native factory — no layout conversion happens again);
* solves can close over a matrix (the :class:`~repro.api.SolveSession`
  pattern) without retracing per call.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import backends
from repro.kernels import layout

from .specs import BackendSpec, LatticeSpec

__all__ = ["WilsonMatrix"]


class _Opaque:
    """Identity-hashed wrapper for non-hashable bind kwargs (meshes,
    partitions) carried through pytree aux data.  Two matrices bound
    with separate opaque opts never share a jit cache entry — by
    design: we cannot prove their unhashable knobs equal."""

    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value


class WilsonMatrix:
    """The even-odd preconditioned Wilson operator, bound to one gauge
    configuration.

    Construct with :meth:`bind` (from complex even/odd gauge halves and
    a :class:`~repro.api.BackendSpec`) or wrap an existing
    :class:`~repro.backends.WilsonOps` with :meth:`from_ops`.  Apply it
    like a function::

        D = WilsonMatrix.bind(U_e, U_o, kappa=0.13,
                              backend=BackendSpec("pallas_fused"))
        out  = D(psi_e)            # Dhat psi      (complex interface)
        outd = D.dagger(psi_e)     # Dhat^dag psi
        outn = D.normal(psi_e)     # Dhat^dag Dhat psi

    Sources with a leading ``nrhs`` axis run the batched kernels.  The
    native-domain boundary is exposed as :meth:`encode` / :meth:`decode`
    / :meth:`apply_native` / :meth:`dagger_native` for callers that
    iterate natively (the Krylov solvers do).
    """

    def __init__(self, gauge: Tuple, kappa: float, lattice: LatticeSpec,
                 backend: BackendSpec, *, gauge_form: str = "complex",
                 rebuild: str = "native", opaque=None, ops=None):
        self._gauge = tuple(gauge)
        self.kappa = float(kappa)
        self.lattice = lattice
        self.backend = backend
        self._gauge_form = gauge_form
        self._rebuild = rebuild
        self._opaque = opaque
        self._ops = ops
        # Exact complex gauge halves as passed to bind/from_ops; NOT a
        # pytree leaf (an unflattened matrix loses it and falls back to
        # reconstructing from the — possibly dtype-rounded — leaves).
        self._exact_gauge = None
        # Resilience state (set by bind/fallback_next; defaults keep
        # from_ops/unflatten construction paths untouched).
        self.fallback_enabled = False
        self.fallback_events: Tuple[Tuple[str, str], ...] = ()
        self.requested_backend = backend.name if backend else None
        self.gauge_audit = None
        # Deflation subspaces, keyed (rank, mode) — computed once per
        # bound gauge by ensure_deflation and shared by every session /
        # spec that asks for the same knobs.
        self._deflation = {}

    # --- construction -------------------------------------------------

    @classmethod
    def bind(cls, U_e, U_o, kappa: float, backend="auto",
             validate: str = "none", fallback: bool = False,
             **bind_opts) -> "WilsonMatrix":
        """Bind the named backend to complex even/odd gauge halves.

        ``backend`` is a :class:`~repro.api.BackendSpec` or a registry
        name; it is validated against the backend's capability metadata
        here.  ``bind_opts`` are extra factory kwargs that cannot live
        in the (hashable) spec — e.g. a ``mesh``/``partition`` for the
        distributed backend.  All expensive bind-once work (layout
        conversion, device placement) happens in this call.

        ``validate`` audits the gauge for SU(3) unitarity / finiteness
        before binding: ``"none"`` skips, ``"warn"`` emits a
        ``RuntimeWarning`` on defects, ``"repair"`` projects defective
        links back onto SU(3) (identity-replacing non-finite ones) so
        that even *compressed* codecs pack the repaired links.  The
        audit report lands on ``.gauge_audit``.

        ``fallback=True`` arms graceful degradation: if binding fails,
        the declared fallback chain (see
        :func:`repro.resilience.fallback_chain`) is walked here; if a
        *solve* later fails, :class:`~repro.api.SolveSession` walks it
        via :meth:`fallback_next`.  Degradation is recorded on
        ``.fallback_events`` and the ``.degraded`` flag.
        """
        if validate not in ("none", "warn", "repair"):
            raise ValueError(
                f"validate must be 'none'|'warn'|'repair', "
                f"got {validate!r}")
        audit = None
        if validate != "none":
            from repro.resilience import validate as _rv
            if validate == "repair":
                U_e, U_o, audit = _rv.repair_gauge(U_e, U_o)
            else:
                audit = _rv.audit_gauge(U_e, U_o)
                if not audit.ok:
                    import warnings
                    warnings.warn(
                        f"gauge fails SU(3) audit: {audit}; bind with "
                        "validate='repair' to project links back onto "
                        "the group", RuntimeWarning, stacklevel=2)

        spec = BackendSpec.coerce(backend).validated()
        requested = spec.name
        if fallback:
            from repro.resilience import adapt_spec, fallback_chain
            events = []
            last_exc: Optional[BaseException] = None
            for i, name in enumerate(fallback_chain(spec.name)):
                try_spec = spec if i == 0 else adapt_spec(spec, name)
                try_opts = bind_opts if i == 0 else {}
                try:
                    m = cls._bind_one(U_e, U_o, kappa, try_spec,
                                      try_opts)
                    break
                except Exception as exc:      # noqa: BLE001 — chain
                    events.append((name, repr(exc)))
                    last_exc = exc
            else:
                raise last_exc
            m.fallback_events = tuple(events)
        else:
            m = cls._bind_one(U_e, U_o, kappa, spec, bind_opts)
        m.fallback_enabled = bool(fallback)
        m.requested_backend = requested
        m.gauge_audit = audit
        return m

    @classmethod
    def _bind_one(cls, U_e, U_o, kappa, spec, bind_opts):
        lattice = LatticeSpec.from_eo_gauge(U_e)
        opts = {**spec.factory_opts(), **bind_opts}
        gauge = backends.prepare_gauge(spec.name, U_e, U_o, **opts)
        ops = backends.bind_native(spec.name, gauge, **opts)
        caps = backends.backend_info(spec.name)
        m = cls(gauge, kappa, lattice, spec,
                gauge_form=caps.gauge_form, rebuild="native",
                opaque=_Opaque(bind_opts) if bind_opts else None,
                ops=ops)
        # Keep the exact complex gauge for refined solves: the planar
        # leaves are rounded to the compute dtype (bf16 leaves deviate
        # by ~1e-3), so reconstructing the f64 reference operator from
        # them would make the "true residual" target the wrong gauge.
        m._exact_gauge = (U_e, U_o)
        return m

    # --- graceful degradation ------------------------------------------

    @property
    def degraded(self) -> bool:
        """True when this matrix is not running on the backend it was
        asked for (a fallback fired at bind or solve time)."""
        return bool(self.fallback_events) or (
            self.requested_backend is not None
            and self.requested_backend != self.backend.name)

    def fallback_next(self, reason: str = "") -> Optional["WilsonMatrix"]:
        """Rebind this matrix onto the next backend in its declared
        fallback chain, recording ``(failed_backend, reason)``.

        Returns ``None`` when the chain is exhausted (or the matrix
        was wrapped from bare ops and cannot rebind).  Used by
        :class:`~repro.api.SolveSession` to recover from solve-time
        failures without losing the gauge or the session."""
        if self._exact_gauge is None:
            return None
        from repro.resilience import adapt_spec, fallback_chain
        chain = fallback_chain(self.backend.name)
        if len(chain) < 2:
            return None
        spec = adapt_spec(self.backend, chain[1])
        U_e, U_o = self._exact_gauge
        m = self._bind_one(U_e, U_o, self.kappa, spec, {})
        m.fallback_enabled = self.fallback_enabled
        m.requested_backend = self.requested_backend
        m.gauge_audit = self.gauge_audit
        m.fallback_events = self.fallback_events + (
            (self.backend.name, reason),)
        return m

    @classmethod
    def from_ops(cls, ops, kappa: float, gauge=None,
                 backend: Optional[BackendSpec] = None) -> "WilsonMatrix":
        """Wrap an already-bound :class:`~repro.backends.WilsonOps`.

        ``gauge`` (the complex even/odd halves) becomes the pytree
        leaves when given.  If ``ops.backend`` is a registered name the
        matrix stays tree-transformable (operators are rebuilt through
        the registry factory on unflatten); otherwise the bound ops ride
        along as aux data and the leaves must not be substituted.
        """
        leaves = tuple(gauge) if gauge is not None else ()
        lattice = (LatticeSpec.from_eo_gauge(leaves[0])
                   if leaves else None)
        spec = backend or BackendSpec(name=ops.backend)
        try:
            backends.backend_info(ops.backend)
            rebuild = "factory" if leaves else "pinned"
        except ValueError:
            rebuild = "pinned"
        m = cls(leaves, kappa, lattice, spec, gauge_form="complex",
                rebuild=rebuild,
                opaque=_Opaque(ops) if rebuild == "pinned" else None,
                ops=ops)
        if leaves:
            m._exact_gauge = leaves
        return m

    # --- bound operators ----------------------------------------------

    @property
    def ops(self):
        """The bound :class:`~repro.backends.WilsonOps` (rebuilt lazily
        from the gauge leaves after a pytree unflatten)."""
        if self._ops is None:
            if self._rebuild == "native":
                opts = {**self.backend.factory_opts(),
                        **(self._opaque.value if self._opaque else {})}
                # dtype is baked into prepared gauge leaves; rebinding
                # must not try to re-convert.
                self._ops = backends.bind_native(
                    self.backend.name, self._gauge, **opts)
            elif self._rebuild == "factory":
                self._ops = backends.make_wilson_ops(
                    self.backend.name, *self._gauge,
                    **self.backend.factory_opts())
            else:
                raise ValueError(
                    f"WilsonMatrix over unregistered backend "
                    f"{self.backend.name!r} cannot rebuild its "
                    "operators from substituted leaves")
        return self._ops

    @property
    def domain(self) -> str:
        return self.ops.domain

    def _batched(self, psi) -> bool:
        return psi.ndim == 7

    # complex-spinor interface ------------------------------------------

    def apply(self, psi):
        """``Dhat psi`` on complex even-half spinors; a leading ``nrhs``
        axis selects the batched kernels."""
        return self._complex_op(psi, self.ops.apply_dhat_native,
                                self.ops.apply_dhat_native_batched)

    __call__ = apply

    def dagger(self, psi):
        """``Dhat^dag psi`` (gamma5-hermiticity adjoint)."""
        return self._complex_op(psi, self.ops.apply_dhat_dagger_native,
                                self.ops.apply_dhat_dagger_native_batched)

    def normal(self, psi):
        """``Dhat^dag Dhat psi`` — the normal-equations operator the
        ``cg``/``cgnr`` methods iterate on."""
        return self.dagger(self.apply(psi))

    def _complex_op(self, psi, fn, fn_batched):
        ops = self.ops
        if self._batched(psi):
            out = ops.from_domain_batched(
                fn_batched(ops.to_domain_batched(psi), self.kappa))
        else:
            out = ops.from_domain(fn(ops.to_domain(psi), self.kappa))
        return out.astype(psi.dtype) if hasattr(psi, "dtype") else out

    # native-domain boundary --------------------------------------------

    def encode(self, psi):
        """Complex spinor -> native vector (batched by a leading axis)."""
        return (self.ops.to_domain_batched(psi) if self._batched(psi)
                else self.ops.to_domain(psi))

    def decode(self, v, dtype=jnp.complex64):
        """Native vector -> complex spinor."""
        batched = v.ndim == (7 if self.ops.domain == "complex" else 6)
        out = (self.ops.from_domain_batched(v) if batched
               else self.ops.from_domain(v))
        return out.astype(dtype)

    def _native_batched(self, v) -> bool:
        return v.ndim == (7 if self.ops.domain == "complex" else 6)

    def apply_native(self, v):
        fn = (self.ops.apply_dhat_native_batched
              if self._native_batched(v) else self.ops.apply_dhat_native)
        return fn(v, self.kappa)

    def dagger_native(self, v):
        fn = (self.ops.apply_dhat_dagger_native_batched
              if self._native_batched(v)
              else self.ops.apply_dhat_dagger_native)
        return fn(v, self.kappa)

    # deflation ---------------------------------------------------------

    def ensure_deflation(self, rank: int, mode: str = "lanczos", *,
                         checkpoint: Optional[str] = None,
                         lanczos_iters: Optional[int] = None):
        """The bound gauge's deflation state for ``(rank, mode)``,
        building it on first request and caching it on the matrix.

        ``mode="lanczos"`` runs the once-per-gauge Lanczos pass over the
        normal operator ``Dhat^dag Dhat`` (seeded deterministically from
        the lattice shape, so rebinding the same gauge reproduces the
        same basis); ``mode="recycle"`` starts empty and grows from
        harvested solutions (:meth:`repro.core.deflate.DeflationState.
        harvest_column`, driven by :class:`~repro.api.SolveSession`).
        ``checkpoint`` names a :class:`repro.resilience.BasisSnapshot`
        directory: a basis found there (matching shapes) is restored
        instead of rebuilt, and recycle harvests persist as they land.
        """
        rank = int(rank)
        if rank < 1:
            raise ValueError(f"deflation rank must be >= 1; got {rank}")
        key = (rank, str(mode), lanczos_iters)
        state = self._deflation.get(key)
        if state is not None:
            return state
        from repro.core import deflate as _defl
        ops = self.ops
        kappa = self.kappa

        def normal(v):
            return ops.apply_dhat_dagger_native(
                ops.apply_dhat_native(v, kappa), kappa)

        def normal_batched(v):
            return ops.apply_dhat_dagger_native_batched(
                ops.apply_dhat_native_batched(v, kappa), kappa)

        # Deterministic unit-norm start vector through the backend's
        # own encoder — native domain, fixed seed.
        psi = jax.random.normal(
            jax.random.PRNGKey(20240331),
            self.lattice.spinor_eo_shape() + (2,)).astype(jnp.float32)
        psi = jax.lax.complex(psi[..., 0], psi[..., 1])
        v0 = ops.to_domain(psi)

        snap = None
        if checkpoint is not None:
            from repro.resilience import BasisSnapshot
            snap = BasisSnapshot(checkpoint)
        template = _defl.empty_basis(rank, v0)
        restored = snap.resume(template) if snap is not None else None
        if mode == "lanczos":
            if restored is not None and _defl.DeflationBasis(
                    *restored).count() > 0:
                basis = _defl.DeflationBasis(*restored)
            else:
                basis = _defl.lanczos_basis(
                    normal, v0, rank, iters=lanczos_iters,
                    op_batched=normal_batched)
                if snap is not None:
                    snap.save(basis.count(), basis)
            state = _defl.DeflationState(basis, "lanczos", snapshot=snap)
        elif mode == "recycle":
            raw = (_defl.DeflationBasis(*restored)
                   if restored is not None else template)
            refine = _defl.make_ritz_refine(_defl.RECYCLE_QUALITY)
            basis = (_defl.DeflationBasis(*refine(raw))
                     if raw.count() > 0 else raw)
            # Top-of-spectrum estimate scales the Chebyshev harvest
            # filter (see make_recycle_update) — a dozen applies, once
            # per basis.
            lam = _defl.estimate_lambda_max(normal, v0)
            state = _defl.DeflationState(
                basis, "recycle",
                update_fn=_defl.make_recycle_update(
                    normal, lam_max=1.1 * lam),
                refine_fn=refine, snapshot=snap, raw=raw)
        else:
            raise ValueError(
                f"unknown deflation mode {mode!r}; choose 'lanczos' or "
                "'recycle'")
        self._deflation[key] = state
        return state

    # refined solves need the complex gauge back ------------------------

    def gauge_complex(self, dtype=jnp.complex128):
        """The complex even/odd gauge halves: the exact arrays the
        matrix was bound from when available, else reconstructed from
        the bound leaves.  The distinction matters for mixed-precision
        refined solves — leaves are rounded to the compute dtype (bf16
        planes deviate from the true gauge by ~1e-3), and the f64
        reference operator must target the *true* gauge, not the
        rounded one."""
        if self._exact_gauge is not None:
            U_e, U_o = self._exact_gauge
            return U_e.astype(dtype), U_o.astype(dtype)
        if not self._gauge:
            raise ValueError(
                "this WilsonMatrix was wrapped from bare ops without "
                "gauge arrays; pass gauge=(U_e, U_o) to from_ops (or "
                "use WilsonMatrix.bind) to enable refined solves")
        if self._gauge_form == "complex":
            U_e, U_o = self._gauge
            return U_e.astype(dtype), U_o.astype(dtype)
        u_e_p, u_o_p = self._gauge
        return (layout.gauge_from_planar(u_e_p, dtype),
                layout.gauge_from_planar(u_o_p, dtype))

    # --- pytree protocol ----------------------------------------------

    def tree_flatten(self):
        aux = (self.kappa, self.lattice, self.backend, self._gauge_form,
               self._rebuild, self._opaque)
        return self._gauge, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        kappa, lattice, backend, gauge_form, rebuild, opaque = aux
        ops = opaque.value if rebuild == "pinned" and opaque else None
        return cls(tuple(leaves), kappa, lattice, backend,
                   gauge_form=gauge_form, rebuild=rebuild, opaque=opaque,
                   ops=ops)

    def __repr__(self):
        lat = self.lattice.extents if self.lattice else None
        return (f"WilsonMatrix(backend={self.backend.name!r}, "
                f"kappa={self.kappa}, lattice={lat})")


jax.tree_util.register_pytree_node(
    WilsonMatrix,
    lambda m: m.tree_flatten(),
    WilsonMatrix.tree_unflatten)
