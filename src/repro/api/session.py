"""Serving-loop solve session: compiled-solve caching + observability.

A :class:`SolveSession` holds one bound :class:`~repro.api.WilsonMatrix`
plus a cache of jitted solve executables keyed on
``(SolveSpec, rhs shape, rhs dtype)``.  The first solve of a given key
traces and compiles the full native-domain pipeline (Eq. 4 RHS build,
Krylov ``while_loop``, Eq. 5 reconstruction); the second and every later
same-shape solve reuses the executable and skips tracing entirely —
the property a serving system handling heavy repeated solve traffic
needs, and the one the paper buys on A64FX by packing the gauge layout
once outside the hot loop.

``session.stats()`` is the observability hook: trace counts (compiles),
cache hits/misses, per-key first-solve vs steady-state wall times,
per-solve Krylov iteration counts in call order (the surface where a
recycle-deflated key shows its iterations *dropping* across a request
stream), and the resilience ledger — backend fallbacks taken, the
``degraded`` flag, and per-refined-key outer-iteration /
precision-escalation histories.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import backends
from repro.core import solver as _solver

from .matrix import WilsonMatrix
from .specs import SolveSpec

__all__ = ["SolveSession"]


class _CacheEntry:
    __slots__ = ("fn", "kind", "times", "outer", "escalations",
                 "iterations", "col_iterations", "deflation")

    def __init__(self, fn, kind, deflation=None):
        self.fn = fn
        self.kind = kind          # "plain" | "refined"
        self.times = []           # per-solve wall seconds, in call order
        self.outer = []           # refined: outer iterations per solve
        self.escalations = []     # refined: dtype rungs climbed per solve
        self.iterations = []      # plain: max Krylov iterations per solve
        self.col_iterations = []  # plain batched: per-column counts
        self.deflation = deflation  # DeflationState driving this key


class SolveSession:
    """Bind once, solve many: compiled solves cached per
    ``(SolveSpec, rhs shape/dtype)``.

    ::

        D = WilsonMatrix.bind(U_e, U_o, kappa, backend="pallas_fused")
        session = SolveSession(D, SolveSpec(method="bicgstab", tol=1e-6))
        xe, xo, res = session.solve(eta_e, eta_o)      # traces + compiles
        xe, xo, res = session.solve(eta2_e, eta2_o)    # cache hit: no trace
        print(session.stats())

    Plain solves are jitted whole (encode/decode stay outside the
    executable, at the native-domain boundary); the trace counter in
    :meth:`stats` increments inside the traced function, so it counts
    *actual* retraces, including any the cache failed to prevent.
    Mixed-precision refined solves (``SolveSpec.inner_dtype``) cache a
    refined runner whose f64 operator and inner-Krylov jit caches are
    built once per key; their Python-level outer loop runs per solve
    (data-dependent exit), so refined keys count one trace at build.
    """

    def __init__(self, matrix: WilsonMatrix,
                 spec: Optional[SolveSpec] = None):
        if not isinstance(matrix, WilsonMatrix):
            raise TypeError(
                f"SolveSession needs a WilsonMatrix; got "
                f"{type(matrix).__name__} (wrap bound ops with "
                "WilsonMatrix.from_ops, or build with WilsonMatrix.bind)")
        self.matrix = matrix
        self.default_spec = spec if spec is not None else SolveSpec()
        self._cache = {}
        self._counters = {"solves": 0, "traces": 0, "cache_hits": 0,
                          "cache_misses": 0, "fallbacks": 0}

    # --- solve --------------------------------------------------------

    def solve(self, eta_e, eta_o, spec: Optional[SolveSpec] = None):
        """Solve ``D_W xi = eta`` for one source pair (or a leading-axis
        RHS block); returns ``(xi_e, xi_o, result)``.

        When the bound matrix was created with ``fallback=True``, a
        solve-time failure (kernel compile error, backend fault) walks
        the declared fallback chain: the matrix is rebound onto the
        next backend, the compiled-solve cache is flushed (it belonged
        to the failed backend), and the solve retries — recorded in
        ``stats()["fallbacks"]`` and the matrix's ``fallback_events``.
        """
        spec = self.default_spec if spec is None else spec
        while True:
            try:
                return self._solve_once(eta_e, eta_o, spec)
            except Exception as exc:   # noqa: BLE001 — chain walk
                if not getattr(self.matrix, "fallback_enabled", False):
                    raise
                nxt = self.matrix.fallback_next(repr(exc))
                if nxt is None:
                    raise
                self.matrix = nxt
                self._cache.clear()
                self._counters["fallbacks"] += 1

    def _solve_once(self, eta_e, eta_o, spec: SolveSpec):
        if self.matrix.lattice is not None:
            batched = spec.validate_rhs(eta_e, eta_o, self.matrix.lattice)
        else:
            batched = eta_e.ndim == 7
        key = (spec, tuple(eta_e.shape), str(eta_e.dtype))

        t0 = time.perf_counter()
        entry = self._cache.get(key)
        hit = entry is not None
        if entry is None:
            entry = self._build(spec, batched)

        x_native = None
        if entry.kind == "refined":
            xi_e, xi_o, res = entry.fn(eta_e, eta_o)
        else:
            ops = self.matrix.ops
            if batched:
                v_e = ops.to_domain_batched(eta_e)
                v_o = ops.to_domain_batched(eta_o)
            else:
                v_e, v_o = ops.to_domain(eta_e), ops.to_domain(eta_o)
            if entry.deflation is not None:
                x, v_xi_o, res = entry.fn(v_e, v_o,
                                          entry.deflation.basis)
            else:
                x, v_xi_o, res = entry.fn(v_e, v_o)
            x_native = x
            from_dom = (ops.from_domain_batched if batched
                        else ops.from_domain)
            # Decode keeps the caller's spinor dtype (c128 under x64).
            xi_e = from_dom(x).astype(eta_e.dtype)
            xi_o = from_dom(v_xi_o).astype(eta_o.dtype)
            res = res._replace(x=xi_e)
        jax.block_until_ready((xi_e, xi_o))

        # Commit cache + counters only after the run succeeded: a build
        # or execution failure (refined spec without x64, an injected
        # kernel fault) must leave both untouched so a fallback retry —
        # or a later successful call — isn't double-counted.
        self._cache[key] = entry
        self._counters["cache_hits" if hit else "cache_misses"] += 1
        self._counters["solves"] += 1
        if entry.kind == "refined":
            entry.outer.append(int(res.outer_iterations))
            entry.escalations.append(tuple(res.escalations))
        else:
            entry.iterations.append(int(jnp.max(res.iterations)))
            if getattr(res.iterations, "ndim", 0) >= 1:
                # Per-column counts of the batched solve, for the
                # serving layer's split-back observability: each
                # coalesced request's own iteration cost is visible,
                # not just the batch maximum.
                entry.col_iterations.append(
                    [int(i) for i in res.iterations])
        entry.times.append(time.perf_counter() - t0)
        self._maybe_harvest(entry, x_native, res, batched)
        return xi_e, xi_o, res

    def solve_block(self, eta_e, eta_o, spec: Optional[SolveSpec] = None,
                    *, donate: bool = False, bounds=None):
        """Batched serving entry: solve one coalesced RHS block and
        split the result back per request.

        ``eta_e`` / ``eta_o`` is a multi-RHS block (a leading ``nrhs``
        axis; a single 6-d source pair is promoted to a block of one).
        ``bounds`` maps batch columns back to the independent requests
        that were coalesced into the block — a sequence of ``(lo, hi)``
        column ranges (default: one range per column); the returned
        ``parts`` list holds one per-request result each, produced by
        :func:`repro.core.solver.split_columns` (per-column iterations
        / residuals / convergence verdicts — meaningful independently
        because converged columns freeze bit-exactly).

        ``donate=True`` switches the cache entry to a buffer-donating
        executable (see :class:`~repro.api.SolveSpec` ``donate_rhs``):
        the sources are consumed by the solve — the contract a
        coalescing daemon wants for the batch temporaries it assembles.

        Returns ``(xi_e, xi_o, res, parts)``.
        """
        spec = self.default_spec if spec is None else spec
        if eta_e.ndim == 6:
            eta_e, eta_o = eta_e[None], eta_o[None]
        nrhs = int(eta_e.shape[0])
        if spec.nrhs is not None and spec.nrhs != nrhs:
            # A serving block's size is chosen by the batcher, not the
            # spec; a pinned nrhs would just fragment the cache.
            spec = dataclasses.replace(spec, nrhs=None)
        if donate and not spec.donate_rhs:
            spec = dataclasses.replace(spec, donate_rhs=True)
        xi_e, xi_o, res = self.solve(eta_e, eta_o, spec)
        if bounds is None:
            bounds = [(j, j + 1) for j in range(nrhs)]
        return xi_e, xi_o, res, _solver.split_columns(res, bounds)

    def _maybe_harvest(self, entry, x_native, res, batched):
        """Feed converged solutions of a recycle-deflated key back into
        the basis (x solves the normal system ``A x = Dhat^dag rhs``, so
        it is naturally rich in A's low modes); the next solve of the
        same key sees the grown basis as a changed jit *argument* — no
        retrace."""
        state = entry.deflation
        if (state is None or state.mode != "recycle"
                or x_native is None or state.count >= state.rank):
            return
        if batched:
            ok = jax.device_get(res.converged)
            for j, conv in enumerate(ok):
                if not conv:
                    continue
                col = jax.tree_util.tree_map(lambda l: l[j], x_native)
                state.harvest_column(col)
                if state.count >= state.rank:
                    break
        elif bool(res.converged):
            state.harvest_column(x_native)

    def _escalation_factory(self):
        """A ``bops_factory`` for the refined solve's precision ladder:
        rebinds the *session's* backend at the requested rung when its
        capabilities allow, else drops to the jnp reference operator at
        the matching complex dtype (always available)."""
        matrix = self.matrix

        def factory(rung: str):
            bspec = matrix.backend
            caps = backends.backend_info(bspec.name)
            U_e, U_o = matrix.gauge_complex(jnp.complex128)
            if rung in caps.dtypes:
                spec2 = dataclasses.replace(bspec, dtype=rung)
                extra = (matrix._opaque.value
                         if (matrix._rebuild == "native"
                             and matrix._opaque) else {})
                return backends.make_wilson_ops(
                    bspec.name, U_e, U_o,
                    **{**spec2.factory_opts(), **extra})
            cdt = jnp.complex128 if rung == "f64" else jnp.complex64
            return backends.make_wilson_ops(
                "jnp", U_e.astype(cdt), U_o.astype(cdt))

        return factory

    def _build(self, spec: SolveSpec, batched: bool) -> _CacheEntry:
        if spec.inner_dtype is not None:
            # Mixed-precision refinement: the bound matrix IS the inner
            # backend (bind it at the inner dtype); the f64 reference
            # operator is rebuilt from the bound gauge leaves and jitted
            # once here.
            U64_e, U64_o = self.matrix.gauge_complex()
            fn = _solver.make_refined_solve(
                self.matrix.ops, U64_e, U64_o, self.matrix.kappa,
                method=spec.method, tol=spec.tol,
                max_iters=spec.max_iters,
                recompute_every=spec.recompute_every,
                inner_tol=spec.inner_tol, max_outer=spec.max_outer,
                batched=batched, guard=spec.guard,
                stagnation_window=spec.stagnation_window,
                max_restarts=spec.max_restarts,
                inner_dtype=spec.inner_dtype,
                escalate=spec.escalate,
                bops_factory=(self._escalation_factory()
                              if spec.escalate else None))
            self._counters["traces"] += 1
            return _CacheEntry(fn, "refined")

        deflation = None
        if spec.deflate_rank > 0:
            deflation = self.matrix.ensure_deflation(
                spec.deflate_rank, spec.deflate_mode,
                checkpoint=spec.deflate_checkpoint,
                lanczos_iters=spec.deflate_iters)
        native = _solver.make_native_solve(
            self.matrix.ops, self.matrix.kappa, method=spec.method,
            tol=spec.tol, max_iters=spec.max_iters,
            recompute_every=spec.recompute_every, batched=batched,
            guard=spec.guard,
            stagnation_window=spec.stagnation_window,
            max_restarts=spec.max_restarts,
            deflated=deflation is not None)
        counters = self._counters

        if deflation is not None:
            def counted(v_e, v_o, basis):
                counters["traces"] += 1
                return native(v_e, v_o, basis)
        else:
            def counted(v_e, v_o):
                # Python side effect at trace time only: counts real
                # (re)compiles, not calls.
                counters["traces"] += 1
                return native(v_e, v_o)

        # donate_rhs: the encoded source vectors (argnums 0/1; a
        # deflation basis argument is never donated) are handed to XLA
        # for reuse — the serving hot path's batch temporaries.
        # Platforms without donation support warn and run undonated.
        jit_kw = {"donate_argnums": (0, 1)} if spec.donate_rhs else {}
        return _CacheEntry(jax.jit(counted, **jit_kw), "plain",
                           deflation)

    # --- observability ------------------------------------------------

    def stats(self) -> dict:
        """Serving-loop report: totals plus per-key timing breakdown.

        ``traces`` counts compile events (for plain keys, incremented at
        actual trace time); ``steady_state_s`` is the median wall time
        of the cached (non-first) solves of a key — the number a serving
        loop sustains once warm.
        """
        keys = {}
        for (spec, shape, dtype), entry in self._cache.items():
            times = entry.times
            steady = sorted(times[1:])
            row = {
                "kind": entry.kind,
                "solves": len(times),
                "first_solve_s": times[0] if times else None,
                "steady_state_s": (steady[len(steady) // 2]
                                   if steady else None),
            }
            if entry.kind == "refined":
                row["outer_iterations"] = list(entry.outer)
                row["escalations"] = [list(e) for e in entry.escalations]
            else:
                # Per-solve Krylov iteration counts in call order — on a
                # recycle-deflated key this is where the drop across the
                # request stream shows up.
                row["iterations"] = list(entry.iterations)
                if entry.col_iterations:
                    row["col_iterations"] = [
                        list(c) for c in entry.col_iterations]
            if entry.deflation is not None:
                row["deflation"] = {
                    "mode": entry.deflation.mode,
                    "rank": entry.deflation.rank,
                    "filled": entry.deflation.count,
                    "active": entry.deflation.active,
                    "harvested": entry.deflation.harvested,
                }
            keys["|".join([spec.cache_token(), f"shape={shape}",
                           f"dtype={dtype}"])] = row
        return {
            **self._counters,
            "backend": self.matrix.backend.name,
            "degraded": bool(getattr(self.matrix, "degraded", False)),
            "fallback_events": list(
                getattr(self.matrix, "fallback_events", ()) or ()),
            "keys": keys,
        }
