"""Validated spec dataclasses — the public configuration surface.

Three frozen (hashable) dataclasses replace the ~10-kwarg sprawl that
the CLI, examples, and benchmarks each used to hand-wire into the old
one-shot solver entry point:

* :class:`LatticeSpec`   — the lattice geometry (extents, even-odd
  half-extent) and the shapes derived from it;
* :class:`BackendSpec`   — which operator backend, at which compute
  dtype, with which knobs — validated against the registry's
  per-backend :class:`~repro.backends.BackendCapabilities`;
* :class:`SolveSpec`     — the Krylov configuration (method, tolerance,
  batching, mixed-precision refinement).

Being frozen and hashable, the specs double as cache keys: a
:class:`~repro.api.SolveSession` keys its compiled solves on
``(SolveSpec, rhs shape/dtype)``, and a :class:`~repro.api.WilsonMatrix`
carries its ``LatticeSpec``/``BackendSpec`` as static pytree aux data,
so two same-shape matrices hit the same jit cache entry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro import backends
from repro.core import solver as _solver

__all__ = ["LatticeSpec", "BackendSpec", "SolveSpec"]


@dataclasses.dataclass(frozen=True)
class LatticeSpec:
    """Lattice geometry: full extents ``(T, Z, Y, X)``.

    The even-odd layout packs x in half (``Xh = X // 2``); all public
    arrays are shaped from these extents, so the spec is the single
    source for shape validation (see :meth:`spinor_eo_shape`).
    """

    extents: Tuple[int, int, int, int]

    def __post_init__(self):
        ext = tuple(int(e) for e in self.extents)
        object.__setattr__(self, "extents", ext)
        if len(ext) != 4 or any(e <= 0 for e in ext):
            raise ValueError(
                f"LatticeSpec.extents must be 4 positive ints (T, Z, Y, "
                f"X); got {self.extents!r}")
        if ext[3] % 2:
            raise ValueError(
                f"X extent must be even for the even-odd packing; got "
                f"X={ext[3]}")

    @property
    def T(self):
        return self.extents[0]

    @property
    def Z(self):
        return self.extents[1]

    @property
    def Y(self):
        return self.extents[2]

    @property
    def X(self):
        return self.extents[3]

    @property
    def Xh(self):
        """Packed (even-odd) x half-extent."""
        return self.extents[3] // 2

    @property
    def volume(self):
        T, Z, Y, X = self.extents
        return T * Z * Y * X

    @classmethod
    def from_eo_gauge(cls, U_e) -> "LatticeSpec":
        """Infer the spec from an even-half gauge field
        ``(4, T, Z, Y, Xh, 3, 3)``."""
        if U_e.ndim != 7 or U_e.shape[0] != 4 or U_e.shape[-2:] != (3, 3):
            raise ValueError(
                f"expected even-odd gauge half (4, T, Z, Y, Xh, 3, 3); "
                f"got shape {U_e.shape}")
        T, Z, Y, Xh = U_e.shape[1:5]
        return cls((T, Z, Y, 2 * Xh))

    def spinor_eo_shape(self, nrhs: Optional[int] = None):
        """Shape of one even/odd spinor half; with ``nrhs`` a leading
        RHS batch axis is prepended."""
        base = (self.T, self.Z, self.Y, self.Xh, 4, 3)
        return base if nrhs is None else (int(nrhs),) + base


_DTYPE_ALIASES = {
    "f32": "f32", "float32": "f32",
    "bf16": "bf16", "bfloat16": "bf16",
    "f64": "f64", "float64": "f64",
}
_DTYPE_JNP = {"f32": jnp.float32, "bf16": jnp.bfloat16, "f64": jnp.float64}


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Which operator backend to bind, and how.

    ``name`` is a registry name (:func:`repro.backends.available_backends`)
    or ``"auto"`` (``pallas_fused`` on TPU, ``jnp`` elsewhere);
    ``dtype`` the planar compute dtype (``"f32"``/``"bf16"``/``"f64"``)
    for backends that take one; ``interpret`` forces/disables the Pallas
    interpreter (``None`` = auto off-TPU); ``gauge_compression`` selects
    the stored SU(3) link representation (``"none"`` | ``"two_row"`` |
    ``"minimal"`` — 18/12/8 real planes per link, reconstructed
    in-register by the kernels); ``opts`` is a tuple of extra
    ``(key, value)`` pairs forwarded verbatim to the factory (values
    must be hashable — the spec is jit-cache aux data).

    :meth:`validated` resolves ``"auto"`` and checks every knob against
    the backend's registered :class:`~repro.backends.BackendCapabilities`,
    so a bad combination fails at spec time with the capability listing
    in the error, not deep inside a bind.
    """

    name: str = "auto"
    dtype: Optional[str] = None
    interpret: Optional[bool] = None
    gauge_compression: str = "none"
    opts: Tuple[Tuple[str, object], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "opts", tuple(
            (str(k), v) for k, v in self.opts))
        gc = str(self.gauge_compression or "none")
        if gc not in ("none", "two_row", "minimal"):
            raise ValueError(
                f"unknown gauge_compression {self.gauge_compression!r}; "
                "choose from ('none', 'two_row', 'minimal')")
        object.__setattr__(self, "gauge_compression", gc)
        if self.dtype is not None:
            norm = _DTYPE_ALIASES.get(str(self.dtype).lower())
            if norm is None:
                raise ValueError(
                    f"unknown compute dtype {self.dtype!r}; choose from "
                    f"{sorted(set(_DTYPE_ALIASES.values()))}")
            object.__setattr__(self, "dtype", norm)

    @classmethod
    def coerce(cls, value) -> "BackendSpec":
        """Accept a BackendSpec, a registry name string, or None."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(name=value)
        raise TypeError(
            f"backend must be a BackendSpec or a registry name string; "
            f"got {type(value).__name__}")

    def resolve_name(self) -> str:
        if self.name != "auto":
            return self.name
        import jax
        return "pallas_fused" if jax.default_backend() == "tpu" else "jnp"

    def validated(self) -> "BackendSpec":
        """Resolve ``"auto"`` and validate against the backend's
        capability metadata; returns the concrete spec."""
        name = self.resolve_name()
        caps = backends.backend_info(name)   # raises with the listing
        if self.dtype is not None and self.dtype not in caps.dtypes:
            if not caps.dtypes:
                raise ValueError(
                    f"backend {name!r} takes no compute dtype (it "
                    f"follows the gauge dtype); drop BackendSpec.dtype "
                    f"[capabilities: {caps}]")
            raise ValueError(
                f"backend {name!r} does not support dtype "
                f"{self.dtype!r}; supported: {caps.dtypes} "
                f"[capabilities: {caps}]")
        if self.interpret is not None and not caps.supports_interpret:
            raise ValueError(
                f"backend {name!r} has no interpret mode; drop "
                f"BackendSpec.interpret [capabilities: {caps}]")
        if (self.gauge_compression != "none"
                and self.gauge_compression not in caps.gauge_compressions):
            raise ValueError(
                f"backend {name!r} does not support gauge_compression "
                f"{self.gauge_compression!r}; supported: "
                f"{caps.gauge_compressions} [capabilities: {caps}]")
        return dataclasses.replace(self, name=name)

    @property
    def capabilities(self) -> backends.BackendCapabilities:
        return backends.backend_info(self.resolve_name())

    def factory_opts(self) -> dict:
        """The kwargs this spec hands the backend factory."""
        out = dict(self.opts)
        if self.dtype is not None:
            out["dtype"] = _DTYPE_JNP[self.dtype]
        if self.interpret is not None:
            out["interpret"] = self.interpret
        if self.gauge_compression != "none":
            out["gauge_compression"] = self.gauge_compression
        return out


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """One Krylov solve configuration.

    ``method`` comes from :data:`repro.core.solver.KRYLOV_METHODS` — the
    CLI's ``--method`` choices are *derived* from that tuple through
    this class, never duplicated.  ``nrhs`` is optional: ``None`` means
    "infer from the source block" (a leading batch axis on the sources
    selects the batched pipeline); when set, the sources are validated
    against it.  ``inner_dtype`` switches to mixed-precision iterative
    refinement (inner Krylov in that dtype, outer f64 true-residual loop
    — needs jax x64).

    Resilience knobs: ``guard`` enables the in-loop divergence guards
    (non-finite freeze + stagnation restart — see
    :mod:`repro.core.solver`), tuned by ``stagnation_window`` /
    ``max_restarts``; ``escalate`` lets a stalling refined solve climb
    the inner-dtype precision ladder
    (:data:`repro.core.solver.ESCALATION_LADDER`).

    Deflation knobs (:mod:`repro.core.deflate`): ``deflate_rank > 0``
    turns on low-mode deflation of the normal operator for the
    normal-equations methods (:data:`repro.core.solver.DEFLATABLE_METHODS`)
    — the subspace is computed once per bound gauge and cached on the
    :class:`~repro.api.WilsonMatrix`.  ``deflate_mode`` picks how the
    subspace is built: ``"lanczos"`` pays an up-front eigensolve;
    ``"recycle"`` starts empty and harvests converged solutions from
    the request stream, so per-solve iteration counts drop as the
    stream proceeds (watch ``SolveSession.stats()``).
    ``deflate_iters`` caps the Lanczos step count (``None`` = auto;
    raise it when the low spectrum is degenerate — single-vector
    Lanczos resolves one copy of a degenerate cluster per ~cluster
    revisit, so finding all of them needs more steps than the
    default).
    ``deflate_checkpoint`` names a directory where the basis is
    persisted (:class:`repro.resilience.BasisSnapshot`) and restored
    from on a later bind of the same gauge.

    ``donate_rhs`` marks the solve's (encoded) source buffers as
    donated to the compiled executable — the serving hot path's knob:
    a request batch assembled by the coalescing daemon is a temporary
    the caller never reads again, so XLA may reuse its bytes for the
    solution block instead of allocating a fresh one.  The caller MUST
    NOT touch the source arrays after the solve (for backends whose
    native domain is the complex layout the encoded vector aliases the
    caller's array).  Plain (non-refined) solves only; some platforms
    (CPU) may decline donation with a warning and run correctly
    without the reuse.
    """

    METHODS = _solver.KRYLOV_METHODS
    DEFLATE_MODES = ("lanczos", "recycle")

    method: str = "cgnr"
    tol: float = 1e-6
    max_iters: int = 2000
    recompute_every: int = 0
    nrhs: Optional[int] = None
    inner_dtype: Optional[str] = None
    inner_tol: float = 1e-4
    max_outer: int = 25
    guard: bool = True
    stagnation_window: int = _solver.STAGNATION_WINDOW
    max_restarts: int = _solver.MAX_RESTARTS
    escalate: bool = True
    deflate_rank: int = 0
    deflate_mode: str = "lanczos"
    deflate_iters: Optional[int] = None
    deflate_checkpoint: Optional[str] = None
    donate_rhs: bool = False

    def __post_init__(self):
        if self.method not in self.METHODS:
            raise ValueError(
                f"unknown method {self.method!r}; choose from "
                f"{self.METHODS}")
        if not (self.tol > 0):
            raise ValueError(f"tol must be > 0; got {self.tol}")
        if self.max_iters < 1:
            raise ValueError(
                f"max_iters must be >= 1; got {self.max_iters}")
        if self.recompute_every < 0:
            raise ValueError(
                f"recompute_every must be >= 0 (0 = never); got "
                f"{self.recompute_every}")
        if self.nrhs is not None and self.nrhs < 1:
            raise ValueError(f"nrhs must be >= 1; got {self.nrhs}")
        if self.inner_dtype is not None:
            # normalizes spelling and raises on unknown dtypes
            _solver.resolve_inner_dtype(self.inner_dtype)
        if not (self.inner_tol > 0):
            raise ValueError(
                f"inner_tol must be > 0; got {self.inner_tol}")
        if self.max_outer < 1:
            raise ValueError(
                f"max_outer must be >= 1; got {self.max_outer}")
        if self.stagnation_window < 2:
            raise ValueError(
                f"stagnation_window must be >= 2; got "
                f"{self.stagnation_window}")
        if self.max_restarts < 0:
            raise ValueError(
                f"max_restarts must be >= 0; got {self.max_restarts}")
        if self.deflate_rank < 0:
            raise ValueError(
                f"deflate_rank must be >= 0 (0 = no deflation); got "
                f"{self.deflate_rank}")
        if self.deflate_mode not in self.DEFLATE_MODES:
            raise ValueError(
                f"unknown deflate_mode {self.deflate_mode!r}; choose "
                f"from {self.DEFLATE_MODES}")
        if self.deflate_iters is not None and self.deflate_iters < 1:
            raise ValueError(
                f"deflate_iters must be >= 1 (None = auto); got "
                f"{self.deflate_iters}")
        if self.deflate_rank > 0:
            if self.method not in _solver.DEFLATABLE_METHODS:
                raise ValueError(
                    f"deflation applies to the normal-equations methods "
                    f"{_solver.DEFLATABLE_METHODS}, not "
                    f"{self.method!r}")
            if self.inner_dtype is not None:
                raise ValueError(
                    "deflation and mixed-precision refinement "
                    "(inner_dtype) are not combinable yet: the deflation "
                    "basis lives on the native normal operator, which "
                    "the refined solve rebuilds per escalation rung")
        if self.donate_rhs and self.inner_dtype is not None:
            raise ValueError(
                "donate_rhs applies to plain solves only: the refined "
                "outer loop re-reads the f64 source every pass, so its "
                "buffers cannot be donated")

    def validate_rhs(self, eta_e, eta_o, lattice: LatticeSpec) -> bool:
        """Check a source pair against the lattice and ``nrhs``;
        returns whether the solve is batched."""
        if eta_e.shape != eta_o.shape:
            raise ValueError(
                f"even/odd sources disagree: {eta_e.shape} vs "
                f"{eta_o.shape}")
        batched = eta_e.ndim == 7
        want = lattice.spinor_eo_shape(eta_e.shape[0] if batched
                                       else None)
        if eta_e.shape != want:
            raise ValueError(
                f"source shape {eta_e.shape} does not match lattice "
                f"{lattice.extents} (expected {want}; a leading axis "
                "would select the batched multi-RHS pipeline)")
        got_nrhs = eta_e.shape[0] if batched else 1
        if self.nrhs is not None and self.nrhs != got_nrhs:
            raise ValueError(
                f"SolveSpec.nrhs={self.nrhs} but the source block has "
                f"nrhs={got_nrhs}")
        return batched

    def cache_token(self) -> str:
        """Compact human-readable form used in session stats keys.

        Covers every field (defaults elided where unambiguous) so two
        distinct specs can never collide onto one stats row."""
        parts = [self.method, f"tol{self.tol:g}", f"mi{self.max_iters}"]
        if self.recompute_every:
            parts.append(f"re{self.recompute_every}")
        if self.nrhs is not None:
            parts.append(f"nrhs{self.nrhs}")
        if self.inner_dtype is not None:
            parts.append(f"inner-{self.inner_dtype}"
                         f"@{self.inner_tol:g}x{self.max_outer}")
            if not self.escalate:
                parts.append("noesc")
        if not self.guard:
            parts.append("noguard")
        else:
            if self.stagnation_window != _solver.STAGNATION_WINDOW:
                parts.append(f"sw{self.stagnation_window}")
            if self.max_restarts != _solver.MAX_RESTARTS:
                parts.append(f"mr{self.max_restarts}")
        if self.deflate_rank:
            parts.append(f"defl{self.deflate_rank}-{self.deflate_mode}")
            if self.deflate_iters is not None:
                parts.append(f"li{self.deflate_iters}")
        if self.donate_rhs:
            parts.append("donate")
        return ":".join(parts)
