"""Unified operator-backend registry.

Every implementation of the even-odd Wilson hopping blocks — pure-XLA
complex arithmetic, the planar Pallas kernel, the fused single-kernel
Dhat, the shard_map'd distributed operator — registers here under a
string name and exposes the same bound-operator interface:

    bops = backends.make_wilson_ops("pallas_fused", U_e, U_o)
    psi_o = bops.hop_oe(psi_e)
    out   = bops.apply_dhat(psi_e, kappa)

so backend choice is a config/CLI string instead of hand-wired
callables.  All bound operators speak the *complex* even-odd interface
(spinors ``(T, Z, Y, Xh, 4, 3)`` complex64); layout conversion to the
kernel's planar form, gauge preprocessing, and device placement happen
once at bind time inside the factory.

Built-in entries (see :mod:`repro.backends.wilson`):

* ``"jnp"``          — reference pure-XLA path (:mod:`repro.core.evenodd`);
* ``"pallas"``       — planar Pallas stencil, one kernel per hopping block;
* ``"pallas_fused"`` — Dhat as ONE kernel, intermediate VMEM-resident
  (auto-falls back to the two-kernel path when it exceeds the scratch
  budget);
* ``"distributed"``  — shard_map over a device mesh.

Third parties extend via :func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

__all__ = ["WilsonOps", "register_backend", "get_backend",
           "available_backends", "make_wilson_ops"]


@dataclasses.dataclass(frozen=True)
class WilsonOps:
    """Hopping-block operators bound to one gauge configuration.

    ``hop_oe`` / ``hop_eo`` map a complex even/odd spinor to the opposite
    parity; ``apply_dhat(psi_e, kappa)`` is the even-odd preconditioned
    operator ``(1 - kappa^2 H_eo H_oe) psi_e``; ``apply_dhat_dagger`` its
    adjoint (gamma5-hermiticity).
    """

    backend: str
    hop_oe: Callable        # psi_e -> psi_o
    hop_eo: Callable        # psi_o -> psi_e
    apply_dhat: Callable    # (psi_e, kappa) -> psi_e
    apply_dhat_dagger: Callable


# name -> factory(U_e, U_o, **opts) -> WilsonOps
_REGISTRY: Dict[str, Callable] = {}


def register_backend(name: str, factory: Callable, *,
                     overwrite: bool = False) -> None:
    """Register ``factory(U_e, U_o, **opts) -> WilsonOps`` under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def available_backends():
    return sorted(_REGISTRY)


def get_backend(name: str) -> Callable:
    """Resolve a backend factory by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{available_backends()}") from None


def make_wilson_ops(name: str, U_e, U_o, **opts) -> WilsonOps:
    """Bind the named backend to a gauge configuration."""
    return get_backend(name)(U_e, U_o, **opts)


# Built-in backends self-register on import.
from . import wilson as _wilson  # noqa: E402,F401
