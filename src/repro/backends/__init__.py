"""Unified operator-backend registry.

Every implementation of the even-odd Wilson hopping blocks — pure-XLA
complex arithmetic, the planar Pallas kernel, the fused single-kernel
Dhat, the shard_map'd distributed operator — registers here under a
string name and exposes the same bound-operator interface:

    bops = backends.make_wilson_ops("pallas_fused", U_e, U_o)
    psi_o = bops.hop_oe(psi_e)
    out   = bops.apply_dhat(psi_e, kappa)

so backend choice is a config/CLI string instead of hand-wired
callables.

Every backend declares its **native vector domain** — the layout its
kernels actually eat — and exposes an encode/decode boundary plus
native-domain operators:

    v    = bops.to_domain(psi)           # complex spinor -> native vector
    w    = bops.apply_dhat_native(v, kappa)
    psi2 = bops.from_domain(w)           # native vector -> complex spinor

``"jnp"`` is native in the complex even-odd interface (spinors
``(T, Z, Y, Xh, 4, 3)`` complex64, encode/decode are identity); the
Pallas backends are native in the planar re/im layout
(``(T, Z, 24, Y, Xh)`` float32, :mod:`repro.kernels.layout`); the
``distributed`` backend's domain is a *sharded* planar vector, placed on
the device mesh by ``to_domain`` so it stays there across calls.  Krylov solvers
(:func:`repro.core.solver.make_native_solve`, driven by
:class:`repro.api.SolveSession`) encode once at solve entry, iterate
entirely in the native domain, and decode once at exit — no
per-iteration layout churn or re-placement.

The complex-interface methods (``hop_oe``/``hop_eo``/``apply_dhat``/
``apply_dhat_dagger``) remain as thin ``from_domain . native . to_domain``
wrappers for backward compatibility; gauge preprocessing and gauge
placement still happen once at bind time inside the factory.

Built-in entries (see :mod:`repro.backends.wilson`):

* ``"jnp"``          — reference pure-XLA path (:mod:`repro.core.evenodd`);
* ``"pallas"``       — planar Pallas stencil, one kernel per hopping block;
* ``"pallas_fused"`` — Dhat as ONE kernel, intermediate VMEM-resident
  (three-way auto policy: falls to the streaming plane-window kernel
  when the resident scratch exceeds the budget, then to the two-kernel
  path);
* ``"pallas_fused_stream"`` — the plane-window kernel, forced: the VMEM
  scratch is a 4-row ring of odd-intermediate t-planes, so the local
  volume is never capped by T;
* ``"distributed"``  — shard_map over a device mesh.

Third parties extend via :func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

__all__ = ["WilsonOps", "BackendCapabilities", "register_backend",
           "get_backend", "available_backends", "backend_info",
           "make_wilson_ops", "prepare_gauge", "bind_native"]


def _identity(v):
    return v


def _vmap1(fn):
    """Batched fallback: vmap over the leading RHS axis (lazy import)."""
    def batched(v):
        import jax
        return jax.vmap(fn)(v)
    return batched


def _vmap1_kappa(fn):
    def batched(v, kappa):
        import jax
        return jax.vmap(fn, in_axes=(0, None))(v, kappa)
    return batched


@dataclasses.dataclass(frozen=True)
class WilsonOps:
    """Hopping-block operators bound to one gauge configuration.

    ``hop_oe`` / ``hop_eo`` map a complex even/odd spinor to the opposite
    parity; ``apply_dhat(psi_e, kappa)`` is the even-odd preconditioned
    operator ``(1 - kappa^2 H_eo H_oe) psi_e``; ``apply_dhat_dagger`` its
    adjoint (gamma5-hermiticity).

    ``domain`` names the backend's native vector layout;
    ``to_domain``/``from_domain`` encode/decode between the complex
    even-odd spinor interface and that layout, and the ``*_native``
    operators work directly on native vectors.  Backends constructed the
    pre-domain way (complex ops only) get an identity domain, so existing
    third-party factories keep working unchanged.

    **Multi-RHS batching:** the ``*_batched`` fields are the batched
    counterparts — a batched vector is the native vector with a *leading*
    ``nrhs`` axis (batched complex spinor: ``(nrhs, T, Z, Y, Xh, 4, 3)``).
    Backends with genuinely batched kernels (the Pallas stencils, which
    load each gauge plane once per grid step for the whole block; the
    distributed operator, which does one batched halo exchange) provide
    them; everyone else gets a correct-but-unamortized ``jax.vmap``
    fallback automatically, so batched solves work on any backend.
    """

    backend: str
    hop_oe: Callable        # psi_e -> psi_o
    hop_eo: Callable        # psi_o -> psi_e
    apply_dhat: Callable    # (psi_e, kappa) -> psi_e
    apply_dhat_dagger: Callable
    # --- native vector domain (encode once, iterate natively) ---------
    domain: str = "complex"
    to_domain: Callable = None      # psi -> v
    from_domain: Callable = None    # v -> psi
    hop_oe_native: Callable = None
    hop_eo_native: Callable = None
    apply_dhat_native: Callable = None
    apply_dhat_dagger_native: Callable = None
    # --- batched (multi-RHS) counterparts; leading nrhs axis ----------
    to_domain_batched: Callable = None
    from_domain_batched: Callable = None
    hop_oe_native_batched: Callable = None
    hop_eo_native_batched: Callable = None
    apply_dhat_native_batched: Callable = None
    apply_dhat_dagger_native_batched: Callable = None

    def __post_init__(self):
        # Legacy construction: complex interface IS the native domain.
        defaults = {"to_domain": _identity, "from_domain": _identity,
                    "hop_oe_native": self.hop_oe,
                    "hop_eo_native": self.hop_eo,
                    "apply_dhat_native": self.apply_dhat,
                    "apply_dhat_dagger_native": self.apply_dhat_dagger}
        given = [f for f in defaults if getattr(self, f) is not None]
        if given and len(given) < len(defaults):
            # A half-native construction would silently route complex
            # ops into the native iteration path; fail loudly instead.
            missing = sorted(set(defaults) - set(given))
            raise ValueError(
                f"backend {self.backend!r}: partial native-domain "
                f"construction — also provide {missing} (or none of "
                "the domain fields, for an identity domain); "
                "WilsonOps.from_native builds a consistent set")
        for field, default in defaults.items():
            if getattr(self, field) is None:
                object.__setattr__(self, field, default)
        # Batched fallbacks: identity encodes stay identity (they are
        # already shape-polymorphic); everything else vmaps the
        # unbatched native op over the leading RHS axis.  Individually
        # overridable — a backend with a truly batched kernel supplies
        # its own (see WilsonOps.from_native / repro.backends.wilson).
        batched_defaults = {
            "to_domain_batched": (
                self.to_domain if self.to_domain is _identity
                else _vmap1(self.to_domain)),
            "from_domain_batched": (
                self.from_domain if self.from_domain is _identity
                else _vmap1(self.from_domain)),
            "hop_oe_native_batched": _vmap1(self.hop_oe_native),
            "hop_eo_native_batched": _vmap1(self.hop_eo_native),
            "apply_dhat_native_batched": _vmap1_kappa(self.apply_dhat_native),
            "apply_dhat_dagger_native_batched":
                _vmap1_kappa(self.apply_dhat_dagger_native),
        }
        for field, default in batched_defaults.items():
            if getattr(self, field) is None:
                object.__setattr__(self, field, default)

    @classmethod
    def from_native(cls, backend: str, *, domain: str,
                    to_domain: Callable, from_domain: Callable,
                    hop_oe: Callable, hop_eo: Callable,
                    apply_dhat: Callable,
                    apply_dhat_dagger: Callable,
                    to_domain_batched: Callable = None,
                    from_domain_batched: Callable = None,
                    hop_oe_batched: Callable = None,
                    hop_eo_batched: Callable = None,
                    apply_dhat_batched: Callable = None,
                    apply_dhat_dagger_batched: Callable = None
                    ) -> "WilsonOps":
        """Build from native-domain operators; the complex-interface
        methods become thin encode/op/decode wrappers.

        The optional ``*_batched`` operators take/return native vectors
        with a leading ``nrhs`` axis; omitted ones fall back to a
        ``jax.vmap`` of the unbatched op (correct, but without the
        gauge-traffic amortization a truly batched kernel gives).
        """

        def wrap_hop(fn):
            def wrapped(psi):
                out = from_domain(fn(to_domain(psi)))
                # preserve the caller's complex dtype (e.g. complex128
                # under x64): the planar decode defaults to complex64
                return out.astype(psi.dtype) if hasattr(psi, "dtype") else out
            return wrapped

        def wrap_dhat(fn):
            def wrapped(psi, kappa):
                out = from_domain(fn(to_domain(psi), kappa))
                return out.astype(psi.dtype) if hasattr(psi, "dtype") else out
            return wrapped

        return cls(
            backend=backend,
            hop_oe=wrap_hop(hop_oe), hop_eo=wrap_hop(hop_eo),
            apply_dhat=wrap_dhat(apply_dhat),
            apply_dhat_dagger=wrap_dhat(apply_dhat_dagger),
            domain=domain, to_domain=to_domain, from_domain=from_domain,
            hop_oe_native=hop_oe, hop_eo_native=hop_eo,
            apply_dhat_native=apply_dhat,
            apply_dhat_dagger_native=apply_dhat_dagger,
            to_domain_batched=to_domain_batched,
            from_domain_batched=from_domain_batched,
            hop_oe_native_batched=hop_oe_batched,
            hop_eo_native_batched=hop_eo_batched,
            apply_dhat_native_batched=apply_dhat_batched,
            apply_dhat_dagger_native_batched=apply_dhat_dagger_batched)


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """Introspectable per-backend metadata on the registry.

    Consumed by :class:`repro.api.BackendSpec` validation, the CLI's
    ``--backend help`` listing, and :class:`repro.api.WilsonMatrix`
    (which uses ``gauge_form`` to decide what the pytree gauge leaves
    look like and how to rebuild operators from them).

    * ``domain`` — the native vector domain (``"complex"`` / ``"planar"``
      / ``"planar_sharded"``).
    * ``gauge_form`` — layout of the bound gauge arrays the backend's
      kernels actually read (``"complex"`` even/odd halves, or
      ``"planar"`` re/im component planes, possibly mesh-placed).
    * ``batched_kernels`` — True when the ``*_batched`` ops are genuinely
      batched kernels (gauge loaded once per grid step / one halo
      exchange per block) rather than the automatic ``jax.vmap``
      fallback.
    * ``dtypes`` — planar compute dtypes the factory's ``dtype=`` knob
      accepts; empty means the backend has no dtype knob (it follows the
      gauge dtype, like ``"jnp"``).
    * ``supports_interpret`` — whether the factory takes ``interpret=``
      (Pallas interpreter off-TPU).
    * ``policies`` — the fused-Dhat execution paths the backend can take
      per application (policy introspection; ``"auto"`` means it picks
      among the others by VMEM footprint).
    * ``gauge_compressions`` — SU(3) link storage representations the
      factory's ``gauge_compression=`` knob accepts (``"none"`` full
      18-real links; ``"two_row"`` 12-real; ``"minimal"`` 8-real —
      compressed planes are expanded in-register by the kernels).
    * ``fallback`` — name of the next-best backend to rebind onto when
      this one fails to bind or compile (``None`` ends the chain).
      Declared here so the degradation order is registry data;
      :func:`repro.resilience.fallback_chain` walks the links and
      ``WilsonMatrix.bind(fallback=True)`` / ``SolveSession`` take
      them.
    """

    name: str
    domain: str = "complex"
    gauge_form: str = "complex"
    batched_kernels: bool = False
    dtypes: tuple = ()
    supports_interpret: bool = False
    policies: tuple = ()
    gauge_compressions: tuple = ("none",)
    fallback: "str | None" = None
    description: str = ""


@dataclasses.dataclass(frozen=True)
class _BackendEntry:
    factory: Callable                    # (U_e, U_o, **opts) -> WilsonOps
    capabilities: BackendCapabilities
    # (gauge_leaves_tuple, **opts) -> WilsonOps, where the leaves are the
    # backend's *bound* gauge arrays (``capabilities.gauge_form``) — the
    # rebind path repro.api.WilsonMatrix uses so pytree-unflattened
    # matrices (jit arguments, tree_map results) reconstruct their
    # operators from leaves without re-doing layout conversion.
    native_factory: Callable = None
    # (U_e, U_o, **opts) -> gauge_leaves_tuple: the bind-once conversion
    # (layout packing, sharding placement) split out of ``factory``.
    prepare_gauge: Callable = None


# name -> _BackendEntry
_REGISTRY: Dict[str, _BackendEntry] = {}


def _default_prepare(U_e, U_o, **_opts):
    return (U_e, U_o)


def register_backend(name: str, factory: Callable, *,
                     capabilities: BackendCapabilities = None,
                     native_factory: Callable = None,
                     prepare_gauge: Callable = None,
                     overwrite: bool = False) -> None:
    """Register ``factory(U_e, U_o, **opts) -> WilsonOps`` under ``name``.

    ``capabilities`` (a :class:`BackendCapabilities`) is optional but
    recommended; without it the backend is assumed legacy-style (complex
    identity domain, no dtype/interpret knobs, vmap-batched).  The
    optional ``prepare_gauge`` / ``native_factory`` pair splits the
    factory into its bind-once gauge conversion and an operator build
    from already-converted gauge; backends that omit them default to
    complex gauge leaves rebuilt through ``factory`` itself, which keeps
    plain third-party factories fully usable from :mod:`repro.api`.
    """
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    caps = capabilities or BackendCapabilities(name=name)
    _REGISTRY[name] = _BackendEntry(
        factory=factory, capabilities=caps,
        native_factory=native_factory or (
            lambda gauge, **opts: factory(*gauge, **opts)),
        prepare_gauge=prepare_gauge or _default_prepare)


def available_backends():
    """Registered backend names, **sorted** (stable across registration
    order, so CLI choices / docs / cache keys don't depend on import
    order)."""
    return sorted(_REGISTRY)


def _entry(name: str) -> _BackendEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{available_backends()} (see backend_info(name) for "
            "per-backend capabilities)") from None


def get_backend(name: str) -> Callable:
    """Resolve a backend factory by name."""
    return _entry(name).factory


def backend_info(name: str) -> BackendCapabilities:
    """Capability metadata for a registered backend."""
    return _entry(name).capabilities


def make_wilson_ops(name: str, U_e, U_o, **opts) -> WilsonOps:
    """Bind the named backend to a gauge configuration."""
    return get_backend(name)(U_e, U_o, **opts)


def prepare_gauge(name: str, U_e, U_o, **opts):
    """Run the named backend's bind-once gauge conversion (layout
    packing, sharding placement), returning the tuple of bound gauge
    arrays — the pytree leaves of a :class:`repro.api.WilsonMatrix`."""
    return tuple(_entry(name).prepare_gauge(U_e, U_o, **opts))


def bind_native(name: str, gauge, **opts) -> WilsonOps:
    """Build the named backend's operators from already-prepared gauge
    arrays (the output of :func:`prepare_gauge`); no layout conversion
    or placement happens here, so this is safe to call with tracers."""
    return _entry(name).native_factory(tuple(gauge), **opts)


# Built-in backends self-register on import.
from . import wilson as _wilson  # noqa: E402,F401
