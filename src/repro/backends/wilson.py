"""Built-in Wilson operator backends: jnp / pallas / pallas_fused /
distributed, all bound through :func:`repro.backends.register_backend`.

Factories take the complex even/odd gauge halves ``(4, T, Z, Y, Xh, 3, 3)``
and do their layout conversion / sharding once; the returned
:class:`~repro.backends.WilsonOps` then works purely on complex even/odd
spinors, so a solver written against one backend runs on any of them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import evenodd, gamma
from repro.kernels import layout, ops

from . import WilsonOps, register_backend


def _dagger_via_gamma5(apply_dhat):
    """``Dhat^dag = g5 Dhat g5`` on the complex spinor interface."""
    g5 = jnp.asarray(gamma.GAMMA5)

    def fn(psi_e, kappa):
        gp = jnp.einsum("ij,...jc->...ic", g5, psi_e)
        return jnp.einsum("ij,...jc->...ic", g5, apply_dhat(gp, kappa))

    return fn


def make_jnp_backend(U_e, U_o, **_unused) -> WilsonOps:
    """Pure-XLA reference path (complex arithmetic end to end)."""
    def apply_dhat(psi_e, kappa):
        return evenodd.apply_dhat(U_e, U_o, psi_e, kappa)

    return WilsonOps(
        backend="jnp",
        hop_oe=lambda psi_e: evenodd.hop_oe(U_e, U_o, psi_e),
        hop_eo=lambda psi_o: evenodd.hop_eo(U_e, U_o, psi_o),
        apply_dhat=apply_dhat,
        apply_dhat_dagger=_dagger_via_gamma5(apply_dhat))


def _make_pallas(U_e, U_o, *, fused: Optional[bool],
                 interpret: Optional[bool] = None,
                 name: str) -> WilsonOps:
    u_e_p, u_o_p = ops.make_planar_fields(U_e, U_o)

    def apply_dhat(psi_e, kappa):
        return ops.apply_dhat_kernel(u_e_p, u_o_p, psi_e, kappa,
                                     fused=fused, interpret=interpret)

    return WilsonOps(
        backend=name,
        hop_oe=lambda psi_e: ops.hop_oe_kernel(u_e_p, u_o_p, psi_e,
                                               interpret=interpret),
        hop_eo=lambda psi_o: ops.hop_eo_kernel(u_e_p, u_o_p, psi_o,
                                               interpret=interpret),
        apply_dhat=apply_dhat,
        apply_dhat_dagger=_dagger_via_gamma5(apply_dhat))


def make_pallas_backend(U_e, U_o, *, interpret=None, **_unused) -> WilsonOps:
    """Planar Pallas stencil, one ``pallas_call`` per hopping block."""
    return _make_pallas(U_e, U_o, fused=False, interpret=interpret,
                        name="pallas")


def make_pallas_fused_backend(U_e, U_o, *, interpret=None,
                              **_unused) -> WilsonOps:
    """Dhat as a single fused kernel; intermediate never touches HBM.

    Falls back to the two-kernel path automatically when the lattice's
    VMEM-resident intermediate exceeds the scratch budget
    (``fused=None`` auto-select in :func:`repro.kernels.ops.apply_dhat_kernel`).
    """
    return _make_pallas(U_e, U_o, fused=None, interpret=interpret,
                        name="pallas_fused")


def make_distributed_backend(U_e, U_o, *, partition=None, mesh=None,
                             local_backend: str = "jnp",
                             overlap: str = "fused",
                             interpret: Optional[bool] = None,
                             **_unused) -> WilsonOps:
    """shard_map'd operator over a device mesh.

    Accepts an explicit :class:`repro.distributed.qcd.QCDPartition` (or a
    mesh to derive one from); defaults to all local devices on a
    ``(data, model)`` mesh.  The gauge field is planarized and placed with
    the partition's sharding once, here; spinors are converted and placed
    per call (convenience path — performance-critical callers should use
    :mod:`repro.distributed.qcd` directly on planar sharded arrays).
    """
    from repro.distributed import qcd  # local import: shard_map machinery

    if partition is None:
        if mesh is None:
            mesh = compat.make_mesh((jax.device_count(), 1),
                                    ("data", "model"))
        partition = qcd.QCDPartition.for_mesh(
            mesh, backend=local_backend, overlap=overlap,
            interpret=interpret)

    u_e_p, u_o_p = ops.make_planar_fields(U_e, U_o)
    u_e_p = jax.device_put(u_e_p, partition.gauge_sharding())
    u_o_p = jax.device_put(u_o_p, partition.gauge_sharding())
    sp_shard = partition.spinor_sharding()

    hop_fns = {p: jax.jit(qcd.make_hop_fn(partition, p))
               for p in (evenodd.EVEN, evenodd.ODD)}
    dhat_cache = {}

    def _hop(out_parity, u_out_first):
        def fn(psi):
            p = jax.device_put(layout.spinor_to_planar(psi), sp_shard)
            out = hop_fns[out_parity](*u_out_first, p)
            return layout.spinor_from_planar(out, dtype=psi.dtype)
        return fn

    def apply_dhat(psi_e, kappa):
        k = float(kappa)
        if k not in dhat_cache:
            dhat_cache[k] = jax.jit(qcd.make_dhat_fn(partition, k))
        p = jax.device_put(layout.spinor_to_planar(psi_e), sp_shard)
        out = dhat_cache[k](u_e_p, u_o_p, p)
        return layout.spinor_from_planar(out, dtype=psi_e.dtype)

    return WilsonOps(
        backend="distributed",
        # H_oe reads even-parity gauge links as u_in, writes odd sites.
        hop_oe=_hop(evenodd.ODD, (u_o_p, u_e_p)),
        hop_eo=_hop(evenodd.EVEN, (u_e_p, u_o_p)),
        apply_dhat=apply_dhat,
        apply_dhat_dagger=_dagger_via_gamma5(apply_dhat))


register_backend("jnp", make_jnp_backend)
register_backend("pallas", make_pallas_backend)
register_backend("pallas_fused", make_pallas_fused_backend)
register_backend("distributed", make_distributed_backend)
