"""Built-in Wilson operator backends: jnp / pallas / pallas_fused /
pallas_fused_stream / distributed, all bound through
:func:`repro.backends.register_backend`.

Factories take the complex even/odd gauge halves ``(4, T, Z, Y, Xh, 3, 3)``
and do their layout conversion / sharding once at bind time.  Each backend
declares its native vector domain (:class:`~repro.backends.WilsonOps`):

* ``"jnp"``          — native domain ``"complex"``; encode/decode are
  identity.
* ``"pallas"`` / ``"pallas_fused"`` / ``"pallas_fused_stream"`` — native
  domain ``"planar"``: the
  re/im-separated ``(T, Z, 24, Y, Xh)`` float layout the kernel eats
  (:mod:`repro.kernels.layout`).  The dagger acts on the planar
  spin-component planes directly (gamma5 = sign flip of planes 12..23),
  so native callers never touch complex arithmetic at all.
* ``"distributed"``  — native domain ``"planar_sharded"``: a planar
  vector placed on the device mesh by ``to_domain``; the operators run
  on already-placed arrays, so a solver iterating natively pays zero
  per-call ``device_put``/layout conversion.

The complex-interface methods remain as encode/op/decode wrappers, so a
solver written against one backend still runs on any of them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import evenodd, gamma
from repro.kernels import layout, ops

from . import BackendCapabilities, WilsonOps, register_backend


def _dagger_via_gamma5(apply_dhat):
    """``Dhat^dag = g5 Dhat g5`` on the complex spinor interface."""
    g5 = jnp.asarray(gamma.GAMMA5)

    def fn(psi_e, kappa):
        gp = jnp.einsum("ij,...jc->...ic", g5, psi_e)
        return jnp.einsum("ij,...jc->...ic", g5, apply_dhat(gp, kappa))

    return fn


def _dagger_via_gamma5_planar(apply_dhat_native):
    """``Dhat^dag = g5 Dhat g5`` natively on planar component planes."""
    def fn(v, kappa):
        return layout.gamma5_planar(
            apply_dhat_native(layout.gamma5_planar(v), kappa))

    return fn


def make_jnp_backend(U_e, U_o, **_unused) -> WilsonOps:
    """Pure-XLA reference path (complex arithmetic end to end)."""
    def apply_dhat(psi_e, kappa):
        return evenodd.apply_dhat(U_e, U_o, psi_e, kappa)

    return WilsonOps(
        backend="jnp",
        hop_oe=lambda psi_e: evenodd.hop_oe(U_e, U_o, psi_e),
        hop_eo=lambda psi_o: evenodd.hop_eo(U_e, U_o, psi_o),
        apply_dhat=apply_dhat,
        apply_dhat_dagger=_dagger_via_gamma5(apply_dhat),
        domain="complex")


def _pallas_prepare_gauge(U_e, U_o, *, dtype=jnp.float32,
                          gauge_compression: str = "none", **_unused):
    """Bind-once layout conversion of the pallas-family backends.

    ``gauge_compression`` selects the stored link representation ("none"
    | "two_row" | "minimal"); the compressed planes are what lives in
    the ``WilsonMatrix`` pytree leaves (~33%/55% fewer gauge bytes) and
    the kernels expand them in-register.
    """
    return ops.make_planar_fields(U_e, U_o, dtype=dtype,
                                  compression=gauge_compression)


def _make_pallas_from_planar(u_e_p, u_o_p, *, fused,
                             interpret: Optional[bool] = None,
                             name: str) -> WilsonOps:
    # ``fused``: None (three-way auto policy), True/"resident",
    # "stream", or False/"unfused" — forwarded per call to
    # ops.apply_dhat_planar_any so the policy sees the actual
    # (possibly batched) vector shape.
    def to_domain(psi):
        return layout.spinor_to_planar(psi, dtype=u_e_p.dtype)

    def from_domain(v):
        return layout.spinor_from_planar(v)

    def hop_oe(v):
        return ops.hop_block(u_o_p, u_e_p, v, out_parity=evenodd.ODD,
                             interpret=interpret)

    def hop_eo(v):
        return ops.hop_block(u_e_p, u_o_p, v, out_parity=evenodd.EVEN,
                             interpret=interpret)

    def apply_dhat(v, kappa):
        return ops.apply_dhat_planar_any(u_e_p, u_o_p, v, kappa,
                                         fused=fused, interpret=interpret)

    dagger = _dagger_via_gamma5_planar(apply_dhat)
    # The planar kernels (and the layout codecs) are batch-polymorphic:
    # a leading nrhs axis runs ONE kernel with each gauge plane loaded
    # once per grid step, so the batched ops ARE the unbatched ops.
    return WilsonOps.from_native(
        name, domain="planar",
        to_domain=to_domain, from_domain=from_domain,
        hop_oe=hop_oe, hop_eo=hop_eo, apply_dhat=apply_dhat,
        apply_dhat_dagger=dagger,
        to_domain_batched=to_domain, from_domain_batched=from_domain,
        hop_oe_batched=hop_oe, hop_eo_batched=hop_eo,
        apply_dhat_batched=apply_dhat, apply_dhat_dagger_batched=dagger)


def _make_pallas(U_e, U_o, *, fused, interpret: Optional[bool] = None,
                 name: str, dtype=jnp.float32,
                 gauge_compression: str = "none") -> WilsonOps:
    u_e_p, u_o_p = _pallas_prepare_gauge(
        U_e, U_o, dtype=dtype, gauge_compression=gauge_compression)
    return _make_pallas_from_planar(u_e_p, u_o_p, fused=fused,
                                    interpret=interpret, name=name)


def _pallas_native_factory(fused, name):
    """Rebind a pallas-family backend from already-planar gauge leaves
    (``dtype`` is baked into the leaves, so it is accepted and ignored)."""
    def native(gauge, *, interpret=None, dtype=None, **_unused):
        del dtype
        return _make_pallas_from_planar(*gauge, fused=fused,
                                        interpret=interpret, name=name)
    return native


def make_pallas_backend(U_e, U_o, *, interpret=None, dtype=jnp.float32,
                        gauge_compression="none", **_unused) -> WilsonOps:
    """Planar Pallas stencil, one ``pallas_call`` per hopping block.

    ``dtype`` sets the planar compute dtype (f32 default; bf16 for the
    mixed-precision inner solve).  ``gauge_compression`` stores 12-real
    (two_row) or 8-real (minimal) links, expanded in-register.
    """
    return _make_pallas(U_e, U_o, fused=False, interpret=interpret,
                        name="pallas", dtype=dtype,
                        gauge_compression=gauge_compression)


def make_pallas_fused_backend(U_e, U_o, *, interpret=None,
                              dtype=jnp.float32, gauge_compression="none",
                              **_unused) -> WilsonOps:
    """Dhat as a single fused kernel; intermediate never touches HBM.

    Auto-selects the three-way fused policy (``fused=None`` in
    :func:`repro.kernels.ops.apply_dhat_planar_any`): the VMEM-resident
    single kernel when the whole (batched) intermediate fits the scratch
    budget — sized by the actual compute ``dtype`` and the RHS batch —
    the streaming plane-window kernel when only its t-plane ring does,
    and the two-kernel path as the last silent-correct fallback.
    """
    return _make_pallas(U_e, U_o, fused=None, interpret=interpret,
                        name="pallas_fused", dtype=dtype,
                        gauge_compression=gauge_compression)


def make_pallas_fused_stream_backend(U_e, U_o, *, interpret=None,
                                     dtype=jnp.float32,
                                     gauge_compression="none",
                                     **_unused) -> WilsonOps:
    """Streaming plane-window fused Dhat, forced (no auto-policy).

    One kernel per application whose VMEM scratch is a
    :data:`~repro.kernels.wilson_stencil.STREAM_WINDOW_ROWS`-row ring of
    odd-intermediate t-planes — the working set is independent of T, so
    there is no resident-scratch local-volume cap.  Selecting this
    backend by name pins the streaming kernel even for lattices the
    resident scratch could hold (useful for benchmarking the window
    overhead); the ``pallas_fused`` backend auto-picks between the two.
    """
    return _make_pallas(U_e, U_o, fused="stream", interpret=interpret,
                        name="pallas_fused_stream", dtype=dtype,
                        gauge_compression=gauge_compression)


def _normalize_overlap(overlap):
    """Accept the boolean comms/compute-overlap knob.

    ``True`` means "overlap the halo exchange with interior compute" —
    the ``"interior"`` mode of :mod:`repro.distributed.qcd`; ``False``
    means the serialized batched exchange (``"fused"``).  String modes
    pass through.
    """
    if overlap is True:
        return "interior"
    if overlap is False:
        return "fused"
    return overlap


def make_distributed_backend(U_e, U_o, *, partition=None, mesh=None,
                             local_backend: str = "jnp_planar",
                             overlap: str = "fused",
                             interpret: Optional[bool] = None,
                             dtype=jnp.float32,
                             gauge_compression: str = "none",
                             **_unused) -> WilsonOps:
    """shard_map'd operator over a device mesh.

    Accepts an explicit :class:`repro.distributed.qcd.QCDPartition` (or a
    mesh to derive one from); defaults to all local devices on a
    ``(data, model)`` mesh.  The gauge field is planarized and placed with
    the partition's sharding once, here.  The native domain is a *sharded
    planar* spinor: ``to_domain`` planarizes and places onto the mesh,
    after which the native operators run with no per-call conversion or
    ``device_put`` — a natively-iterating solver keeps the field resident
    on the mesh for the whole solve.  (The complex-interface methods
    re-encode per call, as before.)

    ``local_backend`` defaults to ``"jnp_planar"`` — the planar-native
    pure-XLA stencil — so the per-rank compute is conversion-free too;
    ``"jnp"`` (complex round-trip inside the shard, the old default) and
    ``"pallas"`` remain selectable.

    ``overlap`` picks the halo/stencil schedule: ``"fused"`` (default,
    one batched exchange serialized against the full stencil),
    ``"interior"`` (issue the exchange first and run the interior
    stencil while it is in flight — the comms/compute-overlap mode; also
    selectable as ``overlap=True``), or ``"split"`` (legacy recompute
    split).  ``gauge_compression`` stores AND ships compressed links:
    the halo exchange moves the compressed planes, so gauge halo traffic
    shrinks with the storage (~33% two_row / ~55% minimal).
    """
    overlap = _normalize_overlap(overlap)
    u_e_p, u_o_p = _distributed_prepare_gauge(
        U_e, U_o, partition=partition, mesh=mesh,
        local_backend=local_backend, overlap=overlap,
        interpret=interpret, dtype=dtype,
        gauge_compression=gauge_compression)
    return _make_distributed_from_planar(
        u_e_p, u_o_p, partition=partition, mesh=mesh,
        local_backend=local_backend, overlap=overlap, interpret=interpret)


# A bind resolves its partition twice (prepare_gauge places the gauge,
# the native factory builds the shard_map'd operators); memoize so both
# get the SAME partition object and the mesh/sharding setup runs once.
_PARTITION_MEMO = {}


def _resolve_partition(partition, mesh, local_backend, overlap, interpret):
    from repro.distributed import qcd  # local import: shard_map machinery

    if partition is not None:
        return partition
    overlap = _normalize_overlap(overlap)
    key = (mesh if mesh is not None else ("default", jax.device_count()),
           local_backend, overlap, interpret)
    if key not in _PARTITION_MEMO:
        m = mesh
        if m is None:
            m = compat.make_mesh((jax.device_count(), 1),
                                 ("data", "model"))
        _PARTITION_MEMO[key] = qcd.QCDPartition.for_mesh(
            m, backend=local_backend, overlap=overlap,
            interpret=interpret)
    return _PARTITION_MEMO[key]


def _distributed_prepare_gauge(U_e, U_o, *, partition=None, mesh=None,
                               local_backend: str = "jnp_planar",
                               overlap: str = "fused", interpret=None,
                               dtype=jnp.float32,
                               gauge_compression: str = "none",
                               **_unused):
    """Bind-once gauge work of the distributed backend: planarize,
    optionally compress, AND place on the device mesh.

    Compression happens *before* placement, so the mesh-resident leaves
    — and every halo exchange of gauge planes derived from them — carry
    the compressed representation.
    """
    partition = _resolve_partition(partition, mesh, local_backend,
                                   overlap, interpret)
    u_e_p, u_o_p = ops.make_planar_fields(U_e, U_o, dtype=dtype,
                                          compression=gauge_compression)
    u_e_p = jax.device_put(u_e_p, partition.gauge_sharding())
    u_o_p = jax.device_put(u_o_p, partition.gauge_sharding())
    return u_e_p, u_o_p


def _make_distributed_from_planar(u_e_p, u_o_p, *, partition=None,
                                  mesh=None,
                                  local_backend: str = "jnp_planar",
                                  overlap: str = "fused",
                                  interpret=None, dtype=None,
                                  **_unused) -> WilsonOps:
    """Operators from already-planarized-and-placed gauge fields (the
    rebind path; no placement happens here)."""
    del dtype  # baked into the planar leaves
    from repro.distributed import qcd

    overlap = _normalize_overlap(overlap)
    partition = _resolve_partition(partition, mesh, local_backend,
                                   overlap, interpret)
    sp_shard = partition.spinor_sharding()
    bsp_shard = partition.batched_spinor_sharding()

    hop_fns = {(p, b): jax.jit(qcd.make_hop_fn(partition, p, batched=b))
               for p in (evenodd.EVEN, evenodd.ODD)
               for b in (False, True)}
    dhat_cache = {}

    def to_domain(psi):
        return jax.device_put(
            layout.spinor_to_planar(psi, dtype=u_e_p.dtype), sp_shard)

    def from_domain(v):
        return layout.spinor_from_planar(v)

    def to_domain_batched(psi):
        # One placement for the whole RHS block.
        return jax.device_put(
            layout.spinor_to_planar(psi, dtype=u_e_p.dtype), bsp_shard)

    def hop_oe(v):
        # H_oe reads even-parity gauge links as u_in, writes odd sites.
        return hop_fns[evenodd.ODD, False](u_o_p, u_e_p, v)

    def hop_eo(v):
        return hop_fns[evenodd.EVEN, False](u_e_p, u_o_p, v)

    def hop_oe_batched(v):
        return hop_fns[evenodd.ODD, True](u_o_p, u_e_p, v)

    def hop_eo_batched(v):
        return hop_fns[evenodd.EVEN, True](u_e_p, u_o_p, v)

    def _dhat(v, kappa, batched):
        k = (float(kappa), batched)
        if k not in dhat_cache:
            dhat_cache[k] = jax.jit(
                qcd.make_dhat_fn(partition, k[0], batched=batched))
        return dhat_cache[k](u_e_p, u_o_p, v)

    def apply_dhat(v, kappa):
        return _dhat(v, kappa, False)

    def apply_dhat_batched(v, kappa):
        # The batched operator's halo exchange moves the whole RHS block
        # in one ppermute per face — not nrhs exchanges.
        return _dhat(v, kappa, True)

    return WilsonOps.from_native(
        "distributed", domain="planar_sharded",
        to_domain=to_domain, from_domain=from_domain,
        hop_oe=hop_oe, hop_eo=hop_eo, apply_dhat=apply_dhat,
        apply_dhat_dagger=_dagger_via_gamma5_planar(apply_dhat),
        to_domain_batched=to_domain_batched,
        from_domain_batched=from_domain,
        hop_oe_batched=hop_oe_batched, hop_eo_batched=hop_eo_batched,
        apply_dhat_batched=apply_dhat_batched,
        apply_dhat_dagger_batched=_dagger_via_gamma5_planar(
            apply_dhat_batched))


_GAUGE_COMPRESSIONS = ("none", "two_row", "minimal")

_PALLAS_DTYPES = ("f32", "bf16", "f64")

register_backend(
    "jnp", make_jnp_backend,
    capabilities=BackendCapabilities(
        name="jnp", domain="complex", gauge_form="complex",
        batched_kernels=False, dtypes=(), supports_interpret=False,
        policies=(),
        description="pure-XLA complex reference path (compute dtype "
                    "follows the gauge dtype; batched ops are a vmap "
                    "fallback)"),
    native_factory=lambda gauge, **opts: make_jnp_backend(*gauge),
    prepare_gauge=lambda U_e, U_o, **_: (U_e, U_o))
register_backend(
    "pallas", make_pallas_backend,
    capabilities=BackendCapabilities(
        name="pallas", domain="planar", gauge_form="planar",
        batched_kernels=True, dtypes=_PALLAS_DTYPES,
        supports_interpret=True, policies=("unfused",),
        gauge_compressions=_GAUGE_COMPRESSIONS, fallback="jnp",
        description="planar Pallas stencil, one kernel per hopping "
                    "block (two kernels per Dhat)"),
    native_factory=_pallas_native_factory(False, "pallas"),
    prepare_gauge=_pallas_prepare_gauge)
register_backend(
    "pallas_fused", make_pallas_fused_backend,
    capabilities=BackendCapabilities(
        name="pallas_fused", domain="planar", gauge_form="planar",
        batched_kernels=True, dtypes=_PALLAS_DTYPES,
        supports_interpret=True,
        policies=("auto", "resident", "stream", "unfused"),
        gauge_compressions=_GAUGE_COMPRESSIONS, fallback="pallas",
        description="Dhat as ONE kernel; three-way auto policy sized by "
                    "dtype and nrhs (resident VMEM scratch -> streaming "
                    "plane window -> two-kernel fallback)"),
    native_factory=_pallas_native_factory(None, "pallas_fused"),
    prepare_gauge=_pallas_prepare_gauge)
register_backend(
    "pallas_fused_stream", make_pallas_fused_stream_backend,
    capabilities=BackendCapabilities(
        name="pallas_fused_stream", domain="planar", gauge_form="planar",
        batched_kernels=True, dtypes=_PALLAS_DTYPES,
        supports_interpret=True, policies=("stream",),
        gauge_compressions=_GAUGE_COMPRESSIONS,
        fallback="pallas_fused",
        description="streaming plane-window fused Dhat, forced: VMEM "
                    "holds a 4-row ring of odd-intermediate t-planes "
                    "(no T-dependent volume cap)"),
    native_factory=_pallas_native_factory("stream", "pallas_fused_stream"),
    prepare_gauge=_pallas_prepare_gauge)
register_backend(
    "distributed", make_distributed_backend,
    capabilities=BackendCapabilities(
        name="distributed", domain="planar_sharded",
        gauge_form="planar_sharded", batched_kernels=True,
        dtypes=_PALLAS_DTYPES, supports_interpret=True,
        policies=("local:jnp_planar", "local:jnp", "local:pallas",
                  "overlap:fused", "overlap:interior",
                  "overlap:split"),
        gauge_compressions=_GAUGE_COMPRESSIONS, fallback="jnp",
        description="shard_map over a device mesh with z/t halo "
                    "exchange; gauge placed once at bind, one batched "
                    "exchange per RHS block (overlappable with the "
                    "interior stencil; links shippable compressed)"),
    native_factory=lambda gauge, **opts: _make_distributed_from_planar(
        *gauge, **opts),
    prepare_gauge=_distributed_prepare_gauge)
