"""Fault-tolerant checkpointing: atomic, last-k, async, reshard-on-restore.

No external deps (no orbax/tensorstore): each pytree leaf is saved as an
``.npy`` under a staging directory which is atomically renamed into place
— a crashed save can never corrupt the latest checkpoint.  Restore
``device_put``s into the *current* sharding, so a job restarted on a
different mesh (elastic re-mesh after node loss) picks up transparently.

Layout:
  <dir>/step_000123/MANIFEST.json   tree structure + dtypes + step + extras
  <dir>/step_000123/leaf_<i>.npy    one file per leaf
  <dir>/LATEST                      text file with the newest step
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _tree_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, leaves


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending = None
        self._lock = threading.Lock()

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, extras: Optional[Dict] = None,
             block: bool = False):
        """Snapshot ``tree`` at ``step``.  Device arrays are fetched to
        host synchronously (consistent snapshot), file I/O may be async."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        manifest = {
            "step": int(step),
            "n_leaves": len(host_leaves),
            "extras": extras or {},
        }

        def write():
            stage = self.dir / f".tmp_step_{step:09d}"
            final = self.dir / f"step_{step:09d}"
            if stage.exists():
                shutil.rmtree(stage)
            stage.mkdir(parents=True)
            for i, arr in enumerate(host_leaves):
                np.save(stage / f"leaf_{i}.npy", arr)
            (stage / "MANIFEST.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            stage.rename(final)                      # atomic publish
            (self.dir / "LATEST.tmp").write_text(str(step))
            (self.dir / "LATEST.tmp").rename(self.dir / "LATEST")
            self._gc()

        if self._pool and not block:
            with self._lock:
                if self._pending is not None:
                    self._pending.result()           # backpressure: 1 deep
                self._pending = self._pool.submit(write)
        else:
            write()

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")]

    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if f.exists():
            s = int(f.read_text().strip())
            if (self.dir / f"step_{s:09d}").exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int, Dict]:
        """Restore into the structure of ``tree_like``; leaves are placed
        with ``shardings`` (tree of NamedSharding) when given — this is
        the reshard-on-restore path for elastic restarts."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        leaves, treedef = jax.tree_util.tree_flatten(tree_like)
        assert manifest["n_leaves"] == len(leaves), \
            f"checkpoint has {manifest['n_leaves']} leaves, model {len(leaves)}"
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "device_set"))
            if shardings is not None else [None] * len(leaves))
        if len(sh_leaves) != len(leaves):
            raise ValueError(
                f"shardings tree has {len(sh_leaves)} leaves but the "
                f"value tree has {len(leaves)}; pass a fully aligned "
                "sharding tree (use None for the whole argument to skip)")
        out = []
        for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = np.load(d / f"leaf_{i}.npy")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return (jax.tree_util.tree_unflatten(treedef, out), step,
                manifest.get("extras", {}))
