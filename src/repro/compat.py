"""Version-adaptive shims over the JAX APIs that drifted between releases.

The repo targets JAX 0.4.37 (the pinned CI version) through current
releases.  Three API families moved underneath us:

* ``shard_map``: ``jax.experimental.shard_map.shard_map(..., check_rep=)``
  on 0.4.x became ``jax.shard_map(..., check_vma=)`` on newer releases.
* Pallas TPU compiler params: ``pltpu.TPUCompilerParams`` was renamed to
  ``pltpu.CompilerParams``.
* ``jax.make_mesh``: newer releases grew an ``axis_types=`` kwarg and the
  ``jax.sharding.AxisType`` enum; 0.4.37 has neither (every mesh axis is
  implicitly "auto").

Everything in the repo that needs one of these goes through this module;
nothing else may touch the moved names directly.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = ["shard_map", "tpu_compiler_params", "make_mesh", "axis_size",
           "HAS_AXIS_TYPE", "JAX_VERSION"]

JAX_VERSION = jax.__version__

# --- shard_map -------------------------------------------------------------

if hasattr(jax, "shard_map"):                     # JAX >= 0.6-ish
    _shard_map_impl = jax.shard_map
else:                                             # 0.4.x fallback
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``shard_map`` with the new-style keyword interface on every version.

    ``check_vma`` is the current name of 0.4.x's ``check_rep``; we accept
    the new name and translate down when running on an old JAX.
    """
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, **kwargs)


# --- static mesh-axis size inside shard_map ---------------------------------

if hasattr(jax.lax, "axis_size"):

    def axis_size(name) -> int:
        """Static size of a named mesh axis, usable inside ``shard_map``."""
        return jax.lax.axis_size(name)

else:  # 0.4.x: the axis frame carries the size directly

    def axis_size(name) -> int:
        """Static size of a named mesh axis, usable inside ``shard_map``."""
        return jax.core.axis_frame(name)


# --- Pallas TPU compiler params --------------------------------------------

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` / ``pltpu.TPUCompilerParams``, whichever
    this JAX provides.  Unknown fields are dropped (newer JAX occasionally
    renames them) rather than crashing an old pin."""
    valid = frozenset(
        inspect.signature(_COMPILER_PARAMS_CLS.__init__).parameters)
    return _COMPILER_PARAMS_CLS(
        **{k: v for k, v in kwargs.items() if k in valid})


# --- mesh construction ------------------------------------------------------

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_MAKE_MESH_PARAMS = frozenset(
    inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence] = None,
              explicit: bool = False):
    """``jax.make_mesh`` with auto-typed axes on every JAX version.

    On 0.4.37 there is no ``AxisType`` and every axis is auto, so the
    kwarg is simply omitted.  On newer JAX we pass ``AxisType.Auto``
    explicitly (or ``AxisType.Explicit`` when ``explicit=True``) so the
    behaviour matches the old default instead of whatever the new default
    drifts to.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE and "axis_types" in _MAKE_MESH_PARAMS:
        ty = (jax.sharding.AxisType.Explicit if explicit
              else jax.sharding.AxisType.Auto)
        kwargs["axis_types"] = (ty,) * len(tuple(axis_names))
    elif explicit:
        raise NotImplementedError(
            f"explicit mesh axes need jax.sharding.AxisType "
            f"(JAX {JAX_VERSION} predates it)")
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
