"""Architecture registry: the ten assigned LM configs plus the paper's own
Wilson-QCD lattice configs.

``get(name)`` accepts the public (dashed) arch id, e.g. ``--arch
deepseek-7b``; ``shapes_for(cfg)`` returns the benchmark shape cells that
apply to an architecture (decode shapes need a decoder; ``long_500k``
needs sub-quadratic sequence mixing — skips are recorded, not silent).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "deepseek-7b": "deepseek_7b",
    "minicpm3-4b": "minicpm3_4b",
    "minitron-4b": "minitron_4b",
    "deepseek-67b": "deepseek_67b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "grok-1-314b": "grok_1_314b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "hymba-1.5b": "hymba_1_5b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


# ---------------------------------------------------------------------------
# Benchmark shape cells (assigned input-shape set for the LM family)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shapes_for(cfg: ModelConfig) -> List[Tuple[ShapeCell, Optional[str]]]:
    """[(cell, skip_reason_or_None)] for every assigned shape."""
    out = []
    for cell in SHAPES:
        skip = None
        if cell.kind == "decode" and not cfg.supports_decode:
            skip = "encoder-only: no decode step"
        elif cell.name == "long_500k" and not cfg.subquadratic:
            skip = "full attention is quadratic at 500k; per-instruction skip"
        out.append((cell, skip))
    return out


# ---------------------------------------------------------------------------
# Wilson-QCD lattice configs (the paper's own benchmark points)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LatticeConfig:
    name: str
    # global lattice (T, Z, Y, X); paper tables quote per-process sizes —
    # these globals reproduce them on the quoted process grids.
    shape: Tuple[int, int, int, int]
    kappa: float = 0.13


QCD_CONFIGS = {
    # CI/demo size: small enough for interpret-mode kernel backends
    "wilson-8x8x8x8": LatticeConfig("wilson-8x8x8x8", (8, 8, 8, 8)),
    # paper Table 1 local volumes (single A64FX node = 4 ranks [1,1,2,2])
    "wilson-16x16x16x16": LatticeConfig("wilson-16x16x16x16",
                                        (16, 16, 16, 16)),
    "wilson-64x16x16x8": LatticeConfig("wilson-64x16x16x8", (16, 16, 16, 64)),
    "wilson-64x32x32x16": LatticeConfig("wilson-64x32x32x16",
                                        (32, 32, 32, 64)),
    # production dry-run lattice for the (2,16,16) mesh: T over pod*data,
    # Z over model; local block 8 x 8 x 32 x 32.
    "wilson-production": LatticeConfig("wilson-production",
                                       (256, 128, 32, 32)),
}


def get_qcd(name: str) -> LatticeConfig:
    return QCD_CONFIGS[name]
