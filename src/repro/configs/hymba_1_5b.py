"""Hymba-1.5B — hybrid parallel attention + Mamba heads
[arXiv:2411.13676; hf].

Simplifications recorded in DESIGN.md: meta tokens omitted; the few
global-attention layers are approximated as sliding-window for the
long-context serve path (the SSM branch carries global state).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    attention="hybrid", ssm_state=16, ssm_expand=2,
    sliding_window=2048, subquadratic=True,
)
