"""Llama-4 Maverick 400B-A17B (MoE 128 experts top-1, interleaved
dense/MoE, shared expert) [hf:meta-llama/Llama-4-*; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    attention="gqa", rope_theta=500000.0,
    moe=True, n_experts=128, top_k=1, moe_every=2,
    n_shared_experts=1, moe_d_ff=8192,
)
