"""Phi-3-vision 4.2B — phi3-mini backbone + CLIP frontend (stubbed:
input_specs supplies precomputed patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    attention="gqa", rope_theta=10000.0,
    modality="vision", num_prefix_embeds=576,  # 336px CLIP-L/14 patches
)
