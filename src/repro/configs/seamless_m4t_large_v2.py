"""SeamlessM4T-large v2 — encoder-decoder, multimodal (speech frontend
stubbed: input_specs supplies precomputed frame embeddings)
[arXiv:2308.11596; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    attention="gqa", rope_theta=10000.0,
    encoder_layers=24, cross_attention=True,
    modality="audio", num_prefix_embeds=0,
)
