"""Core lattice-QCD library: the paper's contribution in JAX.

Layering (each validated against the one above it):

1. :mod:`repro.core.wilson` — textbook full-lattice Wilson operator.
2. :mod:`repro.core.evenodd` — even-odd compacted layout + hopping blocks
   (the paper's data layout, pure jnp complex).
3. :mod:`repro.kernels.ref` — planar (re/im separated) float layout, the
   oracle for the Pallas kernel.
4. :mod:`repro.kernels.wilson_stencil` — the Pallas TPU kernel.
"""
from .lattice import LatticeGeometry, MU_X, MU_Y, MU_Z, MU_T, shift, site_parity
from .gamma import GAMMA, GAMMA5, project, reconstruct
from .su3 import random_gauge, unit_gauge, plaquette, unitarity_defect
from .wilson import apply_wilson, apply_wilson_dagger, hop, DW_FLOPS_PER_SITE
from .evenodd import (EVEN, ODD, pack, unpack, pack_gauge, eo_shift,
                      hop_oe, hop_eo, apply_dhat, apply_dhat_dagger,
                      apply_wilson_eo)
from .solver import cg, cgnr, bicgstab, SolveResult
