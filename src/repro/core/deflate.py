"""Low-mode deflation and subspace recycling for repeated solves.

Repeated solves against ONE bound gauge configuration — a propagator
request stream, an HMC force loop — all fight the same few low modes of
the normal operator ``A = Dhat^dag Dhat``: those modes dominate the
condition number and therefore every CG iteration count.  This module
computes a small deflation subspace once per gauge and removes it from
every subsequent solve, making the *stream* sublinear in total
iterations even though each individual solve is unchanged Krylov:

* :class:`DeflationBasis` — the subspace as a fixed-shape pytree
  ``(vectors, avectors, gram, mask)``: ``rank`` native-domain basis
  vectors ``W`` stacked on a leading axis (zero-padded past the fill
  count), the matching operator images ``A W`` (both builders compute
  them anyway — storing them makes the per-iteration projection free
  of extra operator applies), ``gram = W^H A W`` (identity in unused
  slots) and a slot mask.  Fixed shapes are the point: the basis is
  passed into the jitted solve as an ARGUMENT, so a basis that grows
  between solves updates values, never shapes — no retrace.
* :func:`lanczos_basis` — an m-step fully reorthogonalized Lanczos
  pass over ``A`` with Rayleigh-Ritz extraction of the lowest ``rank``
  modes; the reduction ``H = V^H (A V)`` rides the backend's batched
  native operator (one batched apply over the whole Krylov basis).
* :func:`galerkin_guess` — the Galerkin initial guess
  ``x0 = W (W^H A W)^{-1} W^H b``: solves the low-mode block before
  the Krylov loop starts.  An empty basis returns the zero guess
  bit-for-bit.
* :func:`make_projector` — the per-iteration half of deflation: new
  search directions are built from ``P r = r - W G^{-1} (A W)^H r``
  instead of ``r``, keeping every direction A-orthogonal to the
  subspace.  This is what makes deflation ROBUST in f32: the guess
  alone ("init-CG") only pays off with eigenvector accuracy near the
  solve tolerance, while the projected recurrence locks the low modes
  out of the Krylov space even when the basis spans them only
  approximately (harvested solutions, a modest Lanczos pass).  Cost
  per iteration: rank-sized dot products against the stored ``A W``
  — no operator applies.
* :func:`make_recycle_update` / :class:`DeflationState` — the
  recycling alternative to an up-front eigensolve: start empty and
  harvest converged solutions from the request stream itself
  (``x = A^{-1} b`` weights mode ``i`` by ``1/lambda_i`` — solutions
  are naturally low-mode rich), so per-solve iteration counts DROP as
  the stream proceeds; ``SolveSession.stats()`` exposes the drop.
* checkpointing — :class:`repro.resilience.BasisSnapshot` persists a
  basis (atomic staged saves) so a re-bound gauge restores it instead
  of re-paying the Lanczos pass or the recycle warm-up.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as _np

from . import solver as _sol


class DeflationBasis(NamedTuple):
    """Fixed-shape deflation subspace (a pytree; see module docstring).

    ``vectors`` mirrors the native vector pytree with a leading
    ``rank`` axis per leaf; ``avectors`` holds the operator images
    ``A W`` in the same layout; slots past the fill count are zero.
    ``gram`` is ``W^H A W`` with identity rows/columns in unused slots
    (always solvable); ``mask`` flags filled slots.
    """
    vectors: jax.Array
    avectors: jax.Array
    gram: jax.Array
    mask: jax.Array

    @property
    def rank(self) -> int:
        return int(self.mask.shape[0])

    def count(self) -> int:
        return int(jnp.sum(self.mask))


def _gram_dtype(v_like):
    return _sol._vdot(v_like, v_like).dtype


def empty_basis(rank: int, v_like) -> DeflationBasis:
    """All-slots-empty basis shaped for ``rank`` vectors like ``v_like``
    (the recycle starting point, and the snapshot restore template)."""
    def stack_like(_):
        return jax.tree_util.tree_map(
            lambda leaf: jnp.zeros((rank,) + leaf.shape, leaf.dtype),
            v_like)

    gdtype = _gram_dtype(v_like)
    return DeflationBasis(stack_like(None), stack_like(None),
                          jnp.eye(rank, dtype=gdtype),
                          jnp.zeros((rank,), bool))


def _stack_dot(vecs, v, batched: bool):
    """Coefficients ``c[i] = <W_i, v>`` against a stacked basis —
    ``(rank,)``, or ``(rank, nrhs)`` for a batched ``v`` (f32-accumulated
    for sub-f32 leaves, like the solver reductions)."""
    out = None
    for w, x in zip(jax.tree_util.tree_leaves(vecs),
                    jax.tree_util.tree_leaves(v)):
        w, x = _sol._acc(w), _sol._acc(x)
        wf = jnp.conj(w).reshape(w.shape[0], -1)
        if batched:
            c = wf @ x.reshape(x.shape[0], -1).T
        else:
            c = wf @ x.reshape(-1)
        out = c if out is None else out + c
    return out


def _stack_comb(coef, vecs):
    """Linear combination ``sum_i coef[i] * W_i`` over the stacked
    basis; a ``(rank, nrhs)`` coefficient block yields the batched
    vector (leading nrhs axis).  The coefficient is cast down to the
    leaf dtype (see ``solver._apply_scalar``)."""
    def leaf(w):
        c = _sol._apply_scalar(coef, w)
        return jnp.tensordot(c, w, axes=((0,), (0,)))
    return jax.tree_util.tree_map(leaf, vecs)


def _mix(coef, stacked):
    """Re-stack a basis through a ``(k, m)`` coefficient matrix:
    ``out_i = sum_j coef[i, j] * V_j``."""
    def leaf(v):
        c = _sol._apply_scalar(coef, v)
        return jnp.tensordot(c, v, axes=((1,), (0,)))
    return jax.tree_util.tree_map(leaf, stacked)


def _masked_gram(gram, mask):
    """``W^H A W`` restricted to filled slots, identity elsewhere —
    always solvable, and empty slots contribute exactly zero."""
    gdtype = gram.dtype
    rank = mask.shape[0]
    mf = mask.astype(gdtype)
    return (mf[:, None] * mf[None, :]) * gram \
        + (1.0 - mf) * jnp.eye(rank, dtype=gdtype)


def galerkin_guess(basis: DeflationBasis, bn, *, batched: bool = False):
    """Galerkin (init-CG) deflation guess ``W (W^H A W)^{-1} W^H bn``.

    ``bn`` is the normal-equations RHS ``Dhat^dag rhs`` the solver
    iterates on.  Empty slots are masked to identity rows/zero RHS, so
    an EMPTY basis returns the zero vector — bit-for-bit the undeflated
    start (what makes a growing recycle basis safe to pass from solve
    zero onward).
    """
    mask = basis.mask
    mf = mask.astype(basis.gram.dtype)
    c = _stack_dot(basis.vectors, bn, batched)
    c = c * (mf[:, None] if batched else mf)
    gm = _masked_gram(basis.gram, mask)
    return _stack_comb(jnp.linalg.solve(gm, c), basis.vectors)


def make_projector(basis: DeflationBasis, *, batched: bool = False):
    """A-orthogonal deflation projector ``P r = r - W G^{-1} (A W)^H r``.

    The returned closure is handed to the solver's ``project`` hook:
    every new search direction is projected so ``W^H A p = 0``, which
    keeps the Krylov space out of the (approximately) deflated low
    modes for the whole solve — see the module docstring for why the
    initial guess alone is not enough in f32.  Uses the stored ``A W``
    (no operator applies).  With an EMPTY basis the correction term is
    exactly zero, so the projector is the identity and the solve
    matches the undeflated recurrence.
    """
    mask = basis.mask
    mf = mask.astype(basis.gram.dtype)
    gm = _masked_gram(basis.gram, mask)

    def project(r):
        c = _stack_dot(basis.avectors, r, batched)
        c = c * (mf[:, None] if batched else mf)
        y = jnp.linalg.solve(gm, c)
        corr = _stack_comb(y, basis.vectors)
        return jax.tree_util.tree_map(lambda a, d: a - d, r, corr)

    return project


def lanczos_basis(op: Callable, v0, rank: int, *,
                  iters: Optional[int] = None,
                  op_batched: Optional[Callable] = None
                  ) -> DeflationBasis:
    """Lowest-``rank`` Ritz pairs of the HPD ``op`` via Lanczos.

    Runs ``iters`` (default ``max(3*rank, rank+16)``, clamped to the
    space dimension — the projected recurrence tolerates approximate
    Ritz vectors, but more steps still buy fewer iterations per
    deflated solve) Lanczos steps from ``v0`` with full
    reorthogonalization (modified Gram-Schmidt against every stored
    vector — m is small, orthogonality is what makes the low Ritz pairs
    trustworthy), then extracts Ritz vectors from the explicit
    Rayleigh quotient ``H = V^H (A V)``.  ``op_batched`` (the backend's
    batched native operator) computes ``A V`` as ONE batched apply over
    the whole stacked basis; without it the column-wise fallback is
    used.  Eager Python loop by design: once per bound gauge, with a
    data-dependent early exit on Krylov-space exhaustion.
    """
    dim = sum(leaf.size
              for leaf in jax.tree_util.tree_leaves(v0))
    m = int(iters) if iters else max(3 * rank, rank + 16)
    m = max(1, min(m, dim))
    nrm2 = _sol._norm2(v0)
    tiny = _sol._tiny(nrm2.dtype)
    v = _sol._scale(1.0 / jnp.sqrt(jnp.maximum(nrm2, tiny)), v0)
    basis_vecs = [v]
    for _ in range(m - 1):
        w = op(basis_vecs[-1])
        pre2 = _sol._norm2(w)
        # Full reorthogonalization (MGS) — also subsumes the three-term
        # recurrence's alpha/beta subtraction.
        for u in basis_vecs:
            w = _sol._axpy(-_sol._vdot(u, w), u, w)
        w2 = _sol._norm2(w)
        # RELATIVE breakdown test: once the Krylov space saturates (a
        # well-conditioned operator exhausts it in a few dozen steps),
        # what survives orthogonalization is pure roundoff — normalizing
        # it would stack a numerically dependent direction into V and
        # seed Rayleigh-Ritz with spurious near-null eigenvalues.
        if float(w2) <= max(float(tiny), 1e-10 * float(pre2)):
            break                      # Krylov space exhausted
        basis_vecs.append(_sol._scale(1.0 / jnp.sqrt(w2), w))
    stacked = jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves), *basis_vecs)
    if op_batched is not None:
        av = op_batched(stacked)
    else:
        av = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves),
            *[op(u) for u in basis_vecs])
    h = _sol._bgram(stacked, av)
    h = 0.5 * (h + jnp.conj(h).T)
    vals, y = jnp.linalg.eigh(h)       # ascending: low modes first
    # Spurious-mode filter: residual f32 rank loss in V shows up as
    # Ritz values at roundoff scale (~eps^2 of the spectrum top) that
    # correspond to no eigenvalue of the HPD operator; deflating one
    # would project against garbage.  Genuine deflatable low modes of
    # an f32-solvable system sit far above eps * lambda_max.
    vals = _np.asarray(vals.real)
    eps_h = float(jnp.finfo(jnp.zeros((), h.dtype).real.dtype).eps)
    cutoff = max(vals[-1], 0.0) * eps_h * 16
    genuine = [int(i) for i in range(vals.shape[0]) if vals[i] > cutoff]
    keep = min(rank, len(genuine))
    yk = y[:, _np.asarray(genuine[:keep], dtype=_np.int64)].T
    w_ritz = _mix(yk, stacked)
    aw = _mix(yk, av)
    # Quality filter: the projector divides by the Ritz value, so an
    # UNCONVERGED pair (Ritz residual |A w - theta w| comparable to
    # theta itself) would amplify its eigenvector error by 1/theta and
    # poison every deflated solve.  Keep only pairs whose residual is
    # safely below their value; dropping a marginal mode merely forgoes
    # its iteration savings.
    theta = vals[_np.asarray(genuine[:keep], dtype=_np.int64)]
    resid = jax.tree_util.tree_map(
        lambda a_, w_: a_ - _sol._bb(
            _sol._apply_scalar(jnp.asarray(theta), w_), w_) * w_,
        aw, w_ritz)
    rres = _np.sqrt(_np.asarray(jax.device_get(_sol._bnorm2(resid))))
    ok = [i for i in range(keep)
          if rres[i] <= RITZ_QUALITY * theta[i]]
    if len(ok) < keep:
        sel = _np.asarray(ok, dtype=_np.int64)
        w_ritz = jax.tree_util.tree_map(lambda l: l[sel], w_ritz)
        aw = jax.tree_util.tree_map(lambda l: l[sel], aw)
        keep = len(ok)
    gram = _sol._bgram(w_ritz, aw)
    gram = 0.5 * (gram + jnp.conj(gram).T)
    out = empty_basis(rank, v0)

    def fill(z, w):
        return jax.tree_util.tree_map(
            lambda zl, wl: zl.at[:keep].set(wl.astype(zl.dtype)), z, w)

    return DeflationBasis(
        fill(out.vectors, w_ritz), fill(out.avectors, aw),
        out.gram.at[:keep, :keep].set(gram.astype(out.gram.dtype)),
        out.mask.at[:keep].set(True))


# Ritz-pair acceptance: a pair only deflates when its eigenvector
# residual |A w - theta w| is below this fraction of theta — the
# projector divides by theta, so a sloppier pair amplifies its own
# error by 1/theta into every deflated iteration.
RITZ_QUALITY = 0.5
# Recycle refinement accepts at a laxer, band-level gate: harvested
# spans resolve the low CLUSTER collectively before any individual
# pair converges (intra-band mixing inflates per-pair residuals while
# the span already deflates the band — measured: a stream that stays
# flat gated at 0.5, and mildly *degrades* gated at 2 when only part
# of a cluster activates, drops ~30% gated at 5).  Genuinely dangerous
# pairs — near-null values carrying roundoff garbage — have residual
# ratios orders of magnitude above this and stay rejected.
RECYCLE_QUALITY = 5.0


def make_ritz_refine(quality: float = RITZ_QUALITY):
    """Jitted ``raw span -> deflation basis`` Rayleigh-Ritz refinement.

    Deflating with RAW harvested solutions is numerically fragile: a
    solution ``x = A^{-1} b`` mixes every mode, so its image ``A x``
    is large relative to its tiny Rayleigh quotient, and the
    projection identity ``P^H r = r`` that CG's step length relies on
    degrades by that ratio in f32 — measured as harvests *slowing the
    stream down*.  This refinement rotates the harvested span to its
    Ritz pairs (Rayleigh-Ritz on the stored ``W^H A W``) and ACCEPTS —
    via the basis mask — only pairs passing the :data:`RITZ_QUALITY`
    eigenvector-residual test, i.e. the directions the span already
    resolves as approximate eigenvectors.  Early in the stream nothing
    may qualify (the projector stays the identity — no harm); as
    harvests accumulate the low cluster converges, pairs activate, and
    per-solve iterations drop.

    The empty-slot handling rides the exact block structure of the
    masked gram: masked entries are zero, so filled and empty blocks
    cannot mix in ``eigh``; empty diagonals get a sentinel only a few
    times the spectrum scale (an f32 ``eigh``'s backward error is
    ``eps * |gm|`` — a huge sentinel would destroy the small Ritz
    values), and empty-block eigenpairs are identified by their
    eigenvector weight, not their value.
    """
    def refine(raw: DeflationBasis) -> DeflationBasis:
        vecs, avecs, gram, mask = raw
        rank = mask.shape[0]
        gdtype = gram.dtype
        rdtype = jnp.zeros((), gdtype).real.dtype
        mf = mask.astype(gdtype)
        diag = jnp.abs(jnp.diag(gram).real) * mf.real
        sentinel = 4.0 * jnp.maximum(jnp.max(diag), 1.0)
        gm = (mf[:, None] * mf[None, :]) * gram \
            + ((1.0 - mf) * sentinel.astype(gdtype)) \
            * jnp.eye(rank, dtype=gdtype)
        vals, y = jnp.linalg.eigh(gm)          # ascending
        theta = vals.real.astype(rdtype)
        # out_i = sum_j y[j, i] V_j  ->  coefficient matrix y.T
        w = _mix(y.T, vecs)
        aw = _mix(y.T, avecs)
        resid = jax.tree_util.tree_map(
            lambda a_, w_: a_ - _sol._bb(
                _sol._apply_scalar(theta, w_), w_) * w_, aw, w)
        r2 = _sol._bnorm2(resid)
        # weight of each eigenvector on EMPTY slots: exactly 1 for the
        # sentinel block's pairs, exactly 0 for genuine ones.
        wempty = ((1.0 - mf.real)[None, :] @ (jnp.abs(y) ** 2)).ravel()
        accept = jnp.logical_and(
            jnp.logical_and(wempty < 0.5, theta > 0.0),
            r2 <= (quality * theta) ** 2)
        gnew = jnp.diag(jnp.where(accept, theta, 1.0).astype(gdtype))
        zero = jnp.zeros((), rdtype)
        wm = jax.tree_util.tree_map(
            lambda l: l * _sol._bb(_sol._apply_scalar(
                jnp.where(accept, zero + 1.0, zero), l), l), w)
        awm = jax.tree_util.tree_map(
            lambda l: l * _sol._bb(_sol._apply_scalar(
                jnp.where(accept, zero + 1.0, zero), l), l), aw)
        return DeflationBasis(wm, awm, gnew, accept)

    return jax.jit(refine)


def estimate_lambda_max(op: Callable, v0, iters: int = 12) -> float:
    """Power-iteration estimate of the top eigenvalue of the HPD
    ``op`` — scales the recycle harvest filter (see
    :func:`make_recycle_update`).  A dozen applies, once per basis."""
    n2 = _sol._norm2(v0)
    tiny = _sol._tiny(n2.dtype)
    v = _sol._scale(1.0 / jnp.sqrt(jnp.maximum(n2, tiny)), v0)
    lam = 0.0
    for _ in range(max(1, int(iters))):
        w = op(v)
        lam = float(_sol._vdot(v, w).real)
        w2 = _sol._norm2(w)
        v = _sol._scale(1.0 / jnp.sqrt(jnp.maximum(w2, tiny)), w)
    return lam


def make_recycle_update(op: Callable, *, lam_max: Optional[float] = None,
                        filter_steps: int = 8, lo_frac: float = 0.05):
    """Jitted ``(basis, v) -> basis`` appending one harvested solution.

    ``v`` is orthogonalized against the filled slots, normalized, and
    written into the first free slot; the Gram matrix — and the stored
    ``A W`` image — come from ONE ``op`` apply.  Fixed shapes throughout
    (where-selects, clipped scatter index), so every update reuses one
    executable.  The update is rejected — basis returned unchanged —
    when the basis is full, the new component is non-finite, or ``v``
    is numerically inside the span already (a dependent direction would
    make the Gram solve ill-posed for zero deflation gain).

    ``lam_max`` (with ``filter_steps > 0``) arms the Chebyshev harvest
    filter.  A raw solution is only ``1/sigma``-weighted in the normal
    operator's eigenbasis (``x = Dhat^{-1} rhs``) — too weak for the
    harvested span to ever resolve the low cluster the projector needs
    (its Ritz pairs stall an order of magnitude above the true low
    modes).  ``filter_steps`` three-term Chebyshev steps on
    ``[lo_frac * lam_max, lam_max]`` suppress every mode inside that
    interval to ``|T_k| <= 1`` while amplifying the modes BELOW it
    exponentially in ``k``, so each harvest enters the span low-mode
    dominated and the stream becomes a filtered subspace iteration —
    at ``filter_steps`` operator applies per harvest, a fraction of
    one solve.
    """
    def update(basis: DeflationBasis, v) -> DeflationBasis:
        vecs, avecs, gram, mask = basis
        if lam_max is not None and filter_steps > 0:
            b_hi = float(lam_max)
            a_lo = float(lo_frac) * b_hi
            half = 0.5 * (b_hi - a_lo)
            mid = 0.5 * (b_hi + a_lo)

            def smap(u):
                # affine map of A onto [-1, 1] over [a_lo, b_hi]
                return jax.tree_util.tree_map(
                    lambda p, q: (p - mid * q) / half, op(u), u)

            t0, t1 = v, smap(v)
            for _ in range(int(filter_steps) - 1):
                t2 = jax.tree_util.tree_map(
                    lambda s, p: 2.0 * s - p, smap(t1), t0)
                t0, t1 = t1, t2
            v = t1
        rank = mask.shape[0]
        gdtype = gram.dtype
        mf = mask.astype(gdtype)
        c = _stack_dot(vecs, v, batched=False) * mf
        d = jax.tree_util.tree_map(
            lambda x, u: x - u, v, _stack_comb(c, vecs))
        d2 = _sol._norm2(d)
        v2 = _sol._norm2(v)
        tiny = _sol._tiny(d2.dtype)
        idx = jnp.sum(mask).astype(jnp.int32)
        good = jnp.logical_and(
            jnp.logical_and(idx < rank, jnp.isfinite(d2)),
            d2 > v2 * 1e-8)
        w = _sol._scale(1.0 / jnp.sqrt(jnp.maximum(d2, tiny)), d)
        aw = op(w)
        col = _stack_dot(vecs, aw, batched=False) * mf
        diag = _sol._vdot(w, aw)
        # Hermitian extension: gram[idx, j] = <w, A W_j> = conj(col_j).
        g1 = gram.at[idx, :].set(jnp.conj(col))
        g1 = g1.at[:, idx].set(col)
        g1 = g1.at[idx, idx].set(diag.astype(gdtype))
        vecs1 = jax.tree_util.tree_map(
            lambda z, wl: z.at[idx].set(wl.astype(z.dtype)), vecs, w)
        avecs1 = jax.tree_util.tree_map(
            lambda z, wl: z.at[idx].set(wl.astype(z.dtype)), avecs, aw)
        return DeflationBasis(
            _sol._swhere(good, vecs1, vecs),
            _sol._swhere(good, avecs1, avecs),
            jnp.where(good, g1, gram),
            jnp.where(good, mask.at[idx].set(True), mask))

    return jax.jit(update)


class DeflationState:
    """Per-(matrix, spec) deflation holder the session drives.

    Owns the current :class:`DeflationBasis` (passed into each jitted
    solve as an argument), the jitted recycle updater, and the optional
    :class:`repro.resilience.BasisSnapshot` persisting the basis across
    process lifetimes.  ``mode``: ``"lanczos"`` pays an up-front
    eigensolve and stays fixed; ``"recycle"`` starts empty and grows
    from the stream via :meth:`harvest_column`.
    """

    def __init__(self, basis: DeflationBasis, mode: str,
                 update_fn=None, snapshot=None, refine_fn=None,
                 raw: Optional[DeflationBasis] = None):
        self.basis = basis        # what solves project against
        self.raw = raw            # recycle: harvested span behind it
        self.mode = mode
        self.harvested = 0
        self._update = update_fn
        self._refine = refine_fn
        self._snapshot = snapshot

    @property
    def rank(self) -> int:
        return self.basis.rank

    @property
    def count(self) -> int:
        """Filled slots — of the raw harvested span in recycle mode
        (what gates further harvesting), of the basis otherwise."""
        if self.raw is not None:
            return self.raw.count()
        return self.basis.count()

    @property
    def active(self) -> int:
        """Basis slots the projector actually uses (recycle: Ritz pairs
        passing the quality filter — at most ``count``)."""
        return self.basis.count()

    def harvest_column(self, v) -> bool:
        """Offer one CONVERGED solution vector to a recycle basis.

        The raw span grows by the (orthogonalized) solution, then the
        EXPOSED basis is re-derived by Rayleigh-Ritz refinement — only
        quality-passing Ritz pairs deflate (see
        :func:`make_ritz_refine`), so the caller's next solve sees the
        grown basis as changed values, never changed shapes.
        Lanczos-mode and full bases decline.  The grown raw span is
        snapshotted immediately when persistence is on, so a restarted
        process resumes with the learned subspace.
        """
        if self.mode != "recycle" or self._update is None:
            return False
        before = self.count
        if before >= self.rank:
            return False
        raw1 = DeflationBasis(*self._update(self.raw, v))
        after = raw1.count()
        if after == before:
            return False
        self.raw = raw1
        self.basis = (DeflationBasis(*self._refine(raw1))
                      if self._refine is not None else raw1)
        self.harvested += 1
        if self._snapshot is not None:
            self._snapshot.save(after, raw1)
        return True
