"""Even-odd (red-black) layout and hopping blocks.

The even/odd arrays are compacted in the x-direction (paper Fig. 4):

``even[t, z, y, xh] = full[t, z, y, 2*xh + (t+z+y) % 2]``
``odd [t, z, y, xh] = full[t, z, y, 2*xh + (t+z+y+1) % 2]``

so both have shape ``(T, Z, Y, Xh, ...)`` with ``Xh = X // 2``.  The price
is the parity-dependent x-shift of Fig. 5: the +-x neighbor of a site sits
at the *same* ``xh`` in the opposite-parity array for half the rows and at
``xh +- 1`` for the other half, with the row parity ``(t+z+y) % 2`` as the
predicate.  :func:`eo_shift` implements exactly the paper's ``sel`` +
``tbl`` sequence as a masked roll.

``hop_oe`` (even -> odd) and ``hop_eo`` (odd -> even) are the two hopping
blocks; ``D_eo = -kappa * hop_eo`` etc.  The even-odd preconditioned
operator of Eq. (4) is ``Dhat = 1 - kappa^2 * H_eo H_oe``.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from . import gamma, wilson
from .lattice import AXIS_OF_MU, MU_X, MU_Y, MU_Z, NDIM, row_parity

EVEN, ODD = 0, 1


def _row_par(shape: Tuple[int, ...], trailing: int) -> jnp.ndarray:
    return row_parity(shape, trailing_dims=trailing)


def pack(field: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split a full-lattice field ``(T, Z, Y, X, ...)`` into (even, odd)."""
    T, Z, Y, X = field.shape[:4]
    rest = field.shape[4:]
    v = field.reshape(T, Z, Y, X // 2, 2, *rest)
    off = _row_par((T, Z, Y), trailing=len(rest))  # (T,Z,Y,1,1...)
    v0, v1 = v[:, :, :, :, 0], v[:, :, :, :, 1]
    even = jnp.where(off == 0, v0, v1)
    odd = jnp.where(off == 0, v1, v0)
    return even, odd


def unpack(even: jnp.ndarray, odd: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack`."""
    T, Z, Y, Xh = even.shape[:4]
    rest = even.shape[4:]
    off = _row_par((T, Z, Y), trailing=len(rest))
    v0 = jnp.where(off == 0, even, odd)
    v1 = jnp.where(off == 0, odd, even)
    v = jnp.stack([v0, v1], axis=4)
    return v.reshape(T, Z, Y, 2 * Xh, *rest)


def pack_gauge(U: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack a gauge field ``(4, T, Z, Y, X, 3, 3)`` into even/odd halves."""
    pairs = [pack(U[mu]) for mu in range(NDIM)]
    return (jnp.stack([p[0] for p in pairs]), jnp.stack([p[1] for p in pairs]))


def eo_shift(src: jnp.ndarray, mu: int, direction: int, out_parity: int,
             parity_offset: int = 0) -> jnp.ndarray:
    """Neighbor fetch inside the even-odd layout.

    Returns ``src(x + direction * mu_hat)`` evaluated at the sites of
    ``out_parity``, where ``src`` is the compacted array of the *opposite*
    parity.  For mu in {y, z, t} this is a plain periodic roll; for mu = x
    it is the paper's parity-masked shift (``sel`` on a rolled copy).

    ``parity_offset`` is ``(t0 + z0 + y0) % 2`` of the local shard origin,
    so distributed shards with an odd origin use the flipped mask.
    """
    axis = AXIS_OF_MU[mu]
    if mu != MU_X:
        return jnp.roll(src, -direction, axis=axis)
    T, Z, Y, Xh = src.shape[:4]
    trailing = src.ndim - 4
    par = _row_par((T, Z, Y), trailing=trailing)
    m = (out_parity + (1 if direction > 0 else 0) + parity_offset) % 2
    rolled = jnp.roll(src, -direction, axis=3)
    return jnp.where(par == m, rolled, src)


def hop_block(U_e: jnp.ndarray, U_o: jnp.ndarray, src: jnp.ndarray,
              out_parity: int, parity_offset: int = 0) -> jnp.ndarray:
    """One hopping block: ``H_oe`` if ``out_parity == ODD`` else ``H_eo``.

    ``U_e, U_o``: ``(4, T, Z, Y, Xh, 3, 3)``; ``src``: spinor of the
    opposite parity, ``(T, Z, Y, Xh, 4, 3)``.
    """
    U_out = U_o if out_parity == ODD else U_e   # U_mu(x) at output sites
    U_in = U_e if out_parity == ODD else U_o    # U_mu at source-parity sites
    out = jnp.zeros_like(src)
    for mu in range(NDIM):
        # Forward: (1 - g_mu) U_mu(x) src(x + mu).
        fwd = eo_shift(src, mu, +1, out_parity, parity_offset)
        h = gamma.project(fwd, mu, s=-1)
        uh = jnp.einsum("...ab,...hb->...ha", U_out[mu], h)
        out = out + gamma.reconstruct(uh, mu, s=-1)
        # Backward: (1 + g_mu) U_mu^dag(x - mu) src(x - mu).
        bwd = eo_shift(src, mu, -1, out_parity, parity_offset)
        u_bwd = eo_shift(U_in[mu], mu, -1, out_parity, parity_offset)
        h = gamma.project(bwd, mu, s=+1)
        uh = jnp.einsum("...ba,...hb->...ha", u_bwd.conj(), h)
        out = out + gamma.reconstruct(uh, mu, s=+1)
    return out


def hop_oe(U_e, U_o, psi_e):
    """even -> odd hopping block."""
    return hop_block(U_e, U_o, psi_e, ODD)


def hop_eo(U_e, U_o, psi_o):
    """odd -> even hopping block."""
    return hop_block(U_e, U_o, psi_o, EVEN)


def apply_dhat(U_e, U_o, psi_e, kappa, hop_oe_fn=None, hop_eo_fn=None):
    """Even-odd preconditioned operator ``(1 - kappa^2 H_eo H_oe) psi_e``.

    ``hop_*_fn`` may be swapped for the Pallas-backed implementations.
    """
    hop_oe_fn = hop_oe_fn or hop_oe
    hop_eo_fn = hop_eo_fn or hop_eo
    tmp = hop_oe_fn(U_e, U_o, psi_e)
    return psi_e - (kappa * kappa) * hop_eo_fn(U_e, U_o, tmp)


def apply_dhat_dagger(U_e, U_o, psi_e, kappa, hop_oe_fn=None, hop_eo_fn=None):
    """``Dhat^dag`` via gamma5-hermiticity (g5 Dhat g5 = Dhat^dag)."""
    g5 = jnp.asarray(gamma.GAMMA5)
    g5psi = jnp.einsum("ij,...jc->...ic", g5, psi_e)
    out = apply_dhat(U_e, U_o, g5psi, kappa, hop_oe_fn, hop_eo_fn)
    return jnp.einsum("ij,...jc->...ic", g5, out)


def apply_wilson_eo(U_e, U_o, psi_e, psi_o, kappa):
    """Full D_W in even-odd form: returns (D psi)_e, (D psi)_o."""
    return (psi_e - kappa * hop_eo(U_e, U_o, psi_o),
            psi_o - kappa * hop_oe(U_e, U_o, psi_e))


def _masked_roll_x(arr: jnp.ndarray, direction: int, out_parity: int,
                   parity_offset) -> jnp.ndarray:
    """Parity-masked x-roll on a ``(T, Z, Y, Xh, ...)`` array (sel + tbl)."""
    T, Z, Y = arr.shape[:3]
    trailing = arr.ndim - 4
    par = _row_par((T, Z, Y), trailing=trailing)
    m = (out_parity + (1 if direction > 0 else 0) + parity_offset) % 2
    rolled = jnp.roll(arr, -direction, axis=3)
    return jnp.where(par == m, rolled, arr)


def hop_block_ext(U_out: jnp.ndarray, U_in_ext: jnp.ndarray,
                  src_ext: jnp.ndarray, out_parity: int,
                  parity_offset=0) -> jnp.ndarray:
    """Hopping block on halo-extended arrays (the distributed local step).

    ``src_ext``: ``(Tl+2, Zl+2, Y, Xh, 4, 3)`` with t/z halos;
    ``U_in_ext``: ``(4, Tl+2, Zl+2, Y, Xh, 3, 3)``;
    ``U_out``: unextended ``(4, Tl, Zl, Y, Xh, 3, 3)``.
    ``parity_offset`` is the (possibly traced) global ``(t0+z0) % 2`` of
    the local block origin.

    z/t neighbors are static slices of the extended arrays; x/y shifts are
    in-plane (periodic is exact there because x/y are never sharded).
    """
    c = src_ext[1:-1, 1:-1]
    out = jnp.zeros_like(c)

    def fwd_bwd(mu):
        if mu == MU_X:
            fwd = _masked_roll_x(c, +1, out_parity, parity_offset)
            bwd = _masked_roll_x(c, -1, out_parity, parity_offset)
            u_bwd = _masked_roll_x(U_in_ext[0, 1:-1, 1:-1], -1, out_parity,
                                   parity_offset)
        elif mu == MU_Y:
            fwd = jnp.roll(c, -1, axis=2)
            bwd = jnp.roll(c, +1, axis=2)
            u_bwd = jnp.roll(U_in_ext[1, 1:-1, 1:-1], +1, axis=2)
        elif mu == MU_Z:
            fwd = src_ext[1:-1, 2:]
            bwd = src_ext[1:-1, :-2]
            u_bwd = U_in_ext[2, 1:-1, :-2]
        else:
            fwd = src_ext[2:, 1:-1]
            bwd = src_ext[:-2, 1:-1]
            u_bwd = U_in_ext[3, :-2, 1:-1]
        return fwd, bwd, u_bwd

    for mu in range(NDIM):
        fwd, bwd, u_bwd = fwd_bwd(mu)
        h = gamma.project(fwd, mu, s=-1)
        uh = jnp.einsum("...ab,...hb->...ha", U_out[mu], h)
        out = out + gamma.reconstruct(uh, mu, s=-1)
        h = gamma.project(bwd, mu, s=+1)
        uh = jnp.einsum("...ba,...hb->...ha", u_bwd.conj(), h)
        out = out + gamma.reconstruct(uh, mu, s=+1)
    return out


# ---------------------------------------------------------------------------
# Oracles via the full lattice (slow, for tests).
# ---------------------------------------------------------------------------

def hop_block_oracle(U: jnp.ndarray, src: jnp.ndarray, out_parity: int) -> jnp.ndarray:
    """Same contraction through the full-lattice reference operator."""
    zeros = jnp.zeros_like(src)
    full = unpack(src, zeros) if out_parity == ODD else unpack(zeros, src)
    hopped = wilson.hop(U, full)
    even, odd = pack(hopped)
    return odd if out_parity == ODD else even
