"""Gamma-matrix algebra for the Wilson fermion matrix.

We use the DeGrand-Rossi (chiral) basis, in which every ``(1 + s*gamma_mu)``
projector has the half-spinor structure exploited by the paper (Sec. 2):

* project the 4-spinor onto two 2-component half-spinors ``h = (h0, h1)``,
* multiply the SU(3) link on the color index of each half-spinor,
* reconstruct the 4-spinor: rows 0,1 are ``h0, h1`` and rows 2,3 are
  ``coeff * h_perm`` with ``coeff`` in ``{+-1, +-i}``.

This halves the SU(3) work per hop and is the structure hand-coded in the
Pallas kernel.  The generic matrix forms below are the oracle used by tests.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_I = 1j

# gamma_mu, mu = 0(x), 1(y), 2(z), 3(t); Hermitian, gamma^2 = 1.
GAMMA = np.zeros((4, 4, 4), dtype=np.complex64)
GAMMA[0] = [[0, 0, 0, _I], [0, 0, _I, 0], [0, -_I, 0, 0], [-_I, 0, 0, 0]]
GAMMA[1] = [[0, 0, 0, -1], [0, 0, 1, 0], [0, 1, 0, 0], [-1, 0, 0, 0]]
GAMMA[2] = [[0, 0, _I, 0], [0, 0, 0, -_I], [-_I, 0, 0, 0], [0, _I, 0, 0]]
GAMMA[3] = [[0, 0, 1, 0], [0, 0, 0, 1], [1, 0, 0, 0], [0, 1, 0, 0]]
GAMMA5 = np.diag([1, 1, -1, -1]).astype(np.complex64)  # = g_x g_y g_z g_t


def projector(mu: int, s: int) -> np.ndarray:
    """Dense ``(1 + s*gamma_mu)`` as a 4x4 matrix (twice a projector)."""
    return np.eye(4, dtype=np.complex64) + s * GAMMA[mu]


def project(psi: jnp.ndarray, mu: int, s: int) -> jnp.ndarray:
    """Half-spinor projection of ``(1 + s*gamma_mu) psi``.

    ``psi``: ``(..., 4, 3)`` -> returns ``(..., 2, 3)`` such that
    :func:`reconstruct` recovers the full ``(1 + s*gamma_mu) psi``.
    """
    p0, p1, p2, p3 = (psi[..., i, :] for i in range(4))
    si = s * _I
    if mu == 0:  # x
        h0, h1 = p0 + si * p3, p1 + si * p2
    elif mu == 1:  # y
        h0, h1 = p0 - s * p3, p1 + s * p2
    elif mu == 2:  # z
        h0, h1 = p0 + si * p2, p1 - si * p3
    elif mu == 3:  # t
        h0, h1 = p0 + s * p2, p1 + s * p3
    else:
        raise ValueError(f"bad direction {mu}")
    return jnp.stack([h0, h1], axis=-2)


def reconstruct(h: jnp.ndarray, mu: int, s: int) -> jnp.ndarray:
    """Rebuild the 4-spinor from the half-spinor of ``(1 + s*gamma_mu)``."""
    h0, h1 = h[..., 0, :], h[..., 1, :]
    si = s * _I
    if mu == 0:  # x
        r2, r3 = -si * h1, -si * h0
    elif mu == 1:  # y
        r2, r3 = s * h1, -s * h0
    elif mu == 2:  # z
        r2, r3 = -si * h0, si * h1
    elif mu == 3:  # t
        r2, r3 = s * h0, s * h1
    else:
        raise ValueError(f"bad direction {mu}")
    return jnp.stack([h0, h1, r2, r3], axis=-2)
