"""Lattice geometry helpers.

Conventions (used throughout the package):

* Site arrays are indexed ``[t, z, y, x]`` (t slowest, x fastest).
* A spinor field on the full lattice has shape ``(T, Z, Y, X, 4, 3)``
  (spin, color) and complex dtype.
* A gauge field has shape ``(4, T, Z, Y, X, 3, 3)`` with direction index
  ``mu``: 0 = x, 1 = y, 2 = z, 3 = t.  ``U[mu, t, z, y, x]`` lives on the
  link from site ``x`` to ``x + mu_hat``.
* Site parity is ``(t + z + y + x) % 2``; parity 0 is "even".

The x-direction is the SIMD-packed direction of the paper; the even/odd
arrays are compacted in x (``Xh = X // 2``), see :mod:`repro.core.evenodd`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# Direction indices.
MU_X, MU_Y, MU_Z, MU_T = 0, 1, 2, 3
# Array axis carrying each direction for a ``(T, Z, Y, X, ...)`` field.
AXIS_OF_MU = {MU_X: 3, MU_Y: 2, MU_Z: 1, MU_T: 0}
NDIM = 4


@dataclasses.dataclass(frozen=True)
class LatticeGeometry:
    """Global lattice geometry (sizes are in sites, full lattice)."""

    shape: Tuple[int, int, int, int]  # (T, Z, Y, X)

    def __post_init__(self):
        T, Z, Y, X = self.shape
        if X % 2:
            raise ValueError(f"X extent must be even for even-odd layout, got {X}")

    @property
    def T(self) -> int:
        return self.shape[0]

    @property
    def Z(self) -> int:
        return self.shape[1]

    @property
    def Y(self) -> int:
        return self.shape[2]

    @property
    def X(self) -> int:
        return self.shape[3]

    @property
    def Xh(self) -> int:
        return self.shape[3] // 2

    @property
    def n_sites(self) -> int:
        return int(np.prod(self.shape))

    def spinor_shape(self, even_odd: bool = False) -> Tuple[int, ...]:
        if even_odd:
            return (self.T, self.Z, self.Y, self.Xh, 4, 3)
        return (self.T, self.Z, self.Y, self.X, 4, 3)

    def gauge_shape(self, even_odd: bool = False) -> Tuple[int, ...]:
        if even_odd:
            return (NDIM, self.T, self.Z, self.Y, self.Xh, 3, 3)
        return (NDIM, self.T, self.Z, self.Y, self.X, 3, 3)


def site_parity(shape: Sequence[int]) -> jnp.ndarray:
    """(T, Z, Y, X) int32 array of site parities (0 = even)."""
    T, Z, Y, X = shape
    t = jnp.arange(T).reshape(T, 1, 1, 1)
    z = jnp.arange(Z).reshape(1, Z, 1, 1)
    y = jnp.arange(Y).reshape(1, 1, Y, 1)
    x = jnp.arange(X).reshape(1, 1, 1, X)
    return (t + z + y + x) % 2


def row_parity(shape: Sequence[int], trailing_dims: int = 0) -> jnp.ndarray:
    """``(t + z + y) % 2`` per x-row, shaped ``(T, Z, Y, 1, *1s)``.

    This is the parity that decides the even-odd x-shift pattern (the
    predicate of the paper's ``sel`` instruction, Fig. 5).  The result
    broadcasts against an even/odd array ``(T, Z, Y, Xh, ...)`` when
    ``trailing_dims`` extra singleton axes are appended.
    """
    T, Z, Y = shape[0], shape[1], shape[2]
    t = jnp.arange(T).reshape(T, 1, 1)
    z = jnp.arange(Z).reshape(1, Z, 1)
    y = jnp.arange(Y).reshape(1, 1, Y)
    par = (t + z + y) % 2
    par = par[..., None]  # x axis
    for _ in range(trailing_dims):
        par = par[..., None]
    return par


def shift(field: jnp.ndarray, mu: int, direction: int) -> jnp.ndarray:
    """Periodic shift of a full-lattice field.

    ``direction=+1`` returns ``field(x + mu_hat)`` (forward neighbor),
    ``direction=-1`` returns ``field(x - mu_hat)``.
    """
    axis = AXIS_OF_MU[mu]
    return jnp.roll(field, -direction, axis=axis)
