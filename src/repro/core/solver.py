"""Iterative Krylov solvers for the even-odd preconditioned Wilson system.

All solvers are matrix-free (take a linear-operator callable), run under
``lax.while_loop`` so they jit/pjit cleanly, and treat pytrees of complex
arrays as vectors.  CGNR (CG on the normal equations) is the robust
workhorse for the non-Hermitian ``Dhat``; BiCGStab is the faster
alternative the paper's solver stack (QWS) uses in practice.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def _vdot(a, b):
    leaves_a, leaves_b = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(x, y) for x, y in zip(leaves_a, leaves_b))


def _axpy(alpha, x, y):
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def _scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def _norm2(x):
    return _vdot(x, x).real


class SolveResult(NamedTuple):
    x: jax.Array
    iterations: jnp.ndarray
    residual: jnp.ndarray      # relative residual |r| / |b|
    converged: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    tol: float = 1e-6
    max_iters: int = 1000
    # Check-pointed restart support: residual recomputed from scratch
    # every ``recompute_every`` iterations to bound drift (0 = never).
    recompute_every: int = 0


def cg(op: Callable, b, x0=None, *, tol: float = 1e-6, max_iters: int = 1000,
       recompute_every: int = 0) -> SolveResult:
    """Conjugate gradients for a Hermitian positive-definite ``op``.

    ``recompute_every > 0`` replaces the recursively-updated residual
    with the true residual ``b - op(x)`` every that many iterations
    (inside the ``while_loop``), bounding floating-point drift on long
    solves (0 = never).
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    p = r
    rr = _norm2(r)
    b2 = _norm2(b)
    tol2 = (tol * tol) * b2

    def cond(state):
        _, _, _, rr, k = state
        return jnp.logical_and(rr > tol2, k < max_iters)

    def body(state):
        x, r, p, rr, k = state
        ap = op(p)
        alpha = rr / _vdot(p, ap).real
        x = _axpy(alpha, p, x)
        r = _axpy(-alpha, ap, r)
        if recompute_every:
            r = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r, x)
        rr_new = _norm2(r)
        beta = rr_new / rr
        p = _axpy(beta, p, r)
        return x, r, p, rr_new, k + 1

    x, r, p, rr, k = jax.lax.while_loop(cond, body, (x, r, p, rr, jnp.int32(0)))
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return SolveResult(x, k, rel, rel <= tol)


def cgnr(op: Callable, op_dag: Callable, b, x0=None, *,
         tol: float = 1e-6, max_iters: int = 1000,
         recompute_every: int = 0) -> SolveResult:
    """CG on the normal equations ``op^dag op x = op^dag b``."""
    bn = op_dag(b)

    def normal(v):
        return op_dag(op(v))

    res = cg(normal, bn, x0, tol=tol, max_iters=max_iters,
             recompute_every=recompute_every)
    # Report the true residual of the original system.
    r = _axpy(-1.0, op(res.x), b)
    rel = jnp.sqrt(_norm2(r) / jnp.maximum(_norm2(b), 1e-30))
    return SolveResult(res.x, res.iterations, rel, rel <= tol * 10)


def bicgstab(op: Callable, b, x0=None, *, tol: float = 1e-6,
             max_iters: int = 1000, recompute_every: int = 0) -> SolveResult:
    """BiCGStab for general (non-Hermitian) ``op``.

    Works on any pytree vector domain: the Krylov scalars take the dtype
    of ``<b, b>`` (complex for complex spinors, real for planar-native
    vectors, where the operator is the real representation of ``Dhat``).
    ``recompute_every`` as in :func:`cg` (reliable-updates style
    true-residual replacement).
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    r0 = r
    one = jnp.ones((), dtype=_vdot(b, b).dtype)
    rho = alpha = omega = one
    v = p = _scale(0.0, b)
    b2 = _norm2(b)
    tol2 = (tol * tol) * b2

    def cond(state):
        _, r, *_, k = state
        return jnp.logical_and(_norm2(r) > tol2, k < max_iters)

    def body(state):
        x, r, p, v, rho, alpha, omega, k = state
        rho_new = _vdot(r0, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = _axpy(beta, _axpy(-omega, v, p), r)
        v = op(p)
        alpha = rho_new / _vdot(r0, v)
        s = _axpy(-alpha, v, r)
        t = op(s)
        omega = _vdot(t, s) / _vdot(t, t)
        x = _axpy(alpha, p, _axpy(omega, s, x))
        r = _axpy(-omega, t, s)
        if recompute_every:
            r = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r, x)
        return x, r, p, v, rho_new, alpha, omega, k + 1

    state = (x, r, p, v, rho, alpha, omega, jnp.int32(0))
    x, r, *_, k = jax.lax.while_loop(cond, body, state)
    rel = jnp.sqrt(_norm2(r) / jnp.maximum(b2, 1e-30))
    return SolveResult(x, k, rel, rel <= tol)


def _run_krylov(method: str, dhat, dhat_dag, rhs, *, tol, max_iters,
                recompute_every):
    if method == "cgnr":
        return cgnr(dhat, dhat_dag, rhs, tol=tol, max_iters=max_iters,
                    recompute_every=recompute_every)
    if method == "bicgstab":
        return bicgstab(dhat, rhs, tol=tol, max_iters=max_iters,
                        recompute_every=recompute_every)
    raise ValueError(f"unknown method {method!r}")


def solve_wilson_eo(U_e, U_o, eta_e, eta_o, kappa, *, method: str = "cgnr",
                    tol: float = 1e-6, max_iters: int = 2000,
                    recompute_every: int = 0, config: SolverConfig = None,
                    apply_dhat_fn=None, apply_dhat_dag_fn=None,
                    hop_oe_fn=None, hop_eo_fn=None,
                    backend=None, backend_opts=None):
    """Solve ``D_W xi = eta`` via the even-odd Schur system (Eqs. 4-5).

    Returns ``(xi_e, xi_o, SolveResult)``.  For the Wilson matrix
    ``D_ee = D_oo = 1`` so the reconstruction is Eq. (5) with trivial
    inverses.

    The operator implementation is chosen by ``backend`` — a name from
    :mod:`repro.backends` (``"jnp"``, ``"pallas"``, ``"pallas_fused"``,
    ``"distributed"``; ``backend_opts`` are forwarded to the factory) or
    an already-bound :class:`repro.backends.WilsonOps` (so callers
    solving repeatedly against one gauge field bind once, keeping jit
    caches and the planarized gauge warm across solves).

    With a backend, the whole Krylov iteration runs in the backend's
    *native* vector domain: the sources are encoded once via
    ``bops.to_domain``, every iteration applies the native operators
    (planar, sharded-planar, ...) with zero per-iteration layout
    conversion or device placement, and the solution is decoded once at
    exit.  Explicitly passed ``*_fn`` callables win over the backend and
    keep the old complex-interface hand-wiring (and its per-call
    conversion cost) available.

    ``config`` (a :class:`SolverConfig`) supplies ``tol`` / ``max_iters``
    / ``recompute_every`` in one object; individual keywords are ignored
    when it is given.
    """
    from . import evenodd  # local import to avoid cycle
    from repro import backends as backends_lib  # avoid import cycle

    if config is not None:
        tol, max_iters = config.tol, config.max_iters
        recompute_every = config.recompute_every

    explicit = (apply_dhat_fn or apply_dhat_dag_fn
                or hop_oe_fn or hop_eo_fn)
    bops = None
    if backend is not None:
        bops = (backend if isinstance(backend, backends_lib.WilsonOps)
                else backends_lib.make_wilson_ops(
                    backend, U_e, U_o, **(backend_opts or {})))
    if explicit or bops is None:
        # Legacy hand-wiring: synthesize an identity-domain WilsonOps
        # from the explicit *_fn callables (falling back to the backend's
        # complex interface, then to the evenodd reference ops), so both
        # wirings run through the one solve implementation below.
        if bops is not None:
            cops = bops
            hop_oe_fn = hop_oe_fn or (lambda ue, uo, p: cops.hop_oe(p))
            hop_eo_fn = hop_eo_fn or (lambda ue, uo, p: cops.hop_eo(p))
            apply_dhat_fn = apply_dhat_fn or (
                lambda v: cops.apply_dhat(v, kappa))
            apply_dhat_dag_fn = apply_dhat_dag_fn or (
                lambda v: cops.apply_dhat_dagger(v, kappa))
        hop_oe_fn = hop_oe_fn or evenodd.hop_oe
        hop_eo_fn = hop_eo_fn or evenodd.hop_eo
        dhat = apply_dhat_fn or (lambda v: evenodd.apply_dhat(
            U_e, U_o, v, kappa, hop_oe_fn, hop_eo_fn))
        dhat_dag = apply_dhat_dag_fn or (
            lambda v: evenodd.apply_dhat_dagger(
                U_e, U_o, v, kappa, hop_oe_fn, hop_eo_fn))
        bops = backends_lib.WilsonOps(
            backend="explicit",
            hop_oe=lambda p: hop_oe_fn(U_e, U_o, p),
            hop_eo=lambda p: hop_eo_fn(U_e, U_o, p),
            apply_dhat=lambda v, _k: dhat(v),
            apply_dhat_dagger=lambda v, _k: dhat_dag(v))

    # Encode once, iterate in the backend's native domain, decode once.
    v_e, v_o = bops.to_domain(eta_e), bops.to_domain(eta_o)
    # RHS of Eq. (4): eta_e + kappa * H_eo eta_o  (D_eo = -kappa H_eo).
    rhs = _axpy(kappa, bops.hop_eo_native(v_o), v_e)
    res = _run_krylov(
        method,
        lambda v: bops.apply_dhat_native(v, kappa),
        lambda v: bops.apply_dhat_dagger_native(v, kappa),
        rhs, tol=tol, max_iters=max_iters,
        recompute_every=recompute_every)
    # Eq. (5): xi_o = eta_o + kappa * H_oe xi_e.
    v_xi_o = _axpy(kappa, bops.hop_oe_native(res.x), v_o)
    # Decode keeps the callers' spinor dtype (complex128 under x64).
    xi_e = bops.from_domain(res.x).astype(eta_e.dtype)
    xi_o = bops.from_domain(v_xi_o).astype(eta_o.dtype)
    return xi_e, xi_o, res._replace(x=xi_e)
