"""Iterative Krylov solvers for the even-odd preconditioned Wilson system.

All solvers are matrix-free (take a linear-operator callable), run under
``lax.while_loop`` so they jit/pjit cleanly, and treat pytrees of complex
arrays as vectors.  CGNR (CG on the normal equations) is the robust
workhorse for the non-Hermitian ``Dhat``; BiCGStab is the faster
alternative the paper's solver stack (QWS) uses in practice.

Two production features beyond the single-RHS f32 path:

* **Multi-RHS batching** — ``cg_batched`` / ``cgnr_batched`` /
  ``bicgstab_batched`` iterate a whole block of right-hand sides (leading
  ``nrhs`` axis) through ONE batched operator application per iteration,
  with *per-column* Krylov scalars and a per-column convergence mask:
  converged columns freeze (their updates are zeroed) while the loop runs
  until every column converged or ``max_iters``.
* **Mixed-precision iterative refinement** — :func:`make_refined_solve`
  (``SolveSpec(inner_dtype="f32")`` through the public API) runs the
  Krylov iteration in a cheap inner dtype (f32 default, bf16 optional)
  and wraps it in an f64 outer loop: true residual recomputed in f64,
  correction solved in the inner dtype, repeat until the *f64* tolerance
  is met.  The expensive f64 operator is applied once per outer pass
  instead of twice per Krylov iteration — the QWS / Kanamori-Matsufuru
  single-precision-inner strategy.
* **Compensated (f32-accumulate) reductions** — Krylov scalars of bf16
  vector domains are accumulated in f32 and cast back down at the axpy
  (see :data:`COMPENSATED_REDUCTIONS`), so ``inner_dtype="bf16"``
  converges at ``inner_tol`` values where naive bf16 accumulation
  stalls on saturated norms.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


# Krylov scalars (<a,b>, |r|^2, alpha/beta/rho/omega) of sub-f32 vector
# domains (bf16 planar vectors of the mixed-precision inner solve)
# accumulate in f32.  A naive bf16 sum saturates once the partial sum
# reaches ~256 x the element magnitude (half-ulp rounding swallows every
# further term), so |b|^2 of a few-thousand-element vector is off by an
# order of magnitude and alpha/beta turn to noise — the solve stalls.
# f32 accumulation fixes the scalars while the vectors (and all the
# bandwidth-heavy operator work) stay bf16: the scalars are cast back to
# the leaf dtype at the axpy, never promoting the iterate.  Module-level
# so tests can flip it to demonstrate the stall.
COMPENSATED_REDUCTIONS = True

_LOW_PRECISION = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16))


def _acc(x):
    """Upcast a sub-f32 leaf to the f32 accumulation dtype (no-op for
    f32/f64/complex leaves, or with compensation disabled)."""
    if COMPENSATED_REDUCTIONS and x.dtype in _LOW_PRECISION:
        return x.astype(jnp.float32)
    return x


def _apply_scalar(alpha, leaf):
    """``alpha`` ready to multiply ``leaf`` without promoting it: an f32
    Krylov scalar meeting a bf16 leaf is cast *down* (bf16 stays the
    vector dtype; the scalar was merely accumulated more accurately)."""
    if not hasattr(alpha, "astype") or not hasattr(leaf, "dtype"):
        return alpha
    if jnp.result_type(alpha.dtype, leaf.dtype) != jnp.dtype(leaf.dtype):
        return alpha.astype(leaf.dtype)
    return alpha


def _vdot(a, b):
    leaves_a, leaves_b = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(_acc(x), _acc(y)) for x, y in zip(leaves_a, leaves_b))


def _axpy(alpha, x, y):
    return jax.tree_util.tree_map(
        lambda xi, yi: _apply_scalar(alpha, yi) * xi + yi, x, y)


def _scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def _norm2(x):
    return _vdot(x, x).real


# --- per-column (batched) vector algebra; leading axis = RHS index ------

def _bvdot(a, b):
    """Per-column ``<a, b>``: reduces every axis but the leading one
    (f32-accumulated for sub-f32 leaves, like :func:`_vdot`)."""
    leaves_a, leaves_b = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    out = None
    for x, y in zip(leaves_a, leaves_b):
        x, y = _acc(x), _acc(y)
        s = jnp.sum((jnp.conj(x) * y).reshape(x.shape[0], -1), axis=1)
        out = s if out is None else out + s
    return out


def _bnorm2(x):
    return _bvdot(x, x).real


def _bb(alpha, leaf):
    """Broadcast a per-column scalar ``(nrhs,)`` against a leaf."""
    return alpha.reshape(alpha.shape + (1,) * (leaf.ndim - 1))


def _baxpy(alpha, x, y):
    """``y + alpha * x`` with a per-column ``alpha`` (cast down to the
    leaf dtype so an f32-accumulated scalar never promotes the batch)."""
    return jax.tree_util.tree_map(
        lambda xi, yi: _bb(_apply_scalar(alpha, xi), xi) * xi + yi, x, y)


def _tiny(dtype):
    """Breakdown threshold: far below any meaningful Krylov scalar but
    above the denormal underflow that poisons the division chain."""
    real = jnp.finfo(jnp.zeros((), dtype).real.dtype)
    return real.tiny ** 0.5


def _nz(d, tiny):
    """Guard a denominator: the quotient is only *consumed* where
    ``|d| > tiny``, but a 0/0 in a dead lane would still produce a NaN
    that survives the masking multiply (``NaN * 0 = NaN``) — replace
    dead-lane denominators with 1 so every division is finite."""
    return jnp.where(jnp.abs(d) > tiny, d, jnp.ones_like(d))


class SolveResult(NamedTuple):
    x: jax.Array
    iterations: jnp.ndarray
    residual: jnp.ndarray      # relative residual |r| / |b|
    converged: jnp.ndarray


class RefinedResult(NamedTuple):
    """Result of a mixed-precision (iterative-refinement) solve.

    First four fields match :class:`SolveResult` so existing callers
    duck-type; the extras quantify the precision split: ``f64_applies``
    counts applications of the f64 operator (the pure-f64 solve pays
    ~2 per Krylov iteration; refinement pays 1 per outer pass), and
    ``inner_iterations`` the total inner-dtype Krylov iterations.
    """
    x: jax.Array
    iterations: jnp.ndarray
    residual: jnp.ndarray
    converged: jnp.ndarray
    outer_iterations: int
    f64_applies: int
    inner_iterations: int


def cg(op: Callable, b, x0=None, *, tol: float = 1e-6, max_iters: int = 1000,
       recompute_every: int = 0) -> SolveResult:
    """Conjugate gradients for a Hermitian positive-definite ``op``.

    ``recompute_every > 0`` replaces the recursively-updated residual
    with the true residual ``b - op(x)`` every that many iterations
    (inside the ``while_loop``), bounding floating-point drift on long
    solves (0 = never).
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    p = r
    rr = _norm2(r)
    b2 = _norm2(b)
    tiny = _tiny(rr.dtype)
    tol2 = (tol * tol) * b2

    def cond(state):
        _, _, _, rr, good, k = state
        return jnp.logical_and(
            jnp.logical_and(rr > tol2, k < max_iters), good)

    def body(state):
        x, r, p, rr, good, k = state
        ap = op(p)
        pap = _vdot(p, ap).real
        # Breakdown guard: pap ~ 0 (numerically nullspace direction)
        # would scale the update by garbage — freeze and exit instead.
        ok = pap > tiny
        alpha = jnp.where(ok, rr / _nz(pap, tiny), 0.0)
        x = _axpy(alpha, p, x)
        r = _axpy(-alpha, ap, r)
        if recompute_every:
            r = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r, x)
        rr_new = _norm2(r)
        beta = rr_new / rr
        p = _axpy(beta, p, r)
        return x, r, p, rr_new, ok, k + 1

    state = (x, r, p, rr, jnp.bool_(True), jnp.int32(0))
    x, r, p, rr, good, k = jax.lax.while_loop(cond, body, state)
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return SolveResult(x, k, rel, rel <= tol)


def cg_batched(op: Callable, b, x0=None, *, tol: float = 1e-6,
               max_iters: int = 1000,
               recompute_every: int = 0) -> SolveResult:
    """Batched CG: one operator application per iteration for the whole
    RHS block, per-column scalars, per-column convergence freezing.

    A column whose residual reaches tolerance has its updates zeroed
    (``alpha = beta = 0``) from then on — its ``x``/``r`` are frozen
    bit-exactly while the remaining columns keep iterating.  Returns
    per-column ``iterations`` / ``residual`` / ``converged``.
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = b if x0 is None else _axpy(-1.0, op(x), b)
    p = r
    rr = _bnorm2(r)
    b2 = _bnorm2(b)
    tiny = _tiny(rr.dtype)
    tol2 = (tol * tol) * b2
    active = rr > tol2
    iters = jnp.zeros(rr.shape, jnp.int32)

    def cond(state):
        *_, active, _, k = state
        return jnp.logical_and(jnp.any(active), k < max_iters)

    def body(state):
        x, r, p, rr, active, iters, k = state
        ap = op(p)
        pap = _bvdot(p, ap).real
        # Breakdown guard: a (numerically) nullspace search direction
        # gives pap ~ 0 — freeze that column instead of scaling by a
        # garbage alpha (mirrors the bicgstab guards).
        ok = jnp.logical_and(active, pap > tiny)
        af = ok.astype(rr.dtype)
        alpha = af * rr / _nz(pap, tiny)
        x = _baxpy(alpha, p, x)
        r = _baxpy(-alpha, ap, r)
        if recompute_every:
            r = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r, x)
        rr_new = _bnorm2(r)
        beta = af * rr_new / _nz(rr, tiny)
        p = _baxpy(beta, p, r)
        active_new = jnp.logical_and(ok, rr_new > tol2)
        leaving = jnp.logical_and(active, jnp.logical_not(active_new))
        iters = jnp.where(leaving, k + 1, iters)
        return x, r, p, rr_new, active_new, iters, k + 1

    state = (x, r, p, rr, active, iters, jnp.int32(0))
    x, r, p, rr, active, iters, k = jax.lax.while_loop(cond, body, state)
    iters = jnp.where(active, k, iters)      # unconverged: ran to the end
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return SolveResult(x, iters, rel, rel <= tol)


def cgnr(op: Callable, op_dag: Callable, b, x0=None, *,
         tol: float = 1e-6, max_iters: int = 1000,
         recompute_every: int = 0) -> SolveResult:
    """CG on the normal equations ``op^dag op x = op^dag b``."""
    bn = op_dag(b)

    def normal(v):
        return op_dag(op(v))

    res = cg(normal, bn, x0, tol=tol, max_iters=max_iters,
             recompute_every=recompute_every)
    # Report the true residual of the original system.
    r = _axpy(-1.0, op(res.x), b)
    rel = jnp.sqrt(_norm2(r) / jnp.maximum(_norm2(b), 1e-30))
    return SolveResult(res.x, res.iterations, rel, rel <= tol * 10)


def cgnr_batched(op: Callable, op_dag: Callable, b, x0=None, *,
                 tol: float = 1e-6, max_iters: int = 1000,
                 recompute_every: int = 0) -> SolveResult:
    """Batched CGNR; per-column true residuals of the original system."""
    bn = op_dag(b)

    def normal(v):
        return op_dag(op(v))

    res = cg_batched(normal, bn, x0, tol=tol, max_iters=max_iters,
                     recompute_every=recompute_every)
    r = _axpy(-1.0, op(res.x), b)
    rel = jnp.sqrt(_bnorm2(r) / jnp.maximum(_bnorm2(b), 1e-30))
    return SolveResult(res.x, res.iterations, rel, rel <= tol * 10)


def bicgstab(op: Callable, b, x0=None, *, tol: float = 1e-6,
             max_iters: int = 1000, recompute_every: int = 0) -> SolveResult:
    """BiCGStab for general (non-Hermitian) ``op``.

    Works on any pytree vector domain: the Krylov scalars take the dtype
    of ``<b, b>`` (complex for complex spinors, real for planar-native
    vectors, where the operator is the real representation of ``Dhat``).
    ``recompute_every`` as in :func:`cg` (reliable-updates style
    true-residual replacement).

    Breakdown guards: BiCGStab's recurrence divides by ``rho``,
    ``<r0, v>`` and ``<t, t>`` (via ``omega``); any of them underflowing
    would turn the whole state into NaN inside the ``while_loop``.  Each
    is checked against a tiny threshold — on breakdown the update scalars
    are zeroed (state freezes at the last good iterate), the loop exits,
    and the result honestly reports the frozen residual with
    ``converged=False`` instead of NaN.
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    r0 = r
    one = jnp.ones((), dtype=_vdot(b, b).dtype)
    tiny = _tiny(one.dtype)
    rho = alpha = omega = one
    v = p = _scale(0.0, b)
    b2 = _norm2(b)
    tol2 = (tol * tol) * b2

    def cond(state):
        _, r, *_, good, k = state
        return jnp.logical_and(
            jnp.logical_and(_norm2(r) > tol2, k < max_iters), good)

    def body(state):
        x, r, p, v, rho, alpha, omega, good, k = state
        rho_new = _vdot(r0, r)
        ok = jnp.logical_and(jnp.abs(rho_new) > tiny,
                             jnp.logical_and(jnp.abs(rho) > tiny,
                                             jnp.abs(omega) > tiny))
        okc = ok.astype(rho_new.dtype)
        beta = okc * (rho_new / _nz(rho, tiny)) * (alpha / _nz(omega, tiny))
        p = _axpy(beta, _axpy(-omega, v, p), r)
        v = op(p)
        r0v = _vdot(r0, v)
        ok = jnp.logical_and(ok, jnp.abs(r0v) > tiny)
        okc = ok.astype(rho_new.dtype)
        alpha_new = okc * rho_new / _nz(r0v, tiny)
        s = _axpy(-alpha_new, v, r)
        t = op(s)
        tt = _vdot(t, t).real
        ok = jnp.logical_and(ok, tt > tiny)
        okc = ok.astype(rho_new.dtype)
        omega_new = okc * _vdot(t, s) / _nz(tt, tiny).astype(rho_new.dtype)
        x = _axpy(alpha_new, p, _axpy(omega_new, s, x))
        r = _axpy(-omega_new, t, s)
        if recompute_every:
            r = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r, x)
        return x, r, p, v, rho_new, alpha_new, omega_new, ok, k + 1

    state = (x, r, p, v, rho, alpha, omega, jnp.bool_(True), jnp.int32(0))
    x, r, *_, k = jax.lax.while_loop(cond, body, state)
    rel = jnp.sqrt(_norm2(r) / jnp.maximum(b2, 1e-30))
    return SolveResult(x, k, rel, rel <= tol)


def bicgstab_batched(op: Callable, b, x0=None, *, tol: float = 1e-6,
                     max_iters: int = 1000,
                     recompute_every: int = 0) -> SolveResult:
    """Batched BiCGStab with per-column convergence AND breakdown masks.

    Converged columns freeze (scalars zeroed, iterate kept bit-exact);
    broken-down columns freeze the same way but stay unconverged —
    ``converged[j] = False`` for them instead of a NaN-poisoned batch.
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = b if x0 is None else _axpy(-1.0, op(x), b)
    r0 = r
    sdtype = _bvdot(b, b).dtype
    tiny = _tiny(sdtype)
    n = jax.tree_util.tree_leaves(b)[0].shape[0]
    one = jnp.ones((n,), dtype=sdtype)
    rho = alpha = omega = one
    v = p = _scale(0.0, b)
    b2 = _bnorm2(b)
    tol2 = (tol * tol) * b2
    active = _bnorm2(r) > tol2
    iters = jnp.zeros((n,), jnp.int32)

    def cond(state):
        *_, active, _, k = state
        return jnp.logical_and(jnp.any(active), k < max_iters)

    def body(state):
        x, r, p, v, rho, alpha, omega, active, iters, k = state
        rho_new = _bvdot(r0, r)
        ok = jnp.logical_and(
            active,
            jnp.logical_and(jnp.abs(rho_new) > tiny,
                            jnp.logical_and(jnp.abs(rho) > tiny,
                                            jnp.abs(omega) > tiny)))
        okc = ok.astype(sdtype)
        beta = okc * (rho_new / _nz(rho, tiny)) * (alpha / _nz(omega, tiny))
        # Frozen columns get beta = 0 -> p := r (harmless: their alpha /
        # omega below are 0, so x and r never move again).
        p = _baxpy(beta, _baxpy(-omega * okc, v, p), r)
        v = op(p)
        r0v = _bvdot(r0, v)
        ok = jnp.logical_and(ok, jnp.abs(r0v) > tiny)
        okc = ok.astype(sdtype)
        alpha_new = okc * rho_new / _nz(r0v, tiny)
        s = _baxpy(-alpha_new, v, r)
        t = op(s)
        tt = _bvdot(t, t).real
        ok = jnp.logical_and(ok, tt > tiny)
        okc = ok.astype(sdtype)
        omega_new = okc * _bvdot(t, s) / _nz(tt, tiny).astype(sdtype)
        x = _baxpy(alpha_new, p, _baxpy(omega_new, s, x))
        r = _baxpy(-omega_new, t, s)
        if recompute_every:
            r = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r, x)
        rr = _bnorm2(r)
        # Columns that broke down this iteration (ok went False while
        # still active and unconverged) freeze too: drop them from the
        # active set so the loop can terminate for the rest.  Either way
        # of leaving the active set records the iteration it happened at.
        active_new = jnp.logical_and(ok, rr > tol2)
        leaving = jnp.logical_and(active, jnp.logical_not(active_new))
        iters = jnp.where(leaving, k + 1, iters)
        return (x, r, p, v, rho_new, alpha_new, omega_new, active_new,
                iters, k + 1)

    state = (x, r, p, v, rho, alpha, omega, active, iters, jnp.int32(0))
    x, r, *_, active, iters, k = jax.lax.while_loop(cond, body, state)
    iters = jnp.where(active, k, iters)
    rel = jnp.sqrt(_bnorm2(r) / jnp.maximum(b2, 1e-30))
    return SolveResult(x, iters, rel, rel <= tol)


# Krylov methods valid on the (non-Hermitian) even-odd Schur system.
# "cg" is plain CG run on the normal equations Dhat^dag Dhat x =
# Dhat^dag rhs — the same system "cgnr" solves, minus cgnr's final
# true-residual recomputation of the original system (one op + one
# op_dag cheaper; its reported residual is the normal-equation one).
# repro.api.SolveSpec derives its method choices (and the CLI's
# --method list) from this tuple — extend HERE, not in the CLI.
KRYLOV_METHODS = ("cg", "cgnr", "bicgstab")


def _run_krylov(method: str, dhat, dhat_dag, rhs, *, tol, max_iters,
                recompute_every, batched: bool = False):
    if method == "cg":
        fn = cg_batched if batched else cg

        def normal(v):
            return dhat_dag(dhat(v))

        return fn(normal, dhat_dag(rhs), tol=tol, max_iters=max_iters,
                  recompute_every=recompute_every)
    if method == "cgnr":
        fn = cgnr_batched if batched else cgnr
        return fn(dhat, dhat_dag, rhs, tol=tol, max_iters=max_iters,
                  recompute_every=recompute_every)
    if method == "bicgstab":
        fn = bicgstab_batched if batched else bicgstab
        return fn(dhat, rhs, tol=tol, max_iters=max_iters,
                  recompute_every=recompute_every)
    raise ValueError(
        f"unknown method {method!r}; choose from {KRYLOV_METHODS}")


_INNER_DTYPES = {
    "f32": jnp.float32, "float32": jnp.float32,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
}


def resolve_inner_dtype(inner_dtype):
    """Map an inner-dtype spelling (``"f32"``/``"bf16"``/...) or dtype to
    the jnp dtype; the single source of truth the CLI reuses too."""
    if isinstance(inner_dtype, str):
        try:
            return _INNER_DTYPES[inner_dtype.lower()]
        except KeyError:
            raise ValueError(
                f"unknown inner_dtype {inner_dtype!r}; "
                f"choose from {sorted(set(_INNER_DTYPES))}") from None
    return jnp.dtype(inner_dtype).type


def make_native_solve(bops, kappa, *, method: str = "cgnr",
                      tol: float = 1e-6, max_iters: int = 2000,
                      recompute_every: int = 0, batched: bool = False):
    """Build the native-domain Schur-solve pipeline for a bound operator.

    Returns ``fn(v_e, v_o) -> (x, v_xi_o, SolveResult)`` working entirely
    on native vectors of ``bops`` (no encode/decode, no placement): the
    Eq. (4) RHS build, the Krylov iteration, and the Eq. (5) odd
    reconstruction.  The returned function is side-effect free and
    jit-compatible — :class:`repro.api.SolveSession` wraps it in ``jax.jit``
    once per ``(SolveSpec, rhs shape)`` key, which is what makes the
    second and every later same-shape solve skip tracing entirely.
    """
    if batched:
        hop_eo_nat = bops.hop_eo_native_batched
        hop_oe_nat = bops.hop_oe_native_batched
        dhat_nat = bops.apply_dhat_native_batched
        dhat_dag_nat = bops.apply_dhat_dagger_native_batched
    else:
        hop_eo_nat, hop_oe_nat = bops.hop_eo_native, bops.hop_oe_native
        dhat_nat = bops.apply_dhat_native
        dhat_dag_nat = bops.apply_dhat_dagger_native

    def solve_native(v_e, v_o):
        # RHS of Eq. (4): eta_e + kappa * H_eo eta_o  (D_eo = -kappa H_eo).
        rhs = _axpy(kappa, hop_eo_nat(v_o), v_e)
        res = _run_krylov(
            method,
            lambda v: dhat_nat(v, kappa),
            lambda v: dhat_dag_nat(v, kappa),
            rhs, tol=tol, max_iters=max_iters,
            recompute_every=recompute_every, batched=batched)
        # Eq. (5): xi_o = eta_o + kappa * H_oe xi_e.
        v_xi_o = _axpy(kappa, hop_oe_nat(res.x), v_o)
        return res.x, v_xi_o, res

    return solve_native


def make_refined_solve(bops, U64_e, U64_o, kappa, *, method: str = "cgnr",
                       tol: float = 1e-10, max_iters: int = 2000,
                       recompute_every: int = 0, inner_tol: float = 1e-4,
                       max_outer: int = 25, batched: bool = False):
    """Build a reusable mixed-precision iterative-refinement solve.

    ``bops`` is the *inner* backend, already bound at the cheap inner
    dtype; ``U64_e`` / ``U64_o`` is the gauge for the f64 reference
    operator (upcast to complex128 here).  The f64 operator and hops are
    jitted **once at build time**, so a caller holding the returned
    ``fn(eta_e, eta_o) -> (xi_e, xi_o, RefinedResult)`` (e.g. a
    :class:`repro.api.SolveSession` cache entry) pays the f64 traces on
    the first solve only.  The outer loop itself is Python-level — a
    handful of passes with data-dependent exit — so it is rebuilt per
    call by design; the expensive pieces (f64 operator, inner Krylov
    ``while_loop``) reuse their jit caches across calls.

    Outer loop: f64 true residual of ``Dhat x = rhs``, then a correction
    solve ``Dhat e = r`` in the inner dtype through ``bops``'s native
    domain, ``x += e``, until the **f64** relative residual meets
    ``tol``.  The f64 operator is applied once per outer pass — versus
    ~2 per Krylov iteration for a pure-f64 solve — and all the
    bandwidth-hungry iterating happens at the inner dtype's traffic.
    """
    from . import evenodd

    if jnp.zeros((), jnp.float64).dtype != jnp.dtype(jnp.float64):
        raise ValueError(
            "mixed-precision refinement needs float64 for the outer "
            "residual: enable x64 (jax.config.update('jax_enable_x64', "
            "True) or the jax.experimental.enable_x64 context)")

    U64_e = U64_e.astype(jnp.complex128)
    U64_o = U64_o.astype(jnp.complex128)

    def _maybe_vmap(fn):
        return jax.vmap(fn) if batched else fn

    dhat64 = jax.jit(_maybe_vmap(
        lambda v: evenodd.apply_dhat(U64_e, U64_o, v, kappa)))
    hop_eo64 = jax.jit(_maybe_vmap(
        lambda v: evenodd.hop_eo(U64_e, U64_o, v)))
    hop_oe64 = jax.jit(_maybe_vmap(
        lambda v: evenodd.hop_oe(U64_e, U64_o, v)))

    if batched:
        to_dom, from_dom = bops.to_domain_batched, bops.from_domain_batched
        dhat_nat = bops.apply_dhat_native_batched
        dhat_dag_nat = bops.apply_dhat_dagger_native_batched
    else:
        to_dom, from_dom = bops.to_domain, bops.from_domain
        dhat_nat = bops.apply_dhat_native
        dhat_dag_nat = bops.apply_dhat_dagger_native

    bnorm = _bnorm2 if batched else _norm2

    def refined(eta_e, eta_o):
        eta64_e = eta_e.astype(jnp.complex128)
        eta64_o = eta_o.astype(jnp.complex128)
        rhs64 = eta64_e + kappa * hop_eo64(eta64_o)
        f64_applies = 1  # the hop above
        b2 = bnorm(rhs64)

        x64 = jnp.zeros_like(rhs64)
        inner_iters = 0
        # Per-column (batched) / scalar (unbatched) total inner
        # iterations, matching the batched SolveResult contract
        # RefinedResult duck-types.
        iters_acc = jnp.zeros(b2.shape, jnp.int32)
        outer = 0
        rel = None
        for outer in range(1, max_outer + 1):
            r64 = rhs64 - dhat64(x64)
            f64_applies += 1
            rel = jnp.sqrt(bnorm(r64) / jnp.maximum(b2, 1e-300))
            if bool(jnp.all(rel <= tol)):
                break
            # Correction solve in the inner dtype, native domain.
            v = to_dom(r64.astype(jnp.complex64))
            res = _run_krylov(
                method,
                lambda w: dhat_nat(w, kappa),
                lambda w: dhat_dag_nat(w, kappa),
                v, tol=inner_tol, max_iters=max_iters,
                recompute_every=recompute_every, batched=batched)
            x64 = x64 + from_dom(res.x).astype(jnp.complex128)
            iters_acc = iters_acc + res.iterations.astype(jnp.int32)
            inner_iters += int(jnp.max(res.iterations))
        else:
            # Outer budget exhausted: report the residual of the final
            # iterate, not the one from before the last correction.
            r64 = rhs64 - dhat64(x64)
            f64_applies += 1
            rel = jnp.sqrt(bnorm(r64) / jnp.maximum(b2, 1e-300))
        converged = rel <= tol

        xi_o64 = eta64_o + kappa * hop_oe64(x64)
        f64_applies += 1
        xi_e = x64.astype(eta_e.dtype)
        xi_o = xi_o64.astype(eta_o.dtype)
        return xi_e, xi_o, RefinedResult(
            x=xi_e, iterations=iters_acc, residual=rel,
            converged=converged, outer_iterations=outer,
            f64_applies=f64_applies, inner_iterations=inner_iters)

    return refined
