"""Iterative Krylov solvers for the even-odd preconditioned Wilson system.

All solvers are matrix-free (take a linear-operator callable), run under
``lax.while_loop`` so they jit/pjit cleanly, and treat pytrees of complex
arrays as vectors.  CGNR (CG on the normal equations) is the robust
workhorse for the non-Hermitian ``Dhat``; BiCGStab is the faster
alternative the paper's solver stack (QWS) uses in practice.

Two production features beyond the single-RHS f32 path:

* **Multi-RHS batching** — ``cg_batched`` / ``cgnr_batched`` /
  ``bicgstab_batched`` iterate a whole block of right-hand sides (leading
  ``nrhs`` axis) through ONE batched operator application per iteration,
  with *per-column* Krylov scalars and a per-column convergence mask:
  converged columns freeze (their updates are zeroed) while the loop runs
  until every column converged or ``max_iters``.
* **Block CG** — :func:`blockcg_batched` (``method="blockcg"``) upgrades
  the batched normal-equations solve from shared operator *traffic* to a
  shared Krylov *space*: small nrhs x nrhs Gram solves mix every search
  direction into every column, cutting iteration count on RHS blocks
  with overlapping spectral content.  Low-mode deflation / recycling of
  repeated solves on one gauge lives in :mod:`repro.core.deflate` and
  plugs in here as a Galerkin initial guess (``deflation=`` in
  :func:`_run_krylov` / ``deflated=`` in :func:`make_native_solve`).
  All normal-equations methods report the TRUE-system relative residual
  at exit (see :func:`_true_system_result` for the metric contract).
* **Mixed-precision iterative refinement** — :func:`make_refined_solve`
  (``SolveSpec(inner_dtype="f32")`` through the public API) runs the
  Krylov iteration in a cheap inner dtype (f32 default, bf16 optional)
  and wraps it in an f64 outer loop: true residual recomputed in f64,
  correction solved in the inner dtype, repeat until the *f64* tolerance
  is met.  The expensive f64 operator is applied once per outer pass
  instead of twice per Krylov iteration — the QWS / Kanamori-Matsufuru
  single-precision-inner strategy.
* **Compensated (f32-accumulate) reductions** — Krylov scalars of bf16
  vector domains are accumulated in f32 and cast back down at the axpy
  (see :data:`COMPENSATED_REDUCTIONS`), so ``inner_dtype="bf16"``
  converges at ``inner_tol`` values where naive bf16 accumulation
  stalls on saturated norms.
* **Divergence guards** (``guard=True``, the default) — every
  ``while_loop`` cond carries a non-finite check on the residual (the
  structural invariant analysis rule J6 asserts), so a poisoned state
  can never buy another iteration, and the loop body freezes a
  non-finite column/solve **bit-exactly** at its last finite iterate
  via ``where``-selects (the alpha-zeroing freeze alone cannot:
  ``0 * NaN = NaN``).  A residual that makes no new minimum for
  ``stagnation_window`` consecutive iterations triggers a
  deterministic restart — the Krylov space is re-seeded from the
  current iterate's true residual — up to ``max_restarts`` times,
  after which the column freezes.  Both paths report through the
  ``diverged`` field of :class:`SolveResult` instead of the old
  silent NaN exit whose ``converged`` came from a NaN comparison.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


# Krylov scalars (<a,b>, |r|^2, alpha/beta/rho/omega) of sub-f32 vector
# domains (bf16 planar vectors of the mixed-precision inner solve)
# accumulate in f32.  A naive bf16 sum saturates once the partial sum
# reaches ~256 x the element magnitude (half-ulp rounding swallows every
# further term), so |b|^2 of a few-thousand-element vector is off by an
# order of magnitude and alpha/beta turn to noise — the solve stalls.
# f32 accumulation fixes the scalars while the vectors (and all the
# bandwidth-heavy operator work) stay bf16: the scalars are cast back to
# the leaf dtype at the axpy, never promoting the iterate.  Module-level
# so tests can flip it to demonstrate the stall.
COMPENSATED_REDUCTIONS = True

_LOW_PRECISION = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16))


def _acc(x):
    """Upcast a sub-f32 leaf to the f32 accumulation dtype (no-op for
    f32/f64/complex leaves, or with compensation disabled)."""
    if COMPENSATED_REDUCTIONS and x.dtype in _LOW_PRECISION:
        return x.astype(jnp.float32)
    return x


def _apply_scalar(alpha, leaf):
    """``alpha`` ready to multiply ``leaf`` without promoting it: an f32
    Krylov scalar meeting a bf16 leaf is cast *down* (bf16 stays the
    vector dtype; the scalar was merely accumulated more accurately)."""
    if not hasattr(alpha, "astype") or not hasattr(leaf, "dtype"):
        return alpha
    if jnp.result_type(alpha.dtype, leaf.dtype) != jnp.dtype(leaf.dtype):
        return alpha.astype(leaf.dtype)
    return alpha


def _vdot(a, b):
    leaves_a, leaves_b = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(_acc(x), _acc(y)) for x, y in zip(leaves_a, leaves_b))


def _axpy(alpha, x, y):
    return jax.tree_util.tree_map(
        lambda xi, yi: _apply_scalar(alpha, yi) * xi + yi, x, y)


def _scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def _norm2(x):
    return _vdot(x, x).real


# --- per-column (batched) vector algebra; leading axis = RHS index ------

def _bvdot(a, b):
    """Per-column ``<a, b>``: reduces every axis but the leading one
    (f32-accumulated for sub-f32 leaves, like :func:`_vdot`)."""
    leaves_a, leaves_b = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    out = None
    for x, y in zip(leaves_a, leaves_b):
        x, y = _acc(x), _acc(y)
        s = jnp.sum((jnp.conj(x) * y).reshape(x.shape[0], -1), axis=1)
        out = s if out is None else out + s
    return out


def _bnorm2(x):
    return _bvdot(x, x).real


def _bb(alpha, leaf):
    """Broadcast a per-column scalar ``(nrhs,)`` against a leaf."""
    return alpha.reshape(alpha.shape + (1,) * (leaf.ndim - 1))


def _baxpy(alpha, x, y):
    """``y + alpha * x`` with a per-column ``alpha`` (cast down to the
    leaf dtype so an f32-accumulated scalar never promotes the batch)."""
    return jax.tree_util.tree_map(
        lambda xi, yi: _bb(_apply_scalar(alpha, xi), xi) * xi + yi, x, y)


# --- block (shared-Krylov) algebra; leading axis = RHS index -----------

def _bgram(a, b):
    """Block Gram matrix ``G[i, j] = <a_i, b_j>`` over the leading RHS
    axis (f32-accumulated for sub-f32 leaves, like :func:`_bvdot`)."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    out = None
    for x, y in zip(leaves_a, leaves_b):
        x, y = _acc(x), _acc(y)
        g = jnp.conj(x).reshape(x.shape[0], -1) @ \
            y.reshape(y.shape[0], -1).T
        out = g if out is None else out + g
    return out


def _bcomb(coef, x, y=None):
    """Block column mixing ``y_j + sum_i coef[i, j] * x_i`` — the
    nrhs x nrhs direction-sharing step of block CG (``coef`` cast down
    like :func:`_baxpy` so an f32-accumulated Gram solve never promotes
    the batch)."""
    def leaf(xi, yi=None):
        c = _apply_scalar(coef, xi)
        upd = jnp.tensordot(c, xi, axes=((0,), (0,)))
        return upd if yi is None else upd + yi
    if y is None:
        return jax.tree_util.tree_map(leaf, x)
    return jax.tree_util.tree_map(leaf, x, y)


def _tiny(dtype):
    """Breakdown threshold: far below any meaningful Krylov scalar but
    above the denormal underflow that poisons the division chain."""
    real = jnp.finfo(jnp.zeros((), dtype).real.dtype)
    return real.tiny ** 0.5


def _nz(d, tiny):
    """Guard a denominator: the quotient is only *consumed* where
    ``|d| > tiny``, but a 0/0 in a dead lane would still produce a NaN
    that survives the masking multiply (``NaN * 0 = NaN``) — replace
    dead-lane denominators with 1 so every division is finite."""
    return jnp.where(jnp.abs(d) > tiny, d, jnp.ones_like(d))


# Divergence-guard defaults (see the module docstring): a column that
# makes no new residual minimum for a full window is stagnating; it gets
# this many deterministic restarts before freezing as diverged.
STAGNATION_WINDOW = 50
MAX_RESTARTS = 1

# Block CG replaces its recursive residual with the true residual at
# this cadence when the caller leaves recompute_every at 0 (see
# blockcg_batched: the orthonormalized recursion NEEDS reliable updates
# for a trustworthy convergence test; the other solvers keep 0 = never).
BLOCKCG_RECOMPUTE_DEFAULT = 50


def _swhere(flag, new, old):
    """Whole-solve freeze-select over a pytree: ``new`` where the scalar
    ``flag`` else ``old``.  The guard's bit-exact freeze — unlike the
    alpha-zeroing freeze, a ``where`` cannot be poisoned by a NaN on the
    rejected side (``0 * NaN = NaN`` would)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(flag, n, o), new, old)


def _bwhere(mask, new, old):
    """Per-column freeze-select: ``mask`` is ``(nrhs,)``, broadcast
    against every leaf of the batched pytrees."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(_bb(mask, n), n, o), new, old)


def _stagnation_reset(recompute_every, k, mask, rr1, best, since):
    """Re-baseline the stagnation window at a true-residual recompute.

    The ``recompute_every`` replacement is a drift *correction*: the
    recomputed ``|r|^2`` routinely reads higher than the stale recursive
    minimum the detector has been tracking, and feeding it into the
    ``best``/``since`` comparison as-is counts the correction as "no
    improvement" — iterations burn toward a spurious restart and, past
    ``max_restarts``, a false ``diverged`` on a perfectly healthy solve.
    At a recompute iteration the corrected residual IS the new baseline:
    reset ``best`` to it and the no-improvement counter to zero.
    ``mask`` limits the reset to columns that accepted the update
    (scalar ``True`` for the unbatched solvers).  Note the flip side:
    with ``recompute_every < stagnation_window`` the window can never
    fill between two corrections, so genuine stagnation is then judged
    per recompute interval (document, don't "fix" — the true residual
    is the more trustworthy signal).
    """
    if not recompute_every:
        return best, since
    recomp = jnp.logical_and((k + 1) % recompute_every == 0, mask)
    best = jnp.where(recomp, rr1, best)
    since = jnp.where(recomp, jnp.zeros_like(since), since)
    return best, since


class SolveResult(NamedTuple):
    x: jax.Array
    iterations: jnp.ndarray
    residual: jnp.ndarray      # relative residual |r| / |b|
    converged: jnp.ndarray
    # Divergence-guard verdict (scalar, or per-column for the batched
    # solvers): the state went non-finite or stagnated past the restart
    # budget and was frozen at its last good iterate.  Disjoint from
    # ``converged``; a plain breakdown freeze stays (False, False).
    diverged: jnp.ndarray = False


def _result(x, iters, rel, conv, div) -> SolveResult:
    """Assemble a SolveResult with the exit-time divergence fold: a
    non-finite *relative residual* is divergence even when the loop
    never tripped a guard (guard=False, or a NaN RHS whose column was
    never active) — the old silent-NaN exit reported ``converged`` from
    a NaN comparison instead."""
    div = jnp.logical_or(div, jnp.logical_not(jnp.isfinite(rel)))
    return SolveResult(x, iters, rel,
                       jnp.logical_and(conv, jnp.logical_not(div)), div)


def _col_field(v, lo: int, hi: int):
    """Slice a per-column result field to columns ``[lo, hi)``; scalar
    fields (an unbatched solve's iterations, the default ``diverged=
    False``) pass through unchanged."""
    if hasattr(v, "ndim") and getattr(v, "ndim", 0) >= 1:
        return v[lo:hi]
    return v


def split_columns(res, bounds):
    """Split a batched solve result back into per-request results.

    ``bounds`` is a sequence of ``(lo, hi)`` column ranges over the
    leading RHS axis — the serving layer's coalescing map (request i
    occupies columns ``lo_i..hi_i`` of the batch it rode in).  Every
    per-column field (``x``, ``iterations``, ``residual``,
    ``converged``, ``diverged``) is sliced, so each request gets back
    exactly its own columns' iteration counts, exit residuals, and
    convergence/divergence verdicts — the per-column freeze semantics
    make these independently meaningful (a frozen converged column is
    bit-identical to what an unshared solve of it would have kept).
    Works on :class:`SolveResult` and (duck-typed) on
    :class:`RefinedResult` — scalar bookkeeping fields
    (``outer_iterations``, ``f64_applies``, ...) are shared by the
    whole batch and pass through to every part.
    """
    parts = []
    for lo, hi in bounds:
        lo, hi = int(lo), int(hi)
        if lo < 0 or hi <= lo:
            raise ValueError(
                f"column bounds must be 0 <= lo < hi; got ({lo}, {hi})")
        parts.append(type(res)(*[
            _col_field(v, lo, hi) for v in res]))
    return parts


class RefinedResult(NamedTuple):
    """Result of a mixed-precision (iterative-refinement) solve.

    First four fields match :class:`SolveResult` so existing callers
    duck-type; the extras quantify the precision split: ``f64_applies``
    counts applications of the f64 operator (the pure-f64 solve pays
    ~2 per Krylov iteration; refinement pays 1 per outer pass), and
    ``inner_iterations`` the total inner-dtype Krylov iterations.
    ``escalations`` records each precision-escalation step the outer
    loop took (inner-dtype ladder rung names, in order) and
    ``diverged`` mirrors :class:`SolveResult`.
    """
    x: jax.Array
    iterations: jnp.ndarray
    residual: jnp.ndarray
    converged: jnp.ndarray
    outer_iterations: int
    f64_applies: int
    inner_iterations: int
    diverged: jnp.ndarray = False
    escalations: tuple = ()


def cg(op: Callable, b, x0=None, *, tol: float = 1e-6, max_iters: int = 1000,
       recompute_every: int = 0, guard: bool = True,
       stagnation_window: int = STAGNATION_WINDOW,
       max_restarts: int = MAX_RESTARTS,
       project: Optional[Callable] = None) -> SolveResult:
    """Conjugate gradients for a Hermitian positive-definite ``op``.

    ``recompute_every > 0`` replaces the recursively-updated residual
    with the true residual ``b - op(x)`` every that many iterations
    (inside the ``while_loop``), bounding floating-point drift on long
    solves (0 = never).  ``guard`` enables the divergence guard
    (non-finite freeze + stagnation restart, see the module docstring);
    ``guard=False`` keeps the bare recurrence for A/B overhead
    measurements and the J6 seeded-violation test.  ``project``
    (deflated CG; :func:`repro.core.deflate.make_projector`) is applied
    to the residual wherever a search direction is (re)built, keeping
    every direction A-orthogonal to the deflation subspace; ``None``
    keeps the recurrence bit-exactly undeflated.
    """
    proj = project if project is not None else (lambda v: v)
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    p = proj(r)
    rr = _norm2(r)
    b2 = _norm2(b)
    tiny = _tiny(rr.dtype)
    tol2 = (tol * tol) * b2

    def cond(state):
        x, r, p, rr, good, div, best, since, restarts, k = state
        go = jnp.logical_and(
            jnp.logical_and(rr > tol2, k < max_iters), good)
        if guard:
            # The non-finite guard lives in the COND (J6 asserts the
            # is_finite primitive here): a poisoned residual can never
            # buy another iteration.
            go = jnp.logical_and(go, jnp.logical_and(
                jnp.isfinite(rr), jnp.logical_not(div)))
        return go

    def body(state):
        x, r, p, rr, good, div, best, since, restarts, k = state
        ap = op(p)
        pap = _vdot(p, ap).real
        # Breakdown guard: pap ~ 0 (numerically nullspace direction)
        # would scale the update by garbage — freeze and exit instead.
        ok = pap > tiny
        alpha = jnp.where(ok, rr / _nz(pap, tiny), 0.0)
        x1 = _axpy(alpha, p, x)
        r1 = _axpy(-alpha, ap, r)
        if recompute_every:
            r1 = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r1, x1)
        rr1 = _norm2(r1)
        beta = rr1 / rr
        p1 = _axpy(beta, p, proj(r1))
        if not guard:
            return (x1, r1, p1, rr1, ok, div, best, since, restarts,
                    k + 1)
        # Non-finite freeze: keep the last finite iterate bit-exactly.
        finite = jnp.isfinite(rr1)
        x1 = _swhere(finite, x1, x)
        r1 = _swhere(finite, r1, r)
        p1 = _swhere(finite, p1, p)
        rr1 = jnp.where(finite, rr1, rr)
        div = jnp.logical_or(div, jnp.logical_not(finite))
        # Stagnation: no new residual minimum for a full window ->
        # deterministic restart (re-seed the Krylov space from the
        # current iterate's true residual); past the restart budget,
        # freeze and report diverged.
        improved = rr1 < best
        best = jnp.minimum(best, rr1)
        since = jnp.where(improved, 0, since + 1)
        best, since = _stagnation_reset(
            recompute_every, k, finite, rr1, best, since)
        stag = jnp.logical_and(finite, since >= stagnation_window)
        restart = jnp.logical_and(stag, restarts < max_restarts)

        def reseed(xk):
            rt = _axpy(-1.0, op(xk), b)
            return rt, _norm2(rt)

        r1, rr1 = jax.lax.cond(restart, reseed,
                               lambda _: (r1, rr1), x1)
        p1 = _swhere(restart, proj(r1), p1)
        best = jnp.where(restart, rr1, best)
        since = jnp.where(restart, 0, since)
        restarts = restarts + restart.astype(jnp.int32)
        div = jnp.logical_or(div, jnp.logical_and(
            stag, jnp.logical_not(restart)))
        return x1, r1, p1, rr1, ok, div, best, since, restarts, k + 1

    state = (x, r, p, rr, jnp.bool_(True), jnp.bool_(False), rr,
             jnp.int32(0), jnp.int32(0), jnp.int32(0))
    out = jax.lax.while_loop(cond, body, state)
    x, rr, div, k = out[0], out[3], out[5], out[9]
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return _result(x, k, rel, rel <= tol, div)


def cg_batched(op: Callable, b, x0=None, *, tol: float = 1e-6,
               max_iters: int = 1000, recompute_every: int = 0,
               guard: bool = True,
               stagnation_window: int = STAGNATION_WINDOW,
               max_restarts: int = MAX_RESTARTS,
               project: Optional[Callable] = None) -> SolveResult:
    """Batched CG: one operator application per iteration for the whole
    RHS block, per-column scalars, per-column convergence freezing.

    A column whose residual reaches tolerance has its updates zeroed
    (``alpha = beta = 0``) from then on — its ``x``/``r`` are frozen
    bit-exactly while the remaining columns keep iterating.  With
    ``guard`` (default), a column that goes non-finite or stagnates
    past the restart budget freezes the same way and reports through
    the per-column ``diverged`` mask; healthy columns are untouched
    (all scalars are per-column, so their trajectories are independent
    of the poisoned one).  Returns per-column ``iterations`` /
    ``residual`` / ``converged`` / ``diverged``.  ``project`` is the
    (batched) deflation projector, applied as in :func:`cg`.
    """
    proj = project if project is not None else (lambda v: v)
    x = x0 if x0 is not None else _scale(0.0, b)
    r = b if x0 is None else _axpy(-1.0, op(x), b)
    p = proj(r)
    rr = _bnorm2(r)
    b2 = _bnorm2(b)
    tiny = _tiny(rr.dtype)
    tol2 = (tol * tol) * b2
    # A non-finite source column is never active (NaN > tol2 is False):
    # it sits at x = 0 with iters = 0 and exits through the diverged
    # fold in _result.
    active = rr > tol2
    iters = jnp.zeros(rr.shape, jnp.int32)
    div = jnp.logical_not(jnp.isfinite(rr)) if guard \
        else jnp.zeros(rr.shape, bool)

    def cond(state):
        x, r, p, rr, active, iters, div, best, since, restarts, k = state
        if guard:
            # Only columns with a finite residual can buy iterations
            # (per-column analogue of the scalar guard; J6 asserts the
            # is_finite primitive structurally).
            live = jnp.logical_and(active, jnp.isfinite(rr))
            return jnp.logical_and(jnp.any(live), k < max_iters)
        return jnp.logical_and(jnp.any(active), k < max_iters)

    def body(state):
        x, r, p, rr, active, iters, div, best, since, restarts, k = state
        ap = op(p)
        pap = _bvdot(p, ap).real
        # Breakdown guard: a (numerically) nullspace search direction
        # gives pap ~ 0 — freeze that column instead of scaling by a
        # garbage alpha (mirrors the bicgstab guards).
        ok = jnp.logical_and(active, pap > tiny)
        af = ok.astype(rr.dtype)
        alpha = af * rr / _nz(pap, tiny)
        x1 = _baxpy(alpha, p, x)
        r1 = _baxpy(-alpha, ap, r)
        if recompute_every:
            r1 = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r1, x1)
        rr1 = _bnorm2(r1)
        beta = af * rr1 / _nz(rr, tiny)
        p1 = _baxpy(beta, p, proj(r1))
        if guard:
            # Per-column freeze: only active columns whose new residual
            # stayed finite accept the update (where-select, so a NaN
            # column cannot leak through the zeroed-alpha arithmetic).
            finite = jnp.isfinite(rr1)
            accept = jnp.logical_and(active, finite)
            x1 = _bwhere(accept, x1, x)
            r1 = _bwhere(accept, r1, r)
            p1 = _bwhere(accept, p1, p)
            rr1 = jnp.where(accept, rr1, rr)
            newly_bad = jnp.logical_and(active, jnp.logical_not(finite))
            div = jnp.logical_or(div, newly_bad)
            # Per-column stagnation -> deterministic restart.
            improved = rr1 < best
            best = jnp.where(accept, jnp.minimum(best, rr1), best)
            since = jnp.where(
                accept, jnp.where(improved, 0, since + 1), since)
            best, since = _stagnation_reset(
                recompute_every, k, accept, rr1, best, since)
            stag = jnp.logical_and(accept, since >= stagnation_window)
            restart = jnp.logical_and(stag, restarts < max_restarts)
            exhausted = jnp.logical_and(stag, jnp.logical_not(restart))

            def reseed(args):
                xk, r_, p_, rr_ = args
                rt = _axpy(-1.0, op(xk), b)
                rt2 = _bnorm2(rt)
                return (_bwhere(restart, rt, r_),
                        _bwhere(restart, proj(rt), p_),
                        jnp.where(restart, rt2, rr_))

            r1, p1, rr1 = jax.lax.cond(
                jnp.any(restart), reseed,
                lambda a: (a[1], a[2], a[3]), (x1, r1, p1, rr1))
            best = jnp.where(restart, rr1, best)
            since = jnp.where(restart, 0, since)
            restarts = restarts + restart.astype(jnp.int32)
            div = jnp.logical_or(div, exhausted)
            active_new = jnp.logical_and(
                jnp.logical_or(ok, restart), rr1 > tol2)
            active_new = jnp.logical_and(
                active_new, jnp.logical_not(div))
        else:
            active_new = jnp.logical_and(ok, rr1 > tol2)
        leaving = jnp.logical_and(active, jnp.logical_not(active_new))
        iters = jnp.where(leaving, k + 1, iters)
        return (x1, r1, p1, rr1, active_new, iters, div, best, since,
                restarts, k + 1)

    state = (x, r, p, rr, active, iters, div, rr,
             jnp.zeros(rr.shape, jnp.int32),
             jnp.zeros(rr.shape, jnp.int32), jnp.int32(0))
    out = jax.lax.while_loop(cond, body, state)
    x, rr, active, iters, div, k = (out[0], out[3], out[4], out[5],
                                    out[6], out[10])
    iters = jnp.where(active, k, iters)      # unconverged: ran to the end
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return _result(x, iters, rel, rel <= tol, div)


def _true_system_result(res, op, b, tol, batched) -> SolveResult:
    """Fold a normal-equations solve back to the TRUE-system metric.

    The convergence metric contract: every normal-equations solver
    (``cgnr``, ``method="cg"``, ``method="blockcg"``) *iterates* — and
    meets ``tol`` — in the normal-equation metric
    ``|A^dag r| / |A^dag b|``, but *reports* the true-system relative
    residual ``|b - A x| / |b|`` (one extra operator apply, outside the
    loop), so ``SolveResult.residual`` is comparable across every
    method and with the independent full-system check the CLI prints.
    The two metrics differ by up to a condition-number factor, hence
    the documented 10x slack on the exit-time ``converged`` test; the
    inner solve's divergence verdict carries over unchanged.
    """
    r = _axpy(-1.0, op(res.x), b)
    nrm = _bnorm2 if batched else _norm2
    rel = jnp.sqrt(nrm(r) / jnp.maximum(nrm(b), 1e-30))
    return _result(res.x, res.iterations, rel, rel <= tol * 10,
                   res.diverged)


def cgnr(op: Callable, op_dag: Callable, b, x0=None, *,
         tol: float = 1e-6, max_iters: int = 1000,
         recompute_every: int = 0, guard: bool = True,
         stagnation_window: int = STAGNATION_WINDOW,
         max_restarts: int = MAX_RESTARTS,
         project: Optional[Callable] = None) -> SolveResult:
    """CG on the normal equations ``op^dag op x = op^dag b``.

    Residual metric: iterates to ``tol`` in the normal-equation metric,
    reports the true-system relative residual (see
    :func:`_true_system_result`).
    """
    bn = op_dag(b)

    def normal(v):
        return op_dag(op(v))

    res = cg(normal, bn, x0, tol=tol, max_iters=max_iters,
             recompute_every=recompute_every, guard=guard,
             stagnation_window=stagnation_window,
             max_restarts=max_restarts, project=project)
    return _true_system_result(res, op, b, tol, batched=False)


def cgnr_batched(op: Callable, op_dag: Callable, b, x0=None, *,
                 tol: float = 1e-6, max_iters: int = 1000,
                 recompute_every: int = 0, guard: bool = True,
                 stagnation_window: int = STAGNATION_WINDOW,
                 max_restarts: int = MAX_RESTARTS,
                 project: Optional[Callable] = None) -> SolveResult:
    """Batched CGNR; per-column true residuals of the original system."""
    bn = op_dag(b)

    def normal(v):
        return op_dag(op(v))

    res = cg_batched(normal, bn, x0, tol=tol, max_iters=max_iters,
                     recompute_every=recompute_every, guard=guard,
                     stagnation_window=stagnation_window,
                     max_restarts=max_restarts, project=project)
    return _true_system_result(res, op, b, tol, batched=True)


def bicgstab(op: Callable, b, x0=None, *, tol: float = 1e-6,
             max_iters: int = 1000, recompute_every: int = 0,
             guard: bool = True,
             stagnation_window: int = STAGNATION_WINDOW,
             max_restarts: int = MAX_RESTARTS) -> SolveResult:
    """BiCGStab for general (non-Hermitian) ``op``.

    Works on any pytree vector domain: the Krylov scalars take the dtype
    of ``<b, b>`` (complex for complex spinors, real for planar-native
    vectors, where the operator is the real representation of ``Dhat``).
    ``recompute_every`` as in :func:`cg` (reliable-updates style
    true-residual replacement).

    Breakdown guards: BiCGStab's recurrence divides by ``rho``,
    ``<r0, v>`` and ``<t, t>`` (via ``omega``); any of them underflowing
    would turn the whole state into NaN inside the ``while_loop``.  Each
    is checked against a tiny threshold — on breakdown the update scalars
    are zeroed (state freezes at the last good iterate), the loop exits,
    and the result honestly reports the frozen residual with
    ``converged=False`` instead of NaN.

    The divergence guard (``guard``, default on) adds the non-finite
    cond check + bit-exact freeze, and a stagnation restart that
    re-seeds the *whole* Krylov space — shadow residual ``r0``, zeroed
    ``p``/``v``, unit scalars — from the current iterate's true
    residual.
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    one = jnp.ones((), dtype=_vdot(b, b).dtype)
    tiny = _tiny(one.dtype)
    b2 = _norm2(b)
    rr0 = _norm2(r)
    tol2 = (tol * tol) * b2
    zero_v = _scale(0.0, b)

    def cond(state):
        (x, r, r0, p, v, rho, alpha, omega, rr, good, div, best,
         since, restarts, k) = state
        go = jnp.logical_and(
            jnp.logical_and(rr > tol2, k < max_iters), good)
        if guard:
            go = jnp.logical_and(go, jnp.logical_and(
                jnp.isfinite(rr), jnp.logical_not(div)))
        return go

    def body(state):
        (x, r, r0, p, v, rho, alpha, omega, rr, good, div, best,
         since, restarts, k) = state
        rho_new = _vdot(r0, r)
        ok = jnp.logical_and(jnp.abs(rho_new) > tiny,
                             jnp.logical_and(jnp.abs(rho) > tiny,
                                             jnp.abs(omega) > tiny))
        okc = ok.astype(rho_new.dtype)
        beta = okc * (rho_new / _nz(rho, tiny)) * (alpha / _nz(omega, tiny))
        p1 = _axpy(beta, _axpy(-omega, v, p), r)
        v1 = op(p1)
        r0v = _vdot(r0, v1)
        ok = jnp.logical_and(ok, jnp.abs(r0v) > tiny)
        okc = ok.astype(rho_new.dtype)
        alpha1 = okc * rho_new / _nz(r0v, tiny)
        s = _axpy(-alpha1, v1, r)
        t = op(s)
        tt = _vdot(t, t).real
        ok = jnp.logical_and(ok, tt > tiny)
        okc = ok.astype(rho_new.dtype)
        omega1 = okc * _vdot(t, s) / _nz(tt, tiny).astype(rho_new.dtype)
        x1 = _axpy(alpha1, p1, _axpy(omega1, s, x))
        r1 = _axpy(-omega1, t, s)
        if recompute_every:
            r1 = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r1, x1)
        rr1 = _norm2(r1)
        if not guard:
            return (x1, r1, r0, p1, v1, rho_new, alpha1, omega1, rr1,
                    ok, div, best, since, restarts, k + 1)
        # Non-finite freeze at the last finite iterate (bit-exact).
        finite = jnp.isfinite(rr1)
        x1 = _swhere(finite, x1, x)
        r1 = _swhere(finite, r1, r)
        p1 = _swhere(finite, p1, p)
        v1 = _swhere(finite, v1, v)
        rho1 = jnp.where(finite, rho_new, rho)
        alpha1 = jnp.where(finite, alpha1, alpha)
        omega1 = jnp.where(finite, omega1, omega)
        rr1 = jnp.where(finite, rr1, rr)
        div = jnp.logical_or(div, jnp.logical_not(finite))
        # Stagnation -> restart: fresh shadow residual, zeroed search
        # space, unit scalars, all seeded from the true residual.
        improved = rr1 < best
        best = jnp.minimum(best, rr1)
        since = jnp.where(improved, 0, since + 1)
        best, since = _stagnation_reset(
            recompute_every, k, finite, rr1, best, since)
        stag = jnp.logical_and(finite, since >= stagnation_window)
        restart = jnp.logical_and(stag, restarts < max_restarts)

        def reseed(xk):
            rt = _axpy(-1.0, op(xk), b)
            return rt, _norm2(rt)

        r1, rr1 = jax.lax.cond(restart, reseed,
                               lambda _: (r1, rr1), x1)
        r0 = _swhere(restart, r1, r0)
        p1 = _swhere(restart, zero_v, p1)
        v1 = _swhere(restart, zero_v, v1)
        rho1 = jnp.where(restart, one, rho1)
        alpha1 = jnp.where(restart, one, alpha1)
        omega1 = jnp.where(restart, one, omega1)
        best = jnp.where(restart, rr1, best)
        since = jnp.where(restart, 0, since)
        restarts = restarts + restart.astype(jnp.int32)
        div = jnp.logical_or(div, jnp.logical_and(
            stag, jnp.logical_not(restart)))
        # A restart also clears a same-iteration breakdown: the frozen
        # scalars were just re-seeded.
        good = jnp.logical_or(ok, restart)
        return (x1, r1, r0, p1, v1, rho1, alpha1, omega1, rr1, good,
                div, best, since, restarts, k + 1)

    state = (x, r, r, zero_v, zero_v, one, one, one, rr0,
             jnp.bool_(True), jnp.bool_(False), rr0, jnp.int32(0),
             jnp.int32(0), jnp.int32(0))
    out = jax.lax.while_loop(cond, body, state)
    x, rr, div, k = out[0], out[8], out[10], out[14]
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return _result(x, k, rel, rel <= tol, div)


def bicgstab_batched(op: Callable, b, x0=None, *, tol: float = 1e-6,
                     max_iters: int = 1000, recompute_every: int = 0,
                     guard: bool = True,
                     stagnation_window: int = STAGNATION_WINDOW,
                     max_restarts: int = MAX_RESTARTS) -> SolveResult:
    """Batched BiCGStab with per-column convergence AND breakdown masks.

    Converged columns freeze (scalars zeroed, iterate kept bit-exact);
    broken-down columns freeze the same way but stay unconverged —
    ``converged[j] = False`` for them instead of a NaN-poisoned batch.
    The divergence guard (default on) where-freezes non-finite columns
    bit-exactly, restarts stagnating columns from their true residual,
    and reports both through the per-column ``diverged`` mask; healthy
    columns are bit-for-bit independent of poisoned ones (every Krylov
    scalar is per-column and the operator acts column-wise).
    """
    x = x0 if x0 is not None else _scale(0.0, b)
    r = b if x0 is None else _axpy(-1.0, op(x), b)
    sdtype = _bvdot(b, b).dtype
    tiny = _tiny(sdtype)
    n = jax.tree_util.tree_leaves(b)[0].shape[0]
    one = jnp.ones((n,), dtype=sdtype)
    zero_v = _scale(0.0, b)
    b2 = _bnorm2(b)
    tol2 = (tol * tol) * b2
    rr0 = _bnorm2(r)
    active = rr0 > tol2
    iters = jnp.zeros((n,), jnp.int32)
    div = jnp.logical_not(jnp.isfinite(rr0)) if guard \
        else jnp.zeros((n,), bool)

    def cond(state):
        rr, active, k = state[8], state[9], state[15]
        if guard:
            live = jnp.logical_and(active, jnp.isfinite(rr))
            return jnp.logical_and(jnp.any(live), k < max_iters)
        return jnp.logical_and(jnp.any(active), k < max_iters)

    def body(state):
        (x, r, r0, p, v, rho, alpha, omega, rr, active, iters, div,
         best, since, restarts, k) = state
        rho_new = _bvdot(r0, r)
        ok = jnp.logical_and(
            active,
            jnp.logical_and(jnp.abs(rho_new) > tiny,
                            jnp.logical_and(jnp.abs(rho) > tiny,
                                            jnp.abs(omega) > tiny)))
        okc = ok.astype(sdtype)
        beta = okc * (rho_new / _nz(rho, tiny)) * (alpha / _nz(omega, tiny))
        # Frozen columns get beta = 0 -> p := r (harmless: their alpha /
        # omega below are 0, so x and r never move again).
        p1 = _baxpy(beta, _baxpy(-omega * okc, v, p), r)
        v1 = op(p1)
        r0v = _bvdot(r0, v1)
        ok = jnp.logical_and(ok, jnp.abs(r0v) > tiny)
        okc = ok.astype(sdtype)
        alpha1 = okc * rho_new / _nz(r0v, tiny)
        s = _baxpy(-alpha1, v1, r)
        t = op(s)
        tt = _bvdot(t, t).real
        ok = jnp.logical_and(ok, tt > tiny)
        okc = ok.astype(sdtype)
        omega1 = okc * _bvdot(t, s) / _nz(tt, tiny).astype(sdtype)
        x1 = _baxpy(alpha1, p1, _baxpy(omega1, s, x))
        r1 = _baxpy(-omega1, t, s)
        if recompute_every:
            r1 = jax.lax.cond(
                (k + 1) % recompute_every == 0,
                lambda xk: _axpy(-1.0, op(xk), b),
                lambda _: r1, x1)
        rr1 = _bnorm2(r1)
        rho1, alpha_o, omega_o = rho_new, alpha1, omega1
        if guard:
            # Per-column bit-exact freeze of non-finite columns.
            finite = jnp.isfinite(rr1)
            accept = jnp.logical_and(active, finite)
            x1 = _bwhere(accept, x1, x)
            r1 = _bwhere(accept, r1, r)
            p1 = _bwhere(accept, p1, p)
            v1 = _bwhere(accept, v1, v)
            rho1 = jnp.where(accept, rho_new, rho)
            alpha_o = jnp.where(accept, alpha1, alpha)
            omega_o = jnp.where(accept, omega1, omega)
            rr1 = jnp.where(accept, rr1, rr)
            newly_bad = jnp.logical_and(active, jnp.logical_not(finite))
            div = jnp.logical_or(div, newly_bad)
            # Per-column stagnation -> full Krylov-space re-seed.
            improved = rr1 < best
            best = jnp.where(accept, jnp.minimum(best, rr1), best)
            since = jnp.where(
                accept, jnp.where(improved, 0, since + 1), since)
            best, since = _stagnation_reset(
                recompute_every, k, accept, rr1, best, since)
            stag = jnp.logical_and(accept, since >= stagnation_window)
            restart = jnp.logical_and(stag, restarts < max_restarts)
            exhausted = jnp.logical_and(stag, jnp.logical_not(restart))

            def reseed(args):
                x_, r_, r0_, p_, v_, rr_ = args
                rt = _axpy(-1.0, op(x_), b)
                rt2 = _bnorm2(rt)
                return (_bwhere(restart, rt, r_),
                        _bwhere(restart, rt, r0_),
                        _bwhere(restart, zero_v, p_),
                        _bwhere(restart, zero_v, v_),
                        jnp.where(restart, rt2, rr_))

            r1, r0, p1, v1, rr1 = jax.lax.cond(
                jnp.any(restart), reseed,
                lambda a: (a[1], a[2], a[3], a[4], a[5]),
                (x1, r1, r0, p1, v1, rr1))
            rho1 = jnp.where(restart, one, rho1)
            alpha_o = jnp.where(restart, one, alpha_o)
            omega_o = jnp.where(restart, one, omega_o)
            best = jnp.where(restart, rr1, best)
            since = jnp.where(restart, 0, since)
            restarts = restarts + restart.astype(jnp.int32)
            div = jnp.logical_or(div, exhausted)
            active_new = jnp.logical_and(
                jnp.logical_or(ok, restart), rr1 > tol2)
            active_new = jnp.logical_and(
                active_new, jnp.logical_not(div))
        else:
            # Columns that broke down this iteration (ok went False
            # while still active and unconverged) freeze too: drop them
            # from the active set so the loop can terminate.
            active_new = jnp.logical_and(ok, rr1 > tol2)
        leaving = jnp.logical_and(active, jnp.logical_not(active_new))
        iters = jnp.where(leaving, k + 1, iters)
        return (x1, r1, r0, p1, v1, rho1, alpha_o, omega_o, rr1,
                active_new, iters, div, best, since, restarts, k + 1)

    state = (x, r, r, zero_v, zero_v, one, one, one, rr0, active,
             iters, div, rr0, jnp.zeros((n,), jnp.int32),
             jnp.zeros((n,), jnp.int32), jnp.int32(0))
    out = jax.lax.while_loop(cond, body, state)
    x, rr, active, iters, div, k = (out[0], out[8], out[9], out[10],
                                    out[11], out[15])
    iters = jnp.where(active, k, iters)
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return _result(x, iters, rel, rel <= tol, div)

def blockcg_batched(op: Callable, b, x0=None, *, tol: float = 1e-6,
                    max_iters: int = 1000, recompute_every: int = 0,
                    guard: bool = True,
                    stagnation_window: int = STAGNATION_WINDOW,
                    max_restarts: int = MAX_RESTARTS,
                    project: Optional[Callable] = None) -> SolveResult:
    """Block CG: ONE Krylov space shared by the whole RHS block.

    Where :func:`cg_batched` runs nrhs *independent* recurrences that
    merely share operator applications, block CG searches the sum of
    the columns' Krylov spaces: every iteration solves small
    nrhs x nrhs systems and mixes every search direction into every
    column, so columns with overlapping spectral content (point sources
    on one gauge, noise dilutions) converge in fewer iterations than
    any of them would alone — the multi-RHS batching that already
    amortizes gauge-field traffic now also amortizes iteration count.
    Requires a Hermitian positive-definite ``op``; ``method="blockcg"``
    runs it on the normal equations of the Wilson Schur system.

    This is the residual-orthonormalized variant (Dubrulle's BCGrQ, the
    form lattice production code uses): the residual block is kept as
    ``R = Q S`` with ``Q`` orthonormalized every iteration by a
    Cholesky QR of the small Gram matrix and ``S`` the accumulated
    upper-triangular product.  Plain O'Leary block CG loses the
    residual block's rank in finite precision on ill-conditioned
    systems (the ``R^H R`` solve amplifies rounding until the block
    diverges); orthonormalizing ``Q`` keeps every small solve
    well-conditioned.  Rank-deficiency guards reuse the breakdown-freeze
    machinery: the small Gram/curvature matrices carry a relative ~eps
    identity ridge (invisible at full rank, decisive for duplicate or
    numerically dependent RHS columns), so exactly repeated sources
    stay solvable instead of poisoning the block.

    Per-column convergence freeze, bit-exactly: a column that leaves
    the active set has its ``S`` column zeroed, after which the shared
    recursion can never move its ``x`` again.  The divergence guard
    mirrors :func:`cg_batched` — ``is_finite`` in the loop cond (J6),
    per-column where-freeze of ``x``, stagnation restart re-seeding the
    block from the true residual — with one block-structural caveat: a
    mid-solve operator fault lives in the SHARED direction space, so it
    can freeze the whole block (every unconverged column reports
    ``diverged``), not just one column as in the independent recurrence.

    Residual metric: the loop iterates on the recursive ``S`` product,
    whose accumulated rounding drifts below the true residual on long
    f32 solves; ``recompute_every`` replaces the whole block with the
    true residual (fresh QR) every N iterations — recommended for tight
    tolerances — and the returned ``residual`` is always re-measured
    from ``b - op(x)`` at exit (one extra apply), with the documented
    10x slack on ``converged``.
    """
    # The S-product's drift is intrinsic to the orthonormalized block
    # recursion, so blockcg treats recompute_every=0 as "solver
    # default" (a true-residual replacement every 50 iterations), not
    # "never" — without reliable updates the recursive convergence test
    # is not trustworthy on long f32 solves.  Pass an explicit cadence
    # to override.
    recompute_every = recompute_every or BLOCKCG_RECOMPUTE_DEFAULT
    proj = project if project is not None else (lambda v: v)
    zero_v = jax.tree_util.tree_map(jnp.zeros_like, b)
    x = x0 if x0 is not None else zero_v
    r = b if x0 is None else _axpy(-1.0, op(x), b)
    rr0 = _bnorm2(r)
    b2 = _bnorm2(b)
    tiny = _tiny(rr0.dtype)
    tol2 = (tol * tol) * b2
    n = rr0.shape[0]
    gdtype = _bgram(b, b).dtype
    eye = jnp.eye(n, dtype=gdtype)
    eps = jnp.finfo(jnp.zeros((), gdtype).real.dtype).eps
    gzero = jnp.zeros((), gdtype)

    finite0 = jnp.isfinite(rr0)
    div = jnp.logical_not(finite0) if guard \
        else jnp.zeros(rr0.shape, bool)
    active = jnp.logical_and(rr0 > tol2, finite0)
    if guard:
        # The QR / Gram mixing COUPLES columns: a non-finite source
        # column would poison every small matrix it touches (and
        # 0 * NaN = NaN survives coefficient masking).  Park poisoned
        # columns on true zeros; they are never active and exit through
        # the diverged fold.
        r = _bwhere(finite0, r, zero_v)
        x = _bwhere(finite0, x, zero_v)

    def _chol_qr(rt):
        """Cholesky QR of the stacked block: ``rt = Q C`` with ``Q``
        orthonormal rows and ``C`` upper triangular.  The relative ~eps
        identity ridge is the rank-deficiency guard: a duplicate RHS
        column makes the Gram matrix exactly singular, and the ridge
        keeps the factorization finite while the dependent direction's
        C entries collapse to ~sqrt(eps) — it simply stops contributing
        new Krylov directions."""
        g = _bgram(rt, rt)
        g = 0.5 * (g + jnp.conj(g).T)
        dg = jnp.abs(jnp.diagonal(g))
        lam = (eps * n) * jnp.maximum(jnp.max(dg), tiny)
        low = jnp.linalg.cholesky(g + lam.astype(gdtype) * eye)
        inv_cl = jnp.linalg.inv(jnp.conj(low))
        q = jax.tree_util.tree_map(
            lambda leaf: jnp.tensordot(_apply_scalar(inv_cl, leaf),
                                       leaf, axes=((1,), (0,))), rt)
        return q, jnp.conj(low).T

    def _snorm2(s):
        """Per-column |R|^2 from the S factor (Q is orthonormal, so
        the residual column norms are the S column norms)."""
        return jnp.sum(jnp.abs(s) ** 2, axis=0).real.astype(rr0.dtype)

    qm, c0 = _chol_qr(r)
    s = jnp.where(active[None, :], c0, gzero)
    p = proj(qm)
    rr = _snorm2(s)

    def cond(state):
        rr, active, k = state[4], state[5], state[11]
        if guard:
            live = jnp.logical_and(active, jnp.isfinite(rr))
            return jnp.logical_and(jnp.any(live), k < max_iters)
        return jnp.logical_and(jnp.any(active), k < max_iters)

    def body(state):
        (x, qm, p, s, rr, active, iters, div, best, since, restarts,
         k) = state
        ap = op(p)
        if guard:
            # A direction the operator poisoned must not reach the
            # mixing step (0 * NaN = NaN would spread it everywhere):
            # park it on zeros — the ridged curvature solve then gives
            # it a finite, negligible coefficient row.
            apfin = jnp.isfinite(_bnorm2(ap))
            ap = _bwhere(apfin, ap, zero_v)
        xi = _bgram(p, ap)
        xi = 0.5 * (xi + jnp.conj(xi).T)
        dxi = jnp.abs(jnp.diagonal(xi))
        lam = (eps * n) * jnp.maximum(jnp.max(dxi), tiny)
        alpha = jnp.linalg.inv(xi + lam.astype(gdtype) * eye)
        if project is not None:
            # Deflated directions break the BCGrQ identity P^H Q = I
            # the plain step relies on; the exact small step is
            # M = (P^H A P)^{-1} (P^H Q) — one extra nrhs x nrhs Gram.
            alpha = alpha @ _bgram(p, qm)
        x1 = _bcomb(alpha @ s, p, x)
        t = _bcomb(-alpha, ap, qm)
        qm1, c1 = _chol_qr(t)
        s1 = c1 @ s
        p1 = _bcomb(jnp.conj(c1).T, p, proj(qm1))
        rr1 = _snorm2(s1)
        recomp = ((k + 1) % recompute_every == 0) if recompute_every \
            else jnp.bool_(False)
        if guard:
            finite = jnp.isfinite(rr1)
            accept = jnp.logical_and(active, finite)
            x1 = _bwhere(accept, x1, x)
            rr1 = jnp.where(accept, rr1, rr)
            newly_bad = jnp.logical_and(active, jnp.logical_not(finite))
            div = jnp.logical_or(div, newly_bad)
            improved = rr1 < best
            best = jnp.where(accept, jnp.minimum(best, rr1), best)
            since = jnp.where(
                accept, jnp.where(improved, 0, since + 1), since)
            # The recompute_every x stagnation interaction: a residual
            # replacement is a drift correction, not stagnation — the
            # window is re-baselined below (after the replacement), and
            # a replacement iteration never counts toward a restart.
            stag = jnp.logical_and(
                jnp.logical_and(accept, since >= stagnation_window),
                jnp.logical_not(recomp))
            restart = jnp.logical_and(stag, restarts < max_restarts)
            exhausted = jnp.logical_and(stag, jnp.logical_not(restart))
            restarts = restarts + restart.astype(jnp.int32)
            div = jnp.logical_or(div, exhausted)
            active_new = jnp.logical_and(
                active, jnp.logical_and(jnp.logical_not(div),
                                        rr1 > tol2))
            trigger = jnp.logical_or(recomp, jnp.any(restart))
        else:
            active_new = jnp.logical_and(active, rr1 > tol2)
            trigger = recomp
        # Bit-exact per-column freeze: a column out of the active set
        # has its S column zeroed — the shared recursion can never move
        # its x again (and a NaN S entry of a frozen column is scrubbed
        # rather than multiplied by zero).
        s1 = jnp.where(active_new[None, :], s1, gzero)

        def replace(args):
            # True-residual replacement (reliable update) / stagnation
            # restart: rebuild the whole block state from b - op(x) with
            # a fresh QR; the search space restarts from the residual.
            xa, s_, qm_, p_, rr_ = args
            rt = _axpy(-1.0, op(xa), b)
            if guard:
                rtfin = jnp.isfinite(_bnorm2(rt))
                rt = _bwhere(rtfin, rt, zero_v)
            qm2, c2 = _chol_qr(rt)
            s2 = jnp.where(active_new[None, :], c2, gzero)
            return xa, s2, qm2, proj(qm2), _snorm2(s2)

        if recompute_every or guard:
            _, s1, qm1, p1, rr_t = jax.lax.cond(
                trigger, replace, lambda a: a,
                (x1, s1, qm1, p1, rr1))
            rr1 = jnp.where(active_new, rr_t, rr1)
        if guard:
            # Window re-baseline at a replacement/restart: the fresh
            # true residual is the new best; a no-improvement streak
            # measured against the drifted recursive norm is void.
            rebase = jnp.logical_and(trigger, active_new)
            best = jnp.where(rebase, rr1, best)
            since = jnp.where(rebase, 0, since)
        leaving = jnp.logical_and(active, jnp.logical_not(active_new))
        iters = jnp.where(leaving, k + 1, iters)
        return (x1, qm1, p1, s1, rr1, active_new, iters, div, best,
                since, restarts, k + 1)

    state = (x, qm, p, s, rr, active,
             jnp.zeros(rr.shape, jnp.int32), div, rr,
             jnp.zeros(rr.shape, jnp.int32),
             jnp.zeros(rr.shape, jnp.int32), jnp.int32(0))
    out = jax.lax.while_loop(cond, body, state)
    x, rr, active, iters, div, k = (out[0], out[4], out[5], out[6],
                                    out[7], out[11])
    iters = jnp.where(active, k, iters)
    # Exit-time true residual (one extra apply): the recursive S
    # product's drift never reaches the caller — blockcg REPORTS the
    # recomputed |b - op(x)| / |b|, converged with the documented 10x
    # slack against it (the loop met tol in the recursive metric).
    rt = _axpy(-1.0, op(x), b)
    rel = jnp.sqrt(_bnorm2(rt) / jnp.maximum(b2, 1e-30))
    return _result(x, iters, rel, rel <= tol * 10, div)


# Krylov methods valid on the (non-Hermitian) even-odd Schur system.
# "cg" is plain CG run on the normal equations Dhat^dag Dhat x =
# Dhat^dag rhs — the same system "cgnr" solves; "blockcg" is the
# shared-Krylov block variant of the same normal-equations solve
# (degenerates to "cg" for a single RHS).  All three iterate in the
# normal-equation metric and report the true-system residual (see
# _true_system_result).  repro.api.SolveSpec derives its method choices
# (and the CLI's --method list) from this tuple — extend HERE, not in
# the CLI.
KRYLOV_METHODS = ("cg", "cgnr", "bicgstab", "blockcg")

# Methods that iterate the Hermitian positive-definite normal equations
# Dhat^dag Dhat — the operator a low-mode deflation subspace
# (repro.core.deflate) is built for; bicgstab iterates Dhat itself, so
# a normal-equations Galerkin guess does not apply.
DEFLATABLE_METHODS = ("cg", "cgnr", "blockcg")


def _run_krylov(method: str, dhat, dhat_dag, rhs, *, tol, max_iters,
                recompute_every, batched: bool = False,
                guard: bool = True,
                stagnation_window: int = STAGNATION_WINDOW,
                max_restarts: int = MAX_RESTARTS, deflation=None):
    """Dispatch one native-domain Krylov solve of ``Dhat x = rhs``.

    ``deflation`` (a :class:`repro.core.deflate.DeflationBasis`)
    deflates the normal-equations methods two ways at once: the
    Galerkin low-mode guess ``x0 = W (W^H A W)^{-1} W^H (A^dag rhs)``
    solves the subspace block up front, and the A-orthogonal projector
    (:func:`repro.core.deflate.make_projector`) is applied to every new
    search direction so the Krylov loop stays out of the deflated modes
    for the whole solve (same metric, same tolerance semantics, fewer
    iterations — robust even for an approximate basis).
    """
    kw = dict(tol=tol, max_iters=max_iters,
              recompute_every=recompute_every, guard=guard,
              stagnation_window=stagnation_window,
              max_restarts=max_restarts)
    if deflation is not None and method not in DEFLATABLE_METHODS:
        raise ValueError(
            f"deflation applies to the normal-equations methods "
            f"{DEFLATABLE_METHODS}, not {method!r}")

    def _guess(bn):
        if deflation is None:
            return None
        from repro.core.deflate import galerkin_guess
        return galerkin_guess(deflation, bn, batched=batched)

    if deflation is not None:
        from repro.core.deflate import make_projector
        kw["project"] = make_projector(deflation, batched=batched)

    if method in ("cg", "blockcg"):
        if batched:
            fn = blockcg_batched if method == "blockcg" else cg_batched
        else:
            # A single RHS has no block to share its Krylov space with:
            # blockcg degenerates to plain CG.
            fn = cg

        def normal(v):
            return dhat_dag(dhat(v))

        bn = dhat_dag(rhs)
        res = fn(normal, bn, _guess(bn), **kw)
        return _true_system_result(res, dhat, rhs, tol, batched)
    if method == "cgnr":
        fn = cgnr_batched if batched else cgnr
        x0 = _guess(dhat_dag(rhs)) if deflation is not None else None
        return fn(dhat, dhat_dag, rhs, x0, **kw)
    if method == "bicgstab":
        fn = bicgstab_batched if batched else bicgstab
        return fn(dhat, rhs, **kw)
    raise ValueError(
        f"unknown method {method!r}; choose from {KRYLOV_METHODS}")


_INNER_DTYPES = {
    "f32": jnp.float32, "float32": jnp.float32,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
    # top rung of the escalation ladder (inner solve at full precision;
    # only useful when the outer loop escalated its way up there, or
    # for A/B-ing refinement overhead against a pure-f64 solve)
    "f64": jnp.float64, "float64": jnp.float64,
}

# Precision-escalation ladder (cheap -> exact): when a refined solve's
# outer residual stops contracting, make_refined_solve climbs one rung
# and rebuilds the inner operator there (see ``bops_factory``).
ESCALATION_LADDER = ("bf16", "f32", "f64")


def resolve_inner_dtype(inner_dtype):
    """Map an inner-dtype spelling (``"f32"``/``"bf16"``/...) or dtype to
    the jnp dtype; the single source of truth the CLI reuses too."""
    if isinstance(inner_dtype, str):
        try:
            return _INNER_DTYPES[inner_dtype.lower()]
        except KeyError:
            raise ValueError(
                f"unknown inner_dtype {inner_dtype!r}; "
                f"choose from {sorted(set(_INNER_DTYPES))}") from None
    return jnp.dtype(inner_dtype).type


def make_native_solve(bops, kappa, *, method: str = "cgnr",
                      tol: float = 1e-6, max_iters: int = 2000,
                      recompute_every: int = 0, batched: bool = False,
                      guard: bool = True,
                      stagnation_window: int = STAGNATION_WINDOW,
                      max_restarts: int = MAX_RESTARTS,
                      deflated: bool = False):
    """Build the native-domain Schur-solve pipeline for a bound operator.

    Returns ``fn(v_e, v_o) -> (x, v_xi_o, SolveResult)`` working entirely
    on native vectors of ``bops`` (no encode/decode, no placement): the
    Eq. (4) RHS build, the Krylov iteration, and the Eq. (5) odd
    reconstruction.  The returned function is side-effect free and
    jit-compatible — :class:`repro.api.SolveSession` wraps it in ``jax.jit``
    once per ``(SolveSpec, rhs shape)`` key, which is what makes the
    second and every later same-shape solve skip tracing entirely.

    ``deflated=True`` returns ``fn(v_e, v_o, deflation)`` instead: the
    deflation basis is a pytree ARGUMENT of the jitted solve, not a
    closure constant — a recycled basis that grows between solves
    (fixed shapes, changing values) updates the guess without ever
    retracing the executable.
    """
    if batched:
        hop_eo_nat = bops.hop_eo_native_batched
        hop_oe_nat = bops.hop_oe_native_batched
        dhat_nat = bops.apply_dhat_native_batched
        dhat_dag_nat = bops.apply_dhat_dagger_native_batched
    else:
        hop_eo_nat, hop_oe_nat = bops.hop_eo_native, bops.hop_oe_native
        dhat_nat = bops.apply_dhat_native
        dhat_dag_nat = bops.apply_dhat_dagger_native

    def _solve(v_e, v_o, deflation):
        # RHS of Eq. (4): eta_e + kappa * H_eo eta_o  (D_eo = -kappa H_eo).
        rhs = _axpy(kappa, hop_eo_nat(v_o), v_e)
        res = _run_krylov(
            method,
            lambda v: dhat_nat(v, kappa),
            lambda v: dhat_dag_nat(v, kappa),
            rhs, tol=tol, max_iters=max_iters,
            recompute_every=recompute_every, batched=batched,
            guard=guard, stagnation_window=stagnation_window,
            max_restarts=max_restarts, deflation=deflation)
        # Eq. (5): xi_o = eta_o + kappa * H_oe xi_e.
        v_xi_o = _axpy(kappa, hop_oe_nat(res.x), v_o)
        return res.x, v_xi_o, res

    if deflated:
        def solve_native_deflated(v_e, v_o, deflation):
            return _solve(v_e, v_o, deflation)
        return solve_native_deflated

    def solve_native(v_e, v_o):
        return _solve(v_e, v_o, None)

    return solve_native


def make_refined_solve(bops, U64_e, U64_o, kappa, *, method: str = "cgnr",
                       tol: float = 1e-10, max_iters: int = 2000,
                       recompute_every: int = 0, inner_tol: float = 1e-4,
                       max_outer: int = 25, batched: bool = False,
                       guard: bool = True,
                       stagnation_window: int = STAGNATION_WINDOW,
                       max_restarts: int = MAX_RESTARTS,
                       inner_dtype="f32", escalate: bool = True,
                       bops_factory=None, stall_factor: float = 0.9,
                       snapshot=None):
    """Build a reusable mixed-precision iterative-refinement solve.

    ``bops`` is the *inner* backend, already bound at the cheap inner
    dtype; ``U64_e`` / ``U64_o`` is the gauge for the f64 reference
    operator (upcast to complex128 here).  The f64 operator and hops are
    jitted **once at build time**, so a caller holding the returned
    ``fn(eta_e, eta_o) -> (xi_e, xi_o, RefinedResult)`` (e.g. a
    :class:`repro.api.SolveSession` cache entry) pays the f64 traces on
    the first solve only.  The outer loop itself is Python-level — a
    handful of passes with data-dependent exit — so it is rebuilt per
    call by design; the expensive pieces (f64 operator, inner Krylov
    ``while_loop``) reuse their jit caches across calls.

    Outer loop: f64 true residual of ``Dhat x = rhs``, then a correction
    solve ``Dhat e = r`` in the inner dtype through ``bops``'s native
    domain, ``x += e``, until the **f64** relative residual meets
    ``tol``.  The f64 operator is applied once per outer pass — versus
    ~2 per Krylov iteration for a pure-f64 solve — and all the
    bandwidth-hungry iterating happens at the inner dtype's traffic.

    **Precision escalation** (``escalate``, on by default, active when a
    ``bops_factory`` is supplied): when an outer pass fails to contract
    the residual by ``stall_factor`` — or the inner solve's divergence
    guard trips — the inner dtype climbs :data:`ESCALATION_LADDER` from
    its starting rung (``inner_dtype``) and the inner operator is
    rebuilt via ``bops_factory(rung_name) -> bops``.  Each step taken is
    recorded in ``RefinedResult.escalations``; at the ``"f64"`` rung the
    correction residual is handed to the inner solve at complex128.

    ``snapshot`` (a :class:`repro.resilience.RefinementSnapshot`) makes
    the outer loop resumable: the f64 iterate is checkpointed after
    every correction, and a later call resumes from the newest one
    instead of from zero.
    """
    from . import evenodd

    if jnp.zeros((), jnp.float64).dtype != jnp.dtype(jnp.float64):
        raise ValueError(
            "mixed-precision refinement needs float64 for the outer "
            "residual: enable x64 (jax.config.update('jax_enable_x64', "
            "True) or the jax.experimental.enable_x64 context)")

    U64_e = U64_e.astype(jnp.complex128)
    U64_o = U64_o.astype(jnp.complex128)

    def _maybe_vmap(fn):
        return jax.vmap(fn) if batched else fn

    dhat64 = jax.jit(_maybe_vmap(
        lambda v: evenodd.apply_dhat(U64_e, U64_o, v, kappa)))
    hop_eo64 = jax.jit(_maybe_vmap(
        lambda v: evenodd.hop_eo(U64_e, U64_o, v)))
    hop_oe64 = jax.jit(_maybe_vmap(
        lambda v: evenodd.hop_oe(U64_e, U64_o, v)))

    def _inner_ops(bops_):
        if batched:
            return (bops_.to_domain_batched, bops_.from_domain_batched,
                    bops_.apply_dhat_native_batched,
                    bops_.apply_dhat_dagger_native_batched)
        return (bops_.to_domain, bops_.from_domain,
                bops_.apply_dhat_native, bops_.apply_dhat_dagger_native)

    bnorm = _bnorm2 if batched else _norm2

    ladder = list(ESCALATION_LADDER)
    start = inner_dtype if isinstance(inner_dtype, str) else "f32"
    start = {"float32": "f32", "bfloat16": "bf16",
             "float64": "f64"}.get(start.lower(), start.lower())
    start_rung = ladder.index(start) if start in ladder \
        else ladder.index("f32")

    def refined(eta_e, eta_o):
        eta64_e = eta_e.astype(jnp.complex128)
        eta64_o = eta_o.astype(jnp.complex128)
        rhs64 = eta64_e + kappa * hop_eo64(eta64_o)
        f64_applies = 1  # the hop above
        b2 = bnorm(rhs64)

        x64 = jnp.zeros_like(rhs64)
        start_outer = 0
        if snapshot is not None:
            x64, start_outer, _ = snapshot.resume(x64)
        inner_iters = 0
        # Per-column (batched) / scalar (unbatched) total inner
        # iterations, matching the batched SolveResult contract
        # RefinedResult duck-types.
        iters_acc = jnp.zeros(b2.shape, jnp.int32)
        cur = bops
        to_dom, from_dom, dhat_nat, dhat_dag_nat = _inner_ops(cur)
        rung = start_rung
        escalations = []
        inner_div = None
        best_worst = None
        outer = start_outer
        rel = None
        for outer in range(start_outer + 1, max_outer + 1):
            r64 = rhs64 - dhat64(x64)
            f64_applies += 1
            rel = jnp.sqrt(bnorm(r64) / jnp.maximum(b2, 1e-300))
            if bool(jnp.all(rel <= tol)):
                break
            # Escalation trigger: the previous pass failed to contract
            # the worst-column residual by stall_factor, or its inner
            # solve tripped the divergence guard.
            worst = float(jnp.max(rel))
            stalled = (best_worst is not None
                       and not worst < best_worst * stall_factor)
            tripped = inner_div is not None and bool(jnp.any(inner_div))
            if ((stalled or tripped) and escalate
                    and bops_factory is not None):
                while rung + 1 < len(ladder):
                    rung += 1
                    try:
                        cur = bops_factory(ladder[rung])
                    except Exception:       # rung unavailable: keep
                        continue            # climbing
                    to_dom, from_dom, dhat_nat, dhat_dag_nat = \
                        _inner_ops(cur)
                    escalations.append(ladder[rung])
                    best_worst = None       # fresh contraction baseline
                    break
            if best_worst is None or worst < best_worst:
                best_worst = worst
            # Correction solve in the inner dtype, native domain (the
            # f64 rung keeps the correction residual at complex128).
            cdt = jnp.complex128 if ladder[rung] == "f64" \
                else jnp.complex64
            v = to_dom(r64.astype(cdt))
            res = _run_krylov(
                method,
                lambda w: dhat_nat(w, kappa),
                lambda w: dhat_dag_nat(w, kappa),
                v, tol=inner_tol, max_iters=max_iters,
                recompute_every=recompute_every, batched=batched,
                guard=guard, stagnation_window=stagnation_window,
                max_restarts=max_restarts)
            inner_div = res.diverged
            x64 = x64 + from_dom(res.x).astype(jnp.complex128)
            iters_acc = iters_acc + res.iterations.astype(jnp.int32)
            inner_iters += int(jnp.max(res.iterations))
            if snapshot is not None:
                snapshot.save(outer, x64)
        else:
            # Outer budget exhausted: report the residual of the final
            # iterate, not the one from before the last correction.
            r64 = rhs64 - dhat64(x64)
            f64_applies += 1
            rel = jnp.sqrt(bnorm(r64) / jnp.maximum(b2, 1e-300))
        diverged = jnp.logical_not(jnp.isfinite(rel))
        if inner_div is not None:
            # An inner guard trip only counts as divergence if the
            # outer loop never recovered the column to tolerance.
            diverged = jnp.logical_or(diverged, jnp.logical_and(
                inner_div, jnp.logical_not(rel <= tol)))
        converged = jnp.logical_and(rel <= tol,
                                    jnp.logical_not(diverged))

        xi_o64 = eta64_o + kappa * hop_oe64(x64)
        f64_applies += 1
        xi_e = x64.astype(eta_e.dtype)
        xi_o = xi_o64.astype(eta_o.dtype)
        return xi_e, xi_o, RefinedResult(
            x=xi_e, iterations=iters_acc, residual=rel,
            converged=converged, outer_iterations=outer,
            f64_applies=f64_applies, inner_iterations=inner_iters,
            diverged=diverged, escalations=tuple(escalations))

    return refined
