"""Iterative Krylov solvers for the even-odd preconditioned Wilson system.

All solvers are matrix-free (take a linear-operator callable), run under
``lax.while_loop`` so they jit/pjit cleanly, and treat pytrees of complex
arrays as vectors.  CGNR (CG on the normal equations) is the robust
workhorse for the non-Hermitian ``Dhat``; BiCGStab is the faster
alternative the paper's solver stack (QWS) uses in practice.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def _vdot(a, b):
    leaves_a, leaves_b = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(x, y) for x, y in zip(leaves_a, leaves_b))


def _axpy(alpha, x, y):
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def _scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def _norm2(x):
    return _vdot(x, x).real


class SolveResult(NamedTuple):
    x: jax.Array
    iterations: jnp.ndarray
    residual: jnp.ndarray      # relative residual |r| / |b|
    converged: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    tol: float = 1e-6
    max_iters: int = 1000
    # Check-pointed restart support: residual recomputed from scratch
    # every ``recompute_every`` iterations to bound drift (0 = never).
    recompute_every: int = 0


def cg(op: Callable, b, x0=None, *, tol: float = 1e-6, max_iters: int = 1000) -> SolveResult:
    """Conjugate gradients for a Hermitian positive-definite ``op``."""
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    p = r
    rr = _norm2(r)
    b2 = _norm2(b)
    tol2 = (tol * tol) * b2

    def cond(state):
        _, _, _, rr, k = state
        return jnp.logical_and(rr > tol2, k < max_iters)

    def body(state):
        x, r, p, rr, k = state
        ap = op(p)
        alpha = rr / _vdot(p, ap).real
        x = _axpy(alpha, p, x)
        r = _axpy(-alpha, ap, r)
        rr_new = _norm2(r)
        beta = rr_new / rr
        p = _axpy(beta, p, r)
        return x, r, p, rr_new, k + 1

    x, r, p, rr, k = jax.lax.while_loop(cond, body, (x, r, p, rr, jnp.int32(0)))
    rel = jnp.sqrt(rr / jnp.maximum(b2, 1e-30))
    return SolveResult(x, k, rel, rel <= tol)


def cgnr(op: Callable, op_dag: Callable, b, x0=None, *,
         tol: float = 1e-6, max_iters: int = 1000) -> SolveResult:
    """CG on the normal equations ``op^dag op x = op^dag b``."""
    bn = op_dag(b)

    def normal(v):
        return op_dag(op(v))

    res = cg(normal, bn, x0, tol=tol, max_iters=max_iters)
    # Report the true residual of the original system.
    r = _axpy(-1.0, op(res.x), b)
    rel = jnp.sqrt(_norm2(r) / jnp.maximum(_norm2(b), 1e-30))
    return SolveResult(res.x, res.iterations, rel, rel <= tol * 10)


def bicgstab(op: Callable, b, x0=None, *, tol: float = 1e-6,
             max_iters: int = 1000) -> SolveResult:
    """BiCGStab for general (non-Hermitian) ``op``."""
    x = x0 if x0 is not None else _scale(0.0, b)
    r = _axpy(-1.0, op(x), b)
    r0 = r
    rho = alpha = omega = jnp.complex64(1.0)
    v = p = _scale(0.0, b)
    b2 = _norm2(b)
    tol2 = (tol * tol) * b2

    def cond(state):
        _, r, *_, k = state
        return jnp.logical_and(_norm2(r) > tol2, k < max_iters)

    def body(state):
        x, r, p, v, rho, alpha, omega, k = state
        rho_new = _vdot(r0, r)
        beta = (rho_new / rho) * (alpha / omega)
        p = _axpy(beta, _axpy(-omega, v, p), r)
        v = op(p)
        alpha = rho_new / _vdot(r0, v)
        s = _axpy(-alpha, v, r)
        t = op(s)
        omega = _vdot(t, s) / _vdot(t, t)
        x = _axpy(alpha, p, _axpy(omega, s, x))
        r = _axpy(-omega, t, s)
        return x, r, p, v, rho_new, alpha, omega, k + 1

    state = (x, r, p, v, rho, alpha, omega, jnp.int32(0))
    x, r, *_, k = jax.lax.while_loop(cond, body, state)
    rel = jnp.sqrt(_norm2(r) / jnp.maximum(b2, 1e-30))
    return SolveResult(x, k, rel, rel <= tol)


def solve_wilson_eo(U_e, U_o, eta_e, eta_o, kappa, *, method: str = "cgnr",
                    tol: float = 1e-6, max_iters: int = 2000,
                    apply_dhat_fn=None, apply_dhat_dag_fn=None,
                    hop_oe_fn=None, hop_eo_fn=None,
                    backend=None, backend_opts=None):
    """Solve ``D_W xi = eta`` via the even-odd Schur system (Eqs. 4-5).

    Returns ``(xi_e, xi_o, SolveResult)``.  For the Wilson matrix
    ``D_ee = D_oo = 1`` so the reconstruction is Eq. (5) with trivial
    inverses.

    The operator implementation is chosen by ``backend`` — a name from
    :mod:`repro.backends` (``"jnp"``, ``"pallas"``, ``"pallas_fused"``,
    ``"distributed"``; ``backend_opts`` are forwarded to the factory) or
    an already-bound :class:`repro.backends.WilsonOps` (so callers
    solving repeatedly against one gauge field bind once, keeping jit
    caches and the planarized gauge warm across solves).  Explicitly
    passed ``*_fn`` callables win over the backend, keeping the old
    hand-wiring possible.
    """
    from . import evenodd  # local import to avoid cycle

    if backend is not None:
        from repro import backends as backends_lib  # avoid import cycle
        bops = (backend if isinstance(backend, backends_lib.WilsonOps)
                else backends_lib.make_wilson_ops(
                    backend, U_e, U_o, **(backend_opts or {})))
        hop_oe_fn = hop_oe_fn or (lambda ue, uo, p: bops.hop_oe(p))
        hop_eo_fn = hop_eo_fn or (lambda ue, uo, p: bops.hop_eo(p))
        apply_dhat_fn = apply_dhat_fn or (
            lambda v: bops.apply_dhat(v, kappa))
        apply_dhat_dag_fn = apply_dhat_dag_fn or (
            lambda v: bops.apply_dhat_dagger(v, kappa))

    hop_oe_fn = hop_oe_fn or evenodd.hop_oe
    hop_eo_fn = hop_eo_fn or evenodd.hop_eo
    dhat = apply_dhat_fn or (lambda v: evenodd.apply_dhat(
        U_e, U_o, v, kappa, hop_oe_fn, hop_eo_fn))
    dhat_dag = apply_dhat_dag_fn or (lambda v: evenodd.apply_dhat_dagger(
        U_e, U_o, v, kappa, hop_oe_fn, hop_eo_fn))

    # RHS of Eq. (4): eta_e + kappa * H_eo eta_o  (D_eo = -kappa H_eo).
    rhs = eta_e + kappa * hop_eo_fn(U_e, U_o, eta_o)
    if method == "cgnr":
        res = cgnr(dhat, dhat_dag, rhs, tol=tol, max_iters=max_iters)
    elif method == "bicgstab":
        res = bicgstab(dhat, rhs, tol=tol, max_iters=max_iters)
    else:
        raise ValueError(f"unknown method {method!r}")
    xi_e = res.x
    # Eq. (5): xi_o = eta_o + kappa * H_oe xi_e.
    xi_o = eta_o + kappa * hop_oe_fn(U_e, U_o, xi_e)
    return xi_e, xi_o, res
