"""SU(3) gauge-field utilities."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .lattice import NDIM, shift


def random_su3(key: jax.Array, shape: Sequence[int], dtype=jnp.complex64) -> jnp.ndarray:
    """Haar-ish random SU(3) matrices of shape ``(*shape, 3, 3)``.

    Gram-Schmidt (QR) on a random complex matrix, with the determinant phase
    divided out so ``det U = 1`` exactly (up to fp rounding).
    """
    kr, ki = jax.random.split(key)
    m = (jax.random.normal(kr, (*shape, 3, 3))
         + 1j * jax.random.normal(ki, (*shape, 3, 3))).astype(dtype)
    q, r = jnp.linalg.qr(m)
    # Fix the U(1) phases left free by QR: make diag(r) real-positive.
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / jnp.abs(d))[..., None, :]
    det = jnp.linalg.det(q)
    return q * (det[..., None, None] ** (-1.0 / 3.0))


def random_gauge(key: jax.Array, lat_shape: Sequence[int], dtype=jnp.complex64) -> jnp.ndarray:
    """Random gauge field ``(4, T, Z, Y, X, 3, 3)``."""
    return random_su3(key, (NDIM, *lat_shape), dtype=dtype)


def unit_gauge(lat_shape: Sequence[int], dtype=jnp.complex64) -> jnp.ndarray:
    """Free-field (identity) gauge configuration."""
    eye = jnp.eye(3, dtype=dtype)
    return jnp.broadcast_to(eye, (NDIM, *lat_shape, 3, 3))


def weak_gauge(key: jax.Array, lat_shape: Sequence[int],
               eps: float = 0.2, dtype=jnp.complex64) -> jnp.ndarray:
    """Weak-field (smooth) gauge configuration ``U = exp(i eps H)``
    with ``H`` random Hermitian traceless — exactly SU(3), a small
    fluctuation around the free field.

    The physics that makes this the deflation test bed: a smooth
    configuration keeps the free operator's momentum-mode structure, so
    the low end of ``Dhat^dag Dhat`` is a few ISOLATED (and degenerate
    — 12-fold at p=0: 4 spinor x 3 color) clusters that a small
    deflation basis can actually remove, whereas a Haar-random ("hot")
    gauge smears the low spectrum into a quasi-continuum no small basis
    helps with.
    """
    kr, ki = jax.random.split(key)
    shape = (NDIM, *lat_shape, 3, 3)
    a = (jax.random.normal(kr, shape)
         + 1j * jax.random.normal(ki, shape)).astype(jnp.complex64)
    h = 0.5 * (a + jnp.conj(jnp.swapaxes(a, -1, -2)))
    tr = jnp.trace(h, axis1=-2, axis2=-1) / 3.0
    h = h - tr[..., None, None] * jnp.eye(3, dtype=h.dtype)
    return jax.scipy.linalg.expm(1j * eps * h).astype(dtype)


def compress_two_row(U: jnp.ndarray) -> jnp.ndarray:
    """Keep the first two rows: ``(..., 3, 3)`` -> ``(..., 2, 3)``.

    12 real numbers per link instead of 18. Exact for any SU(3) matrix:
    the third row is ``conj(a x b)`` (see :func:`reconstruct_two_row`).
    """
    return U[..., :2, :]


def reconstruct_two_row(W: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`compress_two_row`: ``(..., 2, 3)`` -> ``(..., 3, 3)``."""
    a, b = W[..., 0, :], W[..., 1, :]
    c = jnp.cross(a, b).conj()
    return jnp.stack([a, b, c], axis=-2)


def compress_minimal(U: jnp.ndarray) -> jnp.ndarray:
    """8-real compression: ``(..., 3, 3)`` complex -> ``(..., 8)`` real.

    Stores ``a2, a3, b1`` (re/im) and the phases of ``a1`` and ``c1``;
    unitarity fixes the rest. Singular when ``|a2|^2 + |a3|^2 == 0``
    (e.g. the unit gauge) — intended for interacting gauge fields. More
    sensitive to rounding than ``two_row`` (a 1/D division), so expect
    ~1e-4 round-trip error in f32 instead of ~1e-6.
    """
    real = jnp.float64 if U.dtype == jnp.complex128 else jnp.float32
    a2, a3, b1 = U[..., 0, 1], U[..., 0, 2], U[..., 1, 0]
    th_a = jnp.angle(U[..., 0, 0]).astype(real)
    th_c = jnp.angle(U[..., 2, 0]).astype(real)
    return jnp.stack(
        [a2.real.astype(real), a2.imag.astype(real),
         a3.real.astype(real), a3.imag.astype(real),
         b1.real.astype(real), b1.imag.astype(real), th_a, th_c], axis=-1)


def reconstruct_minimal(W: jnp.ndarray, dtype=jnp.complex64) -> jnp.ndarray:
    """Inverse of :func:`compress_minimal`: ``(..., 8)`` -> ``(..., 3, 3)``."""
    a2 = (W[..., 0] + 1j * W[..., 1]).astype(dtype)
    a3 = (W[..., 2] + 1j * W[..., 3]).astype(dtype)
    b1 = (W[..., 4] + 1j * W[..., 5]).astype(dtype)
    th_a, th_c = W[..., 6], W[..., 7]
    d = (jnp.abs(a2) ** 2 + jnp.abs(a3) ** 2).real
    a1 = (jnp.sqrt(jnp.maximum(1.0 - d, 0.0))
          * jnp.exp(1j * th_a)).astype(dtype)
    c1 = (jnp.sqrt(jnp.maximum(d - jnp.abs(b1) ** 2, 0.0))
          * jnp.exp(1j * th_c)).astype(dtype)
    dinv = (1.0 / jnp.maximum(d, 1e-30)).astype(dtype)
    s = -a1.conj() * b1
    b2 = (a2 * s - a3.conj() * c1.conj()) * dinv
    b3 = (a3 * s + a2.conj() * c1.conj()) * dinv
    c2 = (a3 * b1 - a1 * b3).conj()
    c3 = (a1 * b2 - a2 * b1).conj()
    row_a = jnp.stack([a1, a2, a3], axis=-1)
    row_b = jnp.stack([b1, b2, b3], axis=-1)
    row_c = jnp.stack([c1, c2, c3], axis=-1)
    return jnp.stack([row_a, row_b, row_c], axis=-2)


def unitarity_defect(U: jnp.ndarray) -> jnp.ndarray:
    """max |U U^dag - 1| over the field; ~1e-6 for healthy f32 SU(3)."""
    eye = jnp.eye(3, dtype=U.dtype)
    uud = jnp.einsum("...ab,...cb->...ac", U, U.conj())
    return jnp.max(jnp.abs(uud - eye))


def project_su3(U: jnp.ndarray) -> jnp.ndarray:
    """Project ``(..., 3, 3)`` complex matrices onto SU(3).

    The unitary polar factor ``W V^dag`` of the SVD (the nearest unitary
    in Frobenius norm), with the residual determinant phase divided out
    — the repair half of a gauge-integrity audit
    (:func:`repro.resilience.repair_gauge`).  Links must be finite and
    non-singular; replace corrupted links first.
    """
    w, _, vh = jnp.linalg.svd(U)
    q = jnp.einsum("...ab,...bc->...ac", w, vh)
    det = jnp.linalg.det(q)
    return q * (det[..., None, None] ** (-1.0 / 3.0))


def plaquette(U: jnp.ndarray) -> jnp.ndarray:
    """Average plaquette ``Re tr P / 3`` over all sites and planes.

    ``P_{mu,nu}(x) = U_mu(x) U_nu(x+mu) U_mu(x+nu)^dag U_nu(x)^dag``;
    gauge invariant, equals 1 for the unit gauge.
    """
    total = 0.0
    count = 0
    for mu in range(NDIM):
        for nu in range(mu + 1, NDIM):
            u_mu, u_nu = U[mu], U[nu]
            u_nu_xmu = shift(u_nu, mu, +1)  # U_nu(x+mu)
            u_mu_xnu = shift(u_mu, nu, +1)  # U_mu(x+nu)
            p = jnp.einsum("...ab,...bc,...dc,...ed->...ae",
                           u_mu, u_nu_xmu, u_mu_xnu.conj(), u_nu.conj())
            tr = jnp.trace(p, axis1=-2, axis2=-1)
            total = total + jnp.mean(tr.real)
            count += 1
    return total / (3.0 * count)
