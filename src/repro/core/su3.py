"""SU(3) gauge-field utilities."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .lattice import NDIM, shift


def random_su3(key: jax.Array, shape: Sequence[int], dtype=jnp.complex64) -> jnp.ndarray:
    """Haar-ish random SU(3) matrices of shape ``(*shape, 3, 3)``.

    Gram-Schmidt (QR) on a random complex matrix, with the determinant phase
    divided out so ``det U = 1`` exactly (up to fp rounding).
    """
    kr, ki = jax.random.split(key)
    m = (jax.random.normal(kr, (*shape, 3, 3))
         + 1j * jax.random.normal(ki, (*shape, 3, 3))).astype(dtype)
    q, r = jnp.linalg.qr(m)
    # Fix the U(1) phases left free by QR: make diag(r) real-positive.
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / jnp.abs(d))[..., None, :]
    det = jnp.linalg.det(q)
    return q * (det[..., None, None] ** (-1.0 / 3.0))


def random_gauge(key: jax.Array, lat_shape: Sequence[int], dtype=jnp.complex64) -> jnp.ndarray:
    """Random gauge field ``(4, T, Z, Y, X, 3, 3)``."""
    return random_su3(key, (NDIM, *lat_shape), dtype=dtype)


def unit_gauge(lat_shape: Sequence[int], dtype=jnp.complex64) -> jnp.ndarray:
    """Free-field (identity) gauge configuration."""
    eye = jnp.eye(3, dtype=dtype)
    return jnp.broadcast_to(eye, (NDIM, *lat_shape, 3, 3))


def unitarity_defect(U: jnp.ndarray) -> jnp.ndarray:
    """max |U U^dag - 1| over the field; ~1e-6 for healthy f32 SU(3)."""
    eye = jnp.eye(3, dtype=U.dtype)
    uud = jnp.einsum("...ab,...cb->...ac", U, U.conj())
    return jnp.max(jnp.abs(uud - eye))


def plaquette(U: jnp.ndarray) -> jnp.ndarray:
    """Average plaquette ``Re tr P / 3`` over all sites and planes.

    ``P_{mu,nu}(x) = U_mu(x) U_nu(x+mu) U_mu(x+nu)^dag U_nu(x)^dag``;
    gauge invariant, equals 1 for the unit gauge.
    """
    total = 0.0
    count = 0
    for mu in range(NDIM):
        for nu in range(mu + 1, NDIM):
            u_mu, u_nu = U[mu], U[nu]
            u_nu_xmu = shift(u_nu, mu, +1)  # U_nu(x+mu)
            u_mu_xnu = shift(u_mu, nu, +1)  # U_mu(x+nu)
            p = jnp.einsum("...ab,...bc,...dc,...ed->...ae",
                           u_mu, u_nu_xmu, u_mu_xnu.conj(), u_nu.conj())
            tr = jnp.trace(p, axis1=-2, axis2=-1)
            total = total + jnp.mean(tr.real)
            count += 1
    return total / (3.0 * count)
