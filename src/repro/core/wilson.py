"""Full-lattice Wilson fermion matrix (textbook reference).

``D_W psi = psi - kappa * H psi`` with the hopping term

``H(x,y) = sum_mu [ (1 - g_mu) U_mu(x) d_{x+mu,y}
                  + (1 + g_mu) U_mu^dag(x - mu) d_{x-mu,y} ]``

This module is the slowest, clearest implementation; everything else
(even-odd packing, planar float layout, the Pallas kernel) is validated
against it, directly or transitively.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import gamma
from .lattice import NDIM, shift

# Flop count per site of one hopping application, QXS convention (paper
# Sec. 2): 8 hops x (project 12 + SU(3) x half-spinor 132 + reconstruct 12)
# + 7 x 24 accumulate adds + 24 x 2 for the 1 - kappa*H axpy = 1368.
HOP_FLOPS_PER_SITE = 1320
DW_FLOPS_PER_SITE = 1368


def hop(U: jnp.ndarray, psi: jnp.ndarray) -> jnp.ndarray:
    """Apply the hopping term ``H psi`` on the full lattice.

    ``U``: ``(4, T, Z, Y, X, 3, 3)``; ``psi``: ``(T, Z, Y, X, 4, 3)``.
    """
    out = jnp.zeros_like(psi)
    for mu in range(NDIM):
        # Forward: (1 - g_mu) U_mu(x) psi(x + mu).
        fwd = shift(psi, mu, +1)
        h = gamma.project(fwd, mu, s=-1)
        uh = jnp.einsum("...ab,...hb->...ha", U[mu], h)
        out = out + gamma.reconstruct(uh, mu, s=-1)
        # Backward: (1 + g_mu) U_mu^dag(x - mu) psi(x - mu).
        bwd = shift(psi, mu, -1)
        u_bwd = shift(U[mu], mu, -1)  # U_mu(x - mu)
        h = gamma.project(bwd, mu, s=+1)
        uh = jnp.einsum("...ba,...hb->...ha", u_bwd.conj(), h)
        out = out + gamma.reconstruct(uh, mu, s=+1)
    return out


def apply_wilson(U: jnp.ndarray, psi: jnp.ndarray, kappa: float) -> jnp.ndarray:
    """``D_W psi = psi - kappa * H psi``."""
    return psi - kappa * hop(U, psi)


def apply_wilson_dagger(U: jnp.ndarray, psi: jnp.ndarray, kappa: float) -> jnp.ndarray:
    """``D_W^dag psi`` via gamma5-hermiticity: ``D^dag = g5 D g5``."""
    g5 = jnp.asarray(gamma.GAMMA5)
    g5psi = jnp.einsum("ij,...jc->...ic", g5, psi)
    return jnp.einsum("ij,...jc->...ic", g5, apply_wilson(U, g5psi, kappa))
