"""Data pipeline: deterministic synthetic streams + binary token shards.

Design constraints for 1000+ node runs:

* **Deterministic addressing** — batch ``i`` of epoch ``e`` is a pure
  function of ``(seed, e, i)``, so a restarted (or re-meshed) job replays
  the exact token stream from its checkpointed cursor: bitwise-identical
  loss curves across restarts.
* **Shard-aware** — each host materializes only its slice of the global
  batch (``host_slice``); with jax.make_array_from_process_local_data the
  global array is assembled without any cross-host traffic.
* **Zero-copy binary shards** — token files are flat uint16/uint32 memmaps
  with a JSON sidecar; no tokenizer in the hot path.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None      # None -> synthetic
    num_prefix_embeds: int = 0      # vision stub
    d_model: int = 0
    enc_frames: int = 0             # audio stub


class SyntheticLM:
    """Deterministic synthetic LM stream (Zipf-ish unigram + markov mix).

    Cheap to generate, non-trivial to predict, fully reproducible.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed unigram distribution (Zipf alpha=1.1)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = (p / p.sum()).astype(np.float64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        toks = rng.choice(cfg.vocab_size, p=self._p,
                          size=(cfg.global_batch, cfg.seq_len))
        # overlay a deterministic local pattern so loss can fall below
        # unigram entropy (tests train on this)
        toks[:, 1::2] = (toks[:, 0::2] * 31 + 7) % cfg.vocab_size
        out = {"tokens": toks.astype(np.int32),
               "mask": np.ones((cfg.global_batch, cfg.seq_len), np.float32)}
        if cfg.num_prefix_embeds:
            out["prefix_embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.num_prefix_embeds, cfg.d_model)
            ).astype(np.float32) * 0.02
        if cfg.enc_frames:
            out["enc_embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.enc_frames, cfg.d_model)
            ).astype(np.float32) * 0.02
        return out


class BinaryShards:
    """Flat binary token shards: <name>.bin (uint16/uint32) + <name>.json
    metadata {"dtype": ..., "n_tokens": ...}.  Batch ``step`` reads a
    deterministic strided window — restart-safe without an index server.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        path = pathlib.Path(cfg.path)
        meta = json.loads(path.with_suffix(".json").read_text())
        self._tokens = np.memmap(path, dtype=np.dtype(meta["dtype"]),
                                 mode="r")
        self._n = len(self._tokens)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        span = cfg.seq_len + 1
        n_windows = self._n // span
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 1]))
        idx = rng.integers(0, n_windows, size=cfg.global_batch)
        rows = np.stack([self._tokens[i * span: i * span + cfg.seq_len]
                         for i in idx])
        return {"tokens": rows.astype(np.int32),
                "mask": np.ones_like(rows, np.float32)}

    @staticmethod
    def write(path: str, tokens: np.ndarray):
        path = pathlib.Path(path)
        arr = tokens.astype(np.uint32 if tokens.max() > 2 ** 16 - 1
                            else np.uint16)
        arr.tofile(path)
        path.with_suffix(".json").write_text(json.dumps(
            {"dtype": str(arr.dtype), "n_tokens": int(arr.size)}))


def make_source(cfg: DataConfig):
    return BinaryShards(cfg) if cfg.path else SyntheticLM(cfg)


def host_slice(batch: Dict[str, np.ndarray], sharding) -> Dict[str, jax.Array]:
    """Build global sharded arrays from per-host data (single-controller:
    device_put; multi-host: make_array_from_process_local_data)."""
    out = {}
    for k, v in batch.items():
        sh = sharding[k] if isinstance(sharding, dict) else sharding
        if jax.process_count() > 1:
            out[k] = jax.make_array_from_process_local_data(sh, v)
        else:
            out[k] = jax.device_put(v, sh)
    return out
