from . import compress, halo, qcd
from .qcd import QCDPartition, make_dhat_dagger_fn, make_dhat_fn, make_hop_fn
