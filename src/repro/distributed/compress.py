"""Int8 error-feedback gradient compression for the data-parallel
all-reduce (cross-pod traffic is the scarce resource at 1000+ nodes).

Scheme: per-tensor scale ``s = max|g| / 127``; quantize ``q = round(g/s)``
to int8; all-reduce ``q`` (s32 accumulate) and the scales; dequantize with
the mean scale.  The quantization residual is fed back into the next
step's gradient (error feedback), which keeps SGD-style convergence
guarantees (Karimireddy et al., 2019).

4x less DP all-reduce traffic than f32 (2x vs bf16); pairs with the
``pod`` axis where links are longest.  Used inside shard_map (the
explicit-collective path); under plain pjit XLA owns the reduction and
this module is bypassed.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis_name, residual: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce-mean ``g`` over ``axis_name`` in int8 with error
    feedback.  Returns (mean gradient, new residual).

    Two-phase: a scalar ``pmax`` agrees on a shared scale, then the int8
    grid all-reduces exactly — per-element error of the mean is bounded
    by ``shared_scale / 2`` and the residual feeds it back next step."""
    n = lax.psum(1, axis_name)
    gf = g.astype(jnp.float32) + residual
    scale = lax.pmax(jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12),
                     axis_name) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    q_sum = lax.psum(q.astype(jnp.int32), axis_name)
    mean = q_sum.astype(jnp.float32) * scale / n
    return mean, new_residual


def compressed_psum_tree(grads: Any, axis_name, residuals: Any
                         ) -> Tuple[Any, Any]:
    """Tree version; 1-D/small leaves go uncompressed (scales dominate)."""
    def one(g, r):
        if g.size < 4096:
            return lax.pmean(g.astype(jnp.float32), axis_name), r
        return compressed_psum(g, axis_name, r)

    pairs = jax.tree_util.tree_map(one, grads, residuals)
    means = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return means, res


def init_residuals(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
