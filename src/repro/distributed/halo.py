"""Halo exchange for the domain-decomposed lattice.

The lattice is sharded T over (``pod``, ``data``) and Z over ``model``;
x and y stay on-chip inside the packed (Y, Xh) plane, so — unlike the
paper, which needs MPI in all four directions — only the two *simple*
directions ever cross ranks, and the involved x/y boundary pack/unpack
(paper Sec. 3.5, ``compact`` + ``tbl``) disappears by construction.

``ppermute``-based neighbor exchange; corners ride along with the z faces
(harmless — the 8-point stencil never reads them).  Exchange volume per
rank per application: 2 x (Zl x C x Y x Xh) + 2 x ((Tl+2) x C x Y x Xh)
elements.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple, Union

import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.kernels.layout import GAUGE_COMPS, SPINOR_COMPS

AxisNames = Union[str, Tuple[str, ...]]


def _axis_size(axes: AxisNames) -> int:
    if isinstance(axes, str):
        return compat.axis_size(axes)
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def axis_index(axes: AxisNames) -> jnp.ndarray:
    """Linearized index along one or more mesh axes (lexicographic)."""
    if isinstance(axes, str):
        return lax.axis_index(axes)
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + lax.axis_index(a)
    return idx


def neighbor_plane(x: jnp.ndarray, axes: AxisNames, direction: int,
                   axis: int) -> jnp.ndarray:
    """Fetch the face plane from the +-1 neighbor rank along ``axes``.

    ``direction=+1`` returns this rank's *lower* halo filled with the
    neighbor-below's top face... concretely: every rank sends the face that
    its ``direction`` neighbor needs.  With a single rank on the axis the
    permutation is the identity — periodic wrap for free.
    """
    n = _axis_size(axes)
    if direction > 0:
        # halo below local block: receive last plane of rank-1.
        face = lax.slice_in_dim(x, x.shape[axis] - 1, x.shape[axis], axis=axis)
        perm = [(i, (i + 1) % n) for i in range(n)]
    else:
        # halo above local block: receive first plane of rank+1.
        face = lax.slice_in_dim(x, 0, 1, axis=axis)
        perm = [(i, (i - 1) % n) for i in range(n)]
    return lax.ppermute(face, axes, perm)


def extend_tz(x: jnp.ndarray, t_axes: AxisNames, z_axes: AxisNames,
              t_axis: int = 0, z_axis: int = 1) -> jnp.ndarray:
    """Halo-extend a local ``(Tl, Zl, ...)`` array to ``(Tl+2, Zl+2, ...)``."""
    lo_t = neighbor_plane(x, t_axes, +1, t_axis)
    hi_t = neighbor_plane(x, t_axes, -1, t_axis)
    x = jnp.concatenate([lo_t, x, hi_t], axis=t_axis)
    lo_z = neighbor_plane(x, z_axes, +1, z_axis)
    hi_z = neighbor_plane(x, z_axes, -1, z_axis)
    return jnp.concatenate([lo_z, x, hi_z], axis=z_axis)


def local_origin(t_axes: AxisNames, z_axes: AxisNames,
                 t_local: int, z_local: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global (t0, z0) origin of this rank's block."""
    return (axis_index(t_axes) * t_local, axis_index(z_axes) * z_local)


class HaloSlots(NamedTuple):
    """The four in-flight halo faces of one local array.

    Produced by :func:`start_exchange_tz` *before* the interior stencil
    runs; consumed by :func:`assemble_tz` only in the thin boundary
    pass, so the scheduler is free to overlap the ``ppermute`` traffic
    with the interior compute (double-buffered halo slots).
    """

    lo_t: jnp.ndarray   # (1, Zl, ...)  from the t-1 neighbor
    hi_t: jnp.ndarray   # (1, Zl, ...)  from the t+1 neighbor
    lo_z: jnp.ndarray   # (Tl, 1, ...)  from the z-1 neighbor
    hi_z: jnp.ndarray   # (Tl, 1, ...)  from the z+1 neighbor


def start_exchange_tz(x: jnp.ndarray, t_axes: AxisNames, z_axes: AxisNames,
                      t_axis: int = 0, z_axis: int = 1) -> HaloSlots:
    """Issue all four face ``ppermute``s of ``x`` without assembling.

    Unlike :func:`extend_tz` — whose ``concatenate`` makes every
    downstream read depend on the exchange — this returns the in-flight
    faces as separate slots.  All four act on the *un-extended* array,
    so the z faces do NOT carry t-corner sites (the +-stencil never
    reads corners; :func:`assemble_tz` zero-pads them).
    """
    return HaloSlots(
        lo_t=neighbor_plane(x, t_axes, +1, t_axis),
        hi_t=neighbor_plane(x, t_axes, -1, t_axis),
        lo_z=neighbor_plane(x, z_axes, +1, z_axis),
        hi_z=neighbor_plane(x, z_axes, -1, z_axis))


def assemble_tz(x: jnp.ndarray, slots: HaloSlots,
                t_axis: int = 0, z_axis: int = 1) -> jnp.ndarray:
    """Assemble ``(Tl+2, Zl+2, ...)`` from a local block and its slots.

    The four corner sites are zero-filled (the faces were exchanged from
    the un-extended array): equivalent to :func:`extend_tz` for every
    read the 8-point hopping stencil performs, since it never touches a
    diagonal ``(t+-1, z+-1)`` neighbor.
    """
    ext_t = jnp.concatenate([slots.lo_t, x, slots.hi_t], axis=t_axis)
    corner_shape = list(slots.lo_z.shape)
    corner_shape[t_axis] = 1
    corner = jnp.zeros(corner_shape, x.dtype)
    lo_z = jnp.concatenate([corner, slots.lo_z, corner], axis=t_axis)
    hi_z = jnp.concatenate([corner, slots.hi_z, corner], axis=t_axis)
    return jnp.concatenate([lo_z, ext_t, hi_z], axis=z_axis)


def boundary_slab_index(ndim: int, complex_layout: bool, axis: int = 0,
                        index: int = 0) -> Tuple:
    """Index tuple selecting one t/z boundary plane of an even-odd
    spinor field — exactly the slab a halo exchange ships (``axis``
    0 = t faces, 1 = z faces; only t and z ever cross ranks here).

    Understands both vector layouts, with or without a leading nrhs
    axis: complex ``(T, Z, Y, Xh, 4, 3)`` and planar-native
    ``(T, Z, C, Y, Xh)``.  The fault injector
    (``repro.resilience.corrupt_halo_slab``) uses this to poison
    precisely the data a corrupted exchange would have delivered.
    """
    base = 6 if complex_layout else 5
    if ndim not in (base, base + 1):
        raise ValueError(
            f"unrecognized spinor layout: ndim={ndim} for "
            f"{'complex' if complex_layout else 'planar'} data")
    if axis not in (0, 1):
        raise ValueError("axis must be 0 (t faces) or 1 (z faces)")
    idx: list = [slice(None)] * ndim
    idx[(ndim - base) + axis] = index
    return tuple(idx)


def halo_traffic_model(Tl: int, Zl: int, Y: int, Xh: int, *,
                       nrhs: int = 1, itemsize: int = 4,
                       gauge_comps: int = GAUGE_COMPS) -> dict:
    """Per-rank interconnect bytes of one hopping-block halo exchange.

    ``extend_tz`` moves 2 t-faces of ``Zl`` planes and 2 z-faces of
    ``Tl + 2`` planes (corners ride along); the slot-based overlap path
    moves the same faces minus the 4 corner rows (``Tl`` instead of
    ``Tl + 2``) — the model uses the extend_tz count, an upper bound
    either way.  Spinor faces scale with ``nrhs``; gauge faces scale
    with ``gauge_comps`` — *compressed links are shipped compressed*, so
    the two_row/minimal representations cut gauge halo traffic by the
    same 33%/55% as their storage.  A Dhat application runs two hopping
    blocks (one per parity): double everything for the operator.
    """
    face_sites = (2 * Zl + 2 * (Tl + 2)) * Y * Xh
    bytes_spinor = itemsize * nrhs * SPINOR_COMPS * face_sites
    bytes_gauge = itemsize * 4 * gauge_comps * face_sites
    return {
        "face_sites": face_sites,
        "bytes_spinor_exchange": bytes_spinor,
        "bytes_gauge_exchange": bytes_gauge,
        "bytes_hop_exchange": bytes_spinor + bytes_gauge,
        "bytes_dhat_exchange": 2 * (bytes_spinor + bytes_gauge),
    }
