"""Halo exchange for the domain-decomposed lattice.

The lattice is sharded T over (``pod``, ``data``) and Z over ``model``;
x and y stay on-chip inside the packed (Y, Xh) plane, so — unlike the
paper, which needs MPI in all four directions — only the two *simple*
directions ever cross ranks, and the involved x/y boundary pack/unpack
(paper Sec. 3.5, ``compact`` + ``tbl``) disappears by construction.

``ppermute``-based neighbor exchange; corners ride along with the z faces
(harmless — the 8-point stencil never reads them).  Exchange volume per
rank per application: 2 x (Zl x C x Y x Xh) + 2 x ((Tl+2) x C x Y x Xh)
elements.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax.numpy as jnp
from jax import lax

from repro import compat

AxisNames = Union[str, Tuple[str, ...]]


def _axis_size(axes: AxisNames) -> int:
    if isinstance(axes, str):
        return compat.axis_size(axes)
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


def axis_index(axes: AxisNames) -> jnp.ndarray:
    """Linearized index along one or more mesh axes (lexicographic)."""
    if isinstance(axes, str):
        return lax.axis_index(axes)
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + lax.axis_index(a)
    return idx


def neighbor_plane(x: jnp.ndarray, axes: AxisNames, direction: int,
                   axis: int) -> jnp.ndarray:
    """Fetch the face plane from the +-1 neighbor rank along ``axes``.

    ``direction=+1`` returns this rank's *lower* halo filled with the
    neighbor-below's top face... concretely: every rank sends the face that
    its ``direction`` neighbor needs.  With a single rank on the axis the
    permutation is the identity — periodic wrap for free.
    """
    n = _axis_size(axes)
    if direction > 0:
        # halo below local block: receive last plane of rank-1.
        face = lax.slice_in_dim(x, x.shape[axis] - 1, x.shape[axis], axis=axis)
        perm = [(i, (i + 1) % n) for i in range(n)]
    else:
        # halo above local block: receive first plane of rank+1.
        face = lax.slice_in_dim(x, 0, 1, axis=axis)
        perm = [(i, (i - 1) % n) for i in range(n)]
    return lax.ppermute(face, axes, perm)


def extend_tz(x: jnp.ndarray, t_axes: AxisNames, z_axes: AxisNames,
              t_axis: int = 0, z_axis: int = 1) -> jnp.ndarray:
    """Halo-extend a local ``(Tl, Zl, ...)`` array to ``(Tl+2, Zl+2, ...)``."""
    lo_t = neighbor_plane(x, t_axes, +1, t_axis)
    hi_t = neighbor_plane(x, t_axes, -1, t_axis)
    x = jnp.concatenate([lo_t, x, hi_t], axis=t_axis)
    lo_z = neighbor_plane(x, z_axes, +1, z_axis)
    hi_z = neighbor_plane(x, z_axes, -1, z_axis)
    return jnp.concatenate([lo_z, x, hi_z], axis=z_axis)


def local_origin(t_axes: AxisNames, z_axes: AxisNames,
                 t_local: int, z_local: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global (t0, z0) origin of this rank's block."""
    return (axis_index(t_axes) * t_local, axis_index(z_axes) * z_local)
