"""Distributed even-odd Wilson operator: shard_map over the production mesh.

Sharding: lattice T over (``pod``, ``data``), Z over ``model``; the packed
(Y, Xh) plane — the SIMD-analogue dims — is never sharded.  The hopping
blocks therefore need halo exchange only for z/t, via ``lax.ppermute``.

Three overlap modes (paper Sec. 3.5/3.6):

* ``fused``: halo-extend (ppermute + concat), then one kernel over the
  extended array.  Simplest; XLA may still overlap the ppermutes with
  whatever precedes the operator.
* ``interior``: the comms/compute-overlap mode.  The four face
  ``ppermute``s are issued FIRST as double-buffered halo slots
  (:func:`halo.start_exchange_tz`), then the interior ``(Tl-2, Zl-2)``
  block — whose stencil reach lies entirely inside the local block, so
  it has NO data dependence on the exchange — runs the main kernel
  while the faces are in flight; a thin 1-plane boundary pass consumes
  the assembled halos and the rows are concatenated.  Unlike ``split``
  nothing is recomputed and multi-RHS batching works (the boundary pass
  is the batch-polymorphic planar-native stencil).
* ``split``: the *bulk* kernel runs on local data with periodic wrap and
  does not depend on the ppermutes, so the scheduler can overlap the halo
  traffic with the full bulk stencil (the EO1 / bulk / EO2 structure);
  boundary planes are then recomputed from the halos and merged
  (single-RHS only).

Backends: ``pallas`` (the TPU kernel; interpret-mode off-TPU) or ``jnp``
(pure-XLA reference path, also used by the CPU dry-run so the lowered HLO
is kernel-free and fully analyzable).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core import evenodd
from repro.kernels import ref as kref
from repro.kernels.wilson_stencil import (hop_block_ext_planar_native,
                                          hop_block_planar)
from . import halo


@dataclasses.dataclass(frozen=True)
class QCDPartition:
    """How the lattice maps onto the device mesh."""

    mesh: Mesh
    t_axes: Tuple[str, ...]
    z_axes: Tuple[str, ...]
    backend: str = "jnp"          # "jnp" | "jnp_planar" | "pallas"
    overlap: str = "fused"        # "fused" | "interior" | "split"
    interpret: Optional[bool] = None
    # hoist the gauge halo exchange out of the operator: the gauge field
    # is solver-invariant, so its halos are exchanged ONCE per solve and
    # the operator takes pre-extended gauge arrays (beyond-paper: the
    # paper re-packs gauge boundaries every application)
    hoist_gauge: bool = False

    @classmethod
    def for_mesh(cls, mesh: Mesh, **kw) -> "QCDPartition":
        names = mesh.axis_names
        t_axes = tuple(a for a in ("pod", "data") if a in names)
        z_axes = tuple(a for a in ("model",) if a in names)
        if not t_axes or not z_axes:
            raise ValueError(f"mesh {names} lacks the expected axes")
        return cls(mesh=mesh, t_axes=t_axes, z_axes=z_axes, **kw)

    # PartitionSpecs for the planar arrays.
    @property
    def spinor_spec(self) -> P:
        return P(self.t_axes, self.z_axes, None, None, None)

    @property
    def batched_spinor_spec(self) -> P:
        """Spec for a multi-RHS planar block ``(nrhs, T, Z, 24, Y, Xh)``:
        the RHS axis is replicated, the lattice sharding is unchanged."""
        return P(None, self.t_axes, self.z_axes, None, None, None)

    @property
    def gauge_spec(self) -> P:
        return P(None, self.t_axes, self.z_axes, None, None, None)

    def spinor_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.spinor_spec)

    def batched_spinor_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.batched_spinor_spec)

    def gauge_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.gauge_spec)


def _local_hop(part: QCDPartition, u_out, u_in, src, out_parity,
               u_in_pre_extended: bool = False):
    """One hopping block on this rank's block (inside shard_map).

    ``src`` may carry a leading multi-RHS axis ``(nrhs, Tl, Zl, 24, Y,
    Xh)``: the halo exchange then moves the whole batched face in ONE
    ``ppermute`` per direction (instead of nrhs exchanges) and the local
    stencil runs the batched kernel.
    """
    batched = src.ndim == 6
    lead = 1 if batched else 0
    Tl, Zl = src.shape[lead], src.shape[lead + 1]
    t0, z0 = halo.local_origin(part.t_axes, part.z_axes, Tl, Zl)

    if part.overlap == "interior":
        return _interior_overlap_hop(part, u_out, u_in, src, out_parity,
                                     batched, lead, Tl, Zl, t0, z0,
                                     u_in_pre_extended)

    src_ext = halo.extend_tz(src, part.t_axes, part.z_axes, lead, lead + 1)
    u_in_ext = (u_in if u_in_pre_extended else
                halo.extend_tz(u_in, part.t_axes, part.z_axes, 1, 2))

    if part.overlap == "fused":
        if part.backend == "pallas":
            return hop_block_planar(u_out, u_in_ext, src_ext, out_parity,
                                    tz_offset=(t0, z0), halo=True,
                                    interpret=part.interpret)
        if part.backend == "jnp_planar":
            return hop_block_ext_planar_native(u_out, u_in_ext, src_ext,
                                               out_parity, (t0 + z0) % 2)
        if batched:
            # complex-roundtrip local stencil isn't batch-polymorphic;
            # vmap it (the halo exchange above already ran once for the
            # whole block, outside the vmap)
            return jax.vmap(lambda s: kref.hop_block_ext_planar(
                u_out, u_in_ext, s, out_parity, (t0 + z0) % 2))(src_ext)
        return kref.hop_block_ext_planar(u_out, u_in_ext, src_ext,
                                         out_parity, (t0 + z0) % 2)

    if part.overlap != "split":
        raise ValueError(f"unknown overlap mode {part.overlap!r}: "
                         "expected 'fused', 'interior' or 'split'")
    if batched:
        raise ValueError("multi-RHS batching requires overlap='fused' or "
                         "'interior' (the split boundary-recompute path "
                         "is single-RHS only)")

    # --- split: bulk with periodic wrap (no halo dependency) ------------
    if part.backend == "pallas":
        bulk = hop_block_planar(u_out, u_in, src, out_parity,
                                tz_offset=(t0, z0), halo=False,
                                interpret=part.interpret)
    else:
        # periodic-local jnp bulk via the same ext code on a wrapped array
        wrap_t = jnp.concatenate([src[-1:], src, src[:1]], axis=0)
        src_w = jnp.concatenate([wrap_t[:, -1:], wrap_t, wrap_t[:, :1]], axis=1)
        uw_t = jnp.concatenate([u_in[:, -1:], u_in, u_in[:, :1]], axis=1)
        u_w = jnp.concatenate([uw_t[:, :, -1:], uw_t, uw_t[:, :, :1]], axis=2)
        bulk = kref.hop_block_ext_planar(u_out, u_w, src_w, out_parity,
                                         (t0 + z0) % 2)

    # --- boundary recompute from halos (EO2 analogue) -------------------
    def fix(sl_t, sl_z, uo_t, uo_z, off):
        sub_src = src_ext[sl_t, sl_z]
        sub_uin = u_in_ext[:, sl_t, sl_z]
        sub_uout = u_out[:, uo_t, uo_z]
        return kref.hop_block_ext_planar(sub_uout, sub_uin, sub_src,
                                         out_parity, off)

    if Tl < 2 or Zl < 2:
        raise ValueError("overlap='split' needs local T,Z >= 2; use 'fused'")
    all_ = slice(None)
    par0 = (t0 + z0) % 2
    # t-boundary planes (full z extent, z halos included in the slab).
    lo_t = fix(slice(0, 3), all_, slice(0, 1), all_, par0)
    hi_t = fix(slice(Tl - 1, Tl + 2), all_, slice(Tl - 1, Tl), all_,
               (t0 + Tl - 1 + z0) % 2)
    # z-boundary planes (full t extent, t halos included in the slab).
    lo_z = fix(all_, slice(0, 3), all_, slice(0, 1), par0)
    hi_z = fix(all_, slice(Zl - 1, Zl + 2), all_, slice(Zl - 1, Zl),
               (t0 + z0 + Zl - 1) % 2)
    out = bulk.at[0:1].set(lo_t).at[Tl - 1:Tl].set(hi_t)
    out = out.at[:, 0:1].set(lo_z).at[:, Zl - 1:Zl].set(hi_z)
    return out


def _interior_overlap_hop(part: QCDPartition, u_out, u_in, src, out_parity,
                          batched, lead, Tl, Zl, t0, z0,
                          u_in_pre_extended):
    """Comms/compute-overlapped hopping block (``overlap='interior'``).

    Schedule, in issue order:

    1. the four spinor face ``ppermute``s (plus the four gauge faces
       unless the gauge was pre-extended) are issued first, as
       double-buffered :class:`halo.HaloSlots` — no concat, so nothing
       the interior reads depends on them;
    2. the **interior pass** computes output rows ``(1..Tl-2, 1..Zl-2)``
       with the main (Pallas or planar-native) kernel: the un-extended
       local block already holds every stencil operand of the interior —
       it IS the halo-extended array of the interior sub-block — so the
       kernel runs while the faces are in flight;
    3. the slots are assembled into halo-extended arrays (corners
       zero-padded; never read by the +-stencil);
    4. the **boundary pass** recomputes nothing: four 1-plane slabs (two
       t-rows over the full z extent, two z-columns over the interior t
       rows) run the batch-polymorphic planar-native stencil on thin
       slices of the assembled arrays;
    5. rows are merged by concatenation (corner sites land in the t-row
       slabs; the z-column slabs are trimmed to the interior t range).

    Needs local ``Tl, Zl >= 3`` so the interior block is non-empty.
    """
    if Tl < 3 or Zl < 3:
        raise ValueError("overlap='interior' needs local T,Z >= 3 (a "
                         "non-empty interior after peeling one boundary "
                         "plane per side); use 'fused' for thin shards")

    # (1) issue the exchange; nothing before step (3) depends on it.
    src_slots = halo.start_exchange_tz(src, part.t_axes, part.z_axes,
                                       lead, lead + 1)
    if u_in_pre_extended:
        u_in_local = u_in[:, 1:-1, 1:-1]
        u_slots = None
    else:
        u_in_local = u_in
        u_slots = halo.start_exchange_tz(u_in, part.t_axes, part.z_axes,
                                         1, 2)

    # (2) interior pass on the un-extended local block.
    u_out_int = u_out[:, 1:-1, 1:-1]
    par_int = (t0 + 1 + z0 + 1) % 2
    if part.backend == "pallas":
        interior = hop_block_planar(u_out_int, u_in_local, src, out_parity,
                                    tz_offset=(t0 + 1, z0 + 1), halo=True,
                                    interpret=part.interpret)
    elif part.backend == "jnp_planar":
        interior = hop_block_ext_planar_native(u_out_int, u_in_local, src,
                                               out_parity, par_int)
    elif batched:
        interior = jax.vmap(lambda s: kref.hop_block_ext_planar(
            u_out_int, u_in_local, s, out_parity, par_int))(src)
    else:
        interior = kref.hop_block_ext_planar(u_out_int, u_in_local, src,
                                             out_parity, par_int)

    # (3) assemble the halo-extended views from the landed slots.
    src_ext = halo.assemble_tz(src, src_slots, lead, lead + 1)
    u_in_ext = (u_in if u_in_pre_extended else
                halo.assemble_tz(u_in, u_slots, 1, 2))

    # (4) boundary pass: thin slabs through the planar-native stencil
    # (batch-polymorphic, so multi-RHS blocks work — unlike 'split').
    bidx = (slice(None),) * lead

    def slab(sl_t, sl_z, uo_t, uo_z, off):
        sub_src = src_ext[bidx + (sl_t, sl_z)]
        sub_uin = u_in_ext[:, sl_t, sl_z]
        sub_uout = u_out[:, uo_t, uo_z]
        return hop_block_ext_planar_native(sub_uout, sub_uin, sub_src,
                                           out_parity, off)

    all_ = slice(None)
    par0 = (t0 + z0) % 2
    lo_t = slab(slice(0, 3), all_, slice(0, 1), all_, par0)
    hi_t = slab(slice(Tl - 1, Tl + 2), all_, slice(Tl - 1, Tl), all_,
                (t0 + Tl - 1 + z0) % 2)
    lo_z = slab(all_, slice(0, 3), all_, slice(0, 1), par0)
    hi_z = slab(all_, slice(Zl - 1, Zl + 2), all_, slice(Zl - 1, Zl),
                (t0 + z0 + Zl - 1) % 2)

    # (5) merge by concatenation — no scatter on the hot path.
    t_int = slice(1, Tl - 1)
    mid = jnp.concatenate([lo_z[bidx + (t_int,)], interior,
                           hi_z[bidx + (t_int,)]], axis=lead + 1)
    return jnp.concatenate([lo_t, mid, hi_t], axis=lead)


def make_hop_fn(part: QCDPartition, out_parity: int, *,
                batched: bool = False):
    """Global (sharded-array) hopping block as a pjit-able function.

    ``batched=True`` builds the multi-RHS variant: spinor arguments carry
    a leading ``nrhs`` axis (replicated over the mesh) and each hop does
    ONE batched halo exchange for the whole block.
    """
    sspec = part.batched_spinor_spec if batched else part.spinor_spec

    def local_fn(u_out, u_in, src):
        return _local_hop(part, u_out, u_in, src, out_parity)

    return shard_map(
        local_fn, mesh=part.mesh,
        in_specs=(part.gauge_spec, part.gauge_spec, sspec),
        out_specs=sspec, check_vma=False)


def make_dhat_fn(part: QCDPartition, kappa: float, *,
                 batched: bool = False):
    """Even-odd preconditioned operator on globally sharded planar arrays.

    Returns ``f(u_e_p, u_o_p, psi_e_p) -> (1 - kappa^2 H_eo H_oe) psi_e``.
    With ``part.hoist_gauge`` the gauge arguments must be pre-extended via
    :func:`make_gauge_extender` (halo'd once per solve, not per apply).
    ``batched`` as in :func:`make_hop_fn`.
    """
    k2 = float(kappa) ** 2
    pre = part.hoist_gauge
    sspec = part.batched_spinor_spec if batched else part.spinor_spec

    def local_fn(u_e, u_o, psi_e):
        tmp = _local_hop(part, u_o, u_e, psi_e, evenodd.ODD,
                         u_in_pre_extended=pre)
        hop2 = _local_hop(part, u_e, u_o, tmp, evenodd.EVEN,
                          u_in_pre_extended=pre)
        return psi_e - jnp.asarray(k2, psi_e.dtype) * hop2

    if pre:
        # u_out is read unextended: strip the halo ring locally (cheap
        # slice) so one pre-extended array serves both roles
        inner = local_fn

        def local_fn(u_e_ext, u_o_ext, psi_e):  # noqa: F811
            tmp = _local_hop(part, u_o_ext[:, 1:-1, 1:-1], u_e_ext,
                             psi_e, evenodd.ODD, u_in_pre_extended=True)
            hop2 = _local_hop(part, u_e_ext[:, 1:-1, 1:-1], u_o_ext,
                              tmp, evenodd.EVEN, u_in_pre_extended=True)
            return psi_e - jnp.asarray(k2, psi_e.dtype) * hop2

    return shard_map(
        local_fn, mesh=part.mesh,
        in_specs=(part.gauge_spec, part.gauge_spec, sspec),
        out_specs=sspec, check_vma=False)


def make_gauge_extender(part: QCDPartition):
    """Returns f(u_p) -> halo-extended gauge (run once per solve)."""
    def local_fn(u):
        return halo.extend_tz(u, part.t_axes, part.z_axes, 1, 2)

    return shard_map(
        local_fn, mesh=part.mesh, in_specs=(part.gauge_spec,),
        out_specs=part.gauge_spec, check_vma=False)


def make_dhat_dagger_fn(part: QCDPartition, kappa: float, *,
                        batched: bool = False):
    """``Dhat^dag`` on sharded planar arrays via gamma5-hermiticity.

    gamma5 in the planar layout flips the sign of spin components 2,3
    (DeGrand-Rossi basis), i.e. planar components 12..23 (batch-
    polymorphic: it acts on the trailing ``(24, Y, Xh)`` dims).
    """
    from repro.kernels.layout import gamma5_planar

    dhat = make_dhat_fn(part, kappa, batched=batched)

    def fn(u_e, u_o, psi_e):
        return gamma5_planar(dhat(u_e, u_o, gamma5_planar(psi_e)))

    return fn
