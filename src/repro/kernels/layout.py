"""Planar (re/im-separated) field layout for the TPU kernel.

The A64FX implementation keeps real and imaginary parts in *separate* SIMD
vectors and packs an x-y tile of sites into each vector (paper Sec. 3.2).
The TPU analogue puts the ``(Y, Xh)`` site plane in the two trailing array
dims — sublanes x lanes of the VPU — and splits complex numbers into a
re/im component axis:

* spinor: ``(T, Z, Y, Xh, 4, 3)`` complex  <->  ``(T, Z, 24, Y, Xh)`` real
  with component index ``c = (spin * 3 + color) * 2 + reim``;
* gauge:  ``(4, T, Z, Y, Xh, 3, 3)`` complex <-> ``(4, T, Z, 18, Y, Xh)``
  real with ``c = (row * 3 + col) * 2 + reim``.

This is the AoSoA layout of Eq. (6)/(7) with the SIMD vector grown to a
whole VMEM-resident plane.

Multi-RHS batching: the spinor conversions accept arbitrary *leading*
batch dims, so a block of right-hand sides ``(nrhs, T, Z, Y, Xh, 4, 3)``
maps to the batched planar layout ``(nrhs, T, Z, 24, Y, Xh)`` — the
layout the batched kernels eat while loading each gauge plane once for
the whole block.
"""
from __future__ import annotations

import jax.numpy as jnp

SPINOR_COMPS = 24  # 4 spin x 3 color x re/im
GAUGE_COMPS = 18   # 3 x 3 x re/im
GAUGE_COMPS_TWO_ROW = 12   # rows a, b; c = conj(a x b) rebuilt in-register
GAUGE_COMPS_MINIMAL = 8    # a2, a3, b1 + phases of a1, c1

#: compression mode -> planar component-plane count
GAUGE_COMPRESSIONS = {
    "none": GAUGE_COMPS,
    "two_row": GAUGE_COMPS_TWO_ROW,
    "minimal": GAUGE_COMPS_MINIMAL,
}


def _real_dtype_of(complex_dtype):
    return (jnp.float64 if jnp.dtype(complex_dtype) == jnp.dtype(jnp.complex128)
            else jnp.float32)


def spinor_to_planar(psi: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """``(..., T, Z, Y, Xh, 4, 3)`` complex -> ``(..., T, Z, 24, Y, Xh)``.

    Leading batch dims (the multi-RHS axis) pass through unchanged.
    """
    *batch, T, Z, Y, Xh = psi.shape[:-2]
    arr = jnp.stack([psi.real, psi.imag], axis=-1)    # (...,T,Z,Y,Xh,4,3,2)
    # (Y, Xh) to the trailing (sublane, lane) position.
    arr = jnp.moveaxis(arr, (-5, -4), (-2, -1))       # (...,T,Z,4,3,2,Y,Xh)
    return arr.reshape(*batch, T, Z, SPINOR_COMPS, Y, Xh).astype(dtype)


def spinor_from_planar(p: jnp.ndarray, dtype=jnp.complex64) -> jnp.ndarray:
    """Inverse of :func:`spinor_to_planar` (batch dims pass through)."""
    *batch, T, Z, _, Y, Xh = p.shape
    arr = p.astype(_real_dtype_of(dtype)).reshape(*batch, T, Z, 4, 3, 2, Y, Xh)
    arr = jnp.moveaxis(arr, (-2, -1), (-5, -4))       # (...,T,Z,Y,Xh,4,3,2)
    return (arr[..., 0] + 1j * arr[..., 1]).astype(dtype)


def gamma5_planar(p: jnp.ndarray) -> jnp.ndarray:
    """``gamma5 psi`` directly on a planar spinor ``(..., 24, Y, Xh)``.

    ``gamma5 = diag(1, 1, -1, -1)`` in this basis, and the planar
    component index is ``(spin * 3 + color) * 2 + reim``, so it simply
    negates component planes 12..23 — no complex round-trip needed.
    """
    sign = jnp.concatenate([jnp.ones((12,), p.dtype),
                            -jnp.ones((12,), p.dtype)])
    return p * sign.reshape(SPINOR_COMPS, 1, 1)


def gauge_to_planar(u: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """``(4, T, Z, Y, Xh, 3, 3)`` complex -> ``(4, T, Z, 18, Y, Xh)`` real."""
    _, T, Z, Y, Xh = u.shape[:5]
    arr = jnp.stack([u.real, u.imag], axis=-1)           # (4,T,Z,Y,Xh,3,3,2)
    arr = arr.transpose(0, 1, 2, 5, 6, 7, 3, 4)          # (4,T,Z,3,3,2,Y,Xh)
    return arr.reshape(4, T, Z, GAUGE_COMPS, Y, Xh).astype(dtype)


def gauge_from_planar(p: jnp.ndarray, dtype=jnp.complex64) -> jnp.ndarray:
    """Inverse of :func:`gauge_to_planar`.

    Accepts compressed planar gauge fields too (12 or 8 component
    planes): they are expanded to the full 18 planes first, so every
    caller that round-trips through the complex form sees reconstructed
    full SU(3) links regardless of the storage representation.
    """
    if p.shape[-3] != GAUGE_COMPS:
        p = gauge_expand_planar(p)
    _, T, Z, _, Y, Xh = p.shape
    arr = p.astype(_real_dtype_of(dtype)).reshape(4, T, Z, 3, 3, 2, Y, Xh)
    arr = arr.transpose(0, 1, 2, 6, 7, 3, 4, 5)          # (4,T,Z,Y,Xh,3,3,2)
    return (arr[..., 0] + 1j * arr[..., 1]).astype(dtype)


# --- SU(3) link compression (planar form) ----------------------------
#
# Component-plane index is c = (row * 3 + col) * 2 + reim, i.e. planes
#   a1=(0,1)  a2=(2,3)  a3=(4,5)     row a = U[0,:]
#   b1=(6,7)  b2=(8,9)  b3=(10,11)   row b = U[1,:]
#   c1=(12,13) c2=(14,15) c3=(16,17) row c = U[2,:]
#
# two_row (12 real): keep rows a and b — a contiguous plane slice — and
# rebuild c = conj(a x b) in-register (~42 extra flops per link).
#
# minimal (8 real): keep a2, a3, b1 and the *phases* of a1 and c1.
# Unitarity fixes the moduli: with D = |a2|^2 + |a3|^2,
#   |a1| = sqrt(1 - D),   |c1| = sqrt(D - |b1|^2),
# and the pair (b2, b3) solves the 2x2 linear system given by
#   a2 b3 - a3 b2 = conj(c1)   (c = conj(a x b))
#   conj(a2) b2 + conj(a3) b3 = -conj(a1) b1   (row orthogonality)
# whose determinant is -D, so reconstruction divides by D once
# (~150 extra flops per link, incl. sin/cos). Degenerate caveat: at
# D = 0 (e.g. the unit gauge, |a1| = 1) the system is singular and the
# stored 8 numbers no longer determine the link — "minimal" is for
# *interacting* (random/thermalized) gauge fields; the 1/D division is
# clamped so free-field links degrade gracefully instead of NaN-ing.


def _cmul(ar, ai, br, bi):
    """(ar + i ai)(br + i bi) on split re/im planes."""
    return ar * br - ai * bi, ar * bi + ai * br


def expand_links_planes(u):
    """Expand one direction's planar link planes ``(gc, ...)`` to 18.

    ``u`` has the component axis *leading* (the orientation the hopping
    kernels index); trailing dims are arbitrary. For ``gc == 18`` the
    input is returned unchanged — an expanded call site adds nothing to
    the jaxpr. Otherwise a list of the 18 component planes is returned
    (callers index it exactly like an array's leading axis).

    Reconstruction is element-wise, so lane/sublane rolls and boundary
    masks commute with it — kernels shift the *compressed* planes and
    expand after, which is cheaper.
    """
    gc = u.shape[0]
    if gc == GAUGE_COMPS:
        return u
    if gc == GAUGE_COMPS_TWO_ROW:
        (a1r, a1i, a2r, a2i, a3r, a3i,
         b1r, b1i, b2r, b2i, b3r, b3i) = (u[i] for i in range(12))
        # c1 = conj(a2 b3 - a3 b2)
        t1r, t1i = _cmul(a2r, a2i, b3r, b3i)
        t2r, t2i = _cmul(a3r, a3i, b2r, b2i)
        c1r, c1i = t1r - t2r, t2i - t1i
    elif gc == GAUGE_COMPS_MINIMAL:
        a2r, a2i, a3r, a3i, b1r, b1i, tha, thc = (u[i] for i in range(8))
        d = a2r * a2r + a2i * a2i + a3r * a3r + a3i * a3i
        a1m = jnp.sqrt(jnp.maximum(1.0 - d, 0.0))
        a1r, a1i = a1m * jnp.cos(tha), a1m * jnp.sin(tha)
        c1m = jnp.sqrt(jnp.maximum(d - (b1r * b1r + b1i * b1i), 0.0))
        c1r, c1i = c1m * jnp.cos(thc), c1m * jnp.sin(thc)
        dinv = 1.0 / jnp.maximum(d, 1e-30)
        # s = -conj(a1) b1
        sr, si = _cmul(a1r, -a1i, b1r, b1i)
        sr, si = -sr, -si
        # b2 = (a2 s - conj(a3) conj(c1)) / D
        t1r, t1i = _cmul(a2r, a2i, sr, si)
        t2r, t2i = _cmul(a3r, -a3i, c1r, -c1i)
        b2r, b2i = (t1r - t2r) * dinv, (t1i - t2i) * dinv
        # b3 = (a3 s + conj(a2) conj(c1)) / D
        t3r, t3i = _cmul(a3r, a3i, sr, si)
        t4r, t4i = _cmul(a2r, -a2i, c1r, -c1i)
        b3r, b3i = (t3r + t4r) * dinv, (t3i + t4i) * dinv
    else:
        raise ValueError(
            f"planar gauge block has {gc} component planes; expected one "
            f"of {sorted(GAUGE_COMPRESSIONS.values())}")
    # c2 = conj(a3 b1 - a1 b3), c3 = conj(a1 b2 - a2 b1)
    t1r, t1i = _cmul(a3r, a3i, b1r, b1i)
    t2r, t2i = _cmul(a1r, a1i, b3r, b3i)
    c2r, c2i = t1r - t2r, t2i - t1i
    t1r, t1i = _cmul(a1r, a1i, b2r, b2i)
    t2r, t2i = _cmul(a2r, a2i, b1r, b1i)
    c3r, c3i = t1r - t2r, t2i - t1i
    return [a1r, a1i, a2r, a2i, a3r, a3i,
            b1r, b1i, b2r, b2i, b3r, b3i,
            c1r, c1i, c2r, c2i, c3r, c3i]


def gauge_compress_planar(p: jnp.ndarray, mode: str) -> jnp.ndarray:
    """Compress a full planar gauge field ``(4, T, Z, 18, Y, Xh)``.

    ``mode`` is one of :data:`GAUGE_COMPRESSIONS`; ``"none"`` returns
    the input unchanged. The compressed array keeps the same axis order
    with a smaller component-plane axis (12 or 8).
    """
    if mode in (None, "none"):
        return p
    if p.shape[-3] != GAUGE_COMPS:
        raise ValueError(
            f"can only compress a full 18-plane gauge field, got "
            f"{p.shape[-3]} planes")
    if mode == "two_row":
        return p[..., :GAUGE_COMPS_TWO_ROW, :, :]
    if mode == "minimal":
        u = jnp.moveaxis(p, -3, 0)
        f32 = jnp.float32 if p.dtype != jnp.float64 else jnp.float64
        tha = jnp.arctan2(u[1].astype(f32), u[0].astype(f32)).astype(p.dtype)
        thc = jnp.arctan2(u[13].astype(f32), u[12].astype(f32)).astype(p.dtype)
        planes = [u[2], u[3], u[4], u[5], u[6], u[7], tha, thc]
        return jnp.moveaxis(jnp.stack(planes), 0, -3)
    raise ValueError(f"unknown gauge compression mode {mode!r}")


def gauge_expand_planar(p: jnp.ndarray) -> jnp.ndarray:
    """Expand a compressed planar gauge field back to 18 planes."""
    if p.shape[-3] == GAUGE_COMPS:
        return p
    planes = expand_links_planes(jnp.moveaxis(p, -3, 0))
    return jnp.moveaxis(jnp.stack(planes), 0, -3)
