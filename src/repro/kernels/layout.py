"""Planar (re/im-separated) field layout for the TPU kernel.

The A64FX implementation keeps real and imaginary parts in *separate* SIMD
vectors and packs an x-y tile of sites into each vector (paper Sec. 3.2).
The TPU analogue puts the ``(Y, Xh)`` site plane in the two trailing array
dims — sublanes x lanes of the VPU — and splits complex numbers into a
re/im component axis:

* spinor: ``(T, Z, Y, Xh, 4, 3)`` complex  <->  ``(T, Z, 24, Y, Xh)`` real
  with component index ``c = (spin * 3 + color) * 2 + reim``;
* gauge:  ``(4, T, Z, Y, Xh, 3, 3)`` complex <-> ``(4, T, Z, 18, Y, Xh)``
  real with ``c = (row * 3 + col) * 2 + reim``.

This is the AoSoA layout of Eq. (6)/(7) with the SIMD vector grown to a
whole VMEM-resident plane.

Multi-RHS batching: the spinor conversions accept arbitrary *leading*
batch dims, so a block of right-hand sides ``(nrhs, T, Z, Y, Xh, 4, 3)``
maps to the batched planar layout ``(nrhs, T, Z, 24, Y, Xh)`` — the
layout the batched kernels eat while loading each gauge plane once for
the whole block.
"""
from __future__ import annotations

import jax.numpy as jnp

SPINOR_COMPS = 24  # 4 spin x 3 color x re/im
GAUGE_COMPS = 18   # 3 x 3 x re/im


def _real_dtype_of(complex_dtype):
    return (jnp.float64 if jnp.dtype(complex_dtype) == jnp.dtype(jnp.complex128)
            else jnp.float32)


def spinor_to_planar(psi: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """``(..., T, Z, Y, Xh, 4, 3)`` complex -> ``(..., T, Z, 24, Y, Xh)``.

    Leading batch dims (the multi-RHS axis) pass through unchanged.
    """
    *batch, T, Z, Y, Xh = psi.shape[:-2]
    arr = jnp.stack([psi.real, psi.imag], axis=-1)    # (...,T,Z,Y,Xh,4,3,2)
    # (Y, Xh) to the trailing (sublane, lane) position.
    arr = jnp.moveaxis(arr, (-5, -4), (-2, -1))       # (...,T,Z,4,3,2,Y,Xh)
    return arr.reshape(*batch, T, Z, SPINOR_COMPS, Y, Xh).astype(dtype)


def spinor_from_planar(p: jnp.ndarray, dtype=jnp.complex64) -> jnp.ndarray:
    """Inverse of :func:`spinor_to_planar` (batch dims pass through)."""
    *batch, T, Z, _, Y, Xh = p.shape
    arr = p.astype(_real_dtype_of(dtype)).reshape(*batch, T, Z, 4, 3, 2, Y, Xh)
    arr = jnp.moveaxis(arr, (-2, -1), (-5, -4))       # (...,T,Z,Y,Xh,4,3,2)
    return (arr[..., 0] + 1j * arr[..., 1]).astype(dtype)


def gamma5_planar(p: jnp.ndarray) -> jnp.ndarray:
    """``gamma5 psi`` directly on a planar spinor ``(..., 24, Y, Xh)``.

    ``gamma5 = diag(1, 1, -1, -1)`` in this basis, and the planar
    component index is ``(spin * 3 + color) * 2 + reim``, so it simply
    negates component planes 12..23 — no complex round-trip needed.
    """
    sign = jnp.concatenate([jnp.ones((12,), p.dtype),
                            -jnp.ones((12,), p.dtype)])
    return p * sign.reshape(SPINOR_COMPS, 1, 1)


def gauge_to_planar(u: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """``(4, T, Z, Y, Xh, 3, 3)`` complex -> ``(4, T, Z, 18, Y, Xh)`` real."""
    _, T, Z, Y, Xh = u.shape[:5]
    arr = jnp.stack([u.real, u.imag], axis=-1)           # (4,T,Z,Y,Xh,3,3,2)
    arr = arr.transpose(0, 1, 2, 5, 6, 7, 3, 4)          # (4,T,Z,3,3,2,Y,Xh)
    return arr.reshape(4, T, Z, GAUGE_COMPS, Y, Xh).astype(dtype)


def gauge_from_planar(p: jnp.ndarray, dtype=jnp.complex64) -> jnp.ndarray:
    """Inverse of :func:`gauge_to_planar`."""
    _, T, Z, _, Y, Xh = p.shape
    arr = p.astype(_real_dtype_of(dtype)).reshape(4, T, Z, 3, 3, 2, Y, Xh)
    arr = arr.transpose(0, 1, 2, 6, 7, 3, 4, 5)          # (4,T,Z,Y,Xh,3,3,2)
    return (arr[..., 0] + 1j * arr[..., 1]).astype(dtype)
