"""Jit'd public wrappers around the Wilson stencil Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import evenodd
from . import layout
from .wilson_stencil import (dhat_planar_fused, dhat_planar_fused_stream,
                             fused_dhat_policy, hop_block_planar)


@functools.partial(jax.jit, static_argnames=("out_parity", "halo", "interpret"))
def hop_block(u_out_p, u_in_p, src_p, *, out_parity: int,
              tz_offset: Tuple[int, int] = (0, 0), halo: bool = False,
              interpret: Optional[bool] = None):
    """Planar hopping block (jit'd; ``src_p`` may carry a leading RHS
    batch axis — the gauge planes are loaded once per grid step either
    way)."""
    return hop_block_planar(u_out_p, u_in_p, src_p, out_parity,
                            tz_offset=tz_offset, halo=halo,
                            interpret=interpret)


def make_planar_fields(U_e, U_o, dtype=jnp.float32, compression="none"):
    """Convert complex even/odd gauge fields to the kernel layout.

    ``compression`` selects the stored link representation ("none" |
    "two_row" | "minimal" — see :func:`layout.gauge_compress_planar`);
    the kernels expand compressed planes in-register.
    """
    u_e_p = layout.gauge_to_planar(U_e, dtype)
    u_o_p = layout.gauge_to_planar(U_o, dtype)
    if compression not in (None, "none"):
        u_e_p = layout.gauge_compress_planar(u_e_p, compression)
        u_o_p = layout.gauge_compress_planar(u_o_p, compression)
    return u_e_p, u_o_p


def hop_oe_kernel(u_e_p, u_o_p, psi_e, *, interpret=None):
    """even -> odd hop; complex spinor in/out, Pallas inside."""
    src_p = layout.spinor_to_planar(psi_e, dtype=u_e_p.dtype)
    out_p = hop_block_planar(u_o_p, u_e_p, src_p, evenodd.ODD,
                             interpret=interpret)
    return layout.spinor_from_planar(out_p, dtype=psi_e.dtype)


def hop_eo_kernel(u_e_p, u_o_p, psi_o, *, interpret=None):
    """odd -> even hop; complex spinor in/out, Pallas inside."""
    src_p = layout.spinor_to_planar(psi_o, dtype=u_e_p.dtype)
    out_p = hop_block_planar(u_e_p, u_o_p, src_p, evenodd.EVEN,
                             interpret=interpret)
    return layout.spinor_from_planar(out_p, dtype=psi_o.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kappa", "fused", "interpret"))
def apply_dhat_planar(u_e_p, u_o_p, psi_e_p, kappa: float, *,
                      fused: bool = True,
                      interpret: Optional[bool] = None):
    """Even-odd preconditioned operator, planar layout, Pallas-backed.

    ``fused=True`` folds the final ``psi - kappa^2 * tmp`` axpy into the
    second kernel's epilogue (one less HBM round-trip of the result).
    """
    tmp = hop_block_planar(u_o_p, u_e_p, psi_e_p, evenodd.ODD,
                           interpret=interpret)
    if fused:
        return hop_block_planar(u_e_p, u_o_p, tmp, evenodd.EVEN,
                                axpy=(-float(kappa) ** 2, psi_e_p),
                                interpret=interpret)
    out = hop_block_planar(u_e_p, u_o_p, tmp, evenodd.EVEN,
                           interpret=interpret)
    return psi_e_p - jnp.asarray(float(kappa) ** 2, psi_e_p.dtype) * out


@functools.partial(jax.jit, static_argnames=("kappa", "interpret"))
def apply_dhat_planar_fused(u_e_p, u_o_p, psi_e_p, kappa: float, *,
                            interpret: Optional[bool] = None):
    """Even-odd preconditioned operator as ONE Pallas kernel (jit'd).

    Unlike :func:`apply_dhat_planar` — two ``pallas_call``s with the odd
    intermediate round-tripping through HBM between them — this runs both
    hopping blocks and the axpy epilogue in a single kernel with the
    intermediate resident in VMEM scratch.  Falls back is the caller's
    job: see :func:`repro.kernels.wilson_stencil.fused_dhat_fits`.
    """
    return dhat_planar_fused(u_e_p, u_o_p, psi_e_p, kappa,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("kappa", "interpret"))
def apply_dhat_planar_stream(u_e_p, u_o_p, psi_e_p, kappa: float, *,
                             interpret: Optional[bool] = None):
    """Streaming (plane-window) fused Dhat — ONE kernel whose VMEM
    scratch is a 4-row ring of odd-intermediate t-planes instead of the
    full lattice, so there is no T-dependent local-volume cap (jit'd).
    See :func:`repro.kernels.wilson_stencil.dhat_planar_fused_stream`.
    """
    return dhat_planar_fused_stream(u_e_p, u_o_p, psi_e_p, kappa,
                                    interpret=interpret)


def apply_dhat_planar_any(u_e_p, u_o_p, src_p, kappa: float, *,
                          fused=None,
                          interpret: Optional[bool] = None):
    """Planar-in/planar-out Dhat — the native-domain entry point.

    Accepts a batched source ``(nrhs, T, Z, 24, Y, Xh)`` (one kernel for
    the whole RHS block).  ``fused`` selects the path:

    * ``None`` — the three-way auto policy
      (:func:`~repro.kernels.wilson_stencil.fused_dhat_policy`, sized by
      the *actual* dtype and nrhs): single-kernel resident scratch when
      the whole (batched) odd intermediate fits the VMEM budget, the
      streaming plane-window kernel when only the t-plane ring does, and
      the two-kernel fallback otherwise — silently correct in all three.
    * ``True`` / ``"resident"`` — force the resident single kernel.
    * ``"stream"`` — force the streaming plane-window kernel.
    * ``False`` / ``"unfused"`` — force the two-kernel path.
    """
    if fused is None:
        fused = fused_dhat_policy(src_p.shape, src_p.dtype,
                                  gauge_comps=u_e_p.shape[3])
    elif fused is True:
        fused = "resident"
    elif fused is False:
        fused = "unfused"
    if fused == "resident":
        return apply_dhat_planar_fused(u_e_p, u_o_p, src_p, kappa,
                                       interpret=interpret)
    if fused == "stream":
        return apply_dhat_planar_stream(u_e_p, u_o_p, src_p, kappa,
                                        interpret=interpret)
    if fused != "unfused":
        raise ValueError(
            f"fused={fused!r}: expected None, bool, 'resident', "
            "'stream' or 'unfused'")
    return apply_dhat_planar(u_e_p, u_o_p, src_p, kappa,
                             interpret=interpret)


def apply_dhat_kernel(u_e_p, u_o_p, psi_e, kappa: float, *, fused=None,
                      interpret: Optional[bool] = None):
    """Complex-interface Dhat: planar conversion + Pallas inside."""
    src_p = layout.spinor_to_planar(psi_e, dtype=u_e_p.dtype)
    out_p = apply_dhat_planar_any(u_e_p, u_o_p, src_p, kappa,
                                  fused=fused, interpret=interpret)
    return layout.spinor_from_planar(out_p, dtype=psi_e.dtype)
