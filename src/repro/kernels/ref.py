"""Pure-jnp oracle for the Wilson stencil kernel (planar layout).

Wraps the already-validated complex even-odd implementation
(:mod:`repro.core.evenodd`, itself validated against the full-lattice
textbook operator) behind the planar float interface of the kernel.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import evenodd
from . import layout


def hop_block_planar_ref(u_out_p: jnp.ndarray, u_in_p: jnp.ndarray,
                         src_p: jnp.ndarray, out_parity: int, *,
                         tz_offset: Tuple[int, int] = (0, 0),
                         axpy: Optional[Tuple[float, jnp.ndarray]] = None
                         ) -> jnp.ndarray:
    """Oracle with the exact call signature of the Pallas kernel (no halo)."""
    u_out = layout.gauge_from_planar(u_out_p)
    u_in = layout.gauge_from_planar(u_in_p)
    src = layout.spinor_from_planar(src_p)
    u_e = u_in if out_parity == evenodd.ODD else u_out
    u_o = u_out if out_parity == evenodd.ODD else u_in
    parity_offset = (tz_offset[0] + tz_offset[1]) % 2
    out = evenodd.hop_block(u_e, u_o, src, out_parity,
                            parity_offset=parity_offset)
    out_p = layout.spinor_to_planar(out, dtype=src_p.dtype)
    if axpy is not None:
        coeff, psi0 = axpy
        out_p = psi0 + jnp.asarray(coeff, src_p.dtype) * out_p
    return out_p


def apply_dhat_planar_ref(u_e_p, u_o_p, psi_e_p, kappa):
    """``(1 - kappa^2 H_eo H_oe) psi_e`` through the oracle path."""
    tmp = hop_block_planar_ref(u_o_p, u_e_p, psi_e_p, evenodd.ODD)
    return hop_block_planar_ref(u_e_p, u_o_p, tmp, evenodd.EVEN,
                                axpy=(-(kappa * kappa), psi_e_p))


def hop_block_ext_planar(u_out_p, u_in_ext_p, src_ext_p, out_parity,
                         parity_offset=0):
    """Halo-extended hopping block with planar in/out (jnp backend).

    ``parity_offset`` may be a traced scalar (distributed shard origin).
    """
    u_out = layout.gauge_from_planar(u_out_p)
    u_in_ext = layout.gauge_from_planar(u_in_ext_p)
    src_ext = layout.spinor_from_planar(src_ext_p)
    out = evenodd.hop_block_ext(u_out, u_in_ext, src_ext, out_parity,
                                parity_offset=parity_offset)
    return layout.spinor_to_planar(out, dtype=src_ext_p.dtype)
