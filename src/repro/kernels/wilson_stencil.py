"""Pallas TPU kernel for the even-odd Wilson hopping blocks.

Maps the paper's A64FX SIMD strategy onto the TPU memory hierarchy:

* grid over ``(T, Z)``; each grid step owns one x-y site plane — the 2-D
  SIMD tile of the paper grown to a VMEM block (``BlockSpec`` below);
* the x-shift of the even-odd compacted layout (paper Fig. 5, ``sel`` +
  ``tbl``) is a lane-roll of the plane masked by the row parity
  ``(t+z+y) % 2``;
* the y-shift (Fig. 6, ``ext``) is a sublane-roll;
* z/t neighbors arrive as extra pipelined operands of the *same* array
  with shifted ``index_map`` (modular wrap for the periodic single-shard
  case, or offset-by-one into halo-extended arrays for the distributed
  case) — no gather/scatter anywhere, exactly the paper's rule;
* complex arithmetic is planar: separate re/im component planes, pure f32
  mul/add on the VPU (the A64FX argument against ``fcmla`` becomes a hard
  constraint on TPU);
* SU(3) x half-spinor products are fully unrolled element-wise FMAs over
  the plane: color=3 contractions are far below MXU size, so the VPU is
  the right unit — the systolic array is *not* used, by design.

All 8 hop directions are computed and accumulated in VMEM registers per
plane; the plane is written once.  Optionally the kernel fuses the
``psi0 + coeff * hop`` axpy of the even-odd preconditioned operator so the
accumulator never round-trips through HBM (beyond-paper fusion; QWS does
the analogous fusion on A64FX).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from .layout import GAUGE_COMPS, SPINOR_COMPS

# Flops per lattice site of one hopping block application, QXS convention.
HOP_FLOPS_PER_SITE = 1320


def _c(p: jnp.ndarray, s: int, a: int):
    """(re, im) planes of spinor component (spin s, color a)."""
    i = (s * 3 + a) * 2
    return p[i], p[i + 1]


def _u(u: jnp.ndarray, a: int, b: int):
    """(re, im) planes of gauge element (row a, col b)."""
    i = (a * 3 + b) * 2
    return u[i], u[i + 1]


def _sgn(s: int, v):
    return v if s > 0 else -v


def _proj(p: jnp.ndarray, mu: int, s: int):
    """Half-spinor projection of ``(1 + s*gamma_mu)``; returns h[2][3] pairs."""
    h = [[None] * 3 for _ in range(2)]
    for a in range(3):
        p0r, p0i = _c(p, 0, a)
        p1r, p1i = _c(p, 1, a)
        p2r, p2i = _c(p, 2, a)
        p3r, p3i = _c(p, 3, a)
        if mu == 0:    # x: h0 = p0 + s*i*p3, h1 = p1 + s*i*p2
            h[0][a] = (p0r - _sgn(s, p3i), p0i + _sgn(s, p3r))
            h[1][a] = (p1r - _sgn(s, p2i), p1i + _sgn(s, p2r))
        elif mu == 1:  # y: h0 = p0 - s*p3,  h1 = p1 + s*p2
            h[0][a] = (p0r - _sgn(s, p3r), p0i - _sgn(s, p3i))
            h[1][a] = (p1r + _sgn(s, p2r), p1i + _sgn(s, p2i))
        elif mu == 2:  # z: h0 = p0 + s*i*p2, h1 = p1 - s*i*p3
            h[0][a] = (p0r - _sgn(s, p2i), p0i + _sgn(s, p2r))
            h[1][a] = (p1r + _sgn(s, p3i), p1i - _sgn(s, p3r))
        else:          # t: h0 = p0 + s*p2,  h1 = p1 + s*p3
            h[0][a] = (p0r + _sgn(s, p2r), p0i + _sgn(s, p2i))
            h[1][a] = (p1r + _sgn(s, p3r), p1i + _sgn(s, p3i))
    return h


def _su3_mul(u: jnp.ndarray, h, dagger: bool):
    """uh[s][a] = sum_b U[a,b] h[s][b] (or U^dag for ``dagger``)."""
    out = [[None] * 3 for _ in range(2)]
    for sp in range(2):
        for a in range(3):
            rr = ri = None
            for b in range(3):
                ur, ui = _u(u, b, a) if dagger else _u(u, a, b)
                hr, hi = h[sp][b]
                if dagger:  # conj(u): (ur - i ui)(hr + i hi)
                    tr = ur * hr + ui * hi
                    ti = ur * hi - ui * hr
                else:
                    tr = ur * hr - ui * hi
                    ti = ur * hi + ui * hr
                rr = tr if rr is None else rr + tr
                ri = ti if ri is None else ri + ti
            out[sp][a] = (rr, ri)
    return out


def _recon_acc(acc, uh, mu: int, s: int):
    """Reconstruct the 4-spinor of ``(1 + s*gamma_mu)`` and accumulate."""

    def add(sp, a, vr, vi):
        i = (sp * 3 + a) * 2
        acc[i] = vr if acc[i] is None else acc[i] + vr
        acc[i + 1] = vi if acc[i + 1] is None else acc[i + 1] + vi

    for a in range(3):
        h0r, h0i = uh[0][a]
        h1r, h1i = uh[1][a]
        add(0, a, h0r, h0i)
        add(1, a, h1r, h1i)
        if mu == 0:    # r2 = -s*i*h1, r3 = -s*i*h0
            add(2, a, _sgn(s, h1i), -_sgn(s, h1r))
            add(3, a, _sgn(s, h0i), -_sgn(s, h0r))
        elif mu == 1:  # r2 = s*h1, r3 = -s*h0
            add(2, a, _sgn(s, h1r), _sgn(s, h1i))
            add(3, a, -_sgn(s, h0r), -_sgn(s, h0i))
        elif mu == 2:  # r2 = -s*i*h0, r3 = s*i*h1
            add(2, a, _sgn(s, h0i), -_sgn(s, h0r))
            add(3, a, -_sgn(s, h1i), _sgn(s, h1r))
        else:          # r2 = s*h0, r3 = s*h1
            add(2, a, _sgn(s, h0r), _sgn(s, h0i))
            add(3, a, _sgn(s, h1r), _sgn(s, h1i))


def _hop_plane(p, pzp, pzm, ptp, ptm, u_out, ux, uy, uz, ut,
               tz_par, out_parity: int):
    """One hopping block on a single (Y, Xh) site plane; returns the 24
    accumulator planes.

    ``p`` is the center source plane ``(24, Y, Xh)``; ``pzp/pzm/ptp/ptm``
    the z/t neighbor planes; ``u_out`` the output-parity gauge
    ``(4, 18, Y, Xh)``; ``ux/uy/uz/ut`` the source-parity gauge planes the
    backward hops read (``uz/ut`` already shifted to z-1 / t-1).  x/y
    neighbors are in-register rolls of the center plane (the paper's
    sel/tbl/ext sequence), so no operands are needed for them.
    """
    Y, Xh = p.shape[-2], p.shape[-1]

    # Row parity (t+z+y) % 2 — the predicate of the paper's `sel`.
    row = (jax.lax.broadcasted_iota(jnp.int32, (Y, Xh), 0) + tz_par) % 2
    mask_f = row == (out_parity + 1) % 2   # rows whose +x neighbor is at xh+1
    mask_b = row == out_parity % 2         # rows whose -x neighbor is at xh-1

    # In-register stencil shifts (sel/tbl/ext analogues).
    psi_xf = jnp.where(mask_f, pltpu_roll(p, -1, -1), p)
    psi_xb = jnp.where(mask_b, pltpu_roll(p, +1, -1), p)
    psi_yf = pltpu_roll(p, -1, -2)
    psi_yb = pltpu_roll(p, +1, -2)
    u_xb = jnp.where(mask_b, pltpu_roll(ux, +1, -1), ux)
    u_yb = pltpu_roll(uy, +1, -2)

    acc = [None] * SPINOR_COMPS
    hops = [(psi_xf, psi_xb, u_xb), (psi_yf, psi_yb, u_yb),
            (pzp, pzm, uz), (ptp, ptm, ut)]
    for mu, (pf, pb, ub) in enumerate(hops):
        # Forward: (1 - g_mu) U_mu(x) psi(x + mu).
        uh = _su3_mul(u_out[mu], _proj(pf, mu, -1), dagger=False)
        _recon_acc(acc, uh, mu, -1)
        # Backward: (1 + g_mu) U_mu^dag(x - mu) psi(x - mu).
        uh = _su3_mul(ub, _proj(pb, mu, +1), dagger=True)
        _recon_acc(acc, uh, mu, +1)
    return acc


def _hop_kernel(*refs, out_parity: int, axpy_coeff: Optional[float]):
    """Kernel body; operates on one (Y, Xh) plane of the lattice."""
    if axpy_coeff is not None:
        (par_ref, pc, pzp, pzm, ptp, ptm,
         uo, uix, uiy, uizm, uitm, psi0, out_ref) = refs
    else:
        (par_ref, pc, pzp, pzm, ptp, ptm,
         uo, uix, uiy, uizm, uitm, out_ref) = refs
        psi0 = None

    p = pc[0, 0]                      # (24, Y, Xh)
    compute_dtype = p.dtype
    acc = _hop_plane(p, pzp[0, 0], pzm[0, 0], ptp[0, 0], ptm[0, 0],
                     uo[:, 0, 0], uix[0, 0, 0], uiy[0, 0, 0],
                     uizm[0, 0, 0], uitm[0, 0, 0],
                     par_ref[0, 0], out_parity)

    result = jnp.stack(acc).astype(compute_dtype)
    if axpy_coeff is not None:
        result = psi0[0, 0] + compute_dtype.type(axpy_coeff) * result
    out_ref[0, 0] = result


def pltpu_roll(x: jnp.ndarray, shift: int, axis: int) -> jnp.ndarray:
    """Static roll; lowers to lane/sublane rotates on TPU."""
    return jnp.roll(x, shift, axis=axis)


def hop_block_ext_planar_native(u_out_p: jnp.ndarray,
                                u_in_ext_p: jnp.ndarray,
                                src_ext_p: jnp.ndarray,
                                out_parity: int,
                                parity_offset=0) -> jnp.ndarray:
    """Planar-native jnp hopping block on halo-extended arrays.

    Identical math to the Pallas kernel (same _proj/_su3_mul/_recon_acc
    helpers, vectorized over (T, Z) instead of gridded), with NO
    complex<->planar layout conversions — the pure-XLA fast path used by
    the distributed jnp backend and the dry-run.  ``parity_offset`` may be
    traced ((t0+z0) % 2 of the shard origin).
    """
    src = jnp.moveaxis(src_ext_p, 2, 0)        # (24, T+2, Z+2, Y, Xh)
    u_in = jnp.moveaxis(u_in_ext_p, 3, 1)      # (4, 18, T+2, Z+2, Y, Xh)
    u_out = jnp.moveaxis(u_out_p, 3, 1)        # (4, 18, T, Z, Y, Xh)
    Tl, Zl = u_out_p.shape[1], u_out_p.shape[2]
    Y, Xh = src_ext_p.shape[-2], src_ext_p.shape[-1]

    c = src[:, 1:-1, 1:-1]                     # (24, T, Z, Y, Xh)
    t = jnp.arange(Tl).reshape(Tl, 1, 1, 1)
    z = jnp.arange(Zl).reshape(1, Zl, 1, 1)
    y = jnp.arange(Y).reshape(1, 1, Y, 1)
    row = (t + z + y + parity_offset) % 2      # (T, Z, Y, 1)
    mask_f = row == (out_parity + 1) % 2
    mask_b = row == out_parity % 2

    psi_xf = jnp.where(mask_f, jnp.roll(c, -1, axis=-1), c)
    psi_xb = jnp.where(mask_b, jnp.roll(c, +1, axis=-1), c)
    psi_yf = jnp.roll(c, -1, axis=-2)
    psi_yb = jnp.roll(c, +1, axis=-2)
    psi_zf, psi_zb = src[:, 1:-1, 2:], src[:, 1:-1, :-2]
    psi_tf, psi_tb = src[:, 2:, 1:-1], src[:, :-2, 1:-1]

    ux = u_in[0, :, 1:-1, 1:-1]
    uy = u_in[1, :, 1:-1, 1:-1]
    uz = u_in[2, :, 1:-1, :-2]
    ut = u_in[3, :, :-2, 1:-1]
    u_xb = jnp.where(mask_b, jnp.roll(ux, +1, axis=-1), ux)
    u_yb = jnp.roll(uy, +1, axis=-2)

    acc = [None] * SPINOR_COMPS
    hops = [(psi_xf, psi_xb, u_xb), (psi_yf, psi_yb, u_yb),
            (psi_zf, psi_zb, uz), (psi_tf, psi_tb, ut)]
    for mu, (pf, pb, ub) in enumerate(hops):
        uh = _su3_mul(u_out[mu], _proj(pf, mu, -1), dagger=False)
        _recon_acc(acc, uh, mu, -1)
        uh = _su3_mul(ub, _proj(pb, mu, +1), dagger=True)
        _recon_acc(acc, uh, mu, +1)
    out = jnp.stack(acc).astype(src_ext_p.dtype)
    return jnp.moveaxis(out, 0, 2)             # (T, Z, 24, Y, Xh)


def _build_specs(Tl: int, Zl: int, Y: int, Xh: int, halo: bool,
                 with_axpy: bool):
    """BlockSpecs for (parity, psi x5, U_out, U_in x4[, psi0])."""
    sblk = (1, 1, SPINOR_COMPS, Y, Xh)
    gblk1 = (1, 1, 1, GAUGE_COMPS, Y, Xh)

    def s(im):
        return pl.BlockSpec(sblk, im)

    def g(im):
        return pl.BlockSpec(gblk1, im)

    if halo:
        # Arrays are halo-extended to (T+2, Z+2) in t/z; +1 recenters.
        psi = [
            s(lambda t, z: (t + 1, z + 1, 0, 0, 0)),
            s(lambda t, z: (t + 1, z + 2, 0, 0, 0)),   # z+1
            s(lambda t, z: (t + 1, z, 0, 0, 0)),       # z-1
            s(lambda t, z: (t + 2, z + 1, 0, 0, 0)),   # t+1
            s(lambda t, z: (t, z + 1, 0, 0, 0)),       # t-1
        ]
        u_in = [
            g(lambda t, z: (0, t + 1, z + 1, 0, 0, 0)),  # x, center
            g(lambda t, z: (1, t + 1, z + 1, 0, 0, 0)),  # y, center
            g(lambda t, z: (2, t + 1, z, 0, 0, 0)),      # z, z-1
            g(lambda t, z: (3, t, z + 1, 0, 0, 0)),      # t, t-1
        ]
    else:
        psi = [
            s(lambda t, z: (t, z, 0, 0, 0)),
            s(lambda t, z: (t, (z + 1) % Zl, 0, 0, 0)),
            s(lambda t, z: (t, (z - 1) % Zl, 0, 0, 0)),
            s(lambda t, z: ((t + 1) % Tl, z, 0, 0, 0)),
            s(lambda t, z: ((t - 1) % Tl, z, 0, 0, 0)),
        ]
        u_in = [
            g(lambda t, z: (0, t, z, 0, 0, 0)),
            g(lambda t, z: (1, t, z, 0, 0, 0)),
            g(lambda t, z: (2, t, (z - 1) % Zl, 0, 0, 0)),
            g(lambda t, z: (3, (t - 1) % Tl, z, 0, 0, 0)),
        ]

    par = pl.BlockSpec((1, 1), lambda t, z: (t, z), memory_space=pltpu.SMEM)
    u_out = pl.BlockSpec((4, 1, 1, GAUGE_COMPS, Y, Xh),
                         lambda t, z: (0, t, z, 0, 0, 0))
    specs = [par] + psi + [u_out] + u_in
    if with_axpy:
        specs.append(s(lambda t, z: (t, z, 0, 0, 0)))
    out = s(lambda t, z: (t, z, 0, 0, 0))
    return specs, out


def hop_block_planar(u_out_p: jnp.ndarray, u_in_p: jnp.ndarray,
                     src_p: jnp.ndarray, out_parity: int, *,
                     tz_offset: Tuple[int, int] = (0, 0),
                     halo: bool = False,
                     axpy: Optional[Tuple[float, jnp.ndarray]] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Apply one hopping block in the planar layout via the Pallas kernel.

    Args:
      u_out_p: planar gauge at output-parity sites ``(4, T, Z, 18, Y, Xh)``
        (never halo-extended).
      u_in_p: planar gauge at source-parity sites; halo-extended to
        ``(4, T+2, Z+2, ...)`` iff ``halo``.
      src_p: planar source spinor ``(T, Z, 24, Y, Xh)``, halo-extended to
        ``(T+2, Z+2, ...)`` iff ``halo``.
      out_parity: parity of the *output* (ODD for ``H_oe``).
      tz_offset: global (t0, z0) origin of this shard, for the parity mask.
      halo: neighbor planes come from halo-extended arrays instead of
        periodic wrap (the distributed path).
      axpy: optional ``(coeff, psi0_p)`` fusing ``psi0 + coeff * hop``.
      interpret: force/disable interpret mode (default: auto off-TPU).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Tl, Zl = ((src_p.shape[0] - 2, src_p.shape[1] - 2) if halo
              else (src_p.shape[0], src_p.shape[1]))
    _, Y, Xh = src_p.shape[2:]
    t0, z0 = tz_offset

    par = ((jnp.arange(Tl, dtype=jnp.int32)[:, None] + t0)
           + (jnp.arange(Zl, dtype=jnp.int32)[None, :] + z0)) % 2

    with_axpy = axpy is not None
    in_specs, out_spec = _build_specs(Tl, Zl, Y, Xh, halo, with_axpy)
    coeff = float(axpy[0]) if with_axpy else None

    bytes_spinor = src_p.dtype.itemsize * SPINOR_COMPS * Y * Xh * Tl * Zl
    bytes_gauge = u_out_p.dtype.itemsize * 4 * GAUGE_COMPS * Y * Xh * Tl * Zl
    cost = pl.CostEstimate(
        flops=HOP_FLOPS_PER_SITE * Tl * Zl * Y * Xh,
        bytes_accessed=2 * bytes_spinor + 2 * bytes_gauge
        + (bytes_spinor if with_axpy else 0),
        transcendentals=0)

    kernel = functools.partial(_hop_kernel, out_parity=out_parity,
                               axpy_coeff=coeff)
    operands = [par, src_p, src_p, src_p, src_p, src_p,
                u_out_p, u_in_p, u_in_p, u_in_p, u_in_p]
    if with_axpy:
        operands.append(axpy[1])

    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((Tl, Zl, SPINOR_COMPS, Y, Xh),
                                       src_p.dtype),
        grid=(Tl, Zl),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=interpret,
        cost_estimate=cost,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        name=f"wilson_hop_{'oe' if out_parity else 'eo'}",
    )
    return fn(*operands)


# ---------------------------------------------------------------------------
# Fused even-odd preconditioned operator: Dhat in ONE pallas_call.
# ---------------------------------------------------------------------------

# Conservative VMEM budget for the resident intermediate (v4/v5 cores have
# ~16 MiB; leave room for the pipelined operand/output blocks).
_FUSED_SCRATCH_LIMIT_BYTES = 12 << 20


def _dhat_kernel(par_ref, pc, pzp, pzm, ptp, ptm,
                 ue_all, ue_zm, ue_tm, uo_all, uo_zm, uo_tm,
                 out_ref, tmp_ref, *, kappa2: float, Tl: int, Zl: int):
    """Fused ``Dhat = 1 - kappa^2 H_eo H_oe`` over grid ``(2, T, Z)``.

    Pass 0 (``s == 0``) computes the odd-parity intermediate
    ``tmp = H_oe psi_e`` plane by plane into a full-lattice VMEM scratch;
    pass 1 re-walks the grid applying ``H_eo`` to the scratch (z/t
    neighbor planes are VMEM reads with periodic wrap) and writes the
    fused ``psi0 - kappa^2 * (...)`` epilogue.  The intermediate spinor
    never exists in HBM — the round-trip the two-call
    ``apply_dhat_planar`` pays is gone (QWS applies the same fusion on
    A64FX; cf. Kanamori & Matsufuru on keeping intermediates
    SIMD-resident).
    """
    s = pl.program_id(0)
    t = pl.program_id(1)
    z = pl.program_id(2)
    tz_par = par_ref[0, 0]
    p = pc[0, 0]                      # psi_e center plane (24, Y, Xh)
    compute_dtype = p.dtype

    @pl.when(s == 0)
    def _pass_hoe():
        acc = _hop_plane(p, pzp[0, 0], pzm[0, 0], ptp[0, 0], ptm[0, 0],
                         uo_all[:, 0, 0],
                         ue_all[0, 0, 0], ue_all[1, 0, 0],
                         ue_zm[0, 0, 0], ue_tm[0, 0, 0],
                         tz_par, 1)
        tmp_ref[t, z] = jnp.stack(acc).astype(compute_dtype)

    @pl.when(s == 1)
    def _pass_heo_axpy():
        tc = tmp_ref[t, z]
        tzp = tmp_ref[t, (z + 1) % Zl]
        tzm = tmp_ref[t, (z - 1) % Zl]
        ttp = tmp_ref[(t + 1) % Tl, z]
        ttm = tmp_ref[(t - 1) % Tl, z]
        acc = _hop_plane(tc, tzp, tzm, ttp, ttm,
                         ue_all[:, 0, 0],
                         uo_all[0, 0, 0], uo_all[1, 0, 0],
                         uo_zm[0, 0, 0], uo_tm[0, 0, 0],
                         tz_par, 0)
        hop2 = jnp.stack(acc).astype(compute_dtype)
        out_ref[0, 0] = p - compute_dtype.type(kappa2) * hop2


def dhat_planar_fused(u_e_p: jnp.ndarray, u_o_p: jnp.ndarray,
                      psi_e_p: jnp.ndarray, kappa: float, *,
                      tz_offset: Tuple[int, int] = (0, 0),
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """``(1 - kappa^2 H_eo H_oe) psi_e`` as a single Pallas kernel.

    Both hopping blocks and the axpy epilogue run inside one
    ``pallas_call``; the odd intermediate lives in a full-lattice VMEM
    scratch for the whole invocation, so versus the two-call
    ``apply_dhat_planar`` path one spinor HBM write + pipelined re-read
    (5 planes per grid step) is eliminated.  Periodic single-shard only
    (the distributed path keeps the two-call structure so halos can
    overlap).

    The scratch is the whole odd-parity spinor: ``24 * T*Z*Y*Xh`` floats.
    On a real TPU that caps the local volume (~12 MiB budget, e.g.
    32x32x32x32 f32 exceeds it); callers should fall back to the unfused
    path above that — :func:`fused_dhat_fits` tells you.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Tl, Zl, _, Y, Xh = psi_e_p.shape
    t0, z0 = tz_offset

    tmp_bytes = psi_e_p.dtype.itemsize * SPINOR_COMPS * Tl * Zl * Y * Xh
    if not interpret and tmp_bytes > _FUSED_SCRATCH_LIMIT_BYTES:
        raise ValueError(
            f"fused Dhat intermediate needs {tmp_bytes} B of VMEM scratch "
            f"(> {_FUSED_SCRATCH_LIMIT_BYTES}); use the unfused "
            "apply_dhat_planar path for this local volume")

    par = ((jnp.arange(Tl, dtype=jnp.int32)[:, None] + t0)
           + (jnp.arange(Zl, dtype=jnp.int32)[None, :] + z0)) % 2

    sblk = (1, 1, SPINOR_COMPS, Y, Xh)
    gblk1 = (1, 1, 1, GAUGE_COMPS, Y, Xh)

    def s(im):
        return pl.BlockSpec(sblk, im)

    def g(im):
        return pl.BlockSpec(gblk1, im)

    # Operands read by only one pass collapse to block (0, 0) in the
    # other pass (multiply the index by ``1 - s`` or ``s``): the block
    # index then stays constant across the dead pass's grid steps, so the
    # pipeliner fetches it once instead of streaming a full dead volume
    # from HBM — without this, pass 1 would re-fetch all four psi
    # neighbor planes it never reads and the fusion's HBM saving mostly
    # evaporates.
    psi_specs = [
        s(lambda _, t, z: (t, z, 0, 0, 0)),   # center: psi0 in pass 1
        s(lambda s_, t, z: (t * (1 - s_), ((z + 1) % Zl) * (1 - s_),
                            0, 0, 0)),
        s(lambda s_, t, z: (t * (1 - s_), ((z - 1) % Zl) * (1 - s_),
                            0, 0, 0)),
        s(lambda s_, t, z: (((t + 1) % Tl) * (1 - s_), z * (1 - s_),
                            0, 0, 0)),
        s(lambda s_, t, z: (((t - 1) % Tl) * (1 - s_), z * (1 - s_),
                            0, 0, 0)),
    ]

    def gauge_specs(live):
        # ``live(s)`` is 1 in the pass that reads the shifted planes.
        return [
            pl.BlockSpec((4, 1, 1, GAUGE_COMPS, Y, Xh),
                         lambda _, t, z: (0, t, z, 0, 0, 0)),
            g(lambda s_, t, z: (2, t * live(s_),
                                ((z - 1) % Zl) * live(s_), 0, 0, 0)),
            g(lambda s_, t, z: (3, ((t - 1) % Tl) * live(s_),
                                z * live(s_), 0, 0, 0)),
        ]

    par_spec = pl.BlockSpec((1, 1), lambda _, t, z: (t, z),
                            memory_space=pltpu.SMEM)
    in_specs = ([par_spec] + psi_specs
                + gauge_specs(lambda s_: 1 - s_)    # u_e shifts: pass 0
                + gauge_specs(lambda s_: s_))       # u_o shifts: pass 1
    out_spec = s(lambda _, t, z: (t, z, 0, 0, 0))

    bytes_spinor = psi_e_p.dtype.itemsize * SPINOR_COMPS * Y * Xh * Tl * Zl
    bytes_gauge = u_e_p.dtype.itemsize * 4 * GAUGE_COMPS * Y * Xh * Tl * Zl
    cost = pl.CostEstimate(
        flops=2 * HOP_FLOPS_PER_SITE * Tl * Zl * Y * Xh
        + 2 * SPINOR_COMPS * Tl * Zl * Y * Xh,
        bytes_accessed=2 * bytes_spinor + 4 * bytes_gauge,
        transcendentals=0)

    kernel = functools.partial(_dhat_kernel, kappa2=float(kappa) ** 2,
                               Tl=Tl, Zl=Zl)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((Tl, Zl, SPINOR_COMPS, Y, Xh),
                                       psi_e_p.dtype),
        grid=(2, Tl, Zl),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((Tl, Zl, SPINOR_COMPS, Y, Xh),
                                   psi_e_p.dtype)],
        interpret=interpret,
        cost_estimate=cost,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        name="wilson_dhat_fused",
    )
    return fn(par, psi_e_p, psi_e_p, psi_e_p, psi_e_p, psi_e_p,
              u_e_p, u_e_p, u_e_p, u_o_p, u_o_p, u_o_p)


def fused_dhat_fits(psi_e_p_shape, itemsize: int = 4) -> bool:
    """Whether the fused kernel's VMEM-resident intermediate fits."""
    Tl, Zl, comps, Y, Xh = psi_e_p_shape
    return itemsize * comps * Tl * Zl * Y * Xh <= _FUSED_SCRATCH_LIMIT_BYTES
