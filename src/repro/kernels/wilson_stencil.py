"""Pallas TPU kernel for the even-odd Wilson hopping blocks.

Maps the paper's A64FX SIMD strategy onto the TPU memory hierarchy:

* grid over ``(T, Z)``; each grid step owns one x-y site plane — the 2-D
  SIMD tile of the paper grown to a VMEM block (``BlockSpec`` below);
* the x-shift of the even-odd compacted layout (paper Fig. 5, ``sel`` +
  ``tbl``) is a lane-roll of the plane masked by the row parity
  ``(t+z+y) % 2``;
* the y-shift (Fig. 6, ``ext``) is a sublane-roll;
* z/t neighbors arrive as extra pipelined operands of the *same* array
  with shifted ``index_map`` (modular wrap for the periodic single-shard
  case, or offset-by-one into halo-extended arrays for the distributed
  case) — no gather/scatter anywhere, exactly the paper's rule;
* complex arithmetic is planar: separate re/im component planes, pure f32
  mul/add on the VPU (the A64FX argument against ``fcmla`` becomes a hard
  constraint on TPU);
* SU(3) x half-spinor products are fully unrolled element-wise FMAs over
  the plane: color=3 contractions are far below MXU size, so the VPU is
  the right unit — the systolic array is *not* used, by design.

All 8 hop directions are computed and accumulated in VMEM registers per
plane; the plane is written once.  Optionally the kernel fuses the
``psi0 + coeff * hop`` axpy of the even-odd preconditioned operator so the
accumulator never round-trips through HBM (beyond-paper fusion; QWS does
the analogous fusion on A64FX).

Multi-RHS batching (Duerr-style right-hand-side parallelism): a batched
planar source ``(nrhs, T, Z, 24, Y, Xh)`` runs through the SAME grid —
each (t, z) step loads the gauge planes ONCE and applies the unrolled
SU(3) x half-spinor math to the whole RHS block via broadcasting, so the
flops-per-gauge-byte ratio grows ~nrhs x (the kernel is memory-bound on
the gauge stream at nrhs=1).  :func:`hop_traffic_model` is the
amortization model the benchmarks report next to measured numbers.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from .layout import (GAUGE_COMPS, GAUGE_COMPS_MINIMAL, GAUGE_COMPS_TWO_ROW,
                     SPINOR_COMPS, expand_links_planes)

# Flops per lattice site of one hopping block application, QXS convention.
HOP_FLOPS_PER_SITE = 1320

# Extra in-register flops to rebuild one full SU(3) link from its
# compressed planes (see layout.expand_links_planes): two_row rebuilds
# row c = conj(a x b) (6 complex mul + 3 sub), minimal additionally
# solves the 2x2 system for (b2, b3) and evaluates sqrt/sin/cos for the
# phase-encoded a1/c1.  The hopping block expands 8 links per site.
RECON_FLOPS_PER_LINK = {
    GAUGE_COMPS: 0,
    GAUGE_COMPS_TWO_ROW: 42,
    GAUGE_COMPS_MINIMAL: 150,
}
LINKS_EXPANDED_PER_SITE = 8


def _c(p: jnp.ndarray, s: int, a: int):
    """(re, im) planes of spinor component (spin s, color a)."""
    i = (s * 3 + a) * 2
    return p[i], p[i + 1]


def _u(u: jnp.ndarray, a: int, b: int):
    """(re, im) planes of gauge element (row a, col b)."""
    i = (a * 3 + b) * 2
    return u[i], u[i + 1]


def _sgn(s: int, v):
    return v if s > 0 else -v


def _proj(p: jnp.ndarray, mu: int, s: int):
    """Half-spinor projection of ``(1 + s*gamma_mu)``; returns h[2][3] pairs."""
    h = [[None] * 3 for _ in range(2)]
    for a in range(3):
        p0r, p0i = _c(p, 0, a)
        p1r, p1i = _c(p, 1, a)
        p2r, p2i = _c(p, 2, a)
        p3r, p3i = _c(p, 3, a)
        if mu == 0:    # x: h0 = p0 + s*i*p3, h1 = p1 + s*i*p2
            h[0][a] = (p0r - _sgn(s, p3i), p0i + _sgn(s, p3r))
            h[1][a] = (p1r - _sgn(s, p2i), p1i + _sgn(s, p2r))
        elif mu == 1:  # y: h0 = p0 - s*p3,  h1 = p1 + s*p2
            h[0][a] = (p0r - _sgn(s, p3r), p0i - _sgn(s, p3i))
            h[1][a] = (p1r + _sgn(s, p2r), p1i + _sgn(s, p2i))
        elif mu == 2:  # z: h0 = p0 + s*i*p2, h1 = p1 - s*i*p3
            h[0][a] = (p0r - _sgn(s, p2i), p0i + _sgn(s, p2r))
            h[1][a] = (p1r + _sgn(s, p3i), p1i - _sgn(s, p3r))
        else:          # t: h0 = p0 + s*p2,  h1 = p1 + s*p3
            h[0][a] = (p0r + _sgn(s, p2r), p0i + _sgn(s, p2i))
            h[1][a] = (p1r + _sgn(s, p3r), p1i + _sgn(s, p3i))
    return h


def _su3_mul(u: jnp.ndarray, h, dagger: bool):
    """uh[s][a] = sum_b U[a,b] h[s][b] (or U^dag for ``dagger``).

    The gauge planes ``(Y, Xh)`` broadcast against half-spinor planes that
    may carry a leading RHS-batch axis ``(nrhs, Y, Xh)`` — one gauge load
    serves the whole batch.
    """
    out = [[None] * 3 for _ in range(2)]
    for sp in range(2):
        for a in range(3):
            rr = ri = None
            for b in range(3):
                ur, ui = _u(u, b, a) if dagger else _u(u, a, b)
                hr, hi = h[sp][b]
                if dagger:  # conj(u): (ur - i ui)(hr + i hi)
                    tr = ur * hr + ui * hi
                    ti = ur * hi - ui * hr
                else:
                    tr = ur * hr - ui * hi
                    ti = ur * hi + ui * hr
                rr = tr if rr is None else rr + tr
                ri = ti if ri is None else ri + ti
            out[sp][a] = (rr, ri)
    return out


def _recon_acc(acc, uh, mu: int, s: int):
    """Reconstruct the 4-spinor of ``(1 + s*gamma_mu)`` and accumulate."""

    def add(sp, a, vr, vi):
        i = (sp * 3 + a) * 2
        acc[i] = vr if acc[i] is None else acc[i] + vr
        acc[i + 1] = vi if acc[i + 1] is None else acc[i + 1] + vi

    for a in range(3):
        h0r, h0i = uh[0][a]
        h1r, h1i = uh[1][a]
        add(0, a, h0r, h0i)
        add(1, a, h1r, h1i)
        if mu == 0:    # r2 = -s*i*h1, r3 = -s*i*h0
            add(2, a, _sgn(s, h1i), -_sgn(s, h1r))
            add(3, a, _sgn(s, h0i), -_sgn(s, h0r))
        elif mu == 1:  # r2 = s*h1, r3 = -s*h0
            add(2, a, _sgn(s, h1r), _sgn(s, h1i))
            add(3, a, -_sgn(s, h0r), -_sgn(s, h0i))
        elif mu == 2:  # r2 = -s*i*h0, r3 = s*i*h1
            add(2, a, _sgn(s, h0i), -_sgn(s, h0r))
            add(3, a, -_sgn(s, h1i), _sgn(s, h1r))
        else:          # r2 = s*h0, r3 = s*h1
            add(2, a, _sgn(s, h0r), _sgn(s, h0i))
            add(3, a, _sgn(s, h1r), _sgn(s, h1i))


def _hop_plane(p, pzp, pzm, ptp, ptm, u_out, ux, uy, uz, ut,
               tz_par, out_parity: int):
    """One hopping block on a single (Y, Xh) site plane; returns the 24
    accumulator planes.

    ``p`` is the center source plane ``(24, Y, Xh)`` — or, batched,
    ``(24, nrhs, Y, Xh)`` with the RHS axis right behind the component
    axis; ``pzp/pzm/ptp/ptm`` the z/t neighbor planes; ``u_out`` the
    output-parity gauge ``(4, gc, Y, Xh)``; ``ux/uy/uz/ut`` the
    source-parity gauge planes the backward hops read (``uz/ut`` already
    shifted to z-1 / t-1).  Gauge planes never carry the RHS axis: they
    broadcast, so they are loaded once per plane regardless of the batch.
    x/y neighbors are in-register rolls of the center plane (the paper's
    sel/tbl/ext sequence), so no operands are needed for them.

    ``gc`` may be 18 (full links), 12 (two_row) or 8 (minimal): the
    compressed planes are rolled/masked *first* (reconstruction is
    element-wise, so shifts commute with it and move fewer planes) and
    expanded to the 18 component planes in-register per hop direction —
    the HBM gauge stream shrinks 33%/55% for some extra VPU flops.
    """
    Y, Xh = p.shape[-2], p.shape[-1]

    # Row parity (t+z+y) % 2 — the predicate of the paper's `sel`.
    row = (jax.lax.broadcasted_iota(jnp.int32, (Y, Xh), 0) + tz_par) % 2
    mask_f = row == (out_parity + 1) % 2   # rows whose +x neighbor is at xh+1
    mask_b = row == out_parity % 2         # rows whose -x neighbor is at xh-1

    # In-register stencil shifts (sel/tbl/ext analogues).
    psi_xf = jnp.where(mask_f, pltpu_roll(p, -1, -1), p)
    psi_xb = jnp.where(mask_b, pltpu_roll(p, +1, -1), p)
    psi_yf = pltpu_roll(p, -1, -2)
    psi_yb = pltpu_roll(p, +1, -2)
    u_xb = jnp.where(mask_b, pltpu_roll(ux, +1, -1), ux)
    u_yb = pltpu_roll(uy, +1, -2)

    acc = [None] * SPINOR_COMPS
    hops = [(psi_xf, psi_xb, u_xb), (psi_yf, psi_yb, u_yb),
            (pzp, pzm, uz), (ptp, ptm, ut)]
    for mu, (pf, pb, ub) in enumerate(hops):
        # Forward: (1 - g_mu) U_mu(x) psi(x + mu).
        uh = _su3_mul(expand_links_planes(u_out[mu]), _proj(pf, mu, -1),
                      dagger=False)
        _recon_acc(acc, uh, mu, -1)
        # Backward: (1 + g_mu) U_mu^dag(x - mu) psi(x - mu).
        uh = _su3_mul(expand_links_planes(ub), _proj(pb, mu, +1),
                      dagger=True)
        _recon_acc(acc, uh, mu, +1)
    return acc


def _plane(ref, batched: bool):
    """Component-leading view of one pipelined spinor block.

    Unbatched block ``(1, 1, 24, Y, Xh)`` -> ``(24, Y, Xh)``; batched
    block ``(nrhs, 1, 1, 24, Y, Xh)`` -> ``(24, nrhs, Y, Xh)`` (component
    axis first so the unrolled plane math indexes it the same way).
    """
    return jnp.swapaxes(ref[:, 0, 0], 0, 1) if batched else ref[0, 0]


def _hop_kernel(*refs, out_parity: int, axpy_coeff: Optional[float],
                batched: bool):
    """Kernel body; operates on one (Y, Xh) plane of the lattice."""
    if axpy_coeff is not None:
        (par_ref, pc, pzp, pzm, ptp, ptm,
         uo, uix, uiy, uizm, uitm, psi0, out_ref) = refs
    else:
        (par_ref, pc, pzp, pzm, ptp, ptm,
         uo, uix, uiy, uizm, uitm, out_ref) = refs
        psi0 = None

    p = _plane(pc, batched)           # (24, [nrhs,] Y, Xh)
    compute_dtype = p.dtype
    acc = _hop_plane(p, _plane(pzp, batched), _plane(pzm, batched),
                     _plane(ptp, batched), _plane(ptm, batched),
                     uo[:, 0, 0], uix[0, 0, 0], uiy[0, 0, 0],
                     uizm[0, 0, 0], uitm[0, 0, 0],
                     par_ref[0, 0], out_parity)

    result = jnp.stack(acc).astype(compute_dtype)
    if axpy_coeff is not None:
        result = _plane(psi0, batched) + compute_dtype.type(axpy_coeff) * result
    if batched:
        out_ref[:, 0, 0] = jnp.swapaxes(result, 0, 1)
    else:
        out_ref[0, 0] = result


def pltpu_roll(x: jnp.ndarray, shift: int, axis: int) -> jnp.ndarray:
    """Static roll; lowers to lane/sublane rotates on TPU."""
    return jnp.roll(x, shift, axis=axis)


def hop_block_ext_planar_native(u_out_p: jnp.ndarray,
                                u_in_ext_p: jnp.ndarray,
                                src_ext_p: jnp.ndarray,
                                out_parity: int,
                                parity_offset=0) -> jnp.ndarray:
    """Planar-native jnp hopping block on halo-extended arrays.

    Identical math to the Pallas kernel (same _proj/_su3_mul/_recon_acc
    helpers, vectorized over (T, Z) instead of gridded), with NO
    complex<->planar layout conversions — the pure-XLA fast path used by
    the distributed jnp backend and the dry-run.  ``parity_offset`` may be
    traced ((t0+z0) % 2 of the shard origin).

    Accepts a batched source ``(nrhs, T+2, Z+2, 24, Y, Xh)`` (gauge never
    batched); the RHS axis rides right behind the component axis through
    the broadcasted SU(3) math — one gauge read per plane for the block.
    Compressed planar gauge fields (12/8 component planes) are expanded
    per hop direction, mirroring the in-register path of the kernel.
    """
    # Component axis to the front; an optional leading RHS axis lands
    # right behind it, so the trailing dims are (T, Z, Y, Xh) either way.
    src = jnp.moveaxis(src_ext_p, -3, 0)       # (24, [N,] T+2, Z+2, Y, Xh)
    u_in = jnp.moveaxis(u_in_ext_p, 3, 1)      # (4, gc, T+2, Z+2, Y, Xh)
    u_out = jnp.moveaxis(u_out_p, 3, 1)        # (4, gc, T, Z, Y, Xh)
    Tl, Zl = u_out_p.shape[1], u_out_p.shape[2]
    Y, Xh = src_ext_p.shape[-2], src_ext_p.shape[-1]

    c = src[..., 1:-1, 1:-1, :, :]             # (24, [N,] T, Z, Y, Xh)
    t = jnp.arange(Tl).reshape(Tl, 1, 1, 1)
    z = jnp.arange(Zl).reshape(1, Zl, 1, 1)
    y = jnp.arange(Y).reshape(1, 1, Y, 1)
    row = (t + z + y + parity_offset) % 2      # (T, Z, Y, 1)
    mask_f = row == (out_parity + 1) % 2
    mask_b = row == out_parity % 2

    psi_xf = jnp.where(mask_f, jnp.roll(c, -1, axis=-1), c)
    psi_xb = jnp.where(mask_b, jnp.roll(c, +1, axis=-1), c)
    psi_yf = jnp.roll(c, -1, axis=-2)
    psi_yb = jnp.roll(c, +1, axis=-2)
    psi_zf = src[..., 1:-1, 2:, :, :]
    psi_zb = src[..., 1:-1, :-2, :, :]
    psi_tf = src[..., 2:, 1:-1, :, :]
    psi_tb = src[..., :-2, 1:-1, :, :]

    ux = u_in[0, :, 1:-1, 1:-1]
    uy = u_in[1, :, 1:-1, 1:-1]
    uz = u_in[2, :, 1:-1, :-2]
    ut = u_in[3, :, :-2, 1:-1]
    u_xb = jnp.where(mask_b, jnp.roll(ux, +1, axis=-1), ux)
    u_yb = jnp.roll(uy, +1, axis=-2)

    acc = [None] * SPINOR_COMPS
    hops = [(psi_xf, psi_xb, u_xb), (psi_yf, psi_yb, u_yb),
            (psi_zf, psi_zb, uz), (psi_tf, psi_tb, ut)]
    for mu, (pf, pb, ub) in enumerate(hops):
        uh = _su3_mul(expand_links_planes(u_out[mu]), _proj(pf, mu, -1),
                      dagger=False)
        _recon_acc(acc, uh, mu, -1)
        uh = _su3_mul(expand_links_planes(ub), _proj(pb, mu, +1),
                      dagger=True)
        _recon_acc(acc, uh, mu, +1)
    out = jnp.stack(acc).astype(src_ext_p.dtype)
    return jnp.moveaxis(out, 0, -3)            # ([N,] T, Z, 24, Y, Xh)


def _build_specs(Tl: int, Zl: int, Y: int, Xh: int, halo: bool,
                 with_axpy: bool, nrhs: Optional[int] = None,
                 gauge_comps: int = GAUGE_COMPS):
    """BlockSpecs for (parity, psi x5, U_out, U_in x4[, psi0]).

    With ``nrhs`` the spinor blocks grow a leading RHS axis covered whole
    by every grid step (block index 0); the gauge blocks are unchanged —
    per grid step the pipeline fetches each gauge plane exactly once,
    independent of the batch size.  ``gauge_comps`` sizes the gauge
    component-plane axis (18 full / 12 two_row / 8 minimal).
    """
    if nrhs is None:
        sblk = (1, 1, SPINOR_COMPS, Y, Xh)
    else:
        sblk = (nrhs, 1, 1, SPINOR_COMPS, Y, Xh)
    gblk1 = (1, 1, 1, gauge_comps, Y, Xh)

    def s(im):
        if nrhs is None:
            return pl.BlockSpec(sblk, im)
        return pl.BlockSpec(sblk, lambda t, z, _im=im: (0, *_im(t, z)))

    def g(im):
        return pl.BlockSpec(gblk1, im)

    if halo:
        # Arrays are halo-extended to (T+2, Z+2) in t/z; +1 recenters.
        psi = [
            s(lambda t, z: (t + 1, z + 1, 0, 0, 0)),
            s(lambda t, z: (t + 1, z + 2, 0, 0, 0)),   # z+1
            s(lambda t, z: (t + 1, z, 0, 0, 0)),       # z-1
            s(lambda t, z: (t + 2, z + 1, 0, 0, 0)),   # t+1
            s(lambda t, z: (t, z + 1, 0, 0, 0)),       # t-1
        ]
        u_in = [
            g(lambda t, z: (0, t + 1, z + 1, 0, 0, 0)),  # x, center
            g(lambda t, z: (1, t + 1, z + 1, 0, 0, 0)),  # y, center
            g(lambda t, z: (2, t + 1, z, 0, 0, 0)),      # z, z-1
            g(lambda t, z: (3, t, z + 1, 0, 0, 0)),      # t, t-1
        ]
    else:
        psi = [
            s(lambda t, z: (t, z, 0, 0, 0)),
            s(lambda t, z: (t, (z + 1) % Zl, 0, 0, 0)),
            s(lambda t, z: (t, (z - 1) % Zl, 0, 0, 0)),
            s(lambda t, z: ((t + 1) % Tl, z, 0, 0, 0)),
            s(lambda t, z: ((t - 1) % Tl, z, 0, 0, 0)),
        ]
        u_in = [
            g(lambda t, z: (0, t, z, 0, 0, 0)),
            g(lambda t, z: (1, t, z, 0, 0, 0)),
            g(lambda t, z: (2, t, (z - 1) % Zl, 0, 0, 0)),
            g(lambda t, z: (3, (t - 1) % Tl, z, 0, 0, 0)),
        ]

    par = pl.BlockSpec((1, 1), lambda t, z: (t, z), memory_space=pltpu.SMEM)
    u_out = pl.BlockSpec((4, 1, 1, gauge_comps, Y, Xh),
                         lambda t, z: (0, t, z, 0, 0, 0))
    specs = [par] + psi + [u_out] + u_in
    if with_axpy:
        specs.append(s(lambda t, z: (t, z, 0, 0, 0)))
    out = s(lambda t, z: (t, z, 0, 0, 0))
    return specs, out


def hop_traffic_model(Tl: int, Zl: int, Y: int, Xh: int, *,
                      nrhs: int = 1, itemsize: int = 4,
                      with_axpy: bool = False,
                      gauge_comps: int = GAUGE_COMPS) -> dict:
    """HBM-traffic / flops model of one (batched) hopping-block call.

    The gauge term is *independent of nrhs* — each (t, z) grid step loads
    its gauge planes once and reuses them across the whole RHS block —
    while spinor traffic and flops scale linearly, so the arithmetic
    intensity approaches ``HOP_FLOPS_PER_SITE / (4 * spinor bytes)`` as
    nrhs grows.  This is the model :mod:`benchmarks.bench_multirhs`
    prints next to measured numbers, and what the kernel's
    ``pl.CostEstimate`` is built from.

    ``gauge_comps`` scales the gauge stream for compressed links (12/8
    planes instead of 18) and adds the in-register reconstruction flops
    (:data:`RECON_FLOPS_PER_LINK` x 8 expanded links per site) — the
    bytes/flops trade a memory-bound stencil wants to make.
    """
    sites = Tl * Zl * Y * Xh
    bytes_spinor = itemsize * SPINOR_COMPS * sites * nrhs   # read + written
    bytes_gauge = 2 * itemsize * 4 * gauge_comps * sites    # both parities
    total = 2 * bytes_spinor + bytes_gauge + (bytes_spinor if with_axpy else 0)
    recon = (RECON_FLOPS_PER_LINK[gauge_comps]
             * LINKS_EXPANDED_PER_SITE * sites)
    flops = HOP_FLOPS_PER_SITE * sites * nrhs + recon
    return {
        "flops": flops,
        "flops_recon": recon,
        "bytes_spinor": bytes_spinor,
        "bytes_gauge": bytes_gauge,
        "bytes_total": total,
        "intensity_flops_per_byte": flops / total,
    }


def hop_block_planar(u_out_p: jnp.ndarray, u_in_p: jnp.ndarray,
                     src_p: jnp.ndarray, out_parity: int, *,
                     tz_offset: Tuple[int, int] = (0, 0),
                     halo: bool = False,
                     axpy: Optional[Tuple[float, jnp.ndarray]] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Apply one hopping block in the planar layout via the Pallas kernel.

    Args:
      u_out_p: planar gauge at output-parity sites ``(4, T, Z, gc, Y, Xh)``
        with gc in {18, 12, 8} — compressed links are expanded
        in-register (never halo-extended, never batched).
      u_in_p: planar gauge at source-parity sites; halo-extended to
        ``(4, T+2, Z+2, ...)`` iff ``halo``.
      src_p: planar source spinor ``(T, Z, 24, Y, Xh)`` — or batched
        ``(nrhs, T, Z, 24, Y, Xh)`` — halo-extended in (T, Z) iff ``halo``.
        Batched sources run ONE kernel over the same (T, Z) grid with the
        gauge planes loaded once per step for the whole block.
      out_parity: parity of the *output* (ODD for ``H_oe``).
      tz_offset: global (t0, z0) origin of this shard, for the parity mask.
      halo: neighbor planes come from halo-extended arrays instead of
        periodic wrap (the distributed path).
      axpy: optional ``(coeff, psi0_p)`` fusing ``psi0 + coeff * hop``
        (``psi0_p`` batched iff ``src_p`` is).
      interpret: force/disable interpret mode (default: auto off-TPU).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batched = src_p.ndim == 6
    nrhs = src_p.shape[0] if batched else None
    lead = 1 if batched else 0
    Tl, Zl = src_p.shape[lead], src_p.shape[lead + 1]
    if halo:
        Tl, Zl = Tl - 2, Zl - 2
    Y, Xh = src_p.shape[-2], src_p.shape[-1]
    t0, z0 = tz_offset

    par = ((jnp.arange(Tl, dtype=jnp.int32)[:, None] + t0)
           + (jnp.arange(Zl, dtype=jnp.int32)[None, :] + z0)) % 2

    with_axpy = axpy is not None
    gauge_comps = u_out_p.shape[3]
    in_specs, out_spec = _build_specs(Tl, Zl, Y, Xh, halo, with_axpy,
                                      nrhs=nrhs, gauge_comps=gauge_comps)
    coeff = float(axpy[0]) if with_axpy else None

    model = hop_traffic_model(Tl, Zl, Y, Xh, nrhs=nrhs or 1,
                              itemsize=src_p.dtype.itemsize,
                              with_axpy=with_axpy,
                              gauge_comps=gauge_comps)
    cost = pl.CostEstimate(flops=model["flops"],
                           bytes_accessed=model["bytes_total"],
                           transcendentals=0)

    kernel = functools.partial(_hop_kernel, out_parity=out_parity,
                               axpy_coeff=coeff, batched=batched)
    operands = [par, src_p, src_p, src_p, src_p, src_p,
                u_out_p, u_in_p, u_in_p, u_in_p, u_in_p]
    if with_axpy:
        operands.append(axpy[1])

    out_shape = ((nrhs,) if batched else ()) + (Tl, Zl, SPINOR_COMPS, Y, Xh)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, src_p.dtype),
        grid=(Tl, Zl),
        in_specs=in_specs,
        out_specs=out_spec,
        interpret=interpret,
        cost_estimate=cost,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        name=f"wilson_hop_{'oe' if out_parity else 'eo'}",
    )
    return fn(*operands)


# ---------------------------------------------------------------------------
# Fused even-odd preconditioned operator: Dhat in ONE pallas_call.
# ---------------------------------------------------------------------------

# Conservative VMEM budget for the resident intermediate (v4/v5 cores have
# ~16 MiB; leave room for the pipelined operand/output blocks).
_FUSED_SCRATCH_LIMIT_BYTES = 12 << 20


def _dhat_kernel(par_ref, pc, pzp, pzm, ptp, ptm,
                 ue_all, ue_zm, ue_tm, uo_all, uo_zm, uo_tm,
                 out_ref, tmp_ref, *, kappa2: float, Tl: int, Zl: int,
                 batched: bool):
    """Fused ``Dhat = 1 - kappa^2 H_eo H_oe`` over grid ``(2, T, Z)``.

    Pass 0 (``s == 0``) computes the odd-parity intermediate
    ``tmp = H_oe psi_e`` plane by plane into a full-lattice VMEM scratch;
    pass 1 re-walks the grid applying ``H_eo`` to the scratch (z/t
    neighbor planes are VMEM reads with periodic wrap) and writes the
    fused ``psi0 - kappa^2 * (...)`` epilogue.  The intermediate spinor
    never exists in HBM — the round-trip the two-call
    ``apply_dhat_planar`` pays is gone (QWS applies the same fusion on
    A64FX; cf. Kanamori & Matsufuru on keeping intermediates
    SIMD-resident).

    Batched blocks keep the scratch component-leading
    ``(T, Z, 24, nrhs, Y, Xh)`` so both passes read planes in the layout
    the unrolled math wants; the scratch grows nrhs x (see
    :func:`fused_dhat_fits`).
    """
    s = pl.program_id(0)
    t = pl.program_id(1)
    z = pl.program_id(2)
    tz_par = par_ref[0, 0]
    p = _plane(pc, batched)           # psi_e center plane (24, [N,] Y, Xh)
    compute_dtype = p.dtype

    @pl.when(s == 0)
    def _pass_hoe():
        acc = _hop_plane(p, _plane(pzp, batched), _plane(pzm, batched),
                         _plane(ptp, batched), _plane(ptm, batched),
                         uo_all[:, 0, 0],
                         ue_all[0, 0, 0], ue_all[1, 0, 0],
                         ue_zm[0, 0, 0], ue_tm[0, 0, 0],
                         tz_par, 1)
        tmp_ref[t, z] = jnp.stack(acc).astype(compute_dtype)

    @pl.when(s == 1)
    def _pass_heo_axpy():
        tc = tmp_ref[t, z]
        tzp = tmp_ref[t, (z + 1) % Zl]
        tzm = tmp_ref[t, (z - 1) % Zl]
        ttp = tmp_ref[(t + 1) % Tl, z]
        ttm = tmp_ref[(t - 1) % Tl, z]
        acc = _hop_plane(tc, tzp, tzm, ttp, ttm,
                         ue_all[:, 0, 0],
                         uo_all[0, 0, 0], uo_all[1, 0, 0],
                         uo_zm[0, 0, 0], uo_tm[0, 0, 0],
                         tz_par, 0)
        hop2 = jnp.stack(acc).astype(compute_dtype)
        result = p - compute_dtype.type(kappa2) * hop2
        if batched:
            out_ref[:, 0, 0] = jnp.swapaxes(result, 0, 1)
        else:
            out_ref[0, 0] = result


def dhat_planar_fused(u_e_p: jnp.ndarray, u_o_p: jnp.ndarray,
                      psi_e_p: jnp.ndarray, kappa: float, *,
                      tz_offset: Tuple[int, int] = (0, 0),
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """``(1 - kappa^2 H_eo H_oe) psi_e`` as a single Pallas kernel.

    Both hopping blocks and the axpy epilogue run inside one
    ``pallas_call``; the odd intermediate lives in a full-lattice VMEM
    scratch for the whole invocation, so versus the two-call
    ``apply_dhat_planar`` path one spinor HBM write + pipelined re-read
    (5 planes per grid step) is eliminated.  Periodic single-shard only
    (the distributed path keeps the two-call structure so halos can
    overlap).  Batched sources ``(nrhs, T, Z, 24, Y, Xh)`` are supported;
    the scratch then holds the whole batched intermediate.

    The scratch is the (batched) odd-parity spinor: ``nrhs * 24 *
    T*Z*Y*Xh`` elements.  On a real TPU that caps the local volume (~12
    MiB budget); callers should fall back to the unfused path above that
    — :func:`fused_dhat_fits` (itemsize derived from the actual dtype)
    tells you.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batched = psi_e_p.ndim == 6
    nrhs = psi_e_p.shape[0] if batched else None
    lead = 1 if batched else 0
    Tl, Zl = psi_e_p.shape[lead], psi_e_p.shape[lead + 1]
    Y, Xh = psi_e_p.shape[-2], psi_e_p.shape[-1]
    t0, z0 = tz_offset

    gauge_comps = u_e_p.shape[3]
    if not interpret and not fused_dhat_fits(psi_e_p.shape, psi_e_p.dtype,
                                             gauge_comps=gauge_comps):
        tmp_bytes = psi_e_p.dtype.itemsize * math.prod(psi_e_p.shape)
        raise ValueError(
            f"fused Dhat intermediate needs {tmp_bytes} B of VMEM scratch "
            f"(> {_FUSED_SCRATCH_LIMIT_BYTES} budget at gauge_comps="
            f"{gauge_comps}); use the unfused apply_dhat_planar path for "
            "this local volume / nrhs")

    par = ((jnp.arange(Tl, dtype=jnp.int32)[:, None] + t0)
           + (jnp.arange(Zl, dtype=jnp.int32)[None, :] + z0)) % 2

    if batched:
        sblk = (nrhs, 1, 1, SPINOR_COMPS, Y, Xh)
    else:
        sblk = (1, 1, SPINOR_COMPS, Y, Xh)
    gblk1 = (1, 1, 1, gauge_comps, Y, Xh)

    def s(im):
        if not batched:
            return pl.BlockSpec(sblk, im)
        return pl.BlockSpec(sblk, lambda s_, t, z, _im=im: (0, *_im(s_, t, z)))

    def g(im):
        return pl.BlockSpec(gblk1, im)

    # Operands read by only one pass collapse to block (0, 0) in the
    # other pass (multiply the index by ``1 - s`` or ``s``): the block
    # index then stays constant across the dead pass's grid steps, so the
    # pipeliner fetches it once instead of streaming a full dead volume
    # from HBM — without this, pass 1 would re-fetch all four psi
    # neighbor planes it never reads and the fusion's HBM saving mostly
    # evaporates.
    psi_specs = [
        s(lambda _, t, z: (t, z, 0, 0, 0)),   # center: psi0 in pass 1
        s(lambda s_, t, z: (t * (1 - s_), ((z + 1) % Zl) * (1 - s_),
                            0, 0, 0)),
        s(lambda s_, t, z: (t * (1 - s_), ((z - 1) % Zl) * (1 - s_),
                            0, 0, 0)),
        s(lambda s_, t, z: (((t + 1) % Tl) * (1 - s_), z * (1 - s_),
                            0, 0, 0)),
        s(lambda s_, t, z: (((t - 1) % Tl) * (1 - s_), z * (1 - s_),
                            0, 0, 0)),
    ]

    def gauge_specs(live):
        # ``live(s)`` is 1 in the pass that reads the shifted planes.
        return [
            pl.BlockSpec((4, 1, 1, gauge_comps, Y, Xh),
                         lambda _, t, z: (0, t, z, 0, 0, 0)),
            g(lambda s_, t, z: (2, t * live(s_),
                                ((z - 1) % Zl) * live(s_), 0, 0, 0)),
            g(lambda s_, t, z: (3, ((t - 1) % Tl) * live(s_),
                                z * live(s_), 0, 0, 0)),
        ]

    par_spec = pl.BlockSpec((1, 1), lambda _, t, z: (t, z),
                            memory_space=pltpu.SMEM)
    in_specs = ([par_spec] + psi_specs
                + gauge_specs(lambda s_: 1 - s_)    # u_e shifts: pass 0
                + gauge_specs(lambda s_: s_))       # u_o shifts: pass 1
    out_spec = s(lambda _, t, z: (t, z, 0, 0, 0))

    # Two hopping blocks + axpy epilogue, but only one spinor read and
    # one write touch HBM (the intermediate is scratch-resident).
    n = nrhs or 1
    m = hop_traffic_model(Tl, Zl, Y, Xh, nrhs=n,
                          itemsize=psi_e_p.dtype.itemsize,
                          gauge_comps=gauge_comps)
    cost = pl.CostEstimate(
        flops=2 * m["flops"] + 2 * SPINOR_COMPS * Tl * Zl * Y * Xh * n,
        bytes_accessed=2 * m["bytes_spinor"] + 2 * m["bytes_gauge"],
        transcendentals=0)

    scratch_shape = ((Tl, Zl, SPINOR_COMPS)
                     + ((nrhs,) if batched else ()) + (Y, Xh))
    kernel = functools.partial(_dhat_kernel, kappa2=float(kappa) ** 2,
                               Tl=Tl, Zl=Zl, batched=batched)
    out_shape = ((nrhs,) if batched else ()) + (Tl, Zl, SPINOR_COMPS, Y, Xh)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, psi_e_p.dtype),
        grid=(2, Tl, Zl),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM(scratch_shape, psi_e_p.dtype)],
        interpret=interpret,
        cost_estimate=cost,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        name="wilson_dhat_fused",
    )
    return fn(par, psi_e_p, psi_e_p, psi_e_p, psi_e_p, psi_e_p,
              u_e_p, u_e_p, u_e_p, u_o_p, u_o_p, u_o_p)


def gauge_headroom_bytes(Y: int, Xh: int, itemsize: int,
                         gauge_comps: int = GAUGE_COMPS) -> int:
    """Extra VMEM freed per pipeline stage by compressed gauge blocks.

    The fused kernels keep 12 gauge plane-sets in flight per grid step
    (u_out x4 + u_in x4 shifted views per parity pass over the two
    pipelined passes), double-buffered by the pipeline.  Compression
    shrinks each from 18 to ``gauge_comps`` planes of ``(Y, Xh)``, and
    the scratch budget can absorb the difference — the resident/stream
    policy thresholds move accordingly.  Zero at ``gauge_comps == 18``.
    """
    return (GAUGE_COMPS - gauge_comps) * 12 * 2 * Y * Xh * itemsize


def fused_dhat_fits(psi_e_p_shape, dtype=jnp.float32, *,
                    gauge_comps: int = GAUGE_COMPS) -> bool:
    """Whether the fused kernel's VMEM-resident intermediate fits.

    ``psi_e_p_shape`` is the (possibly batched) planar spinor shape —
    ``(T, Z, 24, Y, Xh)`` or ``(nrhs, T, Z, 24, Y, Xh)``; the scratch is
    exactly that many elements.  ``dtype`` sizes one element (an int
    itemsize is also accepted for backward compatibility) — f64 under
    x64 halves the admissible volume versus f32, bf16 doubles it.
    Compressed links (``gauge_comps`` < 18) free pipeline VMEM
    (:func:`gauge_headroom_bytes`), nudging the threshold up.
    """
    itemsize = dtype if isinstance(dtype, int) else jnp.dtype(dtype).itemsize
    limit = _FUSED_SCRATCH_LIMIT_BYTES + gauge_headroom_bytes(
        psi_e_p_shape[-2], psi_e_p_shape[-1], itemsize, gauge_comps)
    return itemsize * math.prod(psi_e_p_shape) <= limit


# ---------------------------------------------------------------------------
# Streaming (plane-window) fused Dhat: the VMEM cap lifted.
# ---------------------------------------------------------------------------

# Ring rows of odd-intermediate t-planes held in VMEM by the streaming
# kernel: 3 live rows cover the +-t stencil reach of the second hopping
# block, +1 is the row being produced while the previous three are
# consumed (the double buffer).  This is the sliding working set of the
# KNL/AVX-512 predecessors (Kanamori & Matsufuru 1712.01505, 1811.00893)
# mapped onto the TPU pipeline.
STREAM_WINDOW_ROWS = 4


def stream_ring_bytes(psi_e_p_shape, dtype=jnp.float32,
                      window: int = STREAM_WINDOW_ROWS) -> int:
    """VMEM bytes of the streaming kernel's t-plane ring.

    The ring holds ``window`` t-rows of the (batched) odd intermediate —
    ``window * Z * 24 * nrhs * Y * Xh`` elements — so its size is derived
    from the actual ``dtype`` and the RHS batch but is *independent of
    T*: that is the cap-lift.  ``psi_e_p_shape`` as in
    :func:`fused_dhat_fits`.
    """
    itemsize = dtype if isinstance(dtype, int) else jnp.dtype(dtype).itemsize
    lead = 1 if len(psi_e_p_shape) == 6 else 0
    per_row = math.prod(psi_e_p_shape) // psi_e_p_shape[lead]
    return itemsize * window * per_row


def fused_dhat_stream_fits(psi_e_p_shape, dtype=jnp.float32, *,
                           gauge_comps: int = GAUGE_COMPS) -> bool:
    """Whether the streaming kernel's t-plane ring fits the VMEM budget."""
    itemsize = dtype if isinstance(dtype, int) else jnp.dtype(dtype).itemsize
    limit = _FUSED_SCRATCH_LIMIT_BYTES + gauge_headroom_bytes(
        psi_e_p_shape[-2], psi_e_p_shape[-1], itemsize, gauge_comps)
    return stream_ring_bytes(psi_e_p_shape, dtype) <= limit


def fused_dhat_policy(psi_e_p_shape, dtype=jnp.float32, *,
                      gauge_comps: int = GAUGE_COMPS) -> str:
    """Three-way fused-Dhat path selection for a planar spinor shape.

    ``"resident"`` — the whole (batched) odd intermediate fits the VMEM
    scratch budget: use :func:`dhat_planar_fused` (fewest HBM bytes).
    ``"stream"`` — it doesn't, but the :data:`STREAM_WINDOW_ROWS`-row
    plane window does: use :func:`dhat_planar_fused_stream` (same fusion,
    T-independent scratch, 2 recomputed boundary rows).
    ``"unfused"`` — even one window row ring is too large (enormous
    z-planes): fall back to the two-kernel ``apply_dhat_planar`` path,
    which needs no scratch at all.

    ``gauge_comps`` < 18 moves both thresholds up by the pipeline VMEM
    the compressed gauge blocks free (:func:`gauge_headroom_bytes`).
    """
    if fused_dhat_fits(psi_e_p_shape, dtype, gauge_comps=gauge_comps):
        return "resident"
    if fused_dhat_stream_fits(psi_e_p_shape, dtype,
                              gauge_comps=gauge_comps):
        return "stream"
    return "unfused"


def dhat_stream_traffic_model(Tl: int, Zl: int, Y: int, Xh: int, *,
                              nrhs: int = 1, itemsize: int = 4,
                              window: int = STREAM_WINDOW_ROWS,
                              gauge_comps: int = GAUGE_COMPS) -> dict:
    """HBM-traffic / flops / scratch model of one streaming fused Dhat.

    Versus the resident fused kernel the streaming variant recomputes 2
    boundary t-rows of ``H_oe`` (rows T-1 and 0 are produced twice so the
    periodic wrap reads fresh ring slots) and re-fetches their operand
    planes — a ``(T+2)/T`` factor on the first hopping block — while its
    VMEM scratch shrinks from the full lattice to the ``window``-row
    ring.  The :mod:`benchmarks` print these numbers next to measured
    times, and the kernel's ``pl.CostEstimate`` is built from them.
    """
    m = hop_traffic_model(Tl, Zl, Y, Xh, nrhs=nrhs, itemsize=itemsize,
                          gauge_comps=gauge_comps)
    sites = Tl * Zl * Y * Xh
    produce_scale = (Tl + 2) / Tl
    flops = (int(m["flops"] * produce_scale)      # H_oe incl. recompute
             + m["flops"]                          # H_eo
             + 2 * SPINOR_COMPS * sites * nrhs)    # axpy epilogue
    spinor1 = itemsize * SPINOR_COMPS * sites * nrhs
    bytes_spinor = int(spinor1 * (produce_scale + 2))  # psi in, psi0, out
    bytes_gauge = int(m["bytes_gauge"] * (produce_scale + 1))
    shape = ((nrhs,) if nrhs > 1 else ()) + (Tl, Zl, SPINOR_COMPS, Y, Xh)
    return {
        "flops": flops,
        "bytes_spinor": bytes_spinor,
        "bytes_gauge": bytes_gauge,
        "bytes_total": bytes_spinor + bytes_gauge,
        "intensity_flops_per_byte": flops / (bytes_spinor + bytes_gauge),
        "recompute_rows": 2,
        "window_rows": window,
        "vmem_ring_bytes": stream_ring_bytes(shape, itemsize,
                                             window=window),
        "vmem_resident_bytes": itemsize * math.prod(shape),
    }


def _dhat_stream_kernel(par_src, par_out, pc, pzp, pzm, ptp, ptm, psi0,
                        uo_src, ue_src, ue_zm, ue_tm,
                        ue_out, uo_out, uo_zm, uo_tm,
                        out_ref, ring_ref, *, kappa2: float, Tl: int,
                        Zl: int, window: int, batched: bool):
    """Streaming fused ``Dhat`` over grid ``(T + 3, Z)``.

    Step ``(s, z)`` runs two interleaved stages against a ``window``-row
    ring of odd-intermediate t-planes:

    * **produce** (``s <= T+1``): ``ring[s % window][z] = H_oe psi_e``
      for source row ``ts = (s-1) % T`` — the walk starts at row ``T-1``
      and ends by re-producing row ``0``, so both wrap neighbors of the
      consume stage read freshly-computed slots and the periodic
      boundary stays exact (2 recomputed rows total);
    * **consume** (``s >= 3``): output row ``to = (s-3) % T`` applies
      ``H_eo`` to ring rows ``to-1 / to / to+1`` (slots ``(s-3..s-1) %
      window`` — all complete, and all distinct from the slot being
      produced this step) and writes the fused ``psi0 - kappa^2 (...)``
      epilogue.

    The lag of 3 grid rows between produce and consume guarantees row
    ``to+1`` is complete across the whole z extent before any of its
    planes are read, so the ring never needs intra-step ordering.
    """
    s = pl.program_id(0)
    z = pl.program_id(1)
    compute_dtype = out_ref.dtype

    @pl.when(s <= Tl + 1)
    def _produce():
        p = _plane(pc, batched)
        acc = _hop_plane(p, _plane(pzp, batched), _plane(pzm, batched),
                         _plane(ptp, batched), _plane(ptm, batched),
                         uo_src[:, 0, 0],
                         ue_src[0, 0, 0], ue_src[1, 0, 0],
                         ue_zm[0, 0, 0], ue_tm[0, 0, 0],
                         par_src[0, 0], 1)
        ring_ref[s % window, z] = jnp.stack(acc).astype(compute_dtype)

    @pl.when(s >= 3)
    def _consume():
        tc = ring_ref[(s - 2) % window, z]
        tzp = ring_ref[(s - 2) % window, (z + 1) % Zl]
        tzm = ring_ref[(s - 2) % window, (z - 1) % Zl]
        ttp = ring_ref[(s - 1) % window, z]
        ttm = ring_ref[(s - 3) % window, z]
        acc = _hop_plane(tc, tzp, tzm, ttp, ttm,
                         ue_out[:, 0, 0],
                         uo_out[0, 0, 0], uo_out[1, 0, 0],
                         uo_zm[0, 0, 0], uo_tm[0, 0, 0],
                         par_out[0, 0], 0)
        hop2 = jnp.stack(acc).astype(compute_dtype)
        result = _plane(psi0, batched) - compute_dtype.type(kappa2) * hop2
        if batched:
            out_ref[:, 0, 0] = jnp.swapaxes(result, 0, 1)
        else:
            out_ref[0, 0] = result


def dhat_planar_fused_stream(u_e_p: jnp.ndarray, u_o_p: jnp.ndarray,
                             psi_e_p: jnp.ndarray, kappa: float, *,
                             tz_offset: Tuple[int, int] = (0, 0),
                             window: int = STREAM_WINDOW_ROWS,
                             interpret: Optional[bool] = None
                             ) -> jnp.ndarray:
    """``(1 - kappa^2 H_eo H_oe) psi_e`` as ONE kernel with a plane-window
    VMEM scratch — the cap-lifting variant of :func:`dhat_planar_fused`.

    Instead of the full-lattice odd intermediate, only a ``window``-row
    ring of t-planes lives in VMEM (``window * Z * 24 * nrhs * Y * Xh``
    elements — independent of T), double-buffered: each grid step
    produces ``H_oe`` of one t-row into the slot the consume stage is not
    reading while the fused ``H_eo`` + axpy epilogue consumes rows three
    steps behind.  The periodic t-wrap stays exact by producing the two
    boundary rows twice (rows ``T-1`` and ``0`` — see
    :func:`dhat_stream_traffic_model` for the accounted overhead).
    Periodic single-shard only, like the resident variant; batched
    sources ``(nrhs, T, Z, 24, Y, Xh)`` run one kernel with each gauge
    plane fetched once per grid step for the whole block.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if window < STREAM_WINDOW_ROWS:
        raise ValueError(
            f"stream window needs >= {STREAM_WINDOW_ROWS} rows (3 live "
            f"for the +-t stencil reach + 1 produce slot); got {window}")
    batched = psi_e_p.ndim == 6
    nrhs = psi_e_p.shape[0] if batched else None
    lead = 1 if batched else 0
    Tl, Zl = psi_e_p.shape[lead], psi_e_p.shape[lead + 1]
    Y, Xh = psi_e_p.shape[-2], psi_e_p.shape[-1]
    t0, z0 = tz_offset

    gauge_comps = u_e_p.shape[3]
    ring_bytes = stream_ring_bytes(psi_e_p.shape, psi_e_p.dtype,
                                   window=window)
    ring_limit = _FUSED_SCRATCH_LIMIT_BYTES + gauge_headroom_bytes(
        Y, Xh, psi_e_p.dtype.itemsize, gauge_comps)
    if not interpret and ring_bytes > ring_limit:
        raise ValueError(
            f"streaming Dhat ring needs {ring_bytes} B of VMEM "
            f"(> {ring_limit} budget at gauge_comps={gauge_comps}); this "
            "z-plane volume / nrhs needs the unfused apply_dhat_planar "
            "path")

    par = ((jnp.arange(Tl, dtype=jnp.int32)[:, None] + t0)
           + (jnp.arange(Zl, dtype=jnp.int32)[None, :] + z0)) % 2

    if batched:
        sblk = (nrhs, 1, 1, SPINOR_COMPS, Y, Xh)
    else:
        sblk = (1, 1, SPINOR_COMPS, Y, Xh)
    gblk1 = (1, 1, 1, gauge_comps, Y, Xh)
    gblk4 = (4, 1, 1, gauge_comps, Y, Xh)

    def spec(im):
        if not batched:
            return pl.BlockSpec(sblk, im)
        return pl.BlockSpec(sblk, lambda s, z, _im=im: (0, *_im(s, z)))

    def g(im):
        return pl.BlockSpec(gblk1, im)

    def g4(im):
        return pl.BlockSpec(gblk4, im)

    def par_spec(im):
        return pl.BlockSpec((1, 1), im, memory_space=pltpu.SMEM)

    # Produce stage reads source row ts = (s-1) % T; consume stage reads
    # output row to = (s-3) % T.  All wraps are modular block indices, so
    # the two out-of-range lead-in/lead-out rows of each stage fetch
    # valid (revisited) blocks and are simply gated off in the kernel.
    in_specs = [
        par_spec(lambda s, z: ((s - 1) % Tl, z)),            # par @ ts
        par_spec(lambda s, z: ((s - 3) % Tl, z)),            # par @ to
        spec(lambda s, z: ((s - 1) % Tl, z, 0, 0, 0)),       # psi center
        spec(lambda s, z: ((s - 1) % Tl, (z + 1) % Zl, 0, 0, 0)),
        spec(lambda s, z: ((s - 1) % Tl, (z - 1) % Zl, 0, 0, 0)),
        spec(lambda s, z: (s % Tl, z, 0, 0, 0)),             # t+1 of ts
        spec(lambda s, z: ((s - 2) % Tl, z, 0, 0, 0)),       # t-1 of ts
        spec(lambda s, z: ((s - 3) % Tl, z, 0, 0, 0)),       # psi0 @ to
        g4(lambda s, z: (0, (s - 1) % Tl, z, 0, 0, 0)),      # u_o all @ ts
        g4(lambda s, z: (0, (s - 1) % Tl, z, 0, 0, 0)),      # u_e x/y @ ts
        g(lambda s, z: (2, (s - 1) % Tl, (z - 1) % Zl, 0, 0, 0)),
        g(lambda s, z: (3, (s - 2) % Tl, z, 0, 0, 0)),
        g4(lambda s, z: (0, (s - 3) % Tl, z, 0, 0, 0)),      # u_e all @ to
        g4(lambda s, z: (0, (s - 3) % Tl, z, 0, 0, 0)),      # u_o x/y @ to
        g(lambda s, z: (2, (s - 3) % Tl, (z - 1) % Zl, 0, 0, 0)),
        g(lambda s, z: (3, (s - 4) % Tl, z, 0, 0, 0)),
    ]
    out_spec = spec(lambda s, z: ((s - 3) % Tl, z, 0, 0, 0))

    n = nrhs or 1
    model = dhat_stream_traffic_model(Tl, Zl, Y, Xh, nrhs=n,
                                      itemsize=psi_e_p.dtype.itemsize,
                                      window=window,
                                      gauge_comps=gauge_comps)
    cost = pl.CostEstimate(flops=model["flops"],
                           bytes_accessed=model["bytes_total"],
                           transcendentals=0)

    ring_shape = ((window, Zl, SPINOR_COMPS)
                  + ((nrhs,) if batched else ()) + (Y, Xh))
    kernel = functools.partial(_dhat_stream_kernel,
                               kappa2=float(kappa) ** 2, Tl=Tl, Zl=Zl,
                               window=window, batched=batched)
    out_shape = ((nrhs,) if batched else ()) + (Tl, Zl, SPINOR_COMPS, Y, Xh)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, psi_e_p.dtype),
        grid=(Tl + 3, Zl),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM(ring_shape, psi_e_p.dtype)],
        interpret=interpret,
        cost_estimate=cost,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        name="wilson_dhat_fused_stream",
    )
    return fn(par, par,
              psi_e_p, psi_e_p, psi_e_p, psi_e_p, psi_e_p, psi_e_p,
              u_o_p, u_e_p, u_e_p, u_e_p,
              u_e_p, u_o_p, u_o_p, u_o_p)
