import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ["REPRO_TPU_FAITHFUL_DOT"] = "1"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell and record memory / flop / collective statistics.

This is the proof that the distribution config is coherent: a sharding
mismatch, an OOM at compile, or an unsupported collective fails the cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun               # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --qcd-only
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import pathlib
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.launch.sharding import ShardingPolicy
from repro.models import steps as steps_lib
from repro.models.config import ModelConfig
from repro.optim import adamw

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
                "u8": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> Dict[str, Any]:
    """Sum output bytes of every collective in the compiled module.

    Uses the op *result* shape — for all-gather that is the gathered
    size (bytes received per device), for reduce-scatter the scattered
    size; a consistent per-device traffic proxy across op kinds.
    """
    by_kind: Dict[str, float] = {}
    count = 0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, shape_txt, kind = m.groups()
        b = _shape_bytes(shape_txt)
        by_kind[kind] = by_kind.get(kind, 0) + b
        count += 1
    return {"bytes_by_kind": by_kind,
            "total_bytes": sum(by_kind.values()),
            "n_ops": count}


# ---------------------------------------------------------------------------
# Probe-based exact accounting
# ---------------------------------------------------------------------------
# XLA's HLO cost analysis counts a while-loop body ONCE, ignoring the trip
# count, so flop/collective numbers from the full (scanned) compile are
# meaningless.  The dry-run therefore lowers two small UNROLLED probe
# variants (1 and 2 layer-groups, no grad-accumulation loop, no kv-chunk
# loop) whose cost analysis is exact, and scales:
#
#   group  = probe(2g) - probe(1g)        per-group, per-microbatch
#   base   = probe(1g) - group            embed/head/loss/opt, per-micro
#   total  = accum * (base_loss + n_groups * group) + opt_once
#
# For rwkv6 (the only arch with an inner sequence scan) probes run at a
# reduced sequence length and scale linearly — every rwkv op is linear in
# S at fixed chunk size.  The full compile is still performed for memory
# analysis and SPMD coherence.


def _probe_cfg(cfg: ModelConfig, groups: int) -> ModelConfig:
    kw = {"n_layers": groups * (cfg.moe_every if cfg.moe else 1)}
    if cfg.is_enc_dec:
        kw["encoder_layers"] = groups
    return cfg.scaled(**kw)


def _probe_stats(jfn, args) -> Dict[str, Any]:
    with _unrolled():
        lowered = jfn.lower(*args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total_bytes"],
            "coll_by_kind": coll["bytes_by_kind"]}


def _unrolled():
    from repro.models.scan_util import unroll_scans
    return unroll_scans()


def _combine(p1: Dict, p2: Dict, n_groups: int, accum: int = 1,
             seq_scale: float = 1.0) -> Dict[str, Any]:
    out = {}
    for k in ("flops", "bytes", "coll"):
        group = max(0.0, p2[k] - p1[k])
        base = max(0.0, p1[k] - group)
        out[k] = (base + n_groups * group) * accum * seq_scale
    kinds = set(p1["coll_by_kind"]) | set(p2["coll_by_kind"])
    out["coll_by_kind"] = {}
    for kind in kinds:
        a, b = p1["coll_by_kind"].get(kind, 0), p2["coll_by_kind"].get(kind, 0)
        group = max(0.0, b - a)
        base = max(0.0, a - group)
        out["coll_by_kind"][kind] = (base + n_groups * group) * accum \
            * seq_scale
    return out


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _accum_steps(policy: ShardingPolicy, global_batch: int,
                 target_local: int = 4) -> int:
    dp = 1
    for a in policy.batch_spec(global_batch):
        dp *= policy.mesh.shape[a]
    local = max(1, global_batch // dp)
    accum = max(1, local // target_local)
    while global_batch % (accum * dp) != 0 and accum > 1:
        accum -= 1
    return accum


def _attn_constraint(cfg: ModelConfig, policy: ShardingPolicy, mesh,
                     batch: int):
    """Head-parallel attention pin: q/out (B,S,H,hd) shard H over model,
    k/v (B,S,K,hd) shard K when divisible.  Prevents XLA from picking a
    layout that materializes replicated (H,S,S) score tensors.

    Decode special case: when the KV cache is hd-sharded (K % tp != 0),
    head-sharded q forces a per-step all-gather of the whole cache.
    Sharding q/out on hd instead keeps the cache resident and turns the
    mismatch into a small f32 score all-reduce (flash-decoding style)."""
    msize = mesh.shape["model"]
    b = policy.batch_spec(batch)
    kv_mismatch = cfg.n_kv_heads % msize != 0

    def fn(x, kind):
        if x.ndim != 4:
            return x
        S, heads, hd = x.shape[1], x.shape[2], x.shape[3]
        decode = S == 1
        if decode and kv_mismatch and hd % msize == 0 and hd >= msize:
            spec = P(b, None, None, "model")
        elif heads % msize == 0 and heads >= msize:
            spec = P(b, None, "model", None)
        elif kind == "q" and hd % msize == 0 and hd >= msize:
            spec = P(b, None, None, "model")
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return fn


def _with_ctx(fn, ctx_factory):
    """Wrap a step fn so a context manager is active during tracing."""
    def wrapped(*a, **k):
        with ctx_factory():
            return fn(*a, **k)
    return wrapped


def build_lm_lowering(cfg: ModelConfig, cell, mesh, *,
                      seq_shard: bool = True, accum: Optional[int] = None,
                      kv_chunk_prefill: int = 256,
                      opt_level: int = 1):
    """``opt_level=0`` is the pre-hillclimb baseline (global-token MoE
    routing, vocab FSDP, head-sharded decode q); ``1`` applies the
    optimizations recorded in EXPERIMENTS.md §Perf."""
    from repro.models import layers as layers_lib

    fsdp_axis = "data"
    serve_dtype = None
    if opt_level >= 1:
        if cfg.moe and cell.kind in ("train", "prefill"):
            dp_total = 1
            for a in mesh_lib.dp_axes(mesh):
                dp_total *= mesh.shape[a]
            cfg = cfg.scaled(route_groups=dp_total)
        if cell.kind == "decode":
            # weight-resident serving: bf16 params, TP-only when they fit
            serve_dtype = jnp.bfloat16
            if cfg.param_count() * 2 / mesh.shape["model"] < 12e9:
                fsdp_axis = None

    policy = ShardingPolicy(mesh, seq_shard_activations=seq_shard,
                            fsdp_axis=fsdp_axis,
                            vocab_fsdp=(opt_level == 0))
    params = specs_lib.params_shapes(cfg)
    pspecs = policy.param_specs(params)
    psh = policy.named(pspecs)
    attn_fn = _attn_constraint(cfg, policy, mesh, cell.global_batch)

    def attn_ctx():
        return layers_lib.attention_constraint(attn_fn)

    if cell.kind == "train":
        opt = adamw.AdamW()
        opt_shapes = jax.eval_shape(opt.init, params)
        osh = adamw.OptState(
            m=policy.named(pspecs), v=policy.named(pspecs),
            count=NamedSharding(mesh, P()))
        batch = specs_lib.batch_specs(cfg, cell.global_batch, cell.seq_len)
        bsh = {k: NamedSharding(mesh, s)
               for k, s in policy.data_spec(batch).items()}
        # bigger models get smaller microbatches (activation memory)
        target = 1 if cfg.param_count() > 2e10 else 4
        a = accum if accum is not None else _accum_steps(
            policy, cell.global_batch, target_local=target)
        micro_b = cell.global_batch // a
        act = policy.activation_spec(micro_b, cell.seq_len)

        def constraint(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, act))

        def grad_constraint(grads):
            if opt_level == 0:
                return grads
            gspecs = policy.param_specs(grads)
            return jax.tree_util.tree_map(
                lambda g, sp: jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, sp)), grads, gspecs)

        fn = steps_lib.make_train_step(cfg, opt, remat=True, accum_steps=a,
                                       constraint_fn=constraint,
                                       grad_constraint_fn=grad_constraint)
        fn = _with_ctx(fn, attn_ctx)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        jfn = jax.jit(fn,
                      in_shardings=(psh, osh, bsh,
                                    NamedSharding(mesh, P())),
                      out_shardings=(psh, osh, None),
                      donate_argnums=(0, 1))
        return jfn, (params, opt_shapes, batch, step_sds), {"accum": a}

    if cell.kind == "prefill":
        batch = specs_lib.batch_specs(cfg, cell.global_batch, cell.seq_len)
        bsh = {k: NamedSharding(mesh, s)
               for k, s in policy.data_spec(batch).items()}
        fn = steps_lib.make_prefill_step(cfg, cell.seq_len,
                                         kv_chunk=kv_chunk_prefill)
        fn = _with_ctx(fn, attn_ctx)
        cache_sds = specs_lib.cache_shapes(cfg, cell.global_batch,
                                           cell.seq_len)
        csh = policy.named(policy.cache_specs(cfg, cache_sds))
        jfn = jax.jit(fn, in_shardings=(psh, bsh),
                      out_shardings=(None, csh, None))
        return jfn, (params, batch), {}

    # decode
    if serve_dtype is not None:
        params = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, serve_dtype)
            if x.dtype == jnp.float32 else x, params)
        psh = policy.named(policy.param_specs(params))
    cache_sds, tok_sds, idx_sds = specs_lib.decode_specs(cfg, cell)
    csh = policy.named(policy.cache_specs(cfg, cache_sds))
    tsh = NamedSharding(mesh, P(policy.batch_spec(cell.global_batch), None))
    fn = _with_ctx(steps_lib.make_serve_step(cfg), attn_ctx)
    jfn = jax.jit(fn,
                  in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
                  out_shardings=(tsh, None, csh),
                  donate_argnums=(1,))
    return jfn, (params, cache_sds, tok_sds, idx_sds), {}


def scan_aware_collectives(hlo: str, n_groups: int) -> Dict[str, Any]:
    """Collective bytes of a compiled module with ONE level of while
    loops, all assumed to be the layer scan (true for decode graphs):
    entry collectives count once, loop-body collectives x ``n_groups``.

    Used for decode cells where unrolled probes are unreliable: XLA picks
    different (gather-happy) strategies for 2-4 unrolled layers than for
    the actual scanned graph, so the scanned body is the ground truth.
    """
    comps = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{", line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    body_names = set()
    for line in hlo.splitlines():
        m = re.search(r"body=%?([\w\.\-]+)", line)
        if m:
            body_names.add(m.group(1))

    def comp_coll(name):
        by = {}
        for line in comps.get(name, []):
            m = _COLL_RE.search(line)
            if m:
                _, shape_txt, kind = m.groups()
                by[kind] = by.get(kind, 0) + _shape_bytes(shape_txt)
        return by

    total = {}
    for name in comps:
        mult = n_groups if name in body_names else 1
        for k, v in comp_coll(name).items():
            total[k] = total.get(k, 0) + mult * v
    return {"bytes_by_kind": total, "total_bytes": sum(total.values())}


def probe_lm(cfg: ModelConfig, cell, mesh, *, seq_shard: bool,
             accum: int) -> Dict[str, Any]:
    """Exact per-device flop/byte/collective totals via unrolled probes."""
    seq_scale = 1.0
    cellp = cell
    if cfg.attention == "none" and cell.kind in ("train", "prefill") \
            and cell.seq_len > 2048:
        # rwkv: linear in S at fixed chunk; probe short, scale up
        seq_scale = cell.seq_len / 2048
        cellp = dataclasses.replace(cellp, seq_len=2048)
    if cell.kind == "train":
        cellp = dataclasses.replace(cellp,
                                    global_batch=cell.global_batch // accum)
    stats = []
    pair = (2, 3) if cell.kind == "decode" else (1, 2)
    for g in pair:
        pc = _probe_cfg(cfg, g)
        jfn, sds, _ = build_lm_lowering(pc, cellp, mesh,
                                        seq_shard=seq_shard,
                                        accum=1, kv_chunk_prefill=0)
        stats.append(_probe_stats(jfn, sds))
    n_groups = cfg.n_layers // (cfg.moe_every if cfg.moe else 1)
    # with pair (a, b): group = b - a; base = a - pair[0]*group
    out = {}
    a, bst = stats
    for k in ("flops", "bytes", "coll"):
        group = max(0.0, bst[k] - a[k]) / (pair[1] - pair[0])
        base = max(0.0, a[k] - pair[0] * group)
        out[k] = (base + n_groups * group) * \
            (accum if cell.kind == "train" else 1) * seq_scale
    kinds = set(a["coll_by_kind"]) | set(bst["coll_by_kind"])
    out["coll_by_kind"] = {}
    for kind in kinds:
        x, y = a["coll_by_kind"].get(kind, 0), \
            bst["coll_by_kind"].get(kind, 0)
        group = max(0.0, y - x) / (pair[1] - pair[0])
        base = max(0.0, x - pair[0] * group)
        out["coll_by_kind"][kind] = (base + n_groups * group) * \
            (accum if cell.kind == "train" else 1) * seq_scale
    return out


def model_flops(cfg: ModelConfig, cell) -> float:
    n_active = cfg.param_count(active_only=True)
    if cell.kind == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch  # decode: one token


# ---------------------------------------------------------------------------
# QCD cells (the paper's own operator on the production mesh)
# ---------------------------------------------------------------------------

def build_qcd_lowering(lat, mesh, *, backend: str = "jnp",
                       overlap: str = "fused", hoist_gauge: bool = False,
                       dtype=jnp.float32):
    from repro.distributed import qcd as qcd_lib

    part = qcd_lib.QCDPartition.for_mesh(mesh, backend=backend,
                                         overlap=overlap, interpret=True,
                                         hoist_gauge=hoist_gauge)
    T, Z, Y, X = lat.shape
    Xh = X // 2
    ext = 2 if hoist_gauge else 0
    spin = jax.ShapeDtypeStruct((T, Z, 24, Y, Xh), dtype)
    # pre-extended gauge: per-shard halos -> global T/Z dims grow by
    # 2 * (number of shards along the axis)
    tsh = mesh_lib.axis_size(mesh, part.t_axes) if hoist_gauge else 0
    zsh = mesh_lib.axis_size(mesh, part.z_axes) if hoist_gauge else 0
    gauge = jax.ShapeDtypeStruct(
        (4, T + 2 * tsh, Z + 2 * zsh, 18, Y, Xh), dtype)
    # Dry-run lowering jits against abstract ShapeDtypeStructs, so there
    # is no gauge to bind a registry backend to.
    # repro-lint: allow[R2] abstract lowering needs the raw sharded dhat
    dhat = qcd_lib.make_dhat_fn(part, lat.kappa)
    jfn = jax.jit(dhat,
                  in_shardings=(part.gauge_sharding(), part.gauge_sharding(),
                                part.spinor_sharding()),
                  out_shardings=part.spinor_sharding())
    return jfn, (gauge, gauge, spin)


def qcd_model_flops(lat) -> float:
    T, Z, Y, X = lat.shape
    V = T * Z * Y * X
    return 1320.0 * V + 24.0 * V / 2  # two eo hop blocks + fused axpy


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(name: str, jfn, args, extra: Dict[str, Any],
             n_devices: int) -> Dict[str, Any]:
    t0 = time.time()
    lowered = jfn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    coll = collective_stats(hlo_text)
    rec = {
        "cell": name,
        "status": "ok",
        "n_devices": n_devices,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": float(ca.get("flops", -1)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", -1)),
        "arg_bytes_per_device": int(ma.argument_size_in_bytes),
        "out_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "peak_bytes_per_device": int(getattr(ma, "peak_memory_in_bytes", 0)),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "fit_bytes_per_device": int(ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        "collectives": coll,
        "_hlo": hlo_text,
        **extra,
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all', or comma list")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--qcd", action="store_true", default=True)
    ap.add_argument("--no-qcd", dest="qcd", action="store_false")
    ap.add_argument("--qcd-only", action="store_true")
    ap.add_argument("--lm-seq-shard", type=int, default=1)
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = {"single": False, "multi": True}
    if args.mesh != "both":
        meshes = {args.mesh: meshes[args.mesh]}

    results = []

    def record(rec, fname):
        rec.pop("_hlo", None)
        results.append(rec)
        (out_dir / fname).write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ("" if status != "ok" else
                 f" flops/dev={rec['flops_per_device']:.3e}"
                 f" fit={rec['fit_bytes_per_device']/2**30:.2f}GiB"
                 f" coll={rec['collectives']['total_bytes']/2**20:.1f}MiB"
                 f" compile={rec['compile_s']:.1f}s")
        print(f"[{status:>4s}] {rec['cell']}{extra}", flush=True)

    if not args.qcd_only:
        arch_list = (list(configs.ARCH_NAMES) if args.arch == "all"
                     else args.arch.split(","))
        for arch in arch_list:
            cfg = configs.get(arch)
            for cell, skip in configs.shapes_for(cfg):
                if args.shape != "all" and cell.name not in \
                        args.shape.split(","):
                    continue
                for mname, multi in meshes.items():
                    cname = f"{arch}__{cell.name}__{mname}"
                    fname = f"{cname.replace('/', '_')}.json"
                    if skip:
                        record({"cell": cname, "status": "skip",
                                "reason": skip}, fname)
                        continue
                    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
                    try:
                        jfn, sds, extra = build_lm_lowering(
                            cfg, cell, mesh,
                            seq_shard=bool(args.lm_seq_shard))
                        rec = run_cell(cname, jfn, sds, extra,
                                       mesh.devices.size)
                        rec["model_flops_global"] = model_flops(cfg, cell)
                        rec["kind"] = cell.kind
                        rec["arch"] = arch
                        rec["shape"] = cell.name
                        rec["mesh"] = mname
                        try:
                            probe = probe_lm(
                                cfg, cell, mesh,
                                seq_shard=bool(args.lm_seq_shard),
                                accum=rec.get("accum", 1))
                            rec["exact"] = probe
                            if cell.kind == "decode":
                                # unrolled probes over-gather vs the real
                                # scanned graph; use the scan body itself
                                ng = cfg.n_layers // (cfg.moe_every
                                                      if cfg.moe else 1)
                                sc = scan_aware_collectives(
                                    rec.pop("_hlo", ""), ng) \
                                    if "_hlo" in rec else None
                                if sc and sc["total_bytes"] > 0:
                                    rec["exact"]["coll"] = \
                                        sc["total_bytes"]
                                    rec["exact"]["coll_by_kind"] = \
                                        sc["bytes_by_kind"]
                        except Exception as e:  # noqa: BLE001
                            rec["probe_error"] = \
                                f"{type(e).__name__}: {e}"
                    except Exception as e:  # noqa: BLE001
                        rec = {"cell": cname, "status": "fail",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                    record(rec, fname)

    if args.qcd or args.qcd_only:
        for lat_name in ("wilson-production",) if not args.qcd_only else \
                tuple(configs.QCD_CONFIGS):
            lat = configs.get_qcd(lat_name)
            variants = {
                "fused": dict(overlap="fused"),
                "split": dict(overlap="split"),
                "planar": dict(backend="jnp_planar"),
                "opt": dict(backend="jnp_planar", hoist_gauge=True),
                "opt-bf16": dict(backend="jnp_planar", hoist_gauge=True,
                                 dtype=jnp.bfloat16),
            }
            for mname, multi in meshes.items():
                for overlap, vkw in variants.items():
                    cname = f"{lat_name}__dhat-{overlap}__{mname}"
                    fname = f"{cname}.json"
                    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
                    # divisibility: T over (pod,data), Z over model
                    tsh = mesh_lib.axis_size(
                        mesh, tuple(a for a in ("pod", "data")
                                    if a in mesh.axis_names))
                    zsh = mesh_lib.axis_size(mesh, ("model",))
                    T, Z = lat.shape[0], lat.shape[1]
                    skip = None
                    if T % tsh or Z % zsh:
                        skip = (f"lattice T={T},Z={Z} not divisible by "
                                f"mesh shards ({tsh},{zsh}); paper volumes "
                                "are per-node, run them on smaller meshes")
                    elif vkw.get("overlap") == "split" and                             (T // tsh < 2 or Z // zsh < 2):
                        skip = "split overlap needs local T,Z >= 2"
                    if skip:
                        record({"cell": cname, "status": "skip",
                                "reason": skip}, fname)
                        continue
                    try:
                        jfn, sds = build_qcd_lowering(lat, mesh, **vkw)
                        rec = run_cell(cname, jfn, sds, {},
                                       mesh.devices.size)
                        rec["model_flops_global"] = qcd_model_flops(lat)
                        rec["kind"] = "qcd"
                        rec["arch"] = lat_name
                        rec["shape"] = f"dhat-{overlap}"
                        rec["mesh"] = mname
                        # loop-free graph: raw cost analysis is exact
                        rec["exact"] = {
                            "flops": rec["flops_per_device"],
                            "bytes": rec["bytes_accessed_per_device"],
                            "coll": rec["collectives"]["total_bytes"],
                            "coll_by_kind":
                                rec["collectives"]["bytes_by_kind"],
                        }
                    except Exception as e:  # noqa: BLE001
                        rec = {"cell": cname, "status": "fail",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                    record(rec, fname)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"of {len(results)} cells")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
