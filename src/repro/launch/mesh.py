"""Device meshes for the production dry-run and elastic re-meshing.

All constructors are functions (never module-level constants) so importing
this module touches no jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Uses the first prod(shape) devices so the single-pod mesh also works
    in a 512-device dry-run process.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "for the dry-run")
    arr = np.asarray(devs[:n], dtype=object).reshape(shape)
    return Mesh(arr, axes)


def elastic_mesh(n_devices: Optional[int] = None,
                 max_model: int = 16) -> Mesh:
    """Re-derive a legal (data, model) mesh from a surviving device count.

    Fault-tolerance helper: after losing nodes, pick the largest
    power-of-two model axis <= max_model that divides the device count and
    put the rest on data.  Single device degrades to (1, 1).
    """
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    model = 1
    while model * 2 <= max_model and n % (model * 2) == 0:
        model *= 2
    data = n // model
    arr = np.asarray(devs[:n], dtype=object).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
