"""Roofline analysis over the dry-run records.

Per (arch x shape x mesh) cell, from the probe-exact per-device numbers:

  compute term     = flops_per_device / peak_flops          [s]
  memory term      = bytes_per_device / hbm_bw              [s]
  collective term  = collective_bytes_per_device / ici_bw   [s]

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per instructions).  The dominant term is the bottleneck; the
roofline fraction reported in EXPERIMENTS.md §Perf is
``compute_term / max(all three)`` (1.0 = compute-bound at peak).

``MODEL_FLOPS / HLO_FLOPS`` measures how much compiled compute is useful
(catches remat/redundancy waste): for training with full remat the
expected value is ~6/8 = 0.75.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
prints the table and writes experiments/roofline.csv / .md.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (approx, one direction)

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments"


def analyze(rec: Dict) -> Dict:
    if rec.get("status") != "ok" or "exact" not in rec:
        return {}
    e = rec["exact"]
    n_dev = rec["n_devices"]
    t_compute = e["flops"] / PEAK_FLOPS
    t_memory = e["bytes"] / HBM_BW
    t_coll = e["coll"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    model_flops_dev = rec.get("model_flops_global", 0) / n_dev
    return {
        "cell": rec["cell"],
        "arch": rec.get("arch", "?"),
        "shape": rec.get("shape", "?"),
        "mesh": rec.get("mesh", "?"),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_compute / t_bound if t_bound else 0.0,
        "model_flops_per_device": model_flops_dev,
        "useful_flops_ratio": (model_flops_dev / e["flops"]
                               if e["flops"] else 0.0),
        "fit_gib": rec.get("fit_bytes_per_device", 0) / 2 ** 30,
        "step_time_bound_s": t_bound,
        "chip_seconds": t_bound * n_dev,
    }


def load_records(d: pathlib.Path) -> List[Dict]:
    recs = []
    for f in sorted(d.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_row(a: Dict) -> str:
    return (f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} "
            f"| {a['t_collective_s']*1e3:.2f} | {a['dominant']} "
            f"| {a['roofline_fraction']:.3f} "
            f"| {a['useful_flops_ratio']:.2f} | {a['fit_gib']:.1f} |")


HEADER = ("| arch | shape | mesh | compute ms | memory ms | coll ms "
          "| dominant | roofline frac | useful/HLO | fit GiB |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(OUT_DIR / "dryrun"))
    args = ap.parse_args(argv)
    recs = load_records(pathlib.Path(args.dir))
    rows, skips, fails = [], [], []
    for r in recs:
        if r.get("status") == "skip":
            skips.append(r)
        elif r.get("status") == "fail":
            fails.append(r)
        else:
            a = analyze(r)
            if a:
                rows.append(a)
    rows.sort(key=lambda a: (a["arch"], a["shape"], a["mesh"]))
    print(HEADER)
    for a in rows:
        print(fmt_row(a))
    print(f"\n{len(rows)} analyzed, {len(skips)} skipped, "
          f"{len(fails)} failed")
    for s in skips:
        print(f"  skip: {s['cell']}: {s['reason']}")
    for f in fails:
        print(f"  FAIL: {f['cell']}: {f.get('error', '?')[:120]}")

    out = OUT_DIR / "roofline.md"
    body = [HEADER] + [fmt_row(a) for a in rows]
    out.write_text("\n".join(body) + "\n")
    csv = OUT_DIR / "roofline.csv"
    keys = list(rows[0].keys()) if rows else []
    with csv.open("w") as fh:
        fh.write(",".join(keys) + "\n")
        for a in rows:
            fh.write(",".join(str(a[k]) for k in keys) + "\n")
    print(f"wrote {out} and {csv}")


if __name__ == "__main__":
    main()
