"""Propagator-serving daemon CLI: coalescing solve service over HTTP.

  PYTHONPATH=src python -m repro.launch.serve --lattice wilson-8x8x8x8 \
      --max-block 4 --linger-ms 2 --port 8787

Binds one gauge configuration into a :class:`repro.api.WilsonMatrix`,
registers it with a :class:`repro.serving.PropagatorDaemon`, and serves
``POST /v1/solve`` / ``GET /v1/metrics`` / ``GET /v1/healthz`` on a
stdlib asyncio HTTP listener.  Concurrent requests sharing a
:class:`~repro.api.SolveSpec` coalesce into one multi-RHS solve (the
bandwidth-bound kernel streams the gauge once per batch); each caller
gets its own solution slice and per-column stats back.

``--selftest N`` runs the whole stack in-process instead of serving:
N concurrent HTTP requests over two distinct SolveSpecs, then asserts
the serving invariants — one executable trace per (spec, bucket) key
and a mean batch fill above one column — and exits nonzero if the
daemon failed to coalesce.  This is the CI smoke entry point.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
import urllib.request
from concurrent.futures import ThreadPoolExecutor

import jax

from repro import api, configs
from repro.core import evenodd, su3
from repro.serving import (BatchingPolicy, AdmissionPolicy,
                           HttpServerThread, PropagatorDaemon,
                           SessionPool, encode_array, serve_http)


def _parse_lattice(s: str):
    if s in configs.QCD_CONFIGS:
        return configs.get_qcd(s).shape
    try:
        parts = tuple(int(x) for x in s.split("x"))
    except ValueError:
        parts = ()
    if len(parts) != 4:
        raise SystemExit(
            f"--lattice must be a config name {sorted(configs.QCD_CONFIGS)} "
            f"or TxZxYxX; got {s!r}")
    return parts


def _build_daemon(args) -> PropagatorDaemon:
    shape = _parse_lattice(args.lattice)
    key = jax.random.PRNGKey(args.seed)
    U = (su3.weak_gauge(key, shape, eps=args.weak_eps)
         if args.weak_eps else su3.random_gauge(key, shape))
    Ue, Uo = evenodd.pack_gauge(U)
    matrix = api.WilsonMatrix.bind(
        Ue, Uo, args.kappa,
        backend=api.BackendSpec(
            name=api.BackendSpec(name=args.backend).resolve_name(),
            gauge_compression=args.gauge_compression).validated(),
        validate=args.validate, fallback=args.fallback)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    daemon = PropagatorDaemon(
        pool=SessionPool(capacity=args.pool_capacity),
        batching=BatchingPolicy(max_block=args.max_block,
                                linger_s=args.linger_ms / 1e3,
                                buckets=buckets),
        admission=AdmissionPolicy(
            max_queue_depth=args.max_queue_depth,
            default_timeout_s=args.timeout_s or None),
        donate=args.donate)
    spec = api.SolveSpec(method=args.method, tol=args.tol,
                         max_iters=args.max_iters)
    daemon.register(args.name, matrix,
                    warmup_spec=spec if args.warmup else None)
    print(f"registered {args.name!r}: lattice {shape}, backend "
          f"{matrix.backend.name}, kappa {args.kappa}", flush=True)
    return daemon


def _selftest(daemon: PropagatorDaemon, args) -> int:
    """In-process smoke: concurrent HTTP load over two SolveSpecs,
    then assert the coalescing invariants from the live metrics."""
    shape = _parse_lattice(args.lattice)
    lat = api.LatticeSpec(shape)
    srv = HttpServerThread(daemon, "127.0.0.1", args.port)
    host, port = srv.start()
    base = f"http://{host}:{port}"
    specs = [{"method": args.method, "tol": args.tol,
              "max_iters": args.max_iters},
             {"method": "bicgstab", "tol": args.tol,
              "max_iters": args.max_iters}]

    def one(i: int) -> dict:
        k = jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), i)
        eshape = lat.spinor_eo_shape()
        eta = (jax.random.normal(k, eshape + (2,)))
        eta = (eta[..., 0] + 1j * eta[..., 1]).astype("complex64")
        body = json.dumps({
            "matrix": args.name,
            "eta_e": encode_array(eta),
            "eta_o": encode_array(-eta),
            "spec": specs[i % len(specs)],
        }).encode()
        req = urllib.request.Request(
            base + "/v1/solve", data=body,
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=600) as resp:
            return json.loads(resp.read())

    n = args.selftest
    with ThreadPoolExecutor(max_workers=n) as ex:
        outs = list(ex.map(one, range(n)))

    with urllib.request.urlopen(base + "/v1/metrics",
                                timeout=60) as resp:
        metrics = json.loads(resp.read())
    srv.stop()
    daemon.drain()

    entry = metrics["pool"]["entries"][args.name]
    sess = entry["session"]
    fills = [o["stats"]["batch_columns"] for o in outs]
    ok = True
    nkeys = len(sess["keys"])
    if sess["traces"] != nkeys:
        print(f"FAIL: traces={sess['traces']} != keys={nkeys} "
              "(executable cache leaked a retrace)")
        ok = False
    mean_fill = metrics["mean_batch_columns"]
    if not mean_fill or mean_fill <= 1.0:
        print(f"FAIL: mean batch columns {mean_fill} <= 1 "
              "(no cross-request coalescing happened)")
        ok = False
    bad = [o["stats"] for o in outs
           if not all(o["stats"]["converged"])]
    if bad:
        print(f"FAIL: {len(bad)} requests did not converge: {bad[:2]}")
        ok = False
    print(json.dumps({
        "selftest": {"requests": n, "specs": len(specs),
                     "traces": sess["traces"], "keys": nkeys,
                     "mean_batch_columns": mean_fill,
                     "max_request_batch": max(fills),
                     "batches": metrics["batches"],
                     "batch_fill_hist":
                         metrics["batch_fill_hist"]}}, indent=2))
    print("selftest " + ("PASS" if ok else "FAIL"))
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--lattice", default="wilson-8x8x8x8",
                    help="config name or TxZxYxX extents")
    ap.add_argument("--kappa", type=float, default=0.13)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--gauge-compression", default="none",
                    choices=["none", "two_row", "minimal"])
    ap.add_argument("--validate", default="none",
                    choices=["none", "warn", "repair"])
    ap.add_argument("--fallback", action="store_true",
                    help="arm the PR 8 fallback chain: a poisoned "
                         "backend degrades this pool entry, the daemon "
                         "keeps serving")
    ap.add_argument("--weak-eps", type=float, default=0.0,
                    help="bind a weak-field gauge (fast convergence; "
                         "selftest/demo use)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--name", default="default",
                    help="pool name the matrix serves under")
    # solve spec served by --warmup/--selftest
    ap.add_argument("--method", default="cgnr")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--max-iters", type=int, default=2000)
    # batching / admission policy
    ap.add_argument("--max-block", type=int, default=4,
                    help="most RHS columns coalesced into one solve")
    ap.add_argument("--linger-ms", type=float, default=2.0,
                    help="how long a non-full batch waits for company")
    ap.add_argument("--buckets", default="1,2,4",
                    help="compiled batch sizes (ragged batches zero-pad "
                         "up); keeps the executable cache at one trace "
                         "per (spec, bucket)")
    ap.add_argument("--max-queue-depth", type=int, default=256,
                    help="admission bound; submits beyond it shed (429)")
    ap.add_argument("--timeout-s", type=float, default=30.0,
                    help="default per-request deadline (0 = none)")
    ap.add_argument("--pool-capacity", type=int, default=8,
                    help="LRU bound on registered matrices")
    ap.add_argument("--donate", action="store_true",
                    help="donate the assembled batch buffers to XLA")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-trace every bucket at register time")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--selftest", type=int, default=0, metavar="N",
                    help="run N concurrent requests over 2 SolveSpecs "
                         "in-process, assert coalescing invariants, "
                         "exit (CI smoke)")
    args = ap.parse_args(argv)

    daemon = _build_daemon(args)
    daemon.start()
    if args.selftest:
        sys.exit(_selftest(daemon, args))

    print(f"serving on http://{args.host}:{args.port} "
          f"(POST /v1/solve, GET /v1/metrics, GET /v1/healthz)",
          flush=True)
    try:
        asyncio.run(serve_http(daemon, args.host, args.port))
    except KeyboardInterrupt:
        print("draining...", flush=True)
    finally:
        daemon.drain()
        print(json.dumps(daemon.metrics(), indent=2, default=str))


if __name__ == "__main__":
    main()
