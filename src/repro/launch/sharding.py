"""Sharding policy: FSDP x TP PartitionSpecs for every parameter, cache
and activation in the system, with divisibility-checked fallbacks.

Rules (Megatron-style column/row pattern + FSDP):

* "column" weights (qkv/up projections) shard their output dim over
  ``model``; "row" weights (wo/down projections) shard their input dim —
  one all-reduce per block instead of per matmul.
* the other large dim shards over ``data`` (FSDP; XLA all-gathers per
  layer under the scan, which is exactly FSDP's schedule).
* a dim is only sharded if it divides the axis size (GSPMD rejects uneven
  shardings at jit boundaries); fallbacks go to the next-best dim or to
  replication.  Head-count indivisibility (minicpm3 40H, hymba 25H vs
  TP=16) is irrelevant here because feature dims, not head counts, are
  sharded.
* ``pod`` is pure data parallelism (params replicated across pods).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from . import mesh as mesh_lib

# param name -> which dim (from the end, ignoring stack dims) is TP-sharded
_COL = {"wq", "wk", "wv", "wg", "w1", "w3", "wq_a", "wq_b", "wkv_a",
        "wkv_b", "in_proj", "x_proj", "dt_proj", "wr"}
_ROW = {"wo", "w2", "out_proj"}


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"
    seq_shard_activations: bool = True
    # FSDP on the embedding/lm_head non-vocab dim costs a (D, V/tp)
    # all-gather per microbatch; off by default (replicating the non-TP
    # dim of the vocab matrices is cheap relative to that traffic)
    vocab_fsdp: bool = False

    @property
    def dp(self) -> Tuple[str, ...]:
        return mesh_lib.dp_axes(self.mesh)

    def _model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def _fsdp_size(self) -> int:
        return self.mesh.shape[self.fsdp_axis] if self.fsdp_axis else 1

    # -- parameters ------------------------------------------------------
    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        name = path[-1] if path else ""
        if name == "w" and len(path) >= 2:
            name = path[-2]
        nd = len(shape)
        if nd <= 1 or min(shape) == 0:
            return P()
        # embedding / head
        if name == "embed":
            # shard D (not V): jnp.take over a vocab-sharded table forces
            # a full-table all-gather per microbatch; D-sharded lookups
            # stay local (each device gathers its feature slice)
            return self._matrix_spec(shape, tp_dim=1,
                                     fsdp_dim=0 if self.vocab_fsdp else None,
                                     offset=0)
        if name == "lm_head":
            return self._matrix_spec(shape, tp_dim=1,
                                     fsdp_dim=0 if self.vocab_fsdp else None,
                                     offset=0)
        # stacked layer params carry 1 (layers) or 2 (groups) leading dims:
        # treat all but the trailing 2 dims as stack dims.
        offset = nd - 2
        if name in _ROW:
            return self._matrix_spec(shape, tp_dim=0, fsdp_dim=1,
                                     offset=offset)
        if name in _COL:
            return self._matrix_spec(shape, tp_dim=1, fsdp_dim=0,
                                     offset=offset)
        if name in ("router",):
            return self._matrix_spec(shape, tp_dim=None, fsdp_dim=0,
                                     offset=offset)
        if nd - offset >= 2:
            # expert tensors (E, D, F) etc.: handled via offset+explicit
            return self._matrix_spec(shape, tp_dim=1, fsdp_dim=0,
                                     offset=offset)
        return P()

    def _matrix_spec(self, shape, tp_dim: Optional[int],
                     fsdp_dim: Optional[int], offset: int) -> P:
        """Spec for the trailing matrix dims of ``shape`` after ``offset``
        stack dims (stack dims are never sharded)."""
        nd = len(shape)
        spec = [None] * nd
        msize, fsize = self._model_size(), self._fsdp_size()
        if tp_dim is not None:
            d = offset + tp_dim
            if d < nd and shape[d] % msize == 0 and shape[d] >= msize:
                spec[d] = self.model_axis
            else:  # fallback: other matrix dim
                d2 = offset + (1 - tp_dim)
                if d2 < nd and spec[d2] is None and shape[d2] % msize == 0 \
                        and shape[d2] >= msize:
                    spec[d2] = self.model_axis
        if self.fsdp_axis and fsdp_dim is not None:
            d = offset + fsdp_dim
            if d < nd and spec[d] is None and shape[d] % fsize == 0 \
                    and shape[d] >= fsize:
                spec[d] = self.fsdp_axis
            else:
                d2 = offset + (1 - fsdp_dim)
                if d2 < nd and spec[d2] is None and shape[d2] % fsize == 0 \
                        and shape[d2] >= fsize:
                    spec[d2] = self.fsdp_axis
        return P(*spec)

    def param_specs(self, params_shapes) -> Any:
        """Tree of PartitionSpecs matching a tree of ShapeDtypeStructs."""
        def one(path, leaf):
            names = tuple(_key_name(k) for k in path)
            return self.param_spec(names, leaf.shape)

        return jax.tree_util.tree_map_with_path(one, params_shapes)

    # -- batch / activations ---------------------------------------------
    def batch_spec(self, batch_size: int) -> Tuple[str, ...]:
        """Axes to shard the batch dim over (largest divisible prefix)."""
        axes = []
        n = 1
        for a in self.dp:
            if batch_size % (n * self.mesh.shape[a]) == 0:
                axes.append(a)
                n *= self.mesh.shape[a]
        return tuple(axes)

    def data_spec(self, batch: Dict[str, Any]) -> Dict[str, P]:
        out = {}
        for k, v in batch.items():
            b = self.batch_spec(v.shape[0])
            out[k] = P(b, *([None] * (v.ndim - 1)))
        return out

    def activation_spec(self, batch_size: int, seq_len: int) -> P:
        """Residual-stream constraint (B, S, D): DP batch + sequence
        sharding over the model axis (Megatron sequence parallelism) —
        bounds the remat-carry memory of deep models."""
        b = self.batch_spec(batch_size)
        if self.seq_shard_activations and seq_len % self._model_size() == 0 \
                and seq_len >= self._model_size():
            return P(b, self.model_axis, None)
        return P(b, None, None)

    # -- caches ------------------------------------------------------------
    def cache_spec(self, cfg: ModelConfig, name: str,
                   shape: Tuple[int, ...]) -> P:
        msize = self._model_size()
        batch = shape[1]
        b = self.batch_spec(batch)
        if name in ("k", "v", "xk", "xv"):     # (L, B, K, S, hd)
            K, hd = shape[2], shape[4]
            if K % msize == 0:
                return P(None, b, self.model_axis, None, None)
            if hd % msize == 0:
                return P(None, b, None, None, self.model_axis)
            return P(None, b, None, None, None)
        if name == "ckv":                       # (L, B, S, kv_rank)
            r = shape[3]
            tp = self.model_axis if r % msize == 0 else None
            return P(None, b, None, tp)
        if name == "krope":
            r = shape[3]
            tp = self.model_axis if r % msize == 0 else None
            return P(None, b, None, tp)
        if name == "s":                         # (L, B, H, hd, hd)
            H = shape[2]
            tp = self.model_axis if H % msize == 0 else None
            return P(None, b, tp, None, None)
        if name in ("h", "conv"):               # (L, B, ..., d_inner[, ds])
            di_dim = 2 if name == "h" else 3
            di = shape[di_dim]
            spec = [None] * len(shape)
            spec[1] = b
            if di % msize == 0:
                spec[di_dim] = self.model_axis
            return P(*spec)
        if name in ("x_tm", "x_cm"):            # (L, B, D)
            D = shape[2]
            tp = self.model_axis if D % msize == 0 else None
            return P(None, b, tp)
        return P(*([None] * len(shape)))

    def cache_specs(self, cfg: ModelConfig, cache_shapes) -> Any:
        def one(path, leaf):
            name = _key_name(path[-1])
            return self.cache_spec(cfg, name, leaf.shape)

        return jax.tree_util.tree_map_with_path(one, cache_shapes)

    # -- helpers -----------------------------------------------------------
    def named(self, spec_tree) -> Any:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
