"""QCD solver driver: solve D_W xi = eta on the (distributed) lattice with
checkpoint/restart fault tolerance — the end-to-end "serving" loop of the
paper's kind (linear solves are the unit of work in lattice QCD).

  PYTHONPATH=src python -m repro.launch.solve --lattice wilson-16x16x16x16 \
      --tol 1e-6 --ckpt-dir /tmp/qcd_ck

Restart logic: CG is restart-friendly — checkpoint (x, step) and rebuild
the residual from scratch on resume (r = b - A x); convergence continues
where it left off.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import backends, configs
from repro.checkpoint.ckpt import Checkpointer
from repro.core import evenodd, solver, su3, wilson


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--lattice", default="wilson-16x16x16x16")
    ap.add_argument("--kappa", type=float, default=0.13)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--method", default="cgnr",
                    choices=["cgnr", "bicgstab"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto"] + backends.available_backends(),
                    help="operator backend (registry name); 'auto' picks "
                         "jnp off-TPU and pallas_fused on TPU")
    ap.add_argument("--recompute-every", type=int, default=0,
                    help="recompute the true residual every N Krylov "
                         "iterations (0 = never)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--restart-every", type=int, default=0,
                    help="simulate failure/restart every N solves")
    ap.add_argument("--n-solves", type=int, default=1)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)

    lat = configs.get_qcd(args.lattice)
    T, Z, Y, X = lat.shape
    print(f"lattice {lat.shape}, kappa={args.kappa}")

    key = jax.random.PRNGKey(args.seed)
    U = su3.random_gauge(key, lat.shape)
    Ue, Uo = evenodd.pack_gauge(U)
    backend = args.backend
    if backend == "auto":
        backend = ("pallas_fused" if jax.default_backend() == "tpu"
                   else "jnp")
    # bind once: keeps the planarized gauge, partitioning, and jit
    # caches warm across the whole batch of solves; the solver then
    # iterates in the backend's native domain (encode/decode once per
    # solve, not once per operator application)
    bops = backends.make_wilson_ops(backend, Ue, Uo)
    print(f"backend {backend} (native domain: {bops.domain})")

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    for i in range(args.n_solves):
        ke = jax.random.fold_in(key, 100 + i)
        eta = (jax.random.normal(ke, (T, Z, Y, X, 4, 3))
               + 1j * jax.random.normal(jax.random.fold_in(ke, 1),
                                        (T, Z, Y, X, 4, 3))
               ).astype(jnp.complex64)
        ee, eo = evenodd.pack(eta)
        t0 = time.time()
        xe, xo, res = solver.solve_wilson_eo(
            Ue, Uo, ee, eo, args.kappa, method=args.method, tol=args.tol,
            recompute_every=args.recompute_every, backend=bops)
        xi = evenodd.unpack(xe, xo)
        r = eta - wilson.apply_wilson(U, xi, args.kappa)
        rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
        dt = time.time() - t0
        vol = T * Z * Y * X
        flops = 1368.0 * vol * 2 * int(res.iterations)  # ~2 Dhat/iter
        print(f"solve {i}: iters={int(res.iterations)} rel={rel:.2e} "
              f"{dt:.2f}s  ~{flops/dt/1e9:.2f} GFlop/s sustained",
              flush=True)
        if ckpt:
            ckpt.save(i, (xe, xo), extras={"rel": rel}, block=True)
    print("done")


if __name__ == "__main__":
    main()
