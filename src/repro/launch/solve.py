"""QCD solver driver: solve D_W xi = eta on the (distributed) lattice with
checkpoint/restart fault tolerance — the end-to-end "serving" loop of the
paper's kind (linear solves are the unit of work in lattice QCD).

  PYTHONPATH=src python -m repro.launch.solve --lattice wilson-16x16x16x16 \
      --tol 1e-6 --ckpt-dir /tmp/qcd_ck

Built on the public object API (:mod:`repro.api`): the CLI args are
parsed into one ``(LatticeSpec, BackendSpec, SolveSpec)`` triple, the
gauge field is bound ONCE into a :class:`repro.api.WilsonMatrix` (layout
conversion / sharding placement / policy selection at bind), and every
solve goes through one :class:`repro.api.SolveSession` — so the Krylov
loop is traced/compiled on the first solve only and each later
same-shape solve reuses the executable.  The session's cache/timing
report is printed at the end.

Restart logic: CG is restart-friendly — checkpoint (x, step) and rebuild
the residual from scratch on resume (r = b - A x); convergence continues
where it left off.

Multi-RHS: ``--nrhs N`` solves N sources as ONE batched Krylov solve.
``--inner-dtype f32|bf16`` switches to mixed-precision iterative
refinement (inner solves in the cheap dtype, outer f64 true-residual
loop; enables jax x64 automatically).  ``--backend help`` prints the
registry's per-backend capability metadata and exits.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import api, backends, configs
from repro.checkpoint.ckpt import Checkpointer
from repro.core import evenodd, su3, wilson
from repro.core import solver as _solver


def _backend_help() -> str:
    lines = ["registered operator backends (see also --backend help):"]
    for name in backends.available_backends():
        caps = backends.backend_info(name)
        lines.append(f"  {name}: {caps.description} "
                     f"[domain={caps.domain}, batched_kernels="
                     f"{caps.batched_kernels}]")
    return " ".join(lines)


def _print_backend_info():
    print("registered operator backends:")
    for name in backends.available_backends():
        caps = backends.backend_info(name)
        print(f"  {name}")
        print(f"    domain={caps.domain} gauge_form={caps.gauge_form} "
              f"batched_kernels={caps.batched_kernels}")
        print(f"    dtypes={list(caps.dtypes) or '(follows gauge)'} "
              f"interpret={caps.supports_interpret} "
              f"policies={list(caps.policies)}")
        print(f"    gauge_compressions={list(caps.gauge_compressions)}")
        print(f"    {caps.description}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--lattice", default="wilson-16x16x16x16")
    ap.add_argument("--kappa", type=float, default=0.13)
    ap.add_argument("--tol", type=float, default=1e-6)
    # choices DERIVED from the solver's method tuple via SolveSpec —
    # adding a Krylov method there adds it here (this is where plain
    # "cg", valid on the normal equations, comes from).
    ap.add_argument("--method", default="cgnr",
                    choices=list(api.SolveSpec.METHODS))
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "help"] + backends.available_backends(),
                    help="operator backend (registry name); 'auto' picks "
                         "jnp off-TPU and pallas_fused on TPU; 'help' "
                         "prints per-backend capability metadata and "
                         "exits. " + _backend_help())
    ap.add_argument("--gauge-compression", default="none",
                    choices=["none", "two_row", "minimal"],
                    help="stored SU(3) link representation: two_row "
                         "ships 12 of 18 real planes (-33%% gauge "
                         "bytes), minimal ships 8 (-55%%); the kernels "
                         "reconstruct the full matrix in-register")
    ap.add_argument("--overlap", default="",
                    choices=["", "fused", "split", "interior", "on",
                             "off"],
                    help="distributed-backend halo strategy: 'interior' "
                         "(alias 'on') overlaps the ppermute exchange "
                         "with the interior stencil, 'fused' (alias "
                         "'off') exchanges first, 'split' separates "
                         "local/halo passes; only valid with "
                         "--backend distributed")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="number of right-hand sides per solve; >1 runs "
                         "the batched kernels (gauge field streamed once "
                         "per application for the whole block)")
    ap.add_argument("--inner-dtype", default="",
                    choices=["", "f32", "bf16"],
                    help="mixed-precision iterative refinement: inner "
                         "Krylov solves in this dtype, outer f64 "
                         "true-residual loop to --tol (needs x64; "
                         "enabled automatically)")
    ap.add_argument("--recompute-every", type=int, default=0,
                    help="recompute the true residual every N Krylov "
                         "iterations (0 = never)")
    ap.add_argument("--deflate-rank", type=int, default=0,
                    help="low-mode deflation rank (0 = off): project "
                         "the normal operator's low modes out of every "
                         "solve of this gauge (methods: "
                         + ", ".join(_solver.DEFLATABLE_METHODS) + ")")
    ap.add_argument("--deflate-mode", default="lanczos",
                    choices=list(api.SolveSpec.DEFLATE_MODES),
                    help="how the deflation basis is built: 'lanczos' "
                         "pays a once-per-gauge eigensolve; 'recycle' "
                         "starts empty and harvests converged solutions "
                         "from the stream (per-solve iterations drop as "
                         "it fills — watch the session stats)")
    ap.add_argument("--deflate-iters", type=int, default=0,
                    help="Lanczos step count for --deflate-mode lanczos "
                         "(0 = auto; raise it when the low spectrum is "
                         "degenerate)")
    ap.add_argument("--validate", default="none",
                    choices=["none", "warn", "repair"],
                    help="SU(3) gauge-integrity audit at bind: 'warn' "
                         "reports unitarity/finiteness defects, "
                         "'repair' projects defective links back onto "
                         "the group before any codec packs them")
    ap.add_argument("--fallback", action="store_true",
                    help="graceful degradation: on a backend failure "
                         "(bind or solve time) walk the declared "
                         "fallback chain toward the jnp reference "
                         "instead of aborting the campaign")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--restart-every", type=int, default=0,
                    help="simulate failure/restart every N solves")
    ap.add_argument("--n-solves", type=int, default=1)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)

    if args.backend == "help":
        _print_backend_info()
        return

    inner_dtype = args.inner_dtype or None
    if inner_dtype:
        # The refinement outer loop measures its residual in f64.
        jax.config.update("jax_enable_x64", True)

    lat = configs.get_qcd(args.lattice)
    lattice = api.LatticeSpec(lat.shape)
    # Under mixed precision the bound matrix IS the inner-solve backend,
    # so bind it at the inner dtype (jnp has no dtype knob: its inner
    # solve runs at the gauge's complex64).  Resolve "auto" FIRST so
    # e.g. auto->pallas_fused on TPU still honors --inner-dtype.
    bname = api.BackendSpec(name=args.backend).resolve_name()
    opts = []
    if args.overlap:
        if bname != "distributed":
            ap.error("--overlap only applies to --backend distributed")
        opts.append(("overlap",
                     {"on": "interior", "off": "fused"}.get(args.overlap,
                                                            args.overlap)))
    bspec = api.BackendSpec(
        name=bname,
        dtype=(inner_dtype if inner_dtype and bname != "jnp"
               else None),
        gauge_compression=args.gauge_compression,
        opts=tuple(opts)).validated()
    sspec = api.SolveSpec(
        method=args.method, tol=args.tol,
        recompute_every=args.recompute_every,
        nrhs=args.nrhs if args.nrhs > 1 else None,
        inner_dtype=inner_dtype,
        deflate_rank=args.deflate_rank,
        deflate_mode=args.deflate_mode,
        deflate_iters=args.deflate_iters or None)

    T, Z, Y, X = lattice.extents
    print(f"lattice {lattice.extents}, kappa={args.kappa}, "
          f"nrhs={args.nrhs}"
          + (f", inner_dtype={inner_dtype}" if inner_dtype else ""))

    key = jax.random.PRNGKey(args.seed)
    U = su3.random_gauge(key, lattice.extents)
    Ue, Uo = evenodd.pack_gauge(U)
    # Bind once: layout conversion, placement, and policy selection
    # happen HERE; the session below then reuses one compiled solve for
    # the whole batch of same-shape solves.
    matrix = api.WilsonMatrix.bind(Ue, Uo, args.kappa, backend=bspec,
                                   validate=args.validate,
                                   fallback=args.fallback)
    session = api.SolveSession(matrix, sspec)
    print(f"backend {matrix.backend.name} "
          f"(native domain: {matrix.ops.domain})")
    if args.validate != "none":
        print(f"gauge audit: {matrix.gauge_audit}")
    if matrix.degraded:
        print(f"DEGRADED: requested {matrix.requested_backend}, running "
              f"{matrix.backend.name}; events={matrix.fallback_events}")

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    nrhs = args.nrhs

    # Mixed precision refines the iterate in f64; the sources (and hence
    # the returned solution, which is cast back to the source dtype)
    # must be complex128 for that accuracy to survive the decode.
    cdtype = jnp.complex128 if inner_dtype else jnp.complex64

    for i in range(args.n_solves):
        ke = jax.random.fold_in(key, 100 + i)
        bshape = ((nrhs,) if nrhs > 1 else ()) + (T, Z, Y, X, 4, 3)
        eta = (jax.random.normal(ke, bshape)
               + 1j * jax.random.normal(jax.random.fold_in(ke, 1), bshape)
               ).astype(cdtype)
        if nrhs > 1:
            ee, eo = jax.vmap(evenodd.pack)(eta)
        else:
            ee, eo = evenodd.pack(eta)
        t0 = time.time()
        xe, xo, res = session.solve(ee, eo)
        # The residual check is deliberately NOT the session's operator:
        # it re-verifies the solution against the independent full-system
        # reference D_W, so a broken backend can't vouch for itself.
        if nrhs > 1:
            xi = jax.vmap(evenodd.unpack)(xe, xo)
            r = eta - jax.vmap(
                # repro-lint: allow[R2] independent full-system residual
                lambda v: wilson.apply_wilson(U, v, args.kappa))(xi)
        else:
            xi = evenodd.unpack(xe, xo)
            # repro-lint: allow[R2] independent full-system residual
            r = eta - wilson.apply_wilson(U, xi, args.kappa)
        rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
        dt = time.time() - t0
        vol = T * Z * Y * X
        iters = int(jnp.max(res.iterations))
        flops = 1368.0 * vol * 2 * iters * nrhs  # ~2 Dhat/iter
        line = (f"solve {i}: iters={iters} rel={rel:.2e} {dt:.2f}s "
                f"({dt / nrhs:.2f}s/rhs) "
                f"~{flops / max(dt, 1e-9) / 1e9:.2f} GFlop/s sustained")
        if hasattr(res, "f64_applies"):
            line += (f"  [outer={res.outer_iterations} "
                     f"f64_applies={res.f64_applies} "
                     f"inner_iters={res.inner_iterations}]")
        print(line, flush=True)
        if ckpt:
            ckpt.save(i, (xe, xo), extras={"rel": rel}, block=True)

    st = session.stats()
    for keystr, row in st["keys"].items():
        steady = (f"{row['steady_state_s']:.3f}s"
                  if row["steady_state_s"] is not None else "n/a")
        line = (f"session[{keystr}]: solves={row['solves']} "
                f"first={row['first_solve_s']:.3f}s steady={steady}")
        if row.get("iterations"):
            line += f" iters={row['iterations']}"
        if row.get("deflation"):
            d = row["deflation"]
            line += (f" deflation={d['mode']}:{d['filled']}/{d['rank']}"
                     f" active={d['active']}")
        print(line)
    print(f"session: solves={st['solves']} traces={st['traces']} "
          f"cache_hits={st['cache_hits']} "
          f"cache_misses={st['cache_misses']} "
          f"fallbacks={st['fallbacks']} degraded={st['degraded']}")
    print("done")


if __name__ == "__main__":
    main()
