"""QCD solver driver: solve D_W xi = eta on the (distributed) lattice with
checkpoint/restart fault tolerance — the end-to-end "serving" loop of the
paper's kind (linear solves are the unit of work in lattice QCD).

  PYTHONPATH=src python -m repro.launch.solve --lattice wilson-16x16x16x16 \
      --tol 1e-6 --ckpt-dir /tmp/qcd_ck

Restart logic: CG is restart-friendly — checkpoint (x, step) and rebuild
the residual from scratch on resume (r = b - A x); convergence continues
where it left off.

Multi-RHS: ``--nrhs N`` solves N sources as ONE batched Krylov solve —
the kernels stream the gauge field once per application for the whole
block, so per-RHS time drops as N grows (until VMEM bounds the block).
``--inner-dtype f32|bf16`` switches to mixed-precision iterative
refinement (inner solves in the cheap dtype, outer f64 true-residual
loop; enables jax x64 automatically).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import backends, configs
from repro.checkpoint.ckpt import Checkpointer
from repro.core import evenodd, solver, su3, wilson


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--lattice", default="wilson-16x16x16x16")
    ap.add_argument("--kappa", type=float, default=0.13)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--method", default="cgnr",
                    choices=["cgnr", "bicgstab"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto"] + backends.available_backends(),
                    help="operator backend (registry name); 'auto' picks "
                         "jnp off-TPU and pallas_fused on TPU (whose "
                         "three-way policy streams a plane window when "
                         "the resident fused scratch overflows; "
                         "pallas_fused_stream forces that kernel)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="number of right-hand sides per solve; >1 runs "
                         "the batched kernels (gauge field streamed once "
                         "per application for the whole block)")
    ap.add_argument("--inner-dtype", default="",
                    choices=["", "f32", "bf16"],
                    help="mixed-precision iterative refinement: inner "
                         "Krylov solves in this dtype, outer f64 "
                         "true-residual loop to --tol (needs x64; "
                         "enabled automatically)")
    ap.add_argument("--recompute-every", type=int, default=0,
                    help="recompute the true residual every N Krylov "
                         "iterations (0 = never)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--restart-every", type=int, default=0,
                    help="simulate failure/restart every N solves")
    ap.add_argument("--n-solves", type=int, default=1)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)

    inner_dtype = args.inner_dtype or None
    if inner_dtype:
        # The refinement outer loop measures its residual in f64.
        jax.config.update("jax_enable_x64", True)

    lat = configs.get_qcd(args.lattice)
    T, Z, Y, X = lat.shape
    print(f"lattice {lat.shape}, kappa={args.kappa}, nrhs={args.nrhs}"
          + (f", inner_dtype={inner_dtype}" if inner_dtype else ""))

    key = jax.random.PRNGKey(args.seed)
    U = su3.random_gauge(key, lat.shape)
    Ue, Uo = evenodd.pack_gauge(U)
    backend = args.backend
    if backend == "auto":
        backend = ("pallas_fused" if jax.default_backend() == "tpu"
                   else "jnp")
    # bind once: keeps the planarized gauge, partitioning, and jit
    # caches warm across the whole batch of solves; the solver then
    # iterates in the backend's native domain (encode/decode once per
    # solve, not once per operator application).  Under mixed precision
    # the bound instance IS the inner-solve backend, so bind it at the
    # inner dtype (the refined driver can't re-dtype a prebuilt bops).
    opts = {}
    if inner_dtype and backend != "jnp":
        opts["dtype"] = solver.resolve_inner_dtype(inner_dtype)
    bops = backends.make_wilson_ops(backend, Ue, Uo, **opts)
    print(f"backend {backend} (native domain: {bops.domain})")

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    nrhs = args.nrhs

    # Mixed precision refines the iterate in f64; the sources (and hence
    # the returned solution, which is cast back to the source dtype)
    # must be complex128 for that accuracy to survive the decode.
    cdtype = jnp.complex128 if inner_dtype else jnp.complex64

    for i in range(args.n_solves):
        ke = jax.random.fold_in(key, 100 + i)
        bshape = ((nrhs,) if nrhs > 1 else ()) + (T, Z, Y, X, 4, 3)
        eta = (jax.random.normal(ke, bshape)
               + 1j * jax.random.normal(jax.random.fold_in(ke, 1), bshape)
               ).astype(cdtype)
        if nrhs > 1:
            ee, eo = jax.vmap(evenodd.pack)(eta)
        else:
            ee, eo = evenodd.pack(eta)
        t0 = time.time()
        xe, xo, res = solver.solve_wilson_eo(
            Ue, Uo, ee, eo, args.kappa, method=args.method, tol=args.tol,
            recompute_every=args.recompute_every,
            inner_dtype=inner_dtype, backend=bops)
        if nrhs > 1:
            xi = jax.vmap(evenodd.unpack)(xe, xo)
            r = eta - jax.vmap(
                lambda v: wilson.apply_wilson(U, v, args.kappa))(xi)
        else:
            xi = evenodd.unpack(xe, xo)
            r = eta - wilson.apply_wilson(U, xi, args.kappa)
        rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
        dt = time.time() - t0
        vol = T * Z * Y * X
        iters = int(jnp.max(res.iterations))
        flops = 1368.0 * vol * 2 * iters * nrhs  # ~2 Dhat/iter
        line = (f"solve {i}: iters={iters} rel={rel:.2e} {dt:.2f}s "
                f"({dt / nrhs:.2f}s/rhs) "
                f"~{flops / max(dt, 1e-9) / 1e9:.2f} GFlop/s sustained")
        if hasattr(res, "f64_applies"):
            line += (f"  [outer={res.outer_iterations} "
                     f"f64_applies={res.f64_applies} "
                     f"inner_iters={res.inner_iterations}]")
        print(line, flush=True)
        if ckpt:
            ckpt.save(i, (xe, xo), extras={"rel": rel}, block=True)
    print("done")


if __name__ == "__main__":
    main()
