"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, cell)`` returns the abstract inputs for one
(architecture x shape) cell; frontends are stubs, so vision/audio inputs
are precomputed embeddings of the documented sizes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.models import model as model_lib
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, SDS]:
    """Training/prefill batch: tokens + mask (+ stub frontend embeddings).

    For VLM the text length shrinks so prefix + text == seq (keeps cell
    cost comparable across archs); for audio enc-dec the encoder sees
    seq/4 frame embeddings (typical 4x pre-downsampled speech frontend).
    """
    out: Dict[str, SDS] = {}
    text = seq
    if cfg.modality == "vision" and cfg.num_prefix_embeds:
        text = seq - cfg.num_prefix_embeds
        out["prefix_embeds"] = SDS((batch, cfg.num_prefix_embeds,
                                    cfg.d_model), jnp.bfloat16)
    if cfg.is_enc_dec:
        out["enc_embeds"] = SDS((batch, max(seq // 4, 16), cfg.d_model),
                                jnp.bfloat16)
    out["tokens"] = SDS((batch, text), jnp.int32)
    out["mask"] = SDS((batch, text), jnp.float32)
    return out


def params_shapes(cfg: ModelConfig) -> Any:
    key = SDS((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(model_lib.init_params, cfg), key)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    enc_len = max(max_len // 4, 16) if cfg.is_enc_dec else 0
    return jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg, batch, max_len,
                          enc_len))


def decode_specs(cfg: ModelConfig, cell: ShapeCell
                 ) -> Tuple[Any, SDS, SDS]:
    """(cache, tokens, index) for one serve step at a full cache."""
    cache = cache_shapes(cfg, cell.global_batch, cell.seq_len)
    tokens = SDS((cell.global_batch, 1), jnp.int32)
    index = SDS((), jnp.int32)
    return cache, tokens, index
