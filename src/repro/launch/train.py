"""LM training driver with checkpoint/restart fault tolerance.

Usage (CPU-scale example; the same driver pjit-scales on a real mesh):

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --scale 0.05 --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ck

Fault tolerance:
* saves atomic last-k checkpoints (params, opt state, data cursor) every
  ``--ckpt-every`` steps, async;
* on start, resumes from the latest checkpoint if present (``--fresh`` to
  ignore), replaying the deterministic data stream from the saved cursor;
* ``--mesh elastic`` re-derives the mesh from whatever devices are alive
  (restore reshards via device_put).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint.ckpt import Checkpointer
from repro.data.pipeline import DataConfig, host_slice, make_source
from repro.launch import mesh as mesh_lib
from repro.launch.sharding import ShardingPolicy
from repro.models import model as model_lib
from repro.models import steps as steps_lib
from repro.optim import adamw


def scaled_config(name: str, scale: float):
    """Shrink a published config by ``scale`` for local runs (keeps the
    family: attention flavor, MoE layout, etc.)."""
    cfg = configs.get(name)
    if scale >= 1.0:
        return cfg
    d = max(64, int(cfg.d_model * scale) // 16 * 16)
    heads = max(2, int(cfg.n_heads * scale))
    hd = max(16, d // heads // 8 * 8)
    kv = max(1, min(cfg.n_kv_heads, heads))
    kw = dict(n_layers=max(2, int(cfg.n_layers * scale)),
              d_model=heads * hd, n_heads=heads, n_kv_heads=kv,
              head_dim=hd,
              d_ff=max(128, int(cfg.d_ff * scale) // 16 * 16),
              vocab_size=min(cfg.vocab_size, 32768))
    if cfg.moe:
        kw.update(n_experts=min(cfg.n_experts, 8),
                  moe_d_ff=max(128, int(cfg.moe_ff * scale) // 16 * 16))
        kw["n_layers"] = max(cfg.moe_every, kw["n_layers"]
                             // cfg.moe_every * cfg.moe_every)
    if cfg.attention == "mla":
        kw.update(q_lora_rank=max(32, int(cfg.q_lora_rank * scale)),
                  kv_lora_rank=max(32, int(cfg.kv_lora_rank * scale)),
                  qk_nope_dim=hd // 2, qk_rope_dim=hd // 2,
                  v_head_dim=hd, head_dim=hd)
    if cfg.attention == "none":
        kw.update(d_model=max(128, d // 64 * 64), rwkv_head_dim=64)
        kw["n_heads"] = kw["d_model"] // 64
        kw["n_kv_heads"] = kw["n_heads"]
        kw.pop("head_dim", None)
    if cfg.attention == "hybrid":
        kw.update(ssm_state=cfg.ssm_state)
    if cfg.is_enc_dec:
        kw["encoder_layers"] = max(2, int(cfg.encoder_layers * scale))
    return cfg.scaled(**kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--mesh", default="elastic",
                    choices=["elastic", "single", "multi"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default="", help="binary shard path")
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.scale)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} scaled params={n_params/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    if args.mesh == "elastic":
        mesh = mesh_lib.elastic_mesh()
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
    policy = ShardingPolicy(mesh, seq_shard_activations=False)

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    pspecs = policy.param_specs(jax.eval_shape(lambda: params))
    psh = policy.named(pspecs)
    params = jax.device_put(params, psh)

    opt = adamw.AdamW(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(10, args.steps // 20))
    opt_state = opt.init(params)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, path=args.data or None,
        num_prefix_embeds=cfg.num_prefix_embeds
        if cfg.modality == "vision" else 0,
        d_model=cfg.d_model,
        enc_frames=max(args.seq // 4, 16) if cfg.is_enc_dec else 0)
    source = make_source(dcfg)

    train_step = jax.jit(
        steps_lib.make_train_step(cfg, opt, remat=True),
        donate_argnums=(0, 1))

    start = 0
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and not args.fresh and ckpt.latest_step() is not None:
        rep = NamedSharding(mesh, P())
        opt_sh = adamw.OptState(m=psh, v=psh, count=rep)
        (params, opt_state), start, extras = ckpt.restore(
            (params, opt_state), shardings=(psh, opt_sh))
        print(f"resumed from step {start}")

    bsh = {k: NamedSharding(mesh, s) for k, s in policy.data_spec(
        source.batch(0)).items()}

    t0 = time.time()
    for step in range(start, args.steps):
        batch = host_slice(source.batch(step), bsh)
        params, opt_state, metrics = train_step(
            params, opt_state, batch, jnp.int32(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} grad_norm {gn:.3f} "
                  f"({dt:.1f}s)", flush=True)
        if ckpt and step > start and step % args.ckpt_every == 0:
            ckpt.save(step, (params, opt_state),
                      extras={"data_step": step})
    if ckpt:
        ckpt.save(args.steps, (params, opt_state),
                  extras={"data_step": args.steps}, block=True)
        ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
