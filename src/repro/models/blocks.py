"""Sequence-mixing blocks beyond attention: RWKV6 (Finch) and Mamba-style
selective SSM (used standalone and inside the Hymba hybrid layer).

Both carry O(1) decode state — these are the architectures that run the
``long_500k`` shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_dense, dense_init, rms_norm_init, apply_rms_norm
from .scan_util import xscan

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# RWKV6 time mixing (data-dependent per-channel decay), chunked-parallel
# ---------------------------------------------------------------------------

def rwkv_time_init(key, cfg) -> Params:
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    L = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 10)
    return {
        "mu": jax.random.uniform(ks[0], (5, D), jnp.float32),  # r,k,v,w,g lerp
        "wr": dense_init(ks[1], D, H * hd),
        "wk": dense_init(ks[2], D, H * hd),
        "wv": dense_init(ks[3], D, H * hd),
        "wg": dense_init(ks[4], D, H * hd),
        "w0": jax.random.normal(ks[5], (H * hd,), jnp.float32) - 6.0,
        "w1": dense_init(ks[6], D, L),
        "w2": dense_init(ks[7], L, H * hd, scale=0.01),
        "u": jax.random.normal(ks[8], (H, hd), jnp.float32) * 0.1,
        "ln": rms_norm_init(ks[9], H * hd),
        "wo": dense_init(jax.random.fold_in(key, 99), H * hd, D,
                         scale=1.0 / np.sqrt(H * hd)),
    }


def _wkv_chunk(r, k, v, w_log, u, state):
    """One chunk of the WKV6 recurrence, parallel inside the chunk.

    r,k,v: (B,H,C,hd); w_log: (B,H,C,hd) = log decay in (-inf, 0);
    u: (H,hd) bonus; state: (B,H,hd,hd) mapping k-dim -> v-dim.
    Returns (out (B,H,C,hd), new_state).
    """
    C = r.shape[2]
    cum = jnp.cumsum(w_log, axis=2)                     # decay from chunk start
    # inter-chunk: r_i . diag(exp(cum_{i-1})) . state ; cum_{i-1} = cum_i - w_i
    r_dec = r * jnp.exp(cum - w_log)
    out = jnp.einsum("bhck,bhkv->bhcv", r_dec, state)
    # intra-chunk: sum_{j<i} (r_i * exp(cum_{i-1} - cum_j)) . k_j  v_j
    att = jnp.einsum("bhik,bhjk->bhij", r_dec, k * jnp.exp(-cum))
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    att = jnp.where(mask, att, 0.0)
    out = out + jnp.einsum("bhij,bhjv->bhiv", att, v)
    # diagonal bonus term: (r_i * u . k_i) v_i
    diag = jnp.einsum("bhck,hk,bhck->bhc", r, u, k)
    out = out + diag[..., None] * v
    # state update: S' = diag(exp(cum_C)) S + sum_j diag(exp(cum_C - cum_j)) k_j v_j
    total = cum[:, :, -1:, :]
    kd = k * jnp.exp(total - cum)
    new_state = state * jnp.exp(total.squeeze(2))[..., None] + \
        jnp.einsum("bhck,bhcv->bhkv", kd, v)
    return out, new_state


def apply_rwkv_time(p: Params, x: jnp.ndarray, cfg, *,
                    state: Optional[Params] = None,
                    chunk: int = 64) -> Tuple[jnp.ndarray, Optional[Params]]:
    """RWKV6 time mixing.  ``state`` (decode): {"x": (B,D), "s": (B,H,hd,hd)}."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    first = (jnp.zeros_like(x[:, :1]) if state is None
             else state["x"][:, None, :].astype(x.dtype))
    prev = jnp.concatenate([first, x[:, :-1]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i] * (prev - x) for i in range(5))
    r = apply_dense(p["wr"], xr).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = apply_dense(p["wk"], xk).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = apply_dense(p["wv"], xv).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(apply_dense(p["wg"], xg))
    dec = p["w0"].astype(jnp.float32) + \
        jnp.tanh(apply_dense(p["w1"], xw).astype(jnp.float32)) @ \
        p["w2"]["w"]
    w_log = -jnp.exp(dec)                                # log decay < 0
    w_log = w_log.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    u = p["u"].astype(jnp.float32)

    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    s0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
          else state["s"].astype(jnp.float32))
    if S == 1:
        out, s1 = _wkv_chunk(rf, kf, vf, w_log, u, s0)
    elif S % chunk == 0 and S > chunk:
        nc = S // chunk

        def step(s, xs):
            rc, kc, vc, wc = xs
            o, s = _wkv_chunk(rc, kc, vc, wc, u, s)
            return s, o

        split = lambda a: a.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
        s1, outs = xscan(step, s0, tuple(map(split, (rf, kf, vf, w_log))))
        out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    else:
        out, s1 = _wkv_chunk(rf, kf, vf, w_log, u, s0)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd).astype(x.dtype)
    out = apply_rms_norm(p["ln"], out, cfg.rms_eps) * g
    out = apply_dense(p["wo"], out)
    new_state = None
    if state is not None:
        new_state = {"x": x[:, -1].astype(state["x"].dtype),
                     "s": s1.astype(state["s"].dtype)}
    return out, new_state


def rwkv_channel_init(key, cfg) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "mu": jax.random.uniform(ks[0], (2, D), jnp.float32),
        "wk": dense_init(ks[1], D, F),
        "wv": dense_init(ks[2], F, D, scale=1.0 / np.sqrt(F)),
        "wr": dense_init(ks[3], D, D),
    }


def apply_rwkv_channel(p: Params, x: jnp.ndarray, cfg, *,
                       state: Optional[Params] = None
                       ) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, D = x.shape
    first = (jnp.zeros_like(x[:, :1]) if state is None
             else state["x"][:, None, :].astype(x.dtype))
    prev = jnp.concatenate([first, x[:, :-1]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (prev - x)
    xr = x + mu[1] * (prev - x)
    k = jnp.square(jax.nn.relu(apply_dense(p["wk"], xk)))
    out = jax.nn.sigmoid(apply_dense(p["wr"], xr)) * apply_dense(p["wv"], k)
    new_state = None
    if state is not None:
        new_state = {"x": x[:, -1].astype(state["x"].dtype)}
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (Hymba's parallel head)
# ---------------------------------------------------------------------------

def ssm_init(key, cfg) -> Params:
    D, di, ds, dt = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], D, 2 * di),
        "conv": jax.random.normal(ks[1], (cfg.conv_kernel, di), jnp.float32)
        * (1.0 / np.sqrt(cfg.conv_kernel)),
        "x_proj": dense_init(ks[2], di, dt + 2 * ds),
        "dt_proj": dense_init(ks[3], dt, di),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, D, scale=1.0 / np.sqrt(di)),
    }


def apply_ssm(p: Params, x: jnp.ndarray, cfg, *,
              state: Optional[Params] = None,
              chunk: int = 256) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Selective scan.  ``state`` (decode): {"h": (B,di,ds),
    "conv": (B,k-1,di)} — O(1) per token.

    For long sequences the scan is *chunked*: the (B,S,d_inner,d_state)
    discretized operands are materialized one chunk at a time inside a
    ``lax.scan`` (carry = h), bounding memory at O(chunk * di * ds)
    instead of O(S * di * ds) — the associative scan runs within chunks.
    """
    B, S, D = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    kk = cfg.conv_kernel
    xz = apply_dense(p["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]

    # depthwise causal conv along S
    if state is None:
        pad = jnp.zeros((B, kk - 1, di), xin.dtype)
        new_conv = None
    else:
        pad = state["conv"].astype(xin.dtype)
        new_conv = jnp.concatenate([pad, xin], axis=1)[:, -(kk - 1):]
    xc = jnp.concatenate([pad, xin], axis=1)
    conv_w = p["conv"].astype(xin.dtype)
    xconv = sum(xc[:, i:i + S] * conv_w[i] for i in range(kk))
    xconv = jax.nn.silu(xconv)

    proj = apply_dense(p["x_proj"], xconv)
    dt_r, Bm, Cm = (proj[..., :cfg.ssm_dt_rank],
                    proj[..., cfg.ssm_dt_rank:cfg.ssm_dt_rank + ds],
                    proj[..., cfg.ssm_dt_rank + ds:])
    dt = jax.nn.softplus(apply_dense(p["dt_proj"], dt_r)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                              # (di, ds)
    h0 = (jnp.zeros((B, di, ds), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))

    def assoc(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])

    def scan_block(h_in, dt_c, Bm_c, xconv_c, Cm_c):
        """(B,C,...) slices -> (h_out, y (B,C,di))."""
        da = jnp.exp(dt_c[..., None] * A)
        db = (dt_c[..., None] * Bm_c[:, :, None, :].astype(jnp.float32)
              * xconv_c[..., None].astype(jnp.float32))
        da_ = jnp.concatenate([jnp.ones_like(da[:, :1]), da], axis=1)
        db_ = jnp.concatenate([h_in[:, None], db], axis=1)
        _, hs = jax.lax.associative_scan(assoc, (da_, db_), axis=1)
        hs = hs[:, 1:]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cm_c.astype(jnp.float32))
        return hs[:, -1], y

    if S == 1:
        da = jnp.exp(dt[:, 0][..., None] * A)
        db = (dt[:, 0][..., None] * Bm[:, 0][:, None, :].astype(jnp.float32)
              * xconv[:, 0][..., None].astype(jnp.float32))
        h_last = da * h0 + db
        y = jnp.einsum("bdn,bn->bd", h_last,
                       Cm[:, 0].astype(jnp.float32))[:, None]
    elif S > chunk and S % chunk == 0:
        nc = S // chunk

        def split(a):
            return a.reshape(B, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

        def step(h, xs):
            dt_c, Bm_c, xconv_c, Cm_c = xs
            h2, y = scan_block(h, dt_c, Bm_c, xconv_c, Cm_c)
            return h2, y

        h_last, ys = xscan(step, h0,
                           (split(dt), split(Bm), split(xconv), split(Cm)))
        y = ys.swapaxes(0, 1).reshape(B, S, di)
    else:
        h_last, y = scan_block(h0, dt, Bm, xconv, Cm)
    y = y.astype(x.dtype) + xconv * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = apply_dense(p["out_proj"], y)
    new_state = None
    if state is not None:
        new_state = {"h": h_last.astype(state["h"].dtype),
                     "conv": new_conv.astype(state["conv"].dtype)}
    return out, new_state
