"""Model configuration for the assigned architecture pool.

One frozen dataclass covers all ten families (dense / MoE / MLA / SSM /
hybrid / VLM / audio enc-dec); per-arch instances live in
``repro.configs``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention flavor
    attention: str = "gqa"         # gqa | mla | none (rwkv) | hybrid
    rope_theta: float = 10000.0
    # MLA (MiniCPM3 / DeepSeek-V2 style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 1
    moe_every: int = 1             # 1 = every layer, 2 = alternating
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    # routing groups: tokens are routed within groups of N/route_groups
    # (set to the DP shard count so routing is shard-local under SPMD —
    # kills the replicated global-token scatter; 0 = single group)
    route_groups: int = 0

    # SSM / RWKV / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 -> d_model // 16
    conv_kernel: int = 4
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    sliding_window: int = 0        # hybrid attention window (0 = full)

    # encoder-decoder
    encoder_layers: int = 0        # > 0 => enc-dec (seamless)
    cross_attention: bool = False

    # modality frontends (stubs per instructions)
    modality: str = "text"         # text | vision | audio
    num_prefix_embeds: int = 0     # patch/frame embeddings prepended

    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # which benchmark shapes apply (decode needs a decoder; 500k needs
    # sub-quadratic sequence mixing)
    supports_decode: bool = True
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank", max(1, self.d_model // 16))

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for MODEL_FLOPS = 6 N D) --------------------
    def param_count(self, active_only: bool = False) -> int:
        D, F, H, K = self.d_model, self.d_ff, self.n_heads, self.n_kv_heads
        hd = self.head_dim
        if self.attention == "mla":
            q_in = self.q_lora_rank or D
            attn = (D * self.q_lora_rank if self.q_lora_rank else 0)
            attn += q_in * H * (self.qk_nope_dim + self.qk_rope_dim)
            attn += D * (self.kv_lora_rank + self.qk_rope_dim)
            attn += self.kv_lora_rank * H * (self.qk_nope_dim + self.v_head_dim)
            attn += H * self.v_head_dim * D
        elif self.attention == "none":  # rwkv time-mix
            attn = 4 * D * (H * hd) + D * self.rwkv_decay_lora + \
                self.rwkv_decay_lora * H * hd
        else:
            attn = D * H * hd + 2 * D * K * hd + H * hd * D
        ffn_dense = 3 * D * F
        if self.attention == "none":   # rwkv channel mix: 2 mats + gate
            ffn_dense = 2 * D * F + D * D
        if self.moe:
            ffn_moe = 3 * D * self.moe_ff
            act_experts = self.top_k + self.n_shared_experts
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            ffn_total_active = (n_dense * ffn_dense
                                + n_moe * ffn_moe * act_experts
                                + n_moe * D * self.n_experts)
            ffn_total_full = (n_dense * ffn_dense
                              + n_moe * (ffn_moe * (self.n_experts
                                                    + self.n_shared_experts)
                                         + D * self.n_experts))
        else:
            ffn_total_active = ffn_total_full = self.n_layers * ffn_dense
        if self.family == "hybrid":
            # parallel SSM head on every layer
            di, ds = self.d_inner, self.ssm_state
            ssm = (D * 2 * di + di * self.conv_kernel
                   + di * (self.ssm_dt_rank + 2 * ds)
                   + self.ssm_dt_rank * di + di * ds + di + di * D)
            attn += ssm
        layers_total = self.n_layers + self.encoder_layers
        attn_total = layers_total * attn
        if self.cross_attention:
            attn_total += self.n_layers * (2 * D * K * hd + D * H * hd
                                           + H * hd * D)
        embed = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        total_ffn = ffn_total_active if active_only else ffn_total_full
        if self.encoder_layers:
            total_ffn += self.encoder_layers * 3 * D * F
        return attn_total + total_ffn + embed
