"""Layer primitives shared by all architectures (functional, no framework).

Parameters are plain pytrees (nested dicts of jnp arrays); every ``init_*``
has a matching ``apply_*``.  Compute runs in ``cfg.compute_dtype``
(bf16 by default) with f32 softmax/norm accumulation; parameters stay in
``cfg.param_dtype``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .scan_util import xscan

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Attention-tensor sharding hook (set by the launcher during tracing):
# callable(tensor, kind) -> tensor, with kind in {"q", "kv", "out"}.
# Used to pin head-parallel attention (Megatron-style) so XLA never
# materializes replicated (H, S, S) score tensors.
# ---------------------------------------------------------------------------
import contextlib as _ctxlib

_ATTN_CONSTRAINT = None


@_ctxlib.contextmanager
def attention_constraint(fn):
    global _ATTN_CONSTRAINT
    old, _ATTN_CONSTRAINT = _ATTN_CONSTRAINT, fn
    try:
        yield
    finally:
        _ATTN_CONSTRAINT = old


def _constrain(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if _ATTN_CONSTRAINT is None:
        return x
    return _ATTN_CONSTRAINT(x, kind)


def _norm_init(key, shape):
    return jnp.ones(shape, jnp.float32)


def dense_init(key, in_dim: int, out_dim: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return {"w": jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale}


def apply_dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


def rms_norm_init(key, dim: int) -> Params:
    return {"scale": _norm_init(key, (dim,))}


def apply_rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:                    # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Scaled dot-product attention with optional flash-style chunking
# ---------------------------------------------------------------------------

def _dot_f32(eq: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """einsum with f32 accumulation (``preferred_element_type`` — the
    MXU-native form).  The CPU *runtime* cannot execute bf16xbf16->f32
    dots, so plain CPU runs (tests, examples) upcast inputs instead; the
    dry-run sets REPRO_TPU_FAITHFUL_DOT=1 to keep the TPU-faithful form,
    which lowers and compiles fine on CPU and keeps the memory analysis
    honest (bf16, not f32, attention tensors)."""
    import os as _os
    if (jax.default_backend() == "cpu"
            and not _os.environ.get("REPRO_TPU_FAITHFUL_DOT")):
        return jnp.einsum(eq, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.einsum(eq, a, b, preferred_element_type=jnp.float32)

def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
         causal: bool, q_offset: jnp.ndarray | int = 0,
         kv_positions: Optional[jnp.ndarray] = None,
         window: int = 0, kv_chunk: int = 0) -> jnp.ndarray:
    """Grouped-query attention core.

    q: (B, S, H, hd); k, v: (B, Skv, K, hd) with H = K * G.
    ``q_offset``: absolute position of q[0] (decode: current index).
    ``kv_positions``: absolute positions of cached kv (for ring buffers);
    defaults to arange(Skv).
    ``window``: sliding-window size (0 = full).
    ``kv_chunk``: if > 0, stream over kv chunks with running softmax
    (flash-attention-style, keeps O(S * chunk) score memory).
    """
    B, S, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qh = q.reshape(B, S, K, G, hd)
    scale = 1.0 / np.sqrt(hd)
    q_pos = q_offset + jnp.arange(S)

    def mask_for(kpos):
        # negative positions mark never-written ring-buffer slots
        m = jnp.broadcast_to(kpos[None, :] >= 0, (S, kpos.shape[0]))
        if causal:
            m &= q_pos[:, None] >= kpos[None, :]
        if window > 0:
            m &= kpos[None, :] > q_pos[:, None] - window
        return m

    kv_pos = (kv_positions if kv_positions is not None
              else jnp.arange(Skv))

    if kv_chunk and Skv > kv_chunk and Skv % kv_chunk == 0:
        nchunks = Skv // kv_chunk
        kc = k.reshape(B, nchunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, nchunks, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
        pc = kv_pos.reshape(nchunks, kv_chunk)

        def step(carry, xs):
            m_i, l_i, acc = carry
            kci, vci, pci = xs
            s = _dot_f32("bskgh,btkh->bkgst", qh, kci) * scale
            s = jnp.where(mask_for(pci)[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + jnp.sum(p, axis=-1)
            pv = _dot_f32("bkgst,btkh->bskgh", p.astype(q.dtype), vci)
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, K, G, S), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, G, S), jnp.float32)
        acc0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
        (m_f, l_f, acc), _ = xscan(step, (m0, l0, acc0), (kc, vc, pc))
        out = acc / jnp.maximum(l_f, 1e-30).transpose(0, 3, 1, 2)[..., None]
    else:
        s = _dot_f32("bskgh,btkh->bkgst", qh, k) * scale
        s = jnp.where(mask_for(kv_pos)[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = _dot_f32("bkgst,btkh->bskgh", p.astype(q.dtype), v)
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (self or cross), KV cache aware
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, cross: bool = False) -> Params:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], D, H * hd),
        "wk": dense_init(ks[1], D, K * hd),
        "wv": dense_init(ks[2], D, K * hd),
        "wo": dense_init(ks[3], H * hd, D, scale=1.0 / np.sqrt(H * hd)),
    }


def apply_gqa(p: Params, x: jnp.ndarray, cfg, *,
              positions: jnp.ndarray,
              cache: Optional[Params] = None,
              cache_index: Optional[jnp.ndarray] = None,
              kv_source: Optional[jnp.ndarray] = None,
              cross: bool = False,
              causal: bool = True,
              window: int = 0,
              kv_chunk: int = 0) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Self-attention or cross-attention (``cross=True``).

    Self + ``cache``: decode mode — x is (B, 1, D), k/v appended at
    ``cache_index`` (ring-buffer slot when ``window > 0``).
    Cross + ``cache``: k/v were precomputed at prefill; read-only.
    Cross without cache: k/v computed from ``kv_source``.
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_dense(p["wq"], x).reshape(B, S, H, hd)
    use_rope = not cross
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    q = _constrain(q, "q")

    kv_pos = None
    if cross and cache is not None:
        k = cache["k"].transpose(0, 2, 1, 3)   # (B, enc_len, K, hd)
        v = cache["v"].transpose(0, 2, 1, 3)
        new_cache = cache
    else:
        src = x if not cross else kv_source
        k = apply_dense(p["wk"], src).reshape(B, -1, K, hd)
        v = apply_dense(p["wv"], src).reshape(B, -1, K, hd)
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        new_cache = None
        if cache is not None:
            # decode: write current kv into the cache
            Smax = cache["k"].shape[2]
            slot = cache_index % Smax if window > 0 else cache_index
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                (0, 0, slot, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
                (0, 0, slot, 0))
            new_cache = {"k": kc, "v": vc}
            k = kc.transpose(0, 2, 1, 3)
            v = vc.transpose(0, 2, 1, 3)
            if window > 0:
                # ring buffer: slot s holds the latest position == s (mod Smax)
                slots = jnp.arange(Smax)
                latest = cache_index  # position just written
                kv_pos = latest - ((latest - slots) % Smax)
            else:
                kv_pos = jnp.arange(Smax)

    q_offset = cache_index if cache_index is not None else positions[0, 0]
    k = _constrain(k, "kv")
    v = _constrain(v, "kv")
    out = sdpa(q, k, v, causal=causal and not cross,
               q_offset=q_offset, kv_positions=kv_pos,
               window=window, kv_chunk=kv_chunk)
    out = _constrain(out, "out")
    out = apply_dense(p["wo"], out.reshape(B, S, H * hd))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> Params:
    D, H = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wkv_a": dense_init(ks[0], D, kr + dr),
        "kv_norm": rms_norm_init(ks[1], kr),
        "wkv_b": dense_init(ks[2], kr, H * (dn + dv)),
        "wo": dense_init(ks[3], H * dv, D, scale=1.0 / np.sqrt(H * dv)),
    }
    if qr:
        p["wq_a"] = dense_init(ks[4], D, qr)
        p["q_norm"] = rms_norm_init(ks[5], qr)
        p["wq_b"] = dense_init(ks[6], qr, H * (dn + dr))
    else:
        p["wq"] = dense_init(ks[7], D, H * (dn + dr))
    return p


def apply_mla(p: Params, x: jnp.ndarray, cfg, *,
              positions: jnp.ndarray,
              cache: Optional[Params] = None,
              cache_index: Optional[jnp.ndarray] = None,
              kv_chunk: int = 0) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Latent attention; the cache stores only (c_kv, k_rope) — the MLA
    memory saving — and k/v are re-expanded from the latent on read."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        cq = apply_rms_norm(p["q_norm"], apply_dense(p["wq_a"], x), cfg.rms_eps)
        q = apply_dense(p["wq_b"], cq)
    else:
        q = apply_dense(p["wq"], x)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = apply_dense(p["wkv_a"], x)
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = apply_rms_norm(p["kv_norm"], c_kv, cfg.rms_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B,S,dr) shared

    new_cache = None
    if cache is not None:
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, cache_index, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype),
            (0, cache_index, 0))
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        c_kv, k_rope = ckv_c, kr_c

    Skv = c_kv.shape[1]
    kvb = apply_dense(p["wkv_b"], c_kv).reshape(B, Skv, H, dn + dv)
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Skv, H, dr))],
        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_offset = cache_index if cache_index is not None else positions[0, 0]
    # v head dim differs from qk head dim; pad v to qk dim for shared sdpa
    out = sdpa(qf, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
               causal=True, q_offset=q_offset, kv_chunk=kv_chunk)
    out = out[..., :dv]
    out = apply_dense(p["wo"], out.reshape(B, S, H * dv))
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], d_model, d_ff),
        "w3": dense_init(ks[1], d_model, d_ff),
        "w2": dense_init(ks[2], d_ff, d_model, scale=1.0 / np.sqrt(d_ff)),
    }


def apply_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return apply_dense(
        p["w2"], jax.nn.silu(apply_dense(p["w1"], x)) * apply_dense(p["w3"], x))


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, scatter-based dispatch — no (N,E,C) tensor)
# ---------------------------------------------------------------------------

def moe_init(key, cfg) -> Params:
    D, F, E = cfg.d_model, cfg.moe_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    s1, s2 = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    p = {
        "router": dense_init(ks[0], D, E),
        "w1": jax.random.normal(ks[1], (E, D, F), jnp.float32) * s1,
        "w3": jax.random.normal(ks[2], (E, D, F), jnp.float32) * s1,
        "w2": jax.random.normal(ks[3], (E, F, D), jnp.float32) * s2,
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], D, F * cfg.n_shared_experts)
    return p


def _moe_route_group(p: Params, xt: jnp.ndarray, cfg
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Route one token group (n, D) through the experts (scatter-based
    dispatch; n is the per-group token count)."""
    n, D = xt.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (xt @ p["router"]["w"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                     # (n, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(cfg.capacity_factor * K * n / E))
    cap = max(1, min(cap, n))
    if n <= 8 * E:   # decode-sized batches: no capacity drops
        cap = n
    # position of each (token, k) inside its expert queue
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # (n, K, E)
    flat = onehot.reshape(n * K, E)
    pos = jnp.cumsum(flat, axis=0) - 1                      # (n*K, E)
    pos = jnp.take_along_axis(pos, idx.reshape(n * K, 1),
                              axis=1).reshape(n, K)
    keep = pos < cap
    aux = jnp.mean(probs.mean(0)
                   * jax.nn.one_hot(idx[:, 0], E).mean(0)) * E * E

    eidx = jnp.where(keep, idx, E)                          # drop -> expert E
    ppos = jnp.where(keep, pos, 0)
    xe = jnp.zeros((E + 1, cap, D), xt.dtype)
    xe = xe.at[eidx.reshape(-1), ppos.reshape(-1)].set(
        jnp.repeat(xt[:, None], K, 1).reshape(n * K, D), mode="drop")
    xe = xe[:E]
    h = jnp.einsum("ecd,edf->ecf", xe, p["w1"].astype(xe.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, p["w3"].astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g,
                    p["w2"].astype(xe.dtype))
    # gather back
    yk = ye[jnp.minimum(eidx, E - 1).reshape(-1), ppos.reshape(-1)]
    yk = yk.reshape(n, K, D) * (gate * keep).astype(xt.dtype)[..., None]
    return yk.sum(1), aux.astype(jnp.float32)


def apply_moe(p: Params, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).  Token-choice top-k with capacity.

    With ``cfg.route_groups == G > 1`` tokens are routed within G
    independent groups (vmapped).  Setting G to the DP shard count makes
    the dispatch scatter *batch-partitioned* under SPMD — routing stays
    shard-local and no replicated (N, D) gather/scatter is ever
    materialized (this is how per-device routing works on real systems).
    """
    B, S, D = x.shape
    N = B * S
    G = max(1, cfg.route_groups)
    if N % G != 0 or (N // G) < cfg.n_experts:
        G = 1
    if G == 1:
        out, aux = _moe_route_group(p, x.reshape(N, D), cfg)
    else:
        xg = x.reshape(G, N // G, D)
        out, aux = jax.vmap(lambda xt: _moe_route_group(p, xt, cfg))(xg)
        aux = aux.mean()
    out = out.reshape(B, S, D)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], x)
    return out, aux
