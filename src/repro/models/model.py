"""Model assembly: decoder-only and encoder-decoder transformers covering
all ten assigned architectures, with scan-over-layers, activation remat,
KV/state caches and modality-frontend stubs.

Parameter layout: per-layer parameters are stacked on a leading axis and
the forward pass is a ``lax.scan`` over the stack, keeping HLO size O(1)
in depth (matters for the 95-layer dry-runs).  When dense and MoE layers
alternate (``moe_every > 1``) the scan unit is a *group* of ``moe_every``
layers whose last member is MoE, so the stack stays homogeneous.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import blocks, layers
from .config import ModelConfig
from .scan_util import xscan

Params = Dict[str, Any]


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_mix(cfg: ModelConfig, key) -> Params:
    if cfg.attention == "mla":
        return {"mla": layers.mla_init(key, cfg)}
    if cfg.attention == "none":
        return {"rwkv": blocks.rwkv_time_init(key, cfg)}
    if cfg.attention == "hybrid":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"attn": layers.gqa_init(k1, cfg),
                "ssm": blocks.ssm_init(k2, cfg),
                "ln_a": layers.rms_norm_init(k3, cfg.d_model),
                "ln_s": layers.rms_norm_init(k4, cfg.d_model)}
    return {"attn": layers.gqa_init(key, cfg)}


def _init_ffn(cfg: ModelConfig, key, moe_layer: bool) -> Params:
    if cfg.attention == "none":
        return {"rwkv_ffn": blocks.rwkv_channel_init(key, cfg)}
    if moe_layer:
        return {"moe": layers.moe_init(key, cfg)}
    return {"mlp": layers.mlp_init(key, cfg.d_model, cfg.d_ff)}


def _init_layer(cfg: ModelConfig, key, moe_layer: bool, cross: bool) -> Params:
    ks = jax.random.split(key, 6)
    p = {
        "ln1": layers.rms_norm_init(ks[0], cfg.d_model),
        "mix": _init_mix(cfg, ks[1]),
        "ln2": layers.rms_norm_init(ks[2], cfg.d_model),
        "ffn": _init_ffn(cfg, ks[3], moe_layer),
    }
    if cross:
        p["ln_c"] = layers.rms_norm_init(ks[4], cfg.d_model)
        p["cross"] = layers.gqa_init(ks[5], cfg, cross=True)
    return p


def _group_layout(cfg: ModelConfig) -> Tuple[int, Tuple[bool, ...]]:
    if not cfg.moe:
        return cfg.n_layers, (False,)
    g = cfg.moe_every
    assert cfg.n_layers % g == 0, "n_layers must divide by moe_every"
    return cfg.n_layers // g, tuple([False] * (g - 1) + [True])


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab_size
    n_groups, flags = _group_layout(cfg)
    cross = cfg.is_enc_dec

    def init_group(k):
        kk = jax.random.split(k, len(flags))
        return [_init_layer(cfg, kk[i], flags[i], cross)
                for i in range(len(flags))]

    stacked = jax.vmap(init_group)(jax.random.split(ks[0], n_groups))

    p: Params = {
        "embed": jax.random.normal(ks[1], (V, D), jnp.float32) * 0.02,
        "layers": stacked,
        "final_norm": layers.rms_norm_init(ks[2], D),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ks[3], D, V, scale=0.02)
    if cfg.is_enc_dec:
        p["enc_layers"] = jax.vmap(
            lambda k: [_init_layer(cfg, k, False, False)])(
                jax.random.split(ks[4], cfg.encoder_layers))
        p["enc_norm"] = layers.rms_norm_init(ks[5], D)
    return p


# ---------------------------------------------------------------------------
# Caches (stacked on L to match the scan; regrouped on the fly)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, dtype=jnp.bfloat16) -> Params:
    L, D = cfg.n_layers, cfg.d_model
    K, hd = cfg.n_kv_heads, cfg.head_dim
    c: Params = {}
    if cfg.attention == "gqa":
        c["k"] = jnp.zeros((L, batch, K, max_len, hd), dtype)
        c["v"] = jnp.zeros((L, batch, K, max_len, hd), dtype)
    elif cfg.attention == "mla":
        c["ckv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype)
        c["krope"] = jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dtype)
    elif cfg.attention == "none":
        H = D // cfg.rwkv_head_dim
        c["x_tm"] = jnp.zeros((L, batch, D), dtype)
        c["s"] = jnp.zeros((L, batch, H, cfg.rwkv_head_dim,
                            cfg.rwkv_head_dim), jnp.float32)
        c["x_cm"] = jnp.zeros((L, batch, D), dtype)
    elif cfg.attention == "hybrid":
        W = min(cfg.sliding_window or max_len, max_len)
        c["k"] = jnp.zeros((L, batch, K, W, hd), dtype)
        c["v"] = jnp.zeros((L, batch, K, W, hd), dtype)
        c["h"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state),
                           jnp.float32)
        c["conv"] = jnp.zeros((L, batch, cfg.conv_kernel - 1, cfg.d_inner),
                              dtype)
    if cfg.is_enc_dec:
        c["xk"] = jnp.zeros((L, batch, K, enc_len, hd), dtype)
        c["xv"] = jnp.zeros((L, batch, K, enc_len, hd), dtype)
    return c


def _regroup_cache(cfg: ModelConfig, cache):
    n_groups, flags = _group_layout(cfg)
    g = len(flags)
    if g == 1:
        return jax.tree_util.tree_map(lambda a: a[:, None], cache)
    return jax.tree_util.tree_map(
        lambda a: a.reshape(n_groups, g, *a.shape[1:]), cache)


def _ungroup_cache(cfg: ModelConfig, cache):
    return jax.tree_util.tree_map(
        lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]), cache)


# ---------------------------------------------------------------------------
# One decoder layer
# ---------------------------------------------------------------------------

def _apply_mix(cfg, p, x, *, positions, cache, index, kv_chunk):
    if cfg.attention == "mla":
        return layers.apply_mla(p["mla"], x, cfg, positions=positions,
                                cache=cache, cache_index=index,
                                kv_chunk=kv_chunk)
    if cfg.attention == "none":
        st = None if cache is None else {"x": cache["x_tm"], "s": cache["s"]}
        out, st2 = blocks.apply_rwkv_time(p["rwkv"], x, cfg, state=st)
        return out, (None if st2 is None
                     else {"x_tm": st2["x"], "s": st2["s"]})
    if cfg.attention == "hybrid":
        attn_cache = (None if cache is None
                      else {"k": cache["k"], "v": cache["v"]})
        a_out, a_new = layers.apply_gqa(
            p["attn"], x, cfg, positions=positions, cache=attn_cache,
            cache_index=index, window=cfg.sliding_window, kv_chunk=kv_chunk)
        st = None if cache is None else {"h": cache["h"],
                                         "conv": cache["conv"]}
        s_out, s_new = blocks.apply_ssm(p["ssm"], x, cfg, state=st)
        out = 0.5 * (layers.apply_rms_norm(p["ln_a"], a_out, cfg.rms_eps)
                     + layers.apply_rms_norm(p["ln_s"], s_out, cfg.rms_eps))
        new = None
        if cache is not None:
            new = {"k": a_new["k"], "v": a_new["v"],
                   "h": s_new["h"], "conv": s_new["conv"]}
        return out, new
    return layers.apply_gqa(p["attn"], x, cfg, positions=positions,
                            cache=cache, cache_index=index,
                            kv_chunk=kv_chunk)


def _apply_ffn(cfg, p, x, cache):
    """Returns (out, channel-mix state or None, aux loss)."""
    if "rwkv_ffn" in p:
        st = None if cache is None else {"x": cache["x_cm"]}
        out, st2 = blocks.apply_rwkv_channel(p["rwkv_ffn"], x, cfg, state=st)
        return out, (None if st2 is None else st2["x"]), jnp.float32(0.0)
    if "moe" in p:
        out, aux = layers.apply_moe(p["moe"], x, cfg)
        return out, None, aux
    return layers.apply_mlp(p["mlp"], x), None, jnp.float32(0.0)


def _decoder_layer(cfg, p, x, *, positions, cache, index, enc_out, kv_chunk):
    h = layers.apply_rms_norm(p["ln1"], x, cfg.rms_eps)
    mix_out, mix_cache = _apply_mix(cfg, p["mix"], h, positions=positions,
                                    cache=cache, index=index,
                                    kv_chunk=kv_chunk)
    x = x + mix_out
    if "cross" in p:
        hc = layers.apply_rms_norm(p["ln_c"], x, cfg.rms_eps)
        cross_cache = None
        if cache is not None:
            cross_cache = {"k": cache["xk"], "v": cache["xv"]}
        c_out, _ = layers.apply_gqa(p["cross"], hc, cfg, positions=positions,
                                    kv_source=enc_out, cache=cross_cache,
                                    cross=True, causal=False)
        x = x + c_out
    h2 = layers.apply_rms_norm(p["ln2"], x, cfg.rms_eps)
    ffn_out, x_cm, aux = _apply_ffn(cfg, p["ffn"], h2, cache)
    x = x + ffn_out
    new_cache = mix_cache
    if cache is not None:
        new_cache = dict(new_cache or {})
        if x_cm is not None:
            new_cache["x_cm"] = x_cm
        if "cross" in p:           # read-only, threaded through unchanged
            new_cache["xk"] = cache["xk"]
            new_cache["xv"] = cache["xv"]
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens, prefix_embeds):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_cdtype(cfg))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(cfg: ModelConfig, params, x):
    xn = layers.apply_rms_norm(params["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings:
        return xn @ params["embed"].T.astype(xn.dtype)
    return layers.apply_dense(params["lm_head"], xn)


def encode(cfg: ModelConfig, params, enc_embeds) -> jnp.ndarray:
    """Encoder stack over stub frontend embeddings (B, F, D)."""
    x = enc_embeds.astype(_cdtype(cfg))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def step(carry, gparams):
        xc = carry
        p = gparams[0]
        h = layers.apply_rms_norm(p["ln1"], xc, cfg.rms_eps)
        out, _ = layers.apply_gqa(p["mix"]["attn"], h, cfg,
                                  positions=positions, causal=False)
        xc = xc + out
        h = layers.apply_rms_norm(p["ln2"], xc, cfg.rms_eps)
        out, _, _ = _apply_ffn(cfg, p["ffn"], h, None)
        return xc + out, None

    x, _ = xscan(step, x, params["enc_layers"])
    return layers.apply_rms_norm(params["enc_norm"], x, cfg.rms_eps)


def forward(cfg: ModelConfig, params, tokens, *,
            prefix_embeds=None, enc_embeds=None, remat: bool = False,
            kv_chunk: int = 0, constraint_fn=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward (training / scoring). Returns (logits, aux).

    ``constraint_fn(x)``: optional sharding constraint applied to the
    residual stream at every scan step (sequence parallelism)."""
    enc_out = encode(cfg, params, enc_embeds) if cfg.is_enc_dec else None
    x = _embed(cfg, params, tokens, prefix_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if constraint_fn is not None:
        x = constraint_fn(x)

    def step(carry, gparams):
        xc, aux = carry
        for p in gparams:
            xc, _, aux_i = _decoder_layer(cfg, p, xc, positions=positions,
                                          cache=None, index=None,
                                          enc_out=enc_out, kv_chunk=kv_chunk)
            aux = aux + aux_i
        if constraint_fn is not None:
            xc = constraint_fn(xc)
        return (xc, aux), None

    if remat:
        step = jax.checkpoint(step, prevent_cse=False)
    (x, aux), _ = xscan(step, (x, jnp.float32(0.0)), params["layers"])
    return _logits(cfg, params, x), aux


def decode_step(cfg: ModelConfig, params, cache, tokens, index, *,
                kv_chunk: int = 0):
    """One serving step: tokens (B, 1) at position ``index`` with a
    populated cache.  Returns (logits (B, 1, V), new_cache)."""
    x = _embed(cfg, params, tokens, None)
    B = x.shape[0]
    positions = jnp.broadcast_to(index, (B, 1)).astype(jnp.int32)
    gcache = _regroup_cache(cfg, cache)

    def step(carry, xs):
        xc = carry
        gparams, gc = xs
        new_gc = []
        for i, p in enumerate(gparams):
            ci = jax.tree_util.tree_map(lambda a: a[i], gc)
            xc, nc, _ = _decoder_layer(cfg, p, xc, positions=positions,
                                       cache=ci, index=index, enc_out=None,
                                       kv_chunk=kv_chunk)
            new_gc.append(nc)
        new_gc = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_gc)
        return xc, new_gc

    x, new_cache = xscan(step, x, (params["layers"], gcache))
    return _logits(cfg, params, x), _ungroup_cache(cfg, new_cache)


def prefill(cfg: ModelConfig, params, tokens, max_len: int, *,
            prefix_embeds=None, enc_embeds=None, kv_chunk: int = 0,
            cache_dtype=jnp.bfloat16):
    """Process a prompt and build the decode cache.

    Returns (last-position logits (B, V), cache, next_index).
    For ring-buffer (sliding-window) attention the cache holds the last W
    positions; for state models (rwkv/ssm) it holds the final state.
    """
    enc_out = encode(cfg, params, enc_embeds) if cfg.is_enc_dec else None
    x = _embed(cfg, params, tokens, prefix_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_len = enc_out.shape[1] if enc_out is not None else 0
    cache = init_cache(cfg, B, max_len, enc_len, cache_dtype)
    gcache = _regroup_cache(cfg, cache)

    def fill_layer(cfg_p, x_in, ci):
        """Run one layer over the full prompt and produce its cache slice."""
        p = cfg_p
        h = layers.apply_rms_norm(p["ln1"], x_in, cfg.rms_eps)
        new_ci = dict(ci)
        if cfg.attention in ("gqa", "hybrid"):
            ap = p["mix"]["attn"] if cfg.attention == "hybrid" else p["mix"]["attn"]
            window = cfg.sliding_window if cfg.attention == "hybrid" else 0
            # full-sequence attention (banded if windowed), then cache tail
            mix_out, _ = layers.apply_gqa(ap, h, cfg, positions=positions,
                                          window=window, kv_chunk=kv_chunk)
            k = layers.apply_dense(ap["wk"], h).reshape(
                B, S, cfg.n_kv_heads, cfg.head_dim)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            v = layers.apply_dense(ap["wv"], h).reshape(
                B, S, cfg.n_kv_heads, cfg.head_dim)
            W = ci["k"].shape[2]
            if window:
                # ring buffer: position p -> slot p % W, for the last W
                tail_pos = jnp.arange(S - W, S) if S >= W else jnp.arange(S)
                slots = tail_pos % W
                kk = jnp.zeros_like(ci["k"]).at[:, :, slots].set(
                    k[:, jnp.maximum(tail_pos, 0)].transpose(0, 2, 1, 3)
                    .astype(ci["k"].dtype))
                vv = jnp.zeros_like(ci["v"]).at[:, :, slots].set(
                    v[:, jnp.maximum(tail_pos, 0)].transpose(0, 2, 1, 3)
                    .astype(ci["v"].dtype))
            else:
                kk = ci["k"].at[:, :, :S].set(
                    k.transpose(0, 2, 1, 3).astype(ci["k"].dtype))
                vv = ci["v"].at[:, :, :S].set(
                    v.transpose(0, 2, 1, 3).astype(ci["v"].dtype))
            new_ci["k"], new_ci["v"] = kk, vv
            if cfg.attention == "hybrid":
                st = {"h": ci["h"], "conv": ci["conv"]}
                s_out, s_new = blocks.apply_ssm(p["mix"]["ssm"], h, cfg,
                                                state=st)
                mix_out = 0.5 * (
                    layers.apply_rms_norm(p["mix"]["ln_a"], mix_out,
                                          cfg.rms_eps)
                    + layers.apply_rms_norm(p["mix"]["ln_s"], s_out,
                                            cfg.rms_eps))
                new_ci["h"], new_ci["conv"] = s_new["h"], s_new["conv"]
        elif cfg.attention == "mla":
            mix_out, mc = layers.apply_mla(
                p["mix"]["mla"], h, cfg, positions=positions,
                cache={"ckv": ci["ckv"], "krope": ci["krope"]},
                cache_index=jnp.int32(0), kv_chunk=kv_chunk)
            new_ci.update(mc)
        else:  # rwkv
            st = {"x": ci["x_tm"], "s": ci["s"]}
            mix_out, st2 = blocks.apply_rwkv_time(p["mix"]["rwkv"], h, cfg,
                                                  state=st)
            new_ci["x_tm"], new_ci["s"] = st2["x"], st2["s"]
        x_out = x_in + mix_out
        if "cross" in p:
            hc = layers.apply_rms_norm(p["ln_c"], x_out, cfg.rms_eps)
            ck = layers.apply_dense(p["cross"]["wk"], enc_out).reshape(
                B, enc_len, cfg.n_kv_heads, cfg.head_dim)
            cv = layers.apply_dense(p["cross"]["wv"], enc_out).reshape(
                B, enc_len, cfg.n_kv_heads, cfg.head_dim)
            new_ci["xk"] = ck.transpose(0, 2, 1, 3).astype(ci["xk"].dtype)
            new_ci["xv"] = cv.transpose(0, 2, 1, 3).astype(ci["xv"].dtype)
            c_out, _ = layers.apply_gqa(p["cross"], hc, cfg,
                                        positions=positions,
                                        kv_source=enc_out, cross=True,
                                        causal=False)
            x_out = x_out + c_out
        h2 = layers.apply_rms_norm(p["ln2"], x_out, cfg.rms_eps)
        ffn_out, x_cm, _ = _apply_ffn(cfg, p["ffn"], h2,
                                      ci if "rwkv_ffn" in p["ffn"] else None)
        if x_cm is not None:
            new_ci["x_cm"] = x_cm
        return x_out + ffn_out, new_ci

    def step(carry, xs):
        xc = carry
        gparams, gc = xs
        new_gc = []
        for i, p in enumerate(gparams):
            ci = jax.tree_util.tree_map(lambda a: a[i], gc)
            xc, nc = fill_layer(p, xc, ci)
            new_gc.append(nc)
        new_gc = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_gc)
        return xc, new_gc

    x, new_cache = xscan(step, x, (params["layers"], gcache))
    logits = _logits(cfg, params, x[:, -1:])
    # S already includes any prefix embeddings (concatenated in _embed)
    return logits[:, 0], _ungroup_cache(cfg, new_cache), jnp.int32(S)
