"""Shared lax.scan wrapper with dry-run unroll control."""
import jax


# ---------------------------------------------------------------------------
# Scan-unroll control (dry-run probes)
# ---------------------------------------------------------------------------
# XLA's HLO cost analysis counts while-loop bodies once, ignoring trip
# counts, so the dry-run lowers small *unrolled* probe variants to get
# exact per-layer flop/collective numbers and scales them analytically
# (see launch/dryrun.py).  ``unroll_scans()`` flips every lax.scan in the
# model to unroll=True for such probe lowerings.
import contextlib as _contextlib

_UNROLL = False


@_contextlib.contextmanager
def unroll_scans():
    global _UNROLL
    old, _UNROLL = _UNROLL, True
    try:
        yield
    finally:
        _UNROLL = old


def xscan(body, carry, xs, **kw):
    if _UNROLL:
        kw = dict(kw)
        kw["unroll"] = True
    return jax.lax.scan(body, carry, xs, **kw)
