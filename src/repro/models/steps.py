"""Train and serve steps for the LM substrate.

``train_step`` is a pure function (params, opt_state, batch) -> (params,
opt_state, metrics); it composes with pjit via the sharding policy in
:mod:`repro.launch.sharding`.  ``serve_step`` is one KV-cached decode step;
``prefill_step`` builds the cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.optim import adamw
from . import model as model_lib
from .scan_util import xscan
from .config import ModelConfig


def lm_loss(cfg: ModelConfig, params, batch, *, remat: bool = True,
            kv_chunk: int = 0, constraint_fn=None):
    """Next-token cross entropy with optional modality prefixes.

    batch: {"tokens": (B,S) int32, "mask": (B,S) float, optional
    "prefix_embeds": (B,P,D), "enc_embeds": (B,F,D)}.
    """
    tokens = batch["tokens"]
    logits, aux = model_lib.forward(
        cfg, params, tokens,
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
        remat=remat, kv_chunk=kv_chunk, constraint_fn=constraint_fn)
    P = logits.shape[1] - tokens.shape[1]
    if P > 0:  # drop prefix positions (vision/audio stubs carry no labels)
        logits = logits[:, P:]
    # predict token t+1 from position t
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    mask = batch.get("mask")
    mask = (jnp.ones_like(targets, jnp.float32) if mask is None
            else mask[:, 1:].astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    # router z-loss style regularizer from MoE aux
    total = loss + 0.01 * aux
    metrics = {"loss": loss, "aux": aux,
               "tokens": mask.sum()}
    return total, metrics


def make_train_step(cfg: ModelConfig, opt: adamw.AdamW, *,
                    remat: bool = True, kv_chunk: int = 0,
                    accum_steps: int = 1, constraint_fn=None,
                    grad_constraint_fn=None):
    """Build the jit-able train step.

    ``accum_steps > 1``: gradient accumulation — the global batch is split
    into microbatches scanned sequentially, bounding activation memory
    (grads accumulate in f32 at parameter sharding, so no extra comm).
    ``constraint_fn``: residual-stream sharding constraint (sequence
    parallelism) threaded into the layer scan.
    """

    cdt = jnp.dtype(cfg.compute_dtype)

    def _half(params):
        # cast matrices to the compute dtype BEFORE the layer scan so FSDP
        # all-gathers move bf16, not f32 (halves gather traffic + buffers)
        return jax.tree_util.tree_map(
            lambda p: p.astype(cdt)
            if (p.ndim >= 2 and p.dtype == jnp.float32) else p, params)

    def grads_of(params, batch):
        def loss_fn(ph):
            return lm_loss(cfg, ph, batch, remat=remat, kv_chunk=kv_chunk,
                           constraint_fn=constraint_fn)

        (_, metrics), grads_h = jax.value_and_grad(
            loss_fn, has_aux=True)(_half(params))
        if grad_constraint_fn is not None:
            # pin gradients to the parameter sharding BEFORE the f32 cast
            # and accumulation: turns full-tensor all-reduces into
            # reduce-scatters (each device receives only its shard)
            grads_h = grad_constraint_fn(grads_h)
        grads = jax.tree_util.tree_map(
            lambda g, p: g.astype(jnp.float32) if p.dtype == jnp.float32
            else g, grads_h, params)
        return grads, metrics

    def train_step(params, opt_state, batch, step):
        if accum_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def split(v):
                return v.reshape(accum_steps, v.shape[0] // accum_steps,
                                 *v.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}

            def body(carry, mb):
                acc, met = carry
                g, m = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                met = jax.tree_util.tree_map(lambda a, b: a + b, met, m)
                return (acc, met), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            met0 = {"loss": jnp.float32(0), "aux": jnp.float32(0),
                    "tokens": jnp.float32(0)}
            (grads, metrics), _ = xscan(body, (zeros, met0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            metrics = dict(metrics)
            for k in ("loss", "aux"):
                metrics[k] = metrics[k] / accum_steps

        updates, new_opt_state = opt.update(grads, opt_state, params, step)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = adamw.global_norm(grads)
        return new_params, new_opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, kv_chunk: int = 0):
    """One decode step: (params, cache, tokens (B,1), index) ->
    (next_token (B,1), logits, cache)."""

    def serve_step(params, cache, tokens, index):
        logits, new_cache = model_lib.decode_step(cfg, params, cache, tokens,
                                                  index, kv_chunk=kv_chunk)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: int, *, kv_chunk: int = 0):
    def prefill_step(params, batch):
        return model_lib.prefill(
            cfg, params, batch["tokens"], max_len,
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"), kv_chunk=kv_chunk)

    return prefill_step
