"""Sharded AdamW with gradient clipping, cosine schedule and bf16-friendly
master weights.  Pure pytree implementation — optimizer state inherits the
parameter sharding, so FSDP shards m/v for free under pjit."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1

    def init(self, params) -> OptState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
        return OptState(m=zeros(params), v=zeros(params),
                        count=jnp.zeros((), jnp.int32))

    def schedule(self, step) -> jnp.ndarray:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip((step - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def update(self, grads, state: OptState, params, step
               ) -> Tuple[Any, OptState]:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * scale, grads)
        count = state.count + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)
        m = jax.tree_util.tree_map(
            lambda mm, g: self.b1 * mm + (1 - self.b1) * g, state.m, grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
            state.v, grads)
        lr = self.schedule(step)

        def upd(p, mm, vv):
            mhat = mm / b1c
            vhat = vv / b2c
            u = -lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                       + self.weight_decay * p.astype(jnp.float32))
            return u

        updates = jax.tree_util.tree_map(upd, params, m, v)
        return updates, OptState(m=m, v=v, count=count)
