"""Resilient solve runtime: fault injection, guards, and recovery.

Long campaign solves (propagator batches, HMC trajectories) die in
four characteristic ways, and this package owns the recovery path for
each:

* **numerical divergence** — a Krylov state goes non-finite or stops
  contracting.  The in-loop guards live in :mod:`repro.core.solver`
  (``guard=True``, reported via ``SolveResult.diverged``); this package
  provides the injectors that prove they trip.
* **precision stall** — an inner bf16/f32 refinement solve cannot reach
  the correction tolerance.  ``make_refined_solve`` escalates the inner
  dtype up :data:`repro.core.solver.ESCALATION_LADDER`.
* **corrupted data** — a gauge field damaged in memory or transit.
  :func:`audit_gauge` / :func:`repair_gauge` (SU(3) unitarity audit +
  polar-projection repair) back ``WilsonMatrix.bind(validate=...)``.
* **broken backend** — kernel compilation or a VMEM policy raises.
  :func:`fallback_chain` walks the registry's declared
  ``BackendCapabilities.fallback`` links; ``WilsonMatrix`` /
  ``SolveSession`` rebind down the chain and report ``degraded``.

:class:`RefinementSnapshot` additionally makes the outer refinement
loop resumable across process death (atomic checkpoints via
:mod:`repro.checkpoint`).

All injectors in :mod:`repro.resilience.inject` are seeded and
deterministic — the chaos suite (``tests/test_resilience.py``) is
reproducible run to run.
"""
from .fallback import adapt_spec, fallback_chain
from .inject import (
    InjectedFault,
    bitflip_gauge,
    break_ops,
    corrupt_halo_slab,
    dead_inner_ops,
    nan_operator,
    nan_spinor_column,
    stagnating_system,
)
from .snapshot import BasisSnapshot, RefinementSnapshot
from .validate import GaugeAuditReport, audit_gauge, repair_gauge

__all__ = [
    "BasisSnapshot",
    "GaugeAuditReport",
    "InjectedFault",
    "RefinementSnapshot",
    "adapt_spec",
    "audit_gauge",
    "bitflip_gauge",
    "break_ops",
    "corrupt_halo_slab",
    "dead_inner_ops",
    "fallback_chain",
    "nan_operator",
    "nan_spinor_column",
    "repair_gauge",
    "stagnating_system",
]
