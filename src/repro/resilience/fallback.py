"""Backend fallback chains (graceful degradation).

Each backend *declares* its own next-best substitute via
``BackendCapabilities.fallback`` at registration time — the chain is
data in the registry, not policy hardcoded in the session.  The shipped
order degrades capability monotonically toward the always-available
reference::

    pallas_fused_stream -> pallas_fused -> pallas -> jnp
    distributed         -> jnp

A backend author adding a new kernel opts into degradation by naming
its fallback in ``register_backend(capabilities=...)``; ``None`` ends
the chain.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro import backends


def fallback_chain(name: str) -> Tuple[str, ...]:
    """Ordered backend names starting at ``name``, following declared
    ``BackendCapabilities.fallback`` links until a backend with no
    fallback.  Cycle-safe (a repeated name ends the walk); unknown
    links raise at walk time rather than at solve time."""
    chain = [name]
    seen = {name}
    while True:
        nxt = backends.backend_info(chain[-1]).fallback
        if not nxt or nxt in seen:
            return tuple(chain)
        backends.backend_info(nxt)     # unregistered link: raise here
        chain.append(nxt)
        seen.add(nxt)


def adapt_spec(spec, name: str):
    """Re-target a ``BackendSpec`` at backend ``name`` for a fallback
    rebind, dropping every knob the target's capabilities cannot honor:
    an unsupported ``dtype`` or ``interpret`` reverts to the backend
    default, an unsupported ``gauge_compression`` to ``"none"``, and
    backend-specific ``opts`` are cleared when the backend changes
    (they were named for the failed backend's factory)."""
    caps = backends.backend_info(name)
    changes: dict = {"name": name}
    if spec.dtype is not None and spec.dtype not in caps.dtypes:
        changes["dtype"] = None
    if spec.interpret is not None and not caps.supports_interpret:
        changes["interpret"] = None
    if (spec.gauge_compression != "none"
            and spec.gauge_compression not in caps.gauge_compressions):
        changes["gauge_compression"] = "none"
    if name != spec.name and spec.opts:
        changes["opts"] = ()
    return dataclasses.replace(spec, **changes)
