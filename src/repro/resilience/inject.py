"""Seeded, deterministic fault injectors for the chaos suite.

Every injector is pure: it returns a corrupted *copy* (or a wrapped
operator) and never mutates its input, so an injected run and its
clean control can share the same source arrays.  Injection points
mirror the real failure modes of a campaign solve:

* :func:`nan_spinor_column` — a NaN landing in one RHS column of a
  multi-RHS block (bad I/O, bad source construction).
* :func:`nan_operator` — the operator itself starts emitting a
  non-finite lane (SDC in the stencil datapath); trips the guard
  mid-iteration rather than at entry.
* :func:`bitflip_gauge` — one flipped bit in one real component of one
  gauge link: the classic silent memory corruption the gauge audit
  (``WilsonMatrix.bind(validate=...)``) exists for.
* :func:`corrupt_halo_slab` — a t/z boundary plane full of NaNs, the
  footprint of a torn halo exchange on the distributed backend.
* :func:`dead_inner_ops` — the inner refinement operator returns zero
  corrections: forced stagnation, driving the precision-escalation
  ladder.
* :func:`break_ops` — native operator entry points that raise
  :class:`InjectedFault` at trace time: a deterministic stand-in for
  kernel-compilation / VMEM-policy failure (on CPU CI the Pallas
  interpreter deliberately skips the real VMEM raises, so the fallback
  chain needs a synthetic trigger).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.halo import boundary_slab_index


class InjectedFault(RuntimeError):
    """Raised by :func:`break_ops`-wrapped entry points at trace time."""


def nan_spinor_column(eta, column: int, *, site=(0, 0, 0, 0)):
    """NaN one site of RHS column ``column`` of a batched complex
    source block ``(nrhs, T, Z, Y, Xh, 4, 3)``.

    One poisoned value is enough: the first operator application
    spreads it through the column, and per-column Krylov scalars keep
    it *out* of every other column — which is exactly what the chaos
    suite asserts (healthy columns bit-exact with the clean run).
    """
    bad = jnp.asarray(complex(float("nan"), 0.0), eta.dtype)
    return eta.at[(column, *site, 0, 0)].set(bad)


def nan_operator(op, *, lane: int = 0):
    """Wrap a linear-operator callable so every application with a live
    input emits a NaN in one flat lane of its first output leaf.

    The corruption is gated on the lane being nonzero, so the entry
    residual ``b - op(0)`` stays healthy and the divergence appears
    mid-iteration — the guard's freeze path, not the entry exit —
    which also keeps the wrapper `while_loop`-traceable (no Python
    call counter)."""

    def bad_op(v, *args):
        out = op(v, *args)
        leaves, treedef = jax.tree_util.tree_flatten(out)
        flat = leaves[0].reshape(-1)
        nan = jnp.asarray(float("nan"), flat.dtype)
        flat = flat.at[lane].set(
            jnp.where(jnp.abs(flat[lane]) > 0, nan, flat[lane]))
        leaves = [flat.reshape(leaves[0].shape)] + leaves[1:]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return bad_op


_FLOAT_VIEW = {
    np.dtype(np.complex64): np.float32,
    np.dtype(np.complex128): np.float64,
    np.dtype(np.float32): np.float32,
    np.dtype(np.float64): np.float64,
}


def bitflip_gauge(U, *, seed: int = 0, bit: int | None = None):
    """Flip one bit of one real component of one gauge link.

    Seeded and deterministic (numpy bit-view on a host copy).  The
    default bit is a high exponent bit — the flip that turns a unit
    link entry into ~1e18 and makes the unitarity defect unmissable;
    pass a mantissa bit to model subtler corruption.
    """
    a = np.array(np.asarray(U), copy=True)
    f = a.view(_FLOAT_VIEW[a.dtype]).reshape(-1)
    u = f.view(np.uint64 if f.dtype == np.float64 else np.uint32)
    if bit is None:
        bit = 62 if u.dtype == np.uint64 else 30
    k = int(np.random.default_rng(seed).integers(u.size))
    u[k] ^= u.dtype.type(1) << u.dtype.type(bit)
    return jnp.asarray(a)


def corrupt_halo_slab(v, *, axis: int = 0, index: int = 0):
    """NaN one t/z boundary plane of a spinor field — the slab a halo
    exchange ships (complex or planar-native layout, batched or not;
    see :func:`repro.distributed.halo.boundary_slab_index`)."""
    idx = boundary_slab_index(v.ndim, bool(jnp.iscomplexobj(v)),
                              axis=axis, index=index)
    return v.at[idx].set(jnp.asarray(float("nan"), v.dtype))


def _replace_native(bops, fn):
    return dataclasses.replace(
        bops,
        apply_dhat_native=fn,
        apply_dhat_dagger_native=fn,
        apply_dhat_native_batched=fn,
        apply_dhat_dagger_native_batched=fn,
    )


def dead_inner_ops(bops):
    """A copy of ``bops`` whose native Dhat (and dagger) is the ZERO
    operator: every correction solve returns a zero update, so an
    outer refinement loop driven by it stalls deterministically — the
    forced-stagnation injector behind the escalation chaos tests."""

    def zero(v, kappa):
        del kappa
        return jax.tree_util.tree_map(jnp.zeros_like, v)

    return _replace_native(bops, zero)


def break_ops(bops, message: str = "injected compile failure"):
    """A copy of ``bops`` whose native entry points raise
    :class:`InjectedFault` the moment anything traces through them —
    the forced backend-compilation failure behind the fallback-chain
    chaos tests."""

    def boom(v, kappa):
        raise InjectedFault(message)

    return _replace_native(bops, boom)


def stagnating_system(n: int = 48, *, cond: float = 1e8, seed: int = 0,
                      dtype=jnp.float32):
    """A dense SPD system ``(A, b)`` whose f32 CG stalls far above
    tight tolerances: eigenvalues log-spaced across ``cond`` put the
    attainable relative residual orders of magnitude above ``tol``
    values like 1e-12, so the stagnation guard — not ``max_iters`` —
    is what ends the solve."""
    key = jax.random.PRNGKey(seed)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n), dtype=dtype))
    ev = jnp.logspace(0.0, float(np.log10(cond)), n).astype(dtype)
    A = (q * ev) @ q.T
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype=dtype)
    return A, b
