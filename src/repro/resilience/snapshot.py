"""Snapshot/resume for the outer refinement loop.

The mixed-precision outer loop is the one Python-level, long-running
piece of a solve — the natural checkpoint boundary.  Everything else
(the inner Krylov ``while_loop``) is cheap to redo from the restored
f64 iterate, so the snapshot is just ``{"x64": iterate}`` plus the
outer pass number.

Thin harness over :class:`repro.checkpoint.ckpt.Checkpointer` (atomic
staged saves, LATEST pointer, keep-last-k GC) — synchronous saves, so a
snapshot on disk is always complete when :meth:`save` returns.
"""
from __future__ import annotations

from typing import Optional

from repro.checkpoint.ckpt import Checkpointer


class RefinementSnapshot:
    """Checkpoint the f64 outer iterate of a refined solve.

    Pass one to :func:`repro.core.solver.make_refined_solve` via
    ``snapshot=``: the iterate is saved after every outer correction,
    and the next call against the same directory resumes from the
    newest snapshot instead of from zero (fewer f64 applies, same
    converged answer — the chaos suite asserts both).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.ckpt = Checkpointer(directory, keep=keep, async_save=False)

    def save(self, outer: int, x64, extras: Optional[dict] = None):
        """Persist the iterate after outer pass ``outer`` (atomic)."""
        self.ckpt.save(outer, {"x64": x64}, extras=extras or {})

    def resume(self, x64_init):
        """``(x64, start_outer, extras)`` from the newest snapshot, or
        ``(x64_init, 0, {})`` when the directory holds none."""
        step = self.ckpt.latest_step()
        if step is None:
            return x64_init, 0, {}
        tree, step, extras = self.ckpt.restore({"x64": x64_init},
                                               step=step)
        return tree["x64"], int(step), extras

    def latest_outer(self) -> Optional[int]:
        return self.ckpt.latest_step()


class BasisSnapshot:
    """Checkpoint a deflation basis (:mod:`repro.core.deflate`).

    The Lanczos pass (or a stream of recycled solutions) is the
    expensive once-per-gauge part of deflated solving; the basis itself
    is a small fixed-shape pytree — the natural snapshot unit.  A
    long-lived serving process that re-binds the same gauge restores
    the basis instead of re-paying the build; a recycle basis is saved
    after every harvest, so a restart resumes with everything the
    stream has learned so far.  ``step`` is the basis fill count, so
    LATEST always points at the fullest snapshot.
    """

    def __init__(self, directory: str, keep: int = 2):
        self.ckpt = Checkpointer(directory, keep=keep, async_save=False)

    def save(self, count: int, basis, extras: Optional[dict] = None):
        """Persist ``basis`` holding ``count`` filled slots (atomic)."""
        self.ckpt.save(count, basis, extras=extras or {})

    def resume(self, template):
        """Newest snapshot matching ``template``'s structure/shapes, or
        ``None`` (no snapshot, or a stale one of a different rank /
        domain layout — rebuilding beats restoring garbage)."""
        step = self.ckpt.latest_step()
        if step is None:
            return None
        try:
            tree, _, _ = self.ckpt.restore(template, step=step)
        except Exception:
            return None
        import jax

        ref = jax.tree_util.tree_leaves(template)
        got = jax.tree_util.tree_leaves(tree)
        if any(g.shape != r.shape or g.dtype != r.dtype
               for g, r in zip(got, ref)):
            return None
        return tree
