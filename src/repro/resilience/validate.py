"""SU(3) gauge-integrity audit and projection repair.

A gauge link damaged in memory (bit flip) or in transit (truncated
halo) breaks the one invariant every kernel in this repo silently
assumes: links are SU(3).  Both compressed codecs are *worse* than the
dense form here — two_row reconstructs row 3 as ``conj(a x b)`` and
minimal rebuilds the whole matrix from 8 reals, so a non-unitary input
link decompresses into garbage with no trace of the original damage.
The audit therefore runs on the dense complex field **before** any
codec packs it (``WilsonMatrix.bind(validate=...)`` orders it that
way), which covers every ``gauge_compression`` mode with one check.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import su3

# Audit tolerance by gauge dtype: healthy QR-generated SU(3) sits at
# ~1e-7 (f32) / ~1e-15 (f64); one flipped mantissa bit in a link lands
# orders of magnitude above either bound.
_DEFAULT_TOL = {
    jnp.dtype(jnp.complex64): 1e-4,
    jnp.dtype(jnp.complex128): 1e-10,
}


@dataclasses.dataclass(frozen=True)
class GaugeAuditReport:
    """Outcome of a gauge-integrity audit (both parities together)."""
    max_defect: float          # max |U U^dag - 1| over finite links
    nonfinite_links: int       # links with any NaN/Inf entry
    tolerance: float
    repaired: bool = False

    @property
    def ok(self) -> bool:
        return (self.nonfinite_links == 0
                and self.max_defect <= self.tolerance)


def _tolerance(U_e, tol: Optional[float]) -> float:
    if tol is not None:
        return float(tol)
    return _DEFAULT_TOL.get(jnp.dtype(U_e.dtype), 1e-4)


def _finite_mask(U):
    """(..., 1, 1)-broadcastable per-link all-finite mask."""
    finite = jnp.logical_and(jnp.isfinite(U.real), jnp.isfinite(U.imag))
    return jnp.all(finite, axis=(-2, -1), keepdims=True)


def audit_gauge(U_e, U_o, tol: Optional[float] = None) -> GaugeAuditReport:
    """Audit both even-odd gauge parities for SU(3) integrity.

    Checks every link for non-finite entries and measures the worst
    unitarity defect over the *finite* links (a NaN link would
    otherwise NaN the whole reduction and mask the rest of the field).
    """
    tolerance = _tolerance(U_e, tol)
    nonfinite = 0
    defect = 0.0
    eye = jnp.eye(3, dtype=U_e.dtype)
    for U in (U_e, U_o):
        mask = _finite_mask(U)
        nonfinite += int(jnp.sum(jnp.logical_not(mask)))
        clean = jnp.where(mask, U, eye)
        d = float(su3.unitarity_defect(clean))
        # A finite-but-huge corrupted entry overflows U U^dag to
        # inf - inf = NaN; Python's max() would silently drop it
        # (nan > x is False), so pin non-finite defects to +inf.
        defect = max(defect, d if d == d else float("inf"))
    return GaugeAuditReport(max_defect=defect, nonfinite_links=nonfinite,
                            tolerance=tolerance)


def repair_gauge(U_e, U_o,
                 tol: Optional[float] = None) -> Tuple:
    """Audit, then repair: ``(U_e, U_o, GaugeAuditReport)``.

    Non-finite links are replaced by the identity (the only basis-free
    choice — the original data is gone) and every link is projected
    back onto SU(3) via :func:`repro.core.su3.project_su3` (nearest
    unitary in Frobenius norm, determinant phase divided out).  A
    healthy field is returned untouched — bit-exactly — so calling this
    unconditionally costs one audit, not one projection.
    """
    before = audit_gauge(U_e, U_o, tol)
    if before.ok:
        return U_e, U_o, before
    eye = jnp.eye(3, dtype=U_e.dtype)
    repaired = []
    for U in (U_e, U_o):
        clean = jnp.where(_finite_mask(U), U, eye)
        repaired.append(su3.project_su3(clean))
    after = audit_gauge(repaired[0], repaired[1], tol)
    return (repaired[0], repaired[1],
            dataclasses.replace(after, repaired=True))
