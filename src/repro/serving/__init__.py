"""``repro.serving`` — the propagator-serving daemon.

Independent solve requests against the same bound
:class:`~repro.api.WilsonMatrix` + :class:`~repro.api.SolveSpec`
coalesce into one multi-RHS block (the bandwidth-bound kernel streams
the gauge field once per batch — see ``BENCH_multirhs.json`` for the
arithmetic-intensity ledger), then split back per request with each
request's own iterations / residual / convergence verdict, guaranteed
independent by the solvers' per-column freeze semantics.

Layers (each importable on its own):

* :mod:`repro.serving.policy` — :class:`BatchingPolicy`,
  :class:`AdmissionPolicy`, and the typed error taxonomy.
* :mod:`repro.serving.queue` — the thread-safe coalescing queue.
* :mod:`repro.serving.pool` — :class:`SessionPool` of bound matrices
  with LRU eviction, warmup, and per-entry degradation.
* :mod:`repro.serving.daemon` — :class:`PropagatorDaemon` (submit ->
  future -> :class:`RequestResult`) and the stdlib-asyncio HTTP front
  end :func:`serve_http`.

CLI: ``python -m repro.launch.serve``.
"""
from __future__ import annotations

from .daemon import (HttpServerThread, PropagatorDaemon, RequestResult,
                     decode_array, encode_array, serve_http,
                     spec_from_json)
from .policy import (AdmissionPolicy, BadRequestError, BatchingPolicy,
                     DrainingError, RequestTimeoutError, ServingError,
                     ShedError, UnknownMatrixError)
from .pool import PoolEntry, SessionPool
from .queue import RequestQueue, SolveRequest

__all__ = [
    "PropagatorDaemon", "RequestResult", "serve_http",
    "HttpServerThread",
    "encode_array", "decode_array", "spec_from_json",
    "BatchingPolicy", "AdmissionPolicy",
    "ServingError", "ShedError", "RequestTimeoutError",
    "DrainingError", "UnknownMatrixError", "BadRequestError",
    "SessionPool", "PoolEntry", "RequestQueue", "SolveRequest",
]
