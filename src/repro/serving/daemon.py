"""Propagator-serving daemon: async request queue over a session pool.

The serving thesis, end to end: the paper's kernel is bandwidth-bound,
so the cheapest throughput win for independent solve requests is to
stream the gauge field once per *batch* instead of once per request.
The daemon owns the three pieces that make that safe and observable:

* a :class:`~repro.serving.queue.RequestQueue` coalescing
  same-``(matrix, SolveSpec, shape, dtype)`` requests into one
  multi-RHS block under a :class:`~repro.serving.policy.BatchingPolicy`
  (max block, linger, bucketed padding);
* a :class:`~repro.serving.pool.SessionPool` of bound matrices and
  their compiled-solve caches, with PR 8 fallback degradation scoped to
  the pool entry;
* one dispatcher thread running the batched solves through
  :meth:`repro.api.SolveSession.solve_block` and splitting results back
  per request — per-column freeze semantics make the coalesced answers
  bit-identical to solo answers of the same executable, and per-column
  stats give every request its *own* iterations/residual/diverged.

Request lifecycle: ``submit`` -> admission control (typed
:class:`~repro.serving.policy.ShedError` /
:class:`~repro.serving.policy.DrainingError`) -> queue (deadline ->
:class:`~repro.serving.policy.RequestTimeoutError` with partial stats)
-> batch -> :class:`RequestResult` on a
:class:`concurrent.futures.Future`.  The asyncio HTTP front end
(:func:`serve_http`) is a thin JSON/npy codec over exactly this
lifecycle — stdlib only, no web framework.
"""
from __future__ import annotations

import asyncio
import base64
import dataclasses
import io
import json
import threading
import time
from concurrent.futures import Future
from typing import Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.api import SolveSpec, WilsonMatrix

from .policy import (AdmissionPolicy, BadRequestError, BatchingPolicy,
                     DrainingError, ServingError)
from .pool import SessionPool
from .queue import RequestQueue, SolveRequest

__all__ = ["PropagatorDaemon", "RequestResult", "serve_http",
           "HttpServerThread", "encode_array", "decode_array",
           "spec_from_json"]

_UNSET = object()


@dataclasses.dataclass
class RequestResult:
    """One request's answer, split back out of its batch.

    ``result`` is this request's own column slice of the batched
    :class:`~repro.core.solver.SolveResult` (iterations / residuals /
    converged / diverged are per-column arrays).  ``stats`` adds the
    serving-side accounting: queueing delay, the batch this request
    rode, how full it was, and per-column iteration counts.
    """

    xi_e: object
    xi_o: object
    result: object
    stats: dict

    @property
    def converged(self) -> bool:
        return bool(np.asarray(self.result.converged).all())

    @property
    def diverged(self) -> bool:
        return bool(np.asarray(
            getattr(self.result, "diverged", False)).any())


class PropagatorDaemon:
    """Async request queue + cross-request multi-RHS coalescing over
    the :class:`~repro.api.SolveSession` layer.

    ::

        daemon = PropagatorDaemon()
        daemon.register("cfg0", WilsonMatrix.bind(U_e, U_o, kappa))
        daemon.start()
        futs = [daemon.submit("cfg0", eta_e, eta_o) for ...]
        results = [f.result() for f in futs]       # RequestResult each
        daemon.drain()

    ``submit`` is thread-safe and non-blocking (admission control may
    raise, never wait); results arrive on ``concurrent.futures.Future``
    objects, so both threads and asyncio callers
    (``asyncio.wrap_future``) consume them natively.
    """

    def __init__(self, pool: Optional[SessionPool] = None,
                 batching: Optional[BatchingPolicy] = None,
                 admission: Optional[AdmissionPolicy] = None, *,
                 donate: bool = False, clock=time.monotonic):
        self.pool = pool if pool is not None else SessionPool()
        self.batching = batching if batching is not None \
            else BatchingPolicy()
        self.admission = admission if admission is not None \
            else AdmissionPolicy()
        self.donate = bool(donate)
        self.clock = clock
        self.queue = RequestQueue(self.batching, self.admission,
                                  clock=clock)
        self._stop = threading.Event()
        self._draining = False
        self._thread: Optional[threading.Thread] = None
        self._batch_ids = 0
        self._mlock = threading.Lock()
        self._metrics = {"submitted": 0, "completed": 0, "failed": 0,
                         "shed": 0, "timed_out": 0, "batches": 0,
                         "batch_fill_hist": {}}

    # --- lifecycle -----------------------------------------------------

    def register(self, name: str, matrix: WilsonMatrix,
                 warmup_spec: Optional[SolveSpec] = None,
                 warmup_buckets=None):
        """Register a bound matrix; optionally pre-trace its buckets so
        the first live request pays Krylov time, not compile time."""
        entry = self.pool.register(name, matrix)
        if warmup_spec is not None:
            buckets = (self.batching.buckets if warmup_buckets is None
                       else warmup_buckets)
            self.pool.warmup(name, warmup_spec, buckets)
        return entry

    def start(self) -> "PropagatorDaemon":
        if self._thread is not None:
            raise RuntimeError("daemon already started")
        self._thread = threading.Thread(
            target=self._run, name="propagator-dispatch", daemon=True)
        self._thread.start()
        return self

    def drain(self, timeout: Optional[float] = None) -> None:
        """Graceful shutdown: refuse new submits, finish everything
        already queued, then stop the dispatcher."""
        self._draining = True
        self._stop.set()
        with self.queue.cond:
            self.queue.cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def close(self) -> None:
        """Hard shutdown: queued requests fail with DrainingError."""
        self._draining = True
        n = self.queue.fail_all(
            DrainingError("daemon closed with requests still queued"))
        with self._mlock:
            self._metrics["failed"] += n
        self.drain(timeout=60.0)

    def __enter__(self) -> "PropagatorDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.drain()

    # --- submission ----------------------------------------------------

    def submit(self, name: str, eta_e, eta_o,
               spec: Optional[SolveSpec] = None, *,
               timeout_s=_UNSET) -> "Future[RequestResult]":
        """Enqueue one solve request; returns the future its
        :class:`RequestResult` lands on.

        ``timeout_s`` defaults to the admission policy's deadline; pass
        ``None`` explicitly for no deadline.  Typed rejections
        (:class:`ShedError`, :class:`DrainingError`,
        :class:`UnknownMatrixError`, :class:`BadRequestError`) raise
        here, synchronously — a rejected request never holds a future.
        """
        if self._draining:
            raise DrainingError("daemon is draining; no new requests")
        entry = self.pool.entry(str(name))  # typed 404 before queueing
        eta_e, eta_o, nrhs = self._check_sources(entry, eta_e, eta_o)
        spec = self._normalize_spec(spec)
        now = self.clock()
        if timeout_s is _UNSET:
            timeout_s = self.admission.default_timeout_s
        deadline = None if timeout_s is None else now + float(timeout_s)
        key = (str(name), spec, tuple(eta_e.shape[1:]),
               str(eta_e.dtype))
        fut: "Future[RequestResult]" = Future()
        req = SolveRequest(key, eta_e, eta_o, deadline=deadline,
                           submitted_at=now, future=fut)
        try:
            self.queue.submit(req)
        except ServingError:
            with self._mlock:
                self._metrics["shed"] += 1
            raise
        with self._mlock:
            self._metrics["submitted"] += 1
        return fut

    def solve(self, name: str, eta_e, eta_o,
              spec: Optional[SolveSpec] = None, *,
              timeout_s=_UNSET) -> RequestResult:
        """Blocking convenience around :meth:`submit`."""
        return self.submit(name, eta_e, eta_o, spec,
                           timeout_s=timeout_s).result()

    def _check_sources(self, entry, eta_e, eta_o):
        if getattr(eta_e, "ndim", None) not in (6, 7) \
                or getattr(eta_o, "ndim", None) != eta_e.ndim:
            raise BadRequestError(
                "sources must be 6-d spinor halves or 7-d RHS blocks; "
                f"got ndim {getattr(eta_e, 'ndim', None)} / "
                f"{getattr(eta_o, 'ndim', None)}")
        if eta_e.ndim == 6:
            eta_e, eta_o = eta_e[None], eta_o[None]
        nrhs = int(eta_e.shape[0])
        if nrhs < 1 or nrhs > self.batching.max_block:
            raise BadRequestError(
                f"request carries {nrhs} columns; policy max_block is "
                f"{self.batching.max_block}")
        lat = entry.matrix.lattice
        if lat is not None:
            want = lat.spinor_eo_shape()
            if tuple(eta_e.shape[1:]) != want \
                    or tuple(eta_o.shape[1:]) != want:
                raise BadRequestError(
                    f"source shape {tuple(eta_e.shape[1:])} does not "
                    f"match lattice {want}")
        return eta_e, eta_o, nrhs

    def _normalize_spec(self, spec: Optional[SolveSpec]) -> SolveSpec:
        if spec is None:
            spec = SolveSpec()
        if not isinstance(spec, SolveSpec):
            raise BadRequestError(
                f"spec must be a SolveSpec; got {type(spec).__name__}")
        # Batch size belongs to the batcher; a request-pinned nrhs
        # would split coalescable traffic into distinct keys.
        if spec.nrhs is not None:
            spec = dataclasses.replace(spec, nrhs=None)
        if spec.donate_rhs:
            # Donation is daemon-owned: it donates the *batch*
            # temporaries it assembled, never caller arrays.
            spec = dataclasses.replace(spec, donate_rhs=False)
        return spec

    # --- dispatcher ----------------------------------------------------

    def _run(self) -> None:
        while True:
            got = self.queue.wait_ready(stop_event=self._stop)
            if got is None:
                return
            key, reqs = got
            if reqs:
                self._run_batch(key, reqs)

    def _run_batch(self, key, reqs) -> None:
        name, spec = key[0], key[1]
        t0 = self.clock()
        try:
            entry = self.pool.entry(name)
            cols = sum(r.nrhs for r in reqs)
            bucket = self.batching.bucket(cols)
            eta_e = jnp.concatenate([r.eta_e for r in reqs], axis=0)
            eta_o = jnp.concatenate([r.eta_o for r in reqs], axis=0)
            if bucket > cols:
                # Pad up to the bucket with zero columns: they converge
                # at entry and freeze, costing bandwidth but never
                # iterations — and the executable cache stays at one
                # trace per (spec, bucket).
                pad = jnp.zeros((bucket - cols,) + eta_e.shape[1:],
                                eta_e.dtype)
                eta_e = jnp.concatenate([eta_e, pad], axis=0)
                eta_o = jnp.concatenate([eta_o, pad], axis=0)
            bounds, lo = [], 0
            for r in reqs:
                bounds.append((lo, lo + r.nrhs))
                lo += r.nrhs
            xi_e, xi_o, res, parts = entry.session.solve_block(
                eta_e, eta_o, spec, donate=self.donate, bounds=bounds)
        except Exception as exc:  # noqa: BLE001 — fan failure out
            # The session already walked any armed fallback chain; an
            # exception here is terminal for THIS batch only.  The pool
            # entry survives (possibly degraded) and the daemon keeps
            # serving.
            for r in reqs:
                r.future.set_exception(exc)
            with self._mlock:
                self._metrics["failed"] += len(reqs)
            return

        solve_s = self.clock() - t0
        self._batch_ids += 1
        batch_id = self._batch_ids
        now = self.clock()
        for r, (lo, hi), part in zip(reqs, bounds, parts):
            stats = {
                "request_id": r.id,
                "batch_id": batch_id,
                "batch_columns": cols,
                "bucket": bucket,
                "columns": [lo, hi],
                "queued_s": t0 - r.submitted_at,
                "solve_s": solve_s,
                "iterations": np.asarray(part.iterations).tolist(),
                "residual": np.asarray(part.residual).tolist(),
                "converged": np.asarray(part.converged).tolist(),
                "diverged": np.asarray(
                    getattr(part, "diverged", False)).tolist(),
                "backend": entry.matrix.backend.name,
                "degraded": bool(entry.matrix.degraded),
            }
            r.future.set_result(
                RequestResult(xi_e[lo:hi], xi_o[lo:hi], part, stats))
        entry.requests += len(reqs)
        entry.batches += 1
        entry.columns += cols
        entry.padded_columns += bucket - cols
        with self._mlock:
            self._metrics["completed"] += len(reqs)
            self._metrics["batches"] += 1
            hist = self._metrics["batch_fill_hist"]
            hist[str(cols)] = hist.get(str(cols), 0) + 1

    # --- observability -------------------------------------------------

    def metrics(self) -> dict:
        """The serving report: daemon counters, batch-fill histogram
        (real columns per dispatched batch), queue depth, and the full
        pool/session stats (traces, hits, escalations, fallbacks)."""
        with self._mlock:
            m = {k: (dict(v) if isinstance(v, dict) else v)
                 for k, v in self._metrics.items()}
        hist = m["batch_fill_hist"]
        total = sum(hist.values())
        m["mean_batch_columns"] = (
            sum(int(k) * v for k, v in hist.items()) / total
            if total else None)
        m["queue_depth"] = self.queue.depth
        m["draining"] = self._draining
        m["batching"] = {"max_block": self.batching.max_block,
                         "linger_s": self.batching.linger_s,
                         "buckets": list(self.batching.buckets)}
        m["admission"] = {
            "max_queue_depth": self.admission.max_queue_depth,
            "default_timeout_s": self.admission.default_timeout_s}
        m["pool"] = self.pool.stats()
        return m


# --- JSON / npy payload codec ------------------------------------------


def encode_array(a) -> dict:
    """Array -> ``{"npy": base64}`` (the .npy container keeps dtype and
    shape; base64 keeps it JSON-clean)."""
    buf = io.BytesIO()
    np.save(buf, np.asarray(a), allow_pickle=False)
    return {"npy": base64.b64encode(buf.getvalue()).decode("ascii")}


def decode_array(obj):
    """Accepts ``{"npy": base64}`` or a nested JSON list (complex
    arrays as a trailing re/im axis is the caller's business — lists
    decode with ``np.asarray`` semantics)."""
    if isinstance(obj, dict) and "npy" in obj:
        buf = io.BytesIO(base64.b64decode(obj["npy"]))
        try:
            return np.load(buf, allow_pickle=False)
        except Exception as exc:
            raise BadRequestError(f"bad npy payload: {exc!r}")
    if isinstance(obj, list):
        try:
            return np.asarray(obj)
        except Exception as exc:
            raise BadRequestError(f"bad array payload: {exc!r}")
    raise BadRequestError(
        "array payloads are {'npy': base64} or nested lists; got "
        f"{type(obj).__name__}")


_SPEC_FIELDS = {f.name for f in dataclasses.fields(SolveSpec)}


def spec_from_json(obj) -> SolveSpec:
    """Whitelisted SolveSpec constructor for wire payloads: unknown
    fields are a typed 400, not a silent ignore."""
    if obj is None:
        return SolveSpec()
    if not isinstance(obj, dict):
        raise BadRequestError(
            f"spec must be a JSON object; got {type(obj).__name__}")
    unknown = sorted(set(obj) - _SPEC_FIELDS)
    if unknown:
        raise BadRequestError(
            f"unknown SolveSpec fields {unknown}; allowed: "
            f"{sorted(_SPEC_FIELDS)}")
    try:
        return SolveSpec(**obj)
    except (TypeError, ValueError) as exc:
        raise BadRequestError(f"bad SolveSpec: {exc}")


# --- asyncio HTTP front end --------------------------------------------


def _http_response(status: int, payload: dict) -> bytes:
    body = json.dumps(payload, default=str).encode()
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              429: "Too Many Requests", 503: "Service Unavailable",
              504: "Gateway Timeout"}.get(status, "Error")
    head = (f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode()
    return head + body


async def _read_request(reader):
    line = await reader.readline()
    if not line:
        return None, None, None
    parts = line.decode("latin-1").split()
    if len(parts) < 2:
        return None, None, None
    method, path = parts[0].upper(), parts[1]
    length = 0
    while True:
        h = await reader.readline()
        if not h or h in (b"\r\n", b"\n"):
            break
        name, _, value = h.decode("latin-1").partition(":")
        if name.strip().lower() == "content-length":
            length = int(value.strip())
    body = await reader.readexactly(length) if length else b""
    return method, path, body


async def _handle(daemon: PropagatorDaemon, reader, writer) -> None:
    try:
        method, path, body = await _read_request(reader)
        if method is None:
            return
        if method == "GET" and path == "/v1/healthz":
            out = _http_response(200, {
                "ok": True, "draining": daemon._draining,
                "matrices": list(daemon.pool.names())})
        elif method == "GET" and path == "/v1/metrics":
            out = _http_response(200, daemon.metrics())
        elif method == "POST" and path == "/v1/solve":
            out = await _solve_endpoint(daemon, body)
        else:
            out = _http_response(404, {
                "error": "not_found", "message": f"no route "
                f"{method} {path}"})
    except ServingError as exc:
        out = _http_response(exc.http_status,
                             {"error": exc.code, "message": str(exc)})
    except Exception as exc:  # noqa: BLE001 — wire boundary
        out = _http_response(500, {"error": "error",
                                   "message": repr(exc)})
    try:
        writer.write(out)
        await writer.drain()
    finally:
        writer.close()


async def _solve_endpoint(daemon: PropagatorDaemon,
                          body: bytes) -> bytes:
    try:
        payload = json.loads(body.decode() or "{}")
    except ValueError as exc:
        raise BadRequestError(f"request body is not JSON: {exc}")
    if not isinstance(payload, dict) or "matrix" not in payload:
        raise BadRequestError(
            "POST /v1/solve needs {'matrix': name, 'eta_e': ..., "
            "'eta_o': ..., 'spec'?: {...}, 'timeout_s'?: seconds}")
    eta_e = jnp.asarray(decode_array(payload.get("eta_e")))
    eta_o = jnp.asarray(decode_array(payload.get("eta_o")))
    spec = spec_from_json(payload.get("spec"))
    timeout_s = payload.get("timeout_s", _UNSET)
    fut = daemon.submit(payload["matrix"], eta_e, eta_o, spec,
                        timeout_s=timeout_s)
    try:
        rr = await asyncio.wrap_future(fut)
    except ServingError:
        raise
    return _http_response(200, {
        "xi_e": encode_array(rr.xi_e),
        "xi_o": encode_array(rr.xi_o),
        "stats": rr.stats,
    })


async def serve_http(daemon: PropagatorDaemon, host: str = "127.0.0.1",
                     port: int = 8787, *,
                     ready: Optional[asyncio.Event] = None,
                     stop: Optional[asyncio.Event] = None
                     ) -> Tuple[str, int]:
    """Serve the daemon over HTTP until ``stop`` is set.

    Routes: ``POST /v1/solve`` (JSON body with npy/base64 or list
    sources), ``GET /v1/metrics`` (the full serving report),
    ``GET /v1/healthz``.  Returns the bound ``(host, port)`` — pass
    ``port=0`` to let the OS pick (the test suite does)."""
    server = await asyncio.start_server(
        lambda r, w: _handle(daemon, r, w), host, port)
    bound = server.sockets[0].getsockname()[:2]
    serve_http.last_bound = bound  # cross-thread discovery hook
    if ready is not None:
        ready.set()
    async with server:
        if stop is None:
            await asyncio.Future()  # serve forever
        else:
            await stop.wait()
    return bound


class HttpServerThread:
    """Host :func:`serve_http` on a dedicated event-loop thread.

    The dispatcher thread blocks in JAX solves, and callers (the CLI
    selftest, the test suite, the serving benchmark) are synchronous —
    this wrapper gives them a real HTTP endpoint without owning an
    event loop.  ``start()`` returns the bound ``(host, port)``;
    ``stop()`` shuts the listener down (the daemon's own lifecycle is
    the caller's business)."""

    def __init__(self, daemon: PropagatorDaemon,
                 host: str = "127.0.0.1", port: int = 0):
        self.daemon = daemon
        self.host, self.port = host, port
        self.bound: Optional[Tuple[str, int]] = None
        self._ready = threading.Event()
        self._loop = None
        self._stop_ev = None
        self._thread = threading.Thread(
            target=self._run, name="propagator-http", daemon=True)

    def _run(self) -> None:
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        self._stop_ev = asyncio.Event()
        ready = asyncio.Event()

        async def go():
            task = self._loop.create_task(serve_http(
                self.daemon, self.host, self.port, ready=ready,
                stop=self._stop_ev))
            await ready.wait()
            self.bound = serve_http.last_bound
            self._ready.set()
            await task

        try:
            self._loop.run_until_complete(go())
        finally:
            self._ready.set()  # unblock start() on startup failure
            self._loop.close()

    def start(self) -> Tuple[str, int]:
        self._thread.start()
        self._ready.wait(30.0)
        if self.bound is None:
            raise RuntimeError("HTTP server failed to bind")
        return self.bound

    def stop(self, timeout: float = 10.0) -> None:
        if self._loop is not None and self._stop_ev is not None:
            self._loop.call_soon_threadsafe(self._stop_ev.set)
        self._thread.join(timeout)
