"""Admission control, deadlines, and batching policy for the daemon.

Two frozen policy dataclasses — configuration only, no machinery — plus
the typed error taxonomy every rejection path speaks:

* :class:`BatchingPolicy` — how independent requests coalesce into one
  multi-RHS block: ``max_block`` caps the columns mixed into a batch,
  ``linger_s`` is how long a non-full batch may wait for company, and
  ``buckets`` quantizes the batch size (a ragged batch is zero-padded
  up to the next bucket) so the :class:`~repro.api.SolveSession`
  executable cache holds one compiled solve per bucket instead of one
  per observed batch size.
* :class:`AdmissionPolicy` — bounded queue depth (overload sheds with
  :class:`ShedError` instead of growing latency without bound) and the
  default per-request deadline (:class:`RequestTimeoutError` carries
  the partial stats of a request cancelled while still queued).

Every error is a :class:`ServingError` with a stable ``code`` and an
``http_status``, so the HTTP front end maps failures to responses
without string matching and in-process callers can ``except`` by type.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Optional, Tuple

__all__ = [
    "AdmissionPolicy", "BatchingPolicy", "ServingError", "ShedError",
    "RequestTimeoutError", "DrainingError", "UnknownMatrixError",
    "BadRequestError",
]


class ServingError(RuntimeError):
    """Base of the daemon's typed rejection taxonomy."""

    code = "error"
    http_status = 500


class ShedError(ServingError):
    """Admission control shed the request: the queue is at its bounded
    depth and adding more work would only grow tail latency."""

    code = "shed"
    http_status = 429


class RequestTimeoutError(ServingError):
    """The request's deadline passed while it was still queued.

    ``stats`` carries the partial accounting (time queued, deadline,
    queue depth at expiry) — "cancelled with partial stats", never a
    bare timeout string.
    """

    code = "timeout"
    http_status = 504

    def __init__(self, message: str, stats: Optional[dict] = None):
        super().__init__(message)
        self.stats = dict(stats or {})


class DrainingError(ServingError):
    """The daemon is draining for shutdown and accepts no new work."""

    code = "draining"
    http_status = 503


class UnknownMatrixError(ServingError):
    """The request names a matrix the pool has not registered."""

    code = "unknown_matrix"
    http_status = 404


class BadRequestError(ServingError):
    """Malformed request: bad shapes, bad spec fields, bad payload."""

    code = "bad_request"
    http_status = 400


@dataclasses.dataclass(frozen=True)
class BatchingPolicy:
    """How the queue coalesces same-key requests into one block.

    ``max_block`` — most columns one batch may carry (a single request
    bringing more columns than this is rejected at submit).
    ``linger_s`` — how long the oldest queued request may wait for the
    batch to fill before it is dispatched ragged; ``0`` disables
    coalescing-by-waiting (a batch still forms from requests that are
    *already* queued together).  ``buckets`` — allowed compiled batch
    sizes, ascending; a ragged batch pads with zero columns up to the
    next bucket (zero sources converge at entry and freeze, so padding
    costs bandwidth, never iterations) keeping the executable cache at
    one trace per (spec, bucket).
    """

    max_block: int = 8
    linger_s: float = 0.002
    buckets: Tuple[int, ...] = (1, 2, 4, 8)

    def __post_init__(self):
        buckets = tuple(int(b) for b in self.buckets)
        object.__setattr__(self, "buckets", buckets)
        if not buckets or any(b < 1 for b in buckets) \
                or list(buckets) != sorted(set(buckets)):
            raise ValueError(
                f"buckets must be ascending distinct positive ints; "
                f"got {self.buckets!r}")
        if self.max_block < 1:
            raise ValueError(
                f"max_block must be >= 1; got {self.max_block}")
        if buckets[-1] < self.max_block:
            raise ValueError(
                f"buckets must cover max_block={self.max_block}; "
                f"largest bucket is {buckets[-1]}")
        if self.linger_s < 0:
            raise ValueError(
                f"linger_s must be >= 0; got {self.linger_s}")

    def bucket(self, nrhs: int) -> int:
        """Smallest allowed batch size >= ``nrhs``."""
        if nrhs < 1 or nrhs > self.buckets[-1]:
            raise ValueError(
                f"nrhs={nrhs} outside bucket range {self.buckets}")
        return self.buckets[bisect.bisect_left(self.buckets, nrhs)]


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Bounded-queue admission control and default deadlines.

    ``max_queue_depth`` — most *requests* queued across all keys; a
    submit beyond it sheds (:class:`ShedError`).  ``default_timeout_s``
    — deadline applied when a request does not bring its own (``None``
    = no deadline).  A request still queued past its deadline is
    cancelled with partial stats (:class:`RequestTimeoutError`); a
    request already inside a running batch completes (a Krylov solve
    is not preemptible mid-``while_loop``).
    """

    max_queue_depth: int = 256
    default_timeout_s: Optional[float] = 30.0

    def __post_init__(self):
        if self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1; got "
                f"{self.max_queue_depth}")
        if self.default_timeout_s is not None \
                and not self.default_timeout_s > 0:
            raise ValueError(
                f"default_timeout_s must be > 0 or None; got "
                f"{self.default_timeout_s}")
