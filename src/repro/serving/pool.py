"""Session/matrix pool: bound operators + compiled solves, kept warm.

The daemon serves against matrices that were bound once — layout
conversion, sharding placement, deflation bases are all bind-time work
— and the pool is where those bound :class:`~repro.api.WilsonMatrix`
objects live between requests.  Each registered matrix owns one
:class:`~repro.api.SolveSession`, whose executable cache is keyed by
``(SolveSpec, rhs shape, rhs dtype)``; combined with the batcher's
bucketed block sizes that cache stays at one compiled solve per
``(lattice, backend, SolveSpec, bucket)`` — exactly the key the pool's
``stats()`` reports trace counts against.

Resilience composes rather than duplicates: a matrix registered with
``fallback=True`` carries the PR 8 machinery, so a poisoned backend
degrades *the pool entry* (its session walks the fallback chain,
rebinds, flushes its executable cache, retries) and the daemon keeps
serving — ``stats()`` surfaces ``degraded`` and the fallback ledger per
entry instead of the daemon dying.

Eviction is LRU over entries with a bounded capacity: registering
matrix ``capacity+1`` drops the least-recently-*solved* entry and its
compiled executables.  Deflation bases live on the matrix, so an
evicted-then-reregistered gauge re-traces but does not re-Lanczos if
the caller kept the matrix object alive.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.api import SolveSession, SolveSpec, WilsonMatrix

from .policy import BadRequestError, UnknownMatrixError

__all__ = ["PoolEntry", "SessionPool"]


class PoolEntry:
    """One registered matrix: its session, and serving accounting."""

    __slots__ = ("name", "matrix", "session", "registered_at",
                 "last_used", "requests", "batches", "columns",
                 "padded_columns")

    def __init__(self, name: str, matrix: WilsonMatrix):
        self.name = name
        self.matrix = matrix
        self.session = SolveSession(matrix)
        self.registered_at = time.monotonic()
        self.last_used = self.registered_at
        self.requests = 0        # requests answered from this entry
        self.batches = 0         # coalesced solves run
        self.columns = 0         # real (request) columns solved
        self.padded_columns = 0  # zero-pad columns solved alongside

    def fill_factor(self) -> Optional[float]:
        """Mean real-columns / solved-columns across batches (1.0 =
        every solved column was a request column; padding lowers it)."""
        total = self.columns + self.padded_columns
        return (self.columns / total) if total else None


class SessionPool:
    """Named, LRU-bounded pool of :class:`PoolEntry`.

    Thread-safe; the asyncio front end registers/inspects while the
    dispatcher thread solves.  Lookup raises the typed
    :class:`~repro.serving.policy.UnknownMatrixError` so transports can
    map it to a 404 without string matching.
    """

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self._entries: "OrderedDict[str, PoolEntry]" = OrderedDict()
        self._lock = threading.RLock()
        self._evictions: list = []

    # --- registration --------------------------------------------------

    def register(self, name: str, matrix: WilsonMatrix) -> PoolEntry:
        """Add (or replace) a matrix under ``name``; may evict LRU."""
        if not isinstance(matrix, WilsonMatrix):
            raise BadRequestError(
                f"pool entries are bound WilsonMatrix objects; got "
                f"{type(matrix).__name__}")
        with self._lock:
            entry = PoolEntry(str(name), matrix)
            self._entries.pop(entry.name, None)
            self._entries[entry.name] = entry
            while len(self._entries) > self.capacity:
                victim, _ = self._entries.popitem(last=False)
                self._evictions.append(victim)
            return entry

    def entry(self, name: str) -> PoolEntry:
        """LRU-touching lookup; typed 404 for unknown names."""
        with self._lock:
            e = self._entries.get(name)
            if e is None:
                raise UnknownMatrixError(
                    f"no matrix registered as {name!r}; have "
                    f"{sorted(self._entries)}")
            self._entries.move_to_end(name)
            e.last_used = time.monotonic()
            return e

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._entries

    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._entries)

    # --- warmup --------------------------------------------------------

    def warmup(self, name: str, spec: SolveSpec,
               buckets=(1,)) -> Dict[int, float]:
        """Pre-trace the executables live traffic will hit: one
        zero-source solve per bucket size.  Zero sources converge at
        entry (guard residual 0), so warmup pays compile time, not
        Krylov time.  Returns {bucket: wall_seconds}."""
        e = self.entry(name)
        lat = e.matrix.lattice
        if lat is None:
            raise BadRequestError(
                f"matrix {name!r} has no LatticeSpec; cannot shape "
                "warmup sources")
        shape = lat.spinor_eo_shape()
        timings = {}
        for b in sorted(set(int(x) for x in buckets)):
            eta = jnp.zeros((b,) + shape, dtype=jnp.complex64)
            t0 = time.perf_counter()
            e.session.solve_block(eta, eta, spec)
            timings[b] = time.perf_counter() - t0
        return timings

    # --- observability -------------------------------------------------

    def stats(self) -> dict:
        """Pool-level report: per-entry serving counters + the wrapped
        session stats (traces, hits, iterations, fallbacks)."""
        with self._lock:
            entries = {}
            for name, e in self._entries.items():
                lat = e.matrix.lattice
                entries[name] = {
                    "backend": e.matrix.backend.name,
                    "requested_backend": e.matrix.requested_backend,
                    "degraded": bool(e.matrix.degraded),
                    "lattice": (list(lat.extents) if lat is not None
                                else None),
                    "requests": e.requests,
                    "batches": e.batches,
                    "columns": e.columns,
                    "padded_columns": e.padded_columns,
                    "batch_fill": e.fill_factor(),
                    "session": e.session.stats(),
                }
            return {
                "capacity": self.capacity,
                "size": len(self._entries),
                "evictions": list(self._evictions),
                "entries": entries,
            }
