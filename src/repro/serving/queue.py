"""Coalescing request queue: independent solve requests -> RHS blocks.

The economics this queue exists for are measured in
``BENCH_multirhs.json``: the even-odd Wilson kernel is bandwidth-bound,
and batching right-hand sides amortizes ONE gauge stream over the whole
block (arithmetic intensity 1.72 -> 3.93 flops/byte at nrhs 1 -> 4).
Callers who bring their own batch already win; this queue builds the
batch *for* callers who don't — independent single- or few-RHS requests
against the same bound matrix and :class:`~repro.api.SolveSpec`
coalesce into one multi-RHS solve, and per-column freeze semantics
(PR 3/8/9) guarantee each request's columns converge, freeze, and
report exactly as they would have alone.

Grouping key: requests coalesce only when they share
``(matrix name, SolveSpec, per-RHS shape, dtype)`` — one executable,
one gauge stream, one batch.  The queue itself is transport-agnostic
and thread-safe (a plain :class:`threading.Condition`); the asyncio
front end and the dispatcher thread both talk to it.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from .policy import (AdmissionPolicy, BatchingPolicy,
                     RequestTimeoutError, ShedError)

__all__ = ["SolveRequest", "RequestQueue"]

_REQ_IDS = itertools.count(1)


class SolveRequest:
    """One queued solve request: a source pair, a coalescing key, a
    deadline, and the future its :class:`RequestResult` lands on.

    ``eta_e``/``eta_o`` carry a leading column axis (a single source is
    promoted to a block of one by the daemon before queueing), so a
    request contributes ``nrhs`` columns to whichever batch it rides.
    """

    __slots__ = ("id", "key", "eta_e", "eta_o", "nrhs", "deadline",
                 "submitted_at", "future")

    def __init__(self, key, eta_e, eta_o, *, deadline: Optional[float],
                 submitted_at: float, future):
        self.id = next(_REQ_IDS)
        self.key = key
        self.eta_e = eta_e
        self.eta_o = eta_o
        self.nrhs = int(eta_e.shape[0])
        self.deadline = deadline
        self.submitted_at = submitted_at
        self.future = future

    def queued_stats(self, now: float, depth: int) -> dict:
        """Partial accounting for a request that never ran."""
        return {
            "request_id": self.id,
            "nrhs": self.nrhs,
            "queued_s": now - self.submitted_at,
            "deadline_s": self.deadline,
            "queue_depth": depth,
        }


class RequestQueue:
    """Thread-safe per-key FIFO with batching/admission policy applied.

    The dispatcher blocks in :meth:`wait_ready`, which returns a
    ``(key, requests)`` batch when one is due — a key is due when its
    queued columns can fill ``max_block``, or when its oldest request
    has lingered past ``linger_s`` — after first failing every request
    whose deadline passed while queued (their futures get a
    :class:`~repro.serving.policy.RequestTimeoutError` carrying partial
    stats).  Batches never split a request: a request's columns always
    land in one solve, so its results come from one executable.
    """

    def __init__(self, batching: BatchingPolicy,
                 admission: AdmissionPolicy, *, clock=time.monotonic):
        self.batching = batching
        self.admission = admission
        self.clock = clock
        self.cond = threading.Condition()
        self._pending: Dict[object, deque] = {}
        self._depth = 0

    @property
    def depth(self) -> int:
        return self._depth

    # --- producer side ------------------------------------------------

    def submit(self, request: SolveRequest) -> None:
        """Enqueue, applying admission control; wakes the dispatcher."""
        with self.cond:
            if self._depth >= self.admission.max_queue_depth:
                raise ShedError(
                    f"queue at bounded depth "
                    f"{self.admission.max_queue_depth}; request shed")
            self._pending.setdefault(request.key, deque()).append(
                request)
            self._depth += 1
            self.cond.notify_all()

    # --- dispatcher side ----------------------------------------------

    def _expire_locked(self, now: float) -> List[SolveRequest]:
        expired = []
        for key in list(self._pending):
            dq = self._pending[key]
            keep = deque()
            for r in dq:
                if r.deadline is not None and r.deadline <= now:
                    expired.append(r)
                else:
                    keep.append(r)
            if len(keep) != len(dq):
                if keep:
                    self._pending[key] = keep
                else:
                    del self._pending[key]
        self._depth -= len(expired)
        return expired

    def _pop_batch_locked(self, key) -> List[SolveRequest]:
        dq = self._pending[key]
        batch, cols = [], 0
        while dq and cols + dq[0].nrhs <= self.batching.max_block:
            r = dq.popleft()
            batch.append(r)
            cols += r.nrhs
        if not dq:
            del self._pending[key]
        self._depth -= len(batch)
        return batch

    def _due_key_locked(self, now: float):
        """The due key with the oldest head request, and the earliest
        future instant anything becomes due (for the wait timeout)."""
        due_key, due_at, next_due = None, None, None
        for key, dq in self._pending.items():
            cols = 0
            for r in dq:
                cols += r.nrhs
                if cols >= self.batching.max_block:
                    break
            head = dq[0].submitted_at
            at = head if cols >= self.batching.max_block \
                else head + self.batching.linger_s
            if at <= now:
                if due_key is None or head < due_at:
                    due_key, due_at = key, head
            elif next_due is None or at < next_due:
                next_due = at
            for r in dq:
                if r.deadline is not None and (
                        next_due is None or r.deadline < next_due):
                    next_due = r.deadline
        return due_key, next_due

    def wait_ready(self, *, stop_event: threading.Event,
                   poll_s: float = 0.05
                   ) -> Optional[Tuple[object, List[SolveRequest]]]:
        """Block until a batch is due (or ``stop_event`` is set and the
        queue is empty — the graceful-drain exit).  Expired requests
        are failed here, on the dispatcher thread, so producers never
        observe a half-timed-out queue."""
        with self.cond:
            while True:
                now = self.clock()
                for r in self._expire_locked(now):
                    r.future.set_exception(RequestTimeoutError(
                        f"request {r.id} expired after "
                        f"{now - r.submitted_at:.3f}s in queue",
                        r.queued_stats(now, self._depth)))
                due_key, next_due = self._due_key_locked(now)
                if due_key is not None:
                    return due_key, self._pop_batch_locked(due_key)
                if stop_event.is_set() and not self._pending:
                    return None
                timeout = poll_s if next_due is None \
                    else max(1e-4, min(next_due - now, poll_s))
                self.cond.wait(timeout)

    def fail_all(self, exc: Exception) -> int:
        """Fail every queued request (hard shutdown, not drain)."""
        with self.cond:
            n = 0
            for dq in self._pending.values():
                for r in dq:
                    r.future.set_exception(exc)
                    n += 1
            self._pending.clear()
            self._depth = 0
            return n
