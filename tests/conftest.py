import jax
import jax.numpy as jnp
import pytest

from repro.core import evenodd, su3


@pytest.fixture(scope="session")
def small_lattice():
    """(U, psi, kappa) on a 4x4x4x8 lattice, complex64."""
    shape = (4, 4, 4, 8)
    U = su3.random_gauge(jax.random.PRNGKey(2), shape)
    k1, k2 = jax.random.PRNGKey(3), jax.random.PRNGKey(4)
    psi = (jax.random.normal(k1, (*shape, 4, 3))
           + 1j * jax.random.normal(k2, (*shape, 4, 3))
           ).astype(jnp.complex64)
    return U, psi, 0.13


@pytest.fixture(scope="session")
def small_eo(small_lattice):
    U, psi, kappa = small_lattice
    e, o = evenodd.pack(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e, o, kappa


def build_small(name, **over):
    """Reduced config of an assigned architecture for smoke tests."""
    from repro import configs

    cfg = configs.get(name)
    small = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                 d_ff=128, vocab_size=128, head_dim=16)
    if cfg.n_kv_heads < cfg.n_heads:
        small["n_kv_heads"] = 2
    if cfg.attention == "mla":
        small.update(q_lora_rank=32, kv_lora_rank=24, qk_nope_dim=8,
                     qk_rope_dim=8, v_head_dim=8, head_dim=16)
    if cfg.moe:
        small.update(n_experts=4, moe_d_ff=64, capacity_factor=2.0)
    if cfg.attention == "none":
        small.update(rwkv_head_dim=16, rwkv_decay_lora=8)
    if cfg.attention == "hybrid":
        small.update(ssm_state=4, sliding_window=64, n_heads=5,
                     n_kv_heads=5, d_model=80, head_dim=16)
    if cfg.encoder_layers:
        small.update(encoder_layers=2)
    if cfg.num_prefix_embeds:
        small.update(num_prefix_embeds=6)
    small.update(over)
    return cfg.scaled(**small)
