"""Deliberate R1 violations (linter test fixture — never imported).

This directory is excluded from the real gate (SKIP_DIR_NAMES); the
tests feed these sources to the rules directly, with synthetic paths.
"""
from jax.experimental.shard_map import shard_map          # line 6: R1
from jax.experimental import pallas as pl                 # line 7: R1 (outside kernels/)

import jax


def build(mesh):
    mesh2 = jax.make_mesh((2,), ("x",))                   # line 13: R1
    params = pl.tpu.TPUCompilerParams()                   # line 14: R1
    return shard_map, mesh, mesh2, params


def sizes():
    return jax.lax.axis_size("x")                         # line 19: R1
