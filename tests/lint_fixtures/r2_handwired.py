"""Deliberate R2 violations (linter test fixture — never imported).

Tested with a synthetic ``src/repro/...`` path outside the
implementation zone, where hand-wiring operators is an error.
"""
from repro.kernels import ops                             # line 6: R2
from repro.core.evenodd import apply_dhat                 # line 7: R2

from repro.core import evenodd
from repro.core.evenodd import pack                       # codec: fine


def run(u_e_p, u_o_p, src, psi_e, psi_o, kappa):
    out = ops.apply_dhat_planar_any(u_e_p, u_o_p, src, kappa)
    a = apply_dhat(u_e_p, u_o_p, psi_e, kappa)
    b = evenodd.hop_oe(u_e_p, u_o_p, psi_e)               # line 16: R2
    # repro-lint: allow[R2] fixture-waived call, asserted waived in tests
    c = evenodd.hop_eo(u_e_p, u_o_p, psi_o)
    return out, a, b, c, pack
