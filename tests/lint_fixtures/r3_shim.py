"""Deliberate R3 violations (linter test fixture — never imported)."""
from repro.core.solver import solve_wilson_eo             # line 2: R3

from repro.core import solver


def run(Ue, Uo, e, o, kappa):
    xe, xo, res = solve_wilson_eo(Ue, Uo, e, o, kappa)    # line 8: R3 (Name)
    return solver.solve_wilson_eo(Ue, Uo, xe, xo, kappa)  # line 9: R3 (Attribute)


def solve_wilson_eo(Ue, Uo, e, o, kappa):                 # line 12: R3 (Def)
    return None
