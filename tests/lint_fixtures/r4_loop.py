"""Deliberate R4 violations (linter test fixture — never imported).

Tested with the synthetic path ``src/repro/core/solver.py`` — R4 only
looks there.
"""
import jax
import jax.numpy as jnp


def solve(bops, v, kappa):
    def cond(state):
        k, x = state
        return k < 10

    def body(state):
        k, x = state
        psi = bops.from_domain(x)                         # line 17: R4
        x = bops.to_domain(jax.device_put(psi))           # line 18: R4 (x2)
        return k + 1, x

    def clean_body(state):
        k, x = state
        return k + 1, bops.apply_dhat_native(x, kappa)

    state = jax.lax.while_loop(cond, body, (0, v))
    state = jax.lax.while_loop(cond, clean_body, state)
    # Inline-lambda cond with a placement call is also caught.
    state = jax.lax.while_loop(
        lambda s: jnp.any(jax.device_put(s[1]) > 0),      # line 29: R4
        clean_body, state)
    return state
