"""Tests for the repro.analysis gate: lint rules, jaxpr analyzers,
baseline mechanics, dead-seed audit, and the CLI runner.

Two kinds of coverage, per the gate's contract:

* the healthy tree passes every check (the gate lands green with an
  EMPTY baseline), and
* every rule/check demonstrably FAILS on a seeded violation — lint
  rules via the deliberate-violation fixtures in
  ``tests/lint_fixtures/`` (excluded from the real scan), jaxpr checks
  via their injectable overrides (``ops_transform`` / ``apply_fn`` /
  ``policy_fn`` / ``session_factory``).
"""
import dataclasses
import json
import pathlib
import textwrap

import jax.numpy as jnp
import pytest

from repro import analysis, api
from repro.analysis import deadcode, jaxpr_checks, lint
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.findings import (Finding, load_baseline,
                                     split_baselined, write_baseline)
from repro.analysis.rules import ALL_RULES
from repro.analysis.rules import (r1_compat, r2_registry, r3_api,
                                  r4_loop_hygiene)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = pathlib.Path(__file__).resolve().parent / "lint_fixtures"


def _rule_hits(rule_mod, synthetic_path, source):
    """Run one rule on fixture source under a pretend repo path ->
    sorted (rule, line) pairs (waived findings come back as None and
    are dropped, same as the engine does)."""
    ctx = lint.LintContext(synthetic_path, source)
    return sorted((f.rule, f.line) for f in rule_mod.check(ctx)
                  if f is not None)


def _fixture(name):
    return (FIXTURES / name).read_text()


# --- the gate is green on the healthy tree ---------------------------


def test_lint_clean_on_repo():
    findings = lint.run_lint(REPO_ROOT)
    assert findings == [], "\n" + "\n".join(f.render() for f in findings)


def test_fixtures_are_excluded_from_the_real_scan():
    scanned = {p.as_posix() for p in lint.iter_source_files(REPO_ROOT)}
    assert not any("lint_fixtures" in p for p in scanned)
    # ... but the fixture dir itself is populated.
    assert sorted(p.name for p in FIXTURES.glob("r*_*.py")) == [
        "r1_drifted.py", "r2_handwired.py", "r3_shim.py", "r4_loop.py"]


# --- R1: drifted JAX APIs only via repro.compat ----------------------


def test_r1_fires_on_fixture():
    hits = _rule_hits(r1_compat, "src/repro/launch/somefile.py",
                      _fixture("r1_drifted.py"))
    # line 6 flags twice: the drifted module AND the drifted symbol.
    assert hits == [("R1", 6), ("R1", 6), ("R1", 7), ("R1", 13),
                    ("R1", 14), ("R1", 19)]


def test_r1_kernels_may_import_pallas_but_not_compiler_params():
    hits = _rule_hits(r1_compat, "src/repro/kernels/somefile.py",
                      _fixture("r1_drifted.py"))
    # The plain pallas import (line 7) is allowed in kernels/; the
    # drifted APIs (shard_map, make_mesh, TPUCompilerParams, axis_size)
    # still are not.
    assert ("R1", 7) not in hits
    assert [h for h in hits if h[1] in (13, 14, 19)] == [
        ("R1", 13), ("R1", 14), ("R1", 19)]


def test_r1_compat_module_is_exempt():
    assert _rule_hits(r1_compat, "src/repro/compat.py",
                      _fixture("r1_drifted.py")) == []


# --- R2: operators only via the registry -----------------------------


def test_r2_fires_on_fixture():
    hits = _rule_hits(r2_registry, "src/repro/launch/somefile.py",
                      _fixture("r2_handwired.py"))
    # 6: import of repro.kernels.ops; 7: operator imported by name;
    # 14: ops.apply_dhat_planar_any through the module alias;
    # 16: evenodd.hop_oe.  Line 18 is waived, line 10 (pack) is a
    # codec and never flagged.
    assert hits == [("R2", 6), ("R2", 7), ("R2", 14), ("R2", 16)]


def test_r2_waiver_covers_annotated_and_next_line():
    src = _fixture("r2_handwired.py")
    hits = _rule_hits(r2_registry, "src/repro/launch/somefile.py", src)
    assert ("R2", 18) not in hits
    # Removing the waiver comment resurfaces the finding (one line up,
    # since the file shrank by one line).
    lines = src.splitlines()
    del lines[16]   # the "# repro-lint: allow[R2] ..." line
    hits = _rule_hits(r2_registry, "src/repro/launch/somefile.py",
                      "\n".join(lines))
    assert ("R2", 17) in hits


def test_r2_out_of_scope_paths_are_free():
    src = _fixture("r2_handwired.py")
    for path in ("tests/test_x.py", "benchmarks/bench_x.py",
                 "src/repro/kernels/inner.py", "src/repro/core/x.py",
                 "src/repro/analysis/probe.py"):
        assert _rule_hits(r2_registry, path, src) == []


# --- R3: solve_wilson_eo must not exist ------------------------------


def test_r3_fires_on_fixture():
    hits = _rule_hits(r3_api, "tests/test_other.py",
                      _fixture("r3_shim.py"))
    # import@2, Name call@8, Attribute call@9, re-definition@12.
    assert hits == [("R3", 2), ("R3", 8), ("R3", 9), ("R3", 12)]


def test_r3_formerly_exempt_paths_now_fire():
    """PR 7 deleted the shim at its removal horizon; the old
    containment allowlist (shim home, core re-export, designated parity
    tests) is gone with it — the rule fires everywhere now."""
    src = _fixture("r3_shim.py")
    assert not hasattr(r3_api, "ALLOWED_PATHS")
    for path in ("src/repro/core/solver.py",
                 "src/repro/core/__init__.py",
                 "tests/test_api.py"):
        assert _rule_hits(r3_api, path, src) == [
            ("R3", 2), ("R3", 8), ("R3", 9), ("R3", 12)]


# --- R4: while_loop body hygiene -------------------------------------


def test_r4_fires_on_fixture():
    hits = _rule_hits(r4_loop_hygiene, "src/repro/core/solver.py",
                      _fixture("r4_loop.py"))
    # body(): from_domain@17, device_put@18, to_domain@18; the inline
    # lambda cond: device_put@29.  clean_body never flags.
    assert hits == [("R4", 17), ("R4", 18), ("R4", 18), ("R4", 29)]


def test_r4_only_looks_at_solver_py():
    assert _rule_hits(r4_loop_hygiene, "src/repro/launch/solve.py",
                      _fixture("r4_loop.py")) == []


def test_r4_clean_on_real_solver():
    solver_py = REPO_ROOT / "src" / "repro" / "core" / "solver.py"
    assert _rule_hits(r4_loop_hygiene, "src/repro/core/solver.py",
                      solver_py.read_text()) == []


# --- findings / baseline mechanics -----------------------------------


def test_finding_key_and_render():
    f = Finding(rule="R2", path="src/repro/x.py", line=7, message="m")
    assert f.key() == "R2:src/repro/x.py:7"
    assert f.render() == "src/repro/x.py:7: [R2] m"


def test_baseline_roundtrip_and_split(tmp_path):
    f1 = Finding("R1", "a.py", 1, "one")
    f2 = Finding("R2", "b.py", 2, "two")
    path = tmp_path / "baseline.json"
    write_baseline(path, [f2, f1])
    keys = load_baseline(path)
    assert keys == [f1.key(), f2.key()]

    # f1 stays grandfathered, f3 is fresh, f2's key went stale.
    f3 = Finding("R3", "c.py", 3, "three")
    fresh, old, stale = split_baselined([f1, f3], keys)
    assert fresh == [f3]
    assert old == [f1]
    assert stale == [f2.key()]


def test_baseline_accepts_bare_key_list(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps(["R1:a.py:1"]))
    assert load_baseline(path) == ["R1:a.py:1"]


# --- the CLI runner --------------------------------------------------


def _mini_repo(tmp_path, bad=True):
    """A throwaway repo root with one (optionally violating) module."""
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    body = ("from jax.experimental.shard_map import shard_map\n"
            if bad else "X = 1\n")
    (pkg / "mod.py").write_text(body)
    return tmp_path


def test_runner_exits_zero_on_clean_tree(tmp_path, capsys):
    root = _mini_repo(tmp_path, bad=False)
    assert analysis_main(["--root", str(root), "--lint-only"]) == 0
    assert "findings: none" in capsys.readouterr().out


def test_runner_fails_on_fresh_violation(tmp_path, capsys):
    root = _mini_repo(tmp_path, bad=True)
    assert analysis_main(["--root", str(root), "--lint-only"]) == 1
    out = capsys.readouterr().out
    assert "[R1]" in out and "src/repro/mod.py:1" in out


def test_runner_baseline_grandfathers_and_reports_stale(
        tmp_path, capsys):
    root = _mini_repo(tmp_path, bad=True)
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(
        ["R1:src/repro/mod.py:1",          # matches both line-1 findings
         "R9:gone.py:1"]))                 # stale
    assert analysis_main(["--root", str(root), "--lint-only",
                          "--baseline", str(baseline)]) == 0
    out = capsys.readouterr().out
    assert "grandfathered" in out
    assert "stale baseline keys" in out and "R9:gone.py:1" in out


def test_runner_write_baseline_then_gate_green(tmp_path):
    root = _mini_repo(tmp_path, bad=True)
    baseline = tmp_path / "baseline.json"
    assert analysis_main(["--root", str(root), "--lint-only",
                          "--write-baseline", str(baseline)]) == 0
    assert analysis_main(["--root", str(root), "--lint-only",
                          "--baseline", str(baseline)]) == 0


def test_runner_json_artifact(tmp_path):
    root = _mini_repo(tmp_path, bad=True)
    out = tmp_path / "findings.json"
    assert analysis_main(["--root", str(root), "--lint-only",
                          "--json", str(out)]) == 1
    payload = json.loads(out.read_text())
    assert {f["rule"] for f in payload["fresh"]} == {"R1"}
    assert payload["grandfathered"] == []
    assert payload["stale_baseline_keys"] == []


def test_runner_checks_subset(tmp_path):
    root = _mini_repo(tmp_path, bad=True)
    # Only R3 selected: the R1 violation is invisible, gate passes.
    assert analysis_main(["--root", str(root), "--checks", "R3"]) == 0
    # R1 selected: fails.
    assert analysis_main(["--root", str(root), "--checks", "R1"]) == 1
    with pytest.raises(SystemExit):
        analysis_main(["--root", str(root), "--checks", "R1,NOPE"])


def test_runner_list_rules(capsys):
    assert analysis_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("R1", "R2", "R3", "R4", "J1", "J2", "J3", "J4", "J5",
                "J6"):
        assert rid in out


def test_package_exports():
    assert analysis.Finding is Finding
    assert {r.RULE_ID for r in ALL_RULES} == {"R1", "R2", "R3", "R4"}


# --- jaxpr analyzers: pass on the healthy tree -----------------------


ROOT = str(REPO_ROOT)


def test_j1_conversion_free_every_backend():
    # The full registry — the invariant is per-backend, so run them all
    # (this is the expensive one: one trace per backend).
    findings = jaxpr_checks.check_conversion_free(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_j2_pallas_counts_healthy():
    findings = jaxpr_checks.check_pallas_counts(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_j3_vmem_model_healthy():
    findings = jaxpr_checks.check_vmem_model(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_j4_retrace_budget_healthy():
    findings = jaxpr_checks.check_retrace_budget(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_j5_overlap_interleave_healthy():
    findings = jaxpr_checks.check_overlap_interleave(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_j6_nonfinite_guard_healthy():
    findings = jaxpr_checks.check_nonfinite_guard(ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


# --- jaxpr analyzers: fail on seeded violations ----------------------


def test_j1_catches_precision_roundtrip():
    # Seed the violation J1 exists for: an operator wrapper that
    # round-trips the iterate through a narrower precision each
    # application (downcast + upcast; the downcast is not exempt).
    # Scoped x64 so the complex128 leg of the round-trip is real.
    from jax.experimental import enable_x64

    def sabotage(bops):
        inner = bops.apply_dhat_native

        def lossy(v, kappa):
            w = inner(v, kappa)
            other = (jnp.complex64 if w.dtype == jnp.complex128
                     else jnp.complex128)
            return w.astype(other).astype(w.dtype)

        return dataclasses.replace(bops, apply_dhat_native=lossy)

    with enable_x64():
        findings = jaxpr_checks.check_conversion_free(
            ROOT, backends=["jnp"], ops_transform=sabotage)
    assert len(findings) == 1
    assert findings[0].rule == "J1"
    assert "convert_element_type" in findings[0].message
    assert findings[0].path == "src/repro/core/solver.py"
    assert findings[0].line > 1   # anchored at make_native_solve


def test_j2_catches_double_launch():
    from repro.kernels import ops as kops

    def double(u_e_p, u_o_p, src_p, kappa, fused):
        a = kops.apply_dhat_planar_any(
            u_e_p, u_o_p, src_p, kappa, fused=fused, interpret=True)
        b = kops.apply_dhat_planar_any(
            u_e_p, u_o_p, src_p, kappa, fused=fused, interpret=True)
        return a + b

    findings = jaxpr_checks.check_pallas_counts(
        ROOT, apply_fn=double, expected={"resident": 1},
        compressions=("none",))
    assert [f.rule for f in findings] == ["J2"]
    assert "expected exactly 1" in findings[0].message


def test_j2_catches_wrong_expectation():
    # Equivalent seeding from the other side: the healthy kernel vs a
    # wrong declared count — it must fire on every compression axis.
    findings = jaxpr_checks.check_pallas_counts(
        ROOT, expected={"unfused": 1})
    assert [f.rule for f in findings] == ["J2"] * 3
    assert {c for c in ("'none'", "'two_row'", "'minimal'")
            if any(c in f.message for f in findings)} \
        == {"'none'", "'two_row'", "'minimal'"}


def test_j3_catches_lying_policy():
    findings = jaxpr_checks.check_vmem_model(
        ROOT, policy_fn=lambda shape, dtype=jnp.float32: "stream")
    assert findings and all(f.rule == "J3" for f in findings)
    assert any("fused_dhat_policy" in f.message for f in findings)


def test_j3_catches_wrong_ring_model():
    from repro.kernels import wilson_stencil as ws

    def bloated_ring(shape, dtype=jnp.float32, window=None):
        return 2 * ws.stream_ring_bytes(shape, dtype)

    findings = jaxpr_checks.check_vmem_model(ROOT, ring_fn=bloated_ring)
    assert findings and all(f.rule == "J3" for f in findings)
    assert any("stream_ring_bytes" in f.message for f in findings)


def test_j3_catches_wrong_limit():
    # Shrinking the declared budget makes fits/policy disagree with the
    # real estimators at the boundary cases.
    findings = jaxpr_checks.check_vmem_model(
        ROOT, limit_bytes=1 << 20)
    assert findings and all(f.rule == "J3" for f in findings)


def test_j4_catches_cache_defeat():
    Ue, Uo, e, o = jaxpr_checks._tiny_eo()

    def leaky_factory():
        D = api.WilsonMatrix.bind(Ue, Uo, jaxpr_checks._KAPPA,
                                  backend="jnp")
        session = api.SolveSession(D, api.SolveSpec(
            method="cgnr", tol=1e-5, max_iters=25))
        inner = session.solve

        def solve(ee, oo, spec=None):
            session._cache.clear()   # the retrace leak J4 exists for
            return inner(ee, oo, spec)

        session.solve = solve
        return session

    findings = jaxpr_checks.check_retrace_budget(
        ROOT, session_factory=leaky_factory)
    rules = {f.rule for f in findings}
    assert rules == {"J4"}
    assert any("traces" in f.message for f in findings)


def test_j5_catches_serialized_schedule():
    # The fused schedule is the built-in violation: each of its kernels
    # consumes every face exchanged before it (0 faces left in flight),
    # so the per-kernel overlap requirement fails for both hops.
    findings = jaxpr_checks.check_overlap_interleave(ROOT, overlap="fused")
    rules = [f.rule for f in findings]
    assert rules and set(rules) == {"J5"}
    assert any("serialized behind the halo exchange" in f.message
               for f in findings)


def test_j6_catches_guardless_solver():
    # Seed the violation J6 exists for: the same Krylov trace with the
    # divergence guard compiled out (guard=False) — every method, both
    # pipelines, must be flagged as structurally unguarded.
    import jax
    from repro.core import solver

    n = 24
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (n, n), dtype=jnp.float32)
    A = G @ G.T + n * jnp.eye(n, dtype=jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                          dtype=jnp.float32)

    def unguarded(method, batched):
        rhs = jnp.stack([b, b]) if batched else b
        op = (lambda v: v @ A.T) if batched else (lambda v: A @ v)
        return solver._run_krylov(
            method, op, op, rhs, tol=1e-6, max_iters=8,
            recompute_every=0, batched=batched, guard=False)

    findings = jaxpr_checks.check_nonfinite_guard(ROOT, run_fn=unguarded)
    assert len(findings) == 2 * len(solver.KRYLOV_METHODS)
    assert {f.rule for f in findings} == {"J6"}
    assert all("is_finite" in f.message for f in findings)
    assert findings[0].path == "src/repro/core/solver.py"
    assert findings[0].line > 1   # anchored at _run_krylov


def test_run_jaxpr_checks_validates_ids():
    with pytest.raises(ValueError, match="unknown jaxpr check"):
        jaxpr_checks.run_jaxpr_checks(ROOT, checks=["J9"])


# --- dead-seed audit -------------------------------------------------


def test_dead_code_report_shape():
    report = deadcode.dead_code_report(ROOT)
    assert report["modules_live"] <= report["modules_total"]
    dormant = {d["module"]: d for d in report["dormant"]}
    # The product surface is live ...
    assert "repro.api" not in dormant
    assert "repro.core.solver" not in dormant
    assert "repro.analysis.jaxpr_checks" not in dormant
    # ... the annotated harvest targets are dormant-on-purpose ...
    assert dormant["repro.launch.train"]["intentional"]
    assert "ROADMAP item 5" in dormant["repro.launch.train"]["note"]
    # ... and nothing dormant is unaccounted for: every non-intentional
    # entry is part of the generic LLM seed scaffold.
    for d in report["dormant"]:
        if not d["intentional"]:
            assert d["module"].startswith(("repro.configs.",
                                           "repro.models.",
                                           "repro.optim.")), d


def test_dead_code_sees_function_local_imports(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "api.py").write_text(textwrap.dedent("""
        def f():
            from repro import helper
            return helper
    """))
    (pkg / "helper.py").write_text("X = 1\n")
    (pkg / "orphan.py").write_text("Y = 2\n")
    report = deadcode.dead_code_report(str(tmp_path))
    names = {d["module"] for d in report["dormant"]}
    assert "repro.helper" not in names
    assert "repro.orphan" in names


def test_format_dead_code_report_only():
    report = deadcode.dead_code_report(ROOT)
    text = deadcode.format_dead_code(report)
    assert "report-only" in text
