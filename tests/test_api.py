"""Public API (`repro.api`): spec validation, the bind-once
WilsonMatrix pytree (flatten/unflatten, jit-argument no-retrace,
rebuild-from-leaves), and SolveSession compiled-solve caching (exactly
one trace for N same-shape solves, per backend)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, backends
from repro.core import evenodd, solver, su3

KAPPA = 0.13
SHAPE = (4, 4, 4, 8)


def _interpret(name):
    return (True if name.startswith("pallas")
            and jax.default_backend() != "tpu" else None)


def _bind_matrix(name, Ue, Uo, kappa=KAPPA):
    return api.WilsonMatrix.bind(
        Ue, Uo, kappa, backend=api.BackendSpec(name,
                                               interpret=_interpret(name)))


def make_eo(shape=SHAPE, seed=0, nrhs=None):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    bshape = (() if nrhs is None else (nrhs,)) + (*shape, 4, 3)
    psi = (jax.random.normal(k, bshape)
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    bshape)).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    if nrhs is None:
        e, o = evenodd.pack(psi)
    else:
        e, o = jax.vmap(evenodd.pack)(psi)
    return Ue, Uo, e, o


# --- specs ------------------------------------------------------------


def test_lattice_spec_validation():
    lat = api.LatticeSpec((4, 4, 4, 8))
    assert (lat.T, lat.Z, lat.Y, lat.X, lat.Xh) == (4, 4, 4, 8, 4)
    assert lat.spinor_eo_shape() == (4, 4, 4, 4, 4, 3)
    assert lat.spinor_eo_shape(nrhs=3) == (3, 4, 4, 4, 4, 4, 3)
    with pytest.raises(ValueError, match="4 positive ints"):
        api.LatticeSpec((4, 4, 8))
    with pytest.raises(ValueError, match="must be even"):
        api.LatticeSpec((4, 4, 4, 7))
    Ue, _, _, _ = make_eo()
    assert api.LatticeSpec.from_eo_gauge(Ue) == api.LatticeSpec(SHAPE)


def test_solve_spec_method_choices_derived_from_solver():
    # The satellite contract: the choice list is derived, not duplicated.
    assert api.SolveSpec.METHODS is solver.KRYLOV_METHODS
    assert "cg" in api.SolveSpec.METHODS
    with pytest.raises(ValueError, match="'cg', 'cgnr', 'bicgstab'"):
        api.SolveSpec(method="sor")


def test_solve_spec_validation():
    with pytest.raises(ValueError, match="tol"):
        api.SolveSpec(tol=0.0)
    with pytest.raises(ValueError, match="nrhs"):
        api.SolveSpec(nrhs=0)
    with pytest.raises(ValueError, match="inner_dtype"):
        api.SolveSpec(inner_dtype="f8")
    with pytest.raises(ValueError, match="recompute_every"):
        api.SolveSpec(recompute_every=-1)
    # frozen + hashable: usable as a cache key
    assert hash(api.SolveSpec()) == hash(api.SolveSpec())


def test_backend_spec_validation_against_capabilities():
    with pytest.raises(ValueError, match="unknown backend 'nope'"):
        api.BackendSpec("nope").validated()
    # jnp declares no dtype / interpret knobs
    with pytest.raises(ValueError, match="no compute dtype"):
        api.BackendSpec("jnp", dtype="f32").validated()
    with pytest.raises(ValueError, match="no interpret mode"):
        api.BackendSpec("jnp", interpret=True).validated()
    with pytest.raises(ValueError, match="unknown compute dtype"):
        api.BackendSpec("pallas", dtype="f8")
    ok = api.BackendSpec("pallas_fused", dtype="bfloat16",
                         interpret=True).validated()
    assert ok.dtype == "bf16"      # normalized spelling
    assert ok.factory_opts() == {"dtype": jnp.bfloat16, "interpret": True}
    # "auto" resolves to a concrete registered name
    assert api.BackendSpec("auto").validated().name in \
        backends.available_backends()


def test_available_backends_sorted_and_backend_info():
    names = backends.available_backends()
    assert names == sorted(names)
    for name in names:
        caps = backends.backend_info(name)
        assert caps.name == name
        assert caps.domain in ("complex", "planar", "planar_sharded")
    assert backends.backend_info("pallas_fused").batched_kernels
    assert "auto" in backends.backend_info("pallas_fused").policies
    assert not backends.backend_info("jnp").batched_kernels
    with pytest.raises(ValueError, match="backend_info"):
        backends.backend_info("nope")


# --- WilsonMatrix -----------------------------------------------------


def test_wilson_matrix_applies_match_reference():
    Ue, Uo, e, _ = make_eo(seed=2)
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    D = _bind_matrix("pallas_fused", Ue, Uo)
    np.testing.assert_allclose(
        np.asarray(D(e)), np.asarray(ref.apply_dhat(e, KAPPA)),
        atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(D.dagger(e)),
        np.asarray(ref.apply_dhat_dagger(e, KAPPA)), atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(D.normal(e)),
        np.asarray(ref.apply_dhat_dagger(ref.apply_dhat(e, KAPPA),
                                         KAPPA)), atol=5e-5)


def test_wilson_matrix_batched_apply():
    Ue, Uo, e, _ = make_eo(seed=3, nrhs=2)
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    D = _bind_matrix("pallas_fused", Ue, Uo)
    want = jnp.stack([ref.apply_dhat(e[n], KAPPA) for n in range(2)])
    np.testing.assert_allclose(np.asarray(D(e)), np.asarray(want),
                               atol=5e-5)


def test_wilson_matrix_pytree_flatten_unflatten():
    Ue, Uo, e, _ = make_eo(seed=4)
    D = _bind_matrix("pallas_fused", Ue, Uo)
    leaves, treedef = jax.tree_util.tree_flatten(D)
    assert len(leaves) == 2          # planar gauge halves are the leaves
    assert all(l.dtype == jnp.float32 for l in leaves)
    D2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(D(e)), np.asarray(D2(e)))
    # aux data (specs) survive
    assert D2.backend == D.backend and D2.lattice == D.lattice
    assert D2.kappa == D.kappa


def test_wilson_matrix_rebuilds_ops_from_mapped_leaves():
    """tree_map produces a matrix whose operators see the NEW leaves:
    zeroed gauge turns Dhat into the identity."""
    Ue, Uo, e, _ = make_eo(seed=5)
    D = _bind_matrix("jnp", Ue, Uo)
    D0 = jax.tree_util.tree_map(jnp.zeros_like, D)
    np.testing.assert_allclose(np.asarray(D0(e)), np.asarray(e),
                               atol=1e-6)


def test_wilson_matrix_jit_argument_no_retrace():
    """Two same-shape matrices share one jit cache entry, and the
    compiled fn reads the gauge from the argument (not a baked
    constant)."""
    Ue, Uo, e, _ = make_eo(seed=6)
    U2e, U2o, _, _ = make_eo(seed=16)
    D1 = _bind_matrix("jnp", Ue, Uo)
    D2 = _bind_matrix("jnp", U2e, U2o)
    traces = []

    @jax.jit
    def f(m, psi):
        traces.append(1)
        return m(psi)

    out1 = f(D1, e)
    out2 = f(D2, e)
    assert len(traces) == 1, f"retraced: {len(traces)}"
    ref2 = backends.make_wilson_ops("jnp", U2e, U2o)
    np.testing.assert_allclose(
        np.asarray(out2), np.asarray(ref2.apply_dhat(e, KAPPA)),
        atol=1e-5)
    # and the two results differ (different gauges really were used)
    assert float(jnp.max(jnp.abs(out1 - out2))) > 1e-3


def test_wilson_matrix_composes_under_vmap():
    Ue, Uo, e, _ = make_eo(seed=7, nrhs=3)
    D = _bind_matrix("jnp", Ue, Uo)
    got = jax.vmap(lambda p: D(p))(e)
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    want = jnp.stack([ref.apply_dhat(e[n], KAPPA) for n in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_wilson_matrix_from_ops_wraps_bound_backend():
    Ue, Uo, e, _ = make_eo(seed=8)
    bops = backends.make_wilson_ops("jnp", Ue, Uo)
    D = api.WilsonMatrix.from_ops(bops, KAPPA, gauge=(Ue, Uo))
    np.testing.assert_array_equal(
        np.asarray(D(e)), np.asarray(bops.apply_dhat(e, KAPPA)))
    # gauge round-trip for refined solves (c128 needs x64 enabled)
    from jax.experimental import enable_x64
    with enable_x64():
        U64e, _ = D.gauge_complex()
        assert U64e.dtype == jnp.complex128


def test_wilson_matrix_gauge_complex_from_planar_leaves():
    Ue, Uo, _, _ = make_eo(seed=9)
    D = _bind_matrix("pallas_fused", Ue, Uo)
    U64e, U64o = D.gauge_complex()
    # planar leaves are f32: reconstruction is exact at f32 precision
    np.testing.assert_allclose(np.asarray(U64e),
                               np.asarray(Ue.astype(jnp.complex128)),
                               atol=1e-7)
    # an unflattened matrix loses the exact-gauge ref and reconstructs
    leaves, treedef = jax.tree_util.tree_flatten(D)
    D2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_allclose(np.asarray(D2.gauge_complex()[0]),
                               np.asarray(U64e), atol=1e-7)


def test_wilson_matrix_gauge_complex_exact_despite_bf16_leaves():
    """Refined solves must target the TRUE gauge: a bf16-bound matrix
    keeps the exact complex gauge for gauge_complex() even though its
    planar leaves are rounded to ~3 significant digits."""
    Ue, Uo, _, _ = make_eo(seed=9)
    D = api.WilsonMatrix.bind(
        Ue, Uo, KAPPA, backend=api.BackendSpec(
            "pallas_fused", dtype="bf16", interpret=_interpret("pallas")))
    leaves = jax.tree_util.tree_flatten(D)[0]
    assert leaves[0].dtype == jnp.bfloat16
    U64e, _ = D.gauge_complex()
    np.testing.assert_array_equal(
        np.asarray(U64e.astype(jnp.complex64)), np.asarray(Ue))


# --- SolveSession caching --------------------------------------------


@pytest.mark.parametrize("name", backends.available_backends())
def test_session_compiles_once_per_backend(name):
    """The acceptance criterion: N same-shape solves through one
    session trigger exactly ONE trace (per backend, interpret mode
    off-TPU)."""
    Ue, Uo, _, _ = make_eo(seed=10)
    session = api.SolveSession(
        _bind_matrix(name, Ue, Uo),
        api.SolveSpec(method="bicgstab", tol=1e-3, max_iters=25))
    n = 3
    for i in range(n):
        _, _, e, o = make_eo(seed=20 + i)
        xe, xo, res = session.solve(e, o)
        assert bool(jnp.all(jnp.isfinite(jnp.abs(xe))))
    st = session.stats()
    assert st["solves"] == n
    assert st["traces"] == 1, st
    assert st["cache_hits"] == n - 1 and st["cache_misses"] == 1, st
    (krow,) = st["keys"].values()
    assert krow["solves"] == n and krow["kind"] == "plain"
    assert krow["first_solve_s"] > 0
    assert krow["steady_state_s"] > 0


def test_session_new_key_per_shape_and_spec():
    Ue, Uo, e, o = make_eo(seed=11)
    _, _, eb, ob = make_eo(seed=11, nrhs=2)
    session = api.SolveSession(_bind_matrix("jnp", Ue, Uo))
    spec = api.SolveSpec(method="bicgstab", tol=1e-3, max_iters=25)
    session.solve(e, o, spec)
    session.solve(eb, ob, spec)                      # new shape (nrhs=2)
    session.solve(e, o, dataclasses.replace(spec, tol=1e-2))  # new spec
    session.solve(e, o, spec)                        # hit
    st = session.stats()
    assert st["cache_misses"] == 3 and st["cache_hits"] == 1, st
    assert st["traces"] == 3, st
    assert len(st["keys"]) == 3


def test_session_solution_correct():
    Ue, Uo, e, o = make_eo(seed=12)
    session = api.SolveSession(
        _bind_matrix("pallas_fused", Ue, Uo),
        api.SolveSpec(method="bicgstab", tol=1e-5))
    xe, xo, res = session.solve(e, o)
    assert bool(res.converged)
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    rhs = e + KAPPA * ref.hop_eo(o)
    rel = float(jnp.linalg.norm(rhs - ref.apply_dhat(xe, KAPPA))
                / jnp.linalg.norm(rhs))
    assert rel < 1e-4, rel
    # odd reconstruction: xi_o = eta_o + kappa H_oe xi_e
    np.testing.assert_allclose(
        np.asarray(xo), np.asarray(o + KAPPA * ref.hop_oe(xe)),
        atol=5e-5)


def test_session_cg_method_solves_normal_equations():
    Ue, Uo, e, o = make_eo(seed=13)
    session = api.SolveSession(
        _bind_matrix("jnp", Ue, Uo),
        api.SolveSpec(method="cg", tol=1e-6))
    xe, _, res = session.solve(e, o)
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    rhs = e + KAPPA * ref.hop_eo(o)
    rel = float(jnp.linalg.norm(rhs - ref.apply_dhat(xe, KAPPA))
                / jnp.linalg.norm(rhs))
    assert rel < 1e-4, rel


def test_session_shape_validation():
    Ue, Uo, e, o = make_eo(seed=14)
    session = api.SolveSession(_bind_matrix("jnp", Ue, Uo))
    with pytest.raises(ValueError, match="does not match lattice"):
        session.solve(e[:2], o[:2])
    with pytest.raises(ValueError, match="sources disagree"):
        session.solve(e, o[:2])
    with pytest.raises(ValueError, match="nrhs"):
        session.solve(e, o, api.SolveSpec(nrhs=4))


def test_session_requires_matrix():
    Ue, Uo, _, _ = make_eo(seed=15)
    bops = backends.make_wilson_ops("jnp", Ue, Uo)
    with pytest.raises(TypeError, match="WilsonMatrix"):
        api.SolveSession(bops)


def test_session_refined_solve_cached():
    """Mixed-precision refinement through the session: RefinedResult
    contract, correct to the f64 tolerance, one cache entry reused."""
    from jax.experimental import enable_x64

    with enable_x64():
        Ue, Uo, e, o = make_eo(seed=17)
        e, o = e.astype(jnp.complex128), o.astype(jnp.complex128)
        session = api.SolveSession(
            _bind_matrix("jnp", Ue, Uo),
            api.SolveSpec(method="cgnr", tol=1e-8, inner_dtype="f32"))
        xe, xo, res = session.solve(e, o)
        xe2, _, res2 = session.solve(e, o)
        assert bool(res.converged) and bool(res2.converged)
        assert res.f64_applies < 2 * int(jnp.max(res.iterations)) + 2
        U64e = Ue.astype(jnp.complex128)
        U64o = Uo.astype(jnp.complex128)
        ref = backends.make_wilson_ops("jnp", U64e, U64o)
        rhs = e + KAPPA * ref.hop_eo(o)
        rel = float(jnp.linalg.norm(rhs - ref.apply_dhat(xe, KAPPA))
                    / jnp.linalg.norm(rhs))
        assert rel <= 1e-8, rel
    st = session.stats()
    assert st["cache_hits"] == 1 and st["cache_misses"] == 1, st
    (krow,) = st["keys"].values()
    assert krow["kind"] == "refined" and krow["solves"] == 2


# --- one-shot convenience --------------------------------------------


def test_api_one_shot_solve():
    Ue, Uo, e, o = make_eo(seed=18)
    xe, xo, res = api.solve(
        Ue, Uo, e, o, KAPPA, backend="jnp",
        spec=api.SolveSpec(method="bicgstab", tol=1e-5))
    assert bool(res.converged)


def test_solve_wilson_eo_shim_is_gone():
    """The deprecated kwarg-sprawl entry point reached its removal
    horizon (PR 7): the symbol must not exist anywhere — ``api.solve``
    / SolveSession is the one-shot surface now (lint rule R3 enforces
    the same repo-wide)."""
    import repro.core as core

    assert not hasattr(solver, "solve_wilson_eo")
    assert not hasattr(core, "solve_wilson_eo")
