"""Operator-backend registry: resolution, error paths, and cross-backend
agreement of the uniform hop_oe / hop_eo / apply_dhat interface —
including the fused single-kernel Dhat vs the unfused two-kernel path
(interpret mode off-TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core import evenodd, su3
from repro.kernels import layout, ops, ref


def make_eo(shape, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    psi = (jax.random.normal(k, (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    e, o = evenodd.pack(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e, o


def test_registry_has_builtin_backends():
    for name in ("jnp", "pallas", "pallas_fused", "distributed"):
        assert name in backends.available_backends()
        assert callable(backends.get_backend(name))


def test_unknown_backend_error():
    with pytest.raises(ValueError, match="unknown backend 'nope'"):
        backends.get_backend("nope")
    with pytest.raises(ValueError, match="pallas_fused"):
        # the error names what IS registered
        backends.get_backend("nope")


def test_register_backend_no_silent_overwrite():
    marker = lambda ue, uo, **kw: None
    backends.register_backend("_test_dummy", marker, overwrite=True)
    try:
        with pytest.raises(ValueError, match="already registered"):
            backends.register_backend("_test_dummy", marker)
        backends.register_backend("_test_dummy", marker, overwrite=True)
    finally:
        backends._REGISTRY.pop("_test_dummy", None)


@pytest.mark.parametrize("name", ["pallas", "pallas_fused"])
def test_kernel_backends_match_jnp(name, small_eo):
    Ue, Uo, e, o, kappa = small_eo
    ref_ops = backends.make_wilson_ops("jnp", Ue, Uo)
    bops = backends.make_wilson_ops(name, Ue, Uo, interpret=True)
    assert bops.backend == name
    np.testing.assert_allclose(
        np.asarray(bops.hop_oe(e)), np.asarray(ref_ops.hop_oe(e)),
        atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(bops.hop_eo(o)), np.asarray(ref_ops.hop_eo(o)),
        atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(bops.apply_dhat(e, kappa)),
        np.asarray(ref_ops.apply_dhat(e, kappa)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(bops.apply_dhat_dagger(e, kappa)),
        np.asarray(ref_ops.apply_dhat_dagger(e, kappa)), atol=1e-5)


def test_fused_dhat_matches_jnp_8888():
    """Acceptance: pallas_fused == jnp to 1e-5 (f32) on 8x8x8x8."""
    Ue, Uo, e, _ = make_eo((8, 8, 8, 8), seed=21)
    kappa = 0.13
    want = backends.make_wilson_ops("jnp", Ue, Uo).apply_dhat(e, kappa)
    got = backends.make_wilson_ops(
        "pallas_fused", Ue, Uo, interpret=True).apply_dhat(e, kappa)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_fused_vs_unfused_planar_agreement():
    """dhat_planar_fused (one kernel) == apply_dhat_planar (two kernels)
    to f32 tolerance on a small lattice, interpret mode."""
    Ue, Uo, e, _ = make_eo((4, 4, 4, 8), seed=13)
    kappa = 0.117
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    fused = ops.apply_dhat_planar_fused(Uep, Uop, ep, kappa,
                                        interpret=True)
    unfused = ops.apply_dhat_planar(Uep, Uop, ep, kappa, interpret=True)
    want = ref.apply_dhat_planar_ref(Uep, Uop, ep, kappa)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               atol=5e-5)


def test_fused_scratch_budget_guard():
    from repro.kernels.wilson_stencil import fused_dhat_fits
    assert fused_dhat_fits((8, 8, 24, 8, 4))
    assert not fused_dhat_fits((64, 64, 24, 32, 16))


def test_distributed_backend_single_device(small_eo):
    """Registry entry "distributed" (1-device mesh here: self-permute
    halos, structurally the multi-rank path) matches jnp."""
    Ue, Uo, e, _, kappa = small_eo
    ref_ops = backends.make_wilson_ops("jnp", Ue, Uo)
    bops = backends.make_wilson_ops("distributed", Ue, Uo)
    np.testing.assert_allclose(
        np.asarray(bops.hop_oe(e)), np.asarray(ref_ops.hop_oe(e)),
        atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(bops.apply_dhat(e, kappa)),
        np.asarray(ref_ops.apply_dhat(e, kappa)), atol=1e-5)


def test_solver_accepts_backend_string(small_eo):
    from repro.core import solver

    Ue, Uo, e, o, kappa = small_eo
    xe, xo, res = solver.solve_wilson_eo(
        Ue, Uo, e, o, kappa, method="bicgstab", tol=1e-5,
        backend="pallas_fused", backend_opts={"interpret": True})
    # verify against the jnp-backend operator: Dhat xe == rhs
    bops = backends.make_wilson_ops("jnp", Ue, Uo)
    rhs = e + kappa * bops.hop_eo(o)
    r = rhs - bops.apply_dhat(xe, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(rhs))
    assert rel < 1e-4, rel
