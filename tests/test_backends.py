"""Operator-backend registry: resolution, error paths, and cross-backend
agreement of the uniform hop_oe / hop_eo / apply_dhat interface —
including the fused single-kernel Dhat vs the unfused two-kernel path
(interpret mode off-TPU) — plus the native-domain boundary: encode/decode
round trips, adjointness in both domains, and the zero-conversion /
zero-replacement guarantees of natively-iterating solves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core import evenodd, su3
from repro.kernels import layout, ops, ref

BUILTIN_BACKENDS = ("jnp", "pallas", "pallas_fused", "distributed")


def _bind(name, Ue, Uo):
    """Bind a builtin backend, interpret-mode for Pallas off-TPU."""
    opts = ({"interpret": True} if name.startswith("pallas")
            and jax.default_backend() != "tpu" else {})
    return backends.make_wilson_ops(name, Ue, Uo, **opts)


def make_eo(shape, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    psi = (jax.random.normal(k, (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    e, o = evenodd.pack(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e, o


def test_registry_has_builtin_backends():
    for name in ("jnp", "pallas", "pallas_fused", "distributed"):
        assert name in backends.available_backends()
        assert callable(backends.get_backend(name))


def test_unknown_backend_error():
    with pytest.raises(ValueError, match="unknown backend 'nope'"):
        backends.get_backend("nope")
    with pytest.raises(ValueError, match="pallas_fused"):
        # the error names what IS registered
        backends.get_backend("nope")


def test_register_backend_no_silent_overwrite():
    marker = lambda ue, uo, **kw: None
    backends.register_backend("_test_dummy", marker, overwrite=True)
    try:
        with pytest.raises(ValueError, match="already registered"):
            backends.register_backend("_test_dummy", marker)
        backends.register_backend("_test_dummy", marker, overwrite=True)
    finally:
        backends._REGISTRY.pop("_test_dummy", None)


@pytest.mark.parametrize("name", ["pallas", "pallas_fused"])
def test_kernel_backends_match_jnp(name, small_eo):
    Ue, Uo, e, o, kappa = small_eo
    ref_ops = backends.make_wilson_ops("jnp", Ue, Uo)
    bops = backends.make_wilson_ops(name, Ue, Uo, interpret=True)
    assert bops.backend == name
    np.testing.assert_allclose(
        np.asarray(bops.hop_oe(e)), np.asarray(ref_ops.hop_oe(e)),
        atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(bops.hop_eo(o)), np.asarray(ref_ops.hop_eo(o)),
        atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(bops.apply_dhat(e, kappa)),
        np.asarray(ref_ops.apply_dhat(e, kappa)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(bops.apply_dhat_dagger(e, kappa)),
        np.asarray(ref_ops.apply_dhat_dagger(e, kappa)), atol=1e-5)


def test_fused_dhat_matches_jnp_8888():
    """Acceptance: pallas_fused == jnp to 1e-5 (f32) on 8x8x8x8."""
    Ue, Uo, e, _ = make_eo((8, 8, 8, 8), seed=21)
    kappa = 0.13
    want = backends.make_wilson_ops("jnp", Ue, Uo).apply_dhat(e, kappa)
    got = backends.make_wilson_ops(
        "pallas_fused", Ue, Uo, interpret=True).apply_dhat(e, kappa)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_fused_vs_unfused_planar_agreement():
    """dhat_planar_fused (one kernel) == apply_dhat_planar (two kernels)
    to f32 tolerance on a small lattice, interpret mode."""
    Ue, Uo, e, _ = make_eo((4, 4, 4, 8), seed=13)
    kappa = 0.117
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    fused = ops.apply_dhat_planar_fused(Uep, Uop, ep, kappa,
                                        interpret=True)
    unfused = ops.apply_dhat_planar(Uep, Uop, ep, kappa, interpret=True)
    want = ref.apply_dhat_planar_ref(Uep, Uop, ep, kappa)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               atol=5e-5)


def test_fused_scratch_budget_guard():
    from repro.kernels.wilson_stencil import fused_dhat_fits
    assert fused_dhat_fits((8, 8, 24, 8, 4))
    assert not fused_dhat_fits((64, 64, 24, 32, 16))


def test_distributed_backend_single_device(small_eo):
    """Registry entry "distributed" (1-device mesh here: self-permute
    halos, structurally the multi-rank path) matches jnp."""
    Ue, Uo, e, _, kappa = small_eo
    ref_ops = backends.make_wilson_ops("jnp", Ue, Uo)
    bops = backends.make_wilson_ops("distributed", Ue, Uo)
    np.testing.assert_allclose(
        np.asarray(bops.hop_oe(e)), np.asarray(ref_ops.hop_oe(e)),
        atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(bops.apply_dhat(e, kappa)),
        np.asarray(ref_ops.apply_dhat(e, kappa)), atol=1e-5)


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_domain_roundtrip(name, small_eo):
    """from_domain(to_domain(psi)) == psi for every backend's domain."""
    Ue, Uo, e, _, _ = small_eo
    bops = _bind(name, Ue, Uo)
    assert bops.domain in ("complex", "planar", "planar_sharded")
    np.testing.assert_array_equal(
        np.asarray(bops.from_domain(bops.to_domain(e))), np.asarray(e))


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_adjoint_property_complex_domain(name, small_eo):
    """<x, Dhat y> == <Dhat^dag x, y> on the complex interface."""
    Ue, Uo, e, o, kappa = small_eo
    bops = _bind(name, Ue, Uo)
    k = jax.random.PRNGKey(31)
    x = (jax.random.normal(k, e.shape)
         + 1j * jax.random.normal(jax.random.fold_in(k, 1), e.shape)
         ).astype(jnp.complex64)
    lhs = complex(jnp.vdot(x, bops.apply_dhat(e, kappa)))
    rhs = complex(jnp.vdot(bops.apply_dhat_dagger(x, kappa), e))
    assert abs(lhs - rhs) <= 1e-3 * max(abs(lhs), 1.0), (lhs, rhs)


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_adjoint_property_native_domain(name, small_eo):
    """Adjointness holds inside each backend's native domain too: the
    native rep of Dhat^dag is the transpose of the native rep of Dhat
    (real planar vdot == Re of the complex inner product)."""
    Ue, Uo, e, _, kappa = small_eo
    bops = _bind(name, Ue, Uo)
    k = jax.random.PRNGKey(33)
    x = (jax.random.normal(k, e.shape)
         + 1j * jax.random.normal(jax.random.fold_in(k, 1), e.shape)
         ).astype(jnp.complex64)
    vx, vy = bops.to_domain(x), bops.to_domain(e)
    lhs = complex(jnp.vdot(vx, bops.apply_dhat_native(vy, kappa)))
    rhs = complex(jnp.vdot(bops.apply_dhat_dagger_native(vx, kappa), vy))
    assert abs(lhs - rhs) <= 1e-3 * max(abs(lhs), 1.0), (lhs, rhs)
    # native inner product == Re(complex inner product) for planar domains
    if bops.domain != "complex":
        want = complex(jnp.vdot(x, bops.apply_dhat(e, kappa))).real
        assert abs(lhs.real - want) <= 1e-3 * max(abs(want), 1.0)


@pytest.mark.parametrize("name", ["pallas", "pallas_fused"])
def test_native_dhat_is_conversion_free(name, small_eo):
    """The planar-native operator's trace contains no complex arithmetic
    at all — so a solver iterating natively does zero spinor_to_planar /
    spinor_from_planar conversions inside the Krylov loop."""
    Ue, Uo, e, _, kappa = small_eo
    bops = _bind(name, Ue, Uo)
    v = bops.to_domain(e)
    for fn in (lambda w: bops.apply_dhat_native(w, kappa),
               lambda w: bops.apply_dhat_dagger_native(w, kappa),
               bops.hop_oe_native):
        txt = str(jax.make_jaxpr(fn)(v))
        assert "c64" not in txt and "c128" not in txt, name
        assert "complex" not in txt, name


def test_distributed_native_ops_no_per_call_device_put(small_eo,
                                                       monkeypatch):
    """Sharded-native ops run on already-placed arrays: zero device_put
    per application (placement happens once, in to_domain)."""
    Ue, Uo, e, _, kappa = small_eo
    bops = backends.make_wilson_ops("distributed", Ue, Uo)
    v = bops.to_domain(e)
    calls = []
    orig = jax.device_put
    monkeypatch.setattr(
        jax, "device_put",
        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    jax.block_until_ready(bops.apply_dhat_native(v, kappa))
    jax.block_until_ready(bops.apply_dhat_dagger_native(v, kappa))
    jax.block_until_ready(bops.hop_oe_native(v))
    jax.block_until_ready(bops.hop_eo_native(v))
    assert not calls
    bops.to_domain(e)    # the encode boundary is where placement lives
    assert len(calls) == 1


@pytest.mark.parametrize("name", BUILTIN_BACKENDS[1:])
def test_native_solve_matches_complex_solve(name, small_eo):
    """Acceptance: the natively-iterating solve agrees with a
    complex-interface iteration of the same backend to tolerance, and
    encodes/decodes exactly once per solve (not once per iteration)."""
    from repro import api
    from repro.core import solver

    Ue, Uo, e, o, kappa = small_eo
    bops = _bind(name, Ue, Uo)

    counts = {"to": 0, "from": 0}
    orig_to, orig_from = layout.spinor_to_planar, layout.spinor_from_planar

    def counting_to(*a, **kw):
        counts["to"] += 1
        return orig_to(*a, **kw)

    def counting_from(*a, **kw):
        counts["from"] += 1
        return orig_from(*a, **kw)

    layout.spinor_to_planar = counting_to
    layout.spinor_from_planar = counting_from
    try:
        D = api.WilsonMatrix.from_ops(bops, kappa, gauge=(Ue, Uo))
        session = api.SolveSession(
            D, api.SolveSpec(method="bicgstab", tol=1e-5))
        xe, xo, res = session.solve(e, o)
    finally:
        layout.spinor_to_planar = orig_to
        layout.spinor_from_planar = orig_from
    assert int(res.iterations) > 1
    # encode: eta_e + eta_o; decode: xi_e + xi_o — independent of iters.
    assert counts["to"] == 2, counts
    assert counts["from"] == 2, counts

    # complex-interface iteration of the same backend's operators:
    # Eq. (4) Schur solve on Dhat, Eq. (5) odd reconstruction.
    rhs = e + kappa * bops.hop_eo(o)
    res_c = solver.bicgstab(lambda v: bops.apply_dhat(v, kappa),
                            rhs, tol=1e-5, max_iters=2000)
    xe_c = res_c.x
    xo_c = o + kappa * bops.hop_oe(xe_c)
    np.testing.assert_allclose(np.asarray(xe), np.asarray(xe_c), atol=2e-4)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xo_c), atol=2e-4)


def test_partial_native_construction_rejected():
    """Providing some but not all domain fields would silently route
    complex ops into the native path — it must fail loudly instead."""
    with pytest.raises(ValueError, match="partial native-domain"):
        backends.WilsonOps(
            backend="half", hop_oe=lambda p: p, hop_eo=lambda p: p,
            apply_dhat=lambda p, k: p, apply_dhat_dagger=lambda p, k: p,
            domain="planar", to_domain=layout.spinor_to_planar,
            from_domain=layout.spinor_from_planar)


def test_legacy_complex_only_factory_gets_identity_domain():
    """Third-party factories that predate the domain boundary still work:
    construction with complex ops only yields an identity domain."""
    marker = object()
    bops = backends.WilsonOps(
        backend="legacy", hop_oe=lambda p: p, hop_eo=lambda p: p,
        apply_dhat=lambda p, k: p, apply_dhat_dagger=lambda p, k: p)
    assert bops.domain == "complex"
    assert bops.to_domain(marker) is marker
    assert bops.from_domain(marker) is marker
    assert bops.apply_dhat_native(marker, 0.1) is marker


def test_solver_accepts_backend_string(small_eo):
    from repro import api

    Ue, Uo, e, o, kappa = small_eo
    xe, xo, res = api.solve(
        Ue, Uo, e, o, kappa, backend="pallas_fused", interpret=True,
        spec=api.SolveSpec(method="bicgstab", tol=1e-5))
    # verify against the jnp-backend operator: Dhat xe == rhs
    bops = backends.make_wilson_ops("jnp", Ue, Uo)
    rhs = e + kappa * bops.hop_eo(o)
    r = rhs - bops.apply_dhat(xe, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(rhs))
    assert rel < 1e-4, rel
