"""Block CG (BCGrQ): shared Krylov space over an RHS block.

Dense-operator unit tests for the properties the lattice bench
(``benchmarks/bench_deflation.py``) demonstrates at scale: convergence
no slower than column-independent CG, rank-deficiency tolerance
(duplicate columns), per-column freeze, and NaN-column isolation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver


def _spd(n=64, seed=0):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n, n), dtype=jnp.float32)
    return g @ g.T + n * jnp.eye(n, dtype=jnp.float32)


def _rhs(n, nrhs, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (nrhs, n),
                             dtype=jnp.float32)


def test_blockcg_converges_and_matches_cg_batched():
    """BCGrQ solves the block to the same tolerance in no more
    iterations than the slowest column of independent batched CG —
    the shared Krylov space can only help."""
    A = _spd()
    bb = _rhs(A.shape[0], 4)
    op = lambda v: v @ A.T  # noqa: E731
    blk = solver.blockcg_batched(op, bb, tol=1e-6, max_iters=300)
    ind = solver.cg_batched(op, bb, tol=1e-6, max_iters=300)
    assert bool(jnp.all(blk.converged))
    assert int(jnp.max(blk.iterations)) <= int(jnp.max(ind.iterations))
    rel = jnp.linalg.norm(bb - blk.x @ A.T, axis=1) \
        / jnp.linalg.norm(bb, axis=1)
    assert float(jnp.max(rel)) < 1e-5


def test_blockcg_duplicate_columns():
    """A rank-deficient RHS block (duplicate columns) must not break
    the shared-space QR: the eps-ridge keeps the S-solve well posed and
    both copies converge to the same solution."""
    A = _spd(seed=2)
    bb = _rhs(A.shape[0], 3, seed=3)
    bb = bb.at[2].set(bb[0])
    res = solver.blockcg_batched(lambda v: v @ A.T, bb,
                                 tol=1e-6, max_iters=300)
    assert bool(jnp.all(res.converged))
    np.testing.assert_allclose(np.asarray(res.x[2]),
                               np.asarray(res.x[0]),
                               rtol=1e-4, atol=1e-6)
    rel = jnp.linalg.norm(bb - res.x @ A.T, axis=1) \
        / jnp.linalg.norm(bb, axis=1)
    assert float(jnp.max(rel)) < 1e-5


def test_blockcg_nan_column_isolated():
    """A poisoned column is flagged diverged while the healthy columns
    of the SAME block solve converge to full accuracy (the divergence
    guard isolates it from the shared recursion)."""
    A = _spd(seed=4)
    bb = _rhs(A.shape[0], 3, seed=5)
    bb = bb.at[1, 0].set(jnp.nan)
    res = solver.blockcg_batched(lambda v: v @ A.T, bb,
                                 tol=1e-6, max_iters=300)
    assert bool(res.diverged[1]) and not bool(res.converged[1])
    for col in (0, 2):
        assert bool(res.converged[col]) and not bool(res.diverged[col])
        assert bool(jnp.all(jnp.isfinite(res.x[col])))
        rel = float(jnp.linalg.norm(bb[col] - A @ res.x[col])
                    / jnp.linalg.norm(bb[col]))
        assert rel < 1e-5


def test_blockcg_unbatched_degenerates_to_cg():
    """method="blockcg" on a single (unbatched) RHS is plain CG —
    same solution, same iteration count."""
    A = _spd(seed=6)
    b = _rhs(A.shape[0], 1, seed=7)[0]
    op = lambda v: A @ v  # noqa: E731
    blk = solver._run_krylov("blockcg", op, op, b, tol=1e-6,
                             max_iters=300, recompute_every=0)
    plain = solver._run_krylov("cg", op, op, b, tol=1e-6,
                               max_iters=300, recompute_every=0)
    assert bool(blk.converged)
    assert int(blk.iterations) == int(plain.iterations)
    assert bool(jnp.all(blk.x == plain.x))
