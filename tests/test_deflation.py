"""Low-mode deflation and subspace recycling (repro.core.deflate).

Dense-operator unit tests for the machinery — Lanczos basis quality,
the Galerkin guess + A-orthogonal projector, the Chebyshev harvest
filter, Rayleigh-Ritz refinement of harvested spans — plus the
SolveSpec validation surface and the SolveSession recycle stream.
The at-scale iteration-count claims live in
``benchmarks/bench_deflation.py`` (CI-asserted on a weak-field gauge).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import deflate, evenodd, solver


def _clustered_spd(n=96, nlow=8, seed=0):
    """SPD with an isolated low cluster (1e-3..1e-2) under a bulk
    spectrum (0.5..2.0) — the shape deflation is for."""
    key = jax.random.PRNGKey(seed)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n),
                                           dtype=jnp.float32))
    ev = jnp.concatenate(
        [jnp.linspace(1e-3, 1e-2, nlow),
         jnp.linspace(0.5, 2.0, n - nlow)]).astype(jnp.float32)
    return (q * ev) @ q.T, q, ev


# --- the deflation machinery on a dense operator ---------------------

def test_lanczos_deflation_cuts_iterations():
    """Projected CG with a once-computed Lanczos basis converges in
    far fewer iterations than plain CG on the same clustered system."""
    A, _, _ = _clustered_spd()
    op = lambda v: A @ v  # noqa: E731
    key = jax.random.PRNGKey(1)
    b = jax.random.normal(key, (A.shape[0],), dtype=jnp.float32)
    plain = solver.cg(op, b, tol=1e-5, max_iters=400)
    basis = deflate.lanczos_basis(op, b, rank=8, iters=48)
    assert basis.count() >= 1
    defl = solver.cg(op, b, x0=deflate.galerkin_guess(basis, b),
                     tol=1e-5, max_iters=400,
                     project=deflate.make_projector(basis))
    assert bool(plain.converged) and bool(defl.converged)
    assert int(defl.iterations) < int(plain.iterations)
    rel = float(jnp.linalg.norm(b - A @ defl.x) / jnp.linalg.norm(b))
    assert rel < 1e-4


def test_lanczos_ritz_pairs_pass_quality_gate():
    """Every pair the basis exposes satisfies the acceptance bound
    |A w - theta w| <= RITZ_QUALITY * theta it was filtered by."""
    A, _, _ = _clustered_spd(seed=2)
    v0 = jax.random.normal(jax.random.PRNGKey(3), (A.shape[0],),
                           dtype=jnp.float32)
    basis = deflate.lanczos_basis(lambda v: A @ v, v0, rank=8, iters=48)
    m = np.asarray(basis.mask)
    theta = np.asarray(jnp.diag(basis.gram).real)
    w = np.asarray(basis.vectors)
    aw = np.asarray(basis.avectors)
    for i in np.flatnonzero(m):
        rres = np.linalg.norm(aw[i] - theta[i] * w[i])
        assert rres <= deflate.RITZ_QUALITY * theta[i] * 1.01


def test_empty_basis_is_bit_exact_identity():
    """An empty basis must be invisible: zero Galerkin guess, identity
    projector, and a deflated CG solve bit-identical to the plain one
    (what makes a growing recycle basis safe from solve zero)."""
    A, _, _ = _clustered_spd(seed=4)
    op = lambda v: A @ v  # noqa: E731
    b = jax.random.normal(jax.random.PRNGKey(5), (A.shape[0],),
                          dtype=jnp.float32)
    eb = deflate.empty_basis(6, b)
    assert bool(jnp.all(deflate.galerkin_guess(eb, b) == 0.0))
    plain = solver.cg(op, b, tol=1e-5, max_iters=400)
    defl = solver.cg(op, b, x0=deflate.galerkin_guess(eb, b),
                     tol=1e-5, max_iters=400,
                     project=deflate.make_projector(eb))
    assert int(defl.iterations) == int(plain.iterations)
    assert bool(jnp.all(defl.x == plain.x))


def test_recycle_update_grows_and_rejects_dependent():
    """The jitted updater appends orthogonalized vectors, keeps the
    Gram Hermitian, and rejects a vector already inside the span."""
    A, _, _ = _clustered_spd(seed=6)
    op = lambda v: A @ v  # noqa: E731
    n = A.shape[0]
    upd = deflate.make_recycle_update(op)   # no harvest filter
    key = jax.random.PRNGKey(7)
    v1 = jax.random.normal(key, (n,), dtype=jnp.float32)
    v2 = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                           dtype=jnp.float32)
    b0 = deflate.empty_basis(3, v1)
    b1 = deflate.DeflationBasis(*upd(b0, v1))
    b2 = deflate.DeflationBasis(*upd(b1, v2))
    assert (b1.count(), b2.count()) == (1, 2)
    np.testing.assert_allclose(np.asarray(b2.gram),
                               np.asarray(jnp.conj(b2.gram).T),
                               rtol=1e-5, atol=1e-6)
    # v1 is in the span already -> rejected, basis returned unchanged
    b3 = deflate.DeflationBasis(*upd(b2, v1))
    assert b3.count() == 2
    assert bool(jnp.all(b3.vectors == b2.vectors))


def test_chebyshev_harvest_filter_amplifies_low_modes():
    """With lam_max armed, the harvest filter turns a RANDOM vector
    (low-mode weight ~nlow/n) into a low-mode dominated one — the
    mechanism that lets a recycle span resolve the low cluster."""
    A, q, ev = _clustered_spd(seed=8)
    op = lambda v: A @ v  # noqa: E731
    n, nlow = A.shape[0], 8
    v = jax.random.normal(jax.random.PRNGKey(9), (n,),
                          dtype=jnp.float32)
    lam = deflate.estimate_lambda_max(op, v)
    assert 0.85 * float(ev[-1]) <= lam <= 1.01 * float(ev[-1])
    upd = deflate.make_recycle_update(op, lam_max=1.1 * lam)
    basis = deflate.DeflationBasis(*upd(deflate.empty_basis(2, v), v))
    assert basis.count() == 1
    w = basis.vectors[0]
    low = q[:, :nlow].T @ w
    weight = float(jnp.sum(low ** 2) / jnp.sum(w ** 2))
    assert weight > 0.9, weight


def test_ritz_refine_recovers_eigenpairs_from_span():
    """Rayleigh-Ritz refinement of a harvested span of low-eigenvector
    COMBINATIONS recovers the individual eigenpairs: all accepted, with
    Ritz values matching the true low eigenvalues."""
    A, q, ev = _clustered_spd(seed=10)
    op = lambda v: A @ v  # noqa: E731
    key = jax.random.PRNGKey(11)
    upd = deflate.make_recycle_update(op)   # span is already low-pure
    basis = deflate.empty_basis(4, q[:, 0])
    nmix = 3
    for i in range(nmix):
        c = jax.random.normal(jax.random.fold_in(key, i), (nmix,),
                              dtype=jnp.float32)
        basis = deflate.DeflationBasis(*upd(basis, q[:, :nmix] @ c))
    assert basis.count() == nmix
    refined = deflate.DeflationBasis(
        *deflate.make_ritz_refine()(basis))
    assert refined.count() == nmix
    theta = np.sort(np.asarray(jnp.diag(refined.gram).real)[
        np.asarray(refined.mask)])
    np.testing.assert_allclose(theta, np.asarray(ev[:nmix]),
                               rtol=1e-2)
    # refining an EMPTY span accepts nothing (projector stays identity)
    empty = deflate.DeflationBasis(
        *deflate.make_ritz_refine()(deflate.empty_basis(4, q[:, 0])))
    assert empty.count() == 0


# --- the SolveSpec validation surface --------------------------------

def test_spec_deflation_validation():
    with pytest.raises(ValueError, match="normal-equations"):
        api.SolveSpec(method="bicgstab", deflate_rank=4)
    with pytest.raises(ValueError, match="not combinable"):
        api.SolveSpec(method="cg", deflate_rank=4, inner_dtype="f32")
    with pytest.raises(ValueError, match="deflate_mode"):
        api.SolveSpec(deflate_mode="qr")
    with pytest.raises(ValueError, match="deflate_rank"):
        api.SolveSpec(deflate_rank=-1)
    with pytest.raises(ValueError, match="deflate_iters"):
        api.SolveSpec(method="cg", deflate_rank=4, deflate_iters=0)


def test_spec_deflation_cache_tokens_distinct():
    base = api.SolveSpec(method="cg")
    lan = api.SolveSpec(method="cg", deflate_rank=8)
    lan_it = api.SolveSpec(method="cg", deflate_rank=8,
                           deflate_iters=64)
    rec = api.SolveSpec(method="cg", deflate_rank=8,
                        deflate_mode="recycle")
    tokens = {s.cache_token() for s in (base, lan, lan_it, rec)}
    assert len(tokens) == 4
    assert "defl8-lanczos" in lan.cache_token()
    assert "li64" in lan_it.cache_token()


# --- the SolveSession deflation surface (small lattice) --------------

def _stream_source(seed, shape=(4, 4, 4, 8)):
    k = jax.random.PRNGKey(seed)
    eta = (jax.random.normal(k, (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    return evenodd.pack(eta)


def test_session_recycle_stream_stats(small_eo):
    """A recycle session harvests converged solutions, re-traces
    nothing (the growing basis is an ARGUMENT), and surfaces the whole
    stream on stats(): per-solve iterations plus the deflation row."""
    Ue, Uo, _, _, kappa = small_eo
    D = api.WilsonMatrix.bind(Ue, Uo, kappa, backend="jnp")
    sess = api.SolveSession(
        D, api.SolveSpec(method="cg", tol=1e-5, max_iters=2000,
                         deflate_rank=4, deflate_mode="recycle"))
    for i in range(3):
        _, _, res = sess.solve(*_stream_source(20 + i))
        assert bool(res.converged)
    st = sess.stats()
    assert st["solves"] == 3 and st["traces"] == 1
    row = next(iter(st["keys"].values()))
    assert len(row["iterations"]) == 3
    d = row["deflation"]
    assert d["mode"] == "recycle" and d["rank"] == 4
    assert d["harvested"] >= 1
    assert d["filled"] == d["harvested"]
    assert 0 <= d["active"] <= d["filled"]


def test_session_lanczos_deflation_no_harm(small_eo):
    """Lanczos-mode deflation on a small random (hot) gauge: the
    quality gate may activate few pairs, but the deflated solve must
    stay correct and no slower than plain CG beyond noise."""
    Ue, Uo, ee, eo, kappa = small_eo
    D = api.WilsonMatrix.bind(Ue, Uo, kappa, backend="jnp")
    plain = api.SolveSession(
        D, api.SolveSpec(method="cg", tol=1e-5, max_iters=2000))
    _, _, r0 = plain.solve(ee, eo)
    defl = api.SolveSession(
        D, api.SolveSpec(method="cg", tol=1e-5, max_iters=2000,
                         deflate_rank=4, deflate_iters=24))
    _, _, r1 = defl.solve(ee, eo)
    assert bool(r0.converged) and bool(r1.converged)
    assert int(r1.iterations) <= int(r0.iterations) + 5
    row = next(iter(defl.stats()["keys"].values()))["deflation"]
    assert row["mode"] == "lanczos" and row["rank"] == 4
