"""Multi-device tests: run in a subprocess with 8 forced host devices so
the main pytest process keeps a single device (per dry-run instructions,
the forced device count must never leak into tests)."""
import os
import pathlib
import subprocess
import sys
import textwrap


REPO = pathlib.Path(__file__).resolve().parents[1]


def run_py(body: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices} " + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_dhat_all_modes():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core import su3, evenodd
        from repro.kernels import layout, ops, ref
        from repro.distributed import qcd
        T,Z,Y,X = 8,8,4,8
        U = su3.random_gauge(jax.random.PRNGKey(2), (T,Z,Y,X))
        psi = (jax.random.normal(jax.random.PRNGKey(3), (T,Z,Y,X,4,3))
               + 1j*jax.random.normal(jax.random.PRNGKey(4),
                                      (T,Z,Y,X,4,3))).astype(jnp.complex64)
        e, _ = evenodd.pack(psi)
        Ue, Uo = evenodd.pack_gauge(U)
        Uep, Uop = ops.make_planar_fields(Ue, Uo)
        ep = layout.spinor_to_planar(e)
        want = ref.apply_dhat_planar_ref(Uep, Uop, ep, 0.13)
        from repro import compat
        mesh = compat.make_mesh((2,2,2), ("pod","data","model"))
        for backend in ("jnp","pallas"):
            for overlap in ("fused","split"):
                part = qcd.QCDPartition.for_mesh(
                    mesh, backend=backend, overlap=overlap, interpret=True)
                dhat = jax.jit(qcd.make_dhat_fn(part, 0.13))
                got = dhat(jax.device_put(Uep, part.gauge_sharding()),
                           jax.device_put(Uop, part.gauge_sharding()),
                           jax.device_put(ep, part.spinor_sharding()))
                err = float(jnp.max(jnp.abs(got - want)))
                assert err < 1e-5, (backend, overlap, err)
                print("OK", backend, overlap, err)
    """)
    assert out.count("OK") == 4


def test_distributed_interior_overlap_multidevice():
    """The comms/compute-overlap schedule on a real 2x2 device mesh:
    faces actually cross device boundaries (not the 1-device
    self-permute), with and without compressed links."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core import su3, evenodd
        from repro.kernels import layout, ops, ref
        from repro.distributed import qcd
        from repro import compat
        T,Z,Y,X = 8,8,4,8
        U = su3.random_gauge(jax.random.PRNGKey(2), (T,Z,Y,X))
        psi = (jax.random.normal(jax.random.PRNGKey(3), (T,Z,Y,X,4,3))
               + 1j*jax.random.normal(jax.random.PRNGKey(4),
                                      (T,Z,Y,X,4,3))).astype(jnp.complex64)
        e, _ = evenodd.pack(psi)
        Ue, Uo = evenodd.pack_gauge(U)
        ep = layout.spinor_to_planar(e)
        Uep0, Uop0 = ops.make_planar_fields(Ue, Uo)
        want = ref.apply_dhat_planar_ref(Uep0, Uop0, ep, 0.13)
        mesh = compat.make_mesh((2,2), ("data","model"))   # Tl=Zl=4 >= 3
        for gc in ("none", "two_row"):
            Uep, Uop = ops.make_planar_fields(Ue, Uo, compression=gc)
            part = qcd.QCDPartition.for_mesh(
                mesh, backend="jnp_planar", overlap="interior",
                interpret=True)
            dhat = jax.jit(qcd.make_dhat_fn(part, 0.13))
            got = dhat(jax.device_put(Uep, part.gauge_sharding()),
                       jax.device_put(Uop, part.gauge_sharding()),
                       jax.device_put(ep, part.spinor_sharding()))
            err = float(jnp.max(jnp.abs(got - want)))
            assert err < 1e-5, (gc, err)
            print("OK", gc, err)
    """, n_devices=4)
    assert out.count("OK") == 2


def test_distributed_solver_matches_single():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core import su3, evenodd, solver, wilson
        from repro.kernels import layout, ops
        from repro.distributed import qcd
        T,Z,Y,X = 8,4,4,8
        U = su3.random_gauge(jax.random.PRNGKey(2), (T,Z,Y,X))
        eta = (jax.random.normal(jax.random.PRNGKey(7), (T,Z,Y,X,4,3))
               + 1j*jax.random.normal(jax.random.PRNGKey(8),
                                      (T,Z,Y,X,4,3))).astype(jnp.complex64)
        Ue, Uo = evenodd.pack_gauge(U)
        ee, eo = evenodd.pack(eta)
        kappa = 0.12
        from repro import compat
        mesh = compat.make_mesh((4,2), ("data","model"))
        part = qcd.QCDPartition.for_mesh(mesh, backend="jnp")
        Uep, Uop = ops.make_planar_fields(Ue, Uo)
        Uep = jax.device_put(Uep, part.gauge_sharding())
        Uop = jax.device_put(Uop, part.gauge_sharding())
        dhat_g = qcd.make_dhat_fn(part, kappa)
        dhat_dag_g = qcd.make_dhat_dagger_fn(part, kappa)
        # solve the Schur system distributed, planar layout
        rhs_c = ee + kappa * evenodd.hop_eo(Ue, Uo, eo)
        rhs = jax.device_put(layout.spinor_to_planar(rhs_c),
                             part.spinor_sharding())
        res = solver.cgnr(lambda v: dhat_g(Uep, Uop, v),
                          lambda v: dhat_dag_g(Uep, Uop, v),
                          rhs, tol=1e-6, max_iters=600)
        xe = layout.spinor_from_planar(res.x)
        xo = eo + kappa * evenodd.hop_oe(Ue, Uo, xe)
        xi = evenodd.unpack(xe, xo)
        r = eta - wilson.apply_wilson(U, xi, kappa)
        rel = float(jnp.linalg.norm(r)/jnp.linalg.norm(eta))
        assert rel < 1e-4, rel
        print("OK dist solve rel", rel)
    """)
    assert "OK dist solve" in out


def test_compressed_psum_tree():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compress
        from repro import compat
        mesh = compat.make_mesh((8,), ("data",))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 512, 16)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (8, 32))}
        res = {"w": jnp.zeros((512,16)), "b": jnp.zeros((32,))}
        def f(g, r):
            m, r2 = compress.compressed_psum_tree(g, "data", r)
            return m, r2
        fm = jax.jit(compat.shard_map(f, mesh=mesh,
                     in_specs=({"w": P("data"), "b": P("data")},
                               {"w": P(), "b": P()}),
                     out_specs=(P(), P()), check_vma=False))
        mean, res2 = fm(g, res)
        want_w = np.asarray(g["w"]).mean(0)
        got_w = np.asarray(mean["w"]).reshape(512, 16)
        # int8 with error feedback: bounded error this step
        err = np.abs(got_w - want_w).max()
        bound = np.abs(np.asarray(g["w"])).max() / 254
        assert err <= bound * 1.05, (err, bound)
        # small leaf exact (uncompressed)
        np.testing.assert_allclose(np.asarray(mean["b"]).reshape(-1),
                                   np.asarray(g["b"]).mean(0), atol=1e-6)
        print("OK compress")
    """)
    assert "OK compress" in out


def test_elastic_mesh_shapes():
    out = run_py("""
        import jax
        from repro.launch import mesh as mesh_lib
        m = mesh_lib.elastic_mesh()
        assert m.shape["model"] <= 16
        assert m.devices.size == 8, m.shape
        m6 = mesh_lib.elastic_mesh(6)
        assert m6.devices.size == 6
        print("OK", dict(m.shape), dict(m6.shape))
    """)
    assert "OK" in out


def test_train_checkpoint_restart_resume():
    """Kill-and-resume: a restarted run continues from the checkpoint and
    reaches the same final state as an uninterrupted one (determinism)."""
    out = run_py("""
        import subprocess, sys, os, tempfile, json
        import numpy as np
        from repro.launch import train
        import jax, jax.numpy as jnp
        d = tempfile.mkdtemp()
        args = ["--arch","minitron-4b","--scale","0.02","--seq","32",
                "--batch","4","--lr","1e-3","--ckpt-every","5"]
        # uninterrupted 20 steps
        train.main(args + ["--steps","20","--ckpt-dir",d+"/a","--fresh"])
        # interrupted at 10, then resumed to 20
        train.main(args + ["--steps","10","--ckpt-dir",d+"/b","--fresh"])
        train.main(args + ["--steps","20","--ckpt-dir",d+"/b"])
        from repro.checkpoint.ckpt import Checkpointer
        ca, cb = Checkpointer(d+"/a"), Checkpointer(d+"/b")
        import glob
        sa, sb = ca.latest_step(), cb.latest_step()
        assert sa == sb == 20, (sa, sb)
        print("OK restart")
    """, n_devices=1)
    assert "OK restart" in out
