"""Gamma-matrix algebra: the mathematical backbone of the Wilson matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gamma


@pytest.mark.parametrize("mu", range(4))
def test_hermitian_and_squares_to_one(mu):
    g = gamma.GAMMA[mu]
    assert np.allclose(g, g.conj().T)
    assert np.allclose(g @ g, np.eye(4))


def test_anticommutation():
    for mu in range(4):
        for nu in range(mu + 1, 4):
            ac = gamma.GAMMA[mu] @ gamma.GAMMA[nu] \
                + gamma.GAMMA[nu] @ gamma.GAMMA[mu]
            assert np.allclose(ac, 0), (mu, nu)


def test_gamma5_product():
    g5 = (gamma.GAMMA[0] @ gamma.GAMMA[1] @ gamma.GAMMA[2]
          @ gamma.GAMMA[3])
    assert np.allclose(g5, gamma.GAMMA5)
    assert np.allclose(np.diag(gamma.GAMMA5), [1, 1, -1, -1])


@pytest.mark.parametrize("mu", range(4))
@pytest.mark.parametrize("s", [+1, -1])
def test_project_reconstruct_equals_dense(mu, s):
    key = jax.random.PRNGKey(mu * 2 + (s > 0))
    psi = (jax.random.normal(key, (3, 5, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(key, 1),
                                    (3, 5, 4, 3))).astype(jnp.complex64)
    dense = jnp.einsum("ij,...jc->...ic",
                       jnp.asarray(gamma.projector(mu, s)), psi)
    halved = gamma.reconstruct(gamma.project(psi, mu, s), mu, s)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(halved),
                               atol=2e-6)


@pytest.mark.parametrize("mu", range(4))
def test_projector_property(mu):
    """(1+g)(1-g) = 0 and (1+g)^2 = 2(1+g): true projectors (x2)."""
    p_plus = gamma.projector(mu, +1)
    p_minus = gamma.projector(mu, -1)
    assert np.allclose(p_plus @ p_minus, 0, atol=1e-6)
    assert np.allclose(p_plus @ p_plus, 2 * p_plus, atol=1e-6)
