"""Pallas Wilson stencil kernel vs the pure-jnp oracle (interpret mode),
sweeping lattice shapes, parities, offsets, halo/periodic and the fused
axpy epilogue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import evenodd, su3
from repro.kernels import layout, ops, ref
from repro.kernels.wilson_stencil import hop_block_planar


def make_fields(shape, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    psi = (jax.random.normal(k, (*shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (*shape, 4, 3))).astype(jnp.complex64)
    e, o = evenodd.pack(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return ops.make_planar_fields(Ue, Uo) + (
        layout.spinor_to_planar(e), layout.spinor_to_planar(o))


def test_layout_roundtrip(small_lattice):
    _, psi, _ = small_lattice
    e, _ = evenodd.pack(psi)
    p = layout.spinor_to_planar(e)
    np.testing.assert_array_equal(
        np.asarray(layout.spinor_from_planar(p)), np.asarray(e))


@pytest.mark.parametrize("shape", [(2, 2, 2, 4), (4, 4, 4, 8),
                                   (2, 4, 8, 16), (6, 2, 2, 4),
                                   (3, 5, 4, 8)])
@pytest.mark.parametrize("parity", [evenodd.EVEN, evenodd.ODD])
def test_kernel_matches_ref_shapes(shape, parity):
    Uep, Uop, ep, op_ = make_fields(shape, seed=shape[0] + parity)
    u_out, u_in = (Uop, Uep) if parity else (Uep, Uop)
    src = ep if parity else op_
    got = hop_block_planar(u_out, u_in, src, parity, interpret=True)
    want = ref.hop_block_planar_ref(u_out, u_in, src, parity)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5)


@pytest.mark.parametrize("t0,z0", [(0, 0), (1, 0), (0, 1), (3, 5)])
def test_kernel_parity_offsets(t0, z0):
    Uep, Uop, ep, _ = make_fields((4, 4, 4, 8), seed=9)
    got = hop_block_planar(Uop, Uep, ep, evenodd.ODD, tz_offset=(t0, z0),
                           interpret=True)
    want = ref.hop_block_planar_ref(Uop, Uep, ep, evenodd.ODD,
                                    tz_offset=(t0, z0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5)


def _extend(a, t, z):
    a = jnp.concatenate([a.take(jnp.array([-1]), axis=t), a,
                         a.take(jnp.array([0]), axis=t)], axis=t)
    return jnp.concatenate([a.take(jnp.array([-1]), axis=z), a,
                            a.take(jnp.array([0]), axis=z)], axis=z)


def test_kernel_halo_mode_equals_periodic():
    Uep, Uop, ep, _ = make_fields((4, 6, 4, 8), seed=3)
    got = hop_block_planar(Uop, _extend(Uep, 1, 2), _extend(ep, 0, 1),
                           evenodd.ODD, halo=True, interpret=True)
    want = hop_block_planar(Uop, Uep, ep, evenodd.ODD, halo=False,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)


def test_fused_axpy_epilogue():
    Uep, Uop, ep, _ = make_fields((4, 4, 4, 8), seed=5)
    kappa = 0.124
    fused = ops.apply_dhat_planar(Uep, Uop, ep, kappa, fused=True,
                                  interpret=True)
    unfused = ops.apply_dhat_planar(Uep, Uop, ep, kappa, fused=False,
                                    interpret=True)
    want = ref.apply_dhat_planar_ref(Uep, Uop, ep, kappa)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(want),
                               atol=5e-5)


def test_complex_interface_kernels(small_lattice, small_eo):
    U, psi, kappa = small_lattice
    Ue, Uo, e, o, _ = small_eo
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    got = ops.hop_oe_kernel(Uep, Uop, e, interpret=True)
    want = evenodd.hop_oe(Ue, Uo, e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5)


def test_kernel_bf16_tolerance():
    """bf16 planar fields: kernel within bf16 noise of the f32 oracle."""
    Uep, Uop, ep, _ = make_fields((2, 2, 4, 8), seed=11)
    got16 = hop_block_planar(Uop.astype(jnp.bfloat16),
                             Uep.astype(jnp.bfloat16),
                             ep.astype(jnp.bfloat16), evenodd.ODD,
                             interpret=True)
    want = ref.hop_block_planar_ref(Uop, Uep, ep, evenodd.ODD)
    err = np.max(np.abs(np.asarray(got16, np.float32) - np.asarray(want)))
    scale = np.max(np.abs(np.asarray(want)))
    assert err / scale < 0.05
