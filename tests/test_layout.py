"""Round-trip, dtype, and component-ordering guarantees of the planar
layout (kernels/layout.py) — the encode/decode boundary every native-
domain solve crosses exactly once, guarded here against refactors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gamma, su3
from repro.kernels import layout


@pytest.fixture(scope="module")
def spinor():
    k = jax.random.PRNGKey(11)
    psi = (jax.random.normal(k, (4, 6, 4, 8, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (4, 6, 4, 8, 4, 3)))
    return psi.astype(jnp.complex64)


@pytest.fixture(scope="module")
def gauge():
    # (4, T, Z, Y, X=8, 3, 3); treated as the compacted Xh axis below
    return su3.random_gauge(jax.random.PRNGKey(12), (4, 6, 4, 8))


def test_spinor_roundtrip_exact(spinor):
    """complex64 components are f32, so the f32 planar round trip is
    bit-exact."""
    p = layout.spinor_to_planar(spinor)
    assert p.shape == (4, 6, layout.SPINOR_COMPS, 4, 8)
    assert p.dtype == jnp.float32
    back = layout.spinor_from_planar(p)
    assert back.dtype == jnp.complex64
    np.testing.assert_array_equal(np.asarray(back), np.asarray(spinor))


def test_gauge_roundtrip_exact(gauge):
    p = layout.gauge_to_planar(gauge)
    assert p.shape == (4, 4, 6, layout.GAUGE_COMPS, 4, 8)
    assert p.dtype == jnp.float32
    back = layout.gauge_from_planar(p)
    assert back.dtype == jnp.complex64
    np.testing.assert_array_equal(np.asarray(back), np.asarray(gauge))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_planar_dtype_parameter(spinor, gauge, dtype):
    """The planar dtype is caller-chosen (bf16 for the low-precision
    experiments); decode honours the requested complex dtype."""
    ps = layout.spinor_to_planar(spinor, dtype=dtype)
    pg = layout.gauge_to_planar(gauge, dtype=dtype)
    assert ps.dtype == dtype and pg.dtype == dtype
    back = layout.spinor_from_planar(ps, dtype=jnp.complex64)
    assert back.dtype == jnp.complex64
    tol = 0 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(back), np.asarray(spinor),
                               atol=tol)


def test_spinor_component_ordering(spinor):
    """c = (spin * 3 + color) * 2 + reim — the contract the kernel's
    _c() accessor and gamma5_planar both assume."""
    p = np.asarray(layout.spinor_to_planar(spinor))
    src = np.asarray(spinor)
    for spin, color, reim in ((0, 0, 0), (1, 2, 1), (3, 1, 0), (2, 0, 1)):
        c = (spin * 3 + color) * 2 + reim
        part = src[..., spin, color].real if reim == 0 else \
            src[..., spin, color].imag
        np.testing.assert_array_equal(p[:, :, c], part.astype(np.float32))


def test_gauge_component_ordering(gauge):
    """c = (row * 3 + col) * 2 + reim for the gauge planes."""
    p = np.asarray(layout.gauge_to_planar(gauge))
    src = np.asarray(gauge)
    for row, col, reim in ((0, 0, 0), (2, 1, 1), (1, 2, 0)):
        c = (row * 3 + col) * 2 + reim
        part = src[..., row, col].real if reim == 0 else \
            src[..., row, col].imag
        np.testing.assert_array_equal(p[:, :, :, c],
                                      part.astype(np.float32))


def test_gamma5_planar_matches_complex_gamma5(spinor):
    """gamma5 on planar planes == gamma5 in the complex basis."""
    g5 = jnp.asarray(gamma.GAMMA5)
    want = layout.spinor_to_planar(
        jnp.einsum("ij,...jc->...ic", g5, spinor))
    got = layout.gamma5_planar(layout.spinor_to_planar(spinor))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gamma5_planar_involution(spinor):
    p = layout.spinor_to_planar(spinor)
    np.testing.assert_array_equal(
        np.asarray(layout.gamma5_planar(layout.gamma5_planar(p))),
        np.asarray(p))
