"""Per-architecture smoke tests + decode/forward consistency + block-level
oracles (rwkv chunked vs naive recurrence, ssm scan vs step loop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import blocks, model as M
from conftest import build_small

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_smoke_forward(name):
    c = build_small(name)
    p = M.init_params(c, KEY)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              c.vocab_size)
    kw = {}
    if c.num_prefix_embeds:
        kw["prefix_embeds"] = jnp.ones(
            (B, c.num_prefix_embeds, c.d_model), jnp.bfloat16) * 0.01
    if c.is_enc_dec:
        kw["enc_embeds"] = jnp.ones((B, 12, c.d_model), jnp.bfloat16) * 0.01
    logits, aux = M.forward(c, p, toks, **kw)
    exp_s = S + (c.num_prefix_embeds or 0)
    assert logits.shape == (B, exp_s, c.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_decode_matches_forward(name):
    c = build_small(name)
    p = M.init_params(c, KEY)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              c.vocab_size)
    kw = {}
    if c.num_prefix_embeds:
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(5), (B, c.num_prefix_embeds, c.d_model)
        ).astype(jnp.bfloat16) * 0.1
    if c.is_enc_dec:
        kw["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(6), (B, 12, c.d_model)
        ).astype(jnp.bfloat16) * 0.1
    full, _ = M.forward(c, p, toks, **kw)
    want = full[:, -1].astype(jnp.float32)
    last, cache, idx = M.prefill(
        c, p, toks[:, :S], max_len=S + 8 + (c.num_prefix_embeds or 0),
        cache_dtype=jnp.bfloat16, **kw)
    got, _ = M.decode_step(c, p, cache, toks[:, S:S + 1], idx)
    err = float(jnp.max(jnp.abs(got[:, -1].astype(jnp.float32) - want)))
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    assert err / scale < 0.05, (name, err, scale)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_smoke_train_step(name):
    """One optimizer step on CPU: loss finite, params move, no NaNs."""
    from repro.models import steps as steps_lib
    from repro.optim import adamw

    c = build_small(name)
    p = M.init_params(c, KEY)
    opt = adamw.AdamW(lr=1e-3, total_steps=10, warmup_steps=1)
    st = opt.init(p)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (B, S),
                                          0, c.vocab_size),
             "mask": jnp.ones((B, S), jnp.float32)}
    if c.num_prefix_embeds:
        batch["prefix_embeds"] = jnp.ones(
            (B, c.num_prefix_embeds, c.d_model), jnp.bfloat16) * 0.01
    if c.is_enc_dec:
        batch["enc_embeds"] = jnp.ones((B, 12, c.d_model),
                                       jnp.bfloat16) * 0.01
    step_fn = steps_lib.make_train_step(c, opt, remat=True)
    p2, st2, metrics = step_fn(p, st, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    moved = jax.tree_util.tree_reduce(
        lambda a, b: a + b,
        jax.tree_util.tree_map(
            lambda x, y: float(jnp.max(jnp.abs(x - y))), p, p2))
    assert moved > 0


def test_rwkv_chunked_equals_naive():
    """Chunked WKV6 == naive per-step recurrence."""
    B, H, S, hd, C = 2, 3, 32, 8, 8
    k = jax.random.PRNGKey(2)
    r, kk, v = (jax.random.normal(jax.random.fold_in(k, i),
                                  (B, H, S, hd)) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3),
                                       (B, H, S, hd)) - 2.0)
    u = jax.random.normal(jax.random.fold_in(k, 4), (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))

    # naive recurrence
    outs = []
    s = s0
    for t in range(S):
        kv = jnp.einsum("bhk,bhv->bhkv", kk[:, :, t], v[:, :, t])
        att = s + u[None, :, :, None] * kv
        outs.append(jnp.einsum("bhk,bhkv->bhv", r[:, :, t], att))
        s = jnp.exp(w_log[:, :, t])[..., None] * s + kv
    want = jnp.stack(outs, axis=2)

    got_all = []
    s = s0
    for c0 in range(0, S, C):
        o, s = blocks._wkv_chunk(r[:, :, c0:c0 + C], kk[:, :, c0:c0 + C],
                                 v[:, :, c0:c0 + C],
                                 w_log[:, :, c0:c0 + C], u, s)
        got_all.append(o)
    got = jnp.concatenate(got_all, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_ssm_scan_equals_stepwise():
    """Selective-scan forward == repeated single-step decode."""
    c = build_small("hymba-1.5b")
    p = blocks.ssm_init(jax.random.PRNGKey(0), c)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, c.d_model)) * 0.3
    full, _ = blocks.apply_ssm(p, x, c)
    st = {"h": jnp.zeros((B, c.d_inner, c.ssm_state)),
          "conv": jnp.zeros((B, c.conv_kernel - 1, c.d_inner))}
    outs = []
    for t in range(S):
        o, st = blocks.apply_ssm(p, x[:, t:t + 1], c, state=st)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-3, rtol=5e-2)


def test_moe_gate_mass_and_dropping():
    """MoE combine weights sum to <= 1 per token and == 1 with no drops."""
    from repro.models import layers

    c = build_small("grok-1-314b", capacity_factor=8.0)
    p = layers.moe_init(jax.random.PRNGKey(0), c)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, c.d_model)) * 0.5
    x = x.astype(jnp.bfloat16)
    out, aux = layers.apply_moe(p, x, c)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # identical tokens -> identical outputs (routing determinism)
    xx = jnp.broadcast_to(x[:, :1], x.shape)
    out2, _ = layers.apply_moe(p, xx, c)
    diff = float(jnp.max(jnp.abs(out2[:, 0].astype(jnp.float32)
                                 - out2[:, -1].astype(jnp.float32))))
    assert diff < 1e-2


def test_tiny_overfit_loss_decreases():
    """200 steps on a repeating batch: loss must drop substantially."""
    from repro.models import steps as steps_lib
    from repro.optim import adamw

    c = build_small("deepseek-7b", n_layers=2, d_model=64, vocab_size=64)
    p = M.init_params(c, KEY)
    opt = adamw.AdamW(lr=3e-3, total_steps=120, warmup_steps=10,
                      weight_decay=0.0)
    st = opt.init(p)
    toks = jax.random.randint(jax.random.PRNGKey(4), (4, 32), 0, 64)
    batch = {"tokens": toks, "mask": jnp.ones((4, 32), jnp.float32)}
    step_fn = jax.jit(steps_lib.make_train_step(c, opt, remat=False))
    first = last = None
    for i in range(120):
        p, st, m = step_fn(p, st, batch, jnp.int32(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.5, (first, last)
