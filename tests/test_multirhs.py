"""Multi-RHS batched kernels/solves and mixed-precision refinement.

Covers the acceptance criteria of the multi-RHS PR: batched kernels load
each gauge block once per grid step regardless of nrhs (structural
jaxpr + traffic-model assertions), batched solves agree column-by-column
with independent single-RHS solves on every builtin backend, per-column
convergence masks freeze correctly, BiCGStab breakdown is detected
instead of NaN-poisoning the batch, and mixed-precision refinement
reaches the f64 tolerance the pure-f64 solve reaches with fewer f64
operator applications.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core import evenodd, solver, su3
from repro.kernels import layout
from repro.kernels.wilson_stencil import (fused_dhat_fits,
                                          hop_traffic_model)

BUILTIN_BACKENDS = ("jnp", "pallas", "pallas_fused", "distributed")
NRHS = 2


def _bind(name, Ue, Uo, **extra):
    opts = ({"interpret": True} if name.startswith("pallas")
            and jax.default_backend() != "tpu" else {})
    opts.update(extra)
    return backends.make_wilson_ops(name, Ue, Uo, **opts)


def make_batched_eo(shape, nrhs, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    psi = (jax.random.normal(k, (nrhs, *shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (nrhs, *shape, 4, 3))
           ).astype(jnp.complex64)
    e, o = jax.vmap(evenodd.pack)(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e, o


def test_batched_layout_roundtrip():
    """Planar codecs pass leading batch dims through losslessly and match
    the unbatched conversion column by column."""
    k = jax.random.PRNGKey(3)
    psi = (jax.random.normal(k, (3, 2, 2, 4, 2, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (3, 2, 2, 4, 2, 4, 3))
           ).astype(jnp.complex64)
    p = layout.spinor_to_planar(psi)
    assert p.shape == (3, 2, 2, 24, 4, 2)
    np.testing.assert_array_equal(
        np.asarray(layout.spinor_from_planar(p)), np.asarray(psi))
    for n in range(3):
        np.testing.assert_array_equal(
            np.asarray(p[n]), np.asarray(layout.spinor_to_planar(psi[n])))


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_batched_native_ops_match_unbatched(name, small_eo):
    """Every backend's batched native ops == the unbatched ops applied
    column by column (hop, Dhat, Dhat^dag)."""
    Ue, Uo, _, _, kappa = small_eo
    Ue_, Uo_, e, _ = make_batched_eo((4, 4, 4, 8), NRHS, seed=11)
    bops = _bind(name, Ue_, Uo_)
    v = bops.to_domain_batched(e)
    out = bops.from_domain_batched(bops.apply_dhat_native_batched(v, kappa))
    hop = bops.from_domain_batched(bops.hop_oe_native_batched(v))
    dag = bops.from_domain_batched(
        bops.apply_dhat_dagger_native_batched(v, kappa))
    for n in range(NRHS):
        np.testing.assert_allclose(
            np.asarray(out[n]), np.asarray(bops.apply_dhat(e[n], kappa)),
            atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(hop[n]), np.asarray(bops.hop_oe(e[n])), atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(dag[n]),
            np.asarray(bops.apply_dhat_dagger(e[n], kappa)), atol=2e-5)


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_batched_solve_matches_sequential(name):
    """Acceptance: a batched solve agrees column-by-column with N
    independent single-RHS solves, on every builtin backend."""
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), NRHS, seed=21)
    kappa = 0.13
    bops = _bind(name, Ue, Uo)
    xe_b, xo_b, res = solver.solve_wilson_eo(
        Ue, Uo, e, o, kappa, method="bicgstab", tol=1e-5, backend=bops)
    assert res.converged.shape == (NRHS,)
    assert bool(res.converged.all()), res
    for n in range(NRHS):
        xe_1, xo_1, _ = solver.solve_wilson_eo(
            Ue, Uo, e[n], o[n], kappa, method="bicgstab", tol=1e-5,
            backend=bops)
        for got, want in ((xe_b[n], xe_1), (xo_b[n], xo_1)):
            d = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
            assert d < 1e-4, (name, n, d)


def test_gauge_loaded_once_per_grid_step(small_eo):
    """Acceptance: the batched hop lowers to ONE pallas_call (not nrhs of
    them / no vmap-unrolled kernels), its grid is the (T, Z) plane grid,
    and the traffic model's gauge term is nrhs-independent."""
    Ue, Uo, _, _, _ = small_eo
    bops = _bind("pallas", Ue, Uo)
    _, _, e, _ = make_batched_eo((4, 4, 4, 8), 4, seed=31)
    v = bops.to_domain_batched(e)
    jaxpr = jax.make_jaxpr(lambda w: bops.hop_oe_native_batched(w))(v)
    txt = str(jaxpr)
    assert txt.count("pallas_call") == 1, txt.count("pallas_call")
    # One batched Dhat through the fused backend is also a single kernel.
    bops_f = _bind("pallas_fused", Ue, Uo)
    vf = bops_f.to_domain_batched(e)
    txt_f = str(jax.make_jaxpr(
        lambda w: bops_f.apply_dhat_native_batched(w, 0.13))(vf))
    assert txt_f.count("pallas_call") == 1
    # Gauge bytes of the model don't grow with nrhs; spinor bytes do.
    m1 = hop_traffic_model(4, 4, 4, 4, nrhs=1)
    m8 = hop_traffic_model(4, 4, 4, 4, nrhs=8)
    assert m1["bytes_gauge"] == m8["bytes_gauge"]
    assert m8["bytes_spinor"] == 8 * m1["bytes_spinor"]
    assert (m8["intensity_flops_per_byte"]
            > 2 * m1["intensity_flops_per_byte"])


def test_batched_cg_convergence_mask_freezes():
    """Converged columns freeze: a zero RHS converges at iteration 0 and
    its iterate never moves; scaled columns converge to scaled solutions
    with identical iteration counts."""
    n = 32
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b1 = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    b = jnp.stack([jnp.zeros(n), b1, 3.0 * b1])
    res = solver.cg_batched(lambda v: (A @ v.T).T, b, tol=1e-7,
                            max_iters=200)
    assert bool(res.converged.all()), res
    assert int(res.iterations[0]) == 0
    assert float(jnp.abs(res.x[0]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(res.x[2]), 3 * np.asarray(res.x[1]),
                               rtol=1e-4)
    # Mixed difficulty: an easy (well-scaled) column must not keep
    # iterating while a harder one finishes — its recorded iteration
    # count is where it froze, <= the batch maximum.
    assert int(res.iterations[1]) <= int(res.iterations.max())


def test_bicgstab_breakdown_guard_unbatched():
    """Skew-symmetric system: <r0, v> = 0 at the first iteration — the
    classic BiCGStab breakdown.  The guard freezes the state and reports
    converged=False instead of NaN."""
    A = jnp.array([[0.0, 1.0], [-1.0, 0.0]])
    b = jnp.array([1.0, 0.0])
    res = solver.bicgstab(lambda v: A @ v, b, tol=1e-8, max_iters=50)
    assert not bool(res.converged)
    assert np.isfinite(np.asarray(res.x)).all()
    assert np.isfinite(float(res.residual))


def test_bicgstab_breakdown_guard_batched():
    """A broken-down column freezes (finite, converged=False) without
    poisoning its batch mates, and records the iteration it froze at."""
    A = jnp.array([[0.0, 1.0], [-1.0, 0.0]])
    b = jnp.stack([jnp.zeros(2), jnp.array([1.0, 0.0])])
    res = solver.bicgstab_batched(lambda v: (A @ v.T).T, b, tol=1e-8,
                                  max_iters=50)
    assert bool(res.converged[0])       # zero RHS: converged at start
    assert not bool(res.converged[1])   # breakdown column: frozen, honest
    assert np.isfinite(np.asarray(res.x)).all()
    assert int(res.iterations[0]) == 0
    assert int(res.iterations[1]) == 1  # broke down AT iteration 1, not 0


def test_bicgstab_batched_recompute_every():
    """recompute_every is honored inside the batched while_loop too."""
    n = 24
    key = jax.random.PRNGKey(7)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, n))
    op = lambda v: (A @ v.T).T  # noqa: E731
    plain = solver.bicgstab_batched(op, b, tol=1e-6, max_iters=200)
    recomp = solver.bicgstab_batched(op, b, tol=1e-6, max_iters=200,
                                     recompute_every=3)
    assert bool(recomp.converged.all()), recomp
    np.testing.assert_allclose(np.asarray(recomp.x), np.asarray(plain.x),
                               atol=1e-4)


def test_inner_dtype_rejects_explicit_operator_fns():
    """Mixed precision rebuilds the operator from the gauge field; a
    silent mismatch with explicit *_fn overrides must be an error."""
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), 1, seed=45)
    with pytest.raises(ValueError, match="operator overrides"):
        solver.solve_wilson_eo(
            Ue, Uo, e[0], o[0], 0.13, inner_dtype="f32",
            apply_dhat_fn=lambda v: v)


def test_bicgstab_healthy_solves_still_converge(small_eo):
    """The breakdown guards must not trip on a healthy Wilson solve."""
    Ue, Uo, e, o, kappa = small_eo
    xe, xo, res = solver.solve_wilson_eo(Ue, Uo, e, o, kappa,
                                         method="bicgstab", tol=1e-5)
    assert bool(res.converged), res


def test_mixed_precision_reaches_f64_tol():
    """Acceptance: inner_dtype=f32 refinement converges to the f64
    tolerance the pure-f64 solve reaches, with fewer f64 operator
    applications (counted: CGNR pays ~2/iteration in f64; refinement
    pays ~1 per outer pass)."""
    from jax.experimental import enable_x64

    tol = 1e-10
    with enable_x64():
        Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), 1, seed=41)
        e, o = e[0].astype(jnp.complex128), o[0].astype(jnp.complex128)
        U64e = Ue.astype(jnp.complex128)
        U64o = Uo.astype(jnp.complex128)

        _, _, pure = solver.solve_wilson_eo(
            U64e, U64o, e, o, 0.13, method="cgnr", tol=tol, backend="jnp")
        assert bool(pure.converged)
        pure_applies = 2 * int(pure.iterations) + 2

        cfg = solver.SolverConfig(tol=tol, max_iters=2000,
                                  inner_dtype="f32")
        xe, xo, mix = solver.solve_wilson_eo(
            U64e, U64o, e, o, 0.13, method="cgnr", config=cfg,
            backend="jnp")
        assert bool(mix.converged), mix
        assert mix.f64_applies < pure_applies, (mix.f64_applies,
                                                pure_applies)
        # Independent f64 residual of the refined solution.
        rhs = e + 0.13 * evenodd.hop_eo(U64e, U64o, o)
        r = rhs - evenodd.apply_dhat(U64e, U64o,
                                     xe.astype(jnp.complex128), 0.13)
        rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(rhs))
        assert rel <= tol, rel


def test_mixed_precision_requires_x64():
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), 1, seed=43)
    if jnp.zeros((), jnp.float64).dtype == jnp.dtype(jnp.float64):
        pytest.skip("x64 already enabled in this session")
    with pytest.raises(ValueError, match="x64"):
        solver.solve_wilson_eo(Ue, Uo, e[0], o[0], 0.13,
                               inner_dtype="f32", backend="jnp")


def test_fused_dhat_fits_dtype_derived():
    """The scratch-budget check sizes elements by the ACTUAL dtype (and
    accepts batched shapes): a shape that fits in f32 can exceed the
    budget in f64, and a batched block multiplies the scratch by nrhs."""
    shape = (8, 8, 24, 32, 36)   # 7.1 MiB f32, 14.2 MiB f64
    assert fused_dhat_fits(shape)                      # default f32
    assert fused_dhat_fits(shape, jnp.float32)
    assert not fused_dhat_fits(shape, jnp.float64)
    assert fused_dhat_fits(shape, jnp.bfloat16)
    assert fused_dhat_fits(shape, 4)                   # itemsize backcompat
    assert not fused_dhat_fits((4, *shape))            # nrhs=4 batched
    assert fused_dhat_fits((2,) + (4, 4, 24, 8, 4))


def test_solve_wilson_eo_batched_via_explicit_fns():
    """The legacy explicit-callable wiring also supports batched sources
    (through the automatic vmap fallback of the identity domain)."""
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), NRHS, seed=51)
    kappa = 0.13
    xe, xo, res = solver.solve_wilson_eo(
        Ue, Uo, e, o, kappa, method="bicgstab", tol=1e-5,
        apply_dhat_fn=None)   # pure evenodd reference ops
    assert res.converged.shape == (NRHS,)
    assert bool(res.converged.all())
    xe_1, _, _ = solver.solve_wilson_eo(Ue, Uo, e[0], o[0], kappa,
                                        method="bicgstab", tol=1e-5)
    d = float(jnp.linalg.norm(xe[0] - xe_1) / jnp.linalg.norm(xe_1))
    assert d < 1e-4, d
