"""Multi-RHS batched kernels/solves and mixed-precision refinement.

Covers the acceptance criteria of the multi-RHS PR: batched kernels load
each gauge block once per grid step regardless of nrhs (structural
jaxpr + traffic-model assertions), batched solves agree column-by-column
with independent single-RHS solves on every builtin backend, per-column
convergence masks freeze correctly, BiCGStab breakdown is detected
instead of NaN-poisoning the batch, and mixed-precision refinement
reaches the f64 tolerance the pure-f64 solve reaches with fewer f64
operator applications.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, backends
from repro.core import evenodd, solver, su3
from repro.kernels import layout
from repro.kernels.wilson_stencil import (fused_dhat_fits,
                                          hop_traffic_model)

BUILTIN_BACKENDS = ("jnp", "pallas", "pallas_fused", "distributed")
NRHS = 2


def _bind(name, Ue, Uo, **extra):
    opts = ({"interpret": True} if name.startswith("pallas")
            and jax.default_backend() != "tpu" else {})
    opts.update(extra)
    return backends.make_wilson_ops(name, Ue, Uo, **opts)


def make_batched_eo(shape, nrhs, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    psi = (jax.random.normal(k, (nrhs, *shape, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (nrhs, *shape, 4, 3))
           ).astype(jnp.complex64)
    e, o = jax.vmap(evenodd.pack)(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e, o


def test_batched_layout_roundtrip():
    """Planar codecs pass leading batch dims through losslessly and match
    the unbatched conversion column by column."""
    k = jax.random.PRNGKey(3)
    psi = (jax.random.normal(k, (3, 2, 2, 4, 2, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (3, 2, 2, 4, 2, 4, 3))
           ).astype(jnp.complex64)
    p = layout.spinor_to_planar(psi)
    assert p.shape == (3, 2, 2, 24, 4, 2)
    np.testing.assert_array_equal(
        np.asarray(layout.spinor_from_planar(p)), np.asarray(psi))
    for n in range(3):
        np.testing.assert_array_equal(
            np.asarray(p[n]), np.asarray(layout.spinor_to_planar(psi[n])))


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_batched_native_ops_match_unbatched(name, small_eo):
    """Every backend's batched native ops == the unbatched ops applied
    column by column (hop, Dhat, Dhat^dag)."""
    Ue, Uo, _, _, kappa = small_eo
    Ue_, Uo_, e, _ = make_batched_eo((4, 4, 4, 8), NRHS, seed=11)
    bops = _bind(name, Ue_, Uo_)
    v = bops.to_domain_batched(e)
    out = bops.from_domain_batched(bops.apply_dhat_native_batched(v, kappa))
    hop = bops.from_domain_batched(bops.hop_oe_native_batched(v))
    dag = bops.from_domain_batched(
        bops.apply_dhat_dagger_native_batched(v, kappa))
    for n in range(NRHS):
        np.testing.assert_allclose(
            np.asarray(out[n]), np.asarray(bops.apply_dhat(e[n], kappa)),
            atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(hop[n]), np.asarray(bops.hop_oe(e[n])), atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(dag[n]),
            np.asarray(bops.apply_dhat_dagger(e[n], kappa)), atol=2e-5)


@pytest.mark.parametrize("name", BUILTIN_BACKENDS)
def test_batched_solve_matches_sequential(name):
    """Acceptance: a batched solve agrees column-by-column with N
    independent single-RHS solves, on every builtin backend."""
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), NRHS, seed=21)
    kappa = 0.13
    bops = _bind(name, Ue, Uo)
    session = api.SolveSession(
        api.WilsonMatrix.from_ops(bops, kappa, gauge=(Ue, Uo)),
        api.SolveSpec(method="bicgstab", tol=1e-5))
    xe_b, xo_b, res = session.solve(e, o)
    assert res.converged.shape == (NRHS,)
    assert bool(res.converged.all()), res
    for n in range(NRHS):
        xe_1, xo_1, _ = session.solve(e[n], o[n])
        for got, want in ((xe_b[n], xe_1), (xo_b[n], xo_1)):
            d = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
            assert d < 1e-4, (name, n, d)


def test_gauge_loaded_once_per_grid_step(small_eo):
    """Acceptance: the batched hop lowers to ONE pallas_call (not nrhs of
    them / no vmap-unrolled kernels), its grid is the (T, Z) plane grid,
    and the traffic model's gauge term is nrhs-independent."""
    Ue, Uo, _, _, _ = small_eo
    bops = _bind("pallas", Ue, Uo)
    _, _, e, _ = make_batched_eo((4, 4, 4, 8), 4, seed=31)
    v = bops.to_domain_batched(e)
    jaxpr = jax.make_jaxpr(lambda w: bops.hop_oe_native_batched(w))(v)
    txt = str(jaxpr)
    assert txt.count("pallas_call") == 1, txt.count("pallas_call")
    # One batched Dhat through the fused backend is also a single kernel.
    bops_f = _bind("pallas_fused", Ue, Uo)
    vf = bops_f.to_domain_batched(e)
    txt_f = str(jax.make_jaxpr(
        lambda w: bops_f.apply_dhat_native_batched(w, 0.13))(vf))
    assert txt_f.count("pallas_call") == 1
    # Gauge bytes of the model don't grow with nrhs; spinor bytes do.
    m1 = hop_traffic_model(4, 4, 4, 4, nrhs=1)
    m8 = hop_traffic_model(4, 4, 4, 4, nrhs=8)
    assert m1["bytes_gauge"] == m8["bytes_gauge"]
    assert m8["bytes_spinor"] == 8 * m1["bytes_spinor"]
    assert (m8["intensity_flops_per_byte"]
            > 2 * m1["intensity_flops_per_byte"])


def test_batched_cg_convergence_mask_freezes():
    """Converged columns freeze: a zero RHS converges at iteration 0 and
    its iterate never moves; scaled columns converge to scaled solutions
    with identical iteration counts."""
    n = 32
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b1 = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    b = jnp.stack([jnp.zeros(n), b1, 3.0 * b1])
    res = solver.cg_batched(lambda v: (A @ v.T).T, b, tol=1e-7,
                            max_iters=200)
    assert bool(res.converged.all()), res
    assert int(res.iterations[0]) == 0
    assert float(jnp.abs(res.x[0]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(res.x[2]), 3 * np.asarray(res.x[1]),
                               rtol=1e-4)
    # Mixed difficulty: an easy (well-scaled) column must not keep
    # iterating while a harder one finishes — its recorded iteration
    # count is where it froze, <= the batch maximum.
    assert int(res.iterations[1]) <= int(res.iterations.max())


def test_bicgstab_breakdown_guard_unbatched():
    """Skew-symmetric system: <r0, v> = 0 at the first iteration — the
    classic BiCGStab breakdown.  The guard freezes the state and reports
    converged=False instead of NaN."""
    A = jnp.array([[0.0, 1.0], [-1.0, 0.0]])
    b = jnp.array([1.0, 0.0])
    res = solver.bicgstab(lambda v: A @ v, b, tol=1e-8, max_iters=50)
    assert not bool(res.converged)
    assert np.isfinite(np.asarray(res.x)).all()
    assert np.isfinite(float(res.residual))


def test_bicgstab_breakdown_guard_batched():
    """A broken-down column freezes (finite, converged=False) without
    poisoning its batch mates, and records the iteration it froze at."""
    A = jnp.array([[0.0, 1.0], [-1.0, 0.0]])
    b = jnp.stack([jnp.zeros(2), jnp.array([1.0, 0.0])])
    res = solver.bicgstab_batched(lambda v: (A @ v.T).T, b, tol=1e-8,
                                  max_iters=50)
    assert bool(res.converged[0])       # zero RHS: converged at start
    assert not bool(res.converged[1])   # breakdown column: frozen, honest
    assert np.isfinite(np.asarray(res.x)).all()
    assert int(res.iterations[0]) == 0
    assert int(res.iterations[1]) == 1  # broke down AT iteration 1, not 0


def test_bicgstab_batched_recompute_every():
    """recompute_every is honored inside the batched while_loop too."""
    n = 24
    key = jax.random.PRNGKey(7)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, n))
    op = lambda v: (A @ v.T).T  # noqa: E731
    plain = solver.bicgstab_batched(op, b, tol=1e-6, max_iters=200)
    recomp = solver.bicgstab_batched(op, b, tol=1e-6, max_iters=200,
                                     recompute_every=3)
    assert bool(recomp.converged.all()), recomp
    np.testing.assert_allclose(np.asarray(recomp.x), np.asarray(plain.x),
                               atol=1e-4)


def test_bicgstab_healthy_solves_still_converge(small_eo):
    """The breakdown guards must not trip on a healthy Wilson solve."""
    Ue, Uo, e, o, kappa = small_eo
    xe, xo, res = api.solve(
        Ue, Uo, e, o, kappa,
        spec=api.SolveSpec(method="bicgstab", tol=1e-5))
    assert bool(res.converged), res


def test_mixed_precision_reaches_f64_tol():
    """Acceptance: inner_dtype=f32 refinement converges to the f64
    tolerance the pure-f64 solve reaches, with fewer f64 operator
    applications (counted: CGNR pays ~2/iteration in f64; refinement
    pays ~1 per outer pass)."""
    from jax.experimental import enable_x64

    tol = 1e-10
    with enable_x64():
        Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), 1, seed=41)
        e, o = e[0].astype(jnp.complex128), o[0].astype(jnp.complex128)
        U64e = Ue.astype(jnp.complex128)
        U64o = Uo.astype(jnp.complex128)

        _, _, pure = api.solve(
            U64e, U64o, e, o, 0.13, backend="jnp",
            spec=api.SolveSpec(method="cgnr", tol=tol))
        assert bool(pure.converged)
        pure_applies = 2 * int(pure.iterations) + 2

        spec = api.SolveSpec(method="cgnr", tol=tol, max_iters=2000,
                             inner_dtype="f32")
        xe, xo, mix = api.solve(U64e, U64o, e, o, 0.13, backend="jnp",
                                spec=spec)
        assert bool(mix.converged), mix
        assert mix.f64_applies < pure_applies, (mix.f64_applies,
                                                pure_applies)
        # Independent f64 residual of the refined solution.
        rhs = e + 0.13 * evenodd.hop_eo(U64e, U64o, o)
        r = rhs - evenodd.apply_dhat(U64e, U64o,
                                     xe.astype(jnp.complex128), 0.13)
        rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(rhs))
        assert rel <= tol, rel


def test_mixed_precision_requires_x64():
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), 1, seed=43)
    if jnp.zeros((), jnp.float64).dtype == jnp.dtype(jnp.float64):
        pytest.skip("x64 already enabled in this session")
    with pytest.raises(ValueError, match="x64"):
        api.solve(Ue, Uo, e[0], o[0], 0.13, backend="jnp",
                  spec=api.SolveSpec(inner_dtype="f32"))


def test_fused_dhat_fits_dtype_derived():
    """The scratch-budget check sizes elements by the ACTUAL dtype (and
    accepts batched shapes): a shape that fits in f32 can exceed the
    budget in f64, and a batched block multiplies the scratch by nrhs."""
    shape = (8, 8, 24, 32, 36)   # 7.1 MiB f32, 14.2 MiB f64
    assert fused_dhat_fits(shape)                      # default f32
    assert fused_dhat_fits(shape, jnp.float32)
    assert not fused_dhat_fits(shape, jnp.float64)
    assert fused_dhat_fits(shape, jnp.bfloat16)
    assert fused_dhat_fits(shape, 4)                   # itemsize backcompat
    assert not fused_dhat_fits((4, *shape))            # nrhs=4 batched
    assert fused_dhat_fits((2,) + (4, 4, 24, 8, 4))


def test_bvdot_bf16_accumulates_in_f32(monkeypatch):
    """The compensated reduction's actual mechanism: bf16 PRODUCTS round
    to 8 mantissa bits before the sum, so a cancellation-heavy dot loses
    significance naively; upcasting the operands first makes every
    product exact in f32 (8x8 mantissa bits < 24).  Deterministic data,
    both the unbatched and per-column reductions."""
    rs = np.random.RandomState(0)
    x64 = rs.standard_normal(8192)
    y64 = np.random.RandomState(1).standard_normal(8192)
    x = jnp.asarray(x64, jnp.bfloat16)
    y = jnp.asarray(y64, jnp.bfloat16)
    # Truth = exact dot of the bf16-rounded inputs (what compensation
    # can and should recover; input rounding is not its job).
    truth = float(np.vdot(np.asarray(x, np.float64),
                          np.asarray(y, np.float64)))

    xb, yb = x.reshape(2, -1), y.reshape(2, -1)
    tb = np.vdot(np.asarray(xb[0], np.float64), np.asarray(yb[0], np.float64))

    monkeypatch.setattr(solver, "COMPENSATED_REDUCTIONS", False)
    naive_b = abs(float(solver._bvdot(xb, yb)[0]) - tb)
    monkeypatch.setattr(solver, "COMPENSATED_REDUCTIONS", True)
    comp = float(solver._vdot(x, y))
    comp_b = abs(float(solver._bvdot(xb, yb)[0]) - tb)

    assert abs(comp - truth) < 1e-3, (comp, truth)
    assert comp_b < 1e-3, comp_b
    assert naive_b > 0.01, naive_b          # ~0.06 observed: products
    assert naive_b > 10 * max(comp_b, 1e-9)  # rounded before the sum
    # And the scalars come back f32, not bf16.
    assert solver._vdot(x, y).dtype == jnp.float32
    assert solver._bvdot(xb, yb).dtype == jnp.float32


def test_compensated_scalars_do_not_promote_bf16_iterates():
    """f32-accumulated scalars must be cast DOWN at the axpy: the vector
    (and hence the solver's memory traffic) stays bf16."""
    x = jnp.ones((16,), jnp.bfloat16)
    y = jnp.ones((16,), jnp.bfloat16)
    alpha = jnp.float32(0.5)
    out = solver._axpy(alpha, x, y)
    assert out.dtype == jnp.bfloat16
    outb = solver._baxpy(jnp.ones((2,), jnp.float32) * 0.5,
                         x.reshape(2, 8), y.reshape(2, 8))
    assert outb.dtype == jnp.bfloat16
    # Complex/f32 domains are untouched (no spurious casts).
    xc = jnp.ones((4,), jnp.complex64)
    assert solver._axpy(jnp.float32(2.0), xc, xc).dtype == jnp.complex64
    assert solver._vdot(xc, xc).dtype == jnp.complex64


def _make_bf16_planar_ops(Ue, Uo, dtype=jnp.bfloat16):
    """Planar-native bf16 Wilson operators via the pure-XLA stencil
    (periodic wrap by halo padding) — the compile-cheap stand-in for the
    Pallas bf16 backend, wired through the public extension API."""
    from repro.kernels.wilson_stencil import hop_block_ext_planar_native

    u_e_p = layout.gauge_to_planar(Ue, dtype)
    u_o_p = layout.gauge_to_planar(Uo, dtype)

    def wrap_s(v):
        pad = [(0, 0)] * (v.ndim - 5) + [(1, 1), (1, 1), (0, 0), (0, 0),
                                         (0, 0)]
        return jnp.pad(v, pad, mode="wrap")

    def wrap_g(u):
        return jnp.pad(u, ((0, 0), (1, 1), (1, 1), (0, 0), (0, 0), (0, 0)),
                       mode="wrap")

    ue_ext, uo_ext = wrap_g(u_e_p), wrap_g(u_o_p)

    def hop_oe(v):
        return hop_block_ext_planar_native(u_o_p, ue_ext, wrap_s(v), 1)

    def hop_eo(v):
        return hop_block_ext_planar_native(u_e_p, uo_ext, wrap_s(v), 0)

    def dhat(v, kappa):
        return v - jnp.asarray(float(kappa) ** 2, dtype) * hop_eo(hop_oe(v))

    def dag(v, kappa):
        return layout.gamma5_planar(dhat(layout.gamma5_planar(v), kappa))

    to_d = lambda psi: layout.spinor_to_planar(psi, dtype=dtype)  # noqa: E731
    from_d = layout.spinor_from_planar
    return backends.WilsonOps.from_native(
        "planar_bf16_test", domain="planar",
        to_domain=to_d, from_domain=from_d,
        hop_oe=hop_oe, hop_eo=hop_eo,
        apply_dhat=dhat, apply_dhat_dagger=dag,
        to_domain_batched=to_d, from_domain_batched=from_d,
        hop_oe_batched=hop_oe, hop_eo_batched=hop_eo,
        apply_dhat_batched=dhat, apply_dhat_dagger_batched=dag)


def test_bf16_inner_converges_where_naive_stalls(monkeypatch):
    """Acceptance for the compensated reductions: at kappa = 0.24 (a hard,
    near-critical system) and inner_tol = 1e-3,

    * NAIVE bf16 accumulation stalls: the batched BiCGStab inner solve
      reports convergence but its bf16-product Krylov scalars (rho,
      <r0,v> — cancellation-heavy dots) are noise, and the iterate's TRUE
      residual is >= O(1): no actual progress, which would poison every
      refinement pass built on it;
    * with COMPENSATED (f32-accumulate) scalars the same inner solve
      genuinely contracts the error, and the full
      ``SolveSpec(inner_dtype="bf16", inner_tol=1e-3)`` refinement
      converges to the f64 tolerance.
    """
    from jax.experimental import enable_x64

    kappa, nrhs, inner_tol = 0.24, 2, 1e-3
    Ue, Uo, e, o = make_batched_eo((4, 4, 4, 8), nrhs, seed=0)
    bops = _make_bf16_planar_ops(Ue, Uo)
    ops32 = _make_bf16_planar_ops(Ue, Uo, dtype=jnp.float32)
    v = bops.to_domain_batched(e)
    v32 = ops32.to_domain_batched(e)
    b2 = jnp.sum(v32 * v32, axis=(1, 2, 3, 4, 5))

    def true_rel(x):
        r = v32 - ops32.apply_dhat_native_batched(
            x.astype(jnp.float32), kappa)
        return np.sqrt(np.asarray(
            jnp.sum(r * r, axis=(1, 2, 3, 4, 5)) / b2))

    op = lambda w: bops.apply_dhat_native_batched(w, kappa)  # noqa: E731

    monkeypatch.setattr(solver, "COMPENSATED_REDUCTIONS", False)
    naive = solver.bicgstab_batched(op, v, tol=inner_tol, max_iters=100)
    naive_rel = true_rel(naive.x)

    monkeypatch.setattr(solver, "COMPENSATED_REDUCTIONS", True)
    comp = solver.bicgstab_batched(op, v, tol=inner_tol, max_iters=100)
    comp_rel = true_rel(comp.x)

    # Naive: at least one column made no real progress at all (true
    # residual >= ~1 while the bf16-scalar recursion *reported* 1e-3);
    # compensated: every column genuinely contracted.
    assert naive_rel.max() > 0.7, naive_rel
    assert comp_rel.max() < 0.5, comp_rel
    assert comp_rel.max() < naive_rel.max() / 2, (naive_rel, comp_rel)

    # End to end: --inner-dtype bf16 refinement through the same
    # operators reaches the f64 tolerance with compensated scalars.
    with enable_x64():
        e64, o64 = e.astype(jnp.complex128), o.astype(jnp.complex128)
        matrix = api.WilsonMatrix.from_ops(
            bops, kappa, gauge=(Ue.astype(jnp.complex128),
                                Uo.astype(jnp.complex128)))
        spec = api.SolveSpec(method="bicgstab", tol=1e-3,
                             inner_dtype="bf16", inner_tol=inner_tol,
                             max_outer=10)
        xe, _, res = api.SolveSession(matrix, spec).solve(e64, o64)
        assert bool(jnp.all(res.converged)), res
        assert res.outer_iterations <= 10
