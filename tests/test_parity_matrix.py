"""Cross-backend parity property-test matrix.

One suite locking down that EVERY registered operator backend — the
pure-XLA reference, both Pallas stencil variants, the streaming
plane-window fused kernel, and the shard_map'd distributed operator —
computes the *same* ``Dhat`` / ``Dhat^dag`` / batched-``Dhat`` map as the
``jnp`` reference, across

* dtype  in {f32, f64}  (planar compute dtype; complex64/128 interface),
* nrhs   in {1, 4}      (batched native ops, leading RHS axis),
* odd lattice extents    (odd T/Z/Y and odd Xh stress every periodic
  wrap: the modular BlockSpec index maps, the scratch-ring boundary rows
  of the streaming kernel, and the parity-masked x-roll).

Two further axes ride on the same harness: ``gauge_compression``
(two_row / minimal compressed links must reproduce the uncompressed
output of the *same* backend within the codec round-trip error) and the
distributed ``overlap="interior"`` schedule (comms/compute overlap must
be numerically invisible).

The deterministic matrix below always runs; a hypothesis layer widens
the lattice/seed space when hypothesis is installed (CI installs it via
requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core import evenodd, su3

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # deterministic matrix still runs without it
    HAVE_HYPOTHESIS = False

DTYPES = ("f32", "f64")
NRHS = (1, 4)
# Odd T/Z/Y; X=6 gives odd Xh=3 — every axis wraps mid-parity-pattern.
ODD_LATTICE = (3, 5, 3, 6)

_PLANAR = {"f32": jnp.float32, "f64": jnp.float64}
_COMPLEX = {"f32": jnp.complex64, "f64": jnp.complex128}
_ATOL = {"f32": 5e-5, "f64": 1e-10}


def all_backends():
    return backends.available_backends()


def _bind(name, Ue, Uo, dtype, **extra):
    opts = {"dtype": _PLANAR[dtype]} if name != "jnp" else {}
    if name.startswith("pallas") and jax.default_backend() != "tpu":
        opts["interpret"] = True
    opts.update(extra)
    ops = backends.make_wilson_ops(name, Ue, Uo, **opts)
    if name == "distributed":
        # Eager shard_map dispatches the body op-by-op (minutes per
        # Dhat); jit the entry points the matrix exercises.
        import dataclasses
        ops = dataclasses.replace(
            ops,
            apply_dhat=jax.jit(ops.apply_dhat, static_argnums=1),
            apply_dhat_dagger=jax.jit(ops.apply_dhat_dagger,
                                      static_argnums=1),
            apply_dhat_native_batched=jax.jit(
                ops.apply_dhat_native_batched, static_argnums=1))
    return ops


def _fields(shape, dtype, nrhs, seed=0):
    cdt = _COMPLEX[dtype]
    # Generate the gauge at the target precision: compressed-link
    # reconstruction relies on unitarity *at that precision*, and an
    # f32-generated field upcast to f64 is only unitary to ~1e-7.
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape, dtype=cdt)
    k = jax.random.PRNGKey(seed + 1)
    bshape = (nrhs, *shape, 4, 3)
    psi = (jax.random.normal(k, bshape)
           + 1j * jax.random.normal(jax.random.fold_in(k, 1), bshape)
           ).astype(cdt)
    e, _ = jax.vmap(evenodd.pack)(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    return Ue, Uo, e


def _check_parity(name, shape, dtype, nrhs, seed=0, **bind_opts):
    """Dhat / Dhat^dag / batched-Dhat of ``name`` vs the jnp reference."""
    kappa = 0.13
    atol = _ATOL[dtype]
    Ue, Uo, e = _fields(shape, dtype, nrhs, seed=seed)
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    bops = _bind(name, Ue, Uo, dtype, **bind_opts)

    want = jnp.stack([ref.apply_dhat(e[n], kappa) for n in range(nrhs)])

    # Unbatched ops (Dhat and its dagger), column by column — the
    # nrhs=1 leg of the matrix carries these; the nrhs>1 legs would
    # repeat byte-identical work and only re-exercise cached kernels.
    if nrhs == 1:
        want_dag = jnp.stack(
            [ref.apply_dhat_dagger(e[n], kappa) for n in range(nrhs)])
        for n in range(nrhs):
            np.testing.assert_allclose(
                np.asarray(bops.apply_dhat(e[n], kappa)),
                np.asarray(want[n]), atol=atol,
                err_msg=f"{name} Dhat col {n} {shape} {dtype}")
            np.testing.assert_allclose(
                np.asarray(bops.apply_dhat_dagger(e[n], kappa)),
                np.asarray(want_dag[n]), atol=atol,
                err_msg=f"{name} Dhat^dag col {n} {shape} {dtype}")

    # Batched native op, whole block at once.
    v = bops.to_domain_batched(e)
    got = bops.from_domain_batched(
        bops.apply_dhat_native_batched(v, kappa)).astype(e.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol,
                               err_msg=f"{name} batched Dhat {shape} "
                                       f"{dtype} nrhs={nrhs}")


def _x64_ctx(dtype):
    from jax.experimental import enable_x64
    import contextlib
    return enable_x64() if dtype == "f64" else contextlib.nullcontext()


def test_matrix_covers_every_registered_backend():
    """The matrix below parametrizes over the LIVE registry — a new
    backend is locked down the moment it registers (and the streaming
    backend is registered)."""
    assert "pallas_fused_stream" in all_backends()
    assert "jnp" in all_backends()


@pytest.mark.parametrize("nrhs", NRHS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", all_backends())
def test_backend_parity_odd_lattice(name, dtype, nrhs):
    with _x64_ctx(dtype):
        _check_parity(name, ODD_LATTICE, dtype, nrhs)


# --- compressed gauge links ------------------------------------------

COMPRESSIONS = ("two_row", "minimal")
# atol vs the *same backend uncompressed* — isolates the codec error
# from the backend-vs-reference error the matrix above already bounds.
_C_ATOL = {("two_row", "f32"): 1e-5, ("two_row", "f64"): 1e-12,
           ("minimal", "f32"): 1e-5, ("minimal", "f64"): 1e-9}


@pytest.mark.parametrize("dtype", DTYPES)
def test_compressed_gauge_parity(dtype):
    """Every backend that advertises a compressed link representation
    reproduces its own uncompressed Dhat within the codec round-trip
    error (capability-gated over the live registry; the uncompressed
    reference is bound once per backend and shared across codecs)."""
    kappa = 0.13
    ran = 0
    with _x64_ctx(dtype):
        Ue, Uo, e = _fields(ODD_LATTICE, dtype, 1)
        for name in all_backends():
            caps = backends.backend_info(name)
            modes = [c for c in COMPRESSIONS
                     if c in caps.gauge_compressions]
            if not modes:
                continue
            plain = _bind(name, Ue, Uo, dtype)
            want = np.asarray(plain.apply_dhat(e[0], kappa))
            for compression in modes:
                comp = _bind(name, Ue, Uo, dtype,
                             gauge_compression=compression)
                np.testing.assert_allclose(
                    np.asarray(comp.apply_dhat(e[0], kappa)), want,
                    atol=_C_ATOL[compression, dtype],
                    err_msg=f"{name} {compression} {dtype}")
                ran += 1
    assert ran >= 8   # pallas x3 + distributed, two codecs each


# --- chaos legs: NaN-column containment across backends x codecs -----


def test_chaos_nan_column_containment_matrix():
    """A NaN injected into one RHS column of the batched solve stays in
    that column on EVERY backend and every supported gauge codec: the
    poisoned column exits ``diverged`` and the healthy columns are
    BIT-EXACT with the uninjected run (per-column Krylov scalars and a
    column-local operator never mix columns — the containment property
    the divergence guard's per-column freeze relies on)."""
    from repro.core import solver
    from repro.resilience import nan_spinor_column

    kappa = 0.13
    nrhs = 3
    ran = 0
    Ue, Uo, e = _fields(ODD_LATTICE, "f32", nrhs)
    e_bad = nan_spinor_column(e, 1)
    for name in all_backends():
        caps = backends.backend_info(name)
        modes = ("none",) + tuple(c for c in COMPRESSIONS
                                  if c in caps.gauge_compressions)
        for compression in modes:
            extra = ({} if compression == "none"
                     else {"gauge_compression": compression})
            bops = _bind(name, Ue, Uo, "f32", **extra)
            run = jax.jit(solver.make_native_solve(
                bops, kappa, method="cgnr", tol=1e-3, max_iters=12,
                batched=True))
            v_o = bops.to_domain_batched(e)
            _, _, clean = run(bops.to_domain_batched(e), v_o)
            _, _, res = run(bops.to_domain_batched(e_bad), v_o)
            tag = f"{name}/{compression}"
            assert bool(res.diverged[1]), tag
            assert not bool(res.converged[1]), tag
            for col in (0, 2):
                assert np.array_equal(np.asarray(res.x[col]),
                                      np.asarray(clean.x[col])), \
                    (f"{tag}: healthy column {col} perturbed by the "
                     "injected NaN column")
            ran += 1
    assert ran >= 8   # every backend, plus each declared codec


# --- distributed comms/compute overlap -------------------------------


@pytest.mark.parametrize("dtype,nrhs", [("f32", 1), ("f32", 4),
                                        ("f64", 1)])
def test_distributed_interior_overlap_parity(dtype, nrhs):
    """The interior/boundary split schedule is numerically identical to
    the fused schedule (ODD_LATTICE has Tl=3: a one-plane-thick interior
    — the thinnest legal overlap region).  The f64 leg runs nrhs=1 only:
    the x64 compile of the split schedule dominates the suite and the
    batched path is already covered at f32."""
    with _x64_ctx(dtype):
        _check_parity("distributed", ODD_LATTICE, dtype, nrhs,
                      overlap="interior")


def test_distributed_interior_compressed_parity():
    """Overlap and compression compose: the interior schedule shipping
    two_row links still matches the jnp reference (one Dhat application
    — the dagger/batched legs are covered by the two tests above, and
    each extra leg is another ~30s compile of the split schedule)."""
    kappa = 0.13
    Ue, Uo, e = _fields(ODD_LATTICE, "f32", 1)
    want = backends.make_wilson_ops("jnp", Ue, Uo).apply_dhat(e[0], kappa)
    bops = _bind("distributed", Ue, Uo, "f32", overlap="interior",
                 gauge_compression="two_row")
    np.testing.assert_allclose(np.asarray(bops.apply_dhat(e[0], kappa)),
                               np.asarray(want), atol=_ATOL["f32"])


if HAVE_HYPOTHESIS:
    settings.register_profile("parity", max_examples=5, deadline=None)
    settings.load_profile("parity")

    odd_dim = st.sampled_from([2, 3, 5])

    @given(T=odd_dim, Z=odd_dim, Y=st.sampled_from([2, 3]),
           Xh=st.sampled_from([2, 3]),
           dtype=st.sampled_from(DTYPES),
           nrhs=st.sampled_from(NRHS),
           seed=st.integers(0, 2 ** 12))
    def test_backend_parity_hypothesis(T, Z, Y, Xh, dtype, nrhs, seed):
        """Random odd-extent lattices: every backend agrees with the
        reference on Dhat / Dhat^dag / batched Dhat."""
        shape = (T, Z, Y, 2 * Xh)
        with _x64_ctx(dtype):
            for name in all_backends():
                _check_parity(name, shape, dtype, nrhs, seed=seed)
