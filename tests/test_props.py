"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import evenodd
from repro.distributed import compress
from repro.models import layers

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

dims = st.sampled_from([2, 4, 6, 8])


@given(T=dims, Z=dims, Y=dims, Xh=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2 ** 16))
def test_pack_unpack_roundtrip(T, Z, Y, Xh, seed):
    k = jax.random.PRNGKey(seed)
    full = jax.random.normal(k, (T, Z, Y, 2 * Xh, 4, 3))
    e, o = evenodd.pack(full)
    np.testing.assert_array_equal(np.asarray(evenodd.unpack(e, o)),
                                  np.asarray(full))


@given(mu=st.integers(0, 3), seed=st.integers(0, 2 ** 16),
       out_parity=st.integers(0, 1))
def test_eo_shift_roundtrip(mu, seed, out_parity):
    """Shifting +mu as seen from parity p, then -mu as seen from parity
    1-p, is the identity (the stencil's defining consistency)."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (4, 4, 4, 4, 2))
    fwd = evenodd.eo_shift(x, mu, +1, out_parity)
    back = evenodd.eo_shift(fwd, mu, -1, 1 - out_parity)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x))


@given(seed=st.integers(0, 2 ** 16))
def test_causality(seed):
    """Perturbing a future token never changes past logits."""
    from conftest import build_small
    from repro.models import model as M

    c = build_small("minitron-4b", n_layers=2)
    p = M.init_params(c, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, 10), 0,
                              c.vocab_size)
    l1, _ = M.forward(c, p, toks)
    toks2 = toks.at[0, 7].set((toks[0, 7] + 1) % c.vocab_size)
    l2, _ = M.forward(c, p, toks2)
    np.testing.assert_array_equal(
        np.asarray(l1[:, :7], np.float32), np.asarray(l2[:, :7],
                                                      np.float32))


@given(seed=st.integers(0, 2 ** 16), pos=st.integers(0, 512))
def test_rope_preserves_norm(seed, pos):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, 32))
    y = layers.apply_rope(x, jnp.full((1, 1), pos), 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y)),
                               np.linalg.norm(np.asarray(x)), rtol=1e-5)


@given(seed=st.integers(0, 2 ** 16))
def test_rope_relative_property(seed):
    """<rope(q,p), rope(k,p+d)> depends only on the offset d."""
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (1, 1, 1, 16))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (1, 1, 1, 16))
    def score(p, d):
        qr = layers.apply_rope(q, jnp.full((1, 1), p), 1e4)
        kr = layers.apply_rope(kk, jnp.full((1, 1), p + d), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(score(3, 5) - score(40, 5)) < 1e-3


@given(seed=st.integers(0, 2 ** 16),
       scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_error_bound(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = compress.quantize(g)
    back = compress.dequantize(q, s)
    # max error <= scale/2 = max|g|/254
    bound = float(jnp.max(jnp.abs(g))) / 254.0 + 1e-9
    assert float(jnp.max(jnp.abs(back - g))) <= bound * 1.01


@given(seed=st.integers(0, 2 ** 16))
def test_rms_norm_scale_invariance(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 8)) + 0.1
    p = {"scale": jnp.ones((8,))}
    y1 = layers.apply_rms_norm(p, x)
    y2 = layers.apply_rms_norm(p, x * 7.3)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


@given(b=st.sampled_from([1, 2]), s=st.sampled_from([4, 8]),
       h=st.sampled_from([2, 4]), seed=st.integers(0, 1000))
def test_sdpa_softmax_rowsum(b, s, h, seed):
    """Attention output is a convex combination of values: componentwise
    within [min(v), max(v)]."""
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (b, s, h, 8))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, s, h, 8))
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, s, h, 8))
    out = layers.sdpa(q, kk, v, causal=True)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4


@given(seed=st.integers(0, 2 ** 16))
def test_flash_chunking_invariance(seed):
    """kv-chunked attention == unchunked attention."""
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (2, 16, 4, 8))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 16, 2, 8))
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 16, 2, 8))
    full = layers.sdpa(q, kk, v, causal=True)
    chunked = layers.sdpa(q, kk, v, causal=True, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=2e-5, rtol=1e-4)
