"""Chaos suite: every injector in ``repro.resilience.inject`` fired at
the resilient solve runtime, asserting detection (the ``diverged``
flag), containment (healthy RHS columns bit-exact with the clean run),
and recovery (stagnation restarts, precision escalation to f64
tolerance, gauge repair, backend fallback, snapshot/resume)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import evenodd, solver, su3
from repro.resilience import (GaugeAuditReport, InjectedFault,
                              audit_gauge, bitflip_gauge, break_ops,
                              corrupt_halo_slab, dead_inner_ops,
                              fallback_chain, nan_operator,
                              nan_spinor_column, repair_gauge,
                              stagnating_system)
from repro.resilience.snapshot import RefinementSnapshot

KAPPA = 0.12
SHAPE = (4, 4, 4, 8)


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def _spd(n=32, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (n, n), dtype=dtype)
    A = G @ G.T + n * jnp.eye(n, dtype=dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype=dtype)
    return A, b


def _fields(dtype=jnp.complex64, seed=0):
    U = su3.random_gauge(jax.random.PRNGKey(seed), SHAPE, dtype=dtype)
    k = jax.random.PRNGKey(seed + 1)
    psi = (jax.random.normal(k, (*SHAPE, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (*SHAPE, 4, 3))).astype(dtype)
    Ue, Uo = evenodd.pack_gauge(U)
    e, o = evenodd.pack(psi)
    return Ue, Uo, e, o


# --- divergence guards: detection at entry and mid-iteration ---------


@pytest.mark.parametrize("method", ["cg", "bicgstab"])
def test_nan_rhs_exits_immediately(method):
    A, b = _spd()
    b = b.at[0].set(jnp.nan)
    fn = solver.cg if method == "cg" else solver.bicgstab
    res = fn(lambda v: A @ v, b, tol=1e-6, max_iters=200)
    assert bool(res.diverged)
    assert not bool(res.converged)
    assert int(res.iterations) == 0


@pytest.mark.parametrize("method", ["cg", "bicgstab"])
def test_nan_operator_trips_guard_mid_iteration(method):
    # The operator starts emitting a NaN lane: divergence appears after
    # a healthy first residual, and the guard freezes a finite iterate
    # instead of running max_iters of NaN arithmetic.
    A, b = _spd()
    bad = nan_operator(lambda v: A @ v)
    fn = solver.cg if method == "cg" else solver.bicgstab
    res = fn(bad, b, tol=1e-10, max_iters=200)
    assert bool(res.diverged)
    assert not bool(res.converged)
    # Mid-iteration, not the entry exit: at least one healthy step ran.
    assert 1 <= int(res.iterations) < 200
    assert bool(jnp.all(jnp.isfinite(res.x)))


def test_guard_off_runs_blind():
    # The control: guard=False keeps the bare recurrence.  The loop
    # still *ends* on a NaN (NaN comparisons are False in the cond, and
    # a NaN pap trips the breakdown exit), but only the EXIT-TIME fold
    # on the non-finite relative residual refuses to call it converged
    # — the in-loop guard verdict, freeze, and stagnation machinery
    # are all gone (the budget-burning control is the `blind` leg of
    # the stagnation test below).
    A, b = _spd()
    bad = nan_operator(lambda v: A @ v)
    res = solver.cg(bad, b, tol=1e-10, max_iters=50, guard=False)
    assert int(res.iterations) >= 1       # the poisoned step did run
    assert bool(res.diverged)             # exit-time fold, not the guard
    assert not bool(res.converged)


@pytest.mark.parametrize("method", ["cg", "bicgstab"])
def test_batched_nan_column_contained_bit_exact(method):
    # One poisoned column; the other columns of the batched solve must
    # be BIT-EXACT with the uninjected run (per-column Krylov scalars
    # never mix columns) and the poisoned one must report diverged.
    A, b = _spd()
    B = jnp.stack([b, 2.0 * b, -b])
    fn = solver.cg_batched if method == "cg" else solver.bicgstab_batched
    op = (lambda v: v @ A.T)

    clean = fn(op, B, tol=1e-6, max_iters=200)
    bad_B = B.at[1, 0].set(jnp.nan)
    res = fn(op, bad_B, tol=1e-6, max_iters=200)

    assert bool(res.diverged[1]) and not bool(res.converged[1])
    for col in (0, 2):
        assert bool(res.converged[col])
        assert np.array_equal(np.asarray(res.x[col]),
                              np.asarray(clean.x[col])), \
            f"healthy column {col} was perturbed by the injected NaN"


def test_stagnation_guard_ends_hopeless_solve_early():
    # f32 CG on a cond=1e8 system cannot reach 1e-12; the stagnation
    # guard (restart, then freeze) must end it long before max_iters.
    A, b = stagnating_system()
    op = (lambda v: A @ v)
    res = solver.cg(op, b, tol=1e-12, max_iters=2000,
                    stagnation_window=20)
    blind = solver.cg(op, b, tol=1e-12, max_iters=2000, guard=False)
    assert bool(res.diverged)
    assert int(res.iterations) < 300
    assert int(blind.iterations) == 2000


def test_stagnation_restart_is_deterministic():
    A, b = stagnating_system()
    r1 = solver.cg(lambda v: A @ v, b, tol=1e-12, max_iters=2000,
                   stagnation_window=20)
    r2 = solver.cg(lambda v: A @ v, b, tol=1e-12, max_iters=2000,
                   stagnation_window=20)
    assert int(r1.iterations) == int(r2.iterations)
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))


# --- gauge audit / repair --------------------------------------------


def test_audit_flags_bitflip_and_repair_projects_back():
    Ue, Uo, _, _ = _fields()
    bad = bitflip_gauge(Ue, seed=3)
    report = audit_gauge(bad, Uo)
    assert not report.ok
    fixed_e, fixed_o, after = repair_gauge(bad, Uo)
    assert after.repaired and after.ok
    assert float(su3.unitarity_defect(fixed_e)) <= after.tolerance


def test_audit_counts_nonfinite_links():
    Ue, Uo, _, _ = _fields()
    bad = Ue.at[(0, 0, 0, 0, 0)].set(jnp.nan)
    report = audit_gauge(bad, Uo)
    assert report.nonfinite_links == 1 and not report.ok
    fixed_e, _, after = repair_gauge(bad, Uo)
    assert after.ok and bool(jnp.all(jnp.isfinite(fixed_e.real)))


def test_repair_is_identity_on_healthy_gauge():
    Ue, Uo, _, _ = _fields()
    fixed_e, fixed_o, report = repair_gauge(Ue, Uo)
    assert not report.repaired and report.ok
    assert np.array_equal(np.asarray(fixed_e), np.asarray(Ue))
    assert np.array_equal(np.asarray(fixed_o), np.asarray(Uo))


def test_bind_validate_warn_and_repair():
    Ue, Uo, e, o = _fields()
    bad = bitflip_gauge(Ue, seed=3)
    with pytest.warns(RuntimeWarning, match="SU\\(3\\) audit"):
        api.WilsonMatrix.bind(bad, Uo, KAPPA, backend="jnp",
                              validate="warn")
    D = api.WilsonMatrix.bind(bad, Uo, KAPPA, backend="jnp",
                              validate="repair")
    assert isinstance(D.gauge_audit, GaugeAuditReport)
    assert D.gauge_audit.repaired and D.gauge_audit.ok
    s = api.SolveSession(D, api.SolveSpec(method="bicgstab", tol=1e-5,
                                          max_iters=400))
    _, _, res = s.solve(e, o)
    assert bool(res.converged)
    with pytest.raises(ValueError, match="validate"):
        api.WilsonMatrix.bind(Ue, Uo, KAPPA, validate="maybe")


def test_repair_feeds_compressed_codecs():
    # The repair happens on the dense complex field BEFORE any codec
    # packs it, so a compressed bind of a corrupted gauge still solves.
    Ue, Uo, e, o = _fields()
    bad = bitflip_gauge(Ue, seed=3)
    spec = api.BackendSpec("pallas", interpret=True,
                           gauge_compression="two_row")
    D = api.WilsonMatrix.bind(bad, Uo, KAPPA, backend=spec,
                              validate="repair")
    assert D.gauge_audit.repaired
    s = api.SolveSession(D, api.SolveSpec(method="cgnr", tol=1e-5,
                                          max_iters=400))
    _, _, res = s.solve(e, o)
    assert bool(res.converged)


# --- halo corruption -------------------------------------------------


def test_corrupt_halo_slab_detected_and_recoverable():
    Ue, Uo, e, o = _fields()
    D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
    s = api.SolveSession(D, api.SolveSpec(method="cgnr", tol=1e-5,
                                          max_iters=400))
    torn = corrupt_halo_slab(e, axis=0, index=0)
    _, _, res = s.solve(torn, o)
    assert bool(res.diverged) and not bool(res.converged)
    # The session survives: a clean re-solve on the same compiled key.
    _, _, res2 = s.solve(e, o)
    assert bool(res2.converged)


# --- precision escalation --------------------------------------------


def test_escalation_rescues_dead_inner_backend():
    # The inner operator returns zero corrections (forced stagnation);
    # the outer loop must climb the ladder to f64 and still converge to
    # the f64 tolerance, recording the climb.
    with _x64():
        Ue, Uo, e, o = _fields(dtype=jnp.complex128)
        D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
        D._ops = dead_inner_ops(D.ops)
        s = api.SolveSession(D, api.SolveSpec(
            method="cgnr", tol=1e-10, max_iters=2000,
            inner_dtype="f32", inner_tol=1e-4, max_outer=25))
        _, _, res = s.solve(e, o)
        assert bool(res.converged)
        assert float(res.residual) <= 1e-10
        assert "f64" in res.escalations
        row = next(iter(s.stats()["keys"].values()))
        assert row["outer_iterations"] == [int(res.outer_iterations)]
        assert row["escalations"] == [list(res.escalations)]


def test_escalation_disabled_reports_divergence():
    with _x64():
        Ue, Uo, e, o = _fields(dtype=jnp.complex128)
        D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
        D._ops = dead_inner_ops(D.ops)
        s = api.SolveSession(D, api.SolveSpec(
            method="cgnr", tol=1e-10, max_iters=2000,
            inner_dtype="f32", inner_tol=1e-4, max_outer=5,
            escalate=False))
        _, _, res = s.solve(e, o)
        assert not bool(res.converged)
        assert res.escalations == ()


def test_healthy_refined_solve_never_escalates():
    with _x64():
        Ue, Uo, e, o = _fields(dtype=jnp.complex128)
        D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
        s = api.SolveSession(D, api.SolveSpec(
            method="cgnr", tol=1e-10, max_iters=2000,
            inner_dtype="f32", inner_tol=1e-4, max_outer=25))
        _, _, res = s.solve(e, o)
        assert bool(res.converged)
        assert res.escalations == ()


# --- backend fallback chain ------------------------------------------


def test_fallback_chain_declared_in_registry():
    assert fallback_chain("pallas_fused_stream") == (
        "pallas_fused_stream", "pallas_fused", "pallas", "jnp")
    assert fallback_chain("distributed") == ("distributed", "jnp")
    assert fallback_chain("jnp") == ("jnp",)


def test_session_falls_back_on_injected_compile_failure():
    Ue, Uo, e, o = _fields()
    spec = api.BackendSpec("pallas", interpret=True)
    D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend=spec, fallback=True)
    D._ops = break_ops(D.ops)
    s = api.SolveSession(D, api.SolveSpec(method="cgnr", tol=1e-5,
                                          max_iters=400))
    _, _, res = s.solve(e, o)
    assert bool(res.converged)
    st = s.stats()
    assert st["fallbacks"] >= 1
    assert st["backend"] == "jnp"
    assert st["degraded"]
    assert st["fallback_events"][0][0] == "pallas"
    assert "InjectedFault" in st["fallback_events"][0][1]
    assert s.matrix.degraded
    # Counters stay consistent after recovery: the failed attempt never
    # committed a solve/miss.
    s.solve(e, o)
    assert s.stats()["solves"] == 2
    assert s.stats()["cache_hits"] == 1


def test_fallback_disabled_raises():
    Ue, Uo, e, o = _fields()
    spec = api.BackendSpec("pallas", interpret=True)
    D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend=spec)
    D._ops = break_ops(D.ops)
    s = api.SolveSession(D, api.SolveSpec(method="cgnr", tol=1e-5,
                                          max_iters=400))
    with pytest.raises(InjectedFault):
        s.solve(e, o)
    assert s.stats()["fallbacks"] == 0
    assert s.stats()["solves"] == 0


def test_healthy_session_reports_not_degraded():
    Ue, Uo, e, o = _fields()
    D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp",
                              fallback=True)
    s = api.SolveSession(D, api.SolveSpec(method="cg", tol=1e-5,
                                          max_iters=400))
    s.solve(e, o)
    st = s.stats()
    assert not st["degraded"]
    assert st["fallbacks"] == 0 and st["fallback_events"] == []


# --- snapshot / resume -----------------------------------------------


def test_snapshot_resume_skips_completed_outer_passes(tmp_path):
    with _x64():
        Ue, Uo, e, o = _fields(dtype=jnp.complex128)
        D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
        kw = dict(method="cgnr", tol=1e-10, max_iters=2000,
                  inner_tol=1e-4, max_outer=25, batched=False)
        U64_e, U64_o = D.gauge_complex()

        fresh = solver.make_refined_solve(D.ops, U64_e, U64_o, KAPPA,
                                          **kw)
        _, _, ref = fresh(e, o)
        assert bool(ref.converged)

        snap_dir = str(tmp_path / "snap")
        snapped = solver.make_refined_solve(
            D.ops, U64_e, U64_o, KAPPA,
            snapshot=RefinementSnapshot(snap_dir), **kw)
        _, _, first = snapped(e, o)
        assert bool(first.converged)
        # Second run resumes from the last saved outer iterate: fewer
        # f64 reference applications, same converged answer.
        xe2, _, second = snapped(e, o)
        assert bool(second.converged)
        assert int(second.f64_applies) < int(first.f64_applies)
        assert float(second.residual) <= 1e-10


def test_snapshot_empty_directory_resumes_from_zero(tmp_path):
    snap = RefinementSnapshot(str(tmp_path / "empty"))
    x0 = jnp.zeros((4,))
    x, outer, extras = snap.resume(x0)
    assert outer == 0 and extras == {}
    assert np.array_equal(np.asarray(x), np.asarray(x0))
    assert snap.latest_outer() is None


# --- the injectors themselves are deterministic ----------------------


def test_injectors_are_pure_and_seeded():
    Ue, _, e, _ = _fields()
    b1, b2 = bitflip_gauge(Ue, seed=7), bitflip_gauge(Ue, seed=7)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert not np.array_equal(np.asarray(b1), np.asarray(Ue))

    eb = jnp.stack([e, e])
    n1 = nan_spinor_column(eb, 1)
    assert bool(jnp.any(jnp.isnan(n1.real[1])))
    assert not bool(jnp.any(jnp.isnan(n1.real[0])))
    assert not bool(jnp.any(jnp.isnan(eb.real)))       # input untouched

    A, b = stagnating_system()
    A2, b2_ = stagnating_system()
    assert np.array_equal(np.asarray(A), np.asarray(A2))
    assert np.array_equal(np.asarray(b), np.asarray(b2_))


def test_break_ops_raises_at_trace():
    Ue, Uo, e, _ = _fields()
    D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
    broken = break_ops(D.ops, "kaboom")
    with pytest.raises(InjectedFault, match="kaboom"):
        broken.apply_dhat_native(broken.to_domain(e), KAPPA)


def test_corrupt_halo_slab_layouts():
    Ue, Uo, e, _ = _fields()
    torn = corrupt_halo_slab(e, axis=0, index=0)
    assert bool(jnp.all(jnp.isnan(torn.real[0])))
    assert bool(jnp.all(jnp.isfinite(torn.real[1:])))
    D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp")
    v = D.ops.to_domain(e)                 # planar-native layout
    torn_v = corrupt_halo_slab(v, axis=1, index=-1)
    assert bool(jnp.any(jnp.isnan(torn_v)))
    assert not bool(jnp.any(jnp.isnan(v)))


def test_solve_spec_resilience_knobs_validated():
    with pytest.raises(ValueError, match="stagnation_window"):
        api.SolveSpec(stagnation_window=1)
    with pytest.raises(ValueError, match="max_restarts"):
        api.SolveSpec(max_restarts=-1)
    tok = api.SolveSpec(guard=False).cache_token()
    assert "noguard" in tok
    tok2 = api.SolveSpec(inner_dtype="f32", escalate=False).cache_token()
    assert "noesc" in tok2


def test_warnings_clean_on_healthy_bind():
    Ue, Uo, _, _ = _fields()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        D = api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend="jnp",
                                  validate="warn")
    assert D.gauge_audit is not None and D.gauge_audit.ok
