"""Serving daemon suite: coalescing correctness (batched answers match
solo answers per request), ragged split-back, admission/deadline typed
rejections that never poison the pool, chaos containment of a poisoned
request inside a shared batch, recycle-deflation harvesting across a
served stream, and the stdlib HTTP front end."""
import json
import threading
import urllib.error
import urllib.request
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import evenodd, su3
from repro.resilience import nan_spinor_column
from repro.serving import (AdmissionPolicy, BadRequestError,
                           BatchingPolicy, DrainingError,
                           HttpServerThread, PropagatorDaemon,
                           RequestQueue, RequestTimeoutError,
                           SessionPool, ShedError, SolveRequest,
                           UnknownMatrixError, decode_array,
                           encode_array, spec_from_json)

KAPPA = 0.1245
SHAPE = (4, 4, 4, 8)


def _matrix(backend="jnp", seed=7, **bind):
    U = su3.weak_gauge(jax.random.PRNGKey(seed), SHAPE, eps=0.2)
    Ue, Uo = evenodd.pack_gauge(U)
    return api.WilsonMatrix.bind(Ue, Uo, KAPPA, backend=backend, **bind)


def _source(seed, nrhs=None):
    bshape = (() if nrhs is None else (nrhs,)) + (*SHAPE, 4, 3)
    k = jax.random.PRNGKey(seed)
    psi = (jax.random.normal(k, bshape)
           + 1j * jax.random.normal(jax.random.fold_in(k, 1), bshape)
           ).astype(jnp.complex64)
    if nrhs is None:
        return evenodd.pack(psi)
    return jax.vmap(evenodd.pack)(psi)


def _daemon(matrix=None, *, max_block=4, linger_s=0.05,
            buckets=(1, 2, 4), name="cfg", **kw):
    d = PropagatorDaemon(
        batching=BatchingPolicy(max_block=max_block, linger_s=linger_s,
                                buckets=buckets), **kw)
    d.register(name, matrix if matrix is not None else _matrix())
    return d


# --- policy ----------------------------------------------------------


def test_bucket_quantization():
    p = BatchingPolicy(max_block=8, buckets=(1, 2, 4, 8))
    assert [p.bucket(n) for n in (1, 2, 3, 4, 5, 8)] == \
        [1, 2, 4, 4, 8, 8]
    with pytest.raises(ValueError):
        p.bucket(9)


def test_policy_validation():
    with pytest.raises(ValueError):
        BatchingPolicy(buckets=(2, 1))
    with pytest.raises(ValueError):
        BatchingPolicy(max_block=8, buckets=(1, 2, 4))
    with pytest.raises(ValueError):
        BatchingPolicy(linger_s=-1.0)
    with pytest.raises(ValueError):
        AdmissionPolicy(max_queue_depth=0)
    with pytest.raises(ValueError):
        AdmissionPolicy(default_timeout_s=0.0)


def test_errors_are_typed():
    for cls, status in [(ShedError, 429), (RequestTimeoutError, 504),
                        (DrainingError, 503),
                        (UnknownMatrixError, 404),
                        (BadRequestError, 400)]:
        assert cls.http_status == status
        assert cls.code != "error"


# --- queue (fake clock; no JAX) --------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class _Src:
    """Array stand-in: the queue only reads ``shape[0]``."""

    def __init__(self, n):
        self.shape = (n,)


def _req(key, n, clock, deadline=None):
    from concurrent.futures import Future
    return SolveRequest(key, _Src(n), _Src(n), deadline=deadline,
                        submitted_at=clock(), future=Future())


def test_queue_coalesces_to_max_block():
    clock = _Clock()
    q = RequestQueue(BatchingPolicy(max_block=4, linger_s=10.0,
                                    buckets=(1, 2, 4)),
                     AdmissionPolicy(), clock=clock)
    for _ in range(5):
        q.submit(_req("k", 1, clock))
    key, batch = q.wait_ready(stop_event=threading.Event())
    assert key == "k" and len(batch) == 4
    assert q.depth == 1  # fifth request waits for the next batch


def test_queue_linger_dispatches_ragged():
    clock = _Clock()
    q = RequestQueue(BatchingPolicy(max_block=4, linger_s=1.0,
                                    buckets=(1, 2, 4)),
                     AdmissionPolicy(), clock=clock)
    q.submit(_req("k", 1, clock))
    clock.t = 0.5
    q.submit(_req("k", 2, clock))
    clock.t = 1.01  # oldest request's linger expired
    _, batch = q.wait_ready(stop_event=threading.Event())
    assert [r.nrhs for r in batch] == [1, 2]


def test_queue_never_splits_a_request():
    clock = _Clock()
    q = RequestQueue(BatchingPolicy(max_block=4, linger_s=0.0,
                                    buckets=(1, 2, 4)),
                     AdmissionPolicy(), clock=clock)
    q.submit(_req("k", 3, clock))
    q.submit(_req("k", 2, clock))  # 3+2 > 4: must not ride along
    _, batch = q.wait_ready(stop_event=threading.Event())
    assert [r.nrhs for r in batch] == [3]
    assert q.depth == 1


def test_queue_sheds_at_depth():
    clock = _Clock()
    q = RequestQueue(BatchingPolicy(), AdmissionPolicy(max_queue_depth=2),
                     clock=clock)
    q.submit(_req("k", 1, clock))
    q.submit(_req("k", 1, clock))
    with pytest.raises(ShedError):
        q.submit(_req("k", 1, clock))


def test_queue_expires_with_partial_stats():
    clock = _Clock()
    q = RequestQueue(BatchingPolicy(max_block=4, linger_s=100.0,
                                    buckets=(1, 2, 4)),
                     AdmissionPolicy(), clock=clock)
    r = _req("k", 1, clock, deadline=1.0)
    q.submit(r)
    clock.t = 2.0
    stop = threading.Event()
    stop.set()
    assert q.wait_ready(stop_event=stop) is None  # drained empty
    with pytest.raises(RequestTimeoutError) as ei:
        r.future.result(timeout=0)
    assert ei.value.stats["queued_s"] == pytest.approx(2.0)
    assert q.depth == 0


def test_queue_keys_do_not_mix():
    clock = _Clock()
    q = RequestQueue(BatchingPolicy(max_block=4, linger_s=0.0,
                                    buckets=(1, 2, 4)),
                     AdmissionPolicy(), clock=clock)
    q.submit(_req("a", 1, clock))
    q.submit(_req("b", 1, clock))
    _, batch = q.wait_ready(stop_event=threading.Event())
    assert len(batch) == 1


# --- pool ------------------------------------------------------------


def test_pool_lru_eviction():
    pool = SessionPool(capacity=2)
    m = _matrix()
    pool.register("a", m)
    pool.register("b", m)
    pool.entry("a")  # touch: "b" becomes LRU
    pool.register("c", m)
    assert pool.names() == ("a", "c")
    assert pool.stats()["evictions"] == ["b"]
    with pytest.raises(UnknownMatrixError):
        pool.entry("b")


def test_pool_warmup_pretraces_buckets():
    pool = SessionPool()
    pool.register("a", _matrix())
    spec = api.SolveSpec(method="cgnr", tol=1e-6)
    timings = pool.warmup("a", spec, buckets=(1, 2))
    assert sorted(timings) == [1, 2]
    st = pool.stats()["entries"]["a"]["session"]
    assert st["traces"] == 2
    # live traffic at a warmed bucket reuses the executable: no trace
    e = pool.entry("a")
    eta_e, eta_o = _source(11, nrhs=2)
    e.session.solve_block(eta_e, eta_o, spec)
    assert pool.stats()["entries"]["a"]["session"]["traces"] == 2


# --- daemon: coalescing correctness ----------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas_fused"])
def test_coalesced_matches_individual(backend):
    """Requests answered from a shared batch agree with solo solves of
    the same matrix/spec to 1e-5 — coalescing is a scheduling decision,
    not a numerical one."""
    matrix = _matrix(backend)
    spec = api.SolveSpec(method="cgnr", tol=1e-6)
    solo = api.SolveSession(matrix)
    want = []
    sources = [_source(20 + i) for i in range(3)]
    for eta_e, eta_o in sources:
        xe, xo, _ = solo.solve(eta_e, eta_o, spec)
        want.append((xe, xo))

    d = _daemon(matrix, linger_s=0.2)
    d.start()
    try:
        futs = [d.submit("cfg", e, o, spec) for e, o in sources]
        got = [f.result(timeout=300) for f in futs]
    finally:
        d.drain()
    assert len({r.stats["batch_id"] for r in got}) == 1  # one batch
    for (we, wo), r in zip(want, got):
        assert r.converged and not r.diverged
        np.testing.assert_allclose(np.asarray(r.xi_e[0]),
                                   np.asarray(we), atol=1e-5)
        np.testing.assert_allclose(np.asarray(r.xi_o[0]),
                                   np.asarray(wo), atol=1e-5)


def test_ragged_split_back_per_request_stats():
    """A 1-column and a 2-column request share a batch; each gets its
    own iterations/residual/convergence arrays of its own width."""
    d = _daemon(linger_s=0.2)
    d.start()
    spec = api.SolveSpec(method="cgnr", tol=1e-6)
    try:
        e1, o1 = _source(31, nrhs=None)
        e2, o2 = _source(32, nrhs=2)
        f1 = d.submit("cfg", e1, o1, spec)
        f2 = d.submit("cfg", e2, o2, spec)
        r1, r2 = f1.result(timeout=300), f2.result(timeout=300)
    finally:
        d.drain()
    assert r1.stats["batch_id"] == r2.stats["batch_id"]
    assert r1.stats["batch_columns"] == 3
    assert r1.stats["bucket"] == 4  # padded up: 1 trace per bucket
    assert len(r1.stats["iterations"]) == 1
    assert len(r2.stats["iterations"]) == 2
    assert r1.xi_e.shape[0] == 1 and r2.xi_e.shape[0] == 2
    assert r1.converged and r2.converged
    assert len(r1.stats["residual"]) == 1
    assert len(r2.stats["residual"]) == 2


def test_executable_cache_one_trace_per_bucket():
    d = _daemon(linger_s=0.15)
    d.start()
    spec = api.SolveSpec(method="cgnr", tol=1e-6)
    try:
        # wave 1: three singles -> batch of 3 -> bucket 4
        futs = [d.submit("cfg", *_source(40 + i), spec)
                for i in range(3)]
        [f.result(timeout=300) for f in futs]
        # wave 2: same shape again -> same bucket -> cache hit
        futs = [d.submit("cfg", *_source(50 + i), spec)
                for i in range(3)]
        [f.result(timeout=300) for f in futs]
    finally:
        d.drain()
    sess = d.pool.stats()["entries"]["cfg"]["session"]
    assert sess["traces"] == 1
    assert len(sess["keys"]) == 1
    m = d.metrics()
    assert m["batches"] == 2
    assert m["mean_batch_columns"] == 3.0


# --- daemon: rejection paths never poison the pool -------------------


def test_shed_is_typed_and_pool_survives():
    d = _daemon(linger_s=0.1,
                admission=AdmissionPolicy(max_queue_depth=1))
    # not started: requests stay queued, so the second submit sheds
    f1 = d.submit("cfg", *_source(60))
    with pytest.raises(ShedError):
        d.submit("cfg", *_source(61))
    d.start()
    try:
        assert f1.result(timeout=300).converged
        # the shed left no residue: a later request is served normally
        assert d.submit("cfg", *_source(62)).result(
            timeout=300).converged
    finally:
        d.drain()
    assert not d.pool.stats()["entries"]["cfg"]["degraded"]
    assert d.metrics()["shed"] == 1


def test_timeout_cancels_with_partial_stats():
    d = _daemon(linger_s=5.0)  # linger longer than the deadline
    d.start()
    try:
        fut = d.submit("cfg", *_source(63), timeout_s=0.05)
        with pytest.raises(RequestTimeoutError) as ei:
            fut.result(timeout=60)
        assert ei.value.stats["queued_s"] >= 0.05
        assert ei.value.stats["nrhs"] == 1
        # daemon still serves after the cancellation
        assert d.submit("cfg", *_source(64),
                        timeout_s=120).result(timeout=300).converged
    finally:
        d.drain()


def test_draining_rejects_new_work():
    d = _daemon()
    d.start()
    d.drain()
    with pytest.raises(DrainingError):
        d.submit("cfg", *_source(65))


def test_bad_shapes_and_unknown_matrix_are_typed():
    d = _daemon()
    with pytest.raises(UnknownMatrixError):
        d.submit("nope", *_source(66))
    with pytest.raises(BadRequestError):
        d.submit("cfg", jnp.zeros((3, 3)), jnp.zeros((3, 3)))
    with pytest.raises(BadRequestError):  # more columns than max_block
        d.submit("cfg", *_source(67, nrhs=5))
    eta_e, eta_o = _source(68)
    with pytest.raises(BadRequestError):  # wrong lattice
        d.submit("cfg", eta_e[:, :2], eta_o[:, :2])


# --- chaos: poisoned request contained within its batch --------------


def test_nan_request_contained_in_shared_batch():
    """One request's NaN source must not leak into batchmates: their
    answers stay bit-identical to a clean run, and only the poisoned
    request reports diverged."""
    matrix = _matrix()
    spec = api.SolveSpec(method="cgnr", tol=1e-6)
    clean_sources = [_source(70 + i) for i in range(3)]

    def run(sources):
        d = _daemon(matrix, linger_s=0.2)
        d.start()
        try:
            futs = [d.submit("cfg", e, o, spec) for e, o in sources]
            return [f.result(timeout=300) for f in futs]
        finally:
            d.drain()

    clean = run(clean_sources)
    poisoned_sources = list(clean_sources)
    pe, po = poisoned_sources[1]
    poisoned_sources[1] = (nan_spinor_column(pe[None], 0)[0], po)
    chaos = run(poisoned_sources)

    assert len({r.stats["batch_id"] for r in chaos}) == 1
    assert chaos[1].diverged and not chaos[1].converged
    for j in (0, 2):
        assert chaos[j].converged and not chaos[j].diverged
        np.testing.assert_array_equal(np.asarray(chaos[j].xi_e),
                                      np.asarray(clean[j].xi_e))
        np.testing.assert_array_equal(np.asarray(chaos[j].xi_o),
                                      np.asarray(clean[j].xi_o))


# --- PR 9 follow-up: recycle harvest across a served stream ----------


@pytest.mark.parametrize("method", ["cg", "blockcg"])
def test_recycle_deflation_fills_from_served_stream(method):
    """Individually-submitted requests coalesce into batched solves;
    every converged column — including individual columns of a blockcg
    block — is harvested into the recycle span, and the iteration
    count drops across the served stream."""
    d = _daemon(linger_s=0.3)
    d.start()
    spec = api.SolveSpec(method=method, tol=1e-6, deflate_rank=24,
                         deflate_mode="recycle")
    iters = []
    try:
        for wave in range(6):
            futs = [d.submit("cfg", *_source(80 + 4 * wave + i), spec)
                    for i in range(4)]
            rs = [f.result(timeout=300) for f in futs]
            assert all(r.converged for r in rs)
            iters.append(max(r.stats["iterations"][0] for r in rs))
    finally:
        d.drain()
    entry = d.pool.stats()["entries"]["cfg"]
    row = next(iter(entry["session"]["keys"].values()))
    assert row["deflation"]["mode"] == "recycle"
    assert row["deflation"]["filled"] > 0
    assert row["deflation"]["harvested"] >= row["deflation"]["filled"]
    # batched solves fed the span: later waves solve strictly cheaper
    assert iters[-1] < iters[0]


# --- donation --------------------------------------------------------


@pytest.mark.filterwarnings("ignore:Some donated buffers")
def test_donated_batch_matches_undonated():
    matrix = _matrix()
    spec = api.SolveSpec(method="cgnr", tol=1e-6)
    session = api.SolveSession(matrix)
    eta_e, eta_o = _source(90, nrhs=2)
    xe0, xo0, res0, parts0 = session.solve_block(eta_e, eta_o, spec)
    xe1, xo1, res1, parts1 = session.solve_block(
        jnp.array(eta_e), jnp.array(eta_o), spec, donate=True)
    np.testing.assert_allclose(np.asarray(xe1), np.asarray(xe0),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(xo1), np.asarray(xo0),
                               atol=1e-6)
    assert len(parts0) == len(parts1) == 2
    # donation is a distinct executable, not a retrace of the same key
    toks = {k.split("|")[0] for k in session.stats()["keys"]}
    assert len(toks) == 2


def test_donate_rhs_rejected_for_refined_solves():
    with pytest.raises(ValueError):
        api.SolveSpec(inner_dtype="f32", donate_rhs=True)


# --- HTTP front end --------------------------------------------------


def test_array_codec_roundtrip():
    a = (np.arange(12, dtype=np.float32).reshape(3, 4)
         + 1j * np.ones((3, 4), np.float32)).astype(np.complex64)
    b = decode_array(encode_array(a))
    assert b.dtype == a.dtype and b.shape == a.shape
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(decode_array([[1.0, 2.0]]),
                                  np.asarray([[1.0, 2.0]]))
    with pytest.raises(BadRequestError):
        decode_array({"npy": "!!!"})
    with pytest.raises(BadRequestError):
        decode_array("nope")


def test_spec_from_json_whitelists_fields():
    s = spec_from_json({"method": "bicgstab", "tol": 1e-5})
    assert s.method == "bicgstab" and s.tol == 1e-5
    assert spec_from_json(None) == api.SolveSpec()
    with pytest.raises(BadRequestError):
        spec_from_json({"methd": "cg"})
    with pytest.raises(BadRequestError):
        spec_from_json({"method": "not-a-method"})
    with pytest.raises(BadRequestError):
        spec_from_json([1, 2])


def test_http_end_to_end_with_typed_errors():
    d = _daemon(linger_s=0.15)
    d.start()
    srv = HttpServerThread(d, port=0)
    host, port = srv.start()
    base = f"http://{host}:{port}"
    try:
        with urllib.request.urlopen(base + "/v1/healthz",
                                    timeout=30) as r:
            hz = json.loads(r.read())
        assert hz["ok"] and hz["matrices"] == ["cfg"]

        def one(i):
            eta_e, eta_o = _source(100 + i)
            body = json.dumps({
                "matrix": "cfg",
                "eta_e": encode_array(eta_e),
                "eta_o": encode_array(eta_o),
                "spec": {"method": "cgnr", "tol": 1e-6},
            }).encode()
            req = urllib.request.Request(
                base + "/v1/solve", data=body,
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req, timeout=300) as resp:
                return json.loads(resp.read())

        with ThreadPoolExecutor(3) as ex:
            outs = list(ex.map(one, range(3)))
        assert len({o["stats"]["batch_id"] for o in outs}) == 1
        for i, o in enumerate(outs):
            assert o["stats"]["converged"] == [True]
            xi = decode_array(o["xi_e"])
            assert xi.shape == (1,) + api.LatticeSpec(
                SHAPE).spinor_eo_shape()

        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(urllib.request.Request(
                base + "/v1/solve",
                data=json.dumps({"matrix": "nope", "eta_e": [1.0],
                                 "eta_o": [1.0]}).encode()),
                timeout=30)
        assert ei.value.code == 404
        assert json.loads(ei.value.read())["error"] == "unknown_matrix"

        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(urllib.request.Request(
                base + "/v1/solve", data=b"not json"), timeout=30)
        assert ei.value.code == 400

        with urllib.request.urlopen(base + "/v1/metrics",
                                    timeout=30) as r:
            m = json.loads(r.read())
        assert m["completed"] == 3
        assert m["mean_batch_columns"] == 3.0
        assert m["pool"]["entries"]["cfg"]["session"]["traces"] == 1
    finally:
        srv.stop()
        d.drain()


def test_metrics_shape_without_traffic():
    d = _daemon()
    m = d.metrics()
    assert m["mean_batch_columns"] is None
    assert m["queue_depth"] == 0
    assert m["batching"]["buckets"] == [1, 2, 4]
    assert "cfg" in m["pool"]["entries"]
    json.dumps(m)  # the whole report is wire-clean
