"""Krylov solvers on the even-odd preconditioned Wilson system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import evenodd, solver, wilson


@pytest.mark.parametrize("method", ["cgnr", "bicgstab"])
def test_solve_full_system(small_lattice, small_eo, method):
    U, _, kappa = small_lattice
    Ue, Uo, _, _, _ = small_eo
    k = jax.random.PRNGKey(7)
    eta = (jax.random.normal(k, U.shape[1:5] + (4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    U.shape[1:5] + (4, 3))
           ).astype(jnp.complex64)
    ee, eo = evenodd.pack(eta)
    xe, xo, res = api.solve(Ue, Uo, ee, eo, kappa,
                            spec=api.SolveSpec(method=method, tol=1e-6))
    assert bool(res.converged)
    xi = evenodd.unpack(xe, xo)
    r = eta - wilson.apply_wilson(U, xi, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
    assert rel < 1e-5


def test_solver_with_pallas_backend(small_lattice, small_eo):
    """Same solve with the Pallas-backed hopping blocks, bound by name
    through the registry."""
    U, _, kappa = small_lattice
    Ue, Uo, ee, eo, _ = small_eo
    xe, xo, res = api.solve(
        Ue, Uo, ee, eo, kappa, backend="pallas", interpret=True,
        spec=api.SolveSpec(method="bicgstab", tol=1e-5))
    xi = evenodd.unpack(xe, xo)
    eta = evenodd.unpack(ee, eo)
    r = eta - wilson.apply_wilson(U, xi, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
    assert rel < 1e-4


def test_cg_on_spd_system():
    """CG solves a small SPD dense system to tolerance."""
    n = 64
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    res = solver.cg(lambda v: A @ v, b, tol=1e-8, max_iters=500)
    assert bool(res.converged)
    assert float(jnp.linalg.norm(A @ res.x - b)
                 / jnp.linalg.norm(b)) < 1e-6


def test_cg_iteration_monotone():
    """CG residual after k iterations decreases with k (property)."""
    n = 48
    key = jax.random.PRNGKey(5)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    prev = None
    for iters in (2, 4, 8, 16):
        res = solver.cg(lambda v: A @ v, b, tol=0.0, max_iters=iters)
        r = float(jnp.linalg.norm(A @ res.x - b))
        if prev is not None:
            assert r <= prev * 1.001
        prev = r


def test_cg_recompute_every_converges_to_same_solution():
    """Periodic true-residual recompute (``recompute_every``)
    doesn't change what CG converges to, and still converges."""
    n = 64
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    # tol within the f32 true-residual floor: the recomputed residual is
    # honest where the recursive one drifts optimistically low.
    plain = solver.cg(lambda v: A @ v, b, tol=1e-6, max_iters=500)
    recomp = solver.cg(lambda v: A @ v, b, tol=1e-6, max_iters=500,
                       recompute_every=4)
    assert bool(recomp.converged)
    np.testing.assert_allclose(np.asarray(recomp.x), np.asarray(plain.x),
                               atol=1e-4)


@pytest.mark.parametrize("method", ["cgnr", "bicgstab"])
def test_solve_wilson_recompute_every(small_lattice, small_eo, method):
    """recompute_every threads through SolveSpec into the while_loop'd
    Krylov solvers; the true solution comes back."""
    U, _, kappa = small_lattice
    Ue, Uo, ee, eo, _ = small_eo
    spec = api.SolveSpec(method=method, tol=1e-6, max_iters=2000,
                         recompute_every=7)
    xe, xo, res = api.solve(Ue, Uo, ee, eo, kappa, spec=spec)
    assert bool(res.converged)
    xi = evenodd.unpack(xe, xo)
    eta = evenodd.unpack(ee, eo)
    r = eta - wilson.apply_wilson(U, xi, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
    assert rel < 1e-4


def test_even_odd_preconditioning_helps(small_lattice, small_eo):
    """The Schur system converges faster than unpreconditioned CGNR on
    the full D_W (the point of Eq. (4))."""
    U, _, kappa = small_lattice
    Ue, Uo, _, _, _ = small_eo
    k = jax.random.PRNGKey(9)
    eta = (jax.random.normal(k, U.shape[1:5] + (4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    U.shape[1:5] + (4, 3))
           ).astype(jnp.complex64)
    ee, eo = evenodd.pack(eta)
    _, _, res_eo = api.solve(Ue, Uo, ee, eo, kappa,
                             spec=api.SolveSpec(method="cgnr", tol=1e-6))
    full = solver.cgnr(
        lambda v: wilson.apply_wilson(U, v, kappa),
        lambda v: wilson.apply_wilson_dagger(U, v, kappa),
        eta, tol=1e-6, max_iters=2000)
    assert int(res_eo.iterations) < int(full.iterations)
