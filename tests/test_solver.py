"""Krylov solvers on the even-odd preconditioned Wilson system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import evenodd, solver, wilson


@pytest.mark.parametrize("method", ["cgnr", "bicgstab"])
def test_solve_full_system(small_lattice, small_eo, method):
    U, _, kappa = small_lattice
    Ue, Uo, _, _, _ = small_eo
    k = jax.random.PRNGKey(7)
    eta = (jax.random.normal(k, U.shape[1:5] + (4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    U.shape[1:5] + (4, 3))
           ).astype(jnp.complex64)
    ee, eo = evenodd.pack(eta)
    xe, xo, res = api.solve(Ue, Uo, ee, eo, kappa,
                            spec=api.SolveSpec(method=method, tol=1e-6))
    assert bool(res.converged)
    xi = evenodd.unpack(xe, xo)
    r = eta - wilson.apply_wilson(U, xi, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
    assert rel < 1e-5


def test_solver_with_pallas_backend(small_lattice, small_eo):
    """Same solve with the Pallas-backed hopping blocks, bound by name
    through the registry."""
    U, _, kappa = small_lattice
    Ue, Uo, ee, eo, _ = small_eo
    xe, xo, res = api.solve(
        Ue, Uo, ee, eo, kappa, backend="pallas", interpret=True,
        spec=api.SolveSpec(method="bicgstab", tol=1e-5))
    xi = evenodd.unpack(xe, xo)
    eta = evenodd.unpack(ee, eo)
    r = eta - wilson.apply_wilson(U, xi, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
    assert rel < 1e-4


def test_cg_on_spd_system():
    """CG solves a small SPD dense system to tolerance."""
    n = 64
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    res = solver.cg(lambda v: A @ v, b, tol=1e-8, max_iters=500)
    assert bool(res.converged)
    assert float(jnp.linalg.norm(A @ res.x - b)
                 / jnp.linalg.norm(b)) < 1e-6


def test_cg_iteration_monotone():
    """CG residual after k iterations decreases with k (property)."""
    n = 48
    key = jax.random.PRNGKey(5)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    prev = None
    for iters in (2, 4, 8, 16):
        res = solver.cg(lambda v: A @ v, b, tol=0.0, max_iters=iters)
        r = float(jnp.linalg.norm(A @ res.x - b))
        if prev is not None:
            assert r <= prev * 1.001
        prev = r


def test_cg_recompute_every_converges_to_same_solution():
    """Periodic true-residual recompute (``recompute_every``)
    doesn't change what CG converges to, and still converges."""
    n = 64
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (n, n))
    A = A @ A.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    # tol within the f32 true-residual floor: the recomputed residual is
    # honest where the recursive one drifts optimistically low.
    plain = solver.cg(lambda v: A @ v, b, tol=1e-6, max_iters=500)
    recomp = solver.cg(lambda v: A @ v, b, tol=1e-6, max_iters=500,
                       recompute_every=4)
    assert bool(recomp.converged)
    np.testing.assert_allclose(np.asarray(recomp.x), np.asarray(plain.x),
                               atol=1e-4)


@pytest.mark.parametrize("method", ["cgnr", "bicgstab"])
def test_solve_wilson_recompute_every(small_lattice, small_eo, method):
    """recompute_every threads through SolveSpec into the while_loop'd
    Krylov solvers; the true solution comes back."""
    U, _, kappa = small_lattice
    Ue, Uo, ee, eo, _ = small_eo
    spec = api.SolveSpec(method=method, tol=1e-6, max_iters=2000,
                         recompute_every=7)
    xe, xo, res = api.solve(Ue, Uo, ee, eo, kappa, spec=spec)
    assert bool(res.converged)
    xi = evenodd.unpack(xe, xo)
    eta = evenodd.unpack(ee, eo)
    r = eta - wilson.apply_wilson(U, xi, kappa)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(eta))
    assert rel < 1e-4


def test_even_odd_preconditioning_helps(small_lattice, small_eo):
    """The Schur system converges faster than unpreconditioned CGNR on
    the full D_W (the point of Eq. (4))."""
    U, _, kappa = small_lattice
    Ue, Uo, _, _, _ = small_eo
    k = jax.random.PRNGKey(9)
    eta = (jax.random.normal(k, U.shape[1:5] + (4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    U.shape[1:5] + (4, 3))
           ).astype(jnp.complex64)
    ee, eo = evenodd.pack(eta)
    _, _, res_eo = api.solve(Ue, Uo, ee, eo, kappa,
                             spec=api.SolveSpec(method="cgnr", tol=1e-6))
    full = solver.cgnr(
        lambda v: wilson.apply_wilson(U, v, kappa),
        lambda v: wilson.apply_wilson_dagger(U, v, kappa),
        eta, tol=1e-6, max_iters=2000)
    assert int(res_eo.iterations) < int(full.iterations)


def _drifty_spd(n=96, seed=5):
    """f32 SPD with a small low-mode cluster: enough spread that the
    recursive residual drifts below the true one near the floor — the
    regime where the recompute correction used to trip the stagnation
    guard."""
    key = jax.random.PRNGKey(seed)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n),
                                           dtype=jnp.float32))
    ev = jnp.concatenate(
        [jnp.linspace(1e-3, 1e-2, 8),
         jnp.linspace(0.5, 2.0, n - 8)]).astype(jnp.float32)
    A = (q * ev) @ q.T
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                          dtype=jnp.float32)
    return A, b


def test_recompute_guard_zero_false_restarts():
    """Regression: recompute_every x stagnation guard.  The recomputed
    true residual reads higher than the stale recursive minimum; before
    the window re-baseline that counted as "no improvement" and a
    healthy solve burned restarts into a false ``diverged``.  Restarts
    are not surfaced on SolveResult, so "zero restarts fired" is
    asserted as bit-exactness against a ``max_restarts=0`` run (a fired
    restart re-seeds the search direction and forks the trajectory)."""
    A, b = _drifty_spd()
    op = lambda v: A @ v  # noqa: E731
    kw = dict(recompute_every=3, stagnation_window=8, guard=True)
    # converging run: recompute more frequent than the window
    ra = solver.cg(op, b, tol=1e-5, max_iters=300, max_restarts=2, **kw)
    rb = solver.cg(op, b, tol=1e-5, max_iters=300, max_restarts=0, **kw)
    assert bool(ra.converged) and not bool(ra.diverged)
    assert int(ra.iterations) == int(rb.iterations)
    assert bool(jnp.all(ra.x == rb.x))
    # floor run: tol=0 parks the solve at the f32 drift floor, where the
    # pre-fix guard falsely diverged within ~64 iterations
    fa = solver.cg(op, b, tol=0.0, max_iters=300, max_restarts=2, **kw)
    fb = solver.cg(op, b, tol=0.0, max_iters=300, max_restarts=0, **kw)
    assert not bool(fa.diverged) and not bool(fb.diverged)
    assert bool(jnp.all(fa.x == fb.x))


def test_batched_frozen_column_bit_exact():
    """A column that converges early is frozen bit-exactly: running the
    batch longer (for the slow columns' sake) cannot touch it."""
    A, _ = _drifty_spd()
    n = A.shape[0]
    key = jax.random.PRNGKey(11)
    # col 0 low-mode-free (fast), col 1 random (slow)
    ev, q = jnp.linalg.eigh(A)
    del ev
    fast = (q[:, -n // 2:] @ jax.random.normal(
        key, (n // 2,), dtype=jnp.float32))
    slow = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                             dtype=jnp.float32)
    bb = jnp.stack([fast, slow])
    op = lambda v: v @ A.T  # noqa: E731
    full = solver.cg_batched(op, bb, tol=1e-3, max_iters=300,
                             recompute_every=5)
    it0, it1 = int(full.iterations[0]), int(full.iterations[1])
    assert bool(jnp.all(full.converged)) and it0 < it1
    short = solver.cg_batched(op, bb, tol=1e-3, max_iters=it0,
                              recompute_every=5)
    assert bool(short.converged[0])
    assert bool(jnp.all(full.x[0] == short.x[0]))


def test_cgnr_reports_true_system_residual():
    """Regression: cgnr's exit residual is the TRUE-system relative
    residual |b - A x| / |b| (recomputed at exit), not the normal-
    equations residual |A^H(b - A x)| the inner CG iterates on."""
    n = 80
    key = jax.random.PRNGKey(13)
    A = (jax.random.normal(key, (n, n), dtype=jnp.float32)
         + n * jnp.eye(n, dtype=jnp.float32))          # nonsymmetric
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                          dtype=jnp.float32)
    res = solver.cgnr(lambda v: A @ v, lambda v: A.T @ v, b,
                      tol=1e-6, max_iters=500)
    assert bool(res.converged)
    rel = float(jnp.linalg.norm(b - A @ res.x) / jnp.linalg.norm(b))
    assert np.isclose(float(res.residual), rel, rtol=1e-3, atol=1e-9)

    bb = jnp.stack([b, jax.random.normal(jax.random.fold_in(key, 2),
                                         (n,), dtype=jnp.float32)])
    bres = solver.cgnr_batched(lambda v: v @ A.T, lambda v: v @ A, bb,
                               tol=1e-6, max_iters=500)
    assert bool(jnp.all(bres.converged))
    rels = jnp.linalg.norm(bb - bres.x @ A.T, axis=1) \
        / jnp.linalg.norm(bb, axis=1)
    np.testing.assert_allclose(np.asarray(bres.residual),
                               np.asarray(rels), rtol=1e-3, atol=1e-9)
