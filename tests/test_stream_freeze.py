"""Per-column freeze semantics of the batched Krylov solvers, pinned
under the streaming fused backend.

A column that is converged (or broken down) must have its iterate
FROZEN — bit-exactly untouched — while the other columns keep iterating
through the shared ``lax.while_loop``.  These are regression tests for
the freeze contract itself (updates exactly zeroed, not merely small),
exercised through ``pallas_fused_stream`` native batched operators so
the contract is locked down on the new kernel path:

* column 0: zero RHS — converged at iteration 0, iterate must stay the
  exact zero vector through every subsequent iteration;
* column 1: pre-converged ``x0`` (solved tighter than the batched tol
  beforehand) — inactive from the start, iterate must remain the exact
  bits of the ``x0`` that was passed in;
* column 2: a live RHS — must converge normally, proving the frozen
  columns didn't gate the active one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core import evenodd, solver, su3

SHAPE = (2, 2, 2, 4)
KAPPA = 0.13
TOL = 1e-4


@pytest.fixture(scope="module")
def stream_setup():
    U = su3.random_gauge(jax.random.PRNGKey(7), SHAPE)
    k = jax.random.PRNGKey(8)
    psi = (jax.random.normal(k, (2, *SHAPE, 4, 3))
           + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                    (2, *SHAPE, 4, 3))).astype(jnp.complex64)
    e, _ = jax.vmap(evenodd.pack)(psi)
    Ue, Uo = evenodd.pack_gauge(U)
    opts = {} if jax.default_backend() == "tpu" else {"interpret": True}
    bops = backends.make_wilson_ops("pallas_fused_stream", Ue, Uo, **opts)

    # Pre-solve column 1 tighter than the batched TOL so it enters the
    # batched solve already converged (the jnp solution's residual under
    # the streaming kernel differs only by kernel roundoff ~1e-6).
    jops = backends.make_wilson_ops("jnp", Ue, Uo)
    res1 = solver.cgnr(lambda v: jops.apply_dhat(v, KAPPA),
                       lambda v: jops.apply_dhat_dagger(v, KAPPA),
                       e[1], tol=1e-7, max_iters=500)
    assert bool(res1.converged)

    b = jnp.stack([jnp.zeros_like(e[0]), e[1], e[0]])      # 3 columns
    vb = bops.to_domain_batched(b)
    x0 = jnp.zeros_like(vb).at[1].set(bops.to_domain(res1.x))
    return bops, vb, x0


def _check_freeze(res, vb, x0, bops):
    # Column 0: exact zero throughout.
    assert int(res.iterations[0]) == 0
    assert bool(res.converged[0])
    np.testing.assert_array_equal(np.asarray(res.x[0]),
                                  np.zeros_like(np.asarray(res.x[0])))
    # Column 1: the exact bits of the pre-converged x0.
    assert int(res.iterations[1]) == 0, res.iterations
    np.testing.assert_array_equal(np.asarray(res.x[1]),
                                  np.asarray(x0[1]))
    # Column 2: actually iterated and converged.
    assert int(res.iterations[2]) > 0
    assert bool(res.converged[2]), res
    # ...to a solution whose streaming-operator residual honors TOL.
    r = vb[2] - bops.apply_dhat_native(res.x[2], KAPPA)
    rel = float(jnp.sqrt(jnp.sum(r.astype(jnp.float32) ** 2)
                         / jnp.sum(vb[2].astype(jnp.float32) ** 2)))
    assert rel <= 5 * TOL, rel


def test_bicgstab_batched_freezes_per_column(stream_setup):
    bops, vb, x0 = stream_setup
    op = lambda w: bops.apply_dhat_native_batched(w, KAPPA)  # noqa: E731
    res = solver.bicgstab_batched(op, vb, x0=x0, tol=TOL, max_iters=200)
    _check_freeze(res, vb, x0, bops)


def test_cgnr_batched_freezes_per_column(stream_setup):
    bops, vb, x0 = stream_setup
    op = lambda w: bops.apply_dhat_native_batched(w, KAPPA)  # noqa: E731
    dag = lambda w: bops.apply_dhat_dagger_native_batched(w, KAPPA)  # noqa: E731
    res = solver.cgnr_batched(op, dag, vb, x0=x0, tol=TOL, max_iters=200)
    # cgnr reports the TRUE residual of the original system; the frozen
    # columns' bit-exactness contract is identical.
    _check_freeze(res, vb, x0, bops)


def test_cg_batched_freezes_per_column(stream_setup):
    """CG on the normal equations (Dhat^dag Dhat), the Hermitian form."""
    bops, vb, x0 = stream_setup
    op = lambda w: bops.apply_dhat_native_batched(w, KAPPA)  # noqa: E731
    dag = lambda w: bops.apply_dhat_dagger_native_batched(w, KAPPA)  # noqa: E731
    normal = lambda w: dag(op(w))  # noqa: E731
    bn = dag(vb)
    res = solver.cg_batched(normal, bn, x0=x0, tol=TOL, max_iters=200)
    # Column 0: zero RHS of the normal system too -> frozen zero.
    assert int(res.iterations[0]) == 0
    np.testing.assert_array_equal(np.asarray(res.x[0]),
                                  np.zeros_like(np.asarray(res.x[0])))
    # Column 1: pre-converged for the normal system as well (the normal
    # residual of a tight Dhat solution is tiny).
    assert int(res.iterations[1]) == 0, res.iterations
    np.testing.assert_array_equal(np.asarray(res.x[1]),
                                  np.asarray(x0[1]))
    assert int(res.iterations[2]) > 0
    assert bool(res.converged[2]), res
