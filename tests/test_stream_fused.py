"""Streaming plane-window fused Dhat: the three-way VMEM policy at its
exact byte boundaries, the cap-lift, and silent-correct fallback.

The resident fused kernel's scratch is the whole (batched) odd
intermediate — ``itemsize * nrhs * 24 * T*Z*Y*Xh`` bytes against a 12 MiB
budget.  The streaming kernel replaces it with a 4-row t-plane ring whose
size is independent of T.  These tests pin the selection policy
(resident -> stream -> unfused) at shapes exactly at / one plane over the
budget for f32/f64/bf16 and nrhs in {1, 8}, and that every path computes
the same operator.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backends
from repro.core import evenodd, su3
from repro.kernels import layout, ops
from repro.kernels import wilson_stencil as ws
from repro.kernels.wilson_stencil import (
    STREAM_WINDOW_ROWS, dhat_planar_fused_stream, dhat_stream_traffic_model,
    fused_dhat_fits, fused_dhat_policy, fused_dhat_stream_fits,
    stream_ring_bytes)

LIMIT = ws._FUSED_SCRATCH_LIMIT_BYTES
KAPPA = 0.13

ITEMSIZE = {"f32": 4, "f64": 8, "bf16": 2}
DTYPE = {"f32": jnp.float32, "f64": jnp.float64, "bf16": jnp.bfloat16}


def _resident_boundary_shape(itemsize, nrhs):
    """Planar shape whose resident scratch is EXACTLY the budget."""
    sites = LIMIT // (24 * itemsize * nrhs)     # = T*Z*Y*Xh at the budget
    T, Z, Y = 16, 16, 8
    Xh = sites // (T * Z * Y)
    assert T * Z * Y * Xh == sites, (itemsize, nrhs)
    shape = (T, Z, 24, Y, Xh)
    return (nrhs, *shape) if nrhs > 1 else shape


def _stream_boundary_shape(itemsize, nrhs):
    """Planar shape whose 4-row ring is EXACTLY the budget (T far too
    large for the resident scratch)."""
    row_sites = LIMIT // (24 * itemsize * nrhs * STREAM_WINDOW_ROWS)
    T, Z, Y = 64, 16, 8
    Xh = row_sites // (Z * Y)
    assert Z * Y * Xh == row_sites, (itemsize, nrhs)
    shape = (T, Z, 24, Y, Xh)
    return (nrhs, *shape) if nrhs > 1 else shape


def _bump(shape, axis_from_t):
    """Same shape with the (batched-aware) T or Z extent + 1 plane."""
    lead = 1 if len(shape) == 6 else 0
    i = lead + axis_from_t
    return shape[:i] + (shape[i] + 1,) + shape[i + 1:]


@pytest.mark.parametrize("nrhs", [1, 8])
@pytest.mark.parametrize("dt", ["f32", "f64", "bf16"])
def test_resident_boundary_exact_and_one_plane_over(dt, nrhs):
    """At the budget: resident.  One t-plane over: the resident scratch
    no longer fits but the ring trivially does -> stream."""
    item = ITEMSIZE[dt]
    shape = _resident_boundary_shape(item, nrhs)
    assert item * math.prod(shape) == LIMIT
    assert fused_dhat_fits(shape, DTYPE[dt])
    assert fused_dhat_policy(shape, DTYPE[dt]) == "resident"

    over = _bump(shape, 0)                      # one extra t-plane row
    assert not fused_dhat_fits(over, DTYPE[dt])
    assert fused_dhat_stream_fits(over, DTYPE[dt])
    assert fused_dhat_policy(over, DTYPE[dt]) == "stream"


@pytest.mark.parametrize("nrhs", [1, 8])
@pytest.mark.parametrize("dt", ["f32", "f64", "bf16"])
def test_stream_boundary_exact_and_one_plane_over(dt, nrhs):
    """At the budget the ring fits -> stream; one z-plane over it cannot
    (the ring holds full z-rows) -> the silent two-kernel fallback."""
    item = ITEMSIZE[dt]
    shape = _stream_boundary_shape(item, nrhs)
    assert stream_ring_bytes(shape, DTYPE[dt]) == LIMIT
    assert not fused_dhat_fits(shape, DTYPE[dt])
    assert fused_dhat_policy(shape, DTYPE[dt]) == "stream"

    over = _bump(shape, 1)                      # one extra z-plane
    assert not fused_dhat_stream_fits(over, DTYPE[dt])
    assert fused_dhat_policy(over, DTYPE[dt]) == "unfused"


def test_ring_bytes_independent_of_t():
    """The cap-lift itself: growing T leaves the ring untouched while the
    resident scratch grows linearly."""
    base = (8, 4, 24, 4, 4)
    tall = (512, 4, 24, 4, 4)
    assert stream_ring_bytes(base) == stream_ring_bytes(tall)
    assert (4 * math.prod(tall)) == 64 * (4 * math.prod(base))
    # Batched shapes scale the ring by nrhs, like the resident scratch.
    assert stream_ring_bytes((8, *base)) == 8 * stream_ring_bytes(base)


def test_acceptance_lattice_runs_streaming_policy():
    """16x16x16x32 at nrhs=8, f32 — the ISSUE's canonical cap casualty:
    the resident scratch (~50 MiB) fails, the ring is exactly 12 MiB."""
    shape = (8, 16, 16, 24, 16, 16)             # planar, batched
    assert not fused_dhat_fits(shape, jnp.float32)
    assert stream_ring_bytes(shape, jnp.float32) == LIMIT
    assert fused_dhat_policy(shape, jnp.float32) == "stream"
    # ...and f64 doubles the ring past the budget -> unfused fallback.
    assert fused_dhat_policy(shape, jnp.float64) == "unfused"


def test_stream_traffic_model_accounts_overhead():
    m = dhat_stream_traffic_model(16, 8, 8, 8, nrhs=2)
    r = ws.hop_traffic_model(16, 8, 8, 8, nrhs=2)
    assert m["recompute_rows"] == 2
    assert m["window_rows"] == STREAM_WINDOW_ROWS
    # Flops: two hopping blocks + 2 recomputed rows of the first + axpy.
    assert m["flops"] > 2 * r["flops"]
    assert m["flops"] < 2.2 * r["flops"]
    # The ring is window/T of the resident scratch.
    assert m["vmem_ring_bytes"] * 16 == m["vmem_resident_bytes"] * 4


def _rand_planar(shape, seed=0, nrhs=None):
    U = su3.random_gauge(jax.random.PRNGKey(seed), shape)
    k = jax.random.PRNGKey(seed + 1)
    bshape = (() if nrhs is None else (nrhs,)) + (*shape, 4, 3)
    psi = (jax.random.normal(k, bshape)
           + 1j * jax.random.normal(jax.random.fold_in(k, 1), bshape)
           ).astype(jnp.complex64)
    Ue, Uo = evenodd.pack_gauge(U)
    if nrhs is None:
        e, _ = evenodd.pack(psi)
    else:
        e, _ = jax.vmap(evenodd.pack)(psi)
    return Ue, Uo, e


def test_stream_kernel_matches_resident_and_unfused(small_eo):
    """All three fused paths compute the same operator (forced
    selection, planar in/out)."""
    Ue, Uo, e, _, kappa = small_eo
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    outs = {f: ops.apply_dhat_planar_any(Uep, Uop, ep, kappa, fused=f,
                                         interpret=True)
            for f in ("resident", "stream", "unfused")}
    np.testing.assert_allclose(np.asarray(outs["stream"]),
                               np.asarray(outs["unfused"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs["resident"]),
                               np.asarray(outs["unfused"]), atol=1e-5)
    # Booleans keep their legacy meaning.
    np.testing.assert_array_equal(
        np.asarray(ops.apply_dhat_planar_any(Uep, Uop, ep, kappa,
                                             fused=True, interpret=True)),
        np.asarray(outs["resident"]))
    with pytest.raises(ValueError, match="fused="):
        ops.apply_dhat_planar_any(Uep, Uop, ep, kappa, fused="bogus",
                                  interpret=True)


def test_auto_policy_routes_over_budget_lattice_to_stream(monkeypatch):
    """A lattice that fails ``fused_dhat_fits`` must run the STREAMING
    kernel under the auto policy (not the two-kernel fallback), and still
    match the jnp reference — the cap-lift acceptance shape in miniature
    (the budget is shrunk instead of the lattice grown; the policy reads
    the live module constant).  T=8 > the 4-row window, so the ring is
    strictly smaller than the resident scratch."""
    Ue, Uo, e = _rand_planar((8, 2, 2, 4), seed=23)
    kappa = KAPPA
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    # Budget below the resident scratch but above the 4-row ring.
    resident = 4 * math.prod(ep.shape)
    ring = stream_ring_bytes(ep.shape)
    assert ring < resident
    monkeypatch.setattr(ws, "_FUSED_SCRATCH_LIMIT_BYTES", ring)
    assert fused_dhat_policy(ep.shape, ep.dtype) == "stream"

    jaxpr = str(jax.make_jaxpr(
        lambda v: ops.apply_dhat_planar_any(Uep, Uop, v, kappa,
                                            interpret=True))(ep))
    assert "wilson_dhat_fused_stream" in jaxpr
    assert jaxpr.count("pallas_call") == 1

    got = layout.spinor_from_planar(
        ops.apply_dhat_planar_any(Uep, Uop, ep, kappa, interpret=True))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.apply_dhat(e, kappa)),
                               atol=1e-5)


def test_auto_policy_unfused_fallback_is_silent_correct(monkeypatch,
                                                        small_eo):
    """Below even the ring budget the auto policy must silently produce
    the correct operator through the two-kernel path."""
    Ue, Uo, e, _, kappa = small_eo
    ref = backends.make_wilson_ops("jnp", Ue, Uo)
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    monkeypatch.setattr(ws, "_FUSED_SCRATCH_LIMIT_BYTES", 1)
    assert fused_dhat_policy(ep.shape, ep.dtype) == "unfused"
    got = layout.spinor_from_planar(
        ops.apply_dhat_planar_any(Uep, Uop, ep, kappa, interpret=True))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.apply_dhat(e, kappa)),
                               atol=1e-5)


def test_stream_kernel_rejects_over_budget_ring_off_interpret():
    """On real hardware an over-budget ring must fail loudly, before any
    lowering (mirrors the resident kernel's guard)."""
    Z, Y, Xh = 17, 32, 64                       # ring 4*Z*24*Y*Xh*4 > 12MiB
    ep = jnp.zeros((8, Z, 24, Y, Xh), jnp.float32)
    u = jnp.zeros((4, 8, Z, 18, Y, Xh), jnp.float32)
    assert not fused_dhat_stream_fits(ep.shape)
    with pytest.raises(ValueError, match="streaming Dhat ring"):
        dhat_planar_fused_stream(u, u, ep, KAPPA, interpret=False)


def test_stream_kernel_rejects_too_small_window(small_eo):
    Ue, Uo, e, _, _ = small_eo
    Uep, Uop = ops.make_planar_fields(Ue, Uo)
    ep = layout.spinor_to_planar(e)
    with pytest.raises(ValueError, match="stream window"):
        dhat_planar_fused_stream(Uep, Uop, ep, KAPPA, window=3,
                                 interpret=True)


def test_stream_backend_registered_and_batched_single_kernel():
    """pallas_fused_stream registers like any other backend; its batched
    Dhat lowers to ONE pallas_call for the whole RHS block."""
    assert "pallas_fused_stream" in backends.available_backends()
    Ue, Uo, e = _rand_planar((4, 4, 4, 8), seed=3, nrhs=4)
    bops = backends.make_wilson_ops("pallas_fused_stream", Ue, Uo,
                                    interpret=True)
    assert bops.domain == "planar"
    v = bops.to_domain_batched(e)
    txt = str(jax.make_jaxpr(
        lambda w: bops.apply_dhat_native_batched(w, KAPPA))(v))
    assert txt.count("pallas_call") == 1
    assert "wilson_dhat_fused_stream" in txt
